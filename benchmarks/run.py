"""Paper-table benchmarks (one function per table), on the engine API.

Reproduces the NNCG evaluation on the container CPU:
  * Tables IV/V/VI — per-image inference latency of the generated C
    (compiled with the host cc, the paper's deployment path) vs. the XLA
    baseline (jax.jit == today's TF-XLA stack, the paper's main rival).
    The C build is *autotuned*: the engine benchmarks every per-layer
    codegen variant and keeps the fastest (paper Table VII selection),
    caching the result on disk so reruns compile nothing.
  * residual — the DAG workload (depthwise + residual Add + Concat),
    same comparison; unrepresentable before the graph IR.
  * int8 — every network also runs through the post-training-quantized
    C build (per-channel int8 weights, int8 intermediates, int32
    accumulators): latency vs the float C path, top-1 agreement with
    the float oracle on the calibration set, and the byte-planned
    arena (~4x smaller than the float arena).  Calibration runs on
    synthetic *camera-like* frames (bounded, spatially smooth — the
    input domain the paper's nets actually see) with histogram-
    percentile range selection; the recorded ``int8_top1_agreement``
    is a hard >= 0.99 gate on every net.
  * Table VII — feature ablation: generic scalar C -> SSE layout ->
    SSE + full unroll -> autotuned per-layer selection.
  * lm — the LM workload behind the same session surface (PR 9):
    prefill tokens/s and decode ms/token of the reduced gemma3-4b
    through the ``"pallas-lm"`` backend with its autotuned Pallas
    kernel-variant policy, persisted as the ``"lm"`` section.

Prints ``name,us_per_call,derived,arena_bytes`` CSV rows; ``derived``
is the speed-up over the XLA baseline (Tables IV-VI) or over the
generic build (Table VII); ``arena_bytes`` is the liveness-planned
workspace of the C build (empty for non-C rows).

Results are also persisted to ``BENCH_engine.json`` at the repo root so
the perf/memory trajectory is tracked across PRs.
"""
from __future__ import annotations

import json
import os
import platform
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.cnn_paper import EXTRA_CNNS, PAPER_CNNS  # noqa: E402
from repro.core import runtime  # noqa: E402
from repro.data.pipeline import camera_frame_batch  # noqa: E402
from repro.engine import (CalibrationConfig, InferenceSession,  # noqa: E402
                          SessionConfig)

ITERS = {"ball": 20000, "pedestrian": 3000, "robot": 800, "residual": 5000}
ALL_CNNS = {**PAPER_CNNS, **EXTRA_CNNS}
BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_engine.json")

# histogram-observer calibration: percentile range selection on
# representative frames (minmax on noise was the robot-net accuracy
# regression — agreement 0.94; see core/quantize.py)
CALIBRATION_METHOD = "percentile"
INT8_AGREEMENT_GATE = 0.99
# perf ratchet: a new run's int8_speedup_vs_c may not fall below this
# fraction of the value persisted in BENCH_engine.json (the slack
# absorbs scheduler noise; a kernel regression is far larger)
INT8_RATCHET_TOLERANCE = 0.90
# layer pipelining: batch-1 stream through the k-stage build vs the
# monolithic build.  The >1.15x win requires a second core — on a
# single-core host the ratio is < 1 by construction (every hand-off is
# pure overhead), so the gate only arms when the host can express the
# parallelism; the measured ratio is recorded honestly either way and
# ratcheted like the int8 speedup.
PIPELINE_GATE = 1.15
PIPELINE_GATE_MIN_NETS = 2
PIPELINE_RATCHET_TOLERANCE = 0.90

RESULTS: dict = {"cnns": {}, "ablation": {}, "lm": {}}

# the LM rows: reduced gemma3-4b through the unified session (Pallas
# variants autotuned exactly like C unroll levels, winner cached)
LM_ARCH = "gemma3-4b"
LM_BATCH, LM_PROMPT, LM_NEW = 4, 24, 16


def _prior_results() -> dict:
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                return json.load(f).get("cnns", {})
        except (OSError, ValueError):
            pass
    return {}


def _check_int8_ratchet(name: str, speedup: float, t_q: float) -> None:
    prior = _prior_results().get(name, {})
    ps = prior.get("int8_speedup_vs_c")
    if ps is None:
        return
    floor = float(ps) * INT8_RATCHET_TOLERANCE
    if speedup >= floor:
        return
    # the ratio also falls when the float *denominator* improves (e.g.
    # a better tuning under a new schedule) — that is a win, and this
    # run's own _persist re-baselines it.  Blame the kernels only when
    # the absolute int8 time itself rose past the same tolerance.
    pq = prior.get("c_int8_us")
    assert pq is not None and t_q <= float(pq) / INT8_RATCHET_TOLERANCE, (
        f"{name}: int8_speedup_vs_c regressed to {speedup:.3f} "
        f"(persisted {ps:.3f}, ratchet floor {floor:.3f}) and c_int8_us "
        f"rose to {t_q:.2f} (persisted {pq}) — the tiled kernels got "
        f"slower; fix the regression or consciously re-baseline "
        f"BENCH_engine.json")
    print(f"# {name}: int8_speedup_vs_c {speedup:.3f} below floor "
          f"{floor:.3f} but c_int8_us {t_q:.2f} holds (persisted {pq}): "
          f"float denominator improved, re-baselining")


def _check_pipeline_ratchet(name: str, speedup: float,
                            t_pipe: float) -> None:
    prior = _prior_results().get(name, {})
    ps = prior.get("pipeline_speedup_batch1")
    if ps is None:
        return
    floor = float(ps) * PIPELINE_RATCHET_TOLERANCE
    if speedup >= floor:
        return
    # same denominator guard as the int8 ratchet: a faster sequential
    # stream drops the ratio without the pipelined build regressing
    pp = prior.get("pipeline_stream_us")
    assert pp is not None and t_pipe <= float(pp) / \
        PIPELINE_RATCHET_TOLERANCE, (
        f"{name}: pipeline_speedup_batch1 regressed to {speedup:.3f} "
        f"(persisted {ps:.3f}, ratchet floor {floor:.3f}) and "
        f"pipeline_stream_us rose to {t_pipe:.2f} (persisted {pp}) — "
        f"the pipelined stream got slower; fix the regression or "
        f"consciously re-baseline BENCH_engine.json")
    print(f"# {name}: pipeline_speedup_batch1 {speedup:.3f} below floor "
          f"{floor:.3f} but pipeline_stream_us {t_pipe:.2f} holds "
          f"(persisted {pp}): sequential baseline improved, "
          f"re-baselining")


def _pipeline_stream_us(g, simd, *, frames: int = 64,
                        repeats: int = 3):
    """Batch-1 stream latency of the monolithic vs the layer-pipelined
    build of the same fused schedule: the pipeline's target workload is
    a camera stream (one frame in flight per stage), so the honest
    comparison is per-frame time of ``predict_batch`` over a frame
    stream, not single-call latency.  Returns
    ``(seq_us_per_frame, pipe_us_per_frame, nstages_timed)``."""
    from repro.core import cgen
    from repro.core.schedule import make_schedule
    from repro.engine.autotune import pipeline_stage_candidates

    # time a real 2-stage build even on a single-core host (where the
    # candidate list is just [1]) — the recorded ratio documents what
    # pipelining costs/buys on *this* machine
    nstages = max(pipeline_stage_candidates() + [2])
    # rolled loops: both builds share the emission style, so the ratio
    # isolates the schedule; the default full unroll would cost minutes
    # of -O3 compile per net for a column about threading
    opts = cgen.CodegenOptions(simd=simd, unroll=None)
    base = runtime.build(g, opts,
                         schedule=make_schedule(g, nstages=1))
    pipe = runtime.build(g, opts,
                         schedule=make_schedule(g, nstages=nstages))
    x = camera_frame_batch(frames, g.input_shape, seed=3)

    def stream_us(net) -> float:
        net.predict_batch(x[:8])          # warm arena pages + threads
        best = None
        for _ in range(repeats):          # min: scheduler-noise guard
            t0 = time.perf_counter()
            net.predict_batch(x)
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        return best / frames * 1e6

    return stream_us(base), stream_us(pipe), nstages


def _check_pipeline_gate() -> None:
    cpus = os.cpu_count() or 1
    if cpus < 2:
        print(f"# pipeline gate skipped: single-core host (cpus={cpus}) "
              f"— stage parallelism needs a second core; ratios "
              f"recorded as measured")
        return
    wins = [n for n, r in RESULTS["cnns"].items()
            if r.get("pipeline_speedup_batch1", 0.0) > PIPELINE_GATE]
    assert len(wins) >= PIPELINE_GATE_MIN_NETS, (
        f"pipeline_speedup_batch1 > {PIPELINE_GATE} on only "
        f"{len(wins)} net(s) ({wins}) with {cpus} cores — expected "
        f">= {PIPELINE_GATE_MIN_NETS}")


def _bench_cnn(name: str):
    simd = runtime.best_isa()
    iters = ITERS[name]
    tune_iters = max(200, iters // 20)
    if name == "ball":
        # the ROADMAP accuracy gate: calibrate and evaluate the ball
        # net *trained* on its dataset, on real frames of that dataset
        # (a random-weight 2-class softmax is a coin flip — its top-1
        # agreement measures tie-breaking luck, not calibration)
        from repro.configs.cnn_paper import trained_ball_classifier
        from repro.data.pipeline import ball_image_batch
        g, _ = trained_ball_classifier(steps=150, seed=0)
        calib = ball_image_batch(32, seed=1)[0]
    else:
        g = ALL_CNNS[name]()
        calib = camera_frame_batch(32, g.input_shape, seed=1)
    x = np.random.default_rng(0).normal(
        size=g.input_shape).astype(np.float32)

    tuned = InferenceSession(g, config=SessionConfig(
        backend="c", autotune=True, simd=simd, tune_iters=tune_iters))
    untuned = InferenceSession(g, config=SessionConfig(backend="c",
                                                       simd=simd))
    int8 = InferenceSession(g, config=SessionConfig(
        backend="c", precision="int8", autotune=True,
        tune_iters=tune_iters,
        calibration=CalibrationConfig(data=calib,
                                      method=CALIBRATION_METHOD)))
    xla = InferenceSession(g, config=SessionConfig(backend="xla"))

    # correctness gates before timing
    ref = xla.predict(x)
    np.testing.assert_allclose(tuned.predict(x), ref, rtol=1e-3, atol=1e-5)
    # the compiled int8 build must match its bit-faithful jax reference
    from repro.core import jax_exec
    from repro.core.quantize import quantization_error
    qref = np.asarray(jax_exec.forward_quantized(int8.qgraph, x[None]))[0]
    np.testing.assert_allclose(int8.predict(x).reshape(qref.shape), qref,
                               rtol=1e-5, atol=1e-6)
    qstats = quantization_error(int8.qgraph, calib)
    assert qstats["top1_agreement"] >= INT8_AGREEMENT_GATE, (
        f"{name}: int8 top-1 agreement "
        f"{qstats['top1_agreement']:.4f} < {INT8_AGREEMENT_GATE} "
        f"(calibration_method={int8.qgraph.method})")

    # min over repeats for the two ratcheted timings: the int8 ratchet
    # asserts on t_c/t_q, and a scheduler-noise spike in either single
    # measurement would fail the gate (or persist a soft baseline)
    t_c = min(tuned.benchmark(x, iters=iters) for _ in range(3))
    t_u = untuned.benchmark(x, iters=iters)
    t_q = min(int8.benchmark(x, iters=iters) for _ in range(3))
    t_x = xla.benchmark(x, iters=max(iters // 10, 100))
    arena = tuned.info["arena_bytes"]
    _check_int8_ratchet(name, t_c / t_q, t_q)
    t_seq_stream, t_pipe_stream, pstages = _pipeline_stream_us(g, simd)
    pipe_speedup = t_seq_stream / t_pipe_stream
    _check_pipeline_ratchet(name, pipe_speedup, t_pipe_stream)

    # fusion record (feeds the README table): what the deployed float
    # schedule fused, whether int8 autotune deployed the fused build,
    # and the arena comparison at the canonical rolled build — the
    # make_schedule invariant (fused arena never grows) re-checked on
    # the real nets every benchmark run
    from repro.core import cgen, codegen
    from repro.core.schedule import make_schedule
    g_opt = tuned.graph
    ropts = cgen.CodegenOptions(simd=simd, unroll=None)
    arena_fused = codegen.compile(
        g_opt, ropts, schedule=make_schedule(g_opt)).arena_bytes
    arena_unfused = codegen.compile(
        g_opt, ropts,
        schedule=make_schedule(g_opt, fusion=False)).arena_bytes
    assert arena_fused <= arena_unfused, name
    sd = tuned.schedule.describe()
    fusion_rec = {
        "fused_adds": len(sd["fused_adds"]),
        "fused_pools": len(sd["fused_pools"]),
        "fused_concats": len(sd["fused_concats"]),
        "arena_bytes_fused": arena_fused,
        "arena_bytes_unfused": arena_unfused,
        "int8_deployed_fused": bool(int8.schedule is not None
                                    and int8.schedule.has_fusion),
    }
    print(f"table_{name}_nncg_c_autotuned,{t_c:.2f},"
          f"speedup_vs_xla={t_x / t_c:.2f},{arena}")
    print(f"table_{name}_nncg_c_untuned,{t_u:.2f},"
          f"autotune_gain={t_u / t_c:.2f},{untuned.info['arena_bytes']}")
    print(f"table_{name}_nncg_c_int8,{t_q:.2f},"
          f"speedup_vs_c={t_c / t_q:.2f},"
          f"variant={int8.simd},{int8.info['arena_bytes']}")
    print(f"table_{name}_xla_jit,{t_x:.2f},baseline=1.0,")
    print(f"table_{name}_nncg_c_pipelined,{t_pipe_stream:.2f},"
          f"pipeline_speedup_batch1={pipe_speedup:.2f},"
          f"stages={pstages}")
    RESULTS["cnns"][name] = {
        "c_autotuned_us": round(t_c, 3),
        "c_untuned_us": round(t_u, 3),
        "c_int8_us": round(t_q, 3),
        "xla_us": round(t_x, 3),
        "speedup_vs_xla": round(t_x / t_c, 3),
        "int8_speedup_vs_c": round(t_c / t_q, 3),
        "int8_kernel_variant": int8.simd,
        "int8_arena_bytes": int8.info["arena_bytes"],
        "int8_top1_agreement": round(qstats["top1_agreement"], 4),
        "int8_max_abs_err": round(qstats["max_abs_err"], 6),
        "calibration_method": int8.qgraph.method,
        "arena_bytes": arena,
        "arena_buffer_sum_bytes": tuned.info["arena_buffer_sum_bytes"],
        "peak_live_bytes": tuned.info["peak_live_bytes"],
        "pipeline_speedup_batch1": round(pipe_speedup, 3),
        "pipeline_stages_timed": pstages,
        "pipeline_stream_us": round(t_pipe_stream, 3),
        "sequential_stream_us": round(t_seq_stream, 3),
        "simd": simd,
        "fusion": fusion_rec,
    }
    return t_c, t_u, t_x


def bench_table4_ball():
    return _bench_cnn("ball")


def bench_table5_pedestrian():
    return _bench_cnn("pedestrian")


def bench_table6_robot():
    return _bench_cnn("robot")


def bench_residual_dag():
    """The DAG workload — depthwise separable block, residual Add,
    Concat — through the same autotuned C vs. XLA comparison."""
    return _bench_cnn("residual")


def bench_table7_features():
    name = "ball"
    iters = ITERS[name]
    g = PAPER_CNNS[name]()
    x = np.random.default_rng(0).normal(
        size=g.input_shape).astype(np.float32)
    sse = "sse" if runtime.host_supports_ssse3() else "structured"

    sessions = {
        "general": InferenceSession(g, config=SessionConfig(
            backend="c", simd="generic", unroll=None)),
        "simd": InferenceSession(g, config=SessionConfig(
            backend="c", simd=sse, unroll=None)),
        "simd_full_unroll": InferenceSession(g, config=SessionConfig(
            backend="c", simd=sse, unroll="auto")),
        "simd_autotuned": InferenceSession(g, config=SessionConfig(
            backend="c", simd=sse, autotune=True,
            tune_iters=max(200, iters // 20))),
    }
    if runtime.host_supports_avx2():  # the paper's named future work
        sessions["avx_fma_autotuned"] = InferenceSession(
            g, config=SessionConfig(backend="c", simd="avx", autotune=True,
                                    tune_iters=max(200, iters // 20)))

    rows = {}
    t_gen = None
    for label, sess in sessions.items():
        t = sess.benchmark(x, iters=iters)
        t_gen = t_gen if t_gen is not None else t
        arena = sess.info["arena_bytes"]  # each build plans its own arena
        print(f"table7_{label},{t:.2f},speedup={t_gen / t:.2f},{arena}")
        rows[f"{label}_us"] = round(t, 3)
        rows[f"{label}_arena_bytes"] = arena
    RESULTS["ablation"] = rows


def bench_lm():
    """The LM workload through the same engine surface: prefill
    throughput (tokens/s) and decode latency (ms/token) of the
    ``"pallas-lm"`` backend with its autotuned kernel policy."""
    from repro.engine import LMConfig, LMSession

    sess = LMSession(config=SessionConfig(
        backend="pallas-lm", autotune=True,
        lm=LMConfig(arch=LM_ARCH, max_context=LM_PROMPT + LM_NEW,
                    decode_batch=LM_BATCH)))
    prompts = np.random.default_rng(0).integers(
        0, sess.model_cfg.vocab_size,
        (LM_BATCH, LM_PROMPT)).astype(np.int32)

    logits, _ = sess.prefill(prompts)       # warm: jit compile both steps
    tok0 = np.argmax(logits, -1).astype(np.int32)

    t_prefill = None
    for _ in range(3):                      # min: scheduler-noise guard
        t0 = time.perf_counter()
        logits, handle = sess.prefill(prompts)
        dt = time.perf_counter() - t0
        t_prefill = dt if t_prefill is None else min(t_prefill, dt)
    sess.decode(handle, tok0)               # warm the decode program
    t_decode = None
    for _ in range(3):
        _, handle = sess.prefill(prompts)
        tok = tok0
        t0 = time.perf_counter()
        for _ in range(LM_NEW):
            tok = np.argmax(sess.decode(handle, tok), -1).astype(np.int32)
        dt = time.perf_counter() - t0
        t_decode = dt if t_decode is None else min(t_decode, dt)

    prefill_tok_s = LM_BATCH * LM_PROMPT / t_prefill
    decode_ms_tok = t_decode / LM_NEW * 1e3  # per step (batch rides free)
    pol = dict(sess.kernel_policy._asdict())
    print(f"lm_{LM_ARCH}_prefill,{t_prefill * 1e6:.0f},"
          f"prefill_tokens_per_s={prefill_tok_s:.0f},")
    print(f"lm_{LM_ARCH}_decode,{t_decode * 1e6:.0f},"
          f"decode_ms_per_token={decode_ms_tok:.2f},")
    RESULTS["lm"][LM_ARCH] = {
        "arch": sess.model_cfg.name,
        "batch": LM_BATCH,
        "prompt_tokens": LM_PROMPT,
        "new_tokens": LM_NEW,
        "prefill_tokens_per_s": round(prefill_tok_s, 1),
        "decode_ms_per_token": round(decode_ms_tok, 3),
        "kernel_policy": pol,
        "tuned_from_cache": bool(sess.tuned.from_cache),
        "n_params": sess.backend.describe()["n_params"],
    }


def _persist() -> None:
    RESULTS["meta"] = {
        "cc": runtime.cc_fingerprint(),
        "isa": runtime.best_isa(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "cpus": os.cpu_count(),
    }
    # read-modify-write: other benchmarks (serve_bench) own their own
    # top-level sections — don't clobber them
    merged = {}
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
    merged.update(RESULTS)
    with open(BENCH_JSON, "w") as f:
        json.dump(merged, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {os.path.normpath(BENCH_JSON)}")


def main() -> None:
    print("name,us_per_call,derived,arena_bytes")
    bench_table4_ball()
    bench_table5_pedestrian()
    bench_table6_robot()
    bench_residual_dag()
    bench_table7_features()
    bench_lm()
    _check_pipeline_gate()
    _persist()


if __name__ == "__main__":
    main()
