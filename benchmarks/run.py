"""Paper-table benchmarks (one function per table).

Reproduces the NNCG evaluation on the container CPU:
  * Tables IV/V/VI — per-image inference latency of the generated C
    (compiled with the host cc, the paper's deployment path) vs. the XLA
    baseline (jax.jit == today's TF-XLA stack, the paper's main rival).
  * Table VII — feature ablation: generic scalar C -> SSE layout ->
    SSE + full unroll (+ an autotuned per-layer variant, the paper's
    "benchmark every code version per layer" selection).

Prints ``name,us_per_call,derived`` CSV rows; ``derived`` is the
speed-up over the XLA baseline (Tables IV-VI) or over the generic build
(Table VII).
"""
from __future__ import annotations

import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.cnn_paper import PAPER_CNNS  # noqa: E402
from repro.core import cgen, jax_exec, passes, runtime  # noqa: E402

ITERS = {"ball": 20000, "pedestrian": 3000, "robot": 800}


def _xla_us(graph, x, iters) -> float:
    f = jax_exec.make_jit_forward(graph)
    xb = jnp.asarray(x[None])
    f(xb).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        f(xb).block_until_ready()
    return (time.perf_counter() - t0) / iters * 1e6


def _nncg_net(graph, simd="sse", unroll="auto", budget=20000):
    opts = cgen.CodegenOptions(
        simd=simd,
        unroll=cgen.choose_levels(graph, budget) if unroll == "auto"
        else unroll)
    return runtime.build(graph, opts)


def _bench_cnn(name: str):
    simd = runtime.best_isa()
    width = cgen.ISAS[simd].width if simd in cgen.ISAS else 4
    g = passes.optimize(PAPER_CNNS[name](), simd_multiple=width)
    x = np.random.default_rng(0).normal(size=g.input_shape).astype(np.float32)
    iters = ITERS[name]
    # paper §II-B.1: per-layer variant selection by benchmarking
    levels, _ = autotune_levels(g, simd, x, iters=max(200, iters // 20))
    net = runtime.build(g, cgen.CodegenOptions(simd=simd, unroll=levels))
    # correctness gate before timing
    ref = jax_exec.predict(g, x)
    np.testing.assert_allclose(net(x).reshape(ref.shape), ref,
                               rtol=1e-3, atol=1e-5)
    t_c = net.time_per_call_us(x, iters=iters)
    t_x = _xla_us(g, x, max(iters // 10, 100))
    print(f"table_{name}_nncg_c,{t_c:.2f},speedup_vs_xla={t_x / t_c:.2f}")
    print(f"table_{name}_xla_jit,{t_x:.2f},baseline=1.0")
    return t_c, t_x


def bench_table4_ball():
    return _bench_cnn("ball")


def bench_table5_pedestrian():
    return _bench_cnn("pedestrian")


def bench_table6_robot():
    return _bench_cnn("robot")


def autotune_levels(graph, simd: str, x, iters=3000):
    """The paper's per-layer variant selection: benchmark every unroll
    level per layer (greedy coordinate descent) and keep the fastest."""
    from repro.core.graph import Conv2D, MaxPool
    levels = cgen.choose_levels(graph, 20000)
    best = runtime.build(graph, cgen.CodegenOptions(
        simd=simd, unroll=dict(levels))).time_per_call_us(x, iters=iters)
    shape = graph.input_shape
    shapes = {}
    cur = shape
    for layer in graph.layers:
        shapes[layer.name] = cur
        cur = layer.out_shape(cur)
    for layer in graph.layers:
        if not isinstance(layer, (Conv2D, MaxPool)):
            continue
        for lvl in (0, 1, 2, None):
            if levels.get(layer.name) == lvl:
                continue
            if cgen.estimate_terms(layer, shapes[layer.name],
                                   lvl) > 200_000:
                continue
            trial = dict(levels)
            trial[layer.name] = lvl
            t = runtime.build(graph, cgen.CodegenOptions(
                simd=simd, unroll=trial)).time_per_call_us(x, iters=iters)
            if t < best:
                best, levels = t, trial
    return levels, best


def bench_table7_features():
    g4 = passes.optimize(PAPER_CNNS["ball"](), simd_multiple=4)
    x = np.random.default_rng(0).normal(size=g4.input_shape).astype(np.float32)
    iters = ITERS["ball"]
    sse = "sse" if runtime.host_supports_ssse3() else "structured"

    t_gen = _nncg_net(g4, simd="generic", unroll=None).time_per_call_us(
        x, iters=iters)
    t_sse = _nncg_net(g4, simd=sse, unroll=None).time_per_call_us(
        x, iters=iters)
    t_full = _nncg_net(g4, simd=sse, unroll="auto").time_per_call_us(
        x, iters=iters)
    _, t_tuned = autotune_levels(g4, sse, x)
    print(f"table7_general,{t_gen:.2f},speedup=1.0")
    print(f"table7_simd,{t_sse:.2f},speedup={t_gen / t_sse:.2f}")
    print(f"table7_simd_full_unroll,{t_full:.2f},speedup={t_gen / t_full:.2f}")
    print(f"table7_simd_autotuned,{t_tuned:.2f},speedup={t_gen / t_tuned:.2f}")
    if runtime.host_supports_avx2():  # the paper's named future work
        g8 = passes.optimize(PAPER_CNNS["ball"](), simd_multiple=8)
        _, t_avx = autotune_levels(g8, "avx", x)
        print(f"table7_avx_fma_autotuned,{t_avx:.2f},"
              f"speedup={t_gen / t_avx:.2f}")


def main() -> None:
    print("name,us_per_call,derived")
    bench_table4_ball()
    bench_table5_pedestrian()
    bench_table6_robot()
    bench_table7_features()


if __name__ == "__main__":
    main()
