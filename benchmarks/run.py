"""Paper-table benchmarks (one function per table), on the engine API.

Reproduces the NNCG evaluation on the container CPU:
  * Tables IV/V/VI — per-image inference latency of the generated C
    (compiled with the host cc, the paper's deployment path) vs. the XLA
    baseline (jax.jit == today's TF-XLA stack, the paper's main rival).
    The C build is *autotuned*: the engine benchmarks every per-layer
    codegen variant and keeps the fastest (paper Table VII selection),
    caching the result on disk so reruns compile nothing.
  * Table VII — feature ablation: generic scalar C -> SSE layout ->
    SSE + full unroll -> autotuned per-layer selection.

Prints ``name,us_per_call,derived`` CSV rows; ``derived`` is the
speed-up over the XLA baseline (Tables IV-VI) or over the generic build
(Table VII).
"""
from __future__ import annotations

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.cnn_paper import PAPER_CNNS  # noqa: E402
from repro.core import runtime  # noqa: E402
from repro.engine import InferenceSession  # noqa: E402

ITERS = {"ball": 20000, "pedestrian": 3000, "robot": 800}


def _bench_cnn(name: str):
    simd = runtime.best_isa()
    iters = ITERS[name]
    tune_iters = max(200, iters // 20)
    g = PAPER_CNNS[name]()
    x = np.random.default_rng(0).normal(
        size=g.input_shape).astype(np.float32)

    tuned = InferenceSession(g, backend="c", autotune=True, simd=simd,
                             tune_iters=tune_iters)
    untuned = InferenceSession(g, backend="c", simd=simd)
    xla = InferenceSession(g, backend="xla")

    # correctness gate before timing
    ref = xla.predict(x)
    np.testing.assert_allclose(tuned.predict(x), ref, rtol=1e-3, atol=1e-5)

    t_c = tuned.benchmark(x, iters=iters)
    t_u = untuned.benchmark(x, iters=iters)
    t_x = xla.benchmark(x, iters=max(iters // 10, 100))
    print(f"table_{name}_nncg_c_autotuned,{t_c:.2f},"
          f"speedup_vs_xla={t_x / t_c:.2f}")
    print(f"table_{name}_nncg_c_untuned,{t_u:.2f},"
          f"autotune_gain={t_u / t_c:.2f}")
    print(f"table_{name}_xla_jit,{t_x:.2f},baseline=1.0")
    return t_c, t_u, t_x


def bench_table4_ball():
    return _bench_cnn("ball")


def bench_table5_pedestrian():
    return _bench_cnn("pedestrian")


def bench_table6_robot():
    return _bench_cnn("robot")


def bench_table7_features():
    name = "ball"
    iters = ITERS[name]
    g = PAPER_CNNS[name]()
    x = np.random.default_rng(0).normal(
        size=g.input_shape).astype(np.float32)
    sse = "sse" if runtime.host_supports_ssse3() else "structured"

    t_gen = InferenceSession(g, backend="c", simd="generic",
                             unroll=None).benchmark(x, iters=iters)
    t_sse = InferenceSession(g, backend="c", simd=sse,
                             unroll=None).benchmark(x, iters=iters)
    t_full = InferenceSession(g, backend="c", simd=sse,
                              unroll="auto").benchmark(x, iters=iters)
    tuned = InferenceSession(g, backend="c", simd=sse, autotune=True,
                             tune_iters=max(200, iters // 20))
    t_tuned = tuned.benchmark(x, iters=iters)
    print(f"table7_general,{t_gen:.2f},speedup=1.0")
    print(f"table7_simd,{t_sse:.2f},speedup={t_gen / t_sse:.2f}")
    print(f"table7_simd_full_unroll,{t_full:.2f},speedup={t_gen / t_full:.2f}")
    print(f"table7_simd_autotuned,{t_tuned:.2f},speedup={t_gen / t_tuned:.2f}")
    if runtime.host_supports_avx2():  # the paper's named future work
        avx = InferenceSession(g, backend="c", simd="avx", autotune=True,
                               tune_iters=max(200, iters // 20))
        t_avx = avx.benchmark(x, iters=iters)
        print(f"table7_avx_fma_autotuned,{t_avx:.2f},"
              f"speedup={t_gen / t_avx:.2f}")


def main() -> None:
    print("name,us_per_call,derived")
    bench_table4_ball()
    bench_table5_pedestrian()
    bench_table6_robot()
    bench_table7_features()


if __name__ == "__main__":
    main()
