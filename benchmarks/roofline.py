"""Roofline analysis from the dry-run artifacts (deliverable (g)).

Reads results/dryrun/*.json and derives, per (arch x shape) on the
single-pod 16x16 mesh:

    compute term    = HLO_FLOPs_per_device / 197e12        [s]
    memory term     = HLO_bytes_per_device / 819e9         [s]
    collective term = collective_bytes_per_device / 50e9   [s]

HLO costs come from the *probe* lowerings (two unrolled group counts,
finite-differenced and extrapolated to the full depth) because
HloCostAnalysis counts a scanned while-body once. ``cost_analysis`` on
the partitioned module is per-device (verified against an analytic
matmul: ratio 255 ≈ 256 chips), so the spec's global/(chips*BW) equals
our per-device/BW. Memory figures come from the full scanned compile.
"""
from __future__ import annotations

import json
import math
import os
import sys
from typing import Dict, Optional

PEAK_FLOPS = 197e12     # bf16 / chip (TPU v5e)
HBM_BW = 819e9          # bytes/s / chip
LINK_BW = 50e9          # bytes/s / link (ICI)
CHIPS = 256             # single pod 16x16

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.lm_archs import ARCHS, SHAPES, all_cells  # noqa: E402
from repro.models.lm import active_param_count, param_count  # noqa: E402

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                          "dryrun")


def _load(arch, shape, tag) -> Optional[dict]:
    p = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__{tag}.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def extrapolate(probe: dict, cfg) -> Dict[str, float]:
    """cost(full) = g1 + (g2 - g1) * (n_groups - 1)."""
    g1, g2 = probe["g1"], probe["g2"]
    ng = cfg.n_groups
    out = {}
    for key in ("flops", "bytes_accessed"):
        d = g2[key] - g1[key]
        out[key] = g1[key] + d * (ng - 1)
    c1 = g1["collectives"]["total_bytes"]
    c2 = g2["collectives"]["total_bytes"]
    out["collective_bytes"] = c1 + (c2 - c1) * (ng - 1)
    return out


def model_flops(arch: str, shape: str) -> float:
    cfg = ARCHS[arch]
    sh = SHAPES[shape]
    n = active_param_count(cfg) if cfg.n_experts else param_count(cfg)
    if sh["kind"] == "train":
        tokens = sh["seq_len"] * sh["global_batch"]
        return 6.0 * n * tokens
    if sh["kind"] == "prefill":
        tokens = sh["seq_len"] * sh["global_batch"]
        return 2.0 * n * tokens
    tokens = sh["global_batch"]  # decode: one token per sequence
    return 2.0 * n * tokens


def analyze_cell(arch: str, shape: str) -> Optional[dict]:
    full = _load(arch, shape, "pod")
    probe = _load(arch, shape, "probe")
    if not full or not full.get("ok"):
        return {"arch": arch, "shape": shape, "ok": False,
                "error": (full or {}).get("error", "missing")}
    cfg = ARCHS[arch]
    row = {"arch": arch, "shape": shape, "ok": True,
           "kind": full["kind"],
           "mem_args_GiB": full["full"]["memory"]["argument_bytes"] / 2**30,
           "mem_temp_GiB": full["full"]["memory"]["temp_bytes"] / 2**30,
           "compile_s": full["full"]["compile_s"]}
    if probe and probe.get("ok"):
        costs = extrapolate(probe, cfg)
    else:  # fallback: scanned costs (body counted once) — flagged
        costs = {"flops": full["full"]["flops"],
                 "bytes_accessed": full["full"]["bytes_accessed"],
                 "collective_bytes":
                     full["full"]["collectives"]["total_bytes"]}
        row["probe_missing"] = True
    t_c = costs["flops"] / PEAK_FLOPS
    t_m = costs["bytes_accessed"] / HBM_BW
    t_x = costs["collective_bytes"] / LINK_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    mf = model_flops(arch, shape)
    hlo_global = costs["flops"] * CHIPS
    row.update(
        flops_per_dev=costs["flops"],
        bytes_per_dev=costs["bytes_accessed"],
        coll_bytes_per_dev=costs["collective_bytes"],
        t_compute_s=t_c, t_memory_s=t_m, t_collective_s=t_x,
        dominant=dom[0],
        step_time_bound_s=dom[1],
        model_flops=mf,
        hlo_flops_global=hlo_global,
        useful_ratio=mf / hlo_global if hlo_global else float("nan"),
        roofline_fraction=(mf / CHIPS / PEAK_FLOPS) / dom[1]
        if dom[1] > 0 else float("nan"),
    )
    return row


_SUGGEST = {
    "compute": "cut non-useful FLOPs (causal-waste in attention tiles, "
               "remat recompute) or raise MXU utilization (128-aligned "
               "tiles)",
    "memory": "fuse elementwise chains, keep bf16 end-to-end, raise "
              "arithmetic intensity with larger per-device tiles",
    "collective": "reshard to cut all-gather volume (wider FSDP prefetch, "
                  "TP only where weights amortize) and overlap with "
                  "compute",
}


def fmt_table(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | "
           "dominant | useful ratio | roofline frac | args GiB | "
           "temp GiB |\n|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        if not r["ok"]:
            lines.append(f"| {r['arch']} | {r['shape']} | FAILED: "
                         f"{r.get('error','')[:60]} | | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} | {r['mem_args_GiB']:.1f} | "
            f"{r['mem_temp_GiB']:.1f} |")
    return hdr + "\n".join(lines) + "\n"


def main():
    rows = [analyze_cell(a, s) for a, s in all_cells()]
    out = os.path.join(os.path.dirname(__file__), "..", "results",
                       "roofline.json")
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(fmt_table(rows))
    ok = [r for r in rows if r["ok"]]
    print(f"\n{len(ok)}/{len(rows)} cells analyzed")
    for dom in ("compute", "memory", "collective"):
        n = sum(1 for r in ok if r["dominant"] == dom)
        print(f"  {dom}-bound: {n}   -> {_SUGGEST[dom]}")


if __name__ == "__main__":
    main()
