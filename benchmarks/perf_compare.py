"""§Perf helper: full-model roofline terms for hillclimb variants.

Usage: PYTHONPATH=src python benchmarks/perf_compare.py
Reads baseline probes from results/dryrun and variant probes from
results/perf, extrapolates to full depth, and prints the three terms.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.lm_archs import ARCHS  # noqa: E402

PEAK, HBM, LINK = 197e12, 819e9, 50e9
ROOT = os.path.join(os.path.dirname(__file__), "..", "results")

CELLS = {
    ("h2o-danube-3-4b", "train_4k"): [
        ("baseline", "dryrun", "probe"),
        ("P4 pad head_dim 120->128 (Dh-shard)", "perf", "probe_pad128"),
        ("q-shard + kv-replicate", "perf", "probe_qshard"),
        ("qshard + flash blocks 1024", "perf", "probe_qshard_b1024"),
        ("qshard + pad128", "perf", "probe_pad128_qshard"),
        ("Ulysses-GQA (a2a q, kv-replicate+slice)", "perf",
         "probe_ulysses_gqa"),
    ],
    ("hubert-xlarge", "prefill_32k"): [
        ("baseline", "dryrun", "probe"),
        ("Ulysses a2a seq-parallel attention", "perf", "probe_ulysses"),
    ],
    ("deepseek-moe-16b", "train_4k"): [
        ("baseline (TP-F experts)", "dryrun", "probe"),
        ("EP all_to_all routing", "perf", "probe_ep"),
        ("EP + EP-native weight layout", "perf", "probe_ep2"),
        ("EP-native + capacity 1.0", "perf", "probe_ep2_cf1"),
        ("EP + 3-D shard_map boundary", "perf", "probe_ep3"),
        ("TP + 3-D shard_map boundary", "perf", "probe_tp3d"),
    ],
}


def terms(arch, sub, tag):
    p = os.path.join(ROOT, sub, f"{arch[0]}__{arch[1]}__{tag}.json")
    if not os.path.exists(p):
        return None
    d = json.load(open(p))
    if not d.get("ok"):
        return None
    ng = ARCHS[arch[0]].n_groups
    g1, g2 = d["g1"], d["g2"]
    f = g1["flops"] + (g2["flops"] - g1["flops"]) * (ng - 1)
    b = g1["bytes_accessed"] + (g2["bytes_accessed"]
                                - g1["bytes_accessed"]) * (ng - 1)
    c1 = g1["collectives"]["total_bytes"]
    c2 = g2["collectives"]["total_bytes"]
    c = c1 + (c2 - c1) * (ng - 1)
    return f / PEAK, b / HBM, c / LINK


def main():
    for cell, variants in CELLS.items():
        print(f"\n=== {cell[0]} x {cell[1]} ===")
        base = None
        for label, sub, tag in variants:
            t = terms(cell, sub, tag)
            if t is None:
                print(f"  {label:42s} (missing)")
                continue
            tc, tm, tx = t
            dom = max(tc, tm, tx)
            which = ["compute", "memory", "collective"][[tc, tm, tx].index(dom)]
            if base is None:
                base = dom
            print(f"  {label:42s} C={tc:8.3f}s M={tm:8.3f}s X={tx:8.3f}s "
                  f"dom={which:10s} bound={dom:7.3f}s "
                  f"({base/dom:4.2f}x vs baseline)")


if __name__ == "__main__":
    main()
