"""Serving benchmark: open-loop load against :class:`repro.serve`.

The paper benchmarks single-image latency (the deployment artifact's
inner loop); this drives the *server* built on top of it the way a
robot-side camera would — frames arriving on a clock, not a closed
request/response loop:

* **Open-loop rates** — for each net, a paced generator submits
  synthetic camera frames at fixed arrival rates (fractions of the
  net's measured single-image capacity), records what the client
  feels: achieved QPS, p50/p99 end-to-end latency, drops, batch
  occupancy.  Open-loop means the schedule never waits for results —
  late responses do not slow down arrivals, so queueing shows up in
  the tail instead of hiding in the offered rate.
* **Saturated throughput** — for the pedestrian net, submit-as-fast-
  as-possible with retry-on-backpressure, compared against a plain
  sequential ``session.predict()`` loop on the same host.  Continuous
  batching must *win* this even single-core: a batch of 64 costs one
  GIL-releasing foreign call where the sequential loop pays Python
  dispatch per image.

Rows are merged into ``BENCH_engine.json`` under a ``"serving"`` key
(read-modify-write — the latency tables owned by ``run.py`` are
preserved).  ``--quick`` shrinks durations for CI smoke use.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.cnn_paper import EXTRA_CNNS, PAPER_CNNS  # noqa: E402
from repro.core import runtime  # noqa: E402
from repro.data.pipeline import camera_frame_batch  # noqa: E402
from repro.engine import InferenceSession, SessionConfig  # noqa: E402
from repro.serve import (InferenceServer, ServeError,  # noqa: E402
                         ServerConfig, ServerOverloaded)

ALL_CNNS = {**PAPER_CNNS, **EXTRA_CNNS}
NETS = ["ball", "pedestrian", "robot", "residual"]
BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_engine.json")

# fractions of the net's measured single-image capacity offered by the
# open-loop generator; the pacer itself costs ~15µs/submit single-core,
# so the offered rate is additionally capped to keep the operating
# point sustainable (above it the queue grows without bound and p99
# measures test duration, not the server)
RATE_FRACTIONS = (0.25, 0.75)
MAX_OFFERED_QPS = 8000.0


def _percentiles(us):
    a = np.asarray(us, dtype=np.float64)
    return (float(np.percentile(a, 50)), float(np.percentile(a, 99)))


def _open_loop(srv: InferenceServer, frames: np.ndarray,
               rate_qps: float, duration_s: float) -> dict:
    n = max(int(rate_qps * duration_s), 32)
    interval = 1.0 / rate_qps
    nf = len(frames)
    # warm the server before the paced clock starts: the first requests
    # through a cold worker pay thread spin-up, page faults and branch
    # training, which at a low offered rate (few total requests) used to
    # dominate p99 — a cold-start artifact, not queueing behavior.
    # These warmup round trips are excluded from the percentile stats.
    for i in range(32):
        try:
            srv.submit(frames[i % nf]).result(timeout=30.0)
        except (ServerOverloaded, ServeError):
            pass
    handles, dropped = [], 0
    t0 = time.perf_counter()
    for i in range(n):
        target = t0 + i * interval
        now = time.perf_counter()
        if target > now:
            time.sleep(target - now)
        try:
            handles.append(srv.submit(frames[i % nf]))
        except ServerOverloaded:
            dropped += 1
    lat_us, t_last = [], t0
    for h in handles:
        try:
            h.result(timeout=30.0)
        except ServeError:
            dropped += 1
            continue
        ts = h.timestamps
        lat_us.append((ts["done"] - ts["submit"]) * 1e6)
        t_last = max(t_last, ts["done"])
    span = max(t_last - t0, 1e-9)
    p50, p99 = _percentiles(lat_us) if lat_us else (float("nan"),) * 2
    occ = srv.stats().get("batch_occupancy", float("nan"))
    return {
        "offered_qps": round(rate_qps, 1),
        "achieved_qps": round(len(lat_us) / span, 1),
        "p50_us": round(p50, 1),
        "p99_us": round(p99, 1),
        "completed": len(lat_us),
        "dropped": dropped,
        "batch_occupancy": round(occ, 3),
    }


def _saturated(sess: InferenceSession, frames: np.ndarray,
               n_requests: int) -> dict:
    """Submit-as-fast-as-possible vs a sequential predict() loop."""
    nf = len(frames)
    for i in range(200):                      # warm both paths
        sess.predict(frames[i % nf])
    t0 = time.perf_counter()
    for i in range(n_requests):
        sess.predict(frames[i % nf])
    seq_qps = n_requests / (time.perf_counter() - t0)

    cfg = ServerConfig(workers=1, max_batch=64, max_queue=8192,
                       batch_deadline_ms=5.0, request_timeout_ms=None)
    srv = InferenceServer(sess, config=cfg)
    for i in range(200):
        srv.submit(frames[i % nf])
    time.sleep(0.1)                           # warm the batch path
    t0 = time.perf_counter()
    handles = []
    for i in range(n_requests):
        while True:
            try:
                handles.append(srv.submit(frames[i % nf]))
                break
            except ServerOverloaded:
                time.sleep(0.0005)
    for h in handles:
        h.result(timeout=60.0)
    sat_qps = n_requests / (time.perf_counter() - t0)
    occ = srv.stats().get("batch_occupancy", float("nan"))
    srv.close()
    return {
        "server_qps": round(sat_qps, 1),
        "sequential_qps": round(seq_qps, 1),
        "speedup_vs_sequential": round(sat_qps / seq_qps, 3),
        "batch_occupancy": round(occ, 3),
        "requests": n_requests,
        # the serving topology the numbers were taken under — a row
        # without these is unreproducible (a 1-worker and a 4-worker
        # saturated run are different experiments)
        "workers": cfg.workers,
        "max_batch": cfg.max_batch,
    }


def bench_net(name: str, *, duration_s: float, quick: bool) -> dict:
    g = ALL_CNNS[name]()
    sess = InferenceSession(g, config=SessionConfig(
        backend="c", autotune=not quick, simd=runtime.best_isa(),
        tune_iters=200))
    frames = camera_frame_batch(64, tuple(g.input_shape), seed=7)

    lat_us = sess.benchmark(frames[0], iters=200 if quick else 1000)
    capacity = 1e6 / lat_us
    rows = []
    open_cfg = ServerConfig(workers=1, max_batch=16, max_queue=4096,
                            batch_deadline_ms=2.0,
                            request_timeout_ms=5000.0)
    for frac in RATE_FRACTIONS:
        rate = min(frac * capacity, MAX_OFFERED_QPS)
        srv = InferenceServer(sess, config=open_cfg)
        row = _open_loop(srv, frames, rate, duration_s)
        srv.close()
        row["capacity_fraction"] = frac
        row["workers"] = open_cfg.workers
        row["max_batch"] = open_cfg.max_batch
        rows.append(row)
        print(f"serve_{name}_rate{frac},{row['p50_us']:.1f},"
              f"p99={row['p99_us']:.1f},qps={row['achieved_qps']:.0f}")

    out = {"single_image_us": round(lat_us, 3),
           "capacity_qps": round(capacity, 1),
           "pipeline_stages": sess.backend.describe().get(
               "pipeline_stages", 1),
           "rates": rows}
    if name == "pedestrian":
        out["saturated"] = _saturated(
            sess, frames, n_requests=2000 if quick else 8000)
        print(f"serve_{name}_saturated,"
              f"{out['saturated']['server_qps']:.0f},"
              f"sequential={out['saturated']['sequential_qps']:.0f},"
              f"x{out['saturated']['speedup_vs_sequential']:.2f}")
    return out


def _persist(serving: dict) -> None:
    merged = {}
    if os.path.exists(BENCH_JSON):
        try:
            with open(BENCH_JSON) as f:
                merged = json.load(f)
        except (OSError, ValueError):
            merged = {}
    merged["serving"] = serving
    with open(BENCH_JSON, "w") as f:
        json.dump(merged, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# wrote {os.path.normpath(BENCH_JSON)}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="short durations, no autotune (CI smoke)")
    ap.add_argument("--nets", nargs="*", default=NETS,
                    choices=NETS, help="subset of nets to drive")
    ap.add_argument("--no-persist", action="store_true",
                    help="don't touch BENCH_engine.json")
    args = ap.parse_args(argv)

    duration = 0.5 if args.quick else 2.0
    print("name,p50_us,derived,qps")
    serving: dict = {"meta": {
        "isa": runtime.best_isa(),
        "machine": platform.machine(),
        "python": platform.python_version(),
        "cpus": os.cpu_count(),
        "quick": bool(args.quick),
    }}
    for name in args.nets:
        serving[name] = bench_net(name, duration_s=duration,
                                  quick=args.quick)
    if not args.no_persist:
        _persist(serving)


if __name__ == "__main__":
    main()
