"""Substrate tests: data determinism, checkpoint atomicity/resume,
optimizer behaviour, and the kill/resume fault-tolerance contract."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="dev dependency — pip install -e '.[dev]'")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.checkpoint.checkpoint import all_steps, latest_step, restore, save
from repro.data.pipeline import (TokenStreamConfig, ball_image_batch,
                                 token_batch)
from repro.optim import AdamW, global_norm, warmup_cosine

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------------------------------------------ data ----

def test_data_deterministic_per_step_and_shard():
    tc = TokenStreamConfig(vocab_size=100, seq_len=16, global_batch=8,
                           seed=3, n_shards=2, shard=1)
    a = token_batch(tc, step=7)
    b = token_batch(tc, step=7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = token_batch(tc, step=8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_data_shards_disjoint_streams():
    tc0 = TokenStreamConfig(vocab_size=100, seq_len=16, global_batch=8,
                            n_shards=2, shard=0)
    tc1 = TokenStreamConfig(vocab_size=100, seq_len=16, global_batch=8,
                            n_shards=2, shard=1)
    assert not np.array_equal(token_batch(tc0, 0)["tokens"],
                              token_batch(tc1, 0)["tokens"])


def test_ball_images():
    imgs, labels = ball_image_batch(32, res=16, seed=1)
    assert imgs.shape == (32, 16, 16, 1) and set(labels) <= {0, 1}
    assert imgs.min() >= 0 and imgs.max() <= 1
    # positives are brighter on average (there is signal to learn)
    assert imgs[labels == 1].mean() > imgs[labels == 0].mean()


# ------------------------------------------------------------ checkpoint ----

def _tree(seed=0):
    r = np.random.default_rng(seed)
    return {"a": jnp.asarray(r.normal(size=(4, 3)), jnp.float32),
            "nested": [jnp.asarray(r.integers(0, 5, (2,))),
                       jnp.asarray(r.normal(size=(5,)), jnp.float32)]}


def test_checkpoint_roundtrip(tmp_path):
    d = str(tmp_path / "ckpt")
    t = _tree()
    save(d, 10, t)
    save(d, 20, t)
    assert all_steps(d) == [10, 20]
    assert latest_step(d) == 20
    restored = restore(d, 10, jax.eval_shape(lambda: t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_keeps_latest(tmp_path):
    d = str(tmp_path / "ckpt")
    for s in (1, 2, 3, 4, 5):
        save(d, s, _tree(), keep=2)
    assert all_steps(d) == [4, 5]


def test_checkpoint_atomic_no_partial(tmp_path):
    """A tmp dir from a crashed writer is never visible as a checkpoint."""
    d = str(tmp_path / "ckpt")
    save(d, 1, _tree())
    os.makedirs(os.path.join(d, "tmp.99"))  # simulated crash mid-write
    assert all_steps(d) == [1]


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore onto a (new) sharding: leaves land with that sharding."""
    d = str(tmp_path / "ckpt")
    t = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save(d, 1, t)
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored = restore(d, 1, jax.eval_shape(lambda: t), shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(t["w"]))


# -------------------------------------------------------------- optimizer ----

@settings(max_examples=10, deadline=None)
@given(st.floats(1e-5, 1e-2), st.integers(0, 2 ** 31 - 1))
def test_adamw_descends_quadratic(lr, seed):
    r = np.random.default_rng(seed)
    target = jnp.asarray(r.normal(size=(8,)), jnp.float32)
    params = {"w": jnp.zeros(8)}
    opt = AdamW(learning_rate=lr, weight_decay=0.0)
    state = opt.init(params)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    l0 = loss(params)
    for _ in range(50):
        g = jax.grad(loss)(params)
        up, state = opt.update(g, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, up)
    assert loss(params) < l0


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(4)}
    opt = AdamW(learning_rate=1.0, clip_norm=1.0, weight_decay=0.0)
    state = opt.init(params)
    huge = {"w": jnp.full(4, 1e9)}
    up, _ = opt.update(huge, state, params)
    assert float(global_norm(up)) < 10.0


def test_warmup_cosine_shape():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(jnp.int32(0))) == 0.0
    assert abs(float(s(jnp.int32(10))) - 1.0) < 1e-6
    assert float(s(jnp.int32(100))) <= 0.1 + 1e-6


# --------------------------------------------------- fault tolerance e2e ----

@pytest.mark.slow
def test_preempt_and_resume_bitexact(tmp_path):
    """Train 6 steps with a kill at 4, resume, and compare the final
    checkpoint to an uninterrupted 6-step run — deterministic data +
    checkpointing must make them identical."""
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
           "JAX_PLATFORMS": "cpu"}
    common = [sys.executable, "-m", "repro.launch.train", "--arch",
              "lm-100m", "--steps", "6", "--batch", "2", "--seq", "32",
              "--ckpt-every", "2", "--log-every", "1"]

    d1 = str(tmp_path / "interrupted")
    r = subprocess.run(common + ["--ckpt-dir", d1, "--preempt-at", "4"],
                       env=env, capture_output=True, text=True, cwd=REPO)
    assert r.returncode == 17, r.stderr[-2000:]
    assert latest_step(d1) == 4
    r = subprocess.run(common + ["--ckpt-dir", d1], env=env,
                       capture_output=True, text=True, cwd=REPO)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "resumed from step 4" in r.stdout

    d2 = str(tmp_path / "straight")
    r = subprocess.run(common + ["--ckpt-dir", d2], env=env,
                       capture_output=True, text=True, cwd=REPO)
    assert r.returncode == 0, r.stderr[-2000:]

    like = None
    import numpy as np
    z1 = np.load(os.path.join(d1, "step_6", "arrays.npz"))
    z2 = np.load(os.path.join(d2, "step_6", "arrays.npz"))
    assert sorted(z1.files) == sorted(z2.files)
    for k in z1.files:
        np.testing.assert_allclose(z1[k], z2[k], rtol=1e-5, atol=1e-6,
                                   err_msg=k)
