"""Tiled int8 dot-product kernels: the per-variant bit-exactness
matrix (generic / madd16 / vpmaddubsw / VNNI, each vs the quantized
jax reference with ``assert_array_equal``), channel counts that land
on every vector-width tail, the static ``vpmaddubsw`` saturation
proof, and the runtime CPU-feature guard (force-masked fallback
chain — an unsupported variant is never built, let alone loaded).

NEON is covered structurally here (codegen must produce the dot/mlal
kernels); its *execution* parity runs cross-compiled under QEMU in CI
via ``tools/cross_check.py``.
"""
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import cgen, jax_exec, passes, quantize, runtime
from repro.core.graph import (
    Add, CNNGraph, Concat, Conv2D, Dense, DepthwiseConv2D, Flatten,
    Input, MaxPool,
)
from repro.engine import autotune

X86_VARIANTS = ["generic", "sse", "avx", "avx_ubs", "avx_vnni"]
ARM_VARIANTS = ["neon", "neon_dot"]


def _skip_unless_int8_simd(simd: str) -> None:
    if not runtime.int8_simd_supported(simd):
        pytest.skip(f"host cannot execute int8 variant {simd!r}")


def _conv(rng, kh, kw, ci, co, **kw_args) -> Conv2D:
    w = rng.normal(0, 0.5, (kh, kw, ci, co)).astype(np.float32)
    b = rng.normal(0, 0.1, (co,)).astype(np.float32)
    return Conv2D(weights=w, bias=b, **kw_args)


def _kernel_zoo(seed=7) -> CNNGraph:
    """Softmax-free net covering every tiled-kernel code path: strided
    same-pad conv, group tails (co=19 and 33 are neither 4- nor
    8-aligned), leaky/relu epilogues, MaxPool >= 16 channels (the
    vectorized byte-max), a two-input Add (the fused vector requant on
    merges), depthwise, and two Dense tails."""
    rng = np.random.default_rng(seed)
    dw_w = rng.normal(0, 0.5, (3, 3, 12, 1)).astype(np.float32)
    dw_b = rng.normal(0, 0.1, (12,)).astype(np.float32)
    return CNNGraph([
        Input(shape=(11, 9, 3), name="in"),
        _conv(rng, 3, 3, 3, 12, padding="same", activation="relu",
              name="c1"),
        DepthwiseConv2D(weights=dw_w, bias=dw_b, padding="same",
                        activation="leaky_relu", name="dw"),
        Add(name="add", inputs=["dw", "c1"], activation="relu"),
        _conv(rng, 3, 3, 12, 19, strides=(2, 2), padding="same",
              activation="leaky_relu", name="c2"),
        MaxPool(size=(2, 2), padding="same", name="mp"),
        _conv(rng, 2, 2, 19, 33, padding="valid", name="c3"),
        Flatten(name="fl"),
        Dense(weights=rng.normal(0, 0.2, (2 * 2 * 33, 21)).astype(
                  np.float32),
              bias=rng.normal(0, 0.1, (21,)).astype(np.float32),
              activation="relu", name="d1"),
        Dense(weights=rng.normal(0, 0.2, (21, 10)).astype(np.float32),
              bias=rng.normal(0, 0.1, (10,)).astype(np.float32),
              name="d2"),
    ])


def _quantized(graph: CNNGraph, seed=3):
    g = passes.optimize(graph, simd_multiple=1)
    xs = np.random.default_rng(seed).normal(
        size=(8,) + tuple(g.input_shape)).astype(np.float32)
    return g, xs, quantize.quantize(g, xs)


# ---------------------------------------------- per-variant parity ----

@pytest.mark.parametrize("simd", X86_VARIANTS)
def test_tiled_kernel_bit_exact_vs_jax_reference(simd):
    """Every kernel variant computes the identical int32 accumulator
    (integer sums are exact in any order; the u8 re-bias folds into the
    bias int32-exactly) and the identical fused requant epilogue (the
    vector round/clamp mirrors the scalar trunc-fixup floor op by op)
    — so outputs must be *equal*, not close, for every variant."""
    _skip_unless_int8_simd(simd)
    g, xs, qg = _quantized(_kernel_zoo())
    ref = np.asarray(jax_exec.make_jit_forward_quantized(qg)(xs))
    net = runtime.build_quantized(qg, cgen.CodegenOptions(simd=simd))
    assert net.simd == simd  # host supports it: no silent fallback
    got = net.predict_batch(xs).reshape(ref.shape)
    np.testing.assert_array_equal(got, ref)


_TAIL_CHANNELS = [1, 3, 4, 5, 8, 9, 17]  # every co % 4 / co % 8 class


@pytest.mark.parametrize("co", _TAIL_CHANNELS)
def test_channel_tail_parity_all_variants(co):
    """Output-channel counts straddling the group widths (4 for SSE,
    8 for the AVX family): full tiles, partial per-channel tails, and
    the sub-group co < G case must all be bit-exact."""
    rng = np.random.default_rng(co)
    g0 = CNNGraph([
        Input(shape=(6, 5, 3), name="in"),
        _conv(rng, 3, 3, 3, co, padding="same", activation="relu",
              name="c1"),
        _conv(rng, 1, 1, co, max(co // 2, 1), name="c2"),
    ])
    g, xs, qg = _quantized(g0, seed=co)
    ref = np.asarray(jax_exec.make_jit_forward_quantized(qg)(xs))
    for simd in X86_VARIANTS:
        if not runtime.int8_simd_supported(simd):
            continue
        net = runtime.build_quantized(qg, cgen.CodegenOptions(simd=simd))
        got = net.predict_batch(xs).reshape(ref.shape)
        np.testing.assert_array_equal(got, ref, err_msg=f"simd={simd}")


if HAVE_HYPOTHESIS:
    @pytest.mark.slow
    @settings(max_examples=12, deadline=None)
    @given(co=st.integers(min_value=1, max_value=36),
           ci=st.integers(min_value=1, max_value=9))
    def test_channel_sweep_parity_hypothesis(co, ci):
        """Wider randomized sweep over (c_in, c_out): the row length
        ci*kw decides the lane-tap tail inside each 4-byte quad, co the
        group tail — both axes must stay exact everywhere."""
        rng = np.random.default_rng(co * 100 + ci)
        g0 = CNNGraph([
            Input(shape=(5, 4, ci), name="in"),
            Conv2D(weights=rng.normal(0, 0.5, (3, 2, ci, co)).astype(
                       np.float32),
                   bias=rng.normal(0, 0.1, (co,)).astype(np.float32),
                   padding="same", activation="leaky_relu", name="c1"),
        ])
        g, xs, qg = _quantized(g0, seed=ci)
        ref = np.asarray(jax_exec.make_jit_forward_quantized(qg)(xs))
        for simd in X86_VARIANTS:
            if not runtime.int8_simd_supported(simd):
                continue
            net = runtime.build_quantized(
                qg, cgen.CodegenOptions(simd=simd))
            got = net.predict_batch(xs).reshape(ref.shape)
            np.testing.assert_array_equal(got, ref, err_msg=f"simd={simd}")


# ------------------------------------- maddubsw saturation proof ----

def test_maddubsw_safe_bounds():
    """The static proof is exactly the int16 saturation bound of
    ``vpmaddubsw``: positive pair sum <= 128, negative >= -128 (255 *
    128 = 32640 <= 32767, but 255 * 129 overflows)."""
    def wt(pair):
        a = np.zeros((8, 4), dtype=np.int64)
        a[0, :2] = pair
        return a

    assert cgen.maddubsw_safe(wt((127, 1)), 8, 1, 4)
    assert cgen.maddubsw_safe(wt((127, -127)), 8, 1, 4)
    assert cgen.maddubsw_safe(wt((-127, -1)), 8, 1, 4)
    assert not cgen.maddubsw_safe(wt((127, 2)), 8, 1, 4)
    assert not cgen.maddubsw_safe(wt((65, 64)), 8, 1, 4)
    assert not cgen.maddubsw_safe(wt((-127, -2)), 8, 1, 4)


def _alternating_sign_conv(rng, kh, kw, ci, co, **kw_args) -> Conv2D:
    """Float weights whose sign alternates along the tap axis: every
    adjacent quantized pair is (one positive, one negative), so the
    positive pair sum is <= 127 and the layer is provably maddubsw-safe
    regardless of magnitudes."""
    taps = np.arange(kh * kw * ci).reshape(kh, kw, ci)
    sign = np.where(taps % 2 == 0, 1.0, -1.0)[..., None]
    w = (rng.uniform(0.1, 1.0, (kh, kw, ci, co)) * sign).astype(np.float32)
    b = rng.normal(0, 0.1, (co,)).astype(np.float32)
    return Conv2D(weights=w, bias=b, **kw_args)


def test_avx_ubs_eligible_layer_uses_maddubsw_and_stays_exact():
    rng = np.random.default_rng(11)
    g0 = CNNGraph([
        Input(shape=(7, 6, 4), name="in"),
        _alternating_sign_conv(rng, 3, 3, 4, 16, padding="same",
                               activation="relu", name="c1"),
        _conv(rng, 1, 1, 16, 8, name="c2"),
    ])
    g, xs, qg = _quantized(g0, seed=11)
    assert cgen.maddubsw_any_eligible(qg)
    src = cgen.generate_quantized_c(qg, cgen.CodegenOptions(simd="avx_ubs"))
    assert "_mm256_maddubs_epi16" in src  # the u8*s8 scheme is emitted
    if runtime.int8_simd_supported("avx_ubs"):
        ref = np.asarray(jax_exec.make_jit_forward_quantized(qg)(xs))
        net = runtime.build_quantized(
            qg, cgen.CodegenOptions(simd="avx_ubs"))
        got = net.predict_batch(xs).reshape(ref.shape)
        np.testing.assert_array_equal(got, ref)


def test_avx_ubs_ineligible_layer_demotes_to_pair_madd():
    """A layer that cannot prove the saturation bound must not emit
    maddubsw — it falls back to the always-exact pair-madd tile inside
    the same build (per layer, not per net)."""
    rng = np.random.default_rng(13)
    g0 = CNNGraph([
        Input(shape=(6, 6, 3), name="in"),
        _conv(rng, 3, 3, 3, 16, padding="same", name="c1"),
    ])
    g, xs, qg = _quantized(g0, seed=13)
    if cgen.maddubsw_any_eligible(qg):  # pragma: no cover
        pytest.skip("random net happened to be maddubsw-safe")
    src = cgen.generate_quantized_c(qg, cgen.CodegenOptions(simd="avx_ubs"))
    assert "_mm256_maddubs_epi16" not in src
    assert "_mm256_madd_epi16" in src


# ------------------------------------------- NEON codegen structure ----

@pytest.mark.parametrize("simd,marker", [("neon", "vmlal_s16"),
                                         ("neon_dot", "vdotq_s32")])
def test_neon_codegen_emits_dot_kernels(simd, marker):
    """Structural check on any host; executed bit-exact under QEMU in
    the CI cross-compile lane (tools/cross_check.py)."""
    g, xs, qg = _quantized(_kernel_zoo())
    src = cgen.generate_quantized_c(qg, cgen.CodegenOptions(simd=simd))
    assert marker in src
    assert "arm_neon.h" in src
    assert "immintrin.h" not in src and "emmintrin.h" not in src


# ------------------------------------------ runtime feature guard ----

def test_force_masked_fallback_chain():
    """The guard walks the QISA fallback chain down to what the masked
    'host' advertises — never crossing an unsupported rung."""
    with runtime.force_cpu_features(["sse2", "ssse3"]):
        assert runtime.resolve_int8_simd("avx_vnni") == "sse"
        assert runtime.resolve_int8_simd("avx_ubs") == "sse"
        assert runtime.resolve_int8_simd("avx") == "sse"
        assert runtime.resolve_int8_simd("sse") == "sse"
        assert runtime.supported_int8_simds() == ["sse", "generic"]
    with runtime.force_cpu_features([]):
        assert runtime.resolve_int8_simd("avx_vnni") == "generic"
        assert runtime.resolve_int8_simd("neon_dot") == "generic"
        assert runtime.supported_int8_simds() == ["generic"]
    with runtime.force_cpu_features(
            ["avx2", "fma", "ssse3", "sse2"]):
        # AVX2 but no VNNI: the VNNI request lands on the avx tile
        assert runtime.resolve_int8_simd("avx_vnni") == "avx"
        assert "avx_vnni" not in runtime.supported_int8_simds()


def test_force_masked_build_never_loads_unsupported_so():
    """Requesting VNNI on a masked SSE-only 'host' must produce an SSE
    .so (bit-exact, runnable) — the AVX-512 binary is never built."""
    g, xs, qg = _quantized(_kernel_zoo())
    ref = np.asarray(jax_exec.make_jit_forward_quantized(qg)(xs))
    with runtime.force_cpu_features(["sse2", "ssse3"]):
        net = runtime.build_quantized(
            qg, cgen.CodegenOptions(simd="avx_vnni"))
        assert net.simd == "sse"
    got = net.predict_batch(xs).reshape(ref.shape)
    np.testing.assert_array_equal(got, ref)


def test_variant_candidates_respect_feature_mask():
    g, xs, qg = _quantized(_kernel_zoo())
    with runtime.force_cpu_features(["sse2", "ssse3"]):
        assert autotune.int8_variant_candidates(qg) == ["sse", "generic"]
    with runtime.force_cpu_features([]):
        assert autotune.int8_variant_candidates(qg) == ["generic"]


def test_cpu_features_are_tokens_not_substrings():
    with runtime.force_cpu_features(["avx512f"]):
        # substring matching would claim 'avx' here
        assert not runtime.host_supports_avx2()
        assert runtime.resolve_int8_simd("avx") == "generic"


@pytest.mark.slow
def test_cross_check_neon_under_qemu():
    """Full ARM lane locally when the toolchain is around (CI always
    runs it via tools/cross_check.py directly): cross-compile the NEON
    variants, execute under qemu-aarch64, bit-compare vs jax."""
    import subprocess
    import sys

    script = os.path.join(os.path.dirname(__file__), "..", "tools",
                          "cross_check.py")
    proc = subprocess.run([sys.executable, script],
                          capture_output=True, text=True, timeout=1200)
    if proc.returncode == 2:
        pytest.skip("aarch64 cross toolchain / qemu not installed")
    assert proc.returncode == 0, proc.stdout + proc.stderr
