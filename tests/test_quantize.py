"""Int8 post-training quantization: emitted-literal fidelity, exact
C-vs-jax-reference parity on the integer path (every calibration
method x SIMD mode), the histogram-observer calibration subsystem
(streaming chunks, percentile/MSE range selection, per-branch Concat
qparams), accuracy gates on the *trained* ball classifier, arena
shrinkage, dtype-aware threading, and the strict-ANSI claim for the
quantized emitter."""
import shutil
import subprocess

import numpy as np
import pytest

try:  # hypothesis widens the literal search; a fixed grid runs without
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.configs.cnn_paper import (
    PAPER_CNNS, residual_cnn, trained_ball_classifier,
)
from repro.core import cgen, jax_exec, passes, quantize, runtime
from repro.core.cgen import _flit
from repro.core.graph import (
    Add, AvgPool, BatchNorm, CNNGraph, Concat, Conv2D, Dense,
    DepthwiseConv2D, GlobalAvgPool, Input, MaxPool,
)
from repro.data.pipeline import ball_image_batch

METHODS = quantize.CALIBRATION_METHODS


def _conv(rng, kh, kw, ci, co, **kw_args) -> Conv2D:
    w = rng.normal(0, 0.5, (kh, kw, ci, co)).astype(np.float32)
    b = rng.normal(0, 0.1, (co,)).astype(np.float32)
    return Conv2D(weights=w, bias=b, **kw_args)


def _dw(rng, kh, kw, c, m, **kw_args) -> DepthwiseConv2D:
    w = rng.normal(0, 0.5, (kh, kw, c, m)).astype(np.float32)
    b = rng.normal(0, 0.1, (c * m,)).astype(np.float32)
    return DepthwiseConv2D(weights=w, bias=b, **kw_args)


def _zoo_graph(seed=1) -> CNNGraph:
    """Every quantizable construct, ending in a softmax-free sink so
    the whole net is on the exact integer path."""
    rng = np.random.default_rng(seed)
    return CNNGraph([
        Input(shape=(12, 10, 3), name="in"),
        _conv(rng, 3, 3, 3, 8, padding="same", activation="relu",
              name="c1"),
        MaxPool(size=(2, 2), padding="same", name="mp"),
        _dw(rng, 3, 3, 8, 1, padding="same", activation="leaky_relu",
            name="dw1"),
        _conv(rng, 1, 1, 8, 8, padding="valid", name="pw"),
        Add(name="add", inputs=["pw", "mp"], activation="relu"),
        _conv(rng, 1, 1, 8, 4, name="b1", inputs=["add"]),
        _conv(rng, 3, 3, 8, 4, padding="same", name="b2", inputs=["add"]),
        Concat(name="cat", inputs=["b1", "b2"]),
        AvgPool(size=(3, 3), strides=(2, 2), padding="same", name="ap"),
        GlobalAvgPool(name="gap"),
        _conv(rng, 1, 1, 8, 5, name="head", activation="relu"),
    ])


def _calib(shape, n=8, seed=3):
    return np.random.default_rng(seed).normal(
        size=(n,) + tuple(shape)).astype(np.float32)


# ------------------------------------------ emitted-literal fidelity ----

def _assert_flit_roundtrip(v: np.float32) -> None:
    lit = _flit(v)
    assert lit.endswith("f")
    back = np.float32(lit[:-1])
    assert back.tobytes() == np.float32(v).tobytes(), (v, lit, back)


_FLIT_GRID = np.concatenate([
    np.random.default_rng(0).normal(0, 1, 200),
    np.random.default_rng(1).normal(0, 1e-30, 50),
    np.random.default_rng(2).normal(0, 1e30, 50),
    [0.0, -0.0, 1.0, -1.0, 1 / 3, np.float32(2 ** -149),
     -np.float32(2 ** -149), np.finfo(np.float32).max,
     np.finfo(np.float32).min, np.finfo(np.float32).tiny],
]).astype(np.float32)


def test_flit_roundtrip_grid():
    """Every emitted C float literal parses back bit-exact (P3 depends
    on it; the quantized requant scales depend on it doubly)."""
    for v in _FLIT_GRID:
        _assert_flit_roundtrip(v)


if HAVE_HYPOTHESIS:
    @settings(max_examples=300, deadline=None)
    @given(st.floats(width=32, allow_nan=False, allow_infinity=False))
    def test_flit_roundtrip_property(x):
        _assert_flit_roundtrip(np.float32(x))


def test_qparams_zero_exactly_representable():
    for mn, mx in [(-1.3, 2.7), (0.0, 5.0), (-4.2, 0.0), (0.0, 0.0),
                   (0.5, 2.0), (-3.0, -1.0)]:
        qp = quantize.qparams_from_range(mn, mx)
        assert quantize.QMIN <= qp.zero_point <= quantize.QMAX
        z = qp.quantize(np.zeros(1, np.float32))
        assert z[0] == qp.zero_point
        assert qp.dequantize(z)[0] == 0.0


def test_qparams_zero_point_rounds_half_up_not_bankers():
    """Regression: the raw zero point here lands exactly on 2.5 —
    ``floor(x + 0.5)`` (the documented scheme, used by every quantize
    step in the C and the jax reference) gives 3; Python's banker's
    ``round`` would give 2."""
    s = np.float32(0.004)
    mn = float(-130.5 * float(s))
    mx = mn + 255 * float(s)
    qp = quantize.qparams_from_range(mn, mx)
    assert float(np.float32(qp.scale)) == float(s)
    assert -128 - mn / qp.scale == 2.5  # the construction held exactly
    assert qp.zero_point == 3


def _assert_zero_exact(mn: float, mx: float) -> None:
    qp = quantize.qparams_from_range(mn, mx)
    assert quantize.QMIN <= qp.zero_point <= quantize.QMAX
    if not np.isfinite(qp.inv_scale):
        return  # degenerate sub-1e-38 range: 1/scale overflows float32
    z = qp.quantize(np.zeros(1, np.float32))
    assert z[0] == qp.zero_point, (mn, mx, qp)
    assert qp.dequantize(z)[0] == 0.0, (mn, mx, qp)


_RANGE_GRID = np.random.default_rng(7).normal(0, 10, (200, 2))


def test_zero_exact_over_randomized_ranges_grid():
    for a, b in _RANGE_GRID:
        _assert_zero_exact(min(a, b), max(a, b))


if HAVE_HYPOTHESIS:
    @settings(max_examples=300, deadline=None)
    @given(st.floats(-1e30, 1e30, allow_nan=False),
           st.floats(-1e30, 1e30, allow_nan=False))
    def test_zero_exact_over_randomized_ranges_property(a, b):
        _assert_zero_exact(min(a, b), max(a, b))


def test_zero_exact_under_every_calibration_method():
    """The qparams any observer method selects keep 0.0 exactly
    representable (ReLU clamps / zero padding depend on it)."""
    rng = np.random.default_rng(11)
    data = np.concatenate([rng.normal(0.5, 2.0, 20000),
                           rng.normal(0, 30.0, 40)]).astype(np.float32)
    obs = quantize.Observer(nbins=512)
    for chunk in np.array_split(data, 5):
        obs.update(chunk)
    for method in METHODS:
        qp = quantize.qparams_from_range(*obs.select_range(method))
        z = qp.quantize(np.zeros(1, np.float32))
        assert z[0] == qp.zero_point, method
        assert qp.dequantize(z)[0] == 0.0, method


# ------------------------------------------------ observer subsystem ----

def test_observer_streaming_minmax_is_exact():
    """Chunked updates track the exact min/max — the ``minmax`` method
    must reproduce the historical whole-batch behavior bit-for-bit."""
    rng = np.random.default_rng(0)
    data = rng.normal(3, 17, 30000).astype(np.float32)
    obs = quantize.Observer(nbins=128)
    for chunk in np.array_split(data, 11):
        obs.update(chunk)
    assert obs.range_minmax() == (float(data.min()), float(data.max()))


def test_observer_histogram_mass_preserved_across_growth():
    """When a later chunk widens the span, existing counts are
    redistributed — never dropped."""
    obs = quantize.Observer(nbins=64)
    obs.update(np.linspace(0.0, 1.0, 1000, dtype=np.float32))
    obs.update(np.linspace(-5.0, 5.0, 500, dtype=np.float32))
    assert np.isclose(float(obs.counts.sum()), 1500.0)
    assert obs.edges[0] <= -5.0 and obs.edges[-1] >= 5.0


def test_observer_percentile_clips_outlier():
    rng = np.random.default_rng(1)
    data = np.concatenate([rng.normal(0, 1, 100_000),
                           [500.0]]).astype(np.float32)
    obs = quantize.Observer()
    for chunk in np.array_split(data, 4):
        obs.update(chunk)
    assert obs.range_minmax()[1] == 500.0
    lo, hi = obs.range_percentile(99.9)
    assert hi < 25.0, hi  # the outlier no longer owns the range
    assert lo < 0.0 < hi


def test_observer_mse_shrinks_heavy_tail():
    rng = np.random.default_rng(2)
    data = np.concatenate([rng.normal(0, 1, 100_000),
                           rng.normal(0, 80, 30)]).astype(np.float32)
    obs = quantize.Observer()
    obs.update(data)
    mse_lo, mse_hi = obs.range_mse()
    mn, mx = obs.range_minmax()
    assert mse_hi < mx and mse_lo > mn  # tighter than minmax
    assert mse_hi > 2.0  # but not collapsed onto the core


def test_calibrate_chunked_equals_one_shot_minmax():
    g = passes.optimize(_zoo_graph(), simd_multiple=1)
    xs = _calib(g.input_shape, n=16)
    a1 = quantize.calibrate(g, xs, method="minmax", chunk_size=3)
    a2 = quantize.calibrate(g, xs, method="minmax", chunk_size=64)
    assert a1 == a2


def test_calibrate_rejects_unknown_method():
    g = passes.optimize(_zoo_graph(), simd_multiple=1)
    with pytest.raises(ValueError, match="calibration method"):
        quantize.calibrate(g, _calib(g.input_shape), method="kl-top")


# ------------------------------------------------ integer-path parity ----

def _skip_unless_simd(simd: str) -> None:
    if simd == "sse" and not runtime.host_supports_ssse3():
        pytest.skip("no SSSE3")
    if simd == "avx" and not runtime.host_supports_avx2():
        pytest.skip("no AVX2")


@pytest.mark.parametrize("simd", ["generic", "sse", "avx"])
@pytest.mark.parametrize("method", METHODS)
def test_quantized_c_bit_exact_vs_jax_reference(method, simd):
    """The generated int8 C and the quantized jax reference share every
    float32 requant constant and an exact int32 integer path — on a
    softmax-free net the outputs must be *identical*, not just close,
    for every calibration method (the methods only change which
    constants are selected) and every SIMD mode (integer addition is
    associative).  The zoo graph includes a two-branch Concat, so the
    per-branch requant path is covered in every cell."""
    _skip_unless_simd(simd)
    g = passes.optimize(_zoo_graph(), simd_multiple=1)
    xs = _calib(g.input_shape)
    qg = quantize.quantize(g, xs, method=method)
    assert qg.method == method
    ref = np.asarray(jax_exec.make_jit_forward_quantized(qg)(xs))
    net = runtime.build_quantized(qg, cgen.CodegenOptions(simd=simd))
    got = net.predict_batch(xs).reshape(ref.shape)
    np.testing.assert_array_equal(got, ref)


def _branchy_graph(seed=5) -> CNNGraph:
    """A Concat whose branches have wildly different output ranges —
    the per-branch calibration workload (a shared range would cost the
    narrow branch ~all of its int8 resolution)."""
    rng = np.random.default_rng(seed)

    def conv(kh, kw, ci, co, gain, **kw_args):
        w = (rng.normal(0, 0.5, (kh, kw, ci, co)) * gain).astype(np.float32)
        b = (rng.normal(0, 0.05, (co,)) * gain).astype(np.float32)
        return Conv2D(weights=w, bias=b, **kw_args)

    return CNNGraph([
        Input(shape=(8, 8, 3), name="in"),
        conv(3, 3, 3, 6, 1.0, padding="same", activation="relu",
             name="stem"),
        conv(1, 1, 6, 4, 0.02, name="narrow", inputs=["stem"]),
        conv(1, 1, 6, 4, 2.0, name="wide", inputs=["stem"]),
        Concat(name="cat", inputs=["narrow", "wide"]),
        conv(1, 1, 8, 5, 1.0, name="head"),
    ])


@pytest.mark.parametrize("method", METHODS)
def test_concat_per_branch_qparams_and_parity(method):
    """Each Concat input keeps its own calibrated range (the narrow
    branch's scale stays ~2 orders finer than the wide one's), the
    Concat output range is the union of the branches' calibrated
    ranges, and the per-edge requant in the generated C matches the
    jax reference bit-for-bit."""
    g = passes.optimize(_branchy_graph(), simd_multiple=1)
    xs = _calib(g.input_shape, n=16)
    qg = quantize.quantize(g, xs, method=method)
    narrow, wide = qg.acts["narrow"], qg.acts["wide"]
    assert narrow.scale * 10 < wide.scale, (narrow, wide)
    lo = min(qg.ranges["narrow"][0], qg.ranges["wide"][0])
    hi = max(qg.ranges["narrow"][1], qg.ranges["wide"][1])
    assert qg.ranges["cat"] == (lo, hi)
    assert qg.acts["cat"] == quantize.qparams_from_range(lo, hi)
    ref = np.asarray(jax_exec.make_jit_forward_quantized(qg)(xs))
    for simd in ("generic", "sse"):
        if simd == "sse" and not runtime.host_supports_ssse3():
            continue
        net = runtime.build_quantized(qg, cgen.CodegenOptions(simd=simd))
        np.testing.assert_array_equal(
            net.predict_batch(xs).reshape(ref.shape), ref)


@pytest.mark.parametrize("name", ["ball", "residual"])
def test_quantized_c_matches_jax_reference_cnn(name):
    """cnn_paper + residual configs: exact integer path, float softmax
    tail allowed one-ulp wiggle (libm expf vs XLA exp)."""
    builder = PAPER_CNNS.get(name, residual_cnn)
    g = passes.optimize(builder(), simd_multiple=1)
    xs = _calib(g.input_shape, n=16)
    qg = quantize.quantize(g, xs)
    ref = np.asarray(jax_exec.make_jit_forward_quantized(qg)(xs))
    net = runtime.build_quantized(qg, cgen.CodegenOptions(simd="generic"))
    got = net.predict_batch(xs).reshape(ref.shape)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
    assert (got.reshape(len(xs), -1).argmax(-1)
            == ref.reshape(len(xs), -1).argmax(-1)).all()


@pytest.mark.slow
def test_quantized_c_matches_jax_reference_pedestrian_robot():
    for builder in (PAPER_CNNS["pedestrian"], PAPER_CNNS["robot"]):
        g = passes.optimize(builder(), simd_multiple=1)
        xs = _calib(g.input_shape, n=4)
        qg = quantize.quantize(g, xs)
        ref = np.asarray(jax_exec.make_jit_forward_quantized(qg)(xs))
        net = runtime.build_quantized(qg, cgen.CodegenOptions(simd="sse"))
        got = net.predict_batch(xs).reshape(ref.shape)
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)


# --------------------------------------------- per-channel requant ----

def _per_channel_graph(co=17, seed=13) -> CNNGraph:
    """A valid-padding chain where every non-sink weighted layer is
    per-channel eligible: Conv (channel tail ``co=17`` exercises the
    tiled groups AND the scalar-tail zero-point table indexing) ->
    Conv -> DepthwiseConv (multiplier 2, group-major channel order) ->
    Dense sink (softmax-free: whole net on the exact integer path,
    and the sink dequant exercises the folded-input branch)."""
    rng = np.random.default_rng(seed)
    return CNNGraph([
        Input(shape=(10, 10, 3), name="in"),
        _conv(rng, 3, 3, 3, co, padding="valid", activation="relu",
              name="c1"),
        _conv(rng, 3, 3, co, 8, padding="valid", name="c2"),
        _dw(rng, 1, 1, 8, 2, padding="valid", name="dwx"),
        Dense(weights=rng.normal(0, 0.1, (6 * 6 * 16, 5))
              .astype(np.float32),
              bias=rng.normal(0, 0.05, (5,)).astype(np.float32),
              name="fc"),
    ])


def test_channel_qparams_match_scalar_rule():
    """channel_qparams_from_range is qparams_from_range applied
    elementwise — same zero-widening, float32 cast, half-up rule."""
    rng = np.random.default_rng(21)
    mn = rng.normal(0, 5, 40)
    mx = mn + np.abs(rng.normal(0, 5, 40))
    cq = quantize.channel_qparams_from_range(mn, mx)
    for i in range(mn.size):
        qp = quantize.qparams_from_range(float(mn[i]), float(mx[i]))
        assert float(cq.scale[i]) == qp.scale, i
        assert int(cq.zero_point[i]) == qp.zero_point, i
    # zero stays exactly representable per channel
    z = cq.quantize(np.zeros((1, mn.size), np.float32))
    assert (z[0] == cq.zero_point).all()
    assert (cq.dequantize(z)[0] == 0.0).all()


def test_per_channel_eligibility():
    g = passes.optimize(_per_channel_graph(), simd_multiple=1)
    # every non-sink weighted layer qualifies; the Dense sink does not
    assert quantize.per_channel_eligible(g) == ["c1", "c2", "dwx"]
    # padded consumers disqualify the producer (the pad fill is one
    # scalar zero code; a per-channel zero point no longer is)
    zoo = passes.optimize(_zoo_graph(), simd_multiple=1)
    for name in quantize.per_channel_eligible(zoo):
        layer = next(l for l in zoo.layers if l.name == name)
        assert all(isinstance(c, quantize._WEIGHTED)
                   for c in zoo.consumers()[layer.name])


@pytest.mark.parametrize("simd", ["generic", "sse", "avx"])
def test_per_channel_bit_exact_vs_jax(simd):
    """Opt-in per-channel requant zero points: producer epilogues index
    per-channel multiplier/zero-point tables, consumers fold the
    producer scales into their weight quantization — and the generated
    C still matches the jax reference bit-for-bit on every SIMD
    variant (the integer inner loop never changed)."""
    _skip_unless_simd(simd)
    g = passes.optimize(_per_channel_graph(), simd_multiple=1)
    xs = _calib(g.input_shape, n=16)
    qg = quantize.quantize(g, xs, per_channel=True)
    assert sorted(qg.channel_acts) == ["c1", "c2", "dwx"]
    # the per-channel zps genuinely vary (otherwise this tests nothing)
    assert any(np.unique(cq.zero_point).size > 1
               for cq in qg.channel_acts.values())
    for name in qg.channel_acts:
        layer = next(l for l in g.layers if l.name == name)
        for c in g.consumers()[name]:
            assert qg.weights[c.name].in_folded
        assert qg.requant_scales(layer).shape == \
            qg.weights[name].w_scale.shape
    ref = np.asarray(jax_exec.make_jit_forward_quantized(qg)(xs))
    net = runtime.build_quantized(qg, cgen.CodegenOptions(simd=simd))
    np.testing.assert_array_equal(
        net.predict_batch(xs).reshape(ref.shape), ref)


def test_per_channel_improves_or_matches_per_tensor():
    """Finer steps for narrow channels can only help on this net: the
    per-channel build's max |int8 - float| error never exceeds the
    per-tensor build's by more than float noise."""
    g = passes.optimize(_per_channel_graph(), simd_multiple=1)
    xs = _calib(g.input_shape, n=24)
    e_pt = quantize.quantization_error(
        quantize.quantize(g, xs), xs)["max_abs_err"]
    e_pc = quantize.quantization_error(
        quantize.quantize(g, xs, per_channel=True), xs)["max_abs_err"]
    assert e_pc <= e_pt * 1.05 + 1e-6, (e_pc, e_pt)


def test_per_channel_off_is_default_and_digest_differs():
    """per_channel=False (the default) is the historical build —
    identical generated C; turning it on changes the qparams digest
    (autotune cache keys must not mix the two)."""
    from repro.core import codegen
    g = passes.optimize(_per_channel_graph(), simd_multiple=1)
    xs = _calib(g.input_shape, n=8)
    qg_off = quantize.quantize(g, xs)
    qg_def = quantize.quantize(g, xs, per_channel=False)
    assert not qg_off.channel_acts and not qg_def.channel_acts
    opts = cgen.CodegenOptions(simd="generic")
    assert codegen.compile(qg_off, opts).source == \
        codegen.compile(qg_def, opts).source
    qg_on = quantize.quantize(g, xs, per_channel=True)
    assert quantize.qparams_digest(qg_on) != quantize.qparams_digest(qg_off)
    assert codegen.compile(qg_on, opts).source != \
        codegen.compile(qg_off, opts).source


def test_session_per_channel_flag():
    from repro.engine import InferenceSession, SessionConfig
    g = _per_channel_graph()
    xs = _calib(g.input_shape, n=16)
    s = InferenceSession(g, config=SessionConfig(
        backend="c", precision="int8", simd="generic",
        calibration={"data": xs, "per_channel": True}))
    ref = InferenceSession(g, config=SessionConfig(
        backend="xla", precision="int8",
        calibration={"data": xs, "per_channel": True}))
    np.testing.assert_array_equal(s.predict(xs), ref.predict(xs))
    assert s.qgraph.channel_acts
    assert s.config.calibration.to_dict()["per_channel"] is True


# ------------------------------------------------- accuracy vs float ----

@pytest.fixture(scope="module")
def trained_ball():
    """The ROADMAP accuracy workload: the Table-I ball net trained on
    its synthetic dataset (calibration quality is invisible on random
    weights — a random 2-class softmax is a coin flip)."""
    return trained_ball_classifier(steps=150, seed=0)


def test_trained_ball_int8_accuracy_and_method_ordering(trained_ball):
    graph, float_acc = trained_ball
    assert float_acc >= 0.97, float_acc
    g = passes.optimize(graph, simd_multiple=1)
    xs, ys = ball_image_batch(256, seed=1)
    calib = xs[:32]
    stats = {}
    for method in METHODS:
        qg = quantize.quantize(g, calib, method=method)
        stats[method] = quantize.quantization_error(qg, xs)
        pred = np.asarray(jax_exec.forward_quantized(qg, xs))
        qacc = float((pred.reshape(len(xs), -1).argmax(-1) == ys).mean())
        # the int8 build classifies (real frames) as well as the float
        assert qacc >= float_acc - 0.02, (method, qacc, float_acc)
        assert stats[method]["max_abs_err"] < 0.08, (method, stats)
    # the histogram methods never do worse than naive min/max here
    for method in ("percentile", "mse", "entropy"):
        assert stats[method]["top1_agreement"] >= \
            stats["minmax"]["top1_agreement"], stats


def test_spatial_sink_top1_is_per_position():
    """Regression for the top-1 metric: a 4-D sink is h*w independent
    channel classifications; the old flat h*w*c argmax both understated
    and overstated agreement depending on where errors landed."""
    rng = np.random.default_rng(3)
    g = passes.optimize(CNNGraph([
        Input(shape=(10, 10, 3), name="in"),
        Conv2D(weights=rng.normal(0, 1.2, (3, 3, 3, 6)).astype(np.float32),
               bias=rng.normal(0, 0.2, (6,)).astype(np.float32),
               padding="same", activation="leaky_relu", name="c1"),
        Conv2D(weights=rng.normal(0, 1.2, (3, 3, 6, 5)).astype(np.float32),
               bias=rng.normal(0, 0.2, (5,)).astype(np.float32),
               padding="same", name="sink"),
    ]), simd_multiple=1)
    xs = _calib(g.input_shape, n=24, seed=9)
    qg = quantize.quantize(g, xs)
    stats = quantize.quantization_error(qg, xs)
    got = np.asarray(jax_exec.forward_quantized(qg, xs))
    ref = np.asarray(jax_exec.make_vmap_forward(g)(xs))
    per_position = float((got.argmax(-1) == ref.argmax(-1)).mean())
    flat = float((got.reshape(len(xs), -1).argmax(-1)
                  == ref.reshape(len(xs), -1).argmax(-1)).mean())
    assert stats["top1_agreement"] == pytest.approx(per_position)
    # the two metrics genuinely differ on this net — the flat one
    # scored 100 positions with one lucky argmax per image
    assert per_position != flat, (per_position, flat)


# ------------------------------------------------------- engine wiring ----

def test_session_int8_end_to_end():
    from repro.engine import InferenceSession
    g = PAPER_CNNS["ball"]()
    xs = _calib(g.input_shape, n=16)
    s8 = InferenceSession(g, backend="c", precision="int8",
                          calibration=xs, simd="generic")
    sref = InferenceSession(g, backend="xla", precision="int8",
                            calibration=xs)
    got, ref = s8.predict(xs), sref.predict(xs)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
    info = s8.info
    assert info["precision"] == "int8"
    assert info["quantized_layers"]
    assert info["arena_bytes"] > 0


def test_provided_qparams_bit_identical_c():
    """QAT-import seam: feeding the acts of a calibrated build back
    through quantize_from_qparams must reproduce the generated C
    bit-for-bit — weight/bias quantization depends only on the
    activation qparams.  Identity/MaxPool entries may be omitted
    (producer-sharing rule)."""
    from repro.core import codegen
    g = passes.optimize(PAPER_CNNS["ball"](), simd_multiple=1)
    xs = _calib(g.input_shape, n=16)
    qg_cal = quantize.quantize(g, xs)
    shared = {l.name for l in g.layers
              if isinstance(l, quantize._SHARE_INPUT_QPARAMS)}
    qparams = {n: (qp.scale, qp.zero_point)
               for n, qp in qg_cal.acts.items() if n not in shared}
    qg_qp = quantize.quantize_from_qparams(g, qparams)
    assert qg_qp.method == "provided"
    assert qg_qp.acts == qg_cal.acts
    opts = cgen.CodegenOptions(simd="generic")
    src_qp = codegen.compile(qg_qp, opts).source
    src_cal = codegen.compile(qg_cal, opts).source
    # only the banner's provenance tag may differ; every emitted
    # constant and loop is byte-identical
    assert src_qp != src_cal  # the tag honestly records the source
    assert src_qp.replace("calibration=provided",
                          "calibration=minmax") == src_cal


def test_provided_qparams_validation():
    g = passes.optimize(PAPER_CNNS["ball"](), simd_multiple=1)
    xs = _calib(g.input_shape, n=8)
    acts = quantize.quantize(g, xs).acts
    weighted = next(l.name for l in g.layers
                    if isinstance(l, quantize._WEIGHTED))
    missing = {n: qp for n, qp in acts.items() if n != weighted}
    with pytest.raises(ValueError, match="missing"):
        quantize.quantize_from_qparams(g, missing)
    with pytest.raises(ValueError, match="not a layer"):
        quantize.quantize_from_qparams(g, {**acts, "nope": (1.0, 0)})
    with pytest.raises(TypeError, match="expected QParams"):
        quantize.quantize_from_qparams(g, {**acts, weighted: "bad"})
    with pytest.raises(ValueError, match="scale"):
        quantize.quantize_from_qparams(g, {**acts, weighted: (0.0, 0)})
    # accepted spellings: QParams, (scale, zp), {"scale": ..., ...}
    mixed = dict(acts)
    mixed[weighted] = {"scale": acts[weighted].scale,
                       "zero_point": acts[weighted].zero_point}
    assert quantize.quantize_from_qparams(g, mixed).acts == acts


def test_session_provided_qparams_skips_calibration():
    """CalibrationConfig(qparams=...) goes straight to the quantized
    build — same predictions as the calibrated session it was exported
    from, method reported as 'provided', no calibration data needed."""
    from repro.engine import InferenceSession, SessionConfig
    g = PAPER_CNNS["ball"]()
    xs = _calib(g.input_shape, n=16)
    s_cal = InferenceSession(g, backend="c", precision="int8",
                             calibration=xs, simd="generic")
    qparams = {n: (qp.scale, qp.zero_point)
               for n, qp in s_cal.qgraph.acts.items()}
    s_qp = InferenceSession(g, config=SessionConfig(
        backend="c", precision="int8", simd="generic",
        calibration={"qparams": qparams}))
    np.testing.assert_array_equal(s_qp.predict(xs), s_cal.predict(xs))
    assert s_qp.info["calibration_method"] == "provided"
    # qparams are runtime state, like data: portable() drops them and
    # the info config section stays reconstructible
    assert SessionConfig(**s_qp.info["config"]).calibration.qparams is None


def test_session_int8_arena_shrinks_vs_fp32():
    from repro.engine import InferenceSession
    g = PAPER_CNNS["pedestrian"]()
    xs = _calib(g.input_shape, n=4)
    sf = InferenceSession(g, backend="c", simd="sse")
    s8 = InferenceSession(g, backend="c", precision="int8",
                          calibration=xs, simd="sse")
    # int8 intermediates: ~4x smaller (the int8 arena also carries the
    # quantized input copy, so slightly less than exactly 4x)
    assert s8.info["arena_bytes"] * 2 < sf.info["arena_bytes"]


def test_session_int8_autotune_over_quant_kernels():
    from repro.engine import InferenceSession
    g = PAPER_CNNS["ball"]()
    xs = _calib(g.input_shape, n=8)
    sess = InferenceSession(g, backend="c", precision="int8",
                            calibration=xs, autotune=True, tune_iters=30)
    ref = InferenceSession(g, backend="xla", precision="int8",
                           calibration=xs)
    np.testing.assert_allclose(sess.predict(xs), ref.predict(xs),
                               rtol=1e-5, atol=1e-6)


def test_session_int8_tuning_cache_round_trip(tmp_path):
    from repro.engine import InferenceSession
    g = PAPER_CNNS["ball"]()
    xs = _calib(g.input_shape, n=8)
    s1 = InferenceSession(g, backend="c", precision="int8",
                          calibration=xs, autotune=True, tune_iters=20,
                          tune_cache=str(tmp_path))
    assert s1.tuned is not None and not s1.tuned.from_cache
    s2 = InferenceSession(g, backend="c", precision="int8",
                          calibration=xs, autotune=True, tune_iters=20,
                          tune_cache=str(tmp_path))
    assert s2.tuned.from_cache and s2.simd == s1.simd
    np.testing.assert_array_equal(s1.predict(xs), s2.predict(xs))


def test_session_calibration_method_threads_through_info():
    from repro.engine import InferenceSession
    g = PAPER_CNNS["ball"]()
    xs = _calib(g.input_shape, n=16)
    s = InferenceSession(g, backend="c", precision="int8",
                         calibration=xs, simd="generic",
                         calibration_method="percentile",
                         calibration_percentile=99.9)
    assert s.info["calibration_method"] == "percentile"
    assert s.info["calibration_percentile"] == 99.9
    assert s.qgraph.method == "percentile"
    mm = InferenceSession(g, backend="c", precision="int8",
                          calibration=xs, simd="generic")
    assert mm.info["calibration_method"] == "minmax"
    assert "calibration_percentile" not in mm.info


def test_session_int8_tune_cache_keyed_by_calibration(tmp_path):
    """Different calibration methods produce different qparams, hence
    different generated C — the autotune cache must not hand one
    method's record to another (qparams_digest in the key)."""
    from repro.engine import InferenceSession
    g = PAPER_CNNS["ball"]()
    xs = _calib(g.input_shape, n=8)
    s1 = InferenceSession(g, backend="c", precision="int8",
                          calibration=xs, autotune=True, tune_iters=20,
                          tune_cache=str(tmp_path))
    assert not s1.tuned.from_cache
    s2 = InferenceSession(g, backend="c", precision="int8",
                          calibration=xs, autotune=True, tune_iters=20,
                          tune_cache=str(tmp_path),
                          calibration_method="mse")
    assert not s2.tuned.from_cache  # a different program: fresh tune
    s3 = InferenceSession(g, backend="c", precision="int8",
                          calibration=xs, autotune=True, tune_iters=20,
                          tune_cache=str(tmp_path),
                          calibration_method="mse")
    assert s3.tuned.from_cache and s3.simd == s2.simd


def test_quantized_threads_match_sequential():
    """Dtype-aware workspace binding: the threaded path allocates int8
    arenas and must reproduce the sequential batch exactly."""
    g = passes.optimize(PAPER_CNNS["ball"](), simd_multiple=1)
    xs = _calib(g.input_shape, n=8)
    qg = quantize.quantize(g, xs)
    net = runtime.build_quantized(qg, cgen.CodegenOptions(simd="generic"))
    np.testing.assert_array_equal(net.predict_batch(xs),
                                  net.predict_batch(xs, threads=3))


def test_check_quantizable_rejects_unfolded_batchnorm():
    rng = np.random.default_rng(0)
    g = CNNGraph([
        Input(shape=(4, 4, 2)),
        _conv(rng, 1, 1, 2, 2),
        BatchNorm(mean=np.zeros(2), var=np.ones(2)),
        _conv(rng, 1, 1, 2, 2),
    ])
    with pytest.raises(ValueError, match="BatchNorm"):
        quantize.check_quantizable(g)


# ------------------------------------------------------- strict ANSI C ----

def test_quantized_c_is_strict_ansi_c89(tmp_path):
    gcc = shutil.which("gcc")
    if gcc is None:
        pytest.skip("gcc not available")
    g = passes.optimize(residual_cnn(), simd_multiple=1)
    # percentile: the histogram-selected constants and the per-branch
    # Concat requant must emit the same strict-ANSI shape as minmax
    qg = quantize.quantize(g, _calib(g.input_shape), method="percentile")
    src = cgen.generate_quantized_c(qg, cgen.CodegenOptions(simd="generic"))
    c_path = tmp_path / "quant.c"
    c_path.write_text(src)
    proc = subprocess.run(
        [gcc, "-std=c89", "-Wall", "-Wextra", "-Werror",
         "-pedantic-errors", "-c", str(c_path), "-o", str(c_path) + ".o"],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr[:4000]


# ------------------------------------------- default-calibration bugfix ----

def test_default_calibration_robot_net_regression():
    """The session's *default* int8 calibration (caller supplies no
    data) used to be unbounded standard-normal noise — the exact
    failure mode diagnosed on the robot net (top-1 agreement 0.94).
    The default is now representative camera-like frames with auto
    percentile range selection; the default-calibrated robot net must
    keep >= 0.99 top-1 agreement on held-out frames."""
    from repro.data.pipeline import camera_frame_batch
    from repro.engine import InferenceSession, SessionConfig

    g = PAPER_CNNS["robot"]()
    s = InferenceSession(g, config=SessionConfig(backend="xla",
                                                 precision="int8"))
    # auto method resolution: synthesized frames -> percentile
    assert s.qgraph.method == "percentile"
    held_out = camera_frame_batch(16, g.input_shape, seed=99)
    stats = quantize.quantization_error(s.qgraph, held_out)
    assert stats["top1_agreement"] >= 0.99, stats


def test_default_calibration_explicit_data_keeps_minmax():
    # callers who pass their own data keep the historical bit-stable
    # default (minmax), and an explicit method always wins
    from repro.engine import InferenceSession, SessionConfig

    g = PAPER_CNNS["ball"]()
    xs = _calib(g.input_shape, n=8)
    s = InferenceSession(g, config=SessionConfig(
        backend="xla", precision="int8",
        calibration={"data": xs}))
    assert s.qgraph.method == "minmax"
    s2 = InferenceSession(g, config=SessionConfig(
        backend="xla", precision="int8",
        calibration={"method": "mse"}))
    assert s2.qgraph.method == "mse"
