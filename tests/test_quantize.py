"""Int8 post-training quantization: emitted-literal fidelity, exact
C-vs-jax-reference parity on the integer path, accuracy vs the float
oracle, arena shrinkage, dtype-aware threading, and the strict-ANSI
claim for the quantized emitter."""
import shutil
import subprocess

import numpy as np
import pytest

try:  # hypothesis widens the literal search; a fixed grid runs without
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.configs.cnn_paper import PAPER_CNNS, residual_cnn
from repro.core import cgen, jax_exec, passes, quantize, runtime
from repro.core.cgen import _flit
from repro.core.graph import (
    Add, AvgPool, BatchNorm, CNNGraph, Concat, Conv2D, DepthwiseConv2D,
    GlobalAvgPool, Input, MaxPool,
)


def _conv(rng, kh, kw, ci, co, **kw_args) -> Conv2D:
    w = rng.normal(0, 0.5, (kh, kw, ci, co)).astype(np.float32)
    b = rng.normal(0, 0.1, (co,)).astype(np.float32)
    return Conv2D(weights=w, bias=b, **kw_args)


def _dw(rng, kh, kw, c, m, **kw_args) -> DepthwiseConv2D:
    w = rng.normal(0, 0.5, (kh, kw, c, m)).astype(np.float32)
    b = rng.normal(0, 0.1, (c * m,)).astype(np.float32)
    return DepthwiseConv2D(weights=w, bias=b, **kw_args)


def _zoo_graph(seed=1) -> CNNGraph:
    """Every quantizable construct, ending in a softmax-free sink so
    the whole net is on the exact integer path."""
    rng = np.random.default_rng(seed)
    return CNNGraph([
        Input(shape=(12, 10, 3), name="in"),
        _conv(rng, 3, 3, 3, 8, padding="same", activation="relu",
              name="c1"),
        MaxPool(size=(2, 2), padding="same", name="mp"),
        _dw(rng, 3, 3, 8, 1, padding="same", activation="leaky_relu",
            name="dw1"),
        _conv(rng, 1, 1, 8, 8, padding="valid", name="pw"),
        Add(name="add", inputs=["pw", "mp"], activation="relu"),
        _conv(rng, 1, 1, 8, 4, name="b1", inputs=["add"]),
        _conv(rng, 3, 3, 8, 4, padding="same", name="b2", inputs=["add"]),
        Concat(name="cat", inputs=["b1", "b2"]),
        AvgPool(size=(3, 3), strides=(2, 2), padding="same", name="ap"),
        GlobalAvgPool(name="gap"),
        _conv(rng, 1, 1, 8, 5, name="head", activation="relu"),
    ])


def _calib(shape, n=8, seed=3):
    return np.random.default_rng(seed).normal(
        size=(n,) + tuple(shape)).astype(np.float32)


# ------------------------------------------ emitted-literal fidelity ----

def _assert_flit_roundtrip(v: np.float32) -> None:
    lit = _flit(v)
    assert lit.endswith("f")
    back = np.float32(lit[:-1])
    assert back.tobytes() == np.float32(v).tobytes(), (v, lit, back)


_FLIT_GRID = np.concatenate([
    np.random.default_rng(0).normal(0, 1, 200),
    np.random.default_rng(1).normal(0, 1e-30, 50),
    np.random.default_rng(2).normal(0, 1e30, 50),
    [0.0, -0.0, 1.0, -1.0, 1 / 3, np.float32(2 ** -149),
     -np.float32(2 ** -149), np.finfo(np.float32).max,
     np.finfo(np.float32).min, np.finfo(np.float32).tiny],
]).astype(np.float32)


def test_flit_roundtrip_grid():
    """Every emitted C float literal parses back bit-exact (P3 depends
    on it; the quantized requant scales depend on it doubly)."""
    for v in _FLIT_GRID:
        _assert_flit_roundtrip(v)


if HAVE_HYPOTHESIS:
    @settings(max_examples=300, deadline=None)
    @given(st.floats(width=32, allow_nan=False, allow_infinity=False))
    def test_flit_roundtrip_property(x):
        _assert_flit_roundtrip(np.float32(x))


def test_qparams_zero_exactly_representable():
    for mn, mx in [(-1.3, 2.7), (0.0, 5.0), (-4.2, 0.0), (0.0, 0.0),
                   (0.5, 2.0), (-3.0, -1.0)]:
        qp = quantize.qparams_from_range(mn, mx)
        assert quantize.QMIN <= qp.zero_point <= quantize.QMAX
        z = qp.quantize(np.zeros(1, np.float32))
        assert z[0] == qp.zero_point
        assert qp.dequantize(z)[0] == 0.0


# ------------------------------------------------ integer-path parity ----

@pytest.mark.parametrize("simd", ["generic", "sse"])
def test_quantized_c_bit_exact_vs_jax_reference(simd):
    """The generated int8 C and the quantized jax reference share every
    float32 requant constant and an exact int32 integer path — on a
    softmax-free net the outputs must be *identical*, not just close
    (SIMD included: integer addition is associative)."""
    if simd == "sse" and not runtime.host_supports_ssse3():
        pytest.skip("no SSSE3")
    g = passes.optimize(_zoo_graph(), simd_multiple=1)
    xs = _calib(g.input_shape)
    qg = quantize.quantize(g, xs)
    ref = np.asarray(jax_exec.make_jit_forward_quantized(qg)(xs))
    net = runtime.build_quantized(qg, cgen.CodegenOptions(simd=simd))
    got = net.predict_batch(xs).reshape(ref.shape)
    np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("name", ["ball", "residual"])
def test_quantized_c_matches_jax_reference_cnn(name):
    """cnn_paper + residual configs: exact integer path, float softmax
    tail allowed one-ulp wiggle (libm expf vs XLA exp)."""
    builder = PAPER_CNNS.get(name, residual_cnn)
    g = passes.optimize(builder(), simd_multiple=1)
    xs = _calib(g.input_shape, n=16)
    qg = quantize.quantize(g, xs)
    ref = np.asarray(jax_exec.make_jit_forward_quantized(qg)(xs))
    net = runtime.build_quantized(qg, cgen.CodegenOptions(simd="generic"))
    got = net.predict_batch(xs).reshape(ref.shape)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
    assert (got.reshape(len(xs), -1).argmax(-1)
            == ref.reshape(len(xs), -1).argmax(-1)).all()


@pytest.mark.slow
def test_quantized_c_matches_jax_reference_pedestrian_robot():
    for builder in (PAPER_CNNS["pedestrian"], PAPER_CNNS["robot"]):
        g = passes.optimize(builder(), simd_multiple=1)
        xs = _calib(g.input_shape, n=4)
        qg = quantize.quantize(g, xs)
        ref = np.asarray(jax_exec.make_jit_forward_quantized(qg)(xs))
        net = runtime.build_quantized(qg, cgen.CodegenOptions(simd="sse"))
        got = net.predict_batch(xs).reshape(ref.shape)
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)


# ------------------------------------------------- accuracy vs float ----

def test_quantized_close_to_float_oracle():
    g = passes.optimize(PAPER_CNNS["ball"](), simd_multiple=1)
    xs = _calib(g.input_shape, n=64)
    qg = quantize.quantize(g, xs)
    stats = quantize.quantization_error(qg, xs)
    # softmax probabilities: int8 should stay within a few percent and
    # agree on top-1 for nearly all calibration images
    assert stats["max_abs_err"] < 0.08, stats
    assert stats["top1_agreement"] >= 0.85, stats


# ------------------------------------------------------- engine wiring ----

def test_session_int8_end_to_end():
    from repro.engine import InferenceSession
    g = PAPER_CNNS["ball"]()
    xs = _calib(g.input_shape, n=16)
    s8 = InferenceSession(g, backend="c", precision="int8",
                          calibration=xs, simd="generic")
    sref = InferenceSession(g, backend="xla", precision="int8",
                            calibration=xs)
    got, ref = s8.predict(xs), sref.predict(xs)
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)
    info = s8.info
    assert info["precision"] == "int8"
    assert info["quantized_layers"]
    assert info["arena_bytes"] > 0


def test_session_int8_arena_shrinks_vs_fp32():
    from repro.engine import InferenceSession
    g = PAPER_CNNS["pedestrian"]()
    xs = _calib(g.input_shape, n=4)
    sf = InferenceSession(g, backend="c", simd="sse")
    s8 = InferenceSession(g, backend="c", precision="int8",
                          calibration=xs, simd="sse")
    # int8 intermediates: ~4x smaller (the int8 arena also carries the
    # quantized input copy, so slightly less than exactly 4x)
    assert s8.info["arena_bytes"] * 2 < sf.info["arena_bytes"]


def test_session_int8_autotune_over_quant_kernels():
    from repro.engine import InferenceSession
    g = PAPER_CNNS["ball"]()
    xs = _calib(g.input_shape, n=8)
    sess = InferenceSession(g, backend="c", precision="int8",
                            calibration=xs, autotune=True, tune_iters=30)
    ref = InferenceSession(g, backend="xla", precision="int8",
                           calibration=xs)
    np.testing.assert_allclose(sess.predict(xs), ref.predict(xs),
                               rtol=1e-5, atol=1e-6)


def test_session_int8_tuning_cache_round_trip(tmp_path):
    from repro.engine import InferenceSession
    g = PAPER_CNNS["ball"]()
    xs = _calib(g.input_shape, n=8)
    s1 = InferenceSession(g, backend="c", precision="int8",
                          calibration=xs, autotune=True, tune_iters=20,
                          tune_cache=str(tmp_path))
    assert s1.tuned is not None and not s1.tuned.from_cache
    s2 = InferenceSession(g, backend="c", precision="int8",
                          calibration=xs, autotune=True, tune_iters=20,
                          tune_cache=str(tmp_path))
    assert s2.tuned.from_cache and s2.simd == s1.simd
    np.testing.assert_array_equal(s1.predict(xs), s2.predict(xs))


def test_quantized_threads_match_sequential():
    """Dtype-aware workspace binding: the threaded path allocates int8
    arenas and must reproduce the sequential batch exactly."""
    g = passes.optimize(PAPER_CNNS["ball"](), simd_multiple=1)
    xs = _calib(g.input_shape, n=8)
    qg = quantize.quantize(g, xs)
    net = runtime.build_quantized(qg, cgen.CodegenOptions(simd="generic"))
    np.testing.assert_array_equal(net.predict_batch(xs),
                                  net.predict_batch(xs, threads=3))


def test_check_quantizable_rejects_unfolded_batchnorm():
    rng = np.random.default_rng(0)
    g = CNNGraph([
        Input(shape=(4, 4, 2)),
        _conv(rng, 1, 1, 2, 2),
        BatchNorm(mean=np.zeros(2), var=np.ones(2)),
        _conv(rng, 1, 1, 2, 2),
    ])
    with pytest.raises(ValueError, match="BatchNorm"):
        quantize.check_quantizable(g)


# ------------------------------------------------------- strict ANSI C ----

def test_quantized_c_is_strict_ansi_c89(tmp_path):
    gcc = shutil.which("gcc")
    if gcc is None:
        pytest.skip("gcc not available")
    g = passes.optimize(residual_cnn(), simd_multiple=1)
    qg = quantize.quantize(g, _calib(g.input_shape))
    src = cgen.generate_quantized_c(qg, cgen.CodegenOptions(simd="generic"))
    c_path = tmp_path / "quant.c"
    c_path.write_text(src)
    proc = subprocess.run(
        [gcc, "-std=c89", "-Wall", "-Wextra", "-Werror",
         "-pedantic-errors", "-c", str(c_path), "-o", str(c_path) + ".o"],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr[:4000]
