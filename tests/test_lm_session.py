"""The LM workload behind the unified session surface: the
``"pallas-lm"`` registry entry, SessionConfig.lm round-trips, the
kernel-variant autotuner + on-disk tuning cache, prefill/decode greedy
equality against the direct :mod:`repro.models.lm` call, mesh fallback,
and token-level serving through the bounded-queue server machinery."""
import glob
import json

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.engine import (  # noqa: E402
    InferenceSession, LMConfig, LMSession, SessionConfig, TuningCache,
    available_backends, get_backend, tune_lm_variants,
)
from repro.engine.backends import LMBackend  # noqa: E402
from repro.models import make_decode_step, make_prefill_step  # noqa: E402
from repro.models.stack import DEFAULT_PAR  # noqa: E402

MAX_CTX, PROMPT, BATCH, STEPS = 32, 12, 2, 4


@pytest.fixture(scope="module")
def cache_dir(tmp_path_factory):
    return str(tmp_path_factory.mktemp("lmtune"))


@pytest.fixture(scope="module")
def sess(cache_dir):
    """One autotuned session shared by the module (builds jit programs
    once; the variant timing itself is the slow part)."""
    return LMSession(config=SessionConfig(
        backend="pallas-lm", autotune=True, tune_cache=cache_dir,
        lm=LMConfig(arch="gemma3-4b", max_context=MAX_CTX,
                    decode_batch=BATCH)))


def _prompts(n=BATCH, t=PROMPT, vocab=256, seed=3):
    rng = np.random.default_rng(seed)
    return rng.integers(0, vocab, size=(n, t)).astype(np.int32)


# ------------------------------------------------------ registry seam ----

def test_registry_lists_lm_backend():
    assert "pallas-lm" in available_backends()
    cls = get_backend("pallas-lm")
    assert issubclass(cls, LMBackend)
    assert cls.workload == "lm"
    assert get_backend("c").workload == "cnn"


def test_cnn_session_rejects_lm_config():
    from repro.configs.cnn_paper import PAPER_CNNS
    g = PAPER_CNNS["ball"]()
    with pytest.raises(TypeError, match="LMSession"):
        InferenceSession(g, config=SessionConfig(lm=LMConfig()))
    # mixed legacy kwarg + config stays an error with lm in the mix
    with pytest.raises(TypeError, match="not both"):
        InferenceSession(g, config=SessionConfig(lm=LMConfig()),
                         backend="xla")
    with pytest.raises(TypeError, match="needs SessionConfig.lm"):
        LMSession(config=SessionConfig())
    with pytest.raises(ValueError, match="LM contract"):
        LMSession(config=SessionConfig(backend="xla", lm=LMConfig()))


def test_session_config_lm_round_trip():
    cfg = SessionConfig(backend="pallas-lm", autotune=True,
                        lm=LMConfig(arch="gemma3-4b", max_context=64,
                                    decode_batch=2,
                                    attn_variant="reference",
                                    block_q=128, mesh_shape=(1, 1)))
    d = json.loads(json.dumps(cfg.to_dict()))  # JSON-safe
    assert d["lm"]["mesh_shape"] == [1, 1]
    assert SessionConfig(**d) == cfg.portable() == cfg
    assert SessionConfig.from_dict(d) == cfg
    # shorthand spellings coerce to the same LMConfig
    assert SessionConfig(lm="gemma3-4b").lm == LMConfig(arch="gemma3-4b")
    assert SessionConfig(lm={"arch": "gemma3-4b"}).lm == LMConfig()
    assert SessionConfig().lm is None


def test_lm_config_validates():
    with pytest.raises(ValueError, match="arch"):
        LMConfig(arch="nope")
    with pytest.raises(ValueError, match="attn_variant"):
        LMConfig(attn_variant="fast")
    with pytest.raises(ValueError, match="scan_variant"):
        LMConfig(scan_variant="nope")
    with pytest.raises(ValueError, match="max_context"):
        LMConfig(max_context=0)
    with pytest.raises(ValueError, match="mesh_shape"):
        LMConfig(mesh_shape=(0, 2))
    with pytest.raises(TypeError, match="lm must be"):
        SessionConfig(lm=3)


# ------------------------------------------------------ the CPU smoke ----

def test_prefill_decode_matches_direct_model(sess):
    """Prefill + 4 decode steps through the session equal the greedy
    loop over the direct models/lm.py step functions (same params,
    same kernel policy)."""
    toks = _prompts(vocab=sess.model_cfg.vocab_size)
    logits, handle = sess.prefill(toks)
    assert logits.shape == (BATCH, sess.model_cfg.vocab_size)
    got = [np.argmax(logits, -1).astype(np.int32)]
    for _ in range(STEPS):
        step = sess.decode(handle, got[-1])
        assert step.shape == (BATCH, sess.model_cfg.vocab_size)
        got.append(np.argmax(step, -1).astype(np.int32))
    got = np.stack(got, axis=1)

    cfg = sess.model_cfg
    par = DEFAULT_PAR.with_kernels(sess.kernel_policy)
    prefill = jax.jit(make_prefill_step(cfg, max_len=MAX_CTX, par=par))
    decode = jax.jit(make_decode_step(cfg, par=par))
    lg, caches, pos = prefill(sess.backend.params,
                              {"tokens": jnp.asarray(toks)})
    tok = jnp.argmax(lg, -1)[:, None]
    ref = [np.asarray(tok[:, 0], np.int32)]
    for _ in range(STEPS):
        lg, caches, pos = decode(sess.backend.params, caches, tok, pos)
        tok = jnp.argmax(lg, -1)[:, None]
        ref.append(np.asarray(tok[:, 0], np.int32))
    np.testing.assert_array_equal(got, np.stack(ref, axis=1))

    # generate() is exactly that loop
    np.testing.assert_array_equal(
        sess.generate(toks, STEPS + 1), got)


def test_predict_full_sequence_agrees_with_prefill(sess):
    toks = _prompts(vocab=sess.model_cfg.vocab_size)
    full = sess.predict(toks)
    assert full.shape == (BATCH, PROMPT, sess.model_cfg.vocab_size)
    last, _ = sess.prefill(toks)
    np.testing.assert_array_equal(full[:, -1].argmax(-1),
                                  last.argmax(-1))


def test_prompt_longer_than_context_rejected(sess):
    with pytest.raises(ValueError, match="max_context"):
        sess.prefill(_prompts(t=MAX_CTX + 1))


def test_session_info(sess):
    info = sess.info
    assert info["workload"] == "lm"
    assert info["backend"] == "pallas-lm"
    assert info["arch"] == "gemma3-4b-smoke"
    assert info["kernel_policy"]["attention"] in (
        "flash_jax", "flash_pallas", "reference")
    assert info["n_params"] > 0
    json.dumps(info["config"])  # reconstructible + serializable
    assert SessionConfig(**info["config"]) == sess.config.portable()


# --------------------------------------------- autotune + tuning cache ----

def test_autotune_persists_winner(sess, cache_dir):
    assert sess.tuned is not None and not sess.tuned.from_cache
    assert sess.tuned.prefill_us > 0
    files = glob.glob(cache_dir + "/*.json")
    assert files, "autotuned winner must land in the on-disk cache"
    rec = json.load(open(files[0]))
    assert rec["policy"]["attention"] == sess.kernel_policy.attention
    assert rec["arch"] == "gemma3-4b-smoke"


def test_second_session_loads_policy_from_cache(sess, cache_dir):
    s2 = LMSession(config=sess.config)
    assert s2.tuned.from_cache
    assert s2.kernel_policy == sess.kernel_policy
    toks = _prompts(vocab=sess.model_cfg.vocab_size)
    np.testing.assert_array_equal(s2.generate(toks, 3),
                                  sess.generate(toks, 3))


def test_tuning_cache_keys_unique_across_variants(sess, tmp_path):
    """Every pinned Pallas-variant combination keys its own cache entry
    — one variant's measurement can never answer for another's."""
    cache = TuningCache(str(tmp_path))
    cfg, params = sess.model_cfg, sess.backend.params
    pins = [
        dict(attention="flash_jax", scan="chunked",
             block_q=128, block_k=128),
        dict(attention="reference", scan="chunked",
             block_q=128, block_k=128),
        dict(attention="flash_jax", scan="chunked",
             block_q=256, block_k=128),
    ]
    for n, fixed in enumerate(pins, start=1):
        r = tune_lm_variants(cfg, params, max_context=16, prompt=8,
                             cache=cache, iters=1, fixed=fixed)
        assert not r.from_cache
        assert r.policy.attention == fixed["attention"]
        assert len(glob.glob(str(tmp_path) + "/*.json")) == n
    # and a repeat of the first pin is a pure cache hit
    r = tune_lm_variants(cfg, params, max_context=16, prompt=8,
                         cache=cache, iters=1, fixed=pins[0])
    assert r.from_cache
    assert len(glob.glob(str(tmp_path) + "/*.json")) == len(pins)


def test_pinned_variants_skip_autotuning(cache_dir):
    s = LMSession(config=SessionConfig(
        backend="pallas-lm",
        lm=LMConfig(max_context=16, attn_variant="reference",
                    scan_variant="chunked", block_q=128, block_k=128)))
    assert s.tuned is None
    assert s.kernel_policy.attention == "reference"
    out = s.generate(_prompts(t=8), 2)
    assert out.shape == (BATCH, 2)


# ----------------------------------------------------------- mesh path ----

def test_mesh_fallback_on_undersized_host():
    cfg = SessionConfig(backend="pallas-lm",
                        lm=LMConfig(max_context=16, mesh_shape=(8, 8),
                                    attn_variant="flash_jax"))
    with pytest.warns(RuntimeWarning, match="mesh_shape"):
        s = LMSession(config=cfg)
    assert s.mesh is None
    assert s.generate(_prompts(t=8), 2).shape == (BATCH, 2)


def test_mesh_single_device_matches_unmeshed():
    lm = LMConfig(max_context=16, attn_variant="flash_jax",
                  scan_variant="chunked", block_q=128, block_k=128)
    s0 = LMSession(config=SessionConfig(backend="pallas-lm", lm=lm))
    s1 = LMSession(config=SessionConfig(
        backend="pallas-lm",
        lm=LMConfig(**{**lm.to_dict(), "mesh_shape": (1, 1)})))
    assert s1.mesh is not None
    toks = _prompts(t=8)
    np.testing.assert_array_equal(s1.generate(toks, 3),
                                  s0.generate(toks, 3))


# ------------------------------------------------------- token serving ----

def test_lm_token_server_end_to_end(sess):
    from repro.serve import LMTokenServer, ServerConfig
    toks = _prompts(vocab=sess.model_cfg.vocab_size)
    want = sess.generate(toks, 6)
    with LMTokenServer(sess, config=ServerConfig(
            workers=1, max_batch=4, request_timeout_ms=None)) as srv:
        futs = [srv.submit(toks[i], max_new=6) for i in range(BATCH)]
        got = np.stack([f.result(timeout=120.0) for f in futs])
        # mixed shapes ride the same queue: a shorter prompt with a
        # different max_new still comes back in order
        other = srv.generate(toks[0, :6], max_new=3, timeout=120.0)
        stats = srv.stats()
    np.testing.assert_array_equal(got, want)
    assert other.shape == (3,)
    assert stats["completed"] == BATCH + 1
    with pytest.raises(TypeError, match="serves tokens"):
        srv.predict(toks[0])


def test_lm_token_server_validates(sess):
    from repro.serve import LMTokenServer
    with pytest.raises(TypeError, match="LMSession or LMBackend"):
        LMTokenServer(object())
    with LMTokenServer(sess.backend, workers=1) as srv:
        with pytest.raises(ValueError, match="1-D int"):
            srv.submit(np.zeros((2, 3), np.int32))
        with pytest.raises(ValueError, match="max_new"):
            srv.submit(np.zeros(3, np.int32), max_new=0)
