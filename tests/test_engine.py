"""InferenceSession engine: cross-backend agreement, tuning-cache
round-trip, batched-vs-looped equivalence, SessionConfig round-trip and
the legacy-kwarg deprecation shim, and the formal Backend protocol."""
import warnings

import numpy as np
import pytest

from repro.configs.cnn_paper import PAPER_CNNS
from repro.core import runtime
from repro.core.graph import CNNGraph, Conv2D, Input, MaxPool, Softmax
from repro.engine import (CalibrationConfig, InferenceSession, SessionConfig,
                          TuningCache, available_backends, get_backend,
                          graph_fingerprint)

RTOL, ATOL = 1e-3, 1e-5


def _tiny_cnn(seed=0) -> CNNGraph:
    """A small but multi-layer net so autotune tests stay fast."""
    r = np.random.default_rng(seed)
    w1 = r.normal(0, 0.5, (3, 3, 1, 4)).astype(np.float32)
    w2 = r.normal(0, 0.5, (2, 2, 4, 2)).astype(np.float32)
    return CNNGraph([
        Input(shape=(8, 8, 1)),
        Conv2D(weights=w1, bias=r.normal(0, 0.1, (4,)).astype(np.float32),
               padding="same", activation="relu"),
        MaxPool(size=(2, 2)),
        Conv2D(weights=w2, bias=r.normal(0, 0.1, (2,)).astype(np.float32),
               padding="valid"),
        Softmax(),
    ])


def _batch(shape, n=3, seed=1):
    return np.random.default_rng(seed).normal(
        size=(n,) + tuple(shape)).astype(np.float32)


# -- SessionConfig ----------------------------------------------------------

def test_session_config_path_matches_legacy_kwargs():
    g = _tiny_cnn()
    x = _batch(g.input_shape)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = InferenceSession(g, backend="c", simd="structured")
    cfg = InferenceSession(g, config=SessionConfig(backend="c",
                                                   simd="structured"))
    np.testing.assert_array_equal(legacy.predict(x), cfg.predict(x))


def test_session_config_round_trips_through_info():
    cfg = SessionConfig(backend="c", simd="structured", tune_iters=50,
                        threads=2,
                        calibration=CalibrationConfig(samples=8,
                                                      method="mse"))
    sess = InferenceSession(_tiny_cnn(), config=cfg)
    # the stable config section reconstructs the config (info is both a
    # dict and callable, so either API spelling works)
    assert sess.info()["config"] == sess.info["config"]
    rt = SessionConfig(**sess.info["config"])
    assert rt == cfg.portable() == cfg  # no runtime-only fields set here
    # runtime-only fields (calibration data, live cache objects) are
    # dropped by the portable projection, not serialized
    cfg2 = cfg.replace(calibration=CalibrationConfig(
        data=np.zeros((1,) + tuple(_tiny_cnn().input_shape), np.float32)))
    assert SessionConfig(**cfg2.to_dict()) == cfg2.portable()
    assert cfg2.portable().calibration.data is None


def test_session_config_accepts_plain_dicts():
    d = {"backend": "c", "simd": "structured",
         "calibration": {"samples": 4, "method": "percentile",
                         "percentile": 99.9}}
    sess = InferenceSession(_tiny_cnn(), config=d)
    assert sess.config.calibration.percentile == 99.9
    assert sess.config == SessionConfig(**d)


def test_session_config_validates():
    with pytest.raises(ValueError, match="precision"):
        SessionConfig(precision="int4")
    with pytest.raises(ValueError, match="method"):
        CalibrationConfig(method="histogram")
    with pytest.raises(ValueError, match="percentile"):
        CalibrationConfig(percentile=0.0)
    with pytest.raises(ValueError, match="tune_iters"):
        SessionConfig(tune_iters=0)


def test_session_config_is_frozen_with_replace():
    cfg = SessionConfig()
    with pytest.raises(Exception):  # FrozenInstanceError
        cfg.backend = "xla"
    assert cfg.replace(backend="xla").backend == "xla"
    assert cfg.backend == "c"


def test_legacy_kwargs_warn_exactly_once(monkeypatch):
    from repro.engine import session as session_mod
    monkeypatch.setattr(session_mod, "_legacy_warned", False)
    g = _tiny_cnn()
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        InferenceSession(g, backend="c", simd="structured")
        InferenceSession(g, backend="c", simd="structured", unroll=2)
        InferenceSession(g, config=SessionConfig(simd="structured"))
        InferenceSession(g)  # all-defaults: the modern path, no warning
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1, [str(x.message) for x in w]
    assert "SessionConfig" in str(dep[0].message)


def test_config_and_legacy_kwargs_are_mutually_exclusive():
    g = _tiny_cnn()
    with pytest.raises(TypeError, match="not both"):
        InferenceSession(g, backend="c", config=SessionConfig())
    with pytest.raises(TypeError, match="not both"):
        InferenceSession(g, config=SessionConfig(), autotune=True)
    with pytest.raises(TypeError, match="unexpected keyword"):
        InferenceSession(g, calibraton_method="mse")  # typo'd kwarg


# -- Backend protocol -------------------------------------------------------

def test_backend_is_a_formal_abc():
    import abc

    from repro.engine import Backend, register_backend
    assert isinstance(Backend, abc.ABCMeta)

    class Incomplete(Backend):
        pass

    with pytest.raises(TypeError, match="abstract"):
        Incomplete(_tiny_cnn())
    with pytest.raises(TypeError, match="must subclass Backend"):
        register_backend("bogus")(object)
    assert "bogus" not in available_backends()


def test_backend_describe_is_uniform_across_substrates():
    g = _tiny_cnn()
    for name in ("c", "xla"):
        sess = InferenceSession(g, config=SessionConfig(
            backend=name, simd="structured"))
        d = sess.backend.describe()
        assert d["name"] == name
        assert d["precision"] == "fp32"
        assert d["input_shape"] == tuple(g.input_shape)
        assert d["output_shape"] == tuple(sess.output_shape)
    c_desc = InferenceSession(g, config=SessionConfig(
        backend="c", simd="structured")).backend.describe()
    assert c_desc["arena_bytes"] > 0 and c_desc["simd"] == "structured"


def test_backend_close_is_optional_and_idempotent():
    sess = InferenceSession(_tiny_cnn(), config=SessionConfig(
        backend="c", simd="structured"))
    with sess.backend as b:
        pass
    b.close()  # second close: still fine
    sess.close()


# -- registry ---------------------------------------------------------------

def test_registry_lists_all_three_backends():
    assert {"c", "xla", "pallas"} <= set(available_backends())
    with pytest.raises(ValueError, match="unknown backend"):
        get_backend("tpu-asic")


# -- cross-backend agreement ------------------------------------------------

@pytest.mark.parametrize("name", ["ball", "pedestrian"])
def test_cross_backend_agreement(name):
    g = PAPER_CNNS[name]()
    x = _batch(g.input_shape)
    ref = InferenceSession(g, backend="xla", simd="sse").predict(x)
    got_c = InferenceSession(g, backend="c", simd="sse").predict(x)
    got_p = InferenceSession(g, backend="pallas", simd="sse").predict(x)
    assert ref.shape == got_c.shape == got_p.shape
    np.testing.assert_allclose(got_c, ref, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(got_p, ref, rtol=1e-4, atol=ATOL)


@pytest.mark.slow
def test_cross_backend_agreement_robot():
    g = PAPER_CNNS["robot"]()
    x = _batch(g.input_shape, n=2)
    ref = InferenceSession(g, backend="xla", simd="sse").predict(x)
    got_c = InferenceSession(g, backend="c", simd="sse").predict(x)
    np.testing.assert_allclose(got_c, ref, rtol=RTOL, atol=1e-4)


# -- autotuning + cache -----------------------------------------------------

def test_tuning_cache_round_trip(tmp_path):
    g = _tiny_cnn()
    s1 = InferenceSession(g, backend="c", autotune=True, simd="structured",
                          tune_cache=str(tmp_path), tune_iters=30)
    assert s1.tuned is not None and not s1.tuned.from_cache
    assert s1.tuned.levels, "autotuner selected no per-layer levels"

    cc_before = runtime.COMPILE_STATS["cc_invocations"]
    s2 = InferenceSession(_tiny_cnn(), backend="c", autotune=True,
                          simd="structured", tune_cache=str(tmp_path),
                          tune_iters=30)
    # second build answers from the tuning cache and the .so content
    # cache: the C compiler must not run at all
    assert s2.tuned.from_cache
    assert s2.tuned.levels == s1.tuned.levels
    assert runtime.COMPILE_STATS["cc_invocations"] == cc_before

    x = _batch(s1.input_shape)
    np.testing.assert_array_equal(s1.predict(x), s2.predict(x))


def test_simd_search_picks_a_candidate(tmp_path):
    g = _tiny_cnn()
    sess = InferenceSession(
        g, backend="c", autotune=True,
        simd_search=("generic", "structured"),
        tune_cache=str(tmp_path), tune_iters=30)
    assert sess.simd in ("generic", "structured")
    x = _batch(sess.input_shape)
    ref = InferenceSession(g, backend="xla").predict(x)
    np.testing.assert_allclose(sess.predict(x), ref, rtol=RTOL, atol=ATOL)


def test_tuning_cache_keys_differ_by_graph_and_simd(tmp_path):
    cache = TuningCache(str(tmp_path))
    g1, g2 = _tiny_cnn(seed=0), _tiny_cnn(seed=7)
    assert graph_fingerprint(g1) != graph_fingerprint(g2)
    assert cache.key(g1, "sse") != cache.key(g2, "sse")
    assert cache.key(g1, "sse") != cache.key(g1, "generic")


def test_graph_fingerprint_sensitive_to_layer_names():
    # cached unroll levels are keyed by layer name, so a rename must
    # miss the cache even with identical weights
    g1, g2 = _tiny_cnn(seed=0), _tiny_cnn(seed=0)
    assert graph_fingerprint(g1) == graph_fingerprint(g2)
    g2.layers[1].name = "stem"
    assert graph_fingerprint(g1) != graph_fingerprint(g2)


# -- batched execution ------------------------------------------------------

def test_batched_matches_looped_c():
    g = _tiny_cnn()
    sess = InferenceSession(g, backend="c", simd="structured")
    x = _batch(sess.input_shape, n=5)
    batched = sess.predict(x)
    looped = np.stack([sess.predict(x[i]) for i in range(5)])
    # same compiled code runs either way -> bit-identical
    np.testing.assert_array_equal(batched, looped)
    assert batched.shape == (5,) + tuple(sess.output_shape)


def test_compiled_net_batch_entry_matches_single_calls():
    g = _tiny_cnn()
    sess = InferenceSession(g, backend="c", simd="structured")
    net = sess._backend.net
    assert net._batch_fn is not None, "batch wrapper missing from .so"
    x = _batch(sess.input_shape, n=4)
    got = net.predict_batch(x)
    want = np.stack([net(x[i]) for i in range(4)])
    np.testing.assert_array_equal(got, want)


def test_predict_rejects_wrong_shape():
    sess = InferenceSession(_tiny_cnn(), backend="c", simd="structured")
    with pytest.raises(ValueError, match="predict"):
        sess.predict(np.zeros((3, 3), np.float32))


def test_benchmark_slices_batch_to_one_image():
    # regression: a batched array used to trip the C backend's
    # single-image assert; the session now slices batch[0] consistently
    # for every backend (and still rejects junk shapes)
    for backend in ("c", "xla"):
        sess = InferenceSession(_tiny_cnn(), backend=backend,
                                simd="structured")
        t = sess.benchmark(_batch(sess.input_shape, n=4), iters=5,
                           warmup=1)
        assert np.isfinite(t) and t > 0
        with pytest.raises(ValueError, match="one image"):
            sess.benchmark(np.zeros((3, 3), np.float32))


def test_jax_backend_timing_measures_compute_not_dispatch():
    # regression: without block_until_ready() inside the timed loop,
    # timing a jitted fn measures async dispatch instead of compute.
    # Compare against a measured dispatch-only baseline rather than a
    # wall-clock constant so the test is machine-independent.
    import time

    import jax
    if not getattr(jax.config, "jax_cpu_enable_async_dispatch", True):
        pytest.skip("synchronous CPU dispatch: nothing to regress")

    sess = InferenceSession(PAPER_CNNS["pedestrian"](), backend="xla")
    t_blocked = sess.benchmark(iters=10, warmup=3)
    assert np.isfinite(t_blocked) and t_blocked > 0

    import jax.numpy as jnp
    fn = sess._backend._fn
    xb = jnp.asarray(np.zeros((1,) + tuple(sess.input_shape), np.float32))
    fn(xb).block_until_ready()  # compiled and warm
    t0 = time.perf_counter()
    for _ in range(10):
        fn(xb)  # the buggy loop: dispatch only, never blocks
    t_dispatch = (time.perf_counter() - t0) / 10 * 1e6
    assert t_blocked > 2 * t_dispatch, (
        f"blocked timing {t_blocked:.1f}us is not clearly above the "
        f"dispatch-only {t_dispatch:.1f}us — is block_until_ready() "
        f"inside the timed loop?")


def test_tuning_cache_keys_differ_by_tuner_params(tmp_path):
    # a record measured with 30 timing iterations must not answer a
    # session that asked for 3000
    g = _tiny_cnn()
    s1 = InferenceSession(g, backend="c", autotune=True, simd="structured",
                          tune_cache=str(tmp_path), tune_iters=30)
    s2 = InferenceSession(_tiny_cnn(), backend="c", autotune=True,
                          simd="structured", tune_cache=str(tmp_path),
                          tune_iters=31)
    assert not s1.tuned.from_cache
    assert not s2.tuned.from_cache  # different iters -> different key
