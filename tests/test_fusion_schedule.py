"""Graph-level scheduling: epilogue fusion (residual Adds folded into
their producer's output loop, float and int8), the unified
``repro.core.codegen.compile()`` API and its deprecation shims, the
layer-pipelined multi-core builds, and the engine knobs that select a
schedule."""
import dataclasses
import warnings

import numpy as np
import pytest

try:  # hypothesis widens the branchy-graph sweep; a fixed grid runs without
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.configs.cnn_paper import residual_cnn
from repro.core import cgen, codegen, jax_exec, passes, quantize, runtime
from repro.core.graph import (
    Add, AvgPool, CNNGraph, Concat, Conv2D, Dense, Flatten, Input,
    MaxPool,
)
from repro.core.schedule import (
    fusable_adds, fusable_concats, fusable_pools, make_schedule,
)
from repro.engine import InferenceSession, SessionConfig
from repro.engine.autotune import (
    pipeline_stage_candidates, tune_pipeline_stages,
)


def _conv(rng, kh, kw, ci, co, **kw_args) -> Conv2D:
    w = rng.normal(0, 0.5, (kh, kw, ci, co)).astype(np.float32)
    b = rng.normal(0, 0.1, (co,)).astype(np.float32)
    return Conv2D(weights=w, bias=b, **kw_args)


def _dense(rng, ci, co, **kw_args) -> Dense:
    w = rng.normal(0, 0.3, (ci, co)).astype(np.float32)
    b = rng.normal(0, 0.1, (co,)).astype(np.float32)
    return Dense(weights=w, bias=b, **kw_args)


def _conv_add_net(seed=0, add_act="relu", c=6) -> CNNGraph:
    """Conv + residual Add (+ activation) with a conv head so the Add
    is not the sink — the canonical fused-epilogue shape."""
    rng = np.random.default_rng(seed)
    return CNNGraph([
        Input(shape=(8, 8, 3), name="in"),
        _conv(rng, 3, 3, 3, c, padding="same", activation="relu",
              name="c1"),
        _conv(rng, 3, 3, c, c, padding="same", name="c2"),
        Add(name="add", inputs=["c2", "c1"], activation=add_act),
        _conv(rng, 1, 1, c, 4, name="head"),
    ])


def _dense_add_net(seed=1) -> CNNGraph:
    """Dense (leaky_relu) + residual Add — the fused epilogue on the
    dot-product kernel family."""
    rng = np.random.default_rng(seed)
    return CNNGraph([
        Input(shape=(4, 4, 2), name="in"),
        Flatten(name="fl"),
        _dense(rng, 32, 16, activation="relu", name="d1"),
        _dense(rng, 16, 16, activation="leaky_relu", name="d2"),
        Add(name="add", inputs=["d2", "d1"], activation="relu"),
        _dense(rng, 16, 5, name="head"),
    ])


def _float_simds():
    simds = ["generic"]
    if runtime.host_supports_ssse3():
        simds.append("sse")
    if runtime.host_supports_avx2():
        simds.append("avx")
    return simds


def _int8_simds():
    want = ("generic", "sse", "avx", "avx_vnni")
    return [s for s in runtime.supported_int8_simds() if s in want]


def _build(g, simd, fusion, nstages=1):
    # rolled loops: scheduling decisions are orthogonal to the unroll
    # level (the fused store is the same expression at every level, and
    # a dedicated straight-line test covers it) and the default full
    # unroll turns each tiny test net into a multi-minute -O3 compile
    return runtime.build(
        g, cgen.CodegenOptions(simd=simd, unroll=None),
        schedule=make_schedule(g, fusion=fusion, nstages=nstages))


# ------------------------------------------------- fusion predicate ----

def test_fusable_adds_predicate():
    g = _conv_add_net()
    assert fusable_adds(g) == [("c2", "add")]
    # the sink Add is never fused: the fused store would need the
    # caller's out pointer inside the producer's loop
    rng = np.random.default_rng(0)
    sink = CNNGraph([
        Input(shape=(6, 6, 3), name="in"),
        _conv(rng, 3, 3, 3, 4, padding="same", activation="relu",
              name="c1"),
        _conv(rng, 3, 3, 4, 4, padding="same", name="c2"),
        Add(name="add", inputs=["c2", "c1"], activation="relu"),
    ])
    assert fusable_adds(sink) == []
    # softmax producers keep their materialized buffer (the epilogue
    # runs per-element; softmax needs the whole channel vector)
    sm = _conv_add_net()
    sm.layers[2].activation = "softmax"
    assert fusable_adds(sm) == []


def test_schedule_digest_distinguishes_programs():
    g = _conv_add_net()
    digests = {make_schedule(g).digest(),
               make_schedule(g, fusion=False).digest(),
               make_schedule(g, nstages=2).digest()}
    assert len(digests) == 3
    # deterministic: same knobs, same digest
    assert make_schedule(g).digest() == make_schedule(g).digest()


# --------------------------------------------- fused parity (float) ----

@pytest.mark.parametrize("simd", _float_simds())
def test_fusion_parity_matrix_float(simd):
    """Conv+Add(+relu/leaky) and Dense+Add epilogues: the fused build
    must match the unfused build bitwise (same left-associated sum,
    same activation code) and the jax oracle to float tolerance."""
    for g in (_conv_add_net(add_act="relu"),
              _conv_add_net(seed=3, add_act="leaky_relu"),
              _dense_add_net()):
        assert fusable_adds(g), "net must exercise the fused path"
        x = np.random.default_rng(7).normal(
            size=(3,) + tuple(g.input_shape)).astype(np.float32)
        fused = _build(g, simd, True).predict_batch(x)
        unfused = _build(g, simd, False).predict_batch(x)
        np.testing.assert_array_equal(fused, unfused)
        ref = np.stack([np.asarray(jax_exec.predict(g, xi)) for xi in x])
        np.testing.assert_allclose(
            fused.reshape(ref.shape), ref, rtol=1e-4, atol=1e-5)


def test_fused_store_in_unrolled_emission():
    """Full unroll (weights as literals, straight-line code) substitutes
    the same fused store expression — parity must hold there too."""
    rng = np.random.default_rng(12)
    g = CNNGraph([
        Input(shape=(4, 4, 2), name="in"),
        _conv(rng, 3, 3, 2, 3, padding="same", activation="relu",
              name="c1"),
        _conv(rng, 3, 3, 3, 3, padding="same", name="c2"),
        Add(name="add", inputs=["c2", "c1"], activation="relu"),
        _conv(rng, 1, 1, 3, 2, name="head"),
    ])
    assert fusable_adds(g) == [("c2", "add")]
    x = np.random.default_rng(0).normal(
        size=(2,) + tuple(g.input_shape)).astype(np.float32)
    opts = cgen.CodegenOptions(simd="generic", unroll=0)
    sched_f, sched_u = make_schedule(g), make_schedule(g, fusion=False)
    np.testing.assert_array_equal(
        runtime.build(g, opts, schedule=sched_f).predict_batch(x),
        runtime.build(g, opts, schedule=sched_u).predict_batch(x))


def test_residual_dag_fused_parity():
    """The shipped residual config (depthwise + Add + Concat) through
    the optimizer: fused == unfused, and the fused arena never grows."""
    g = passes.optimize(residual_cnn(), simd_multiple=1)
    assert fusable_adds(g), "optimized residual net must fuse its Add"
    simd = runtime.best_isa()
    x = np.random.default_rng(5).normal(
        size=(2,) + tuple(g.input_shape)).astype(np.float32)
    np.testing.assert_array_equal(
        _build(g, simd, True).predict_batch(x),
        _build(g, simd, False).predict_batch(x))
    opts = cgen.CodegenOptions(simd=simd, unroll=None)
    gs_f = codegen.compile(g, opts, schedule=make_schedule(g))
    gs_u = codegen.compile(g, opts,
                           schedule=make_schedule(g, fusion=False))
    assert gs_f.arena_bytes < gs_u.arena_bytes  # one buffer eliminated


# ---------------------------------------------- fused parity (int8) ----

@pytest.mark.parametrize("simd", _int8_simds())
def test_fusion_parity_int8_bitexact(simd):
    """Int8 Conv+Add+requant epilogue: fused and unfused builds must
    both match the jax integer-path reference bit-for-bit."""
    g = _conv_add_net(seed=2)
    xs = np.random.default_rng(0).normal(
        size=(8,) + tuple(g.input_shape)).astype(np.float32)
    qg = quantize.quantize(g, xs)
    ref = np.asarray(jax_exec.make_jit_forward_quantized(qg)(xs))
    opts = cgen.CodegenOptions(simd=simd)
    for fusion in (True, False):
        net = runtime.build_quantized(
            qg, opts, schedule=make_schedule(g, fusion=fusion))
        got = net.predict_batch(xs).reshape(ref.shape)
        np.testing.assert_array_equal(got, ref)


# ------------------------------------------- branchy graph sweep -------

def _branchy_net(seed: int, c: int, add_act) -> CNNGraph:
    """A diamond with a pooled side branch and two chained Adds — the
    shapes epilogue fusion must never get wrong."""
    rng = np.random.default_rng(seed)
    return CNNGraph([
        Input(shape=(6, 6, 2), name="in"),
        _conv(rng, 3, 3, 2, c, padding="same", activation="relu",
              name="s"),
        _conv(rng, 3, 3, c, c, padding="same", name="b1"),
        _conv(rng, 1, 1, c, c, activation="leaky_relu", name="b2",
              inputs=["s"]),
        Add(name="a1", inputs=["b1", "b2"], activation=add_act),
        _conv(rng, 3, 3, c, c, padding="same", name="b3"),
        Add(name="a2", inputs=["b3", "a1"], activation="relu"),
        MaxPool(size=(2, 2), name="mp"),
        _conv(rng, 1, 1, c, 3, name="head"),
    ])


def _assert_fused_matches_unfused(seed, c, add_act):
    g = _branchy_net(seed, c, add_act)
    x = np.random.default_rng(seed + 100).normal(
        size=(2,) + tuple(g.input_shape)).astype(np.float32)
    opts = cgen.CodegenOptions(simd="generic", unroll=None)
    gs_f = codegen.compile(g, opts, schedule=make_schedule(g))
    gs_u = codegen.compile(g, opts,
                           schedule=make_schedule(g, fusion=False))
    assert gs_f.arena_bytes <= gs_u.arena_bytes
    np.testing.assert_array_equal(
        _build(g, "generic", True).predict_batch(x),
        _build(g, "generic", False).predict_batch(x))


if HAVE_HYPOTHESIS:

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 10_000),
           c=st.integers(2, 6),
           add_act=st.sampled_from([None, "relu", "leaky_relu"]))
    def test_branchy_fused_equals_unfused(seed, c, add_act):
        _assert_fused_matches_unfused(seed, c, add_act)

else:

    @pytest.mark.parametrize("seed,c,add_act", [
        (0, 2, "relu"), (11, 5, None), (42, 3, "leaky_relu")])
    def test_branchy_fused_equals_unfused(seed, c, add_act):
        _assert_fused_matches_unfused(seed, c, add_act)


# -------------------- branchy pool/Concat sweep (both precisions) ------

def _pool_concat_net(seed: int, c: int) -> CNNGraph:
    """Every fused-epilogue consumer kind from one generator: a MaxPool
    and an AvgPool each behind a sole-consumer conv (window == stride,
    no pads, divisible extent — the fusable shape), and a two-edge
    Concat whose both producers qualify.  ``c`` sweeps the SIMD-group
    channel tails: 1..17 covers sub-group, exact-group and group+tail
    counts for the 8/16-wide kernels."""
    rng = np.random.default_rng(seed)
    return CNNGraph([
        Input(shape=(8, 8, 2), name="in"),
        _conv(rng, 3, 3, 2, c, padding="same", activation="relu",
              name="s"),
        _conv(rng, 1, 1, c, c, activation="relu", name="pm"),
        MaxPool(size=(2, 2), name="mp"),
        _conv(rng, 1, 1, c, c, activation="leaky_relu", name="pa",
              inputs=["s"]),
        AvgPool(size=(2, 2), name="ap"),
        _conv(rng, 3, 3, c, c, padding="same", name="cb1",
              inputs=["mp"]),
        _conv(rng, 1, 1, c, c, name="cb2", inputs=["ap"]),
        Concat(name="cat", inputs=["cb1", "cb2"]),
        _conv(rng, 1, 1, 2 * c, 3, name="head"),
    ])


def _assert_pool_concat_parity(seed: int, c: int) -> None:
    g = _pool_concat_net(seed, c)
    assert fusable_pools(g) == [("pm", "mp"), ("pa", "ap")]
    assert fusable_concats(g) == [("cb1", "cat"), ("cb2", "cat")]
    sched_f = make_schedule(g)
    sched_u = make_schedule(g, fusion=False)
    assert sched_f.fused_pools and sched_f.fused_concats
    xs = np.random.default_rng(seed + 500).normal(
        size=(4,) + tuple(g.input_shape)).astype(np.float32)
    opts = cgen.CodegenOptions(simd="generic", unroll=None)
    # the fused arena never grows — the schedule invariant under test
    assert (codegen.compile(g, opts, schedule=sched_f).arena_bytes
            <= codegen.compile(g, opts, schedule=sched_u).arena_bytes)
    # float: bitwise identical by construction (same op order per slot)
    np.testing.assert_array_equal(
        _build(g, "generic", True).predict_batch(xs),
        _build(g, "generic", False).predict_batch(xs))
    # int8: fused and unfused both bit-exact against the jax oracle
    qg = quantize.quantize(g, xs)
    ref = np.asarray(jax_exec.make_jit_forward_quantized(qg)(xs))
    for fusion in (True, False):
        net = runtime.build_quantized(
            qg, cgen.CodegenOptions(simd="generic"),
            schedule=make_schedule(g, fusion=fusion))
        np.testing.assert_array_equal(
            net.predict_batch(xs).reshape(ref.shape), ref)


if HAVE_HYPOTHESIS:

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 10_000), c=st.integers(1, 17))
    def test_pool_concat_fused_parity_sweep(seed, c):
        _assert_pool_concat_parity(seed, c)

else:

    @pytest.mark.parametrize("seed,c", [
        (0, 1), (7, 2), (11, 4), (21, 7), (5, 16), (42, 17)])
    def test_pool_concat_fused_parity_sweep(seed, c):
        _assert_pool_concat_parity(seed, c)


# --------------------------------------- fusion kinds as variant axes --

def test_make_schedule_kinds_axis():
    """``kinds`` restricts which consumer kinds fuse — the int8
    autotuner times kind subsets as code variants."""
    from repro.engine.autotune import fusion_schedule_candidates
    g = _pool_concat_net(0, 5)
    full = make_schedule(g)
    adds_only = make_schedule(g, kinds=("add",))
    assert full.fused_pools and full.fused_concats
    assert not adds_only.fused_pools and not adds_only.fused_concats
    with pytest.raises(ValueError):
        make_schedule(g, kinds=("pool", "bogus"))
    cands = fusion_schedule_candidates(g)
    digs = [s.digest() for s in cands]
    assert len(digs) == len(set(digs)), "candidates must be distinct"
    assert digs[0] == full.digest()     # deployed default leads
    assert any(not s.has_fusion for s in cands)


def test_compiled_net_fused_counts():
    """CompiledNet self-describes the deployed fusion (adds, pools,
    concat edges) without re-deriving the schedule."""
    g = _pool_concat_net(0, 4)
    fused = _build(g, "generic", True)
    assert fused.has_fusion
    assert fused.fused_counts[1] >= 1 and fused.fused_counts[2] >= 1
    unfused = _build(g, "generic", False)
    assert unfused.fused_counts == (0, 0, 0) and not unfused.has_fusion


# ------------------------------------------------- reorder pass --------

def test_reorder_for_fusion_makes_producer_last():
    """An Add whose topologically-last input is a MaxPool (not fusable)
    but whose other input is a sole-consumer conv: the reorder pass
    moves the conv to just before the Add — a pure permutation — and
    the schedule then fuses it."""
    rng = np.random.default_rng(4)
    g = CNNGraph([
        Input(shape=(8, 8, 3), name="in"),
        _conv(rng, 3, 3, 3, 4, padding="same", activation="relu",
              name="c1"),
        _conv(rng, 3, 3, 4, 4, strides=(2, 2), padding="same",
              name="c2"),
        MaxPool(size=(2, 2), name="p", inputs=["c1"]),
        Add(name="add", inputs=["c2", "p"], activation="relu"),
        _conv(rng, 1, 1, 4, 3, name="head"),
    ])
    assert fusable_adds(g) == []          # MaxPool sits after c2
    g2 = passes.reorder_for_fusion(g)
    assert fusable_adds(g2) == [("c2", "add")]
    assert [l.name for l in g.layers] != [l.name for l in g2.layers]
    x = np.random.default_rng(9).normal(
        size=g.input_shape).astype(np.float32)
    np.testing.assert_array_equal(np.asarray(jax_exec.predict(g, x)),
                                  np.asarray(jax_exec.predict(g2, x)))


# ------------------------------------------------ compile() API --------

def test_compile_api_surface():
    g = _conv_add_net()
    gs = codegen.compile(g, cgen.CodegenOptions(unroll=None))
    assert isinstance(gs, codegen.GeneratedSource)
    assert gs.precision == "fp32" and gs.simd == "sse"
    assert gs.codegen_version == cgen.CODEGEN_VERSION
    assert gs.entry == "nncg_net" and gs.entry_ws == "nncg_net_ws"
    assert gs.schedule.fused_adds  # fusion is the default schedule
    assert gs.nstages == 1 and gs.entry_pipeline is None
    assert gs.arena_bytes == gs.workspace_elems * gs.elem_bytes
    assert gs.source.startswith("/*")  # emitted C, header comment first
    with pytest.raises(dataclasses.FrozenInstanceError):
        gs.simd = "avx"
    d = gs.describe()
    assert d["schedule"]["digest"] == gs.schedule.digest()

    qs = codegen.compile(
        quantize.quantize(g, np.random.default_rng(0).normal(
            size=(4,) + tuple(g.input_shape)).astype(np.float32)))
    assert qs.precision == "int8" and qs.elem_bytes == 1


def test_legacy_shims_warn_once_per_process():
    g = _conv_add_net()
    opts = cgen.CodegenOptions(simd="generic", unroll=None)
    cgen._LEGACY_WARNED[0] = False        # other tests may have tripped it
    with pytest.warns(DeprecationWarning, match="generate_c"):
        legacy = cgen.generate_c(g, opts)
    with warnings.catch_warnings():
        warnings.simplefilter("error")    # a second warning would raise
        again = cgen.generate_c(g, opts)
        qg = quantize.quantize(g, np.random.default_rng(0).normal(
            size=(4,) + tuple(g.input_shape)).astype(np.float32))
        cgen.generate_quantized_c(qg, opts)   # shared once-per-process flag
    assert legacy == again
    # the shims preserve the pre-schedule output exactly: compile()
    # with an unfused single-stage schedule is the same program
    assert legacy == codegen.compile(
        g, opts, schedule=make_schedule(g, fusion=False)).source


# --------------------------------------------- pipelined builds --------

def test_pipeline_parity_float():
    g = _conv_add_net(seed=6)
    x = np.random.default_rng(1).normal(
        size=(6,) + tuple(g.input_shape)).astype(np.float32)
    base = _build(g, "generic", True, nstages=1)
    pipe = _build(g, "generic", True, nstages=2)
    assert pipe.nstages == 2 and len(pipe.stage_func_names) == 2
    # single frame and a streamed batch, both bit-identical to the
    # monolithic build (same kernels, same schedule, split emission)
    np.testing.assert_array_equal(base.predict_batch(x[:1]),
                                  pipe.predict_batch(x[:1]))
    np.testing.assert_array_equal(base.predict_batch(x),
                                  pipe.predict_batch(x))
    gs = codegen.compile(g, cgen.CodegenOptions(simd="generic",
                                                unroll=None),
                         schedule=make_schedule(g, nstages=2))
    assert gs.entry_pipeline == "nncg_net_pipeline"
    assert len(gs.stage_entries) == 2
    assert gs.workspace_elems >= gs.arena_elems + sum(gs.iface_elems)


def test_pipeline_parity_int8():
    g = _conv_add_net(seed=8)
    xs = np.random.default_rng(2).normal(
        size=(6,) + tuple(g.input_shape)).astype(np.float32)
    qg = quantize.quantize(g, xs)
    opts = cgen.CodegenOptions(simd="generic")
    base = runtime.build_quantized(qg, opts,
                                   schedule=make_schedule(g, nstages=1))
    pipe = runtime.build_quantized(qg, opts,
                                   schedule=make_schedule(g, nstages=2))
    np.testing.assert_array_equal(base.predict_batch(xs),
                                  pipe.predict_batch(xs))


def test_pipeline_stage_candidates_host_gated():
    cands = pipeline_stage_candidates()
    import os
    assert cands[0] == 1
    assert all(s <= max(os.cpu_count() or 1, 1) for s in cands[1:])
    # degenerate candidate list: decided without building anything
    assert tune_pipeline_stages(_conv_add_net(), simd="generic",
                                candidates=[1]) == 1


# ----------------------------------------------- engine knobs ----------

def test_session_config_schedule_roundtrip():
    cfg = SessionConfig(backend="c", fusion=False, pipeline_stages=2)
    assert SessionConfig(**cfg.to_dict()) == cfg.portable()
    assert SessionConfig.from_dict(cfg.to_dict()).pipeline_stages == 2
    with pytest.raises(ValueError, match="pipeline_stages"):
        SessionConfig(pipeline_stages=-1)


def test_session_selects_and_reports_schedule():
    g = _conv_add_net(seed=9)
    x = np.random.default_rng(3).normal(
        size=g.input_shape).astype(np.float32)
    plain = InferenceSession(g, config=SessionConfig(
        backend="c", simd="generic", unroll=None))
    piped = InferenceSession(g, config=SessionConfig(
        backend="c", simd="generic", unroll=None, pipeline_stages=2))
    np.testing.assert_array_equal(plain.predict(x), piped.predict(x))
    info = piped.info
    assert info["schedule"]["nstages"] == 2
    assert info["schedule"]["fused_adds"]       # fusion defaults on
    assert info["config"]["pipeline_stages"] == 2
    # round-trip: the reported config reconstructs the same schedule
    re_cfg = SessionConfig(**info["config"])
    assert re_cfg.pipeline_stages == 2 and re_cfg.fusion is None
    unfused = InferenceSession(g, config=SessionConfig(
        backend="c", simd="generic", unroll=None, fusion=False))
    np.testing.assert_array_equal(plain.predict(x), unfused.predict(x))
    assert unfused.info["schedule"]["fused_adds"] == []
