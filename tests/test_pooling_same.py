"""MaxPool/AvgPool ``padding='same'``: shape arithmetic, the
edge-correct AvgPool divisor (per-window valid-tap count, not the fixed
``1/(kh*kw)``), and C-vs-XLA-oracle agreement across every emission
mode."""
import numpy as np
import pytest

from repro.core import cgen, jax_exec, runtime
from repro.core.graph import (
    AvgPool, CNNGraph, Conv2D, Input, MaxPool, pool_window_counts,
)

RTOL, ATOL = 1e-4, 1e-5


def _conv(rng, kh, kw, ci, co, **kw_args) -> Conv2D:
    w = rng.normal(0, 0.5, (kh, kw, ci, co)).astype(np.float32)
    b = rng.normal(0, 0.1, (co,)).astype(np.float32)
    return Conv2D(weights=w, bias=b, **kw_args)


def test_same_pool_output_shapes():
    # same padding: out = ceil(in / stride), like conv
    mp = MaxPool(size=(2, 2), strides=(2, 2), padding="same")
    assert mp.out_shape((5, 7, 3)) == (3, 4, 3)
    ap = AvgPool(size=(3, 3), strides=(2, 2), padding="same")
    assert ap.out_shape((5, 5, 2)) == (3, 3, 2)
    # valid unchanged
    assert MaxPool(size=(2, 2)).out_shape((5, 7, 3)) == (2, 3, 3)


def test_pool_window_counts_edges():
    counts = pool_window_counts(
        (5, 5, 1), (3, 3), (2, 2),
        AvgPool(size=(3, 3), strides=(2, 2),
                padding="same").pad_amounts((5, 5, 1)))
    # 5x5, 3x3 window, stride 2, same: interior windows see 9 taps,
    # edge windows 6, the corner 4
    assert counts.shape == (3, 3)
    assert counts[0, 0] == 9 or counts[2, 2] == 4  # layout sanity
    assert counts.min() < counts.max() == 9


def test_avgpool_same_divisor_is_per_window():
    """The fix: an edge window's average divides by its valid-tap
    count.  Dividing by the fixed kh*kw would undershoot every edge."""
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (5, 5, 1)).astype(np.float32)
    g = CNNGraph([Input(shape=(5, 5, 1)),
                  AvgPool(size=(3, 3), strides=(2, 2), padding="same")])
    got = jax_exec.predict(g, x)
    # manual corner window: taps (0..1, 0..1) shifted by pad (1,1):
    # window rows -1..1 -> valid rows 0..1, count 4
    corner = x[0:2, 0:2, 0].mean()
    np.testing.assert_allclose(got[0, 0, 0], corner, rtol=1e-6)
    net = runtime.build(g, cgen.CodegenOptions(simd="generic", unroll=None))
    np.testing.assert_allclose(net(x).reshape(got.shape), got,
                               rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("simd", ["generic", "structured", "sse"])
@pytest.mark.parametrize("unroll", [0, 1, None])
def test_same_pooling_matches_xla_oracle(simd, unroll):
    """Both pools under 'same' against the oracle, through a conv so
    the pool input is not trivially the network input — every unroll
    level (0 = static tap elision, looped = padded scratch)."""
    if simd == "sse" and not runtime.host_supports_ssse3():
        pytest.skip("no SSSE3")
    rng = np.random.default_rng(2)
    g = CNNGraph([
        Input(shape=(7, 9, 2)),
        _conv(rng, 3, 3, 2, 4, padding="same", activation="relu"),
        MaxPool(size=(2, 2), strides=(2, 2), padding="same"),
        AvgPool(size=(3, 3), strides=(2, 2), padding="same"),
        _conv(rng, 1, 1, 4, 3, padding="valid"),
    ])
    x = rng.normal(0, 1, g.input_shape).astype(np.float32)
    ref = jax_exec.predict(g, x)
    net = runtime.build(g, cgen.CodegenOptions(simd=simd, unroll=unroll))
    np.testing.assert_allclose(net(x).reshape(ref.shape), ref,
                               rtol=RTOL, atol=ATOL)


def test_same_maxpool_stride_one_overlapping_windows():
    rng = np.random.default_rng(3)
    g = CNNGraph([Input(shape=(6, 6, 4)),
                  MaxPool(size=(3, 3), strides=(1, 1), padding="same")])
    x = rng.normal(0, 1, g.input_shape).astype(np.float32)
    ref = jax_exec.predict(g, x)
    assert ref.shape == (6, 6, 4)
    for simd in ("generic", "sse"):
        net = runtime.build(g, cgen.CodegenOptions(simd=simd, unroll=0))
        np.testing.assert_allclose(net(x).reshape(ref.shape), ref,
                                   rtol=RTOL, atol=ATOL)
