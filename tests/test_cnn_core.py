"""Tests for the NNCG core: graph IR, passes, C code generation."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="dev dependency — pip install -e '.[dev]'")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.cnn_paper import PAPER_CNNS, ball_classifier
from repro.core import cgen, jax_exec, passes, runtime
from repro.core.graph import (
    BatchNorm, CNNGraph, Conv2D, Dropout, Input, LeakyReLU, MaxPool, ReLU,
    Softmax,
)

RTOL, ATOL = 1e-3, 1e-5


def _rand_conv(rng, kh, kw, ci, co, **kw_args):
    w = rng.normal(0, 0.5, (kh, kw, ci, co)).astype(np.float32)
    b = rng.normal(0, 0.1, (co,)).astype(np.float32)
    return Conv2D(weights=w, bias=b, **kw_args)


# ---------------------------------------------------------------- shapes ----

def test_paper_shapes():
    """Tables I-III: output shapes match the hand-derived values."""
    assert PAPER_CNNS["ball"]().output_shape == (1, 1, 2)
    assert PAPER_CNNS["pedestrian"]().output_shape == (1, 1, 2)
    assert PAPER_CNNS["robot"]().output_shape == (15, 20, 20)


def test_same_padding_matches_jax():
    rng = np.random.default_rng(0)
    g = CNNGraph([Input(shape=(7, 9, 3)),
                  _rand_conv(rng, 3, 3, 3, 4, strides=(2, 2), padding="same")])
    assert g.output_shape == (4, 5, 4)


# ---------------------------------------------------------------- passes ----

@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(1, 6), st.integers(0, 2 ** 31 - 1))
def test_bn_fold_equivalence(ci, co, seed):
    """Paper §II-B.4: bn(conv(x)) == conv'(x) after weight folding."""
    rng = np.random.default_rng(seed)
    g = CNNGraph([
        Input(shape=(5, 5, ci)),
        _rand_conv(rng, 3, 3, ci, co, padding="same"),
        BatchNorm(mean=rng.normal(0, 1, co), var=rng.uniform(0.1, 2, co),
                  gamma=rng.uniform(0.5, 1.5, co), beta=rng.normal(0, 1, co)),
    ])
    folded = passes.fold_batchnorm(g)
    assert not any(isinstance(l, BatchNorm) for l in folded.layers)
    x = rng.normal(0, 1, (5, 5, ci)).astype(np.float32)
    np.testing.assert_allclose(jax_exec.predict(g, x),
                               jax_exec.predict(folded, x),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(1, 7), st.integers(1, 7),
       st.integers(0, 2 ** 31 - 1))
def test_align_channels_equivalence(ci, co1, co2, seed):
    """P4 zero-filter padding never changes visible outputs."""
    rng = np.random.default_rng(seed)
    g = CNNGraph([
        Input(shape=(8, 8, ci)),
        _rand_conv(rng, 3, 3, ci, co1, padding="same"),
        LeakyReLU(alpha=0.1),
        MaxPool(size=(2, 2)),
        _rand_conv(rng, 3, 3, co1, co2, padding="valid"),
        Softmax(),
    ])
    ga = passes.align_channels(g, multiple=4)
    convs = [l for l in ga.layers if isinstance(l, Conv2D)]
    assert convs[0].c_out % 4 == 0
    assert convs[-1].c_out == co2  # last conv is never padded
    x = rng.normal(0, 1, (8, 8, ci)).astype(np.float32)
    np.testing.assert_allclose(jax_exec.predict(g, x),
                               jax_exec.predict(ga, x), rtol=1e-4, atol=1e-5)


def test_full_pipeline_equivalence():
    for name, builder in PAPER_CNNS.items():
        g = builder()
        go = passes.optimize(g, simd_multiple=4)
        assert not any(isinstance(l, (Dropout, BatchNorm, ReLU, LeakyReLU))
                       for l in go.layers), name
        x = np.random.default_rng(3).normal(size=g.input_shape).astype(np.float32)
        np.testing.assert_allclose(jax_exec.predict(g, x),
                                   jax_exec.predict(go, x),
                                   rtol=1e-3, atol=1e-5)


# ------------------------------------------------------------------ cgen ----

@pytest.mark.parametrize("simd", ["generic", "structured", "sse", "avx"])
@pytest.mark.parametrize("level", [0, 1, 2, None])
def test_cgen_small_net_all_modes(simd, level):
    """Every (simd x unroll level) combination is numerically exact."""
    if simd == "sse" and not runtime.host_supports_ssse3():
        pytest.skip("host lacks SSSE3")
    if simd == "avx" and not runtime.host_supports_avx2():
        pytest.skip("host lacks AVX2/FMA")
    rng = np.random.default_rng(7)
    g = CNNGraph([
        Input(shape=(9, 7, 2)),
        _rand_conv(rng, 3, 3, 2, 8, strides=(2, 2), padding="same"),
        LeakyReLU(alpha=0.1),
        MaxPool(size=(2, 2)),
        _rand_conv(rng, 2, 2, 8, 3, padding="valid"),
        Softmax(),
    ])
    g = passes.fuse_activations(g)
    net = runtime.build(g, cgen.CodegenOptions(simd=simd, unroll=level))
    x = rng.normal(0, 1, g.input_shape).astype(np.float32)
    ref = jax_exec.predict(g, x)
    np.testing.assert_allclose(net(x).reshape(ref.shape), ref,
                               rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("simd", ["sse", "avx"])
@pytest.mark.parametrize("name", list(PAPER_CNNS))
def test_cgen_paper_nets(name, simd):
    if simd == "avx" and not runtime.host_supports_avx2():
        pytest.skip("host lacks AVX2/FMA")
    width = cgen.ISAS[simd].width
    g = passes.optimize(PAPER_CNNS[name](), simd_multiple=width)
    opts = cgen.CodegenOptions(simd=simd, unroll=cgen.choose_levels(g, 20_000))
    net = runtime.build(g, opts)
    x = np.random.default_rng(11).normal(size=g.input_shape).astype(np.float32)
    ref = jax_exec.predict(g, x)
    np.testing.assert_allclose(net(x).reshape(ref.shape), ref,
                               rtol=RTOL, atol=ATOL)


def test_cgen_dependencies_are_ansi_only():
    """Paper claim: no includes beyond math.h (+ SSE intrinsics)."""
    g = passes.optimize(ball_classifier())
    src = cgen.generate_c(g, cgen.CodegenOptions(simd="generic"))
    includes = [l for l in src.splitlines() if l.startswith("#include")]
    assert includes == ["#include <math.h>"]
    src_sse = cgen.generate_c(g, cgen.CodegenOptions(simd="sse"))
    includes = [l for l in src_sse.splitlines() if l.startswith("#include")]
    assert set(includes) == {"#include <math.h>", "#include <emmintrin.h>"}


def test_cgen_no_if_branches():
    """P2: generated compute code uses ternaries, never `if` statements."""
    g = passes.optimize(ball_classifier())
    src = cgen.generate_c(g, cgen.CodegenOptions(simd="generic", unroll=0))
    assert " if " not in src and "\nif" not in src


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 2), st.integers(1, 5), st.sampled_from([1, 2]),
       st.sampled_from(["same", "valid"]), st.integers(0, 2 ** 31 - 1))
def test_cgen_property_conv(ci, co, stride, padding, seed):
    """Property: any small conv net's C output == JAX oracle."""
    rng = np.random.default_rng(seed)
    g = CNNGraph([
        Input(shape=(6, 6, ci)),
        _rand_conv(rng, 3, 3, ci, co, strides=(stride, stride),
                   padding=padding, activation="leaky_relu"),
    ])
    net = runtime.build(g, cgen.CodegenOptions(simd="generic", unroll=None))
    x = rng.normal(0, 1, g.input_shape).astype(np.float32)
    ref = jax_exec.predict(g, x)
    np.testing.assert_allclose(net(x).reshape(ref.shape), ref,
                               rtol=RTOL, atol=ATOL)
