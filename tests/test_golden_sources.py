"""Golden-source digests: the generated C of every bench net is pinned.

One sha256 per (net x precision x schedule) cell — 4 paper/bench nets
x {float, int8} x {fused, unfused}.  Any codegen change that alters
even one byte of any cell fails here *by name*, so refactors that are
supposed to be emission-neutral (the loop-nest IR split was) get a
byte-level regression gate, and intentional changes leave an explicit
diff in review.

The recipe is fully deterministic: ``passes.optimize`` on the builder
graph, int8 calibration on ``np.random.default_rng(0)`` uniform noise
(PCG64 is stable across numpy versions), default ``CodegenOptions``.

Regenerating after an *intentional* emission change — one command::

    PYTHONPATH=src python tests/test_golden_sources.py --regen

which rewrites ``tests/golden_digests.json`` in place; commit the diff
together with the codegen change that caused it.
"""
import hashlib
import json
import os

import numpy as np
import pytest

from repro.configs import cnn_paper
from repro.core import codegen, passes, quantize
from repro.core.cgen import CodegenOptions
from repro.core.schedule import make_schedule

GOLDEN_PATH = os.path.join(os.path.dirname(__file__),
                           "golden_digests.json")

NETS = {
    "ball": cnn_paper.ball_classifier,
    "pedestrian": cnn_paper.pedestrian_classifier,
    "robot": cnn_paper.robot_detector,
    "residual": cnn_paper.residual_cnn,
}


def _cells():
    for name in sorted(NETS):
        for prec in ("float", "int8"):
            for sched in ("unfused", "fused"):
                yield f"{name}_{prec}_{sched}"


def _source_for(tag: str) -> str:
    name, prec, sched = tag.split("_")
    g = passes.optimize(NETS[name]())
    unit = g
    if prec == "int8":
        rng = np.random.default_rng(0)
        h, w, c = g.layers[0].shape
        calib = rng.uniform(-1.0, 1.0,
                            size=(8, h, w, c)).astype(np.float32)
        unit = quantize.quantize(g, calib)
    schedule = make_schedule(g, fusion=(sched == "fused"))
    return codegen.compile(unit, CodegenOptions(),
                           schedule=schedule).source


def _digest(src: str) -> str:
    return hashlib.sha256(src.encode()).hexdigest()


@pytest.fixture(scope="module")
def golden():
    assert os.path.exists(GOLDEN_PATH), (
        "tests/golden_digests.json missing — regenerate with:\n"
        "  PYTHONPATH=src python tests/test_golden_sources.py --regen")
    with open(GOLDEN_PATH) as f:
        return json.load(f)


@pytest.mark.parametrize("tag", list(_cells()))
def test_golden_source_digest(tag, golden):
    assert tag in golden, (
        f"no golden digest for {tag} — regenerate with:\n"
        "  PYTHONPATH=src python tests/test_golden_sources.py --regen")
    got = _digest(_source_for(tag))
    assert got == golden[tag], (
        f"{tag}: generated C changed (sha256 {got[:16]} != golden "
        f"{golden[tag][:16]}).  If intentional, regenerate with:\n"
        "  PYTHONPATH=src python tests/test_golden_sources.py --regen")


def test_golden_table_complete(golden):
    assert sorted(golden) == sorted(_cells())


def _regen() -> None:
    table = {}
    for tag in _cells():
        table[tag] = _digest(_source_for(tag))
        print(f"{tag:32s} {table[tag][:16]}")
    with open(GOLDEN_PATH, "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
        f.write("\n")
    print("wrote", GOLDEN_PATH)


if __name__ == "__main__":
    import sys
    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
