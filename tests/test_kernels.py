"""Per-kernel allclose sweeps against the ref.py oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def rnd(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32
                             ).astype(dtype)


# ---------------------------------------------------------------- conv2d ----

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,h,w,ci,co,kh,kw,stride,padding,act", [
    (1, 16, 16, 1, 8, 5, 5, 2, "same", "relu"),
    (2, 9, 7, 3, 4, 3, 3, 1, "same", "leaky_relu"),
    (1, 8, 8, 8, 12, 3, 3, 1, "valid", None),
    (2, 6, 6, 4, 16, 2, 2, 2, "valid", "relu"),
    (1, 12, 10, 2, 6, 1, 1, 1, "valid", None),
    (1, 60, 80, 3, 8, 3, 3, 1, "same", "leaky_relu"),  # robot detector L1
])
def test_conv2d(n, h, w, ci, co, kh, kw, stride, padding, act, dtype):
    x = rnd(0, (n, h, w, ci), dtype)
    wt = rnd(1, (kh, kw, ci, co), dtype) * 0.2
    b = rnd(2, (co,), jnp.float32)
    y = ops.conv2d(x, wt, b, strides=(stride, stride), padding=padding,
                   act=act)
    y_ref = ref.conv2d_ref(x.astype(jnp.float32), wt.astype(jnp.float32), b,
                           strides=(stride, stride), padding=padding, act=act)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(y_ref),
                               rtol=tol, atol=tol)


def test_conv2d_blocked_cout():
    """c_out tiling across lane blocks is seam-free."""
    x = rnd(0, (1, 8, 8, 4))
    wt = rnd(1, (3, 3, 4, 8)) * 0.2
    b = rnd(2, (8,))
    y1 = ops.conv2d(x, wt, b, padding="same", block_cout=4)
    y2 = ref.conv2d_ref(x, wt, b, padding="same")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)


# ------------------------------------------------------------- maxpool2d ----

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,size,stride", [
    ((1, 8, 8, 8), (2, 2), None),
    ((2, 9, 9, 4), (3, 3), (2, 2)),
    ((1, 16, 8, 12), (2, 2), (2, 2)),
])
def test_maxpool(shape, size, stride, dtype):
    x = rnd(3, shape, dtype)
    y = ops.maxpool2d(x, size=size, strides=stride)
    y_ref = ref.maxpool2d_ref(x, size=size, strides=stride)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), rtol=0, atol=0)


# -------------------------------------------------------- flash attention ----

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,hq,hkv,t,d,causal,window,bq,bk", [
    (1, 4, 4, 128, 32, True, None, 64, 64),
    (2, 8, 2, 128, 64, True, None, 128, 64),    # GQA 4:1
    (1, 4, 1, 256, 32, True, 64, 64, 64),       # sliding window (MQA)
    (1, 2, 2, 128, 32, False, None, 64, 64),    # bidirectional (encoder)
    (1, 4, 2, 192, 64, True, 100, 64, 64),      # window not block-aligned
])
def test_flash_attention(b, hq, hkv, t, d, causal, window, bq, bk, dtype):
    q = rnd(4, (b, hq, t, d), dtype)
    k = rnd(5, (b, hkv, t, d), dtype)
    v = rnd(6, (b, hkv, t, d), dtype)
    y = ops.flash_attention(q, k, v, causal=causal, window=window,
                            block_q=bq, block_k=bk)
    y_ref = ref.attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), causal=causal,
                              window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_matches_block_sizes():
    """Result is independent of the chosen tiling."""
    q, k, v = (rnd(i, (1, 2, 256, 32)) for i in (7, 8, 9))
    outs = [np.asarray(ops.flash_attention(q, k, v, block_q=bq, block_k=bk))
            for bq, bk in [(64, 64), (128, 128), (256, 64), (64, 256)]]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------ linear scan ----

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,t,h,n,m,chunk", [
    (1, 64, 2, 8, 16, 32),
    (2, 128, 4, 16, 16, 128),
    (1, 96, 1, 4, 8, 32),
])
def test_linear_scan(b, t, h, n, m, chunk, dtype):
    decay = jax.nn.sigmoid(rnd(10, (b, t, h, n), jnp.float32)) * 0.5 + 0.5
    k = rnd(11, (b, t, h, n), dtype) * 0.3
    v = rnd(12, (b, t, h, m), dtype) * 0.3
    r = rnd(13, (b, t, h, n), dtype) * 0.3
    s0 = rnd(14, (b, h, n, m), jnp.float32) * 0.1
    y, sT = ops.linear_scan(decay.astype(dtype), k, v, r, s0, chunk=chunk)
    y_ref, sT_ref = ref.linear_scan_ref(decay, k, v, r, s0)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32), rtol=tol,
                               atol=tol)
    np.testing.assert_allclose(np.asarray(sT), np.asarray(sT_ref),
                               rtol=tol, atol=tol)


def test_linear_scan_state_carry():
    """Chunk boundaries carry state exactly: two half scans == one scan."""
    b, t, h, n, m = 1, 64, 2, 4, 8
    decay = jnp.full((b, t, h, n), 0.9)
    k = rnd(15, (b, t, h, n)) * 0.2
    v = rnd(16, (b, t, h, m)) * 0.2
    r = rnd(17, (b, t, h, n)) * 0.2
    s0 = jnp.zeros((b, h, n, m))
    y_full, s_full = ops.linear_scan(decay, k, v, r, s0, chunk=16)
    y1, s1 = ops.linear_scan(decay[:, :32], k[:, :32], v[:, :32], r[:, :32],
                             s0, chunk=16)
    y2, s2 = ops.linear_scan(decay[:, 32:], k[:, 32:], v[:, 32:], r[:, 32:],
                             s1, chunk=16)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.concatenate([y1, y2], axis=1),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                               rtol=1e-5, atol=1e-5)
