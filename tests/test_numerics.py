"""Property tests for the shared numeric helpers (repro.core.numerics).

``flit`` and ``round_half_up`` were historically duplicated between
``cgen.py`` and ``quantize.py``; both now import the single definition.
These tests pin the two contracts everything bit-exact rests on:

* ``flit(v)`` parses back to the *identical* float32 bit pattern — the
  paper's P3 (weights as source constants) and every requant multiplier
  depend on it;
* ``round_half_up(x)`` equals the generated C's trunc-plus-fixup floor
  (``u = t + 0.5f; q = (int)u; q -= (float)q > u;``) for every value
  the int8 path can produce, and preserves the argument dtype.
"""
import numpy as np
import pytest

try:  # hypothesis widens the search; the fixed grid runs without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import cgen, quantize
from repro.core.numerics import flit, round_half_up


# ----------------------------------------------------------- flit ----

def _assert_roundtrip(v: np.float32) -> None:
    lit = flit(v)
    assert lit.endswith("f"), lit
    back = np.float32(lit[:-1])
    assert back.tobytes() == np.float32(v).tobytes(), (v, lit, back)


_GRID = np.concatenate([
    np.random.default_rng(0).normal(0, 1, 300),
    np.random.default_rng(1).normal(0, 1e-30, 60),
    np.random.default_rng(2).normal(0, 1e30, 60),
    [0.0, -0.0, 1.0, -1.0, 1 / 3, 2 / 3, np.float32(2 ** -149),
     -np.float32(2 ** -149), np.finfo(np.float32).max,
     np.finfo(np.float32).min, np.finfo(np.float32).tiny,
     np.float32(0.1), np.float32(16777216.0), np.float32(16777217.0)],
]).astype(np.float32)


def test_flit_roundtrip_grid():
    for v in _GRID:
        _assert_roundtrip(v)


def test_flit_is_the_shared_definition():
    """cgen._flit IS numerics.flit — no second copy to drift."""
    assert cgen._flit is flit


if HAVE_HYPOTHESIS:
    @settings(max_examples=500, deadline=None)
    @given(st.floats(width=32, allow_nan=False, allow_infinity=False))
    def test_flit_roundtrip_property(x):
        _assert_roundtrip(np.float32(x))


# -------------------------------------------------- round_half_up ----

def _c_floor_sequence(t: np.ndarray) -> np.ndarray:
    """The emitted C requant rounding, replayed in float32: trunc
    toward zero, then subtract one when the trunc overshot."""
    t = np.asarray(t, np.float32)
    u = t + np.float32(0.5)
    q = np.trunc(u)
    return q - (q > u)


def test_round_half_up_matches_c_sequence_grid():
    rng = np.random.default_rng(3)
    t = np.concatenate([
        rng.normal(0, 200, 5000),
        np.arange(-130.0, 130.0, 0.5),     # every exact .5 boundary
        np.arange(-130.0, 130.0, 0.25),
    ]).astype(np.float32)
    np.testing.assert_array_equal(round_half_up(t), _c_floor_sequence(t))


def test_round_half_up_halves_go_up_not_bankers():
    # floor(x + 0.5): 2.5 -> 3 and -2.5 -> -2 (banker's would give 2/-2)
    vals = np.float32([2.5, -2.5, 0.5, -0.5, 3.5, -3.5])
    np.testing.assert_array_equal(round_half_up(vals),
                                  np.float32([3, -2, 1, 0, 4, -3]))


def test_round_half_up_preserves_dtype():
    assert round_half_up(np.float32([1.2])).dtype == np.float32
    assert round_half_up(np.float64([1.2])).dtype == np.float64


if HAVE_HYPOTHESIS:
    @settings(max_examples=500, deadline=None)
    @given(st.floats(-3e8, 3e8, allow_nan=False, width=32))
    def test_round_half_up_matches_c_sequence_property(x):
        t = np.float32([x])
        np.testing.assert_array_equal(round_half_up(t),
                                      _c_floor_sequence(t))


# -------------------------------------- the consumers stay wired ----

def test_quantize_uses_shared_rounding():
    """QParams.quantize and the zero-point rule are built on
    round_half_up — one scheme everywhere (regression anchor for the
    dedup refactor)."""
    qp = quantize.qparams_from_range(-1.0, 1.0)
    x = np.float32([0.5 * qp.scale])  # lands exactly on a .5 code
    got = int(qp.quantize(x)[0])
    assert got == int(round_half_up(np.float32(0.5))) + qp.zero_point
