"""Dry-run integration tests (subprocess: needs its own XLA device count).

The production 16x16 / 2x16x16 sweeps live in results/dryrun (see
EXPERIMENTS.md); these tests prove the machinery end-to-end on a small
placeholder mesh so the suite stays fast.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(arch, shape, mesh, tmp, extra=()):
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src"),
           "NNCG_DRYRUN_DEVICES": "8"}
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh, "--out", str(tmp), *extra]
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       cwd=REPO, timeout=540)
    assert r.returncode == 0, r.stderr[-3000:]
    tag = "probe" if "--probe" in extra else (
        "multipod" if "--multipod" in extra else "pod")
    with open(os.path.join(str(tmp), f"{arch}__{shape}__{tag}.json")) as f:
        d = json.load(f)
    assert d["ok"], d.get("error")
    return d


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("h2o-danube-3-4b", "train_4k"),      # dense SWA train
    ("deepseek-moe-16b", "decode_32k"),   # MoE decode w/ caches
    ("zamba2-2.7b", "long_500k"),         # hybrid 500k decode
    ("hubert-xlarge", "prefill_32k"),     # encoder forward
])
def test_dryrun_cells_debug_mesh(arch, shape, tmp_path):
    d = _run(arch, shape, "2,4", tmp_path)
    key = "full"
    assert d[key]["flops"] > 0
    assert d[key]["memory"]["argument_bytes"] > 0


@pytest.mark.slow
def test_dryrun_multipod_axis(tmp_path):
    """3-axis (pod,data,model) debug mesh lowers and compiles."""
    d = _run("gemma3-4b", "train_4k", "2,2,2", tmp_path,
             extra=("--multipod",))
    assert d["axes"] == ["pod", "data", "model"]
    assert d["full"]["collectives"]["total_bytes"] > 0


@pytest.mark.slow
def test_dryrun_probe_extrapolation(tmp_path):
    """g2 costs strictly exceed g1 (one extra group of layers)."""
    d = _run("rwkv6-7b", "train_4k", "2,4", tmp_path, extra=("--probe",))
    assert d["g2"]["flops"] > d["g1"]["flops"] > 0


def test_production_sweep_results_complete():
    """The committed production sweep covers all 34 cells x 3 tags, all ok
    (this is the actual deliverable; regenerate with dryrun --all)."""
    from repro.configs.lm_archs import all_cells
    res = os.path.join(REPO, "results", "dryrun")
    if not os.path.isdir(res):
        pytest.skip("production sweep not present")
    missing, failed = [], []
    for arch, shape in all_cells():
        for tag in ("pod", "probe", "multipod"):
            p = os.path.join(res, f"{arch}__{shape}__{tag}.json")
            if not os.path.exists(p):
                missing.append((arch, shape, tag))
                continue
            with open(p) as f:
                if not json.load(f).get("ok"):
                    failed.append((arch, shape, tag))
    assert not missing, f"missing cells: {missing[:8]}"
    assert not failed, f"failed cells: {failed[:8]}"
