"""P4 head-dim alignment is function-preserving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.lm_archs import ARCHS
from repro.models import forward, init_params
from repro.models.align import pad_head_dim


def test_pad_head_dim_exact():
    # danube-like smoke with a non-aligned head_dim (12 -> pad to 16)
    cfg = dataclasses.replace(
        ARCHS["h2o-danube-3-4b"].smoke(), head_dim=12, n_heads=4,
        n_kv_heads=2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    padded, cfg_p = pad_head_dim(params, cfg, 16)
    assert cfg_p.head_dim == 16
    batch = {"tokens": jnp.arange(2 * 24).reshape(2, 24) % cfg.vocab_size}
    y0, _ = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    y1, _ = jax.jit(lambda p, b: forward(p, cfg_p, b))(padded, batch)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-5, atol=2e-5)


def test_pad_head_dim_with_bias():
    cfg = dataclasses.replace(ARCHS["qwen1.5-110b"].smoke(), head_dim=12)
    params = init_params(cfg, jax.random.PRNGKey(1))
    padded, cfg_p = pad_head_dim(params, cfg, 16)
    batch = {"tokens": jnp.arange(2 * 16).reshape(2, 16) % cfg.vocab_size}
    y0, _ = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    y1, _ = jax.jit(lambda p, b: forward(p, cfg_p, b))(padded, batch)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-5, atol=2e-5)
