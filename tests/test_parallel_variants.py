"""Distributed-execution variants equal the single-device reference.

Runs in a subprocess (needs 8 placeholder devices before jax init).
Covers: GSPMD baseline sharding, TP-MoE shard_map, EP-MoE all_to_all
routing, and Ulysses sequence-parallel attention.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, dataclasses
    from repro.configs.lm_archs import ARCHS
    from repro.launch.mesh import make_mesh
    from repro.launch.sharding import MeshPar
    from repro.models import init_params, forward
    from repro.models.stack import DEFAULT_PAR

    mesh = make_mesh((2, 4))
    cfg = dataclasses.replace(ARCHS["deepseek-moe-16b"].smoke(),
                              capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(4 * 16).reshape(4, 16) % cfg.vocab_size}
    y_ref, _ = jax.jit(lambda p, b: forward(p, cfg, b, DEFAULT_PAR))(
        params, batch)
    with mesh:
        par = MeshPar(mesh, cfg)
        y_tp, _ = jax.jit(lambda p, b: forward(p, cfg, b, par))(params, batch)
        os.environ["NNCG_MOE"] = "ep"
        y_ep, _ = jax.jit(lambda p, b: forward(p, cfg, b, par))(params, batch)
        os.environ.pop("NNCG_MOE")
    assert float(jnp.abs(y_tp - y_ref).max()) < 1e-4, "TP-MoE mismatch"
    assert float(jnp.abs(y_ep - y_ref).max()) < 1e-4, "EP-MoE mismatch"

    cfg2 = ARCHS["hubert-xlarge"].smoke()
    params2 = init_params(cfg2, jax.random.PRNGKey(1))
    batch2 = {"embeds": jax.random.normal(jax.random.PRNGKey(2),
                                          (2, 16, cfg2.d_model))}
    y_ref2, _ = jax.jit(lambda p, b: forward(p, cfg2, b, DEFAULT_PAR))(
        params2, batch2)
    with mesh:
        par2 = MeshPar(mesh, cfg2)
        os.environ["NNCG_ULYSSES"] = "1"
        y_ul, _ = jax.jit(lambda p, b: forward(p, cfg2, b, par2))(
            params2, batch2)
        os.environ.pop("NNCG_ULYSSES")
    assert float(jnp.abs(y_ul - y_ref2).max()) < 1e-4, "Ulysses mismatch"

    # Ulysses GQA kv-replication path (kv heads < model axis)
    cfg3 = ARCHS["h2o-danube-3-4b"].smoke()  # H=4, kv=2; model=4 -> slice
    params3 = init_params(cfg3, jax.random.PRNGKey(3))
    batch3 = {"tokens": jnp.arange(2 * 16).reshape(2, 16) % cfg3.vocab_size}
    y_ref3, _ = jax.jit(lambda p, b: forward(p, cfg3, b, DEFAULT_PAR))(
        params3, batch3)
    with mesh:
        par3 = MeshPar(mesh, cfg3)
        os.environ["NNCG_ULYSSES"] = "1"
        y_gqa, _ = jax.jit(lambda p, b: forward(p, cfg3, b, par3))(
            params3, batch3)
        os.environ.pop("NNCG_ULYSSES")
    assert float(jnp.abs(y_gqa - y_ref3).max()) < 1e-4, "Ulysses-GQA mismatch"
    print("ALL_VARIANTS_EXACT")
""")


@pytest.mark.slow
def test_parallel_variants_match_reference():
    env = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}
    env.pop("NNCG_MOE", None)
    env.pop("NNCG_ULYSSES", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                       capture_output=True, text=True, cwd=REPO,
                       timeout=540)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "ALL_VARIANTS_EXACT" in r.stdout
