"""The Pallas-kernel CNN inference path equals the XLA oracle and the
generated C — all three deployment artifacts of the same trained model."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.cnn_paper import PAPER_CNNS
from repro.core import jax_exec, passes


@pytest.mark.parametrize("name", list(PAPER_CNNS))
def test_pallas_path_matches_oracle(name):
    g = passes.optimize(PAPER_CNNS[name](), simd_multiple=4)
    x = np.random.default_rng(5).normal(size=(2,) + g.input_shape
                                        ).astype(np.float32)
    ref = np.asarray(jax_exec.forward(g, jnp.asarray(x)))
    got = np.asarray(jax_exec.forward_pallas(g, jnp.asarray(x)))
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-5)
