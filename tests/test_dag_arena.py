"""DAG graph IR + liveness-planned arena: planner invariants, random
branching graphs against the XLA oracle, the residual config end-to-end,
reentrancy of the workspace entry point, and the strict-ANSI claim."""
import shutil
import subprocess

import numpy as np
import pytest

try:  # hypothesis widens the DAG property search; a fixed grid runs without
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.configs.cnn_paper import PAPER_CNNS, residual_cnn
from repro.core import cgen, jax_exec, passes, runtime
from repro.core.graph import (
    Add, CNNGraph, Concat, Conv2D, DepthwiseConv2D, GlobalAvgPool,
    Input, MaxPool, ReLU, Softmax,
)

RTOL, ATOL = 1e-4, 1e-5


def _conv(rng, kh, kw, ci, co, **kw_args) -> Conv2D:
    w = rng.normal(0, 0.5, (kh, kw, ci, co)).astype(np.float32)
    b = rng.normal(0, 0.1, (co,)).astype(np.float32)
    return Conv2D(weights=w, bias=b, **kw_args)


# ----------------------------------------------------------- graph IR ----

def test_sequential_list_adapts_to_dag():
    """The list→DAG adapter chains omitted ``inputs`` to the predecessor,
    so every pre-DAG sequential model is a valid graph unchanged."""
    g = PAPER_CNNS["ball"]()
    for prev, layer in zip(g.layers, g.layers[1:]):
        assert layer.inputs == [prev.name]
    assert g.layers[0].inputs == []
    assert g.sink is g.layers[-1]


def test_topo_order_is_validated():
    rng = np.random.default_rng(0)
    with pytest.raises(AssertionError, match="topo order"):
        CNNGraph([
            Input(shape=(4, 4, 1), name="in"),
            _conv(rng, 1, 1, 1, 1, name="a", inputs=["b"]),  # forward ref
            _conv(rng, 1, 1, 1, 1, name="b", inputs=["a"]),
        ])


def test_single_sink_enforced():
    rng = np.random.default_rng(0)
    with pytest.raises(AssertionError, match="exactly one output"):
        CNNGraph([
            Input(shape=(4, 4, 1), name="in"),
            _conv(rng, 1, 1, 1, 2, name="a", inputs=["in"]),
            _conv(rng, 1, 1, 1, 2, name="b", inputs=["in"]),
        ]).sink


def test_fuse_respects_skip_edges():
    """A ReLU whose producer also feeds a skip edge must NOT be fused —
    the skip reads the pre-activation tensor."""
    rng = np.random.default_rng(1)
    g = CNNGraph([
        Input(shape=(6, 6, 2), name="in"),
        _conv(rng, 3, 3, 2, 2, padding="same", name="c1"),
        ReLU(name="r1"),                      # c1 -> r1 AND c1 -> add
        Add(name="add", inputs=["r1", "c1"]),
    ])
    fused = passes.fuse_activations(g)
    assert any(isinstance(l, ReLU) for l in fused.layers)
    x = rng.normal(0, 1, g.input_shape).astype(np.float32)
    np.testing.assert_allclose(jax_exec.predict(g, x),
                               jax_exec.predict(fused, x),
                               rtol=RTOL, atol=ATOL)


# ------------------------------------------------------- arena planner ----

def _assert_plan_sound(plan: cgen.ArenaPlan):
    """No two time-overlapping intervals may overlap in bytes."""
    for a in plan.intervals:
        assert 0 <= a.offset and a.offset + a.size <= plan.total_floats
        for b in plan.intervals:
            if a is b or a.end < b.start or b.end < a.start:
                continue
            disjoint = (a.offset + a.size <= b.offset
                        or b.offset + b.size <= a.offset)
            assert disjoint, f"live intervals collide: {a} vs {b}"


@pytest.mark.parametrize("name", list(PAPER_CNNS))
def test_arena_never_overlaps_live_intervals(name):
    g = passes.optimize(PAPER_CNNS[name](), simd_multiple=4)
    for unroll in (0, None):
        _assert_plan_sound(cgen.plan_arena(
            g, cgen.CodegenOptions(simd="generic", unroll=unroll)))


def test_arena_planner_no_overlap_residual():
    g = passes.optimize(residual_cnn(), simd_multiple=4)
    plan = cgen.plan_arena(g, cgen.CodegenOptions(simd="generic",
                                                  unroll=None))
    _assert_plan_sound(plan)
    # skip edges must extend lifetimes: the stem tensor stays live
    # across the whole residual block
    by_val = {iv.value: iv for iv in plan.intervals}
    stem = by_val["stem"]
    add_idx = [i for i, l in enumerate(g.layers)
               if l.name == "res_add"][0]
    assert stem.end >= add_idx


@pytest.mark.parametrize("name", list(PAPER_CNNS))
def test_arena_strictly_smaller_than_per_layer_buffers(name):
    """Acceptance: the planned arena beats the sum of the per-layer
    static buffers it replaces, for every paper CNN."""
    g = passes.optimize(PAPER_CNNS[name](), simd_multiple=4)
    plan = cgen.plan_arena(g, cgen.CodegenOptions(simd="sse", unroll=None))
    assert plan.total_floats < plan.buffer_sum_floats, (
        plan.total_floats, plan.buffer_sum_floats)
    assert plan.peak_live_floats <= plan.total_floats


# ----------------------------------------------- residual DAG end-to-end ----

@pytest.mark.parametrize("simd", ["generic", "structured", "sse"])
def test_residual_cnn_c_matches_oracle(simd):
    """Acceptance: residual (Add) + depthwise CNN round-trips
    optimize -> generate_c -> compile -> matches XLA within 1e-4."""
    if simd == "sse" and not runtime.host_supports_ssse3():
        pytest.skip("host lacks SSSE3")
    g = passes.optimize(residual_cnn(), simd_multiple=4)
    assert any(isinstance(l, Add) for l in g.layers)
    assert any(isinstance(l, DepthwiseConv2D) for l in g.layers)
    net = runtime.build(g, cgen.CodegenOptions(
        simd=simd, unroll=cgen.choose_levels(g, 20_000)))
    x = np.random.default_rng(3).normal(size=g.input_shape).astype(np.float32)
    ref = jax_exec.predict(g, x)
    np.testing.assert_allclose(net(x).reshape(ref.shape), ref,
                               rtol=RTOL, atol=ATOL)


def test_residual_cnn_through_engine_backends():
    from repro.engine import InferenceSession
    g = residual_cnn()
    x = np.random.default_rng(5).normal(
        size=(3,) + g.input_shape).astype(np.float32)
    ref = InferenceSession(g, backend="xla").predict(x)
    got_c = InferenceSession(g, backend="c", simd="structured").predict(x)
    got_p = InferenceSession(g, backend="pallas").predict(x)
    np.testing.assert_allclose(got_c, ref, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(got_p, ref, rtol=RTOL, atol=ATOL)


def test_session_info_reports_arena():
    from repro.engine import InferenceSession
    sess = InferenceSession(residual_cnn(), backend="c", simd="structured")
    info = sess.info
    assert info["arena_bytes"] > 0
    assert info["arena_bytes"] < info["arena_buffer_sum_bytes"]
    assert 0 < info["peak_live_bytes"] <= info["arena_bytes"]
    assert info["per_layer_live_bytes"]


# ---------------------------------------------------------- reentrancy ----

def test_workspace_entry_is_reentrant_and_thread_parallel():
    g = passes.optimize(residual_cnn(), simd_multiple=4)
    net = runtime.build(g, cgen.CodegenOptions(simd="structured",
                                               unroll=None))
    assert net._ws_fn is not None, "workspace entry missing from .so"
    assert net.workspace_floats > 0
    x = np.random.default_rng(9).normal(
        size=(8,) + g.input_shape).astype(np.float32)
    seq = net.predict_batch(x)
    par = net.predict_batch(x, threads=4)
    np.testing.assert_array_equal(seq, par)


def test_threaded_session_matches_sequential():
    from repro.engine import InferenceSession
    g = residual_cnn()
    x = np.random.default_rng(11).normal(
        size=(6,) + g.input_shape).astype(np.float32)
    seq = InferenceSession(g, backend="c", simd="structured").predict(x)
    par = InferenceSession(g, backend="c", simd="structured",
                           threads=3).predict(x)
    np.testing.assert_array_equal(seq, par)


# --------------------------------------------------- fingerprint / DAG ----

def test_graph_fingerprint_hashes_topology():
    from repro.engine import graph_fingerprint

    def build(skip_from):
        r = np.random.default_rng(2)
        return CNNGraph([
            Input(shape=(6, 6, 2), name="in"),
            Conv2D(weights=r.normal(0, 0.5, (3, 3, 2, 2)).astype(np.float32),
                   padding="same", name="c1"),
            Conv2D(weights=r.normal(0, 0.5, (3, 3, 2, 2)).astype(np.float32),
                   padding="same", name="c2"),
            Add(name="add", inputs=["c2", skip_from]),
        ])

    # identical layers & weights, different wiring -> different programs
    assert graph_fingerprint(build("c1")) != graph_fingerprint(build("in"))
    assert graph_fingerprint(build("c1")) == graph_fingerprint(build("c1"))


# -------------------------------------------- random DAGs vs the oracle ----

def _check_branch_merge_dag(ci, co, deep_branch, merge, pool_tail, seed):
    """Property body: a small branch->merge DAG produces C that matches
    the XLA oracle within 1e-4."""
    rng = np.random.default_rng(seed)
    layers = [
        Input(shape=(8, 8, ci), name="in"),
        _conv(rng, 3, 3, ci, co, padding="same", activation="relu",
              name="stem"),
        _conv(rng, 1, 1, co, co, padding="valid", name="left",
              inputs=["stem"]),
    ]
    right_src = "stem"
    if deep_branch:
        layers.append(DepthwiseConv2D(
            weights=rng.normal(0, 0.5, (3, 3, co, 1)).astype(np.float32),
            padding="same", activation="relu", name="right_dw",
            inputs=["stem"]))
        right_src = "right_dw"
    if merge == "add":
        layers.append(Add(name="merge", inputs=["left", right_src],
                          activation="relu"))
    else:
        layers.append(Concat(name="merge", inputs=["left", right_src]))
    if pool_tail:
        layers.append(MaxPool(size=(2, 2), name="tail_pool"))
    layers.append(GlobalAvgPool(name="gap"))
    layers.append(Softmax(name="sm"))
    g = CNNGraph(layers)

    net = runtime.build(g, cgen.CodegenOptions(simd="generic", unroll=None))
    _assert_plan_sound(cgen.plan_arena(
        g, cgen.CodegenOptions(simd="generic", unroll=None)))
    x = rng.normal(0, 1, g.input_shape).astype(np.float32)
    ref = jax_exec.predict(g, x)
    np.testing.assert_allclose(net(x).reshape(ref.shape), ref,
                               rtol=RTOL, atol=ATOL)


if HAVE_HYPOTHESIS:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(1, 3), st.integers(1, 4), st.booleans(),
           st.sampled_from(["add", "concat"]), st.booleans(),
           st.integers(0, 2 ** 31 - 1))
    def test_random_branch_merge_dag_matches_oracle(ci, co, deep_branch,
                                                    merge, pool_tail, seed):
        _check_branch_merge_dag(ci, co, deep_branch, merge, pool_tail, seed)
else:
    @pytest.mark.parametrize("merge", ["add", "concat"])
    @pytest.mark.parametrize("deep_branch", [False, True])
    @pytest.mark.parametrize("seed", [0, 1234])
    def test_random_branch_merge_dag_matches_oracle(merge, deep_branch,
                                                    seed):
        _check_branch_merge_dag(2, 3, deep_branch, merge,
                                pool_tail=bool(seed), seed=seed)


# ------------------------------------------------------- strict ANSI C ----

@pytest.mark.parametrize("builder", [PAPER_CNNS["ball"], residual_cnn])
def test_generated_c_is_strict_ansi_c89(builder, tmp_path):
    """The paper's 'plain ANSI C' claim, enforced: the generic-mode file
    compiles under gcc -std=c89 -Wall -Wextra -Werror -pedantic-errors."""
    gcc = shutil.which("gcc")
    if gcc is None:
        pytest.skip("gcc not available")
    g = passes.optimize(builder(), simd_multiple=1)
    src = cgen.generate_c(g, cgen.CodegenOptions(simd="generic",
                                                 unroll=None))
    c_path = tmp_path / "net.c"
    c_path.write_text(src)
    proc = subprocess.run(
        [gcc, "-std=c89", "-Wall", "-Wextra", "-Werror", "-pedantic-errors",
         "-c", str(c_path), "-o", str(tmp_path / "net.o")],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr[:4000]
