"""Continuous-batching server: aggregation triggers, result routing,
backpressure, graceful shutdown, stats — plus the CI fast-lane smoke
test (64 camera frames through a real compiled net, p99 < 100ms, zero
drops)."""
import threading
import time
from types import SimpleNamespace

import numpy as np
import pytest

from repro.configs.cnn_paper import PAPER_CNNS
from repro.engine import InferenceSession, SessionConfig
from repro.engine.backends import Backend
from repro.serve import (InferenceServer, RequestTimeout, ServerClosed,
                         ServerConfig, ServerOverloaded)

IN_SHAPE = (4,)


class StubBackend(Backend):
    """Pure-python substrate: output row i = input row i + 1 (so routing
    mistakes are visible), optional per-call delay, optional gate the
    test holds closed to pin the worker mid-batch, and a log of every
    executed batch size."""

    name = "stub"

    def __init__(self, delay: float = 0.0, gated: bool = False):
        super().__init__(SimpleNamespace(input_shape=IN_SHAPE,
                                         output_shape=IN_SHAPE))
        self.delay = delay
        self.gate = threading.Event()
        if not gated:
            self.gate.set()
        self.batch_sizes = []
        self.closed = False

    def predict_batch(self, x):
        self.gate.wait(timeout=10)
        if self.delay:
            time.sleep(self.delay)
        self.batch_sizes.append(x.shape[0])
        return x + 1.0

    def close(self):
        self.closed = True


def _frames(n, seed=0):
    return np.random.default_rng(seed).normal(
        size=(n,) + IN_SHAPE).astype(np.float32)


# -- batch aggregation ------------------------------------------------------

def test_batch_closes_on_size_trigger():
    # deadline is effectively infinite: only the size trigger can close
    # the batch, so completion within the test timeout proves it fired
    be = StubBackend(gated=True)
    with InferenceServer(be, config=ServerConfig(
            workers=1, max_batch=4, batch_deadline_ms=60_000,
            warmup=False)) as srv:
        xs = _frames(4)
        handles = [srv.submit(x) for x in xs]
        be.gate.set()
        outs = np.stack([h.result(timeout=5) for h in handles])
        np.testing.assert_array_equal(outs, xs + 1.0)
    assert 4 in be.batch_sizes


def test_batch_closes_on_deadline_trigger():
    # fewer requests than max_batch: only the SLO deadline can close
    # the batch
    be = StubBackend()
    with InferenceServer(be, config=ServerConfig(
            workers=1, max_batch=64, batch_deadline_ms=30,
            warmup=False)) as srv:
        t0 = time.perf_counter()
        h1 = srv.submit(_frames(1)[0])
        h2 = srv.submit(_frames(1, seed=1)[0])
        h1.result(timeout=5), h2.result(timeout=5)
        elapsed_ms = (time.perf_counter() - t0) * 1e3
    # closed at the ~30ms deadline, nowhere near a size-triggered wait
    assert elapsed_ms < 5_000
    assert max(be.batch_sizes) >= 1
    assert sum(be.batch_sizes) == 2


def test_deadline_zero_serves_immediately():
    be = StubBackend()
    with InferenceServer(be, config=ServerConfig(
            workers=1, max_batch=8, batch_deadline_ms=0,
            warmup=False)) as srv:
        x = _frames(1)[0]
        np.testing.assert_array_equal(srv.predict(x, timeout=5), x + 1.0)


# -- routing under concurrent load ------------------------------------------

def test_results_route_to_their_requesters_under_concurrency():
    be = StubBackend(delay=0.001)
    xs = _frames(96, seed=3)
    results = {}
    errs = []

    with InferenceServer(be, config=ServerConfig(
            workers=4, max_batch=8, batch_deadline_ms=2,
            warmup=False)) as srv:

        def client(lo, hi):
            try:
                hs = [(i, srv.submit(xs[i])) for i in range(lo, hi)]
                for i, h in hs:
                    results[i] = h.result(timeout=10)
            except Exception as e:  # surfaced below
                errs.append(e)

        threads = [threading.Thread(target=client,
                                    args=(i * 24, (i + 1) * 24))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    assert not errs, errs
    assert len(results) == 96
    for i in range(96):
        np.testing.assert_array_equal(results[i], xs[i] + 1.0)


# -- backpressure ------------------------------------------------------------

def test_queue_full_raises_immediately_not_hangs():
    be = StubBackend(gated=True)   # worker pinned: queue can only grow
    srv = InferenceServer(be, config=ServerConfig(
        workers=1, max_batch=1, max_queue=2, batch_deadline_ms=0,
        warmup=False))
    try:
        srv.submit(_frames(1)[0])          # taken by the pinned worker
        time.sleep(0.1)                    # let the worker dequeue it
        srv.submit(_frames(1)[0])
        srv.submit(_frames(1)[0])          # queue now full (max_queue=2)
        t0 = time.perf_counter()
        with pytest.raises(ServerOverloaded, match="queue full"):
            srv.submit(_frames(1)[0])
        assert time.perf_counter() - t0 < 1.0, "backpressure must not block"
        assert srv.stats()["rejected_queue_full"] == 1
    finally:
        be.gate.set()
        srv.close()


# -- per-request timeout ------------------------------------------------------

def test_stale_request_fails_with_timeout_not_executes():
    be = StubBackend(gated=True)
    srv = InferenceServer(be, config=ServerConfig(
        workers=1, max_batch=1, batch_deadline_ms=0,
        request_timeout_ms=20, warmup=False))
    try:
        h0 = srv.submit(_frames(1)[0])     # dequeued fresh, then pinned
        time.sleep(0.1)
        h1 = srv.submit(_frames(1)[0])     # queued behind the pinned one
        time.sleep(0.1)                    # ...for > request_timeout_ms
        be.gate.set()
        h0.result(timeout=5)               # fresh at dequeue: fine
        with pytest.raises(RequestTimeout):
            h1.result(timeout=5)
        assert srv.stats()["timeouts"] == 1
    finally:
        be.gate.set()
        srv.close()


# -- shutdown -----------------------------------------------------------------

def test_graceful_shutdown_drains_in_flight_work():
    be = StubBackend(delay=0.002)
    srv = InferenceServer(be, config=ServerConfig(
        workers=2, max_batch=4, batch_deadline_ms=1, warmup=False))
    xs = _frames(20, seed=5)
    handles = [srv.submit(x) for x in xs]
    srv.close(drain=True)
    for h, x in zip(handles, xs):
        np.testing.assert_array_equal(h.result(timeout=5), x + 1.0)
    st = srv.stats()
    assert st["completed"] == 20
    assert be.closed, "close() must propagate to the backend"
    with pytest.raises(ServerClosed):
        srv.submit(xs[0])
    assert srv.stats()["rejected_closed"] == 1


def test_non_drain_shutdown_fails_queued_requests():
    be = StubBackend(gated=True)
    srv = InferenceServer(be, config=ServerConfig(
        workers=1, max_batch=1, batch_deadline_ms=0, warmup=False))
    h0 = srv.submit(_frames(1)[0])         # pinned in the worker
    time.sleep(0.1)
    queued = [srv.submit(x) for x in _frames(3, seed=7)]
    threading.Timer(0.2, be.gate.set).start()
    srv.close(drain=False)
    h0.result(timeout=5)                   # in-flight one still finishes
    for h in queued:
        with pytest.raises(ServerClosed):
            h.result(timeout=5)


def test_close_is_idempotent():
    srv = InferenceServer(StubBackend(), config=ServerConfig(
        workers=1, warmup=False))
    srv.close()
    srv.close()


# -- stats --------------------------------------------------------------------

def test_stats_percentiles_and_counters_are_sane():
    be = StubBackend(delay=0.001)
    with InferenceServer(be, config=ServerConfig(
            workers=2, max_batch=4, batch_deadline_ms=1,
            warmup=False)) as srv:
        handles = [srv.submit(x) for x in _frames(40, seed=9)]
        for h in handles:
            h.result(timeout=10)
        st = srv.stats()
    assert st["submitted"] == st["completed"] == 40
    assert st["failed"] == st["timeouts"] == 0
    for k in ("latency", "queue_wait", "exec"):
        p50, p99 = st[f"{k}_p50_us"], st[f"{k}_p99_us"]
        assert np.isfinite(p50) and np.isfinite(p99) and 0 <= p50 <= p99, (
            k, p50, p99)
    # exec >= the backend's injected 1ms delay; total >= exec p50
    assert st["exec_p50_us"] >= 1_000
    assert st["latency_p99_us"] >= st["exec_p50_us"]
    assert st["qps"] > 0
    assert 1 <= st["batch_size_mean"] <= st["max_batch"]
    assert 0 < st["batch_occupancy"] <= 1
    assert st["queue_depth"] == 0


def test_request_timestamps_expose_every_stage():
    be = StubBackend()
    with InferenceServer(be, config=ServerConfig(
            workers=1, batch_deadline_ms=0, warmup=False)) as srv:
        h = srv.submit(_frames(1)[0])
        h.result(timeout=5)
    ts = h.timestamps
    assert ts["submit"] <= ts["dequeue"] <= ts["exec_start"] <= ts["done"]
    assert h.batch_size == 1


def test_backend_errors_surface_to_the_waiter():
    class Exploding(StubBackend):
        def predict_batch(self, x):
            raise RuntimeError("kaboom")

    with InferenceServer(Exploding(), config=ServerConfig(
            workers=1, batch_deadline_ms=0, warmup=False)) as srv:
        h = srv.submit(_frames(1)[0])
        with pytest.raises(RuntimeError, match="kaboom"):
            h.result(timeout=5)
        assert srv.stats()["failed"] == 1


def test_config_validation():
    with pytest.raises(ValueError, match="workers"):
        ServerConfig(workers=0)
    with pytest.raises(ValueError, match="max_batch"):
        ServerConfig(max_batch=0)
    with pytest.raises(TypeError, match="not both"):
        InferenceServer(StubBackend(), config=ServerConfig(warmup=False),
                        workers=2)
    srv = InferenceServer(StubBackend(), config=ServerConfig(warmup=False))
    with pytest.raises(ValueError, match="one frame"):
        srv.submit(np.zeros((3, 3), np.float32))
    srv.close()


# -- the real engine under the server (CI fast-lane smoke) -------------------

def test_smoke_64_frames_through_compiled_net_p99_under_100ms():
    """The CI gate: boot the server on a real compiled net, push 64
    camera frames, require p99 < 100ms and zero dropped responses."""
    from repro.data.pipeline import camera_frame_batch

    g = PAPER_CNNS["pedestrian"]()
    sess = InferenceSession(g, config=SessionConfig(backend="c",
                                                    simd="sse"))
    frames = camera_frame_batch(64, sess.input_shape, seed=0)
    ref = sess.predict(frames)
    with InferenceServer(sess, config=ServerConfig(
            workers=3, max_batch=8, batch_deadline_ms=2)) as srv:
        handles = [srv.submit(f) for f in frames]
        outs = np.stack([h.result(timeout=10) for h in handles])
        st = srv.stats()
    # zero drops, every result routed, bit-identical to the offline path
    assert st["completed"] == 64
    assert st["failed"] == st["timeouts"] == 0
    assert st["rejected_queue_full"] == st["rejected_closed"] == 0
    np.testing.assert_array_equal(outs, ref)
    assert st["latency_p99_us"] < 100_000, st


def test_worker_handles_are_independent_and_bit_exact():
    # the C backend hands each worker a private arena over the shared
    # .so; concurrent handles must agree bit-for-bit with the session
    g = PAPER_CNNS["ball"]()
    sess = InferenceSession(g, config=SessionConfig(backend="c",
                                                    simd="generic"))
    xs = np.random.default_rng(0).normal(
        size=(8,) + tuple(sess.input_shape)).astype(np.float32)
    ref = sess.predict(xs)
    w1, w2 = sess.backend.worker(), sess.backend.worker()
    assert w1 is not w2 and w1 is not sess.backend
    out = [None, None]
    t1 = threading.Thread(target=lambda: out.__setitem__(
        0, w1.predict_batch(xs[:4])))
    t2 = threading.Thread(target=lambda: out.__setitem__(
        1, w2.predict_batch(xs[4:])))
    t1.start(), t2.start(), t1.join(), t2.join()
    np.testing.assert_array_equal(np.concatenate(out), ref)
