"""int8 gradient compression: quantization error, error feedback, and
psum correctness on a multi-device pod axis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="dev dependency — pip install -e '.[dev]'")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.optim.compress import (compress_allreduce, dequantize_int8,
                                  quantize_int8)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.floats(1e-3, 1e3))
def test_quantize_roundtrip_error_bound(seed, scale):
    x = jnp.asarray(np.random.default_rng(seed).normal(0, scale, (64,)),
                    jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) / 2 + 1e-6  # half-ULP of the int8 grid


def test_error_feedback_converges():
    """With error feedback, the *running sum* of compressed gradients
    tracks the true sum (bias does not accumulate)."""
    rng = np.random.default_rng(0)
    true_sum = jnp.zeros(32)
    comp_sum = jnp.zeros(32)
    residual = None
    for step in range(50):
        g = {"w": jnp.asarray(rng.normal(0, 1, (32,)), jnp.float32)}
        out, residual = compress_allreduce(g, residual)
        true_sum = true_sum + g["w"]
        comp_sum = comp_sum + out["w"]
    # relative drift of the accumulated update stays at the quant grid
    drift = float(jnp.abs(true_sum - comp_sum).max())
    assert drift < 0.1, drift


def test_psum_over_pod_axis():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    if jax.device_count() < 2:
        import pytest
        pytest.skip("needs >1 device")
    mesh = jax.make_mesh((jax.device_count(),), ("pod",))

    def f(g):
        out, _ = compress_allreduce({"w": g}, axis_name="pod")
        return out["w"]

    g_global = jnp.arange(jax.device_count() * 8, dtype=jnp.float32
                          ).reshape(jax.device_count(), 8) / 10.0
    with mesh:
        y = shard_map(f, mesh=mesh, in_specs=P("pod", None),
                      out_specs=P("pod", None))(g_global)
    want = g_global.mean(axis=0)
    got = np.asarray(y)[0]
    np.testing.assert_allclose(got, np.asarray(want), atol=0.02)
