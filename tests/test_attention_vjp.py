"""flash_mha / local_mha custom-VJP vs. autodiff-through-reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention_vjp import flash_mha, local_mha
from repro.models.layers import flash_attention_jax


def rnd(i, sh):
    return jax.random.normal(jax.random.PRNGKey(i), sh) * 0.5


@pytest.mark.parametrize("B,T,H,Hkv,Dh,causal,window,bq,bk", [
    (2, 128, 4, 2, 32, True, None, 64, 64),
    (1, 256, 8, 8, 16, True, None, 128, 64),
    (2, 128, 4, 1, 32, False, None, 64, 64),     # bidirectional MQA
    (1, 128, 4, 4, 16, True, 48, 64, 64),        # windowed via flash
])
def test_flash_mha_grads(B, T, H, Hkv, Dh, causal, window, bq, bk):
    q, k, v = rnd(1, (B, T, H, Dh)), rnd(2, (B, T, Hkv, Dh)), \
        rnd(3, (B, T, Hkv, Dh))
    out = flash_mha(q, k, v, causal, window, None, bq, bk)
    ref = flash_attention_jax(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)
    g_new = jax.grad(lambda *a: (flash_mha(*a, causal, window, None, bq,
                                           bk) ** 2).sum(),
                     argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(lambda *a: (flash_attention_jax(
        *a, causal=causal, window=window) ** 2).sum(),
        argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_new, g_ref):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("B,T,H,Hkv,Dh,window,bq", [
    (2, 256, 4, 2, 32, 64, 64),
    (1, 512, 2, 2, 16, 100, 128),
    (1, 128, 4, 1, 32, 32, 32),
])
def test_local_mha_grads(B, T, H, Hkv, Dh, window, bq):
    q, k, v = rnd(4, (B, T, H, Dh)), rnd(5, (B, T, Hkv, Dh)), \
        rnd(6, (B, T, Hkv, Dh))
    out = local_mha(q, k, v, window, None, bq)
    ref = flash_attention_jax(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)
    g_new = jax.grad(lambda *a: (local_mha(*a, window, None, bq) ** 2).sum(),
                     argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(lambda *a: (flash_attention_jax(
        *a, causal=True, window=window) ** 2).sum(),
        argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_new, g_ref):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_flash_matches_pallas_kernel_fwd():
    """The jnp path and the Pallas kernel implement the same math."""
    from repro.kernels import ops
    q, k, v = rnd(7, (1, 4, 128, 32)), rnd(8, (1, 2, 128, 32)), \
        rnd(9, (1, 2, 128, 32))
    # kernels use (B,H,T,D); jnp path uses (B,T,H,D)
    o_kernel = ops.flash_attention(q, k, v, causal=True,
                                   block_q=64, block_k=64)
    o_jnp = flash_mha(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                      jnp.moveaxis(v, 1, 2), True, None, None, 64, 64)
    np.testing.assert_allclose(np.asarray(jnp.moveaxis(o_jnp, 1, 2)),
                               np.asarray(o_kernel), rtol=2e-5, atol=2e-5)
