"""runtime.compile_c: content-hash .so caching and compiler-failure
reporting."""
import pytest

from repro.core import runtime

SRC = """
void addone(const float *restrict x, float *restrict out)
{
    out[0] = x[0] + 1.0f;
}
"""


def test_identical_source_hits_cache_with_same_path():
    p1 = runtime.compile_c(SRC, simd="generic")
    cc_before = runtime.COMPILE_STATS["cc_invocations"]
    hits_before = runtime.COMPILE_STATS["so_cache_hits"]
    p2 = runtime.compile_c(SRC, simd="generic")
    assert p2 == p1
    assert runtime.COMPILE_STATS["cc_invocations"] == cc_before
    assert runtime.COMPILE_STATS["so_cache_hits"] == hits_before + 1


def test_flag_change_produces_fresh_path():
    p1 = runtime.compile_c(SRC, simd="generic")
    p2 = runtime.compile_c(SRC, simd="generic", extra_flags=("-DNNCG_X=1",))
    assert p2 != p1


def test_simd_mode_is_part_of_the_cache_key():
    # same source, different cc flags (-mssse3) -> must not share a .so
    p_gen = runtime.compile_c(SRC, simd="generic")
    p_sse = runtime.compile_c(SRC, simd="sse")
    assert p_gen != p_sse


def test_compiler_failure_surfaces_stderr():
    bad = "void broken(const float *x float *out) { out[0] = ; }"
    with pytest.raises(RuntimeError) as exc:
        runtime.compile_c(bad, simd="generic")
    msg = str(exc.value)
    assert "cc failed" in msg
    assert "error" in msg.lower()  # compiler diagnostics included
