"""Per-architecture smoke tests: reduced same-family configs run a real
forward + train step on CPU (shape + finiteness asserts), and causal
archs check decode-against-forward consistency through their caches."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.lm_archs import ARCHS, SHAPES, all_cells, cell_supported
from repro.models import (forward, init_params, make_decode_step,
                          make_prefill_step, make_train_step, param_count)
from repro.optim import AdamW

ALL = sorted(ARCHS)


def _batch(cfg, B=2, T=16, seed=0):
    r = np.random.default_rng(seed)
    batch = {"labels": jnp.asarray(r.integers(0, cfg.vocab_size, (B, T)))}
    if cfg.embed_inputs and cfg.mrope_sections is None:
        batch["tokens"] = jnp.asarray(r.integers(0, cfg.vocab_size, (B, T)))
    else:  # frontend stub: precomputed frame/patch embeddings
        batch["embeds"] = jnp.asarray(
            r.normal(0, 1, (B, T, cfg.d_model)), jnp.float32)
    if cfg.mrope_sections is not None:
        batch["positions3"] = jnp.broadcast_to(
            jnp.arange(T, dtype=jnp.int32)[None, None], (3, B, T))
    return batch


@pytest.mark.parametrize("arch", ALL)
def test_smoke_forward_and_train(arch):
    cfg = ARCHS[arch].smoke()
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, T = 2, 16
    batch = _batch(cfg, B, T)
    logits, _ = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"

    opt = AdamW(learning_rate=1e-3)
    step = jax.jit(make_train_step(cfg, opt))
    state = (params, opt.init(params), jnp.int32(0))
    state, m = step(state, batch)
    state, m = step(state, batch)
    assert bool(jnp.isfinite(m["loss"])), f"{arch}: non-finite loss"
    # at init the loss must be near log(V) — catches scaling bugs
    assert float(m["loss"]) < math.log(cfg.vocab_size) * 2 + 1.0


@pytest.mark.parametrize("arch", [a for a in ALL if ARCHS[a].causal])
def test_smoke_decode_consistency(arch):
    """prefill+decode through caches == full forward on the longer seq."""
    cfg = ARCHS[arch].smoke()
    params = init_params(cfg, jax.random.PRNGKey(1))
    B, T = 2, 16
    batch = _batch(cfg, B, T, seed=1)
    pf = jax.jit(make_prefill_step(cfg, max_len=T + 4))
    dec = jax.jit(make_decode_step(cfg))
    last, caches, pos = pf(params, {k: v for k, v in batch.items()
                                    if k != "labels"})
    assert bool(jnp.isfinite(last).all())
    tok = jnp.argmax(last, -1)[:, None]
    if not cfg.embed_inputs or cfg.mrope_sections is not None:
        # embeds-fed models decode from token embeddings only if they have
        # a vocab table; qwen2-vl does, hubert has no decode at all.
        if "embed" not in params:
            pytest.skip("no embedding table")
    lg, caches, pos = dec(params, caches, tok, pos)

    full = _batch(cfg, B, T + 1, seed=1)
    if "tokens" in full:
        ext = jnp.concatenate([batch["tokens"], tok], axis=1)
        ref_logits, _ = jax.jit(lambda p, b: forward(p, cfg, b))(
            params, {"tokens": ext})
        err = float(jnp.abs(ref_logits[:, -1] - lg).max())
        assert err < 5e-4, f"{arch}: decode/forward mismatch {err}"


@pytest.mark.parametrize("arch", ALL)
def test_full_config_param_count(arch):
    """eval_shape the FULL config (no allocation) and check the total is
    in the right ballpark for the published size."""
    cfg = ARCHS[arch]
    n = param_count(cfg)
    expected = {
        "zamba2-2.7b": 2.7e9, "hubert-xlarge": 1.0e9, "gemma3-4b": 4e9,
        "h2o-danube-3-4b": 4e9, "gemma3-27b": 27e9, "qwen1.5-110b": 110e9,
        "deepseek-moe-16b": 16e9, "grok-1-314b": 314e9, "rwkv6-7b": 7e9,
        "qwen2-vl-72b": 72e9,
    }[arch]
    assert 0.4 * expected < n < 1.9 * expected, (
        f"{arch}: {n/1e9:.2f}B params vs published {expected/1e9:.0f}B")


def test_cell_accounting():
    """34 runnable cells per DESIGN.md §6."""
    cells = all_cells()
    assert len(cells) == 34
    assert not cell_supported("hubert-xlarge", "decode_32k")
    assert not cell_supported("qwen1.5-110b", "long_500k")
    assert cell_supported("rwkv6-7b", "long_500k")
    assert cell_supported("gemma3-27b", "long_500k")
