"""Quickstart: the full NNCG flow on the paper's ball classifier.

  1. Build the Table-I CNN and *train* it on the synthetic ball dataset.
  2. Hand it to the inference engine: ``InferenceSession`` runs the NNCG
     passes, autotunes the per-layer codegen variants, compiles the C,
     and serves single images or batches.
  3. Validate against the XLA oracle and measure latency — the paper's
     Table IV row for this machine.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.cnn_paper import ball_classifier
from repro.core import jax_exec, runtime
from repro.data.pipeline import ball_image_batch
from repro.engine import InferenceSession
from repro.optim import AdamW

# ---------------------------------------------------------------- 1. train
graph = ball_classifier(seed=0)
params = jax_exec.extract_params(graph)
opt = AdamW(learning_rate=3e-3, weight_decay=0.0)
opt_state = opt.init(params)


def loss_fn(p, x, y):
    logits = jax_exec.forward_with_params(graph, p, x)[:, 0, 0, :]
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()


@jax.jit
def step(p, s, x, y):
    loss, g = jax.value_and_grad(loss_fn)(p, x, y)
    up, s = opt.update(g, s, p)
    p = jax.tree.map(lambda a, u: a + u, p, up)
    return p, s, loss


print("training ball classifier on synthetic balls ...")
for i in range(150):
    xs, ys = ball_image_batch(64, seed=0, step=i)
    params, opt_state, loss = step(params, opt_state, jnp.asarray(xs),
                                   jnp.asarray(ys))
    if (i + 1) % 50 == 0:
        print(f"  step {i+1}: loss {float(loss):.4f}")

xs, ys = ball_image_batch(2000, seed=99, step=0)
pred = jnp.argmax(jax_exec.forward_with_params(
    graph, params, jnp.asarray(xs))[:, 0, 0, :], -1)
acc = float((pred == jnp.asarray(ys)).mean())
print(f"accuracy on held-out synthetic set: {acc:.4f} "
      f"(paper reports 99.975% on the RoboCup set)")

trained = jax_exec.insert_params(graph, params)

# ------------------------- 2-3. engine: optimize + autotune + compile C
# InferenceSession runs the NNCG passes, benchmarks every per-layer
# codegen variant (paper Table VII selection, cached on disk), compiles
# the winner with the host cc, and serves batches.
simd = "sse" if runtime.host_supports_ssse3() else "structured"
sess = InferenceSession(trained, backend="c", autotune=True, simd=simd,
                        tune_iters=500)
info = sess.info
print(f"generated {info['c_source_bytes']/1e3:.0f} KB of C, "
      f"compiled to {info['so_path']}")
print(f"autotuned per-layer unroll levels: {info['levels']} "
      f"(from_cache={info['tuned_from_cache']})")

oracle = InferenceSession(trained, backend="xla", simd=simd)
x = xs[0]
ref = oracle.predict(x)
np.testing.assert_allclose(sess.predict(x), ref, rtol=1e-3, atol=1e-5)
# batched serving path: one C call for the whole batch
np.testing.assert_allclose(sess.predict(xs[:16]),
                           oracle.predict(xs[:16]), rtol=1e-3, atol=1e-5)
print("C output == JAX oracle (allclose, single image and batch)")

# ------------------------------------------------------------- 4. latency
t_c = sess.benchmark(x, iters=20000)
t_xla = oracle.benchmark(x, iters=2000)
print(f"latency: NNCG C {t_c:.2f}us | XLA jit {t_xla:.2f}us | "
      f"speed-up {t_xla/t_c:.2f}x (paper: 11.81x vs TF-XLA on i7)")

# ------------------------------------- 5. int8 quantize-and-deploy (2 lines)
# calibrate activation ranges on sample images, compile the int8 C
# build: int8 weights + intermediates, int32 accumulators, ~4x smaller
# memory arena — same float-in/float-out serving interface.
qsess = InferenceSession(trained, backend="c", precision="int8",
                         calibration=xs[:64])
qpred = qsess.predict(xs[:256])

qacc = float((np.argmax(qpred.reshape(256, -1), -1)
              == np.asarray(ys[:256])).mean())
agree = float((np.argmax(qpred.reshape(256, -1), -1)
               == np.asarray(pred[:256])).mean())
t_q = qsess.benchmark(x, iters=20000)
print(f"int8: accuracy {qacc:.4f}, top-1 agreement with float "
      f"{agree:.4f}, latency {t_q:.2f}us, arena "
      f"{qsess.info['arena_bytes']} B (float: "
      f"{sess.info['arena_bytes']} B)")
