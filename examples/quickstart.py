"""Quickstart: the full NNCG flow on the paper's ball classifier.

  1. Build the Table-I CNN and *train* it on the synthetic ball dataset.
  2. Run the NNCG optimization passes (dropout removal, BN fold,
     activation fusion, P4 channel alignment).
  3. Generate the single ANSI C file, compile it with the host cc, and
     validate it against the JAX oracle.
  4. Measure latency: generated C vs XLA(jit) — the paper's Table IV row
     for this machine.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.cnn_paper import ball_classifier
from repro.core import cgen, jax_exec, passes, runtime
from repro.data.pipeline import ball_image_batch
from repro.optim import AdamW

# ---------------------------------------------------------------- 1. train
graph = ball_classifier(seed=0)
params = jax_exec.extract_params(graph)
opt = AdamW(learning_rate=3e-3, weight_decay=0.0)
opt_state = opt.init(params)


def loss_fn(p, x, y):
    logits = jax_exec.forward_with_params(graph, p, x)[:, 0, 0, :]
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()


@jax.jit
def step(p, s, x, y):
    loss, g = jax.value_and_grad(loss_fn)(p, x, y)
    up, s = opt.update(g, s, p)
    p = jax.tree.map(lambda a, u: a + u, p, up)
    return p, s, loss


print("training ball classifier on synthetic balls ...")
for i in range(150):
    xs, ys = ball_image_batch(64, seed=0, step=i)
    params, opt_state, loss = step(params, opt_state, jnp.asarray(xs),
                                   jnp.asarray(ys))
    if (i + 1) % 50 == 0:
        print(f"  step {i+1}: loss {float(loss):.4f}")

xs, ys = ball_image_batch(2000, seed=99, step=0)
pred = jnp.argmax(jax_exec.forward_with_params(
    graph, params, jnp.asarray(xs))[:, 0, 0, :], -1)
acc = float((pred == jnp.asarray(ys)).mean())
print(f"accuracy on held-out synthetic set: {acc:.4f} "
      f"(paper reports 99.975% on the RoboCup set)")

trained = jax_exec.insert_params(graph, params)

# ------------------------------------------------------------- 2. optimize
optimized = passes.optimize(trained, simd_multiple=4)

# ------------------------------------------------- 3. generate + validate C
simd = "sse" if runtime.host_supports_ssse3() else "structured"
opts = cgen.CodegenOptions(simd=simd,
                           unroll=cgen.choose_levels(optimized, 20000))
source = cgen.generate_c(optimized, opts)
net = runtime.build(optimized, opts)
print(f"generated {len(source)/1e3:.0f} KB of C "
      f"({source.count(chr(10))} lines), compiled to {net.so_path}")

x = xs[0]
ref = jax_exec.predict(optimized, x)
got = net(x).reshape(ref.shape)
np.testing.assert_allclose(got, ref, rtol=1e-3, atol=1e-5)
print("C output == JAX oracle (allclose)")

# ------------------------------------------------------------- 4. latency
t_c = net.time_per_call_us(x, iters=20000)
f = jax_exec.make_jit_forward(optimized)
xb = jnp.asarray(x[None])
f(xb).block_until_ready()
t0 = time.perf_counter()
for _ in range(2000):
    f(xb).block_until_ready()
t_xla = (time.perf_counter() - t0) / 2000 * 1e6
print(f"latency: NNCG C {t_c:.2f}us | XLA jit {t_xla:.2f}us | "
      f"speed-up {t_xla/t_c:.2f}x (paper: 11.81x vs TF-XLA on i7)")
