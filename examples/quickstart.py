"""Quickstart: the full NNCG flow on the paper's ball classifier.

  1. Build the Table-I CNN and *train* it on the synthetic ball dataset.
  2. Hand it to the inference engine: ``InferenceSession`` runs the NNCG
     passes, autotunes the per-layer codegen variants, compiles the C,
     and serves single images or batches.
  3. Validate against the XLA oracle and measure latency — the paper's
     Table IV row for this machine.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.cnn_paper import trained_ball_classifier
from repro.core import runtime
from repro.data.pipeline import ball_image_batch
from repro.engine import CalibrationConfig, InferenceSession, SessionConfig

# ---------------------------------------------------------------- 1. train
print("training ball classifier on synthetic balls ...")
trained, acc = trained_ball_classifier(steps=150, seed=0, log=print)
print(f"accuracy on held-out synthetic set: {acc:.4f} "
      f"(paper reports 99.975% on the RoboCup set)")

xs, ys = ball_image_batch(2000, seed=99, step=0)

# ------------------------- 2-3. engine: optimize + autotune + compile C
# InferenceSession runs the NNCG passes, benchmarks every per-layer
# codegen variant (paper Table VII selection, cached on disk), compiles
# the winner with the host cc, and serves batches.
simd = "sse" if runtime.host_supports_ssse3() else "structured"
sess = InferenceSession(trained, config=SessionConfig(
    backend="c", autotune=True, simd=simd, tune_iters=500))
info = sess.info
print(f"generated {info['c_source_bytes']/1e3:.0f} KB of C, "
      f"compiled to {info['so_path']}")
print(f"autotuned per-layer unroll levels: {info['levels']} "
      f"(from_cache={info['tuned_from_cache']})")

oracle = InferenceSession(trained, config=SessionConfig(backend="xla"))
x = xs[0]
ref = oracle.predict(x)
np.testing.assert_allclose(sess.predict(x), ref, rtol=1e-3, atol=1e-5)
# batched serving path: one C call for the whole batch
np.testing.assert_allclose(sess.predict(xs[:16]),
                           oracle.predict(xs[:16]), rtol=1e-3, atol=1e-5)
print("C output == JAX oracle (allclose, single image and batch)")

# ------------------------------------------------------------- 4. latency
t_c = sess.benchmark(x, iters=20000)
t_xla = oracle.benchmark(x, iters=2000)
print(f"latency: NNCG C {t_c:.2f}us | XLA jit {t_xla:.2f}us | "
      f"speed-up {t_xla/t_c:.2f}x (paper: 11.81x vs TF-XLA on i7)")

# ------------------------------------- 5. int8 quantize-and-deploy (2 lines)
# calibrate activation ranges on sample images (streamed through
# histogram observers), compile the int8 C build: int8 weights +
# intermediates, int32 accumulators, ~4x smaller memory arena — same
# float-in/float-out serving interface.  The calibration *method* is
# one more argument: "minmax" (exact range), "percentile" (clip
# outlier tails), "mse" (histogram-MSE-optimal range).
from repro.core import passes, quantize  # noqa: E402

opt_graph = passes.optimize(trained, simd_multiple=1)
print("calibration methods on the trained ball net (64 real frames):")
for method in quantize.CALIBRATION_METHODS:
    qg = quantize.quantize(opt_graph, xs[:64], method=method)
    st = quantize.quantization_error(qg, xs[:512])
    print(f"  {method:10s} top-1 agreement {st['top1_agreement']:.4f}  "
          f"max|err| {st['max_abs_err']:.5f}")

qsess = InferenceSession(trained, config=SessionConfig(
    backend="c", precision="int8",
    calibration=CalibrationConfig(data=xs[:64], method="percentile")))
qpred = qsess.predict(xs[:256])

pred = np.argmax(oracle.predict(xs[:256]).reshape(256, -1), -1)
qacc = float((np.argmax(qpred.reshape(256, -1), -1)
              == np.asarray(ys[:256])).mean())
agree = float((np.argmax(qpred.reshape(256, -1), -1) == pred).mean())
t_q = qsess.benchmark(x, iters=20000)
print(f"int8 ({qsess.info['calibration_method']}): accuracy {qacc:.4f}, "
      f"top-1 agreement with float {agree:.4f}, latency {t_q:.2f}us, "
      f"arena {qsess.info['arena_bytes']} B (float: "
      f"{sess.info['arena_bytes']} B)")
