"""Serving quickstart: continuous-batching inference over a compiled CNN.

  1. Compile the pedestrian detector through ``InferenceSession``
     (``SessionConfig`` is the one knob object — backend, autotune,
     SIMD, quantization all live there).
  2. Boot an ``InferenceServer`` on top: bounded queue, dynamic
     batching against a latency SLO, per-thread warm arena workers.
  3. Drive camera-frame traffic through it three ways — sync
     ``predict``, async futures, and a paced open-loop burst — then
     read the rolling stats.

Run:  PYTHONPATH=src python examples/serve_cnn.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.cnn_paper import pedestrian_classifier
from repro.data.pipeline import camera_frame_batch
from repro.engine import InferenceSession, SessionConfig
from repro.serve import InferenceServer, ServerConfig, ServerOverloaded

# ------------------------------------------------- 1. compile the net
graph = pedestrian_classifier(seed=0)
sess = InferenceSession(graph, config=SessionConfig(
    backend="c", autotune=True, tune_iters=300))
print(f"compiled {sess.info['c_source_bytes'] / 1e3:.0f} KB of C "
      f"({sess.info['simd']} SIMD, "
      f"arena {sess.info['arena_bytes']} B)")

frames = camera_frame_batch(64, tuple(graph.input_shape), seed=1)

# --------------------------------------------------- 2. boot a server
# batch_deadline_ms is the aggregation SLO: a batch ships when it is
# full OR its oldest request has waited this long.  max_queue bounds
# memory; a full queue raises ServerOverloaded instead of hanging.
server = InferenceServer(sess, config=ServerConfig(
    workers=2, max_batch=16, max_queue=1024,
    batch_deadline_ms=2.0, request_timeout_ms=1000.0))

# ------------------------------------------------ 3a. sync convenience
probs = server.predict(frames[0])
print(f"sync predict -> {probs.shape}, argmax {int(np.argmax(probs))}")

# ---------------------------------------------------- 3b. async futures
handles = [server.submit(f) for f in frames[:32]]
outs = [h.result(timeout=5.0) for h in handles]
ts = handles[0].timestamps
print(f"async x32: first request queued "
      f"{(ts['dequeue'] - ts['submit']) * 1e3:.2f} ms, "
      f"rode in a batch of {handles[0].batch_size}")

# -------------------------------------- 3c. paced open-loop camera burst
# 2000 frames at 4 kHz — arrivals on a clock, like a sensor;
# backpressure (ServerOverloaded) is counted, not retried.
rate_hz, n, dropped, handles = 4000.0, 2000, 0, []
t0 = time.perf_counter()
for i in range(n):
    target = t0 + i / rate_hz
    now = time.perf_counter()
    if target > now:
        time.sleep(target - now)
    try:
        handles.append(server.submit(frames[i % len(frames)]))
    except ServerOverloaded:
        dropped += 1
for h in handles:
    h.result(timeout=5.0)

stats = server.stats()
print(f"open loop @ {rate_hz:.0f} Hz: {stats['completed']:.0f} served, "
      f"{dropped} dropped")
print(f"  latency p50 {stats['latency_p50_us']:.0f} us | "
      f"p99 {stats['latency_p99_us']:.0f} us | "
      f"exec p50 {stats['exec_p50_us']:.0f} us")
print(f"  throughput {stats['qps']:.0f} qps, "
      f"mean batch {stats['batch_size_mean']:.1f} "
      f"(occupancy {stats['batch_occupancy']:.2f})")

server.close()          # graceful: drains queued work, joins workers
print("server drained and closed")
