"""Serving example: batched prefill + decode through the KV caches.

Uses the reduced gemma3-4b config (local:global pattern with ring caches
for the SWA layers) so it runs on CPU; the same `make_prefill_step` /
`make_decode_step` functions are what the 512-chip dry-run lowers.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.lm_archs import ARCHS
from repro.models import init_params, make_decode_step, make_prefill_step

cfg = ARCHS["gemma3-4b"].smoke()
params = init_params(cfg, jax.random.PRNGKey(0))

BATCH, PROMPT, NEW = 4, 24, 16
prefill = jax.jit(make_prefill_step(cfg, max_len=PROMPT + NEW))
decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

rng = np.random.default_rng(0)
prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (BATCH, PROMPT)))

t0 = time.time()
logits, caches, pos = prefill(params, {"tokens": prompts})
tok = jnp.argmax(logits, -1)[:, None]
generated = [tok]
for _ in range(NEW - 1):
    logits, caches, pos = decode(params, caches, tok, pos)
    tok = jnp.argmax(logits, -1)[:, None]
    generated.append(tok)
out = jnp.concatenate(generated, axis=1)
dt = time.time() - t0

assert out.shape == (BATCH, NEW)
assert bool(jnp.isfinite(logits).all())
print(f"served {BATCH} requests: prompt={PROMPT} tokens, "
      f"generated={NEW} tokens each in {dt:.2f}s")
print("sample continuation token ids:", np.asarray(out[0])[:10])
