"""Serving example: the LM workload through the unified session API.

PR 9 folded the Pallas LM stack under the same engine surface the CNNs
use — `SessionConfig` carries an `lm` sub-config, the `"pallas-lm"`
registry entry compiles prefill/decode behind an explicit KV-cache
handle, and the autotuner times the Pallas kernel variants (flash vs.
reference attention, block sizes) exactly like C unroll levels, caching
the winner on disk.  The reduced gemma3-4b config (local:global pattern
with ring caches for the SWA layers) keeps this runnable on CPU; token
requests can also ride the bounded-queue server (`LMTokenServer`).

The old direct-import spelling
(`make_prefill_step(...)` / `make_decode_step(...)` by hand) still
works and is used below as the oracle: the session's greedy decode must
reproduce it token-for-token.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import LMConfig, LMSession, SessionConfig
from repro.models import make_decode_step, make_prefill_step
from repro.models.stack import DEFAULT_PAR
from repro.serve import LMTokenServer, ServerConfig

BATCH, PROMPT, NEW = 4, 24, 16
MAX_CTX = PROMPT + NEW

cache_dir = os.environ.get("NNCG_LM_CACHE",
                           os.path.join(tempfile.gettempdir(),
                                        "nncg_lm_cache"))
sess = LMSession(config=SessionConfig(
    backend="pallas-lm", autotune=True, tune_cache=cache_dir,
    lm=LMConfig(arch="gemma3-4b", max_context=MAX_CTX,
                decode_batch=BATCH)))
info = sess.info
print(f"arch={info['arch']}  params={info['n_params']:,}  "
      f"backend={info['backend']}")
print(f"autotuned kernel policy: {info['kernel_policy']} "
      f"(prefill {info['tuned_prefill_us']:.0f}us, "
      f"{'cache hit' if info['tuned_from_cache'] else 'freshly timed'})")

rng = np.random.default_rng(0)
prompts = rng.integers(0, sess.model_cfg.vocab_size,
                       (BATCH, PROMPT)).astype(np.int32)

# -- session path: prefill + greedy decode through the KV-cache handle --
t0 = time.time()
logits, handle = sess.prefill(prompts)
t_prefill = time.time() - t0
tok = np.argmax(logits, -1).astype(np.int32)
out = [tok]
t0 = time.time()
for _ in range(NEW - 1):
    tok = np.argmax(sess.decode(handle, tok), -1).astype(np.int32)
    out.append(tok)
t_decode = time.time() - t0
out = np.stack(out, axis=1)
assert out.shape == (BATCH, NEW)

# -- oracle: the direct models/lm.py loop with the same kernel policy --
par = DEFAULT_PAR.with_kernels(sess.kernel_policy)
cfg = sess.model_cfg
prefill = jax.jit(make_prefill_step(cfg, max_len=MAX_CTX, par=par))
decode = jax.jit(make_decode_step(cfg, par=par))
lg, caches, pos = prefill(sess.backend.params,
                          {"tokens": jnp.asarray(prompts)})
ref_tok = jnp.argmax(lg, -1)[:, None]
ref = [np.asarray(ref_tok[:, 0], np.int32)]
for _ in range(NEW - 1):
    lg, caches, pos = decode(sess.backend.params, caches, ref_tok, pos)
    ref_tok = jnp.argmax(lg, -1)[:, None]
    ref.append(np.asarray(ref_tok[:, 0], np.int32))
np.testing.assert_array_equal(out, np.stack(ref, axis=1))
print("session tokens == direct model tokens: OK")

# generate() is the same loop in one call, and the token server routes
# it through the bounded queue / stats machinery
np.testing.assert_array_equal(sess.generate(prompts, NEW), out)
with LMTokenServer(sess, config=ServerConfig(
        workers=1, max_batch=BATCH, request_timeout_ms=None)) as srv:
    futs = [srv.submit(prompts[i], max_new=NEW) for i in range(BATCH)]
    served = np.stack([f.result(timeout=300.0) for f in futs])
    stats = srv.stats()
np.testing.assert_array_equal(served, out)
print(f"served {BATCH} queued requests through LMTokenServer "
      f"(completed={stats['completed']:.0f})")

tok_s = BATCH * PROMPT / t_prefill
ms_tok = t_decode / (BATCH * (NEW - 1)) * 1e3
print(f"prefill: {BATCH}x{PROMPT} tokens in {t_prefill:.2f}s "
      f"({tok_s:.0f} tok/s)   decode: {ms_tok:.1f} ms/token")
print("sample continuation token ids:", out[0][:10])
