"""End-to-end driver: train the ~100M-param LM for a few hundred steps
with checkpointing (deliverable (b)).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:] or ["--steps", "200", "--batch", "8", "--seq", "256"]
    out = main(["--arch", "lm-100m"] + argv)
    assert out["last_loss"] < out["first_loss"], "loss did not improve"
    print(f"loss {out['first_loss']:.3f} -> {out['last_loss']:.3f} over "
          f"{len(out['loss_curve'])} logged points: training works.")
