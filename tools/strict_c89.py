#!/usr/bin/env python
"""CI gate for the paper's "plain ANSI C" claim.

Generates the C file for the paper's ball CNN and the residual DAG
config (generic SIMD mode — the intrinsics headers are deliberately
out of scope for ANSI), then compiles each with

    gcc -std=c89 -Wall -Wextra -Werror -pedantic-errors

Any warning, any C99-ism (mid-block declarations, ``//`` comments,
``for (int ...``, bare ``restrict``) fails the build.  Exercises both
the fully-unrolled (weights-as-literals) and rolled (const-array)
emission paths, the epilogue-fused and unfused schedules, and the
layer-pipelined (stage functions + ``<func>_pipeline`` driver) builds
— float and int8.
"""
from __future__ import annotations

import os
import shutil
import subprocess
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.cnn_paper import ball_classifier, residual_cnn  # noqa: E402
from repro.core import cgen, codegen, passes, quantize  # noqa: E402
from repro.core.schedule import (  # noqa: E402
    fusable_concats, fusable_pools, make_schedule,
)

STRICT_FLAGS = ["-std=c89", "-Wall", "-Wextra", "-Werror",
                "-pedantic-errors"]


def pool_concat_dag():
    """Branchy DAG exercising the pooling/Concat fused epilogues: a
    MaxPool and an AvgPool each absorbed into their producer conv, a
    two-edge fused Concat, and (under ``:pc``) a per-channel-requanted
    stem whose zero-point table feeds the epilogue."""
    import numpy as np
    from repro.core.graph import (
        AvgPool, CNNGraph, Concat, Conv2D, Input, MaxPool,
    )
    rng = np.random.default_rng(11)

    def conv(kh, kw, ci, co, **kw_args):
        return Conv2D(
            weights=rng.normal(0, 0.5, (kh, kw, ci, co)).astype(
                np.float32),
            bias=rng.normal(0, 0.1, (co,)).astype(np.float32),
            **kw_args)

    return CNNGraph([
        Input(shape=(12, 12, 2), name="in"),
        conv(3, 3, 2, 20, padding="valid", activation="relu", name="s"),
        conv(1, 1, 20, 16, activation="relu", name="pm"),
        MaxPool(size=(2, 2), name="mp"),
        conv(1, 1, 20, 16, activation="leaky_relu", name="pa",
             inputs=["s"]),
        AvgPool(size=(2, 2), name="ap"),
        conv(3, 3, 16, 16, padding="same", name="cb1", inputs=["mp"]),
        conv(1, 1, 16, 16, name="cb2", inputs=["ap"]),
        Concat(name="cat", inputs=["cb1", "cb2"]),
        conv(1, 1, 32, 7, name="head"),
    ])


# (tag, builder, unroll, quant method or None, nstages, fusion);
# a ":pc" method suffix selects per-channel requant zero points
CASES = [
    ("ball-unrolled", ball_classifier, 0, None, 1, True),
    ("ball-rolled", ball_classifier, None, None, 1, True),
    # DAG config (Add/Concat/depthwise) — fused schedule folds the
    # residual Adds into their producer conv loops
    ("residual-fused", residual_cnn, None, None, 1, True),
    ("residual-unfused", residual_cnn, None, None, 1, False),
    # layer-pipelined float build: stage functions, interface buffers,
    # the <func>_pipeline driver — all must survive -std=c89
    ("residual-pipe2", residual_cnn, None, None, 2, True),
    # post-training-quantized builds, one per calibration method (the
    # requant constants differ; the emitted C must stay strict-ANSI
    # regardless of how the ranges were selected)
    ("ball-int8", ball_classifier, None, "minmax", 1, True),
    ("ball-int8-mse", ball_classifier, None, "mse", 1, True),
    # quantized DAG build: per-branch Concat requant under percentile,
    # fused int8 epilogues
    ("residual-int8", residual_cnn, None, "percentile", 1, True),
    # layer-pipelined int8 build
    ("residual-int8-pipe2", residual_cnn, None, "percentile", 2, True),
    # pooling/Concat fused epilogues (MaxPool + AvgPool absorbed into
    # their producer loops, fused Concat slice stores) — float, int8,
    # and int8 with per-channel requant zero-point tables
    ("poolcat-fused", pool_concat_dag, None, None, 1, True),
    ("poolcat-int8", pool_concat_dag, None, "minmax", 1, True),
    ("poolcat-int8-pc", pool_concat_dag, None, "minmax:pc", 1, True),
]


def _compile_unit(graph, unroll, method, nstages, fusion) -> str:
    opts = cgen.CodegenOptions(simd="generic", unroll=unroll)
    sched = make_schedule(graph, nstages=nstages, fusion=fusion)
    if method is not None:
        import numpy as np
        method, _, pc = method.partition(":")
        xs = np.random.default_rng(0).normal(
            size=(8,) + tuple(graph.input_shape)).astype(np.float32)
        unit = quantize.quantize(graph, xs, method=method,
                                 per_channel=pc == "pc")
        if pc == "pc":
            assert unit.channel_acts, \
                "per-channel case must emit zero-point tables"
    else:
        unit = graph
    return codegen.compile(unit, opts, schedule=sched).source


def main() -> int:
    gcc = shutil.which("gcc") or shutil.which("cc")
    if gcc is None:
        print("strict_c89: no C compiler found", file=sys.stderr)
        return 2
    failures = 0
    with tempfile.TemporaryDirectory() as tmp:
        for tag, builder, unroll, method, nstages, fusion in CASES:
            g = passes.optimize(builder(), simd_multiple=1)
            if tag.startswith("poolcat"):
                # the case exists to gate the fused pool/Concat C —
                # fail loudly if the optimizer ever defeats that shape
                assert fusable_pools(g) and fusable_concats(g), tag
            src = _compile_unit(g, unroll, method, nstages, fusion)
            c_path = os.path.join(tmp, f"{tag}.c")
            with open(c_path, "w") as f:
                f.write(src)
            cmd = [gcc, *STRICT_FLAGS, "-c", c_path,
                   "-o", c_path + ".o"]
            proc = subprocess.run(cmd, capture_output=True, text=True)
            if proc.returncode == 0:
                print(f"strict_c89: {tag}: OK ({len(src)} bytes)")
            else:
                failures += 1
                print(f"strict_c89: {tag}: FAILED\n{proc.stderr[:4000]}",
                      file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
