#!/usr/bin/env python
"""CI gate for the paper's "plain ANSI C" claim.

Generates the C file for the paper's ball CNN and the residual DAG
config (generic SIMD mode — the intrinsics headers are deliberately
out of scope for ANSI), then compiles each with

    gcc -std=c89 -Wall -Wextra -Werror -pedantic-errors

Any warning, any C99-ism (mid-block declarations, ``//`` comments,
``for (int ...``, bare ``restrict``) fails the build.  Exercises both
the fully-unrolled (weights-as-literals) and rolled (const-array)
emission paths, the epilogue-fused and unfused schedules, and the
layer-pipelined (stage functions + ``<func>_pipeline`` driver) builds
— float and int8.
"""
from __future__ import annotations

import os
import shutil
import subprocess
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.cnn_paper import ball_classifier, residual_cnn  # noqa: E402
from repro.core import cgen, codegen, passes, quantize  # noqa: E402
from repro.core.schedule import make_schedule  # noqa: E402

STRICT_FLAGS = ["-std=c89", "-Wall", "-Wextra", "-Werror",
                "-pedantic-errors"]

# (tag, builder, unroll, quant method or None, nstages, fusion)
CASES = [
    ("ball-unrolled", ball_classifier, 0, None, 1, True),
    ("ball-rolled", ball_classifier, None, None, 1, True),
    # DAG config (Add/Concat/depthwise) — fused schedule folds the
    # residual Adds into their producer conv loops
    ("residual-fused", residual_cnn, None, None, 1, True),
    ("residual-unfused", residual_cnn, None, None, 1, False),
    # layer-pipelined float build: stage functions, interface buffers,
    # the <func>_pipeline driver — all must survive -std=c89
    ("residual-pipe2", residual_cnn, None, None, 2, True),
    # post-training-quantized builds, one per calibration method (the
    # requant constants differ; the emitted C must stay strict-ANSI
    # regardless of how the ranges were selected)
    ("ball-int8", ball_classifier, None, "minmax", 1, True),
    ("ball-int8-mse", ball_classifier, None, "mse", 1, True),
    # quantized DAG build: per-branch Concat requant under percentile,
    # fused int8 epilogues
    ("residual-int8", residual_cnn, None, "percentile", 1, True),
    # layer-pipelined int8 build
    ("residual-int8-pipe2", residual_cnn, None, "percentile", 2, True),
]


def _compile_unit(graph, unroll, method, nstages, fusion) -> str:
    opts = cgen.CodegenOptions(simd="generic", unroll=unroll)
    sched = make_schedule(graph, nstages=nstages, fusion=fusion)
    if method is not None:
        import numpy as np
        xs = np.random.default_rng(0).normal(
            size=(8,) + tuple(graph.input_shape)).astype(np.float32)
        unit = quantize.quantize(graph, xs, method=method)
    else:
        unit = graph
    return codegen.compile(unit, opts, schedule=sched).source


def main() -> int:
    gcc = shutil.which("gcc") or shutil.which("cc")
    if gcc is None:
        print("strict_c89: no C compiler found", file=sys.stderr)
        return 2
    failures = 0
    with tempfile.TemporaryDirectory() as tmp:
        for tag, builder, unroll, method, nstages, fusion in CASES:
            g = passes.optimize(builder(), simd_multiple=1)
            src = _compile_unit(g, unroll, method, nstages, fusion)
            c_path = os.path.join(tmp, f"{tag}.c")
            with open(c_path, "w") as f:
                f.write(src)
            cmd = [gcc, *STRICT_FLAGS, "-c", c_path,
                   "-o", c_path + ".o"]
            proc = subprocess.run(cmd, capture_output=True, text=True)
            if proc.returncode == 0:
                print(f"strict_c89: {tag}: OK ({len(src)} bytes)")
            else:
                failures += 1
                print(f"strict_c89: {tag}: FAILED\n{proc.stderr[:4000]}",
                      file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
