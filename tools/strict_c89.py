#!/usr/bin/env python
"""CI gate for the paper's "plain ANSI C" claim.

Generates the C file for the paper's ball CNN and the residual DAG
config (generic SIMD mode — the intrinsics headers are deliberately
out of scope for ANSI), then compiles each with

    gcc -std=c89 -Wall -Wextra -Werror -pedantic-errors

Any warning, any C99-ism (mid-block declarations, ``//`` comments,
``for (int ...``, bare ``restrict``) fails the build.  Exercises both
the fully-unrolled (weights-as-literals) and rolled (const-array)
emission paths.
"""
from __future__ import annotations

import os
import shutil
import subprocess
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.cnn_paper import ball_classifier, residual_cnn  # noqa: E402
from repro.core import cgen, passes, quantize  # noqa: E402

STRICT_FLAGS = ["-std=c89", "-Wall", "-Wextra", "-Werror",
                "-pedantic-errors"]

CASES = [
    ("ball", ball_classifier, 0),       # paper CNN, fully unrolled
    ("ball", ball_classifier, None),    # paper CNN, rolled loops
    ("residual", residual_cnn, None),   # DAG config (Add/Concat/depthwise)
    # post-training-quantized builds, one per calibration method (the
    # requant constants differ; the emitted C must stay strict-ANSI
    # regardless of how the ranges were selected)
    ("ball", ball_classifier, "int8:minmax"),
    ("ball", ball_classifier, "int8:mse"),
    # quantized DAG build: per-branch Concat requant under percentile
    ("residual", residual_cnn, "int8:percentile"),
]


def _quantized_source(graph, method: str) -> str:
    import numpy as np
    xs = np.random.default_rng(0).normal(
        size=(8,) + tuple(graph.input_shape)).astype(np.float32)
    qg = quantize.quantize(graph, xs, method=method)
    return cgen.generate_quantized_c(
        qg, cgen.CodegenOptions(simd="generic"))


def main() -> int:
    gcc = shutil.which("gcc") or shutil.which("cc")
    if gcc is None:
        print("strict_c89: no C compiler found", file=sys.stderr)
        return 2
    failures = 0
    with tempfile.TemporaryDirectory() as tmp:
        for name, builder, unroll in CASES:
            g = passes.optimize(builder(), simd_multiple=1)
            if isinstance(unroll, str) and unroll.startswith("int8"):
                src = _quantized_source(g, unroll.split(":")[1])
            else:
                src = cgen.generate_c(
                    g, cgen.CodegenOptions(simd="generic", unroll=unroll))
            c_path = os.path.join(tmp, f"{name}_{unroll}.c")
            with open(c_path, "w") as f:
                f.write(src)
            cmd = [gcc, *STRICT_FLAGS, "-c", c_path,
                   "-o", c_path + ".o"]
            proc = subprocess.run(cmd, capture_output=True, text=True)
            tag = f"{name} unroll={unroll}"
            if proc.returncode == 0:
                print(f"strict_c89: {tag}: OK ({len(src)} bytes)")
            else:
                failures += 1
                print(f"strict_c89: {tag}: FAILED\n{proc.stderr[:4000]}",
                      file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
