#!/usr/bin/env python
"""CI gate for the NEON int8 kernel variants: cross-compile, *execute*,
bit-compare.

For each check net (softmax-free — ``expf`` is libm-version dependent,
the integer path is not) and each ARM variant (``generic`` as the
cross-toolchain baseline, ``neon`` vmlal, ``neon_dot`` vdot):

1. generate the int8 C + a tiny file-I/O ``main()`` harness,
2. cross-compile with ``aarch64-linux-gnu-gcc -static`` (static link:
   QEMU user mode needs no target sysroot),
3. run the binary under ``qemu-aarch64 -cpu max`` (dotprod available),
4. compare the raw float32 outputs byte-for-byte against
   ``jax_exec.forward_quantized`` — the same hard oracle the x86
   variants face in tests/test_int8_kernels.py.

Also compiles the aarch64 ``generic`` build under the strict C89 gate
(``-std=c89 -Wall -Wextra -Werror -pedantic-errors``), so the "plain
ANSI C deploys on the robot" claim is checked with the robot's own
toolchain, not just the host's.

Exit codes: 0 all bit-exact, 1 mismatch/compile failure, 2 toolchain
missing (CI installs it; locally tests skip on 2).
"""
from __future__ import annotations

import argparse
import os
import shutil
import subprocess
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import cgen, codegen, jax_exec, passes, quantize  # noqa: E402
from repro.core.graph import (  # noqa: E402
    Add, AvgPool, CNNGraph, Concat, Conv2D, Dense, DepthwiseConv2D,
    Flatten, Input, MaxPool,
)
from repro.core.schedule import fusable_concats, fusable_pools  # noqa: E402

ARM_VARIANTS = ["generic", "neon", "neon_dot"]
STRICT_FLAGS = ["-std=c89", "-Wall", "-Wextra", "-Werror",
                "-pedantic-errors"]

_HARNESS = """
#include <stdio.h>

int main(int argc, char **argv)
{{
    static float x[{in_n}];
    static float out[{out_n}];
    FILE *fi;
    FILE *fo;
    if (argc != 3) {{
        return 2;
    }}
    fi = fopen(argv[1], "rb");
    fo = fopen(argv[2], "wb");
    if (fi == NULL || fo == NULL) {{
        return 2;
    }}
    while (fread(x, sizeof(float), {in_n}, fi) == (size_t){in_n}) {{
        {func}(x, out);
        fwrite(out, sizeof(float), {out_n}, fo);
    }}
    fclose(fi);
    fclose(fo);
    return 0;
}}
"""


def _conv(rng, kh, kw, ci, co, **kw_args) -> Conv2D:
    w = rng.normal(0, 0.5, (kh, kw, ci, co)).astype(np.float32)
    b = rng.normal(0, 0.1, (co,)).astype(np.float32)
    return Conv2D(weights=w, bias=b, **kw_args)


def _kernel_zoo(seed=7) -> CNNGraph:
    """Same construct coverage as tests/test_int8_kernels.py: tiled
    convs with group tails, depthwise, Add, vectorized MaxPool, Dense."""
    rng = np.random.default_rng(seed)
    dw_w = rng.normal(0, 0.5, (3, 3, 12, 1)).astype(np.float32)
    dw_b = rng.normal(0, 0.1, (12,)).astype(np.float32)
    return CNNGraph([
        Input(shape=(11, 9, 3), name="in"),
        _conv(rng, 3, 3, 3, 12, padding="same", activation="relu",
              name="c1"),
        DepthwiseConv2D(weights=dw_w, bias=dw_b, padding="same",
                        activation="leaky_relu", name="dw"),
        Add(name="add", inputs=["dw", "c1"], activation="relu"),
        _conv(rng, 3, 3, 12, 19, strides=(2, 2), padding="same",
              activation="leaky_relu", name="c2"),
        MaxPool(size=(2, 2), padding="same", name="mp"),
        _conv(rng, 2, 2, 19, 33, padding="valid", name="c3"),
        Flatten(name="fl"),
        Dense(weights=rng.normal(0, 0.2, (2 * 2 * 33, 21)).astype(
                  np.float32),
              bias=rng.normal(0, 0.1, (21,)).astype(np.float32),
              activation="relu", name="d1"),
        Dense(weights=rng.normal(0, 0.2, (21, 10)).astype(np.float32),
              bias=rng.normal(0, 0.1, (10,)).astype(np.float32),
              name="d2"),
    ])


def _camera_conv_net(seed=9) -> CNNGraph:
    """Robot-detector-shaped stack (no softmax head) so the CI lane
    also runs a realistically-sized conv pyramid under emulation."""
    rng = np.random.default_rng(seed)
    return CNNGraph([
        Input(shape=(30, 40, 3), name="in"),
        _conv(rng, 5, 5, 3, 8, strides=(2, 2), padding="same",
              activation="leaky_relu", name="c1"),
        MaxPool(size=(2, 2), name="mp1"),
        _conv(rng, 3, 3, 8, 16, padding="same", activation="leaky_relu",
              name="c2"),
        _conv(rng, 3, 3, 16, 20, padding="valid", activation="relu",
              name="c3"),
    ])


def _pool_concat_net(seed=11) -> CNNGraph:
    """Branchy DAG covering the fused pool/Concat epilogues on NEON:
    MaxPool and AvgPool absorbed into their producer convs, a two-edge
    fused Concat, and a per-channel-requanted stem (quantized with
    ``per_channel=True`` below) whose NEON zero-point-table loads only
    this lane executes on real aarch64 code."""
    rng = np.random.default_rng(seed)
    return CNNGraph([
        Input(shape=(12, 12, 2), name="in"),
        _conv(rng, 3, 3, 2, 20, padding="valid", activation="relu",
              name="s"),
        _conv(rng, 1, 1, 20, 16, activation="relu", name="pm"),
        MaxPool(size=(2, 2), name="mp"),
        _conv(rng, 1, 1, 20, 16, activation="leaky_relu", name="pa",
              inputs=["s"]),
        AvgPool(size=(2, 2), name="ap"),
        _conv(rng, 3, 3, 16, 16, padding="same", name="cb1",
              inputs=["mp"]),
        _conv(rng, 1, 1, 16, 16, name="cb2", inputs=["ap"]),
        Concat(name="cat", inputs=["cb1", "cb2"]),
        _conv(rng, 1, 1, 32, 7, name="head"),
    ])


def _find_tool(explicit, names):
    if explicit:
        return explicit if shutil.which(explicit) else None
    for n in names:
        if shutil.which(n):
            return n
    return None


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--cc", default=None,
                    help="aarch64 cross compiler (default: autodetect)")
    ap.add_argument("--qemu", default=None,
                    help="qemu user-mode binary (default: autodetect)")
    args = ap.parse_args()
    cc = _find_tool(args.cc, ["aarch64-linux-gnu-gcc",
                              "aarch64-unknown-linux-gnu-gcc"])
    qemu = _find_tool(args.qemu, ["qemu-aarch64", "qemu-aarch64-static"])
    if cc is None or qemu is None:
        print(f"cross_check: toolchain missing (cc={cc}, qemu={qemu})",
              file=sys.stderr)
        return 2

    nets = {"zoo": _kernel_zoo(), "camera": _camera_conv_net(),
            "poolcat": _pool_concat_net()}
    failures = 0
    with tempfile.TemporaryDirectory() as tmp:
        for name, g0 in nets.items():
            g = passes.optimize(g0, simd_multiple=1)
            if name == "poolcat":
                assert fusable_pools(g) and fusable_concats(g), \
                    "poolcat net must exercise the fused pool/Concat C"
            rng = np.random.default_rng(3)
            xs = rng.normal(size=(8,) + tuple(g.input_shape)).astype(
                np.float32)
            qg = quantize.quantize(g, xs,
                                   per_channel=name == "poolcat")
            if name == "poolcat":
                assert qg.channel_acts, \
                    "poolcat must carry per-channel zero-point tables"
            ref = np.asarray(jax_exec.make_jit_forward_quantized(qg)(xs))
            in_n = int(np.prod(g.input_shape))
            out_n = ref.size // len(xs)
            x_path = os.path.join(tmp, f"{name}_x.bin")
            xs.astype("<f4").tofile(x_path)
            for simd in ARM_VARIANTS:
                opts = cgen.CodegenOptions(simd=simd)
                src = codegen.compile(qg, opts).source
                src += _HARNESS.format(in_n=in_n, out_n=out_n,
                                       func=opts.func_name)
                c_path = os.path.join(tmp, f"{name}_{simd}.c")
                with open(c_path, "w") as f:
                    f.write(src)
                exe = os.path.join(tmp, f"{name}_{simd}")
                flags = list(cgen.QISAS[simd].cc_flags) \
                    if simd in cgen.QISAS else []
                cmd = [cc, "-O2", "-static", *flags, c_path, "-o", exe,
                       "-lm"]
                proc = subprocess.run(cmd, capture_output=True, text=True)
                tag = f"{name}/{simd}"
                if proc.returncode != 0:
                    failures += 1
                    print(f"cross_check: {tag}: CROSS-COMPILE FAILED\n"
                          f"{proc.stderr[:4000]}", file=sys.stderr)
                    continue
                o_path = os.path.join(tmp, f"{name}_{simd}_out.bin")
                proc = subprocess.run(
                    [qemu, "-cpu", "max", exe, x_path, o_path],
                    capture_output=True, text=True, timeout=600)
                if proc.returncode != 0:
                    failures += 1
                    print(f"cross_check: {tag}: QEMU RUN FAILED "
                          f"(rc={proc.returncode})\n{proc.stderr[:2000]}",
                          file=sys.stderr)
                    continue
                got = np.fromfile(o_path, dtype="<f4").reshape(ref.shape)
                if np.array_equal(got, ref):
                    print(f"cross_check: {tag}: BIT-EXACT "
                          f"({len(xs)} images, {out_n} outputs each)")
                else:
                    failures += 1
                    bad = int((got != ref).sum())
                    print(f"cross_check: {tag}: MISMATCH "
                          f"({bad}/{ref.size} values differ)",
                          file=sys.stderr)
            # strict ANSI gate with the robot's toolchain: the generic
            # int8 build must survive -std=c89 -Werror on aarch64 too
            strict_c = os.path.join(tmp, f"{name}_strict.c")
            with open(strict_c, "w") as f:
                f.write(codegen.compile(
                    qg, cgen.CodegenOptions(simd="generic")).source)
            proc = subprocess.run(
                [cc, *STRICT_FLAGS, "-c", strict_c, "-o",
                 strict_c + ".o"], capture_output=True, text=True)
            if proc.returncode == 0:
                print(f"cross_check: {name}/strict-c89(aarch64): OK")
            else:
                failures += 1
                print(f"cross_check: {name}/strict-c89(aarch64): FAILED\n"
                      f"{proc.stderr[:4000]}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
