#!/usr/bin/env python
"""Dump the typed loop-nest IR (``repro.core.lowering.Program``) a net
lowers to — the inspection window between the schedule and the C text.

Usage::

    python tools/dump_ir.py ball                 # float build
    python tools/dump_ir.py robot --int8         # calibrated int8
    python tools/dump_ir.py residual --no-fusion # legacy layout
    python tools/dump_ir.py ball --simd sse --stages 2 --bodies

Prints each nest with its loop structure (``~`` marks unrolled loops),
kernel kind/variant, epilogue chain (requant, activation, fused
Add/pool/Concat consumers), and the planned arena buffers with byte
offsets and live ranges.  ``--bodies`` inlines the rendered C lines of
every kernel span.  ``--c`` prints the rendered translation unit
instead (what ``render(program)`` — and therefore ``compile()`` —
emits).
"""
from __future__ import annotations

import argparse
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import codegen, passes  # noqa: E402
from repro.core.cgen import CodegenOptions  # noqa: E402
from repro.core.lowering import format_program, render  # noqa: E402
from repro.core.schedule import make_schedule  # noqa: E402
from repro.configs import cnn_paper  # noqa: E402

NETS = {
    "ball": cnn_paper.ball_classifier,
    "pedestrian": cnn_paper.pedestrian_classifier,
    "robot": cnn_paper.robot_detector,
    "residual": cnn_paper.residual_cnn,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("net", choices=sorted(NETS),
                    help="bench net to lower")
    ap.add_argument("--int8", action="store_true",
                    help="calibrate (synthetic frames) and lower the "
                         "quantized build")
    ap.add_argument("--per-channel", action="store_true",
                    help="with --int8: per-channel requant zero points")
    ap.add_argument("--simd", default="generic",
                    help="kernel variant (default: generic)")
    ap.add_argument("--stages", type=int, default=1,
                    help="pipeline stage count (default: 1)")
    ap.add_argument("--no-fusion", action="store_true",
                    help="legacy unfused schedule")
    ap.add_argument("--bodies", action="store_true",
                    help="inline the rendered C lines of each kernel")
    ap.add_argument("--c", action="store_true",
                    help="print the rendered C instead of the IR")
    args = ap.parse_args(argv)

    graph = passes.optimize(NETS[args.net]())
    target = graph
    if args.int8:
        from repro.core import quantize
        from repro.data.pipeline import camera_frame_batch
        calib = camera_frame_batch(16, graph.input_shape, seed=0)
        target = quantize.quantize(graph, np.asarray(calib),
                                   method="percentile",
                                   per_channel=args.per_channel)
    schedule = make_schedule(graph, fusion=not args.no_fusion,
                             nstages=args.stages)
    _, program = codegen.lower(target, CodegenOptions(simd=args.simd),
                               schedule=schedule)
    if args.c:
        sys.stdout.write(render(program))
    else:
        print(f"# schedule: {schedule.describe()}")
        print(format_program(program, bodies=args.bodies))
    return 0


if __name__ == "__main__":
    sys.exit(main())
