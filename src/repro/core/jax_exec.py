"""Pure-JAX executor for :class:`repro.core.graph.CNNGraph`.

Serves two roles:
  1. the numerical *oracle* the generated C is validated against, and
  2. the **XLA baseline** for the paper's speed-up tables — the paper's
     main comparison is TensorFlow XLA; ``jax.jit`` is the same compiler
     stack, so ``jit(forward)`` is the modern equivalent of the tfcompile
     object file.

Evaluation is a topological walk keyed by layer name: each layer reads
its producers from the value environment, so branching DAGs (residual
Adds, Concats) run through the same path as sequential nets — and the
``vmap`` batch oracle and the Pallas kernel path inherit DAG support for
free.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .graph import (
    Add,
    AvgPool,
    BatchNorm,
    CNNGraph,
    Concat,
    Conv2D,
    Dense,
    DepthwiseConv2D,
    Dropout,
    Flatten,
    GlobalAvgPool,
    Input,
    LeakyReLU,
    MaxPool,
    ReLU,
    Softmax,
    pool_window_counts,
)

_DIMS = ("NHWC", "HWIO", "NHWC")


def _activation(x: jnp.ndarray, kind: Optional[str], alpha: float) -> jnp.ndarray:
    if kind is None:
        return x
    if kind == "relu":
        return jnp.maximum(x, 0.0)
    if kind == "leaky_relu":
        # branch-free select — the paper's P2 (conditional move) principle
        return jnp.where(x > 0, x, alpha * x)
    if kind == "softmax":
        return jax.nn.softmax(x, axis=-1)
    raise ValueError(f"unknown activation {kind!r}")


def _pool(x: jnp.ndarray, size, strides, op, init,
          pads=(0, 0, 0, 0)) -> jnp.ndarray:
    kh, kw = size
    sh, sw = strides
    pt, pb, pl, pr = pads
    return jax.lax.reduce_window(
        x, init, op,
        window_dimensions=(1, kh, kw, 1),
        window_strides=(1, sh, sw, 1),
        padding=((0, 0), (pt, pb), (pl, pr), (0, 0)),
    )


def _apply(layer, ins: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """One batched-NHWC layer application; ``ins`` are the producer
    outputs in edge order."""
    x = ins[0] if ins else None
    if isinstance(layer, Conv2D):
        pt, pb, pl, pr = layer.pad_amounts(x.shape[1:])
        y = jax.lax.conv_general_dilated(
            x, jnp.asarray(layer.weights),
            window_strides=layer.strides,
            padding=((pt, pb), (pl, pr)),
            dimension_numbers=_DIMS,
        ) + jnp.asarray(layer.bias)
        return _activation(y, layer.activation, layer.alpha)
    if isinstance(layer, DepthwiseConv2D):
        pt, pb, pl, pr = layer.pad_amounts(x.shape[1:])
        kh, kw = layer.kh, layer.kw
        # HWCM -> HWIO with I=1, O=c*mult (group-major, matches XLA)
        w = jnp.asarray(layer.weights).reshape(kh, kw, 1, layer.c_out)
        y = jax.lax.conv_general_dilated(
            x, w,
            window_strides=layer.strides,
            padding=((pt, pb), (pl, pr)),
            dimension_numbers=_DIMS,
            feature_group_count=layer.c_in,
        ) + jnp.asarray(layer.bias)
        return _activation(y, layer.activation, layer.alpha)
    if isinstance(layer, Dense):
        y = x.reshape(x.shape[0], -1) @ jnp.asarray(layer.weights)
        y = y + jnp.asarray(layer.bias)
        y = _activation(y, layer.activation, layer.alpha)
        return y.reshape(y.shape[0], 1, 1, -1)
    if isinstance(layer, MaxPool):
        pads = layer.pad_amounts(x.shape[1:])
        return _pool(x, layer.size, layer.strides, jax.lax.max, -jnp.inf,
                     pads)
    if isinstance(layer, AvgPool):
        pads = layer.pad_amounts(x.shape[1:])
        s = _pool(x, layer.size, layer.strides, jax.lax.add, 0.0, pads)
        counts = pool_window_counts(x.shape[1:], layer.size, layer.strides,
                                    pads)
        return s / jnp.asarray(counts[None, :, :, None], jnp.float32)
    if isinstance(layer, GlobalAvgPool):
        return jnp.mean(x, axis=(1, 2), keepdims=True)
    if isinstance(layer, Add):
        y = ins[0]
        for other in ins[1:]:
            y = y + other
        return _activation(y, layer.activation, layer.alpha)
    if isinstance(layer, Concat):
        return jnp.concatenate(list(ins), axis=-1)
    if isinstance(layer, ReLU):
        return jnp.maximum(x, 0.0)
    if isinstance(layer, LeakyReLU):
        return jnp.where(x > 0, x, layer.alpha * x)
    if isinstance(layer, Softmax):
        return jax.nn.softmax(x, axis=-1)
    if isinstance(layer, BatchNorm):
        scale, shift = layer.scale_shift()
        return x * jnp.asarray(scale) + jnp.asarray(shift)
    if isinstance(layer, Dropout):
        return x  # identity at inference
    if isinstance(layer, Flatten):
        return x.reshape(x.shape[0], 1, 1, -1)
    raise TypeError(f"unhandled layer {type(layer).__name__}")  # pragma: no cover


def forward(graph: CNNGraph, x: jnp.ndarray) -> jnp.ndarray:
    """Run the graph on a batched NHWC input ``x`` (topo-order walk)."""
    assert x.ndim == 4, "expected NHWC batch"
    vals: Dict[str, jnp.ndarray] = {}
    for layer in graph.layers:
        if isinstance(layer, Input):
            assert x.shape[1:] == tuple(layer.shape), (
                f"input shape {x.shape[1:]} != {layer.shape}"
            )
            vals[layer.name] = x
        else:
            vals[layer.name] = _apply(
                layer, [vals[n] for n in layer.inputs])
    return vals[graph.sink.name]


def make_jit_forward(graph: CNNGraph):
    """Compile the graph with XLA — weights are baked as constants
    (paper P3: the trained model is fully known at compile time)."""

    @jax.jit
    def f(x):
        return forward(graph, x)

    return f


def make_vmap_forward(graph: CNNGraph):
    """Batched oracle: ``vmap`` of the single-image forward, jitted.

    The serving-side counterpart of the generated C batch entry point —
    one trace of the per-image program mapped over the batch axis."""

    def single(xi):
        return forward(graph, xi[None])[0]

    return jax.jit(jax.vmap(single))


def forward_pallas(graph: CNNGraph, x: jnp.ndarray) -> jnp.ndarray:
    """Run the CNN through the Pallas TPU kernels (conv2d fused with
    bias+activation, maxpool) — the TPU-native deployment path of the
    generated-C artifact. Interpret-mode on CPU; Mosaic on TPU.
    Expects an optimized graph (BN folded, activations fused); DAG
    merges and the non-kernel layers fall back to jnp ops."""
    from repro.kernels import ops
    assert x.ndim == 4
    vals: Dict[str, jnp.ndarray] = {}
    for layer in graph.layers:
        if isinstance(layer, Input):
            vals[layer.name] = x
            continue
        ins = [vals[n] for n in layer.inputs]
        xi = ins[0]
        if isinstance(layer, Conv2D):
            act = layer.activation if layer.activation != "softmax" else None
            y = ops.conv2d(xi, jnp.asarray(layer.weights),
                           jnp.asarray(layer.bias), strides=layer.strides,
                           padding=layer.padding, act=act,
                           alpha=layer.alpha)
            if layer.activation == "softmax":
                y = jax.nn.softmax(y, axis=-1)
        elif isinstance(layer, MaxPool) and layer.padding == "valid":
            y = ops.maxpool2d(xi, size=layer.size, strides=layer.strides)
        elif isinstance(layer, (Dropout, BatchNorm, Dense, Flatten)):
            raise NotImplementedError(
                f"run passes.optimize first ({type(layer).__name__})")
        else:
            y = _apply(layer, ins)
        vals[layer.name] = y
    return vals[graph.sink.name]


def forward_quantized(qg, x: jnp.ndarray) -> jnp.ndarray:
    """Int8 reference forward — bit-faithful to the generated C.

    Every intermediate tensor is an int8 code (held as int32 here; the
    values are clipped to [-128, 127]), accumulation is exact int32,
    and requantization is ``floor(float32(acc) * M + 0.5) + zp`` — the
    identical IEEE-754 single-precision op sequence the C emits, so the
    integer path agrees with the compiled net *exactly*, not just
    within tolerance.  Input is float32 NHWC; output is the dequantized
    float32 result (softmax, when fused on the sink, runs in float).

    ``qg`` is a :class:`repro.core.quantize.QuantizedGraph`.
    """
    g = qg.graph
    assert x.ndim == 4, "expected NHWC batch"
    sink = g.sink
    smap = g.shape_map()
    half = jnp.float32(0.5)

    def affine_out(layer, acc, is_sink: bool):
        """Requantize an int32 accumulator of a weighted layer (or
        dequantize it, on the sink) — float32 multiplier path."""
        act = layer.activation
        if is_sink:
            t = acc.astype(jnp.float32) * jnp.asarray(
                qg.dequant_scales(layer))
            if act == "relu":
                t = jnp.where(t > 0, t, jnp.float32(0.0))
            elif act == "leaky_relu":
                t = jnp.where(t > 0, t, jnp.float32(layer.alpha) * t)
            elif act == "softmax":
                t = jax.nn.softmax(t, axis=-1)
            return t
        t = acc.astype(jnp.float32) * jnp.asarray(qg.requant_scales(layer))
        if act == "relu":
            t = jnp.where(t > 0, t, jnp.float32(0.0))
        elif act == "leaky_relu":
            t = jnp.where(t > 0, t, jnp.float32(layer.alpha) * t)
        cq = qg.channel_qp(layer.name)  # per-channel output zps, or None
        zp = (jnp.asarray(cq.zero_point, jnp.int32) if cq is not None
              else qg.out_qp(layer).zero_point)
        q = jnp.floor(t + half).astype(jnp.int32) + zp
        return jnp.clip(q, -128, 127)

    def requant_codes(layer, t):
        """float32 value (already in s_out units) -> int8 codes."""
        q = jnp.floor(t + half).astype(jnp.int32) \
            + qg.out_qp(layer).zero_point
        return jnp.clip(q, -128, 127)

    vals: Dict[str, jnp.ndarray] = {}
    for layer in g.layers:
        name = layer.name
        is_sink = layer is sink
        if isinstance(layer, Input):
            qp = qg.acts[name]
            t = x.astype(jnp.float32) * qp.inv_scale
            q = jnp.floor(t + half).astype(jnp.int32) + qp.zero_point
            vals[name] = jnp.clip(q, -128, 127)
            continue
        ins = [vals[n] for n in layer.inputs]
        qi = ins[0]
        in_shape = smap[layer.inputs[0]]
        if isinstance(layer, (Conv2D, DepthwiseConv2D)):
            lq = qg.weights[name]
            cin = qg.in_channel_qp(layer)
            zp_in = (jnp.asarray(cin.zero_point, jnp.int32)
                     if cin is not None  # eligibility forbids padding
                     else qg.in_qp(layer).zero_point)
            pt, pb, pl, pr = layer.pad_amounts(in_shape)
            xin = qi - zp_in  # zero-padded by conv == C's zp-code fill
            wq = jnp.asarray(lq.w_q, jnp.int32)
            if isinstance(layer, DepthwiseConv2D):
                wq = wq.reshape(layer.kh, layer.kw, 1, layer.c_out)
                acc = jax.lax.conv_general_dilated(
                    xin, wq, layer.strides, ((pt, pb), (pl, pr)),
                    dimension_numbers=_DIMS,
                    feature_group_count=layer.c_in)
            else:
                acc = jax.lax.conv_general_dilated(
                    xin, wq, layer.strides, ((pt, pb), (pl, pr)),
                    dimension_numbers=_DIMS)
            acc = acc + jnp.asarray(lq.b_q, jnp.int32)
            vals[name] = affine_out(layer, acc, is_sink)
        elif isinstance(layer, Dense):
            lq = qg.weights[name]
            cin = qg.in_channel_qp(layer)
            zp_in = (jnp.asarray(cin.zero_point, jnp.int32)
                     if cin is not None  # subtract over channels first,
                     else qg.in_qp(layer).zero_point)  # then flatten
            flat = (qi - zp_in).reshape(qi.shape[0], -1)
            acc = flat @ jnp.asarray(lq.w_q, jnp.int32) \
                + jnp.asarray(lq.b_q, jnp.int32)
            vals[name] = affine_out(
                layer, acc.reshape(acc.shape[0], 1, 1, -1), is_sink)
        elif isinstance(layer, MaxPool):
            # same qparams in/out (forced at calibration): pure int8 max;
            # the -128 init/pad value never wins (>=1 valid tap/window)
            pads = layer.pad_amounts(in_shape)
            vals[name] = _pool(qi, layer.size, layer.strides, jax.lax.max,
                               jnp.int32(-128), pads)
        elif isinstance(layer, AvgPool):
            zp_in = qg.in_qp(layer).zero_point
            pads = layer.pad_amounts(in_shape)
            acc = _pool(qi - zp_in, layer.size, layer.strides, jax.lax.add,
                        jnp.int32(0), pads)
            minv = qg.pool_scales(layer, in_shape)  # (oh, ow) float32
            t = acc.astype(jnp.float32) * jnp.asarray(minv)[None, :, :, None]
            vals[name] = requant_codes(layer, t)
        elif isinstance(layer, GlobalAvgPool):
            zp_in = qg.in_qp(layer).zero_point
            acc = jnp.sum(qi - zp_in, axis=(1, 2), keepdims=True,
                          dtype=jnp.int32)
            t = acc.astype(jnp.float32) * qg.pool_scales(layer, in_shape)
            vals[name] = requant_codes(layer, t)
        elif isinstance(layer, Add):
            t = (ins[0] - qg.in_qp(layer, 0).zero_point).astype(
                jnp.float32) * qg.rescale(layer, 0)
            for i in range(1, len(ins)):
                t = t + (ins[i] - qg.in_qp(layer, i).zero_point).astype(
                    jnp.float32) * qg.rescale(layer, i)
            if layer.activation == "relu":
                t = jnp.where(t > 0, t, jnp.float32(0.0))
            elif layer.activation == "leaky_relu":
                t = jnp.where(t > 0, t, jnp.float32(layer.alpha) * t)
            vals[name] = requant_codes(layer, t)
        elif isinstance(layer, Concat):
            parts = []
            for i, q in enumerate(ins):
                t = (q - qg.in_qp(layer, i).zero_point).astype(
                    jnp.float32) * qg.rescale(layer, i)
                parts.append(requant_codes(layer, t))
            vals[name] = jnp.concatenate(parts, axis=-1)
        elif isinstance(layer, ReLU):
            t = (qi - qg.in_qp(layer).zero_point).astype(
                jnp.float32) * qg.rescale(layer)
            t = jnp.where(t > 0, t, jnp.float32(0.0))
            vals[name] = requant_codes(layer, t)
        elif isinstance(layer, LeakyReLU):
            t = (qi - qg.in_qp(layer).zero_point).astype(
                jnp.float32) * qg.rescale(layer)
            t = jnp.where(t > 0, t, jnp.float32(layer.alpha) * t)
            vals[name] = requant_codes(layer, t)
        elif isinstance(layer, Softmax):
            assert is_sink, "standalone Softmax only supported as sink"
            qp = qg.in_qp(layer)
            deq = (qi - qp.zero_point).astype(jnp.float32) \
                * jnp.float32(qp.scale)
            vals[name] = jax.nn.softmax(deq, axis=-1)
        elif isinstance(layer, (Dropout, Flatten)):
            vals[name] = qi if isinstance(layer, Dropout) \
                else qi.reshape(qi.shape[0], 1, 1, -1)
        else:
            raise TypeError(
                f"forward_quantized: unhandled layer {type(layer).__name__}")
    return vals[sink.name]


def make_jit_forward_quantized(qg):
    """XLA-compiled int8 reference (the quantized parity oracle)."""

    @jax.jit
    def f(x):
        return forward_quantized(qg, x)

    return f


def extract_params(graph: CNNGraph) -> dict:
    """Trainable weights as a pytree keyed by layer name."""
    out = {}
    for layer in graph.layers:
        if isinstance(layer, (Conv2D, DepthwiseConv2D, Dense)):
            out[layer.name] = {"w": jnp.asarray(layer.weights),
                               "b": jnp.asarray(layer.bias)}
    return out


def insert_params(graph: CNNGraph, params: dict) -> CNNGraph:
    """Write trained weights back into a copy of the graph — the
    'trained Keras model' NNCG consumes, produced by our own trainer."""
    g = graph.copy()
    for layer in g.layers:
        if layer.name in params:
            layer.weights = np.asarray(params[layer.name]["w"], np.float32)
            layer.bias = np.asarray(params[layer.name]["b"], np.float32)
    return g


def forward_with_params(graph: CNNGraph, params: dict,
                        x: jnp.ndarray) -> jnp.ndarray:
    """Differentiable forward: like :func:`forward` but weights come from
    the ``params`` pytree (training path)."""
    import dataclasses as _dc
    layers = []
    for layer in graph.layers:
        if layer.name in params:
            layer = _dc.replace(layer, weights=params[layer.name]["w"],
                                bias=params[layer.name]["b"],
                                inputs=list(layer.inputs))
        layers.append(layer)
    return forward(CNNGraph(layers), x)


def predict(graph: CNNGraph, x: np.ndarray) -> np.ndarray:
    squeeze = x.ndim == 3
    if squeeze:
        x = x[None]
    y = make_jit_forward(graph)(jnp.asarray(x, dtype=jnp.float32))
    y = np.asarray(y)
    return y[0] if squeeze else y
