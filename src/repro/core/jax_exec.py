"""Pure-JAX executor for :class:`repro.core.graph.CNNGraph`.

Serves two roles:
  1. the numerical *oracle* the generated C is validated against, and
  2. the **XLA baseline** for the paper's speed-up tables — the paper's
     main comparison is TensorFlow XLA; ``jax.jit`` is the same compiler
     stack, so ``jit(forward)`` is the modern equivalent of the tfcompile
     object file.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .graph import (
    BatchNorm,
    CNNGraph,
    Conv2D,
    Dense,
    Dropout,
    Flatten,
    Input,
    LeakyReLU,
    MaxPool,
    ReLU,
    Softmax,
)

_DIMS = ("NHWC", "HWIO", "NHWC")


def _activation(x: jnp.ndarray, kind: Optional[str], alpha: float) -> jnp.ndarray:
    if kind is None:
        return x
    if kind == "relu":
        return jnp.maximum(x, 0.0)
    if kind == "leaky_relu":
        # branch-free select — the paper's P2 (conditional move) principle
        return jnp.where(x > 0, x, alpha * x)
    if kind == "softmax":
        return jax.nn.softmax(x, axis=-1)
    raise ValueError(f"unknown activation {kind!r}")


def forward(graph: CNNGraph, x: jnp.ndarray) -> jnp.ndarray:
    """Run the graph on a batched NHWC input ``x``."""
    assert x.ndim == 4, "expected NHWC batch"
    for layer in graph.layers:
        if isinstance(layer, Input):
            assert x.shape[1:] == tuple(layer.shape), (
                f"input shape {x.shape[1:]} != {layer.shape}"
            )
        elif isinstance(layer, Conv2D):
            pt, pb, pl, pr = layer.pad_amounts(x.shape[1:])
            x = jax.lax.conv_general_dilated(
                x,
                jnp.asarray(layer.weights),
                window_strides=layer.strides,
                padding=((pt, pb), (pl, pr)),
                dimension_numbers=_DIMS,
            )
            x = x + jnp.asarray(layer.bias)
            x = _activation(x, layer.activation, layer.alpha)
        elif isinstance(layer, Dense):
            x = x.reshape(x.shape[0], -1) @ jnp.asarray(layer.weights)
            x = x + jnp.asarray(layer.bias)
            x = _activation(x, layer.activation, layer.alpha)
            x = x.reshape(x.shape[0], 1, 1, -1)
        elif isinstance(layer, MaxPool):
            kh, kw = layer.size
            sh, sw = layer.strides
            x = jax.lax.reduce_window(
                x,
                -jnp.inf,
                jax.lax.max,
                window_dimensions=(1, kh, kw, 1),
                window_strides=(1, sh, sw, 1),
                padding="VALID",
            )
        elif isinstance(layer, ReLU):
            x = jnp.maximum(x, 0.0)
        elif isinstance(layer, LeakyReLU):
            x = jnp.where(x > 0, x, layer.alpha * x)
        elif isinstance(layer, Softmax):
            x = jax.nn.softmax(x, axis=-1)
        elif isinstance(layer, BatchNorm):
            scale, shift = layer.scale_shift()
            x = x * jnp.asarray(scale) + jnp.asarray(shift)
        elif isinstance(layer, Dropout):
            pass  # identity at inference
        elif isinstance(layer, Flatten):
            x = x.reshape(x.shape[0], 1, 1, -1)
        else:  # pragma: no cover
            raise TypeError(f"unhandled layer {type(layer).__name__}")
    return x


def make_jit_forward(graph: CNNGraph):
    """Compile the graph with XLA — weights are baked as constants
    (paper P3: the trained model is fully known at compile time)."""

    @jax.jit
    def f(x):
        return forward(graph, x)

    return f


def make_vmap_forward(graph: CNNGraph):
    """Batched oracle: ``vmap`` of the single-image forward, jitted.

    The serving-side counterpart of the generated C batch entry point —
    one trace of the per-image program mapped over the batch axis."""

    def single(xi):
        return forward(graph, xi[None])[0]

    return jax.jit(jax.vmap(single))


def forward_pallas(graph: CNNGraph, x: jnp.ndarray) -> jnp.ndarray:
    """Run the CNN through the Pallas TPU kernels (conv2d fused with
    bias+activation, maxpool) — the TPU-native deployment path of the
    generated-C artifact. Interpret-mode on CPU; Mosaic on TPU.
    Expects an optimized graph (BN folded, activations fused)."""
    from repro.kernels import ops
    assert x.ndim == 4
    for layer in graph.layers:
        if isinstance(layer, Input):
            continue
        if isinstance(layer, Conv2D):
            act = layer.activation if layer.activation != "softmax" else None
            x = ops.conv2d(x, jnp.asarray(layer.weights),
                           jnp.asarray(layer.bias), strides=layer.strides,
                           padding=layer.padding, act=act,
                           alpha=layer.alpha)
            if layer.activation == "softmax":
                x = jax.nn.softmax(x, axis=-1)
        elif isinstance(layer, MaxPool):
            x = ops.maxpool2d(x, size=layer.size, strides=layer.strides)
        elif isinstance(layer, ReLU):
            x = jnp.maximum(x, 0.0)
        elif isinstance(layer, LeakyReLU):
            x = jnp.where(x > 0, x, layer.alpha * x)
        elif isinstance(layer, Softmax):
            x = jax.nn.softmax(x, axis=-1)
        elif isinstance(layer, (Dropout, BatchNorm, Dense, Flatten)):
            raise NotImplementedError(
                f"run passes.optimize first ({type(layer).__name__})")
    return x


def extract_params(graph: CNNGraph) -> dict:
    """Trainable weights as a pytree keyed by layer name."""
    out = {}
    for layer in graph.layers:
        if isinstance(layer, (Conv2D, Dense)):
            out[layer.name] = {"w": jnp.asarray(layer.weights),
                               "b": jnp.asarray(layer.bias)}
    return out


def insert_params(graph: CNNGraph, params: dict) -> CNNGraph:
    """Write trained weights back into a copy of the graph — the
    'trained Keras model' NNCG consumes, produced by our own trainer."""
    g = graph.copy()
    for layer in g.layers:
        if layer.name in params:
            layer.weights = np.asarray(params[layer.name]["w"], np.float32)
            layer.bias = np.asarray(params[layer.name]["b"], np.float32)
    return g


def forward_with_params(graph: CNNGraph, params: dict,
                        x: jnp.ndarray) -> jnp.ndarray:
    """Differentiable forward: like :func:`forward` but weights come from
    the ``params`` pytree (training path)."""
    import dataclasses as _dc
    layers = []
    for layer in graph.layers:
        if layer.name in params:
            layer = _dc.replace(layer, weights=params[layer.name]["w"],
                                bias=params[layer.name]["b"])
        layers.append(layer)
    return forward(CNNGraph(layers), x)


def predict(graph: CNNGraph, x: np.ndarray) -> np.ndarray:
    squeeze = x.ndim == 3
    if squeeze:
        x = x[None]
    y = make_jit_forward(graph)(jnp.asarray(x, dtype=jnp.float32))
    y = np.asarray(y)
    return y[0] if squeeze else y
