"""Graph rewrite passes (the NNCG optimization pipeline).

These are the paper's compile-time rewrites, applied before code
generation:

* ``fold_batchnorm``  — paper §II-B.4: bn(conv(x)) = Σ x·(w/σ) − μ/σ,
  generalized to learnable γ/β.
* ``remove_dropout``  — dropout is identity at inference.
* ``fuse_activations`` — standalone ReLU/LeakyReLU/Softmax layers are
  folded into the preceding Conv2D/Dense so one loop nest computes both
  (enables the P2 ternary emission in the same code line).
* ``align_channels`` — paper P4: pad conv output channels to a SIMD
  multiple (4 for SSSE3, 128 for TPU lanes) with zero filters; downstream
  layers are widened consistently so numerics are unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .graph import (
    BatchNorm,
    CNNGraph,
    Conv2D,
    Dense,
    Dropout,
    Layer,
    LeakyReLU,
    MaxPool,
    ReLU,
    Softmax,
)


def fold_batchnorm(graph: CNNGraph) -> CNNGraph:
    """Fold each BatchNorm into the closest preceding Conv2D.

    Layers between the conv and the BN must be channel-preserving and
    *linear in scale* for the fold to be exact; in the paper's nets BN
    immediately follows the conv, which is the case we fold. A BN with no
    foldable conv is kept (the executors handle it directly).
    """
    layers = [dataclasses.replace(l) for l in graph.layers]
    out: List[Layer] = []
    for layer in layers:
        if isinstance(layer, BatchNorm) and out and isinstance(out[-1], Conv2D) \
                and out[-1].activation is None:
            conv = out[-1]
            scale, shift = layer.scale_shift()
            conv.weights = (conv.weights * scale[None, None, None, :]).astype(np.float32)
            conv.bias = (conv.bias * scale + shift).astype(np.float32)
        else:
            out.append(layer)
    return graph.replace(out)


def remove_dropout(graph: CNNGraph) -> CNNGraph:
    return graph.replace([l for l in graph.layers if not isinstance(l, Dropout)])


def fuse_activations(graph: CNNGraph) -> CNNGraph:
    layers = [dataclasses.replace(l) for l in graph.layers]
    out: List[Layer] = []
    for layer in layers:
        prev = out[-1] if out else None
        fusible = isinstance(prev, (Conv2D, Dense)) and prev.activation is None
        if fusible and isinstance(layer, ReLU):
            prev.activation = "relu"
        elif fusible and isinstance(layer, LeakyReLU):
            prev.activation = "leaky_relu"
            prev.alpha = layer.alpha
        elif fusible and isinstance(layer, Softmax):
            prev.activation = "softmax"
        else:
            out.append(layer)
    return graph.replace(out)


def align_channels(graph: CNNGraph, multiple: int = 4) -> CNNGraph:
    """Pad every Conv2D's ``c_out`` (except the last conv) to a multiple.

    Zero filters produce zero channels; ReLU/LeakyReLU/MaxPool map zero to
    zero, and the next conv's weights gain zero-weight input channels, so
    the visible outputs are bit-identical. Softmax is *not* scale-free, so
    the conv feeding a softmax (or the network output) is never padded.
    """
    layers = [dataclasses.replace(l) for l in graph.layers]
    conv_idx = [i for i, l in enumerate(layers) if isinstance(l, Conv2D)]
    for pos, i in enumerate(conv_idx):
        conv = layers[i]
        pad = (-conv.c_out) % multiple
        if pad == 0:
            continue
        is_last_conv = pos == len(conv_idx) - 1
        # anything non-channel-preserving (Dense/Flatten/Softmax) after this
        # conv and before the next conv blocks padding
        nxt = conv_idx[pos + 1] if not is_last_conv else len(layers)
        between_ok = all(
            isinstance(layers[j], (ReLU, LeakyReLU, MaxPool, BatchNorm, Dropout))
            for j in range(i + 1, nxt)
        )
        if is_last_conv or not between_ok:
            continue
        conv.weights = np.pad(conv.weights, ((0, 0),) * 3 + ((0, pad),)).astype(np.float32)
        conv.bias = np.pad(conv.bias, (0, pad)).astype(np.float32)
        for j in range(i + 1, nxt):
            bn = layers[j]
            if isinstance(bn, BatchNorm):
                bn.mean = np.pad(bn.mean, (0, pad))
                bn.var = np.pad(bn.var, (0, pad), constant_values=1.0)
                bn.gamma = np.pad(bn.gamma, (0, pad))
                bn.beta = np.pad(bn.beta, (0, pad))
        nxt_conv = layers[conv_idx[pos + 1]]
        nxt_conv.weights = np.pad(
            nxt_conv.weights, ((0, 0), (0, 0), (0, pad), (0, 0))
        ).astype(np.float32)
    return graph.replace(layers)


def optimize(graph: CNNGraph, simd_multiple: int = 4) -> CNNGraph:
    """The full NNCG pipeline in paper order."""
    g = remove_dropout(graph)
    g = fold_batchnorm(g)
    g = fuse_activations(g)
    if simd_multiple > 1:
        g = align_channels(g, simd_multiple)
    return g
