"""Graph rewrite passes (the NNCG optimization pipeline).

These are the paper's compile-time rewrites, applied before code
generation.  All of them walk the DAG **edges** (``layer.inputs`` /
consumer maps), never list adjacency, so branching graphs (residual
Adds, Concats) are rewritten correctly:

* ``fold_batchnorm``  — paper §II-B.4: bn(conv(x)) = Σ x·(w/σ) − μ/σ,
  generalized to learnable γ/β.
* ``remove_dropout``  — dropout is identity at inference.
* ``fuse_activations`` — standalone ReLU/LeakyReLU/Softmax layers are
  folded into the sole producing Conv2D/DepthwiseConv2D/Dense/Add so one
  loop nest computes both (enables the P2 ternary emission in the same
  code line).
* ``reorder_for_fusion`` — emission-order canonicalization: a
  sole-consumer Conv/DW/Dense feeding a residual Add is moved to just
  before the Add so ``schedule.fusable_adds`` can fold the Add into its
  output loop (pure permutation — numerics unchanged).  Pool/Concat
  fusion needs no such help: those consumers read only their producer,
  so eligibility is position-independent.
* ``align_channels`` — paper P4: pad conv output channels to a SIMD
  multiple (4 for SSSE3, 128 for TPU lanes) with zero filters; downstream
  layers are widened consistently so numerics are unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from .graph import (
    Add,
    AvgPool,
    BatchNorm,
    CNNGraph,
    Conv2D,
    Dense,
    DepthwiseConv2D,
    Dropout,
    Layer,
    LeakyReLU,
    MaxPool,
    ReLU,
    Softmax,
)


def _copy_layers(graph: CNNGraph) -> List[Layer]:
    return [dataclasses.replace(l, inputs=list(l.inputs))
            for l in graph.layers]


def _consumer_map(layers: List[Layer]) -> Dict[str, List[Layer]]:
    out: Dict[str, List[Layer]] = {l.name: [] for l in layers}
    for l in layers:
        for src in l.inputs:
            out[src].append(l)
    return out


def _splice_out(layers: List[Layer], victim: Layer) -> List[Layer]:
    """Remove a single-input layer; its consumers read its producer."""
    (src,) = victim.inputs
    kept = []
    for l in layers:
        if l is victim:
            continue
        l.inputs = [src if n == victim.name else n for n in l.inputs]
        kept.append(l)
    return kept


def remove_dropout(graph: CNNGraph) -> CNNGraph:
    layers = _copy_layers(graph)
    for victim in [l for l in layers if isinstance(l, Dropout)]:
        layers = _splice_out(layers, victim)
    return graph.replace(layers)


def fold_batchnorm(graph: CNNGraph) -> CNNGraph:
    """Fold each BatchNorm into its producing Conv2D.

    The fold is applied when the BN's sole producer is a Conv2D with no
    fused activation **and** that conv feeds nothing but the BN — if the
    conv output also rode a skip edge, folding would silently rescale the
    other branch.  A BN with no foldable conv is kept (the executors
    handle it directly).
    """
    layers = _copy_layers(graph)
    for bn in [l for l in layers if isinstance(l, BatchNorm)]:
        (src,) = bn.inputs
        conv = next(l for l in layers if l.name == src)
        cons = _consumer_map(layers)
        if not (isinstance(conv, Conv2D) and conv.activation is None
                and cons[conv.name] == [bn]):
            continue
        scale, shift = bn.scale_shift()
        conv.weights = (conv.weights * scale[None, None, None, :]).astype(np.float32)
        conv.bias = (conv.bias * scale + shift).astype(np.float32)
        layers = _splice_out(layers, bn)
    return graph.replace(layers)


def fuse_activations(graph: CNNGraph) -> CNNGraph:
    """Fold standalone activations into their sole producer.

    Requires the producer to feed *only* the activation layer: on a
    branching graph, fusing a ReLU into a conv whose raw output also
    feeds a skip connection would change the skip branch."""
    layers = _copy_layers(graph)
    for act in [l for l in layers
                if isinstance(l, (ReLU, LeakyReLU, Softmax))]:
        (src,) = act.inputs
        prev = next(l for l in layers if l.name == src)
        cons = _consumer_map(layers)
        fusible = (isinstance(prev, (Conv2D, DepthwiseConv2D, Dense, Add))
                   and prev.activation is None
                   and cons[prev.name] == [act])
        if isinstance(prev, Add) and isinstance(act, Softmax):
            fusible = False  # Add carries relu-family fusions only
        if not fusible:
            continue
        if isinstance(act, ReLU):
            prev.activation = "relu"
        elif isinstance(act, LeakyReLU):
            prev.activation = "leaky_relu"
            prev.alpha = act.alpha
        else:
            prev.activation = "softmax"
        layers = _splice_out(layers, act)
    return graph.replace(layers)


# Add activations the fused epilogue supports (must match
# repro.core.schedule's predicate — softmax needs the whole channel
# vector after the sum)
_FUSABLE_EPILOGUE_ACTS = (None, "relu", "leaky_relu")


def reorder_for_fusion(graph: CNNGraph) -> CNNGraph:
    """Emission-order canonicalization for epilogue fusion.

    ``schedule.fusable_adds`` folds an Add into a producer only when
    that producer is the *topologically last* of the Add's inputs
    (every other operand must already be in memory when the producer's
    loop runs).  When an Add's last input isn't fusable but another
    input is a sole-consumer Conv2D/DepthwiseConv2D/Dense, moving that
    producer's emission to just before the Add makes it last — a pure
    reorder: edges, weights and numerics are untouched (the float
    left-associated sum follows the Add's *input list* order, not
    emission order), only the layer list is permuted.  Moving is safe
    because the producer's sole consumer is the Add itself, so nothing
    between its old and new position reads it.

    The other fused consumer kinds need no reordering: a fusable
    MaxPool/AvgPool or Concat edge reads *only* its producer, so the
    producer's emission position is irrelevant — ``fusable_pools`` /
    ``fusable_concats`` qualify on sole-consumership alone and this
    pass never has to move anything for them."""
    layers = _copy_layers(graph)
    sink = graph.sink.name
    for add in [l for l in layers if isinstance(l, Add)]:
        if add.name == sink or add.activation not in _FUSABLE_EPILOGUE_ACTS:
            continue
        order = {l.name: i for i, l in enumerate(layers)}
        cons = _consumer_map(layers)

        def fusable(l: Layer) -> bool:
            return (isinstance(l, (Conv2D, DepthwiseConv2D, Dense))
                    and l.activation != "softmax"
                    and cons[l.name] == [add])

        last = layers[order[max(add.inputs, key=lambda n: order[n])]]
        if fusable(last):
            continue  # already in fusable position
        cands = [layers[order[n]] for n in set(add.inputs)
                 if fusable(layers[order[n]])]
        if not cands:
            continue
        # the heaviest candidate: its materialized buffer is the most
        # expensive round-trip to eliminate (any choice is numerically
        # equivalent)
        mv = max(cands, key=lambda l: int(np.prod(np.shape(l.weights))))
        layers.remove(mv)
        layers.insert(layers.index(add), mv)
    return graph.replace(layers)


_CHANNEL_PRESERVING = (ReLU, LeakyReLU, MaxPool, AvgPool, BatchNorm, Dropout)


def _pad_chain(layers: List[Layer], cons: Dict[str, List[Layer]],
               conv: Conv2D):
    """Follow the single-consumer chain of channel-preserving layers from
    ``conv`` to the next Conv2D. Returns (chain, next_conv) or None when
    anything on the way (a branch, a merge, Dense/Softmax/output, a
    depthwise conv whose channel count is semantic) blocks padding."""
    chain: List[Layer] = []
    cur: Layer = conv
    while True:
        nxt_list = cons[cur.name]
        if len(nxt_list) != 1:
            return None
        nxt = nxt_list[0]
        if isinstance(nxt, Conv2D):
            return chain, nxt
        if isinstance(nxt, _CHANNEL_PRESERVING):
            chain.append(nxt)
            cur = nxt
            continue
        return None


def align_channels(graph: CNNGraph, multiple: int = 4) -> CNNGraph:
    """Pad a Conv2D's ``c_out`` to a multiple when the widening is provably
    invisible: zero filters produce zero channels; ReLU/LeakyReLU/pooling
    map zero to zero; the next conv's weights gain zero-weight input
    channels.  Softmax is *not* scale-free and Add/Concat change meaning
    with channel count, so any chain reaching one of those (or the graph
    output) is left alone."""
    layers = _copy_layers(graph)
    for conv in [l for l in layers if isinstance(l, Conv2D)]:
        pad = (-conv.c_out) % multiple
        if pad == 0:
            continue
        hit = _pad_chain(layers, _consumer_map(layers), conv)
        if hit is None:
            continue
        chain, nxt_conv = hit
        conv.weights = np.pad(conv.weights, ((0, 0),) * 3 + ((0, pad),)).astype(np.float32)
        conv.bias = np.pad(conv.bias, (0, pad)).astype(np.float32)
        for bn in chain:
            if isinstance(bn, BatchNorm):
                bn.mean = np.pad(bn.mean, (0, pad))
                bn.var = np.pad(bn.var, (0, pad), constant_values=1.0)
                bn.gamma = np.pad(bn.gamma, (0, pad))
                bn.beta = np.pad(bn.beta, (0, pad))
        nxt_conv.weights = np.pad(
            nxt_conv.weights, ((0, 0), (0, 0), (0, pad), (0, 0))
        ).astype(np.float32)
    return graph.replace(layers)


def optimize(graph: CNNGraph, simd_multiple: int = 4) -> CNNGraph:
    """The full NNCG pipeline in paper order."""
    g = remove_dropout(graph)
    g = fold_batchnorm(g)
    g = fuse_activations(g)
    g = reorder_for_fusion(g)
    if simd_multiple > 1:
        g = align_channels(g, simd_multiple)
    return g
