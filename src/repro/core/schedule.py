"""Graph-level schedule: epilogue fusion + pipeline stage assignment.

The paper specializes code *within* one layer loop; this module decides
how layers are scheduled *across* the graph, one level above the
emitters in ``cgen.py``:

* **Epilogue fusion** — a residual ``Add`` whose last-computed input is
  a ``Conv2D``/``DepthwiseConv2D``/``Dense`` that feeds nothing else can
  be folded into that producer's output loop: at the store site the
  producer's freshly computed value is summed with the already-computed
  other branches (and the Add's activation applied) instead of being
  materialized first.  The producer's output tensor never exists, so its
  arena slot disappears.  Float fusion is *bitwise identical* to the
  unfused graph (same left-associated sum order as the jax oracle);
  int8 fusion is bit-exact (the producer's accumulator is requantized to
  its own int8 code first, exactly as the unfused kernel would store it,
  then dequantized into the Add — no double-rounding shortcut).
* **Stage partition** — the topologically ordered emission units are
  split into contiguous stages balanced by static per-layer cost
  estimates (the same MAC counts the autotuner's variant enumeration
  reasons about).  ``cgen`` emits one C function per stage plus a
  ``<func>_pipeline`` driver; ``runtime.PipelineRunner`` overlaps stages
  of consecutive frames across threads for batch-1 stream throughput.

A :class:`Schedule` is a frozen value object so it can key caches
(tuning records, compiled ``.so`` files) the same way
``SessionConfig``/``CodegenOptions`` do.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from .graph import (
    Add,
    AvgPool,
    BatchNorm,
    CNNGraph,
    Concat,
    Conv2D,
    Dense,
    DepthwiseConv2D,
    Dropout,
    Flatten,
    GlobalAvgPool,
    Input,
    MaxPool,
)

# Add activations the fused epilogue can apply (softmax needs the whole
# channel vector after the sum — never fused into a producer store).
_FUSABLE_ADD_ACTS = (None, "relu", "leaky_relu")

# layers that emit no code of their own (cgen aliases their value to the
# producer's buffer) — they are not pipeline units
_ALIAS_LAYERS = (Dropout, Flatten)


@dataclass(frozen=True)
class Schedule:
    """Fusion decisions + pipeline stage assignment for one graph.

    ``fused_adds`` holds ``(producer_name, add_name)`` pairs: the Add's
    arithmetic runs inside the producer's output loop and the producer's
    tensor is never materialized.  ``stages`` lists the emission units
    (layer names, topological order, fused Adds folded into their
    producer's unit) per pipeline stage; a single-stage schedule is the
    ordinary monolithic function.
    """

    fused_adds: Tuple[Tuple[str, str], ...] = ()
    stages: Tuple[Tuple[str, ...], ...] = field(default=((),))

    @property
    def nstages(self) -> int:
        return len(self.stages)

    @property
    def fused_by_producer(self) -> Dict[str, str]:
        """producer name -> the Add fused into its output loop."""
        return {p: a for p, a in self.fused_adds}

    @property
    def fused_by_add(self) -> Dict[str, str]:
        """fused Add name -> its producer."""
        return {a: p for p, a in self.fused_adds}

    def digest(self) -> str:
        """Short stable hash for cache keys (tuning records, .so names)."""
        blob = repr((self.fused_adds, self.stages)).encode()
        return hashlib.sha256(blob).hexdigest()[:12]

    def describe(self) -> Dict[str, object]:
        return {
            "fused_adds": [list(p) for p in self.fused_adds],
            "nstages": self.nstages,
            "stages": [list(s) for s in self.stages],
            "digest": self.digest(),
        }


def fusable_adds(graph: CNNGraph) -> List[Tuple[str, str]]:
    """All ``(producer, add)`` pairs where the Add can run inside the
    producer's output loop without changing numerics.

    Conditions: the producer is a Conv2D/DepthwiseConv2D/Dense feeding
    *only* this Add (exactly one edge — a doubled ``[p, p]`` input is
    two edges and disqualifies); it is the topologically last of the
    Add's inputs, so every other operand is already in memory when the
    producer's loop runs; its own activation is not softmax (relu /
    leaky_relu are applied to the producer term before the sum, exactly
    as the unfused graph would); the Add's activation is relu-family or
    absent; and the Add is not the graph sink (the quantized sink path
    dequantizes into the float ``out`` buffer — sink Adds take the
    ordinary unfused path so both precisions share one predicate).
    """
    order = {l.name: i for i, l in enumerate(graph.layers)}
    cons = graph.consumers()
    sink = graph.sink.name
    pairs: List[Tuple[str, str]] = []
    for add in graph.layers:
        if not isinstance(add, Add):
            continue
        if add.name == sink:
            continue
        if add.activation not in _FUSABLE_ADD_ACTS:
            continue
        last = max(add.inputs, key=lambda n: order[n])
        p = graph.layer(last)
        if not isinstance(p, (Conv2D, DepthwiseConv2D, Dense)):
            continue
        if p.activation == "softmax":
            continue
        if cons[p.name] != [add]:  # sole consumer, exactly one edge
            continue
        pairs.append((p.name, add.name))
    return pairs


def layer_costs(graph: CNNGraph) -> Dict[str, int]:
    """Static per-layer cost estimate (MACs, or element count for
    memory-bound layers) used to balance pipeline stages."""
    smap = graph.shape_map()
    costs: Dict[str, int] = {}
    for l in graph.layers:
        oh, ow, oc = smap[l.name]
        if isinstance(l, Input) or isinstance(l, _ALIAS_LAYERS):
            costs[l.name] = 0
        elif isinstance(l, Conv2D):
            costs[l.name] = oh * ow * oc * l.kh * l.kw * l.c_in
        elif isinstance(l, DepthwiseConv2D):
            costs[l.name] = oh * ow * oc * l.kh * l.kw
        elif isinstance(l, Dense):
            costs[l.name] = int(l.weights.shape[0]) * int(l.weights.shape[1])
        elif isinstance(l, (MaxPool, AvgPool)):
            costs[l.name] = oh * ow * oc * l.size[0] * l.size[1]
        elif isinstance(l, GlobalAvgPool):
            h, w, c = smap[l.inputs[0]]
            costs[l.name] = h * w * c
        elif isinstance(l, (Add, Concat, BatchNorm)):
            costs[l.name] = oh * ow * oc * max(len(l.inputs), 1)
        else:  # activations, softmax, anything elementwise
            costs[l.name] = oh * ow * oc
    return costs


def emission_units(graph: CNNGraph,
                   fused: Tuple[Tuple[str, str], ...]) -> List[str]:
    """Topologically ordered unit names: every code-emitting layer,
    with fused Adds absorbed into their producer's unit."""
    fused_add_names = {a for _, a in fused}
    return [l.name for l in graph.layers
            if not isinstance(l, Input)
            and not isinstance(l, _ALIAS_LAYERS)
            and l.name not in fused_add_names]


def _partition(costs: List[int], nstages: int) -> List[int]:
    """Contiguous linear partition of ``costs`` into ``nstages`` chunks
    minimizing the maximum chunk sum (classic O(n^2 * S) DP).  Returns
    the chunk *lengths*; every chunk is non-empty."""
    n = len(costs)
    prefix = [0]
    for c in costs:
        prefix.append(prefix[-1] + c)
    inf = float("inf")
    dp = [[inf] * (n + 1) for _ in range(nstages + 1)]
    cut = [[0] * (n + 1) for _ in range(nstages + 1)]
    dp[0][0] = 0.0
    for s in range(1, nstages + 1):
        for i in range(s, n + 1):
            for j in range(s - 1, i):
                cand = max(dp[s - 1][j], prefix[i] - prefix[j])
                if cand < dp[s][i]:
                    dp[s][i] = cand
                    cut[s][i] = j
    lengths: List[int] = []
    i = n
    for s in range(nstages, 0, -1):
        j = cut[s][i]
        lengths.append(i - j)
        i = j
    lengths.reverse()
    return lengths


def _prune_arena_regressions(
        graph: CNNGraph,
        fused: Tuple[Tuple[str, str], ...]) -> Tuple[Tuple[str, str], ...]:
    """Drop fused pairs until the packed arena is no larger than the
    unfused plan's.

    Fusing an Add eliminates its producer's buffer and can only shrink
    the *peak live* set, but the arena packer is first-fit over interval
    interference and first-fit is not monotone: removing a buffer moves
    later buffers to different offsets, which on branchy graphs can
    fragment the packing and *grow* the total.  Rather than weaken the
    "fusion never costs memory" contract, fusion decisions are made
    memory-aware here: greedily drop the pair whose removal shrinks the
    plan most until fused <= unfused (the empty set gives exact
    equality, so this always terminates).  The plan depends on the
    emission style — rolled loops add padding-scratch intervals that
    full unroll handles inline — and on the element width, so the
    invariant is enforced across both uniform unroll styles in float
    and int8 (per-layer mixed-unroll builds sit between the two
    extremes and are not individually checked).
    """
    if not fused:
        return fused
    from . import cgen  # runtime import: cgen imports this module

    plans = [(cgen.CodegenOptions(unroll=u), q)
             for u in (0, None) for q in (False, True)]

    def totals(pairs: Tuple[Tuple[str, str], ...]) -> Tuple[int, ...]:
        sched = Schedule(fused_adds=pairs,
                         stages=(tuple(emission_units(graph, pairs)),))
        return tuple(
            cgen.plan_arena(graph, opts, quantized=q,
                            schedule=sched).total_floats
            for opts, q in plans)

    base = totals(())
    keep = list(fused)

    def excess(pairs: Tuple[Tuple[str, str], ...]) -> int:
        return sum(max(0, t - b) for t, b in zip(totals(pairs), base))

    while keep and excess(tuple(keep)) > 0:
        best = min(range(len(keep)),
                   key=lambda i: excess(tuple(keep[:i] + keep[i + 1:])))
        keep.pop(best)
    return tuple(keep)


def make_schedule(graph: CNNGraph, *, nstages: int = 1,
                  fusion: bool = True) -> Schedule:
    """Build a :class:`Schedule` for ``graph``.

    ``fusion=True`` fuses every eligible Add epilogue whose fusion does
    not grow the packed arena (output is bitwise identical either way;
    see :func:`_prune_arena_regressions` for why packing can regress).
    ``nstages`` > 1 partitions the units into that many balanced
    pipeline stages (clamped to the unit count).
    """
    fused = _prune_arena_regressions(
        graph, tuple(fusable_adds(graph))) if fusion else ()
    units = emission_units(graph, fused)
    if not units:
        return Schedule(fused_adds=fused, stages=((),))
    costs = layer_costs(graph)
    fused_by_p = {p: a for p, a in fused}
    unit_costs = [costs[u] + costs.get(fused_by_p.get(u, ""), 0)
                  for u in units]
    s = max(1, min(int(nstages), len(units)))
    if s == 1:
        return Schedule(fused_adds=fused, stages=(tuple(units),))
    lengths = _partition(unit_costs, s)
    stages: List[Tuple[str, ...]] = []
    i = 0
    for ln in lengths:
        stages.append(tuple(units[i:i + ln]))
        i += ln
    return Schedule(fused_adds=fused, stages=tuple(stages))
