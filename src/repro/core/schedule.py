"""Graph-level schedule: epilogue fusion + pipeline stage assignment.

The paper specializes code *within* one layer loop; this module decides
how layers are scheduled *across* the graph, one level above the
emitters in ``cgen.py``:

* **Epilogue fusion** — a consumer op can be folded into the store site
  of a weighted producer (``Conv2D``/``DepthwiseConv2D``/``Dense``)
  that feeds nothing else, so the producer's output tensor never exists
  and its arena slot disappears.  Three consumer kinds fuse:

  - a residual ``Add`` (the producer is the topologically last input):
    the freshly computed value is summed with the already-computed
    other branches and the Add's activation applied at the store;
  - a ``MaxPool``/``AvgPool`` with window == stride and no padding:
    each producer element lands in exactly one window, so the store
    reduces straight into the pooled output (max via the same ternary
    chain, avg via the same in-order sum plus a finalize divisor pass);
  - a ``Concat`` edge: the producer writes its channel slice of the
    Concat output directly.

  Float fusion is *bitwise identical* to the unfused graph (same float
  op order as the jax oracle); int8 fusion is bit-exact (the producer's
  accumulator is requantized to its own int8 code first, exactly as the
  unfused kernel would store it, then fed to the consumer's reference
  arithmetic — no double-rounding shortcut).
* **Stage partition** — the topologically ordered emission units are
  split into contiguous stages balanced by static per-layer cost
  estimates (the same MAC counts the autotuner's variant enumeration
  reasons about).  ``cgen`` emits one C function per stage plus a
  ``<func>_pipeline`` driver; ``runtime.PipelineRunner`` overlaps stages
  of consecutive frames across threads for batch-1 stream throughput.

A :class:`Schedule` is a frozen value object so it can key caches
(tuning records, compiled ``.so`` files) the same way
``SessionConfig``/``CodegenOptions`` do.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Tuple

from .graph import (
    Add,
    AvgPool,
    BatchNorm,
    CNNGraph,
    Concat,
    Conv2D,
    Dense,
    DepthwiseConv2D,
    Dropout,
    Flatten,
    GlobalAvgPool,
    Input,
    MaxPool,
)

# Add activations the fused epilogue can apply (softmax needs the whole
# channel vector after the sum — never fused into a producer store).
_FUSABLE_ADD_ACTS = (None, "relu", "leaky_relu")

# layers that emit no code of their own (cgen aliases their value to the
# producer's buffer) — they are not pipeline units
_ALIAS_LAYERS = (Dropout, Flatten)


@dataclass(frozen=True)
class Schedule:
    """Fusion decisions + pipeline stage assignment for one graph.

    ``fused_adds`` / ``fused_pools`` / ``fused_concats`` hold
    ``(producer_name, consumer_name)`` pairs: the consumer's arithmetic
    runs inside the producer's output loop and the producer's tensor is
    never materialized.  A fused Add or pool disappears as a layer of
    its own (it is *absorbed* into the producer's emission unit); a
    fused Concat still emits — it copies its remaining unfused edges —
    but the fused producers write their channel slices directly.
    ``stages`` lists the emission units (layer names, topological order,
    absorbed consumers folded into their producer's unit) per pipeline
    stage; a single-stage schedule is the ordinary monolithic function.
    """

    fused_adds: Tuple[Tuple[str, str], ...] = ()
    stages: Tuple[Tuple[str, ...], ...] = field(default=((),))
    fused_pools: Tuple[Tuple[str, str], ...] = ()
    fused_concats: Tuple[Tuple[str, str], ...] = ()

    @property
    def nstages(self) -> int:
        return len(self.stages)

    @property
    def fused_by_producer(self) -> Dict[str, str]:
        """producer name -> the consumer fused into its output loop."""
        out = {p: a for p, a in self.fused_adds}
        out.update({p: pl for p, pl in self.fused_pools})
        out.update({p: c for p, c in self.fused_concats})
        return out

    @property
    def fused_by_add(self) -> Dict[str, str]:
        """fused Add name -> its producer."""
        return {a: p for p, a in self.fused_adds}

    @property
    def fused_by_consumer(self) -> Dict[str, str]:
        """absorbed consumer name -> its producer (Adds and pools only;
        a fused Concat still emits its own unit)."""
        out = {a: p for p, a in self.fused_adds}
        out.update({pl: p for p, pl in self.fused_pools})
        return out

    @property
    def absorbed_consumers(self) -> frozenset:
        """Consumers that emit no unit of their own: fused Adds and
        fused pools.  Fused Concats are *not* absorbed — the Concat
        unit survives to copy any unfused edges."""
        return frozenset(a for _, a in self.fused_adds) | frozenset(
            pl for _, pl in self.fused_pools)

    @property
    def has_fusion(self) -> bool:
        return bool(self.fused_adds or self.fused_pools
                    or self.fused_concats)

    def digest(self) -> str:
        """Short stable hash for cache keys (tuning records, .so names)."""
        blob = repr((self.fused_adds, self.fused_pools,
                     self.fused_concats, self.stages)).encode()
        return hashlib.sha256(blob).hexdigest()[:12]

    def describe(self) -> Dict[str, object]:
        return {
            "fused_adds": [list(p) for p in self.fused_adds],
            "fused_pools": [list(p) for p in self.fused_pools],
            "fused_concats": [list(p) for p in self.fused_concats],
            "nstages": self.nstages,
            "stages": [list(s) for s in self.stages],
            "digest": self.digest(),
        }


def fusable_adds(graph: CNNGraph) -> List[Tuple[str, str]]:
    """All ``(producer, add)`` pairs where the Add can run inside the
    producer's output loop without changing numerics.

    Conditions: the producer is a Conv2D/DepthwiseConv2D/Dense feeding
    *only* this Add (exactly one edge — a doubled ``[p, p]`` input is
    two edges and disqualifies); it is the topologically last of the
    Add's inputs, so every other operand is already in memory when the
    producer's loop runs; its own activation is not softmax (relu /
    leaky_relu are applied to the producer term before the sum, exactly
    as the unfused graph would); the Add's activation is relu-family or
    absent; and the Add is not the graph sink (the quantized sink path
    dequantizes into the float ``out`` buffer — sink Adds take the
    ordinary unfused path so both precisions share one predicate).
    """
    order = {l.name: i for i, l in enumerate(graph.layers)}
    cons = graph.consumers()
    sink = graph.sink.name
    pairs: List[Tuple[str, str]] = []
    for add in graph.layers:
        if not isinstance(add, Add):
            continue
        if add.name == sink:
            continue
        if add.activation not in _FUSABLE_ADD_ACTS:
            continue
        last = max(add.inputs, key=lambda n: order[n])
        p = graph.layer(last)
        if not isinstance(p, (Conv2D, DepthwiseConv2D, Dense)):
            continue
        if p.activation == "softmax":
            continue
        if cons[p.name] != [add]:  # sole consumer, exactly one edge
            continue
        pairs.append((p.name, add.name))
    return pairs


def fusable_pools(graph: CNNGraph) -> List[Tuple[str, str]]:
    """All ``(producer, pool)`` pairs where the MaxPool/AvgPool window
    reduction can run at the producer's store site.

    The mapping from a producer output position to a pool output slot is
    only a pure index transform when window == stride, the pool has no
    padding, and the producer's spatial extent divides evenly by the
    stride (otherwise trailing rows/cols are dropped by the pool and a
    fused store would write out of bounds).  Under those conditions
    every producer element lands in exactly one window, the windows all
    have the full ``kh*kw`` population (so the int8 AvgPool rescale is
    uniform), and the fused reduction applies ops in the same order the
    unfused kernels would — bitwise identical in float, bit-exact in
    int8.  The producer must be a Conv2D/DepthwiseConv2D/Dense feeding
    *only* this pool via a direct edge, non-softmax; the pool must not
    be the graph sink (same sink rule as Add fusion).
    """
    cons = graph.consumers()
    smap = graph.shape_map()
    sink = graph.sink.name
    pairs: List[Tuple[str, str]] = []
    for pool in graph.layers:
        if not isinstance(pool, (MaxPool, AvgPool)):
            continue
        if pool.name == sink:
            continue
        if tuple(pool.size) != tuple(pool.strides):
            continue
        ish = smap[pool.inputs[0]]
        if any(pool.pad_amounts(ish)):
            continue
        h, w, _ = ish
        sh, sw = pool.strides
        if h % sh or w % sw:
            continue
        p = graph.layer(pool.inputs[0])
        if not isinstance(p, (Conv2D, DepthwiseConv2D, Dense)):
            continue
        if p.activation == "softmax":
            continue
        if cons[p.name] != [pool]:  # sole consumer, exactly one edge
            continue
        pairs.append((p.name, pool.name))
    return pairs


def fusable_concats(graph: CNNGraph) -> List[Tuple[str, str]]:
    """All ``(producer, concat)`` pairs where the producer can write its
    channel slice of the Concat output directly.

    Concat fusion is per *edge*: each qualifying producer fuses
    independently and the Concat unit survives to copy whichever edges
    stayed unfused (it disappears entirely only when every edge fused).
    A producer qualifies when it is a Conv2D/DepthwiseConv2D/Dense,
    non-softmax, feeding only this Concat via a direct edge.  A Concat
    with a doubled input (``[p, p]``) is skipped outright: the edge
    position — and hence the channel offset — of ``p`` would be
    ambiguous.  The Concat must not be the graph sink (the quantized
    sink path dequantizes into the float ``out`` buffer).
    """
    cons = graph.consumers()
    sink = graph.sink.name
    pairs: List[Tuple[str, str]] = []
    for cat in graph.layers:
        if not isinstance(cat, Concat):
            continue
        if cat.name == sink:
            continue
        if len(set(cat.inputs)) != len(cat.inputs):
            continue
        for n in cat.inputs:
            p = graph.layer(n)
            if not isinstance(p, (Conv2D, DepthwiseConv2D, Dense)):
                continue
            if p.activation == "softmax":
                continue
            if cons[p.name] != [cat]:  # sole consumer, exactly one edge
                continue
            pairs.append((p.name, cat.name))
    return pairs


def layer_costs(graph: CNNGraph) -> Dict[str, int]:
    """Static per-layer cost estimate (MACs, or element count for
    memory-bound layers) used to balance pipeline stages."""
    smap = graph.shape_map()
    costs: Dict[str, int] = {}
    for l in graph.layers:
        oh, ow, oc = smap[l.name]
        if isinstance(l, Input) or isinstance(l, _ALIAS_LAYERS):
            costs[l.name] = 0
        elif isinstance(l, Conv2D):
            costs[l.name] = oh * ow * oc * l.kh * l.kw * l.c_in
        elif isinstance(l, DepthwiseConv2D):
            costs[l.name] = oh * ow * oc * l.kh * l.kw
        elif isinstance(l, Dense):
            costs[l.name] = int(l.weights.shape[0]) * int(l.weights.shape[1])
        elif isinstance(l, (MaxPool, AvgPool)):
            costs[l.name] = oh * ow * oc * l.size[0] * l.size[1]
        elif isinstance(l, GlobalAvgPool):
            h, w, c = smap[l.inputs[0]]
            costs[l.name] = h * w * c
        elif isinstance(l, (Add, Concat, BatchNorm)):
            costs[l.name] = oh * ow * oc * max(len(l.inputs), 1)
        else:  # activations, softmax, anything elementwise
            costs[l.name] = oh * ow * oc
    return costs


def emission_units(graph: CNNGraph,
                   fused: Tuple[Tuple[str, str], ...],
                   fused_pools: Tuple[Tuple[str, str], ...] = ()) -> List[str]:
    """Topologically ordered unit names: every code-emitting layer,
    with absorbed consumers (fused Adds and pools) folded into their
    producer's unit.  Fused Concats keep their unit — they still copy
    any unfused edges."""
    absorbed = {a for _, a in fused} | {pl for _, pl in fused_pools}
    return [l.name for l in graph.layers
            if not isinstance(l, Input)
            and not isinstance(l, _ALIAS_LAYERS)
            and l.name not in absorbed]


def _partition(costs: List[int], nstages: int) -> List[int]:
    """Contiguous linear partition of ``costs`` into ``nstages`` chunks
    minimizing the maximum chunk sum (classic O(n^2 * S) DP).  Returns
    the chunk *lengths*; every chunk is non-empty."""
    n = len(costs)
    prefix = [0]
    for c in costs:
        prefix.append(prefix[-1] + c)
    inf = float("inf")
    dp = [[inf] * (n + 1) for _ in range(nstages + 1)]
    cut = [[0] * (n + 1) for _ in range(nstages + 1)]
    dp[0][0] = 0.0
    for s in range(1, nstages + 1):
        for i in range(s, n + 1):
            for j in range(s - 1, i):
                cand = max(dp[s - 1][j], prefix[i] - prefix[j])
                if cand < dp[s][i]:
                    dp[s][i] = cand
                    cut[s][i] = j
    lengths: List[int] = []
    i = n
    for s in range(nstages, 0, -1):
        j = cut[s][i]
        lengths.append(i - j)
        i = j
    lengths.reverse()
    return lengths


_FuseSet = Tuple[Tuple[Tuple[str, str], ...],
                 Tuple[Tuple[str, str], ...],
                 Tuple[Tuple[str, str], ...]]


def _prune_arena_regressions(
        graph: CNNGraph,
        fused: Tuple[Tuple[str, str], ...],
        fused_pools: Tuple[Tuple[str, str], ...] = (),
        fused_concats: Tuple[Tuple[str, str], ...] = ()) -> _FuseSet:
    """Drop fused pairs (of any kind) until the packed arena is no
    larger than the unfused plan's.

    Fusing a consumer eliminates its producer's buffer and can only
    shrink the *peak live* set, but the arena packer is first-fit over
    interval interference and first-fit is not monotone: removing a
    buffer moves later buffers to different offsets, which on branchy
    graphs can fragment the packing and *grow* the total.  The int8
    fused AvgPool additionally introduces an aligned ``int32`` window
    scratch interval that can outweigh the eliminated producer buffer.
    Rather than weaken the "fusion never costs memory" contract, fusion
    decisions are made memory-aware here: greedily drop the pair whose
    removal shrinks the plan most until fused <= unfused (the empty set
    gives exact equality, so this always terminates).  The plan depends
    on the emission style — rolled loops add padding-scratch intervals
    that full unroll handles inline — and on the element width, so the
    invariant is enforced across both uniform unroll styles in float
    and int8 (per-layer mixed-unroll builds sit between the two
    extremes and are not individually checked).
    """
    if not (fused or fused_pools or fused_concats):
        return fused, fused_pools, fused_concats
    from . import cgen  # runtime import: cgen imports this module

    plans = [(cgen.CodegenOptions(unroll=u), q)
             for u in (0, None) for q in (False, True)]
    tagged = ([("add", pr) for pr in fused]
              + [("pool", pr) for pr in fused_pools]
              + [("cat", pr) for pr in fused_concats])

    def split(items) -> _FuseSet:
        return (tuple(pr for k, pr in items if k == "add"),
                tuple(pr for k, pr in items if k == "pool"),
                tuple(pr for k, pr in items if k == "cat"))

    def totals(items) -> Tuple[int, ...]:
        fa, fp, fc = split(items)
        sched = Schedule(fused_adds=fa, fused_pools=fp, fused_concats=fc,
                         stages=(tuple(emission_units(graph, fa, fp)),))
        return tuple(
            cgen.plan_arena(graph, opts, quantized=q,
                            schedule=sched).total_floats
            for opts, q in plans)

    base = totals(())
    keep = list(tagged)

    def excess(items) -> int:
        return sum(max(0, t - b) for t, b in zip(totals(items), base))

    while keep and excess(keep) > 0:
        best = min(range(len(keep)),
                   key=lambda i: excess(keep[:i] + keep[i + 1:]))
        keep.pop(best)
    return split(keep)


FUSION_KINDS = ("add", "pool", "concat")


def make_schedule(graph: CNNGraph, *, nstages: int = 1,
                  fusion: bool = True,
                  kinds: Sequence[str] = FUSION_KINDS) -> Schedule:
    """Build a :class:`Schedule` for ``graph``.

    ``fusion=True`` fuses every eligible Add/pool/Concat epilogue whose
    fusion does not grow the packed arena (output is bitwise identical
    either way; see :func:`_prune_arena_regressions` for why packing
    can regress).  ``kinds`` restricts which consumer kinds are
    considered — the int8 autotuner times kind subsets as code
    variants (see ``engine.autotune.fusion_schedule_candidates``).
    ``nstages`` > 1 partitions the units into that many
    balanced pipeline stages (clamped to the unit count); pipelined
    builds drop Concat fusion up front — stage-interface forwarding
    assumes every value is defined by a single stage, and a Concat
    assembled piecemeal by producers in different stages would violate
    that (Add/pool fusions are immune: producer and absorbed consumer
    always share a unit, hence a stage).
    """
    unknown = set(kinds) - set(FUSION_KINDS)
    if unknown:
        raise ValueError(f"unknown fusion kinds: {sorted(unknown)}")
    if fusion:
        cand_adds = (tuple(fusable_adds(graph))
                     if "add" in kinds else ())
        cand_pools = (tuple(fusable_pools(graph))
                      if "pool" in kinds else ())
        cand_cats = (tuple(fusable_concats(graph))
                     if "concat" in kinds and int(nstages) <= 1 else ())
        fused, fused_pools, fused_concats = _prune_arena_regressions(
            graph, cand_adds, cand_pools, cand_cats)
    else:
        fused = fused_pools = fused_concats = ()
    units = emission_units(graph, fused, fused_pools)
    if not units:
        return Schedule(fused_adds=fused, stages=((),),
                        fused_pools=fused_pools,
                        fused_concats=fused_concats)
    costs = layer_costs(graph)
    fused_by_p = {p: a for p, a in fused}
    fused_by_p.update({p: pl for p, pl in fused_pools})
    unit_costs = [costs[u] + costs.get(fused_by_p.get(u, ""), 0)
                  for u in units]
    s = max(1, min(int(nstages), len(units)))
    if s == 1:
        return Schedule(fused_adds=fused, stages=(tuple(units),),
                        fused_pools=fused_pools,
                        fused_concats=fused_concats)
    lengths = _partition(unit_costs, s)
    stages: List[Tuple[str, ...]] = []
    i = 0
    for ln in lengths:
        stages.append(tuple(units[i:i + ln]))
        i += ln
    return Schedule(fused_adds=fused, stages=tuple(stages),
                    fused_pools=fused_pools,
                    fused_concats=fused_concats)
