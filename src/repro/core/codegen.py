"""Unified codegen front-end: one ``compile()`` for both precisions.

``generate_c`` / ``generate_quantized_c`` returned a bare source string
and every caller re-derived entry symbols, workspace sizes and arena
stats from the options object by hand.  :func:`compile` replaces that
with a single call returning a frozen :class:`GeneratedSource` value
object — source text plus everything a loader, cache key or report
needs — mirroring the ``SessionConfig`` consolidation one layer up.

A :class:`~repro.core.schedule.Schedule` (epilogue fusion + pipeline
stage assignment) rides along: the default schedule fuses every
eligible Add/pool/Concat epilogue (bitwise-identical output, smaller
arena) and emits a single stage; pass
``schedule=make_schedule(g, nstages=k)`` for the layer-pipelined build.

Since the loop-nest IR split, generation is two explicit phases:
:func:`lower` produces a typed :class:`~repro.core.lowering.Program`
(loop nests, kernel variants, epilogue chains, planned buffers) and
:func:`~repro.core.lowering.render` turns it into the C string —
``compile()`` does both and keeps the ``Program`` on the result for
inspection (``tools/dump_ir.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from .cgen import (CODEGEN_VERSION, CGenerator, CodegenOptions,
                   QuantCGenerator)
from .lowering import Program, render
from .schedule import Schedule, make_schedule

__all__ = ["GeneratedSource", "compile", "lower", "CodegenOptions",
           "Schedule", "make_schedule", "Program", "render",
           "CODEGEN_VERSION"]


@dataclass(frozen=True)
class GeneratedSource:
    """One generated C translation unit plus its ABI and plan summary.

    ``workspace_elems`` is the size (in ``elem_bytes``-sized elements)
    of the caller-supplied workspace for the ``entry_ws`` /
    ``entry_batch_ws`` / ``entry_pipeline`` entries: the liveness-packed
    arena (``arena_elems``) plus, for pipelined builds, the stage
    interface buffers (``iface_elems``, one per stage boundary).
    """

    source: str
    func_name: str
    precision: str                       # 'fp32' | 'int8'
    simd: str
    codegen_version: int
    schedule: Schedule
    # entry symbols (None when not emitted for this build)
    entry: str
    entry_ws: str
    entry_batch: Optional[str]
    entry_batch_ws: Optional[str]
    entry_pipeline: Optional[str]        # None for single-stage builds
    stage_entries: Tuple[str, ...] = ()
    # sizes (elements are floats for fp32, bytes for int8)
    workspace_elems: int = 0
    elem_bytes: int = 4
    arena_elems: int = 0
    iface_elems: Tuple[int, ...] = ()
    in_elems: int = 0
    out_elems: int = 0
    # arena plan summary (bytes)
    arena_bytes: int = 0
    arena_buffer_sum_bytes: int = 0
    peak_live_bytes: int = 0
    per_layer_live_bytes: Optional[Dict[str, int]] = None
    # the lowered IR the source was rendered from (identity-compared
    # only: Program is mutable and not part of the value semantics)
    program: Optional[Program] = field(default=None, compare=False)

    @property
    def workspace_bytes(self) -> int:
        return self.workspace_elems * self.elem_bytes

    @property
    def nstages(self) -> int:
        return self.schedule.nstages

    def describe(self) -> Dict[str, object]:
        """JSON-able summary (no source text) for info()/telemetry."""
        return {
            "func_name": self.func_name,
            "precision": self.precision,
            "simd": self.simd,
            "codegen_version": self.codegen_version,
            "schedule": self.schedule.describe(),
            "entry_pipeline": self.entry_pipeline,
            "workspace_bytes": self.workspace_bytes,
            "arena_bytes": self.arena_bytes,
            "iface_elems": list(self.iface_elems),
        }


def lower(graph_or_qgraph, opts: Optional[CodegenOptions] = None,
          schedule: Optional[Schedule] = None):
    """Lower a graph to a :class:`~repro.core.lowering.Program` without
    rendering it.  Returns ``(generator, program)`` — the generator
    carries the plan and entry-symbol metadata ``compile()`` packages.
    """
    from .quantize import QuantizedGraph  # lazy: quantize imports jax
    opts = opts or CodegenOptions()
    quantized = isinstance(graph_or_qgraph, QuantizedGraph)
    graph = graph_or_qgraph.graph if quantized else graph_or_qgraph
    if schedule is None:
        schedule = make_schedule(graph, fusion=True, nstages=1)
    gen = (QuantCGenerator(graph_or_qgraph, opts, schedule=schedule)
           if quantized else CGenerator(graph, opts, schedule=schedule))
    return gen, gen.lower()


def compile(graph_or_qgraph, opts: Optional[CodegenOptions] = None,
            schedule: Optional[Schedule] = None) -> GeneratedSource:
    """Generate ANSI C for a float :class:`~repro.core.graph.CNNGraph`
    or a calibrated :class:`~repro.core.quantize.QuantizedGraph`.

    ``schedule=None`` builds the default: every eligible Add/pool/
    Concat epilogue fused (output bitwise identical to the unfused
    graph, arena never larger), single stage.
    ``make_schedule(g, fusion=False)`` reproduces the legacy layout
    byte-for-byte; ``make_schedule(g, nstages=k)`` adds the
    ``<func>_stage<i>`` / ``<func>_pipeline`` entries for
    layer-pipelined execution.
    """
    from .quantize import QuantizedGraph  # lazy: quantize imports jax
    opts = opts or CodegenOptions()
    quantized = isinstance(graph_or_qgraph, QuantizedGraph)
    graph = graph_or_qgraph.graph if quantized else graph_or_qgraph
    if schedule is None:
        schedule = make_schedule(graph, fusion=True, nstages=1)
    gen = (QuantCGenerator(graph_or_qgraph, opts, schedule=schedule)
           if quantized else CGenerator(graph, opts, schedule=schedule))
    program = gen.lower()
    source = render(program)
    plan = gen.plan
    S = schedule.nstages
    peak = max(plan.per_layer_live.values(), default=0) * plan.elem_bytes
    return GeneratedSource(
        source=source,
        func_name=opts.func_name,
        precision="int8" if quantized else "fp32",
        simd=opts.simd,
        codegen_version=CODEGEN_VERSION,
        schedule=schedule,
        entry=opts.func_name,
        entry_ws=opts.ws_func_name,
        entry_batch=opts.batch_func_name if opts.emit_batch else None,
        entry_batch_ws=(opts.batch_ws_func_name if opts.emit_batch
                        else None),
        entry_pipeline=opts.pipeline_func_name if S > 1 else None,
        stage_entries=gen.stage_syms,
        workspace_elems=gen.ws_total_elems,
        elem_bytes=plan.elem_bytes,
        arena_elems=plan.total_floats,
        iface_elems=gen.iface_elems,
        in_elems=int(np.prod(graph.input_shape)),
        out_elems=int(np.prod(graph.output_shape)),
        arena_bytes=gen.ws_total_elems * plan.elem_bytes,
        arena_buffer_sum_bytes=plan.buffer_sum_bytes,
        peak_live_bytes=peak,
        per_layer_live_bytes={k: v * plan.elem_bytes
                              for k, v in plan.per_layer_live.items()},
        program=program,
    )
