"""Layer-graph IR for trained CNNs (the NNCG front-end).

The paper compiles a *trained* Keras model; here the IR is framework-free:
a sequential list of layers carrying trained weights as numpy arrays.
Layout is channels-last (NHWC / HWIO) throughout — the paper's P4
principle (vectorize over output channels) requires ``c_out`` to be the
fastest-varying dimension.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np

Shape3 = Tuple[int, int, int]  # (h, w, c)


def _pair(v) -> Tuple[int, int]:
    if isinstance(v, (tuple, list)):
        a, b = v
        return int(a), int(b)
    return int(v), int(v)


@dataclass
class Layer:
    """Base class. ``out_shape`` is filled in by ``CNNGraph.infer_shapes``."""

    name: str = field(default="", kw_only=True)

    def out_shape(self, in_shape: Shape3) -> Shape3:  # pragma: no cover
        raise NotImplementedError

    def param_count(self) -> int:
        return 0


@dataclass
class Input(Layer):
    shape: Shape3 = (1, 1, 1)

    def out_shape(self, in_shape: Shape3) -> Shape3:
        return tuple(int(s) for s in self.shape)


@dataclass
class Conv2D(Layer):
    """2-D convolution, weights HWIO ``(kh, kw, c_in, c_out)``.

    ``activation`` holds a fused activation (None | 'relu' | 'leaky_relu'
    | 'softmax') — the fusion pass moves standalone activation layers in
    here so the code generator emits a single fused loop nest (paper
    §II-B.1).
    """

    weights: np.ndarray = None
    bias: np.ndarray = None
    strides: Tuple[int, int] = (1, 1)
    padding: str = "valid"  # 'same' | 'valid'
    activation: Optional[str] = None
    alpha: float = 0.1  # leaky-ReLU slope

    def __post_init__(self):
        self.strides = _pair(self.strides)
        if not hasattr(self.weights, "aval"):  # leave jax tracers alone
            self.weights = np.asarray(self.weights, dtype=np.float32)
        if self.bias is None:
            self.bias = np.zeros(self.weights.shape[-1], dtype=np.float32)
        if not hasattr(self.bias, "aval"):
            self.bias = np.asarray(self.bias, dtype=np.float32)
        assert self.weights.ndim == 4, "Conv2D weights must be HWIO"
        assert self.padding in ("same", "valid")

    @property
    def kh(self) -> int:
        return self.weights.shape[0]

    @property
    def kw(self) -> int:
        return self.weights.shape[1]

    @property
    def c_in(self) -> int:
        return self.weights.shape[2]

    @property
    def c_out(self) -> int:
        return self.weights.shape[3]

    def pad_amounts(self, in_shape: Shape3) -> Tuple[int, int, int, int]:
        """(top, bottom, left, right) zero padding (paper Eq. 1)."""
        if self.padding == "valid":
            return (0, 0, 0, 0)
        h, w, _ = in_shape
        sh, sw = self.strides
        out_h = -(-h // sh)  # ceil
        out_w = -(-w // sw)
        pad_h = max((out_h - 1) * sh + self.kh - h, 0)
        pad_w = max((out_w - 1) * sw + self.kw - w, 0)
        return (pad_h // 2, pad_h - pad_h // 2, pad_w // 2, pad_w - pad_w // 2)

    def out_shape(self, in_shape: Shape3) -> Shape3:
        h, w, c = in_shape
        assert c == self.c_in, f"{self.name}: c_in {self.c_in} != input {c}"
        sh, sw = self.strides
        pt, pb, pl, pr = self.pad_amounts(in_shape)
        oh = (h + pt + pb - self.kh) // sh + 1
        ow = (w + pl + pr - self.kw) // sw + 1
        return (oh, ow, self.c_out)

    def param_count(self) -> int:
        return int(self.weights.size + self.bias.size)


@dataclass
class Dense(Layer):
    """Fully connected: weights ``(d_in, d_out)``; input is flattened."""

    weights: np.ndarray = None
    bias: np.ndarray = None
    activation: Optional[str] = None
    alpha: float = 0.1

    def __post_init__(self):
        if not hasattr(self.weights, "aval"):
            self.weights = np.asarray(self.weights, dtype=np.float32)
        if self.bias is None:
            self.bias = np.zeros(self.weights.shape[-1], dtype=np.float32)
        if not hasattr(self.bias, "aval"):
            self.bias = np.asarray(self.bias, dtype=np.float32)

    def out_shape(self, in_shape: Shape3) -> Shape3:
        d_in = int(np.prod(in_shape))
        assert d_in == self.weights.shape[0]
        return (1, 1, int(self.weights.shape[1]))

    def param_count(self) -> int:
        return int(self.weights.size + self.bias.size)


@dataclass
class MaxPool(Layer):
    size: Tuple[int, int] = (2, 2)
    strides: Optional[Tuple[int, int]] = None  # default = size

    def __post_init__(self):
        self.size = _pair(self.size)
        self.strides = _pair(self.strides) if self.strides is not None else self.size

    def out_shape(self, in_shape: Shape3) -> Shape3:
        h, w, c = in_shape
        kh, kw = self.size
        sh, sw = self.strides
        return ((h - kh) // sh + 1, (w - kw) // sw + 1, c)


@dataclass
class ReLU(Layer):
    def out_shape(self, in_shape: Shape3) -> Shape3:
        return in_shape


@dataclass
class LeakyReLU(Layer):
    alpha: float = 0.1

    def out_shape(self, in_shape: Shape3) -> Shape3:
        return in_shape


@dataclass
class Softmax(Layer):
    """Softmax over the channel dimension."""

    def out_shape(self, in_shape: Shape3) -> Shape3:
        return in_shape


@dataclass
class BatchNorm(Layer):
    """Inference-mode batch normalization over channels (paper §II-B.4)."""

    mean: np.ndarray = None
    var: np.ndarray = None
    gamma: np.ndarray = None
    beta: np.ndarray = None
    eps: float = 1e-3

    def __post_init__(self):
        self.mean = np.asarray(self.mean, dtype=np.float32)
        self.var = np.asarray(self.var, dtype=np.float32)
        if self.gamma is None:
            self.gamma = np.ones_like(self.mean)
        if self.beta is None:
            self.beta = np.zeros_like(self.mean)
        self.gamma = np.asarray(self.gamma, dtype=np.float32)
        self.beta = np.asarray(self.beta, dtype=np.float32)

    def scale_shift(self) -> Tuple[np.ndarray, np.ndarray]:
        """y = scale * x + shift."""
        inv = self.gamma / np.sqrt(self.var + self.eps)
        return inv.astype(np.float32), (self.beta - self.mean * inv).astype(np.float32)

    def out_shape(self, in_shape: Shape3) -> Shape3:
        return in_shape

    def param_count(self) -> int:
        return int(self.mean.size * 4)


@dataclass
class Dropout(Layer):
    rate: float = 0.5

    def out_shape(self, in_shape: Shape3) -> Shape3:
        return in_shape


@dataclass
class Flatten(Layer):
    def out_shape(self, in_shape: Shape3) -> Shape3:
        return (1, 1, int(np.prod(in_shape)))


@dataclass
class CNNGraph:
    """A sequential CNN: ``layers[0]`` must be :class:`Input`."""

    layers: List[Layer]

    def __post_init__(self):
        assert self.layers and isinstance(self.layers[0], Input)
        for i, l in enumerate(self.layers):
            if not l.name:
                l.name = f"{type(l).__name__.lower()}_{i}"

    @property
    def input_shape(self) -> Shape3:
        return self.layers[0].shape

    def shapes(self) -> List[Shape3]:
        """Per-layer output shapes (``shapes[i]`` = output of layer i)."""
        out: List[Shape3] = []
        cur = self.input_shape
        for l in self.layers:
            cur = l.out_shape(cur)
            out.append(cur)
        return out

    @property
    def output_shape(self) -> Shape3:
        return self.shapes()[-1]

    def param_count(self) -> int:
        return sum(l.param_count() for l in self.layers)

    def replace(self, layers: Sequence[Layer]) -> "CNNGraph":
        return CNNGraph(list(layers))

    def copy(self) -> "CNNGraph":
        return CNNGraph([dataclasses.replace(l) for l in self.layers])
