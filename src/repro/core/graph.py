"""Layer-graph IR for trained CNNs (the NNCG front-end).

The paper compiles a *trained* Keras model; here the IR is framework-free:
a **DAG** of layers carrying trained weights as numpy arrays.  Every layer
names its producers in ``inputs``; a plain sequential list still works —
``CNNGraph`` auto-wires each layer to its predecessor when ``inputs`` is
omitted (the list→DAG adapter), so pre-DAG callers are unchanged.

The layer list itself must be a valid topological order (each layer's
inputs appear earlier in the list); ``CNNGraph`` validates this, so every
consumer — passes, oracles, codegen — can walk ``layers`` directly.

Layout is channels-last (NHWC / HWIO) throughout — the paper's P4
principle (vectorize over output channels) requires ``c_out`` to be the
fastest-varying dimension.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

Shape3 = Tuple[int, int, int]  # (h, w, c)


def _pair(v) -> Tuple[int, int]:
    if isinstance(v, (tuple, list)):
        a, b = v
        return int(a), int(b)
    return int(v), int(v)


def _conv_pads(in_shape: Shape3, kh: int, kw: int, strides: Tuple[int, int],
               padding: str) -> Tuple[int, int, int, int]:
    """(top, bottom, left, right) zero padding (paper Eq. 1)."""
    if padding == "valid":
        return (0, 0, 0, 0)
    h, w, _ = in_shape
    sh, sw = strides
    out_h = -(-h // sh)  # ceil
    out_w = -(-w // sw)
    pad_h = max((out_h - 1) * sh + kh - h, 0)
    pad_w = max((out_w - 1) * sw + kw - w, 0)
    return (pad_h // 2, pad_h - pad_h // 2, pad_w // 2, pad_w - pad_w // 2)


@dataclass
class Layer:
    """Base class.

    ``inputs`` holds the names of producer layers (DAG edges). ``None``
    means "wire me to the previous layer in the list" — resolved by
    :class:`CNNGraph` so sequential model definitions stay terse.
    """

    name: str = field(default="", kw_only=True)
    inputs: Optional[List[str]] = field(default=None, kw_only=True)

    def out_shape(self, in_shape: Shape3) -> Shape3:  # pragma: no cover
        raise NotImplementedError

    def infer_shape(self, in_shapes: Sequence[Shape3]) -> Shape3:
        """Output shape from the (ordered) producer shapes. Single-input
        layers delegate to :meth:`out_shape`; multi-input layers override."""
        return self.out_shape(in_shapes[0] if in_shapes else None)

    def param_count(self) -> int:
        return 0


@dataclass
class Input(Layer):
    shape: Shape3 = (1, 1, 1)

    def out_shape(self, in_shape: Shape3) -> Shape3:
        return tuple(int(s) for s in self.shape)


@dataclass
class Conv2D(Layer):
    """2-D convolution, weights HWIO ``(kh, kw, c_in, c_out)``.

    ``activation`` holds a fused activation (None | 'relu' | 'leaky_relu'
    | 'softmax') — the fusion pass moves standalone activation layers in
    here so the code generator emits a single fused loop nest (paper
    §II-B.1).
    """

    weights: np.ndarray = None
    bias: np.ndarray = None
    strides: Tuple[int, int] = (1, 1)
    padding: str = "valid"  # 'same' | 'valid'
    activation: Optional[str] = None
    alpha: float = 0.1  # leaky-ReLU slope

    def __post_init__(self):
        self.strides = _pair(self.strides)
        if not hasattr(self.weights, "aval"):  # leave jax tracers alone
            self.weights = np.asarray(self.weights, dtype=np.float32)
        if self.bias is None:
            self.bias = np.zeros(self.weights.shape[-1], dtype=np.float32)
        if not hasattr(self.bias, "aval"):
            self.bias = np.asarray(self.bias, dtype=np.float32)
        assert self.weights.ndim == 4, "Conv2D weights must be HWIO"
        assert self.padding in ("same", "valid")

    @property
    def kh(self) -> int:
        return self.weights.shape[0]

    @property
    def kw(self) -> int:
        return self.weights.shape[1]

    @property
    def c_in(self) -> int:
        return self.weights.shape[2]

    @property
    def c_out(self) -> int:
        return self.weights.shape[3]

    def pad_amounts(self, in_shape: Shape3) -> Tuple[int, int, int, int]:
        """(top, bottom, left, right) zero padding (paper Eq. 1)."""
        return _conv_pads(in_shape, self.kh, self.kw, self.strides,
                          self.padding)

    def out_shape(self, in_shape: Shape3) -> Shape3:
        h, w, c = in_shape
        assert c == self.c_in, f"{self.name}: c_in {self.c_in} != input {c}"
        sh, sw = self.strides
        pt, pb, pl, pr = self.pad_amounts(in_shape)
        oh = (h + pt + pb - self.kh) // sh + 1
        ow = (w + pl + pr - self.kw) // sw + 1
        return (oh, ow, self.c_out)

    def param_count(self) -> int:
        return int(self.weights.size + self.bias.size)


@dataclass
class DepthwiseConv2D(Layer):
    """Depthwise convolution, weights HWCM ``(kh, kw, c_in, multiplier)``.

    Each input channel is convolved with its own ``multiplier`` filters;
    output channel ``c * multiplier + m`` comes from input channel ``c``
    (group-major, matching XLA's grouped-conv channel ordering)."""

    weights: np.ndarray = None
    bias: np.ndarray = None
    strides: Tuple[int, int] = (1, 1)
    padding: str = "valid"
    activation: Optional[str] = None
    alpha: float = 0.1

    def __post_init__(self):
        self.strides = _pair(self.strides)
        if not hasattr(self.weights, "aval"):
            self.weights = np.asarray(self.weights, dtype=np.float32)
        assert self.weights.ndim == 4, "DepthwiseConv2D weights must be HWCM"
        if self.bias is None:
            self.bias = np.zeros(self.c_in * self.multiplier, dtype=np.float32)
        if not hasattr(self.bias, "aval"):
            self.bias = np.asarray(self.bias, dtype=np.float32)
        assert self.padding in ("same", "valid")

    @property
    def kh(self) -> int:
        return self.weights.shape[0]

    @property
    def kw(self) -> int:
        return self.weights.shape[1]

    @property
    def c_in(self) -> int:
        return self.weights.shape[2]

    @property
    def multiplier(self) -> int:
        return self.weights.shape[3]

    @property
    def c_out(self) -> int:
        return self.c_in * self.multiplier

    def pad_amounts(self, in_shape: Shape3) -> Tuple[int, int, int, int]:
        return _conv_pads(in_shape, self.kh, self.kw, self.strides,
                          self.padding)

    def out_shape(self, in_shape: Shape3) -> Shape3:
        h, w, c = in_shape
        assert c == self.c_in, f"{self.name}: c_in {self.c_in} != input {c}"
        sh, sw = self.strides
        pt, pb, pl, pr = self.pad_amounts(in_shape)
        oh = (h + pt + pb - self.kh) // sh + 1
        ow = (w + pl + pr - self.kw) // sw + 1
        return (oh, ow, self.c_out)

    def param_count(self) -> int:
        return int(self.weights.size + self.bias.size)


@dataclass
class Dense(Layer):
    """Fully connected: weights ``(d_in, d_out)``; input is flattened."""

    weights: np.ndarray = None
    bias: np.ndarray = None
    activation: Optional[str] = None
    alpha: float = 0.1

    def __post_init__(self):
        if not hasattr(self.weights, "aval"):
            self.weights = np.asarray(self.weights, dtype=np.float32)
        if self.bias is None:
            self.bias = np.zeros(self.weights.shape[-1], dtype=np.float32)
        if not hasattr(self.bias, "aval"):
            self.bias = np.asarray(self.bias, dtype=np.float32)

    def out_shape(self, in_shape: Shape3) -> Shape3:
        d_in = int(np.prod(in_shape))
        assert d_in == self.weights.shape[0]
        return (1, 1, int(self.weights.shape[1]))

    def param_count(self) -> int:
        return int(self.weights.size + self.bias.size)


def pool_window_counts(in_shape: Shape3, size, strides, pads) -> np.ndarray:
    """Per-output-window count of *valid* (non-padding) taps, shape
    ``(oh, ow)``.  Factorizes as rows(i) * cols(j); edge windows of a
    ``same``-padded pool cover fewer valid elements, so AvgPool must
    divide by this, not by the fixed ``kh*kw``."""
    h, w, _ = in_shape
    kh, kw = size
    sh, sw = strides
    pt, pb, pl, pr = pads
    oh = (h + pt + pb - kh) // sh + 1
    ow = (w + pl + pr - kw) // sw + 1
    rows = np.array([min(i * sh - pt + kh, h) - max(i * sh - pt, 0)
                     for i in range(oh)], dtype=np.int64)
    cols = np.array([min(j * sw - pl + kw, w) - max(j * sw - pl, 0)
                     for j in range(ow)], dtype=np.int64)
    return rows[:, None] * cols[None, :]


@dataclass
class _Pool(Layer):
    """Shared window semantics for spatial pooling.

    ``padding='same'`` uses the conv padding arithmetic (paper Eq. 1);
    padded taps never contribute to the result — MaxPool ignores them,
    AvgPool divides by the per-window count of *valid* elements."""

    size: Tuple[int, int] = (2, 2)
    strides: Optional[Tuple[int, int]] = None  # default = size
    padding: str = "valid"  # 'same' | 'valid'

    def __post_init__(self):
        self.size = _pair(self.size)
        self.strides = _pair(self.strides) if self.strides is not None else self.size
        assert self.padding in ("same", "valid")

    def pad_amounts(self, in_shape: Shape3) -> Tuple[int, int, int, int]:
        return _conv_pads(in_shape, self.size[0], self.size[1],
                          self.strides, self.padding)

    def out_shape(self, in_shape: Shape3) -> Shape3:
        h, w, c = in_shape
        kh, kw = self.size
        sh, sw = self.strides
        pt, pb, pl, pr = self.pad_amounts(in_shape)
        return ((h + pt + pb - kh) // sh + 1,
                (w + pl + pr - kw) // sw + 1, c)


@dataclass
class MaxPool(_Pool):
    pass


@dataclass
class AvgPool(_Pool):
    """Average pooling, same window semantics as :class:`MaxPool`."""


@dataclass
class GlobalAvgPool(Layer):
    """Spatial mean over (h, w): ``(h, w, c) -> (1, 1, c)``."""

    def out_shape(self, in_shape: Shape3) -> Shape3:
        return (1, 1, int(in_shape[2]))


@dataclass
class Add(Layer):
    """Elementwise sum of ≥2 same-shape inputs (residual connection).

    ``activation`` (None | 'relu' | 'leaky_relu') lets the fusion pass
    fold the post-merge activation into the same loop."""

    activation: Optional[str] = None
    alpha: float = 0.1

    def infer_shape(self, in_shapes: Sequence[Shape3]) -> Shape3:
        assert len(in_shapes) >= 2, f"{self.name}: Add needs >=2 inputs"
        first = tuple(in_shapes[0])
        for s in in_shapes[1:]:
            assert tuple(s) == first, (
                f"{self.name}: Add shape mismatch {in_shapes}")
        return first

    def out_shape(self, in_shape: Shape3) -> Shape3:
        return in_shape


@dataclass
class Concat(Layer):
    """Channel-axis concatenation of ≥2 inputs with equal (h, w)."""

    def infer_shape(self, in_shapes: Sequence[Shape3]) -> Shape3:
        assert len(in_shapes) >= 2, f"{self.name}: Concat needs >=2 inputs"
        h, w, _ = in_shapes[0]
        for s in in_shapes[1:]:
            assert tuple(s[:2]) == (h, w), (
                f"{self.name}: Concat spatial mismatch {in_shapes}")
        return (h, w, int(sum(s[2] for s in in_shapes)))

    def out_shape(self, in_shape: Shape3) -> Shape3:
        return in_shape


@dataclass
class ReLU(Layer):
    def out_shape(self, in_shape: Shape3) -> Shape3:
        return in_shape


@dataclass
class LeakyReLU(Layer):
    alpha: float = 0.1

    def out_shape(self, in_shape: Shape3) -> Shape3:
        return in_shape


@dataclass
class Softmax(Layer):
    """Softmax over the channel dimension."""

    def out_shape(self, in_shape: Shape3) -> Shape3:
        return in_shape


@dataclass
class BatchNorm(Layer):
    """Inference-mode batch normalization over channels (paper §II-B.4)."""

    mean: np.ndarray = None
    var: np.ndarray = None
    gamma: np.ndarray = None
    beta: np.ndarray = None
    eps: float = 1e-3

    def __post_init__(self):
        self.mean = np.asarray(self.mean, dtype=np.float32)
        self.var = np.asarray(self.var, dtype=np.float32)
        if self.gamma is None:
            self.gamma = np.ones_like(self.mean)
        if self.beta is None:
            self.beta = np.zeros_like(self.mean)
        self.gamma = np.asarray(self.gamma, dtype=np.float32)
        self.beta = np.asarray(self.beta, dtype=np.float32)

    def scale_shift(self) -> Tuple[np.ndarray, np.ndarray]:
        """y = scale * x + shift."""
        inv = self.gamma / np.sqrt(self.var + self.eps)
        return inv.astype(np.float32), (self.beta - self.mean * inv).astype(np.float32)

    def out_shape(self, in_shape: Shape3) -> Shape3:
        return in_shape

    def param_count(self) -> int:
        return int(self.mean.size * 4)


@dataclass
class Dropout(Layer):
    rate: float = 0.5

    def out_shape(self, in_shape: Shape3) -> Shape3:
        return in_shape


@dataclass
class Flatten(Layer):
    def out_shape(self, in_shape: Shape3) -> Shape3:
        return (1, 1, int(np.prod(in_shape)))


@dataclass
class CNNGraph:
    """A DAG of layers; ``layers[0]`` must be :class:`Input` and the list
    must be topologically ordered (validated).  Layers with ``inputs=None``
    are auto-wired to their list predecessor, so a plain sequential list
    is still a valid graph."""

    layers: List[Layer]

    def __post_init__(self):
        assert self.layers and isinstance(self.layers[0], Input)
        for i, l in enumerate(self.layers):
            if not l.name:
                l.name = f"{type(l).__name__.lower()}_{i}"
        names = [l.name for l in self.layers]
        assert len(set(names)) == len(names), f"duplicate layer names: {names}"
        seen: set = set()
        for i, l in enumerate(self.layers):
            if isinstance(l, Input):
                assert not l.inputs, f"{l.name}: Input takes no inputs"
                l.inputs = []
            elif l.inputs is None:  # list→DAG adapter: chain to predecessor
                l.inputs = [self.layers[i - 1].name]
            for src in l.inputs:
                assert src in seen, (
                    f"{l.name}: input {src!r} must precede it (topo order)")
            seen.add(l.name)

    # -- structure -----------------------------------------------------------

    def layer(self, name: str) -> Layer:
        for l in self.layers:
            if l.name == name:
                return l
        raise KeyError(name)

    def consumers(self) -> Dict[str, List[Layer]]:
        """Map producer name -> consuming layers, in topo order."""
        out: Dict[str, List[Layer]] = {l.name: [] for l in self.layers}
        for l in self.layers:
            for src in l.inputs:
                out[src].append(l)
        return out

    @property
    def sink(self) -> Layer:
        """The unique output layer (consumed by nobody)."""
        cons = self.consumers()
        sinks = [l for l in self.layers if not cons[l.name]]
        assert len(sinks) == 1, (
            f"graph must have exactly one output, got "
            f"{[s.name for s in sinks]}")
        return sinks[0]

    @property
    def input_shape(self) -> Shape3:
        return self.layers[0].shape

    def shape_map(self) -> Dict[str, Shape3]:
        """Output shape of every layer, keyed by name (topo evaluation)."""
        smap: Dict[str, Shape3] = {}
        for l in self.layers:
            smap[l.name] = l.infer_shape([smap[n] for n in l.inputs])
        return smap

    def in_shapes(self, layer: Layer,
                  smap: Optional[Dict[str, Shape3]] = None) -> List[Shape3]:
        smap = smap if smap is not None else self.shape_map()
        return [smap[n] for n in layer.inputs]

    def shapes(self) -> List[Shape3]:
        """Per-layer output shapes in list order (``shapes[i]`` = output
        of ``layers[i]``)."""
        smap = self.shape_map()
        return [smap[l.name] for l in self.layers]

    @property
    def output_shape(self) -> Shape3:
        return self.shape_map()[self.sink.name]

    def param_count(self) -> int:
        return sum(l.param_count() for l in self.layers)

    def replace(self, layers: Sequence[Layer]) -> "CNNGraph":
        return CNNGraph(list(layers))

    def copy(self) -> "CNNGraph":
        return CNNGraph([
            dataclasses.replace(l, inputs=list(l.inputs))
            for l in self.layers
        ])
