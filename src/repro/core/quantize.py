"""Post-training int8 quantization (calibration + qparam annotation).

The paper's design principles all exploit full knowledge of the trained
net at generation time; this module extends that to the *value ranges*:
a calibration pass runs sample inputs through the float oracle
(:func:`repro.core.jax_exec.forward`), records per-tensor activation
ranges, and derives:

* **activations** — per-tensor *asymmetric* int8 ``(scale, zero_point)``
  over the observed post-activation range (zero always exactly
  representable, so ReLU clamps and zero padding stay exact);
* **conv / depthwise / dense weights** — *per-output-channel symmetric*
  int8 scales (no zero point), the standard PTQ recipe;
* **biases** — int32 at scale ``s_in * s_w[k]``.

The quantized execution scheme (shared bit-for-bit by the generated C
and the :func:`repro.core.jax_exec.forward_quantized` reference):

* int8 storage for every intermediate tensor, int32 accumulation;
* requantization by a float32 multiplier ``M[k] = s_in*s_w[k]/s_out``
  applied as ``floor(acc * M + 0.5)`` (round-half-up) — float32
  multiply/add/floor are deterministic IEEE-754 ops, so the C build and
  the XLA reference agree *exactly* on the integer path;
* fused ReLU / LeakyReLU applied to the float requant value (both are
  positively-homogeneous, so they commute with the output scale);
* the sink layer dequantizes its int32 accumulator straight to float
  (softmax, when present, runs in float32) — the public API stays
  float-in / float-out.

Every scale used anywhere is computed **here** and cast to float32
once, so the code generator (which prints it via ``_flit``, a bit-exact
round-trip) and the jax reference (which closes over the same array)
can never disagree.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from .graph import (
    BatchNorm,
    CNNGraph,
    Conv2D,
    Dense,
    DepthwiseConv2D,
    Dropout,
    Flatten,
    GlobalAvgPool,
    Input,
    MaxPool,
    Softmax,
    pool_window_counts,
)

QMIN, QMAX = -128, 127

# layers whose int8 output reuses the producer's qparams unchanged:
# identity layers alias the buffer; MaxPool commutes with any monotone
# quantization, so sharing qparams makes it a pure int8 max (no requant)
_SHARE_INPUT_QPARAMS = (Dropout, Flatten, MaxPool)

# weighted layers that get per-output-channel symmetric weight scales
_WEIGHTED = (Conv2D, DepthwiseConv2D, Dense)


@dataclass(frozen=True)
class QParams:
    """Asymmetric per-tensor int8 affine quantization:
    ``real = scale * (q - zero_point)``."""

    scale: float  # stored as the exact float32 value
    zero_point: int

    @property
    def inv_scale(self) -> np.float32:
        """The float32 multiplier the input-quantization step uses —
        computed once here so C literal and jax constant agree."""
        return np.float32(1.0 / float(self.scale))

    def quantize(self, x: np.ndarray) -> np.ndarray:
        """Reference quantizer: float -> int8 codes (round half up) —
        the same ``floor(x*inv + 0.5) + zp`` the C and jax paths use."""
        t = np.asarray(x, np.float32) * self.inv_scale
        q = np.floor(t + np.float32(0.5)).astype(np.int64) + self.zero_point
        return np.clip(q, QMIN, QMAX).astype(np.int8)

    def dequantize(self, q: np.ndarray) -> np.ndarray:
        return ((np.asarray(q, np.int32) - self.zero_point)
                * np.float32(self.scale)).astype(np.float32)


def qparams_from_range(mn: float, mx: float) -> QParams:
    """Derive (scale, zero_point) from an observed float range.

    The range is widened to include zero so that 0.0 is exactly
    representable (``q == zero_point``) — required for exact ReLU
    clamps and for padding int8 feature maps with the zero code."""
    mn = min(float(mn), 0.0)
    mx = max(float(mx), 0.0)
    scale = (mx - mn) / float(QMAX - QMIN)
    if scale == 0.0:  # constant-zero tensor
        scale = 1.0
    scale = float(np.float32(scale))
    zp = int(np.clip(round(QMIN - mn / scale), QMIN, QMAX))
    return QParams(scale=scale, zero_point=zp)


@dataclass
class LayerQuant:
    """Quantized parameters of one weighted layer (weights keep their
    graph layout: HWIO / HWCM / ``(d_in, d_out)``)."""

    w_scale: np.ndarray  # (c_out,) float32, symmetric per-channel
    w_q: np.ndarray      # int8
    b_q: np.ndarray      # int32 at scale s_in * s_w[k]


@dataclass
class QuantizedGraph:
    """A trained graph annotated with calibration-derived qparams."""

    graph: CNNGraph
    acts: Dict[str, QParams]          # layer name -> output qparams
    weights: Dict[str, LayerQuant] = field(default_factory=dict)

    # -- qparam lookups ------------------------------------------------------

    def out_qp(self, layer) -> QParams:
        return self.acts[layer.name]

    def in_qp(self, layer, idx: int = 0) -> QParams:
        return self.acts[layer.inputs[idx]]

    @property
    def input_qp(self) -> QParams:
        return self.acts[self.graph.layers[0].name]

    # -- derived constants (single source for cgen AND the jax ref) ----------

    def requant_scales(self, layer) -> np.ndarray:
        """(c_out,) float32: ``s_in * s_w[k] / s_out``."""
        lq = self.weights[layer.name]
        s_in = float(self.in_qp(layer).scale)
        s_out = float(self.out_qp(layer).scale)
        return np.float32(s_in * lq.w_scale.astype(np.float64) / s_out)

    def dequant_scales(self, layer) -> np.ndarray:
        """(c_out,) float32: ``s_in * s_w[k]`` — sink dequantization."""
        lq = self.weights[layer.name]
        s_in = float(self.in_qp(layer).scale)
        return np.float32(s_in * lq.w_scale.astype(np.float64))

    def rescale(self, layer, idx: int = 0) -> np.float32:
        """float32 ``s_in_idx / s_out`` for Add/Concat/ReLU requant."""
        return np.float32(float(self.in_qp(layer, idx).scale)
                          / float(self.out_qp(layer).scale))

    def pool_scales(self, layer, in_shape) -> np.ndarray:
        """AvgPool/GlobalAvgPool requant multipliers.

        AvgPool: ``(oh, ow)`` float32 ``s_in / (s_out * count[i,j])``
        with the edge-correct per-window valid-tap count.
        GlobalAvgPool: scalar float32 ``s_in / (s_out * h*w)``."""
        s_in = float(self.in_qp(layer).scale)
        s_out = float(self.out_qp(layer).scale)
        if isinstance(layer, GlobalAvgPool):
            return np.float32(s_in / (s_out * in_shape[0] * in_shape[1]))
        counts = pool_window_counts(in_shape, layer.size, layer.strides,
                                    layer.pad_amounts(in_shape))
        return np.float32(s_in / (s_out * counts.astype(np.float64)))

    def effective_bias(self, layer) -> np.ndarray:
        """(c_out,) int32: bias with the input zero-point correction
        folded in (``b_q[k] - zp_in * sum_taps w_q[...,k]``), so the C
        inner loop is a plain raw-code dot product — padding an int8
        feature map with the zero code then cancels exactly."""
        lq = self.weights[layer.name]
        zp = self.in_qp(layer).zero_point
        w = lq.w_q.astype(np.int64)
        if isinstance(layer, Conv2D):
            wsum = w.sum(axis=(0, 1, 2))
        elif isinstance(layer, DepthwiseConv2D):
            wsum = w.sum(axis=(0, 1)).reshape(-1)  # (ci*mult,) group-major
        else:  # Dense
            wsum = w.sum(axis=0)
        return (lq.b_q.astype(np.int64) - zp * wsum).astype(np.int32)


def check_quantizable(graph: CNNGraph) -> None:
    """The int8 path supports the *optimized* layer set; anything the
    NNCG passes should have removed is rejected with a pointer."""
    sink = graph.sink
    for layer in graph.layers:
        if isinstance(layer, BatchNorm):
            raise ValueError(
                f"{layer.name}: BatchNorm is not quantizable — run "
                "passes.optimize first (folds BN into the conv)")
        if isinstance(layer, Softmax) and layer is not sink:
            raise ValueError(
                f"{layer.name}: standalone Softmax is only supported as "
                "the graph output in int8 mode")
        if getattr(layer, "activation", None) == "softmax" \
                and layer is not sink:
            raise ValueError(
                f"{layer.name}: fused softmax is only supported on the "
                "graph output in int8 mode")
    if not isinstance(sink, _WEIGHTED + (Softmax,)):
        raise ValueError(
            f"sink {sink.name} ({type(sink).__name__}): int8 mode "
            "requires a Conv2D/DepthwiseConv2D/Dense (or Softmax) output "
            "layer to dequantize into")


def calibrate(graph: CNNGraph, xs: np.ndarray) -> Dict[str, QParams]:
    """Run the calibration batch through the XLA float oracle and record
    per-tensor (post-activation) ranges for every layer output."""
    from . import jax_exec  # deferred: keep quantize importable sans jax
    import jax.numpy as jnp

    xs = np.asarray(xs, np.float32)
    if xs.ndim == 3:
        xs = xs[None]
    assert xs.ndim == 4 and xs.shape[1:] == tuple(graph.input_shape), (
        f"calibration batch must be (N,)+{tuple(graph.input_shape)}, "
        f"got {xs.shape}")

    vals: Dict[str, "jnp.ndarray"] = {}
    x = jnp.asarray(xs)
    for layer in graph.layers:
        if isinstance(layer, Input):
            vals[layer.name] = x
        else:
            vals[layer.name] = jax_exec._apply(
                layer, [vals[n] for n in layer.inputs])

    acts: Dict[str, QParams] = {}
    for layer in graph.layers:
        if isinstance(layer, _SHARE_INPUT_QPARAMS):
            acts[layer.name] = acts[layer.inputs[0]]
            continue
        v = np.asarray(vals[layer.name])
        acts[layer.name] = qparams_from_range(v.min(), v.max())
    return acts


def quantize_weights(layer) -> LayerQuant:
    """Symmetric per-output-channel int8 weights + int32 bias."""
    w = np.asarray(layer.weights, np.float64)
    if isinstance(layer, Conv2D):
        absmax = np.abs(w).max(axis=(0, 1, 2))          # (c_out,)
    elif isinstance(layer, DepthwiseConv2D):
        absmax = np.abs(w).max(axis=(0, 1)).reshape(-1)  # (ci*mult,)
    elif isinstance(layer, Dense):
        absmax = np.abs(w).max(axis=0)                   # (d_out,)
    else:  # pragma: no cover
        raise TypeError(f"{layer.name}: not a weighted layer")
    scale = np.where(absmax > 0, absmax / QMAX, 1.0)
    scale = scale.astype(np.float32)

    if isinstance(layer, DepthwiseConv2D):
        per_tap = scale.reshape(w.shape[2], w.shape[3])[None, None]
    else:
        per_tap = scale
    w_q = np.clip(np.round(w / per_tap.astype(np.float64)),
                  -QMAX, QMAX).astype(np.int8)
    return LayerQuant(w_scale=scale, w_q=w_q,
                      b_q=np.zeros(scale.shape, np.int32))


def quantize_graph(graph: CNNGraph,
                   acts: Dict[str, QParams]) -> QuantizedGraph:
    """Annotate a calibrated graph with quantized weights and biases."""
    check_quantizable(graph)
    qg = QuantizedGraph(graph=graph, acts=dict(acts))
    for layer in graph.layers:
        if not isinstance(layer, _WEIGHTED):
            continue
        lq = quantize_weights(layer)
        s_in = float(acts[layer.inputs[0]].scale)
        bias_scale = s_in * lq.w_scale.astype(np.float64)
        lq.b_q = np.round(
            np.asarray(layer.bias, np.float64) / bias_scale
        ).astype(np.int32)
        qg.weights[layer.name] = lq
    return qg


def quantize(graph: CNNGraph, calibration: np.ndarray) -> QuantizedGraph:
    """The two-step pipeline: calibrate on samples, annotate the graph."""
    return quantize_graph(graph, calibrate(graph, calibration))


def quantization_error(qg: QuantizedGraph,
                       xs: np.ndarray,
                       ref: Optional[np.ndarray] = None) -> dict:
    """Accuracy probe: int8 vs float oracle on a batch — max |Δ| and
    top-1 agreement over the channel axis (the calibration-set gate)."""
    from . import jax_exec
    xs = np.asarray(xs, np.float32)
    if ref is None:
        ref = np.asarray(jax_exec.make_vmap_forward(qg.graph)(xs))
    got = np.asarray(jax_exec.forward_quantized(qg, xs))
    ref_f = ref.reshape(ref.shape[0], -1)
    got_f = got.reshape(got.shape[0], -1)
    return {
        "max_abs_err": float(np.abs(got_f - ref_f).max()),
        "top1_agreement": float(
            (got_f.argmax(-1) == ref_f.argmax(-1)).mean()),
    }
