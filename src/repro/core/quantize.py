"""Post-training int8 quantization (calibration + qparam annotation).

The paper's design principles all exploit full knowledge of the trained
net at generation time; this module extends that to the *value ranges*:
a calibration pass streams sample inputs through the float oracle
(:func:`repro.core.jax_exec.forward`) in chunks, accumulates a
fixed-bin histogram per tensor (:class:`Observer`), selects a
quantization range per tensor with a pluggable method —

* ``"minmax"``     — the exact observed range (the historical default);
* ``"percentile"`` — clip each tail to the e.g. 99.99th percentile of
  the observed distribution, so a handful of outliers stop inflating
  the quantization step for everything else;
* ``"mse"``        — grid-search the clipped range minimizing the
  quantization mean-squared-error over the histogram;
* ``"entropy"``    — grid-search the clipped range minimizing the
  KL divergence between the observed distribution and its int8
  reconstruction (the TensorRT-style information-loss criterion);

— and derives:

* **activations** — per-tensor *asymmetric* int8 ``(scale, zero_point)``
  over the observed post-activation range (zero always exactly
  representable, so ReLU clamps and zero padding stay exact);
* **conv / depthwise / dense weights** — *per-output-channel symmetric*
  int8 scales (no zero point), the standard PTQ recipe;
* **biases** — int32 at scale ``s_in * s_w[k]``.

The quantized execution scheme (shared bit-for-bit by the generated C
and the :func:`repro.core.jax_exec.forward_quantized` reference):

* int8 storage for every intermediate tensor, int32 accumulation;
* requantization by a float32 multiplier ``M[k] = s_in*s_w[k]/s_out``
  applied as ``floor(acc * M + 0.5)`` (round-half-up) — float32
  multiply/add/floor are deterministic IEEE-754 ops, so the C build and
  the XLA reference agree *exactly* on the integer path;
* fused ReLU / LeakyReLU applied to the float requant value (both are
  positively-homogeneous, so they commute with the output scale);
* the sink layer dequantizes its int32 accumulator straight to float
  (softmax, when present, runs in float32) — the public API stays
  float-in / float-out.

Multi-input layers (Add, Concat) are **per-branch**: every input edge
keeps the qparams of its own producer and both the generated C and the
jax reference requantize per edge (``rescale(layer, idx)``), so a
narrow branch never inherits the step size of a wide sibling.  The
Concat *output* range is the union of its inputs' *calibrated* ranges
(computed per branch, then merged) — never a histogram over the mixed
concatenated tensor, where one wide branch would decide the clip for
all of them.

**Per-channel requant zero points** (opt-in, ``per_channel=True``):
an eligible weighted layer's *activation* gets per-output-channel
``(scale[k], zero_point[k])`` instead of one per-tensor pair —
a channel whose range is a fraction of its widest sibling's gets a
proportionally finer step.  The integer inner loops never change:
the producer's requant epilogue indexes a per-channel multiplier and
zero-point table (it already indexed the multiplier table), and every
*consumer* folds the producer's per-channel scales into its own weight
quantization (``w_eff[.., ci, k] = w[.., ci, k] * s_x[ci]``, then the
usual per-output-channel symmetric scheme) and the per-channel input
zero points into its int32 effective bias — a dot product over raw
codes, exactly as before.  Eligibility (see
:func:`per_channel_eligible`): weighted, non-sink, non-softmax, and
every consumer is a weighted layer reading it directly without
padding (a padded consumer would need a per-channel pad fill).

Every scale used anywhere is computed **here** and cast to float32
once, so the code generator (which prints it via ``_flit``, a bit-exact
round-trip) and the jax reference (which closes over the same array)
can never disagree.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from .graph import (
    BatchNorm,
    CNNGraph,
    Concat,
    Conv2D,
    Dense,
    DepthwiseConv2D,
    Dropout,
    Flatten,
    GlobalAvgPool,
    Input,
    MaxPool,
    Softmax,
    pool_window_counts,
)
from .numerics import round_half_up

QMIN, QMAX = -128, 127

# layers whose int8 output reuses the producer's qparams unchanged:
# identity layers alias the buffer; MaxPool commutes with any monotone
# quantization, so sharing qparams makes it a pure int8 max (no requant)
_SHARE_INPUT_QPARAMS = (Dropout, Flatten, MaxPool)

# weighted layers that get per-output-channel symmetric weight scales
_WEIGHTED = (Conv2D, DepthwiseConv2D, Dense)


@dataclass(frozen=True)
class QParams:
    """Asymmetric per-tensor int8 affine quantization:
    ``real = scale * (q - zero_point)``."""

    scale: float  # stored as the exact float32 value
    zero_point: int

    @property
    def inv_scale(self) -> np.float32:
        """The float32 multiplier the input-quantization step uses —
        computed once here so C literal and jax constant agree."""
        return np.float32(1.0 / float(self.scale))

    def quantize(self, x: np.ndarray) -> np.ndarray:
        """Reference quantizer: float -> int8 codes (round half up) —
        the same ``floor(x*inv + 0.5) + zp`` the C and jax paths use."""
        t = np.asarray(x, np.float32) * self.inv_scale
        q = round_half_up(t).astype(np.int64) + self.zero_point
        return np.clip(q, QMIN, QMAX).astype(np.int8)

    def dequantize(self, q: np.ndarray) -> np.ndarray:
        return ((np.asarray(q, np.int32) - self.zero_point)
                * np.float32(self.scale)).astype(np.float32)


@dataclass(frozen=True)
class ChannelQParams:
    """Per-channel asymmetric int8 affine quantization of one
    activation tensor: ``real[..., k] = scale[k] * (q[..., k] -
    zero_point[k])`` over the channel (last) axis."""

    scale: np.ndarray       # (C,) float32
    zero_point: np.ndarray  # (C,) int32

    @property
    def inv_scale(self) -> np.ndarray:
        """(C,) float32 multipliers — same construction rule as
        :meth:`QParams.inv_scale`, per channel."""
        return np.float32(1.0 / self.scale.astype(np.float64))

    def quantize(self, x: np.ndarray) -> np.ndarray:
        t = np.asarray(x, np.float32) * self.inv_scale
        q = round_half_up(t).astype(np.int64) \
            + self.zero_point.astype(np.int64)
        return np.clip(q, QMIN, QMAX).astype(np.int8)

    def dequantize(self, q: np.ndarray) -> np.ndarray:
        return ((np.asarray(q, np.int32) - self.zero_point)
                * self.scale).astype(np.float32)


def qparams_from_range(mn: float, mx: float) -> QParams:
    """Derive (scale, zero_point) from an observed float range.

    The range is widened to include zero so that 0.0 is exactly
    representable (``q == zero_point``) — required for exact ReLU
    clamps and for padding int8 feature maps with the zero code.
    The zero point rounds half **up** (``floor(x + 0.5)``), the same
    scheme every quantization step in the C build and the jax
    reference uses — not Python's banker's ``round``."""
    mn = min(float(mn), 0.0)
    mx = max(float(mx), 0.0)
    scale = (mx - mn) / float(QMAX - QMIN)
    if scale == 0.0:  # constant-zero tensor
        scale = 1.0
    scale = float(np.float32(scale))
    zp = int(np.clip(round_half_up(QMIN - mn / scale), QMIN, QMAX))
    return QParams(scale=scale, zero_point=zp)


def channel_qparams_from_range(mn: np.ndarray,
                               mx: np.ndarray) -> ChannelQParams:
    """Vectorized :func:`qparams_from_range` over the channel axis —
    the same zero-widening, float32 scale cast, and half-up zero-point
    rule, applied elementwise."""
    mn = np.minimum(np.asarray(mn, np.float64), 0.0)
    mx = np.maximum(np.asarray(mx, np.float64), 0.0)
    scale = (mx - mn) / float(QMAX - QMIN)
    scale = np.where(scale == 0.0, 1.0, scale).astype(np.float32)
    zp = np.clip(round_half_up(QMIN - mn / scale.astype(np.float64)),
                 QMIN, QMAX).astype(np.int32)
    return ChannelQParams(scale=scale, zero_point=zp)


def per_channel_eligible(graph: CNNGraph) -> list:
    """Layer names whose *activation* may quantize per channel.

    The scheme keeps integer inner loops unchanged by moving all
    per-channel bookkeeping to constants: the producer's requant
    epilogue indexes zero-point/multiplier tables it already has the
    loop structure for, and each consumer folds ``s_x[ci]`` into its
    weight quantization and ``zp_x[ci]`` into its effective bias.
    That fold only exists for weighted consumers, so eligibility is:
    weighted, not the sink (the sink dequantizes to float), activation
    not softmax, and every consumer a Conv2D/DepthwiseConv2D/Dense
    reading the producer directly with zero padding (a padded consumer
    fills with the producer's zero code — a scalar, which a per-channel
    zero point no longer is)."""
    smap = graph.shape_map()
    cons = graph.consumers()
    sink = graph.sink.name
    out = []
    for p in graph.layers:
        if not isinstance(p, _WEIGHTED) or p.name == sink:
            continue
        if p.activation == "softmax":
            continue
        cs = cons[p.name]
        if not cs:
            continue
        ok = True
        for c in cs:
            if not isinstance(c, _WEIGHTED) or c.inputs[0] != p.name:
                ok = False
                break
            if isinstance(c, (Conv2D, DepthwiseConv2D)) \
                    and any(c.pad_amounts(smap[p.name])):
                ok = False
                break
        if ok:
            out.append(p.name)
    return out


# ---------------------------------------------------------------------------
# calibration observers (streaming histograms + range selection)
# ---------------------------------------------------------------------------

CALIBRATION_METHODS = ("minmax", "percentile", "mse", "entropy")


class Observer:
    """Streaming per-tensor range observer.

    Accumulates an exact running min/max plus a fixed-bin histogram
    over chunked calibration batches — one chunk's activations at a
    time, so calibration memory is bounded by the chunk, not the whole
    calibration set.  When a later chunk falls outside the current
    histogram span, the span grows to the union and the existing
    counts are redistributed onto the new uniform grid by linear
    interpolation of the cumulative mass (the standard piecewise-
    uniform merge); the min/max themselves always stay exact, so the
    ``minmax`` method reproduces the historical single-pass behavior
    bit-for-bit.
    """

    def __init__(self, nbins: int = 2048):
        assert nbins >= 16, "need a usable histogram resolution"
        self.nbins = int(nbins)
        self.mn = np.inf
        self.mx = -np.inf
        self.counts: Optional[np.ndarray] = None
        self.edges: Optional[np.ndarray] = None

    def update(self, x: np.ndarray) -> None:
        x = np.asarray(x, np.float32).ravel()
        if x.size == 0:
            return
        cmn, cmx = float(x.min()), float(x.max())
        self.mn = min(self.mn, cmn)
        self.mx = max(self.mx, cmx)
        if self.counts is None:
            counts, edges = np.histogram(
                x, bins=self.nbins, range=(cmn, cmx))
            self.counts = counts.astype(np.int64)
            self.edges = edges
            return
        lo, hi = float(self.edges[0]), float(self.edges[-1])
        if cmn < lo or cmx > hi:
            new_lo, new_hi = min(lo, cmn), max(hi, cmx)
            new_edges = np.linspace(new_lo, new_hi, self.nbins + 1)
            cum = np.concatenate([[0.0], np.cumsum(self.counts)])
            remapped = np.diff(np.interp(new_edges, self.edges, cum,
                                         left=0.0, right=cum[-1]))
            self.counts = remapped  # float mass from here on
            self.edges = new_edges
            lo, hi = new_lo, new_hi
        counts, _ = np.histogram(x, bins=self.nbins, range=(lo, hi))
        self.counts = self.counts + counts

    # -- range selection -----------------------------------------------------

    def range_minmax(self) -> Tuple[float, float]:
        assert np.isfinite(self.mn), "Observer.update never called"
        return float(self.mn), float(self.mx)

    def range_percentile(self, percentile: float) -> Tuple[float, float]:
        """Clip each tail to ``(100 - percentile)/2`` % of the observed
        mass (two-sided, asymmetric-friendly); the selected edges come
        from the histogram grid, min/max-clamped."""
        assert 50.0 < percentile <= 100.0, percentile
        assert self.counts is not None, "Observer.update never called"
        total = float(self.counts.sum())
        if total == 0.0:
            return self.range_minmax()
        tail = total * (100.0 - percentile) / 100.0 / 2.0
        cum = np.cumsum(self.counts)
        lo_bin = int(np.searchsorted(cum, tail, side="right"))
        hi_bin = int(np.searchsorted(cum, total - tail, side="left"))
        lo_bin = min(lo_bin, self.nbins - 1)
        hi_bin = max(min(hi_bin, self.nbins - 1), lo_bin)
        lo = max(float(self.edges[lo_bin]), self.mn)
        hi = min(float(self.edges[hi_bin + 1]), self.mx)
        return min(lo, hi), max(lo, hi)

    def range_mse(self, grid: int = 24) -> Tuple[float, float]:
        """Coordinate search over clipped ranges for the one minimizing
        the int8 quantization MSE of the histogram mass (bin centers
        weighted by counts, clipped values saturate — exactly what the
        int8 path does to them).  The full min/max range is always a
        candidate, so ``mse`` can never select something worse than
        ``minmax`` *on the calibration distribution itself*."""
        mn, mx = self.range_minmax()
        if mn == mx:
            return mn, mx
        centers = ((self.edges[:-1] + self.edges[1:]) * 0.5)
        weights = np.asarray(self.counts, np.float64)

        def err(lo: float, hi: float) -> float:
            lo2, hi2 = min(lo, 0.0), max(hi, 0.0)
            scale = (hi2 - lo2) / float(QMAX - QMIN)
            if scale <= 0.0:
                return np.inf
            zp = round_half_up(QMIN - lo2 / scale)
            q = np.clip(round_half_up(centers / scale) + zp, QMIN, QMAX)
            deq = (q - zp) * scale
            return float(((centers - deq) ** 2 * weights).sum())

        los = mn * np.linspace(1.0, 1.0 / grid, grid) if mn < 0 else [mn]
        his = mx * np.linspace(1.0, 1.0 / grid, grid) if mx > 0 else [mx]
        best = (err(mn, mx), mn, mx)
        lo = mn
        for _ in range(2):  # alternate the two ends (coordinate descent)
            for h in his:
                e = err(lo, float(h))
                if e < best[0]:
                    best = (e, lo, float(h))
            hi = best[2]
            for l_ in los:
                e = err(float(l_), hi)
                if e < best[0]:
                    best = (e, float(l_), hi)
            lo = best[1]
        return best[1], best[2]

    def range_entropy(self, grid: int = 24) -> Tuple[float, float]:
        """Coordinate search over clipped ranges for the one minimizing
        the KL divergence ``KL(P || Q)`` between the observed histogram
        mass ``P`` and its int8 reconstruction ``Q`` (``P`` collapsed
        onto the 256 codes, then spread back uniformly over each code's
        bins) — the information-loss criterion.  Saturating a bin that
        holds observed mass relocates its reconstruction out of the bin
        entirely (``Q = 0`` where ``P > 0``), so such candidates score
        ``KL = inf``: entropy only ever trims *empty* outlier gaps of
        the histogram, trading them for a finer in-range step.  Same
        ``los``/``his`` candidate grid and alternating two-end descent
        as :meth:`range_mse`, and the full min/max range is always a
        candidate — on the calibration distribution itself the choice
        can never represent less mass than ``minmax`` does."""
        mn, mx = self.range_minmax()
        if mn == mx:
            return mn, mx
        centers = ((self.edges[:-1] + self.edges[1:]) * 0.5)
        weights = np.asarray(self.counts, np.float64)
        total = float(weights.sum())
        if total == 0.0:
            return mn, mx
        P = weights / total

        def err(lo: float, hi: float) -> float:
            lo2, hi2 = min(lo, 0.0), max(hi, 0.0)
            scale = (hi2 - lo2) / float(QMAX - QMIN)
            if scale <= 0.0:
                return np.inf
            zp = round_half_up(QMIN - lo2 / scale)
            q = round_half_up(centers / scale) + zp
            keep = (q >= QMIN) & (q <= QMAX)
            if float(P[~keep].sum()) > 0.0:
                return np.inf  # saturates observed mass: not entropy's trade
            codes = q[keep].astype(np.int64) - QMIN
            code_mass = np.bincount(codes, weights=P[keep], minlength=256)
            code_bins = np.bincount(codes, minlength=256)
            Q = code_mass[codes] / code_bins[codes]
            Pk = P[keep]
            nz = Pk > 0.0
            return float((Pk[nz] * np.log(Pk[nz] / Q[nz])).sum())

        los = mn * np.linspace(1.0, 1.0 / grid, grid) if mn < 0 else [mn]
        his = mx * np.linspace(1.0, 1.0 / grid, grid) if mx > 0 else [mx]
        best = (err(mn, mx), mn, mx)
        lo = mn
        for _ in range(2):  # alternate the two ends (coordinate descent)
            for h in his:
                e = err(lo, float(h))
                if e < best[0]:
                    best = (e, lo, float(h))
            hi = best[2]
            for l_ in los:
                e = err(float(l_), hi)
                if e < best[0]:
                    best = (e, float(l_), hi)
            lo = best[1]
        return best[1], best[2]

    def select_range(self, method: str,
                     percentile: float = 99.99) -> Tuple[float, float]:
        if method == "minmax":
            return self.range_minmax()
        if method == "percentile":
            return self.range_percentile(percentile)
        if method == "mse":
            return self.range_mse()
        if method == "entropy":
            return self.range_entropy()
        raise ValueError(
            f"unknown calibration method {method!r}; "
            f"expected one of {CALIBRATION_METHODS}")


@dataclass
class LayerQuant:
    """Quantized parameters of one weighted layer (weights keep their
    graph layout: HWIO / HWCM / ``(d_in, d_out)``)."""

    w_scale: np.ndarray  # (c_out,) float32, symmetric per-channel
    w_q: np.ndarray      # int8
    b_q: np.ndarray      # int32 at scale s_in * s_w[k]
    # True when the producer's per-channel input scales were folded
    # into the weights before quantization: ``w_scale`` then already
    # carries the input-scale dimension, so every derived constant
    # drops its ``s_in`` factor (bias scale, requant, dequant).
    in_folded: bool = False


@dataclass
class QuantizedGraph:
    """A trained graph annotated with calibration-derived qparams."""

    graph: CNNGraph
    acts: Dict[str, QParams]          # layer name -> output qparams
    weights: Dict[str, LayerQuant] = field(default_factory=dict)
    # how the activation ranges were selected (threads through session
    # info, autotune cache keys, and benchmark records)
    method: str = "minmax"
    percentile: float = 99.99
    # the selected (lo, hi) float range per observed tensor — what the
    # method actually chose, before the zero-widening in
    # qparams_from_range (debug/info; Concat entries are the union of
    # their branches' calibrated ranges)
    ranges: Dict[str, Tuple[float, float]] = field(default_factory=dict)
    # per-channel activation qparams for the layers
    # :func:`per_channel_eligible` admitted (opt-in; empty by default).
    # A name present here overrides its scalar ``acts`` entry for the
    # int8 execution path; the scalar entry is kept for info/digest.
    channel_acts: Dict[str, ChannelQParams] = field(default_factory=dict)

    # -- qparam lookups ------------------------------------------------------

    def out_qp(self, layer) -> QParams:
        return self.acts[layer.name]

    def in_qp(self, layer, idx: int = 0) -> QParams:
        return self.acts[layer.inputs[idx]]

    def channel_qp(self, name: str) -> Optional[ChannelQParams]:
        """Per-channel qparams of ``name``'s output, or None."""
        return self.channel_acts.get(name)

    def in_channel_qp(self, layer, idx: int = 0) \
            -> Optional[ChannelQParams]:
        return self.channel_acts.get(layer.inputs[idx])

    @property
    def input_qp(self) -> QParams:
        return self.acts[self.graph.layers[0].name]

    # -- derived constants (single source for cgen AND the jax ref) ----------

    def requant_scales(self, layer) -> np.ndarray:
        """(c_out,) float32: ``s_in * s_w[k] / s_out``.

        Per-channel variants fold into the same shape: an ``in_folded``
        layer's ``w_scale`` already carries ``s_in``, and a per-channel
        *output* divides by the per-channel ``s_out[k]`` vector — the
        epilogue still reads one multiplier per output channel."""
        lq = self.weights[layer.name]
        if lq.in_folded:
            num = lq.w_scale.astype(np.float64)
        else:
            s_in = float(self.in_qp(layer).scale)
            num = s_in * lq.w_scale.astype(np.float64)
        cq = self.channel_qp(layer.name)
        if cq is not None:
            return np.float32(num / cq.scale.astype(np.float64))
        return np.float32(num / float(self.out_qp(layer).scale))

    def dequant_scales(self, layer) -> np.ndarray:
        """(c_out,) float32: ``s_in * s_w[k]`` — sink dequantization."""
        lq = self.weights[layer.name]
        if lq.in_folded:
            return np.float32(lq.w_scale.astype(np.float64))
        s_in = float(self.in_qp(layer).scale)
        return np.float32(s_in * lq.w_scale.astype(np.float64))

    def rescale(self, layer, idx: int = 0) -> np.float32:
        """float32 ``s_in_idx / s_out`` for Add/Concat/ReLU requant."""
        return np.float32(float(self.in_qp(layer, idx).scale)
                          / float(self.out_qp(layer).scale))

    def pool_scales(self, layer, in_shape) -> np.ndarray:
        """AvgPool/GlobalAvgPool requant multipliers.

        AvgPool: ``(oh, ow)`` float32 ``s_in / (s_out * count[i,j])``
        with the edge-correct per-window valid-tap count.
        GlobalAvgPool: scalar float32 ``s_in / (s_out * h*w)``."""
        s_in = float(self.in_qp(layer).scale)
        s_out = float(self.out_qp(layer).scale)
        if isinstance(layer, GlobalAvgPool):
            return np.float32(s_in / (s_out * in_shape[0] * in_shape[1]))
        counts = pool_window_counts(in_shape, layer.size, layer.strides,
                                    layer.pad_amounts(in_shape))
        return np.float32(s_in / (s_out * counts.astype(np.float64)))

    def effective_bias(self, layer, x_offset: int = 0) -> np.ndarray:
        """(c_out,) int32: bias with the input zero-point correction
        folded in (``b_q[k] - zp_in * sum_taps w_q[...,k]``), so the C
        inner loop is a plain raw-code dot product — padding an int8
        feature map with the zero code then cancels exactly.

        ``x_offset=128`` is the u8·s8 kernel variant's view
        (``vpmaddubsw``/``vpdpbusd`` take *unsigned* activations): the
        emitter re-biases every int8 code by +128 (one XOR of the sign
        bit), and this fold subtracts the matching ``128 * sum(w)`` —
        the int32 accumulator is bit-identical to the signed kernels'."""
        lq = self.weights[layer.name]
        w = lq.w_q.astype(np.int64)
        cin = self.in_channel_qp(layer)
        if cin is not None:
            # per-channel input zero points: the correction is a per-
            # input-channel weighted sum instead of zp * sum(w)
            zpv = cin.zero_point.astype(np.int64) + x_offset
            if isinstance(layer, Conv2D):
                zsum = np.einsum("hwck,c->k", w, zpv)
            elif isinstance(layer, DepthwiseConv2D):
                zsum = (w.sum(axis=(0, 1))
                        * zpv[:, None]).reshape(-1)  # (ci*mult,)
            else:  # Dense: flattened NHWC input, channel fastest
                zfull = np.tile(zpv, w.shape[0] // zpv.size)
                zsum = (w * zfull[:, None]).sum(axis=0)
            return (lq.b_q.astype(np.int64) - zsum).astype(np.int32)
        zp = self.in_qp(layer).zero_point + x_offset
        if isinstance(layer, Conv2D):
            wsum = w.sum(axis=(0, 1, 2))
        elif isinstance(layer, DepthwiseConv2D):
            wsum = w.sum(axis=(0, 1)).reshape(-1)  # (ci*mult,) group-major
        else:  # Dense
            wsum = w.sum(axis=0)
        return (lq.b_q.astype(np.int64) - zp * wsum).astype(np.int32)


def check_quantizable(graph: CNNGraph) -> None:
    """The int8 path supports the *optimized* layer set; anything the
    NNCG passes should have removed is rejected with a pointer."""
    sink = graph.sink
    for layer in graph.layers:
        if isinstance(layer, BatchNorm):
            raise ValueError(
                f"{layer.name}: BatchNorm is not quantizable — run "
                "passes.optimize first (folds BN into the conv)")
        if isinstance(layer, Softmax) and layer is not sink:
            raise ValueError(
                f"{layer.name}: standalone Softmax is only supported as "
                "the graph output in int8 mode")
        if getattr(layer, "activation", None) == "softmax" \
                and layer is not sink:
            raise ValueError(
                f"{layer.name}: fused softmax is only supported on the "
                "graph output in int8 mode")
    if not isinstance(sink, _WEIGHTED + (Softmax,)):
        raise ValueError(
            f"sink {sink.name} ({type(sink).__name__}): int8 mode "
            "requires a Conv2D/DepthwiseConv2D/Dense (or Softmax) output "
            "layer to dequantize into")


def calibrate(graph: CNNGraph, xs: np.ndarray, *,
              method: str = "minmax",
              percentile: float = 99.99,
              nbins: int = 2048,
              chunk_size: int = 8,
              ranges_out: Optional[Dict[str, Tuple[float, float]]] = None,
              channel_names: Tuple[str, ...] = (),
              channel_out: Optional[Dict[str, ChannelQParams]] = None,
              ) -> Dict[str, QParams]:
    """Stream the calibration batch through the float oracle in chunks
    and derive per-tensor (post-activation) qparams.

    Each chunk runs layer by layer; every observed tensor updates its
    :class:`Observer` (exact min/max + fixed-bin histogram) and is
    dropped as soon as its last in-chunk consumer has run — peak
    calibration memory is one chunk's live set, not the whole
    calibration batch across all layers.  ``method`` selects the range
    per tensor (see :data:`CALIBRATION_METHODS`).

    Per-branch rule for multi-input layers: qparams are selected on
    each *producer* tensor independently (so a Concat branch with a
    narrow range is clipped on its own distribution), and a Concat
    output takes the **union of its branches' calibrated ranges** —
    the generated C and the jax reference then requantize each input
    edge with its own ``rescale(layer, idx)`` multiplier.

    ``channel_names`` requests additional per-output-channel exact
    min/max tracking for those layers (the per-channel path always
    uses minmax — 2048-bin histograms per channel would dwarf the
    model); results land in ``channel_out`` as
    :class:`ChannelQParams`.
    """
    from . import jax_exec  # deferred: keep quantize importable sans jax
    import jax.numpy as jnp

    if method not in CALIBRATION_METHODS:
        raise ValueError(
            f"unknown calibration method {method!r}; "
            f"expected one of {CALIBRATION_METHODS}")
    xs = np.asarray(xs, np.float32)
    if xs.ndim == 3:
        xs = xs[None]
    assert xs.ndim == 4 and xs.shape[1:] == tuple(graph.input_shape), (
        f"calibration batch must be (N,)+{tuple(graph.input_shape)}, "
        f"got {xs.shape}")

    # layers whose qparams are derived, not observed: identity/MaxPool
    # share their producer's; Concat takes the union of its branches
    derived = {l.name for l in graph.layers
               if isinstance(l, _SHARE_INPUT_QPARAMS + (Concat,))}
    # refcounts for in-chunk eviction (a value dies after its last use;
    # the sink is kept through its own step only)
    n_consumers: Dict[str, int] = {l.name: 0 for l in graph.layers}
    for layer in graph.layers:
        for src in layer.inputs:
            n_consumers[src] += 1

    observers: Dict[str, Observer] = {
        l.name: Observer(nbins) for l in graph.layers
        if l.name not in derived}
    ch_set = frozenset(channel_names)
    ch_mn: Dict[str, np.ndarray] = {}
    ch_mx: Dict[str, np.ndarray] = {}

    chunk_size = max(1, int(chunk_size))
    for c0 in range(0, len(xs), chunk_size):
        x = jnp.asarray(xs[c0:c0 + chunk_size])
        vals: Dict[str, "jnp.ndarray"] = {}
        pending: Dict[str, int] = dict(n_consumers)
        for layer in graph.layers:
            if isinstance(layer, Input):
                vals[layer.name] = x
            else:
                vals[layer.name] = jax_exec._apply(
                    layer, [vals[n] for n in layer.inputs])
            if layer.name in observers:
                observers[layer.name].update(np.asarray(vals[layer.name]))
            if layer.name in ch_set:
                v = np.asarray(vals[layer.name], np.float32)
                v = v.reshape(-1, v.shape[-1])
                cmn, cmx = v.min(axis=0), v.max(axis=0)
                if layer.name in ch_mn:
                    ch_mn[layer.name] = np.minimum(ch_mn[layer.name], cmn)
                    ch_mx[layer.name] = np.maximum(ch_mx[layer.name], cmx)
                else:
                    ch_mn[layer.name] = cmn
                    ch_mx[layer.name] = cmx
            for src in layer.inputs:
                pending[src] -= 1
                if pending[src] == 0:
                    del vals[src]  # streaming: chunk-local liveness
            if pending[layer.name] == 0:
                del vals[layer.name]

    ranges: Dict[str, Tuple[float, float]] = {}
    acts: Dict[str, QParams] = {}
    for layer in graph.layers:
        name = layer.name
        if isinstance(layer, _SHARE_INPUT_QPARAMS):
            acts[name] = acts[layer.inputs[0]]
            ranges[name] = ranges[layer.inputs[0]]
            continue
        if isinstance(layer, Concat):
            # per-branch: union of the branches' calibrated ranges
            branch = [ranges[n] for n in layer.inputs]
            lo = min(b[0] for b in branch)
            hi = max(b[1] for b in branch)
            ranges[name] = (lo, hi)
        else:
            ranges[name] = observers[name].select_range(method, percentile)
        acts[name] = qparams_from_range(*ranges[name])
    if ranges_out is not None:
        ranges_out.update(ranges)
    if channel_out is not None:
        for name in ch_set:
            channel_out[name] = channel_qparams_from_range(
                ch_mn[name], ch_mx[name])
    return acts


def quantize_weights(layer,
                     in_scales: Optional[np.ndarray] = None) -> LayerQuant:
    """Symmetric per-output-channel int8 weights + int32 bias.

    ``in_scales`` (producer per-channel activation scales, one per
    input channel) folds into the weights before quantization:
    ``w_eff[.., ci, k] = w[.., ci, k] * s_x[ci]``, so the consumer's
    raw-code dot product implicitly rescales each input channel —
    the integer inner loop is unchanged."""
    w = np.asarray(layer.weights, np.float64)
    if in_scales is not None:
        s = np.asarray(in_scales, np.float64)
        if isinstance(layer, (Conv2D, DepthwiseConv2D)):
            w = w * s[None, None, :, None]        # HWIO / HWCM ci axis
        else:  # Dense: flattened NHWC input, channel fastest
            w = w * np.tile(s, w.shape[0] // s.size)[:, None]
    if isinstance(layer, Conv2D):
        absmax = np.abs(w).max(axis=(0, 1, 2))          # (c_out,)
    elif isinstance(layer, DepthwiseConv2D):
        absmax = np.abs(w).max(axis=(0, 1)).reshape(-1)  # (ci*mult,)
    elif isinstance(layer, Dense):
        absmax = np.abs(w).max(axis=0)                   # (d_out,)
    else:  # pragma: no cover
        raise TypeError(f"{layer.name}: not a weighted layer")
    scale = np.where(absmax > 0, absmax / QMAX, 1.0)
    scale = scale.astype(np.float32)

    if isinstance(layer, DepthwiseConv2D):
        per_tap = scale.reshape(w.shape[2], w.shape[3])[None, None]
    else:
        per_tap = scale
    w_q = np.clip(np.round(w / per_tap.astype(np.float64)),
                  -QMAX, QMAX).astype(np.int8)
    return LayerQuant(w_scale=scale, w_q=w_q,
                      b_q=np.zeros(scale.shape, np.int32),
                      in_folded=in_scales is not None)


def quantize_graph(graph: CNNGraph,
                   acts: Dict[str, QParams],
                   channel_acts: Optional[Dict[str, ChannelQParams]] = None,
                   ) -> QuantizedGraph:
    """Annotate a calibrated graph with quantized weights and biases."""
    check_quantizable(graph)
    channel_acts = dict(channel_acts or {})
    qg = QuantizedGraph(graph=graph, acts=dict(acts),
                        channel_acts=channel_acts)
    for layer in graph.layers:
        if not isinstance(layer, _WEIGHTED):
            continue
        cin = channel_acts.get(layer.inputs[0])
        lq = quantize_weights(
            layer, in_scales=None if cin is None else cin.scale)
        if cin is None:
            s_in = float(acts[layer.inputs[0]].scale)
            bias_scale = s_in * lq.w_scale.astype(np.float64)
        else:  # s_in folded into w_scale already
            bias_scale = lq.w_scale.astype(np.float64)
        lq.b_q = np.round(
            np.asarray(layer.bias, np.float64) / bias_scale
        ).astype(np.int32)
        qg.weights[layer.name] = lq
    return qg


def quantize(graph: CNNGraph, calibration: np.ndarray, *,
             method: str = "minmax",
             percentile: float = 99.99,
             nbins: int = 2048,
             chunk_size: int = 8,
             per_channel: bool = False) -> QuantizedGraph:
    """The two-step pipeline: calibrate on samples (streaming histogram
    observers, range selection per ``method``), annotate the graph.

    ``per_channel=True`` additionally gives every
    :func:`per_channel_eligible` layer per-output-channel activation
    qparams (exact min/max per channel), folding the scales into the
    consumers' weight quantization — see the module docstring."""
    ranges: Dict[str, Tuple[float, float]] = {}
    ch_names = tuple(per_channel_eligible(graph)) if per_channel else ()
    channel_out: Dict[str, ChannelQParams] = {}
    acts = calibrate(graph, calibration, method=method,
                     percentile=percentile, nbins=nbins,
                     chunk_size=chunk_size, ranges_out=ranges,
                     channel_names=ch_names, channel_out=channel_out)
    qg = quantize_graph(graph, acts, channel_acts=channel_out)
    qg.method = method
    qg.percentile = percentile
    qg.ranges = ranges
    return qg


def quantize_from_qparams(graph: CNNGraph,
                          qparams: Dict[str, object]) -> QuantizedGraph:
    """Annotate a graph with *externally-determined* activation qparams
    — e.g. exported from a QAT run — skipping the calibration pass
    entirely (:class:`repro.engine.CalibrationConfig` ``qparams=...``).

    ``qparams`` maps layer name -> :class:`QParams`, ``(scale,
    zero_point)`` pair, or ``{"scale": ..., "zero_point": ...}`` dict.
    Identity/MaxPool layers (:data:`_SHARE_INPUT_QPARAMS`) may be
    omitted — they inherit their producer's entry, the same sharing
    rule :func:`calibrate` applies.  Every other layer must be present.

    Feeding back the ``acts`` dict of a calibrated
    :class:`QuantizedGraph` reproduces that build bit-for-bit: the
    weight/bias quantization depends only on the activation qparams.
    """
    acts: Dict[str, QParams] = {}
    for name, qp in qparams.items():
        if isinstance(qp, QParams):
            pass
        elif isinstance(qp, dict):
            qp = QParams(scale=float(qp["scale"]),
                         zero_point=int(qp["zero_point"]))
        elif isinstance(qp, (tuple, list)) and len(qp) == 2:
            qp = QParams(scale=float(qp[0]), zero_point=int(qp[1]))
        else:
            raise TypeError(
                f"qparams[{name!r}]: expected QParams, (scale, "
                f"zero_point), or a dict with those keys; got {qp!r}")
        if not (qp.scale > 0.0):
            raise ValueError(f"qparams[{name!r}]: scale must be > 0, "
                             f"got {qp.scale!r}")
        acts[name] = qp

    known = {l.name for l in graph.layers}
    unknown = sorted(set(acts) - known)
    if unknown:
        raise ValueError(f"qparams name {unknown[0]!r} is not a layer "
                         "of this graph")
    for layer in graph.layers:
        if layer.name in acts:
            continue
        if isinstance(layer, _SHARE_INPUT_QPARAMS):
            acts[layer.name] = acts[layer.inputs[0]]  # producer first in
            continue                                  # topological order
        raise ValueError(
            f"qparams missing for layer {layer.name!r} "
            f"({type(layer).__name__}); only identity/MaxPool layers "
            "may be omitted")

    qg = quantize_graph(graph, acts)
    qg.method = "provided"
    qg.ranges = {n: (float(qp.scale * (QMIN - qp.zero_point)),
                     float(qp.scale * (QMAX - qp.zero_point)))
                 for n, qp in qg.acts.items()}
    return qg


def qparams_digest(qg: QuantizedGraph) -> str:
    """Content hash of the calibration outcome (method + every
    activation qparam).  Two sessions whose calibration differs —
    different data, method, or percentile — must not share autotune
    cache entries for the int8 build, because the generated C embeds
    the qparams."""
    h = hashlib.sha256()
    h.update(f"{qg.method}:{qg.percentile!r};".encode())
    for name in sorted(qg.acts):
        qp = qg.acts[name]
        h.update(f"{name}={np.float32(qp.scale).tobytes().hex()}"
                 f",{qp.zero_point};".encode())
    for name in sorted(qg.channel_acts):
        cq = qg.channel_acts[name]
        h.update(f"ch:{name}="
                 f"{cq.scale.astype(np.float32).tobytes().hex()},"
                 f"{cq.zero_point.astype(np.int32).tobytes().hex()};"
                 .encode())
    return h.hexdigest()[:16]


def quantization_error(qg: QuantizedGraph,
                       xs: np.ndarray,
                       ref: Optional[np.ndarray] = None) -> dict:
    """Accuracy probe: int8 vs float oracle on a batch — max |Δ| and
    top-1 agreement over the channel axis (the calibration-set gate).

    For a 4-D (N, h, w, c) output the argmax is taken over the channel
    axis at **every spatial position** (a spatial sink like the robot
    detector head is h*w independent classifications, not one flat
    h*w*c argmax); flat outputs argmax over everything but the batch."""
    from . import jax_exec
    xs = np.asarray(xs, np.float32)
    if ref is None:
        ref = np.asarray(jax_exec.make_vmap_forward(qg.graph)(xs))
    got = np.asarray(jax_exec.forward_quantized(qg, xs))
    ref = np.asarray(ref).reshape(got.shape)
    if got.ndim == 4:  # per-position channel argmax
        agree = (got.argmax(-1) == ref.argmax(-1)).mean()
    else:
        agree = (got.reshape(got.shape[0], -1).argmax(-1)
                 == ref.reshape(ref.shape[0], -1).argmax(-1)).mean()
    return {
        "max_abs_err": float(np.abs(got - ref).max()),
        "top1_agreement": float(agree),
    }
