"""Bit-exact numeric helpers shared by codegen and quantization.

Two rules live here so the C emitters, the jax int8 oracle, and
calibration all agree *bitwise*, not just approximately:

``flit``
    Prints a float32 as the shortest C literal that parses back to the
    identical bit pattern (the paper's P3 — weights become source-code
    constants, so the printed decimal must round-trip exactly).

``round_half_up``
    ``floor(x + 0.5)`` — the single rounding rule used everywhere a
    real becomes an integer code: activation quantization, zero-point
    derivation, and the requantization epilogue the generated C emits
    as ``u = t + 0.5f; q = (int)u; q -= (float)q > u;``.  0.5 is exact
    in every IEEE-754 width, so the helper preserves the argument's
    dtype (float32 in, float32 math; float64 in, float64 math).

Both were historically copied between ``cgen.py`` and ``quantize.py``;
``tests/test_numerics.py`` property-tests that this shared version is
bit-identical to the originals.
"""
from __future__ import annotations

import numpy as np

_HALF = np.float32(0.5)


def flit(v: float) -> str:
    """Format a float32 as a C literal.

    ``unique=True`` guarantees the shortest decimal that parses back to
    the exact same float32 bit pattern (property-tested)."""
    s = np.format_float_scientific(np.float32(v), unique=True, trim="0")
    return f"{s}f"


def round_half_up(x):
    """``floor(x + 0.5)`` elementwise, dtype-preserving.

    Matches the generated C's trunc-plus-fixup floor sequence for every
    float32 value the int8 path can produce."""
    return np.floor(x + _HALF)
