"""Compile-and-load harness for NNCG-generated C.

The paper's deployment story: the generated file has no dependencies
beyond ``math.h``/``libm`` (plus SSE intrinsics when enabled), so any
ANSI C compiler — native or cross — produces the executable.  Here we
compile a shared object with the host ``cc`` and bind it via ctypes so
tests/benchmarks can call it directly against the JAX oracle.
"""
from __future__ import annotations

import contextlib
import ctypes
import hashlib
import os
import platform
import subprocess
import tempfile
import threading
import time
from dataclasses import dataclass, replace
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .cgen import CodegenOptions
from .codegen import compile as compile_graph
from .graph import CNNGraph
from .schedule import Schedule

_CACHE_DIR = os.path.join(tempfile.gettempdir(), "nncg_cache")

# observability for the engine's caching tests/telemetry: how often the
# C compiler actually ran vs. the content-hash .so cache answering
COMPILE_STATS = {"cc_invocations": 0, "so_cache_hits": 0}


def _cc() -> str:
    return os.environ.get("CC", "cc")


_CC_FINGERPRINTS: dict = {}


def cc_fingerprint() -> str:
    """First line of ``$CC --version``, cached per resolved compiler.

    Part of every content-cache key (.so cache here, tuning cache in
    the engine): a compiler change must invalidate measured artifacts.
    """
    cc = _cc()
    if cc not in _CC_FINGERPRINTS:
        try:
            out = subprocess.run([cc, "--version"], capture_output=True,
                                 text=True, timeout=10).stdout
            _CC_FINGERPRINTS[cc] = (out.splitlines()[0].strip()
                                    if out else cc)
        except (OSError, subprocess.TimeoutExpired):  # pragma: no cover
            _CC_FINGERPRINTS[cc] = cc
    return _CC_FINGERPRINTS[cc]


def compile_c(source: str, *, simd: str = "sse",
              extra_flags: Sequence[str] = (),
              key_extra: str = "") -> str:
    """Compile C source to a shared object; returns the .so path.

    The output is cached by content hash over (source, simd, flags,
    compiler), so an identical build never re-invokes the compiler and
    a toolchain change never serves a stale binary.  ``key_extra``
    folds additional provenance (e.g. the schedule digest) into the
    key; the source hash already subsumes it, but an explicit key keeps
    cache entries self-describing if codegen ever becomes ambiguous.
    """
    os.makedirs(_CACHE_DIR, exist_ok=True)
    key = hashlib.sha256(
        (source + repr(simd) + repr(tuple(extra_flags)) + key_extra
         + cc_fingerprint()).encode()
    ).hexdigest()[:16]
    so_path = os.path.join(_CACHE_DIR, f"nncg_{key}.so")
    if os.path.exists(so_path):
        COMPILE_STATS["so_cache_hits"] += 1
        return so_path
    c_path = os.path.join(_CACHE_DIR, f"nncg_{key}.c")
    with open(c_path, "w") as f:
        f.write(source)
    flags = ["-O3", "-fPIC", "-shared", "-std=c99"]
    from .cgen import ISAS, QISAS
    if simd in ISAS:
        flags.extend(ISAS[simd].cc_flags)
    elif simd in QISAS:
        flags.extend(QISAS[simd].cc_flags)
    cmd = [_cc(), *flags, *extra_flags, c_path, "-o", so_path, "-lm"]
    t0 = time.time()
    COMPILE_STATS["cc_invocations"] += 1
    proc = subprocess.run(cmd, capture_output=True, text=True)
    if proc.returncode != 0:
        raise RuntimeError(
            f"cc failed ({' '.join(cmd)}):\n{proc.stderr[:4000]}")
    compile_s = time.time() - t0
    with open(so_path + ".meta", "w") as f:
        f.write(f"compile_s={compile_s:.3f} bytes={len(source)}\n")
    return so_path


@dataclass
class CompiledNet:
    """A callable wrapping the generated ``void f(const float*, float*)``.

    Also binds the reentrant ``<func>_ws(x, out, workspace)`` entry point
    when present: every call site supplies its own workspace, so the same
    .so can run one image per thread (``predict_batch(threads=k)``).

    ``precision`` makes the binding dtype-aware: an int8 build's
    workspace is a ``signed char`` arena (``workspace_bytes``), a float
    build's a float one (``workspace_floats``); the public x/out
    interface is float32 either way."""

    so_path: str
    func_name: str
    in_size: int
    out_size: int
    c_source_bytes: int
    batch_func_name: Optional[str] = None
    workspace_floats: int = 0
    arena_bytes: int = 0
    arena_buffer_sum_bytes: int = 0
    per_layer_live_bytes: Optional[dict] = None
    precision: str = "fp32"          # 'fp32' | 'int8'
    workspace_bytes: int = 0         # int8 builds: workspace in bytes
    simd: str = "sse"                # the variant actually compiled
                                     # (post CPU-feature fallback)
    # layer-pipelined builds (schedule.nstages > 1)
    pipeline_func_name: Optional[str] = None
    stage_func_names: Tuple[str, ...] = ()
    iface_elems: Tuple[int, ...] = ()
    arena_elems: int = 0             # per-stage private arena size
    schedule_digest: str = ""
    # (Adds, pools, Concat edges) the deployed schedule fused — the
    # net object self-describes which epilogues run at producer store
    # sites without re-deriving the schedule from the graph
    fused_counts: Tuple[int, int, int] = (0, 0, 0)

    @property
    def nstages(self) -> int:
        return max(len(self.stage_func_names), 1)

    @property
    def has_fusion(self) -> bool:
        return any(self.fused_counts)

    def __post_init__(self):
        lib = ctypes.CDLL(self.so_path)
        FLOATP = ctypes.POINTER(ctypes.c_float)
        self._fn = getattr(lib, self.func_name)
        self._fn.restype = None
        self._fn.argtypes = [FLOATP, FLOATP]
        self._batch_fn = None
        if self.batch_func_name:
            try:
                self._batch_fn = getattr(lib, self.batch_func_name)
            except AttributeError:  # older .so without the batch entry
                pass
            else:
                self._batch_fn.restype = None
                self._batch_fn.argtypes = [FLOATP, FLOATP, ctypes.c_int]
        self._ws_fn = None
        # the workspace pointer type follows the build's precision
        self._ws_ctype = (ctypes.c_byte if self.precision == "int8"
                          else ctypes.c_float)
        try:
            self._ws_fn = getattr(lib, self.func_name + "_ws")
        except AttributeError:  # pre-arena .so
            pass
        else:
            self._ws_fn.restype = None
            self._ws_fn.argtypes = [FLOATP, FLOATP,
                                    ctypes.POINTER(self._ws_ctype)]
        # reentrant batch entry: a whole batch in ONE foreign call on a
        # caller workspace — the serving worker-pool hot path
        self._batch_ws_fn = None
        try:
            self._batch_ws_fn = getattr(lib, self.func_name + "_batch_ws")
        except AttributeError:  # older .so without the entry
            pass
        else:
            self._batch_ws_fn.restype = None
            self._batch_ws_fn.argtypes = [FLOATP, FLOATP, ctypes.c_int,
                                          ctypes.POINTER(self._ws_ctype)]
        # pipelined builds: one function per stage + sequential driver
        self._stage_fns = []
        for sym in self.stage_func_names:
            fn = getattr(lib, sym)
            fn.restype = None
            # (in, out, ws) — element types vary per stage boundary;
            # bind as void* and pass raw buffer addresses
            fn.argtypes = [ctypes.c_void_p] * 3
            self._stage_fns.append(fn)
        self._pipeline_fn = None
        if self.pipeline_func_name:
            self._pipeline_fn = getattr(lib, self.pipeline_func_name)
            self._pipeline_fn.restype = None
            self._pipeline_fn.argtypes = [FLOATP, FLOATP,
                                          ctypes.POINTER(self._ws_ctype),
                                          ctypes.c_int]

    def _alloc_workspace(self) -> np.ndarray:
        if self.precision == "int8":
            return np.empty(max(self.workspace_bytes, 1), dtype=np.int8)
        return np.empty(max(self.workspace_floats, 1), dtype=np.float32)

    def __call__(self, x: np.ndarray) -> np.ndarray:
        x = np.ascontiguousarray(x, dtype=np.float32)
        assert x.size == self.in_size, (x.size, self.in_size)
        out = np.empty(self.out_size, dtype=np.float32)
        self._fn(x.ctypes.data_as(ctypes.POINTER(ctypes.c_float)),
                 out.ctypes.data_as(ctypes.POINTER(ctypes.c_float)))
        return out

    def predict_batch(self, x: np.ndarray,
                      threads: Optional[int] = None) -> np.ndarray:
        """Run N images; returns ``(N, out_size)``.

        ``threads=None``/``1`` uses the generated C batch loop (one
        foreign call).  ``threads=k`` partitions the batch over k Python
        threads, each driving the reentrant ``<func>_ws`` entry with its
        own workspace — ctypes releases the GIL during the call, so this
        is true parallelism on the same .so.

        A layer-pipelined build (``nstages > 1``) streams the batch
        through :class:`PipelineRunner` instead when ``threads`` is not
        given: stage ``s`` of frame ``i`` overlaps stage ``s-1`` of
        frame ``i+1`` on separate cores."""
        x = np.ascontiguousarray(x, dtype=np.float32)
        assert x.size % self.in_size == 0, (x.size, self.in_size)
        n = x.size // self.in_size
        out = np.empty(n * self.out_size, dtype=np.float32)
        if self._stage_fns and n > 1 and threads is None:
            PipelineRunner(self).run(x, out, n)
        elif threads is not None and threads > 1 \
                and self._ws_fn is not None and n > 1:
            self._predict_batch_threaded(x, out, n, threads)
        elif self._batch_fn is not None:
            self._batch_fn(
                x.ctypes.data_as(ctypes.POINTER(ctypes.c_float)),
                out.ctypes.data_as(ctypes.POINTER(ctypes.c_float)),
                ctypes.c_int(n))
        else:
            flat = x.reshape(n, self.in_size)
            for b in range(n):
                out[b * self.out_size:(b + 1) * self.out_size] = self(flat[b])
        return out.reshape(n, self.out_size)

    def _predict_batch_threaded(self, x: np.ndarray, out: np.ndarray,
                                n: int, threads: int) -> None:
        from concurrent.futures import ThreadPoolExecutor
        FLOATP = ctypes.POINTER(ctypes.c_float)
        k = min(threads, n)
        xf = x.reshape(-1)
        # contiguous chunk per thread: with the reentrant batch entry
        # each thread is ONE foreign call for its whole chunk
        bounds = [(n * t) // k for t in range(k + 1)]

        def run(t: int) -> None:
            ws = self._alloc_workspace()
            wp = ws.ctypes.data_as(ctypes.POINTER(self._ws_ctype))
            lo, hi = bounds[t], bounds[t + 1]
            if self._batch_ws_fn is not None:
                xi = xf[lo * self.in_size:hi * self.in_size]
                oi = out[lo * self.out_size:hi * self.out_size]
                self._batch_ws_fn(xi.ctypes.data_as(FLOATP),
                                  oi.ctypes.data_as(FLOATP),
                                  ctypes.c_int(hi - lo), wp)
                return
            for b in range(lo, hi):
                xi = xf[b * self.in_size:(b + 1) * self.in_size]
                oi = out[b * self.out_size:(b + 1) * self.out_size]
                self._ws_fn(xi.ctypes.data_as(FLOATP),
                            oi.ctypes.data_as(FLOATP), wp)

        with ThreadPoolExecutor(max_workers=k) as ex:
            list(ex.map(run, range(k)))

    def time_per_call_us(self, x: np.ndarray, iters: int = 2000,
                         warmup: int = 50) -> float:
        x = np.ascontiguousarray(x, dtype=np.float32)
        out = np.empty(self.out_size, dtype=np.float32)
        xp = x.ctypes.data_as(ctypes.POINTER(ctypes.c_float))
        op = out.ctypes.data_as(ctypes.POINTER(ctypes.c_float))
        fn = self._fn
        for _ in range(warmup):
            fn(xp, op)
        t0 = time.perf_counter()
        for _ in range(iters):
            fn(xp, op)
        return (time.perf_counter() - t0) / iters * 1e6


class PipelineRunner:
    """Stream frames through a layer-pipelined build, one thread per
    stage.

    Stage ``s`` of frame ``i`` runs concurrently with stage ``s-1`` of
    frame ``i+1``: each stage boundary has two interface buffers
    (double buffering, ``free``/``full`` semaphore pair) and each stage
    thread owns a private arena, so a frame flows buffer-to-buffer
    without ever blocking the stage behind it for more than one frame.
    ctypes releases the GIL around each stage call — the overlap is
    real core parallelism on the same .so."""

    def __init__(self, net: CompiledNet):
        if not net._stage_fns:
            raise ValueError("not a pipelined build (nstages == 1)")
        self.net = net

    def run(self, x: np.ndarray, out: np.ndarray, n: int) -> None:
        net = self.net
        S = len(net._stage_fns)
        dt = np.int8 if net.precision == "int8" else np.float32
        bufs = [np.empty((2, max(sz, 1)), dtype=dt)
                for sz in net.iface_elems]
        wss = [np.empty(max(net.arena_elems, 1), dtype=dt)
               for _ in range(S)]
        free = [threading.Semaphore(2) for _ in range(S - 1)]
        full = [threading.Semaphore(0) for _ in range(S - 1)]
        xf = x.reshape(-1)
        in_n, out_n = net.in_size, net.out_size
        errors: list = []

        def worker(s: int) -> None:
            fn = net._stage_fns[s]
            ws_p = wss[s].ctypes.data
            try:
                for i in range(n):
                    if s > 0:
                        full[s - 1].acquire()
                    if s < S - 1:
                        free[s].acquire()
                    src = (xf[i * in_n:(i + 1) * in_n] if s == 0
                           else bufs[s - 1][i & 1])
                    dst = (out[i * out_n:(i + 1) * out_n] if s == S - 1
                           else bufs[s][i & 1])
                    fn(src.ctypes.data, dst.ctypes.data, ws_p)
                    if s > 0:
                        free[s - 1].release()
                    if s < S - 1:
                        full[s].release()
            except BaseException as e:  # pragma: no cover - defensive
                errors.append(e)
                # unblock neighbours so every thread terminates
                if s > 0:
                    free[s - 1].release()
                if s < S - 1:
                    full[s].release()

        threads = [threading.Thread(target=worker, args=(s,), daemon=True)
                   for s in range(S)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:  # pragma: no cover - defensive
            raise errors[0]


def build(graph: CNNGraph, opts: Optional[CodegenOptions] = None,
          extra_flags: Sequence[str] = (),
          schedule: Optional[Schedule] = None) -> CompiledNet:
    """graph -> C -> .so -> callable.

    ``schedule=None`` uses the default (epilogue fusion on, single
    stage); pass ``make_schedule(g, nstages=k)`` for the pipelined
    build."""
    opts = opts or CodegenOptions()
    actual = resolve_float_simd(opts.simd)
    if actual != opts.simd:
        opts = replace(opts, simd=actual)
    gs = compile_graph(graph, opts, schedule=schedule)
    so = compile_c(gs.source, simd=opts.simd, extra_flags=extra_flags,
                   key_extra="sched:" + gs.schedule.digest())
    return CompiledNet(
        so_path=so,
        func_name=gs.func_name,
        in_size=gs.in_elems,
        out_size=gs.out_elems,
        c_source_bytes=len(gs.source),
        batch_func_name=gs.entry_batch,
        workspace_floats=gs.workspace_elems,
        arena_bytes=gs.arena_bytes,
        arena_buffer_sum_bytes=gs.arena_buffer_sum_bytes,
        per_layer_live_bytes=gs.per_layer_live_bytes,
        simd=opts.simd,
        pipeline_func_name=gs.entry_pipeline,
        stage_func_names=gs.stage_entries,
        iface_elems=gs.iface_elems,
        arena_elems=gs.arena_elems,
        schedule_digest=gs.schedule.digest(),
        fused_counts=(len(gs.schedule.fused_adds),
                      len(gs.schedule.fused_pools),
                      len(gs.schedule.fused_concats)),
    )


def build_quantized(qgraph, opts: Optional[CodegenOptions] = None,
                    extra_flags: Sequence[str] = (),
                    schedule: Optional[Schedule] = None) -> CompiledNet:
    """Calibrated int8 graph -> C -> .so -> callable (float32 in/out).

    ``qgraph`` is a :class:`repro.core.quantize.QuantizedGraph`; the
    compiled net's workspace is the byte-planned int8 arena (~4x
    smaller than the float build's).  The requested kernel variant is
    resolved against the host's CPU features first (walking the QISA
    fallback chain), so e.g. an AVX-512-VNNI .so is never built — let
    alone loaded — on a non-VNNI host; ``CompiledNet.simd`` reports
    what actually ran."""
    opts = opts or CodegenOptions()
    actual = resolve_int8_simd(opts.simd)
    if actual != opts.simd:
        opts = replace(opts, simd=actual)
    gs = compile_graph(qgraph, opts, schedule=schedule)
    so = compile_c(gs.source, simd=opts.simd, extra_flags=extra_flags,
                   key_extra="sched:" + gs.schedule.digest())
    return CompiledNet(
        so_path=so,
        func_name=gs.func_name,
        in_size=gs.in_elems,
        out_size=gs.out_elems,
        c_source_bytes=len(gs.source),
        batch_func_name=gs.entry_batch,
        workspace_floats=0,
        arena_bytes=gs.arena_bytes,
        arena_buffer_sum_bytes=gs.arena_buffer_sum_bytes,
        per_layer_live_bytes=gs.per_layer_live_bytes,
        precision="int8",
        workspace_bytes=gs.workspace_elems,
        simd=opts.simd,
        pipeline_func_name=gs.entry_pipeline,
        stage_func_names=gs.stage_entries,
        iface_elems=gs.iface_elems,
        arena_elems=gs.arena_elems,
        schedule_digest=gs.schedule.digest(),
        fused_counts=(len(gs.schedule.fused_adds),
                      len(gs.schedule.fused_pools),
                      len(gs.schedule.fused_concats)),
    )


# -- runtime CPU-feature detection ----------------------------------------

_CPU_FEATURES: Optional[frozenset] = None
_FEATURE_OVERRIDE: Optional[frozenset] = None


def cpu_features() -> frozenset:
    """The host CPU's feature tokens — the union of every ``flags``
    (x86) / ``Features`` (ARM) line in /proc/cpuinfo, split on
    whitespace.  Token-based on purpose: a substring test would accept
    ``avx512f`` as evidence of ``avx``-anything."""
    if _FEATURE_OVERRIDE is not None:
        return _FEATURE_OVERRIDE
    global _CPU_FEATURES
    if _CPU_FEATURES is None:
        feats = set()
        try:
            with open("/proc/cpuinfo") as f:
                for line in f:
                    key, _, rest = line.partition(":")
                    if key.strip().lower() in ("flags", "features"):
                        feats.update(rest.split())
        except OSError:  # pragma: no cover
            pass
        _CPU_FEATURES = frozenset(feats)
    return _CPU_FEATURES


@contextlib.contextmanager
def force_cpu_features(feats: Optional[Sequence[str]]) -> Iterator[None]:
    """Test hook: pretend the host advertises exactly ``feats``
    (``None`` restores real detection).  Lets the fallback chain be
    exercised on any machine without risking an actual SIGILL."""
    global _FEATURE_OVERRIDE
    prev = _FEATURE_OVERRIDE
    _FEATURE_OVERRIDE = None if feats is None else frozenset(feats)
    try:
        yield
    finally:
        _FEATURE_OVERRIDE = prev


def _machine_arch() -> str:
    m = platform.machine().lower()
    return "arm" if ("arm" in m or "aarch" in m) else "x86"


def host_supports_ssse3() -> bool:
    return "ssse3" in cpu_features()


def host_supports_avx2() -> bool:
    feats = cpu_features()
    return "avx2" in feats and "fma" in feats


def best_isa() -> str:
    """Pick the widest supported vector mode (paper: 'extension of NNCG
    to other instruction sets like AVX can be realized rapidly')."""
    if host_supports_avx2():
        return "avx"
    if host_supports_ssse3():
        return "sse"
    return "structured"


def resolve_float_simd(requested: str) -> str:
    """Clamp a float-build SIMD request to what the host can run."""
    if requested == "avx" and not host_supports_avx2():
        requested = "sse"
    if requested == "sse" and not host_supports_ssse3():
        requested = "structured"
    return requested


def int8_simd_supported(name: str) -> bool:
    """True when the host can execute int8 kernel variant ``name``."""
    from .cgen import QISAS
    q = QISAS.get(name)
    if q is None:
        return True  # generic / structured: plain C, runs anywhere
    if q.arch != _machine_arch():
        return False
    feats = cpu_features()
    return all(f in feats for f in q.cpu_flags)


def resolve_int8_simd(requested: str) -> str:
    """Walk the QISA fallback chain down to the best variant the host
    advertises support for (SIGILL guard for every int8 build)."""
    from .cgen import QISAS
    name = requested
    while not int8_simd_supported(name):
        q = QISAS.get(name)
        name = q.fallback if q is not None and q.fallback else "generic"
    return name


def supported_int8_simds() -> List[str]:
    """Every int8 kernel variant this host can run, best-first."""
    order = ["avx_vnni", "avx_ubs", "avx", "sse", "neon_dot", "neon"]
    return [n for n in order if int8_simd_supported(n)] + ["generic"]
