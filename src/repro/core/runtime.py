"""Compile-and-load harness for NNCG-generated C.

The paper's deployment story: the generated file has no dependencies
beyond ``math.h``/``libm`` (plus SSE intrinsics when enabled), so any
ANSI C compiler — native or cross — produces the executable.  Here we
compile a shared object with the host ``cc`` and bind it via ctypes so
tests/benchmarks can call it directly against the JAX oracle.
"""
from __future__ import annotations

import ctypes
import hashlib
import os
import subprocess
import tempfile
import time
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from .cgen import CGenerator, CodegenOptions
from .graph import CNNGraph

_CACHE_DIR = os.path.join(tempfile.gettempdir(), "nncg_cache")

# observability for the engine's caching tests/telemetry: how often the
# C compiler actually ran vs. the content-hash .so cache answering
COMPILE_STATS = {"cc_invocations": 0, "so_cache_hits": 0}


def _cc() -> str:
    return os.environ.get("CC", "cc")


_CC_FINGERPRINTS: dict = {}


def cc_fingerprint() -> str:
    """First line of ``$CC --version``, cached per resolved compiler.

    Part of every content-cache key (.so cache here, tuning cache in
    the engine): a compiler change must invalidate measured artifacts.
    """
    cc = _cc()
    if cc not in _CC_FINGERPRINTS:
        try:
            out = subprocess.run([cc, "--version"], capture_output=True,
                                 text=True, timeout=10).stdout
            _CC_FINGERPRINTS[cc] = (out.splitlines()[0].strip()
                                    if out else cc)
        except (OSError, subprocess.TimeoutExpired):  # pragma: no cover
            _CC_FINGERPRINTS[cc] = cc
    return _CC_FINGERPRINTS[cc]


def compile_c(source: str, *, simd: str = "sse",
              extra_flags: Sequence[str] = ()) -> str:
    """Compile C source to a shared object; returns the .so path.

    The output is cached by content hash over (source, simd, flags,
    compiler), so an identical build never re-invokes the compiler and
    a toolchain change never serves a stale binary.
    """
    os.makedirs(_CACHE_DIR, exist_ok=True)
    key = hashlib.sha256(
        (source + repr(simd) + repr(tuple(extra_flags))
         + cc_fingerprint()).encode()
    ).hexdigest()[:16]
    so_path = os.path.join(_CACHE_DIR, f"nncg_{key}.so")
    if os.path.exists(so_path):
        COMPILE_STATS["so_cache_hits"] += 1
        return so_path
    c_path = os.path.join(_CACHE_DIR, f"nncg_{key}.c")
    with open(c_path, "w") as f:
        f.write(source)
    flags = ["-O3", "-fPIC", "-shared", "-std=c99"]
    from .cgen import ISAS
    if simd in ISAS:
        flags.extend(ISAS[simd].cc_flags)
    cmd = [_cc(), *flags, *extra_flags, c_path, "-o", so_path, "-lm"]
    t0 = time.time()
    COMPILE_STATS["cc_invocations"] += 1
    proc = subprocess.run(cmd, capture_output=True, text=True)
    if proc.returncode != 0:
        raise RuntimeError(
            f"cc failed ({' '.join(cmd)}):\n{proc.stderr[:4000]}")
    compile_s = time.time() - t0
    with open(so_path + ".meta", "w") as f:
        f.write(f"compile_s={compile_s:.3f} bytes={len(source)}\n")
    return so_path


@dataclass
class CompiledNet:
    """A callable wrapping the generated ``void f(const float*, float*)``.

    Also binds the reentrant ``<func>_ws(x, out, workspace)`` entry point
    when present: every call site supplies its own workspace, so the same
    .so can run one image per thread (``predict_batch(threads=k)``).

    ``precision`` makes the binding dtype-aware: an int8 build's
    workspace is a ``signed char`` arena (``workspace_bytes``), a float
    build's a float one (``workspace_floats``); the public x/out
    interface is float32 either way."""

    so_path: str
    func_name: str
    in_size: int
    out_size: int
    c_source_bytes: int
    batch_func_name: Optional[str] = None
    workspace_floats: int = 0
    arena_bytes: int = 0
    arena_buffer_sum_bytes: int = 0
    per_layer_live_bytes: Optional[dict] = None
    precision: str = "fp32"          # 'fp32' | 'int8'
    workspace_bytes: int = 0         # int8 builds: arena size in bytes

    def __post_init__(self):
        lib = ctypes.CDLL(self.so_path)
        FLOATP = ctypes.POINTER(ctypes.c_float)
        self._fn = getattr(lib, self.func_name)
        self._fn.restype = None
        self._fn.argtypes = [FLOATP, FLOATP]
        self._batch_fn = None
        if self.batch_func_name:
            try:
                self._batch_fn = getattr(lib, self.batch_func_name)
            except AttributeError:  # older .so without the batch entry
                pass
            else:
                self._batch_fn.restype = None
                self._batch_fn.argtypes = [FLOATP, FLOATP, ctypes.c_int]
        self._ws_fn = None
        # the workspace pointer type follows the build's precision
        self._ws_ctype = (ctypes.c_byte if self.precision == "int8"
                          else ctypes.c_float)
        try:
            self._ws_fn = getattr(lib, self.func_name + "_ws")
        except AttributeError:  # pre-arena .so
            pass
        else:
            self._ws_fn.restype = None
            self._ws_fn.argtypes = [FLOATP, FLOATP,
                                    ctypes.POINTER(self._ws_ctype)]
        # reentrant batch entry: a whole batch in ONE foreign call on a
        # caller workspace — the serving worker-pool hot path
        self._batch_ws_fn = None
        try:
            self._batch_ws_fn = getattr(lib, self.func_name + "_batch_ws")
        except AttributeError:  # older .so without the entry
            pass
        else:
            self._batch_ws_fn.restype = None
            self._batch_ws_fn.argtypes = [FLOATP, FLOATP, ctypes.c_int,
                                          ctypes.POINTER(self._ws_ctype)]

    def _alloc_workspace(self) -> np.ndarray:
        if self.precision == "int8":
            return np.empty(max(self.workspace_bytes, 1), dtype=np.int8)
        return np.empty(max(self.workspace_floats, 1), dtype=np.float32)

    def __call__(self, x: np.ndarray) -> np.ndarray:
        x = np.ascontiguousarray(x, dtype=np.float32)
        assert x.size == self.in_size, (x.size, self.in_size)
        out = np.empty(self.out_size, dtype=np.float32)
        self._fn(x.ctypes.data_as(ctypes.POINTER(ctypes.c_float)),
                 out.ctypes.data_as(ctypes.POINTER(ctypes.c_float)))
        return out

    def predict_batch(self, x: np.ndarray,
                      threads: Optional[int] = None) -> np.ndarray:
        """Run N images; returns ``(N, out_size)``.

        ``threads=None``/``1`` uses the generated C batch loop (one
        foreign call).  ``threads=k`` partitions the batch over k Python
        threads, each driving the reentrant ``<func>_ws`` entry with its
        own workspace — ctypes releases the GIL during the call, so this
        is true parallelism on the same .so."""
        x = np.ascontiguousarray(x, dtype=np.float32)
        assert x.size % self.in_size == 0, (x.size, self.in_size)
        n = x.size // self.in_size
        out = np.empty(n * self.out_size, dtype=np.float32)
        if threads is not None and threads > 1 and self._ws_fn is not None \
                and n > 1:
            self._predict_batch_threaded(x, out, n, threads)
        elif self._batch_fn is not None:
            self._batch_fn(
                x.ctypes.data_as(ctypes.POINTER(ctypes.c_float)),
                out.ctypes.data_as(ctypes.POINTER(ctypes.c_float)),
                ctypes.c_int(n))
        else:
            flat = x.reshape(n, self.in_size)
            for b in range(n):
                out[b * self.out_size:(b + 1) * self.out_size] = self(flat[b])
        return out.reshape(n, self.out_size)

    def _predict_batch_threaded(self, x: np.ndarray, out: np.ndarray,
                                n: int, threads: int) -> None:
        from concurrent.futures import ThreadPoolExecutor
        FLOATP = ctypes.POINTER(ctypes.c_float)
        k = min(threads, n)
        xf = x.reshape(-1)
        # contiguous chunk per thread: with the reentrant batch entry
        # each thread is ONE foreign call for its whole chunk
        bounds = [(n * t) // k for t in range(k + 1)]

        def run(t: int) -> None:
            ws = self._alloc_workspace()
            wp = ws.ctypes.data_as(ctypes.POINTER(self._ws_ctype))
            lo, hi = bounds[t], bounds[t + 1]
            if self._batch_ws_fn is not None:
                xi = xf[lo * self.in_size:hi * self.in_size]
                oi = out[lo * self.out_size:hi * self.out_size]
                self._batch_ws_fn(xi.ctypes.data_as(FLOATP),
                                  oi.ctypes.data_as(FLOATP),
                                  ctypes.c_int(hi - lo), wp)
                return
            for b in range(lo, hi):
                xi = xf[b * self.in_size:(b + 1) * self.in_size]
                oi = out[b * self.out_size:(b + 1) * self.out_size]
                self._ws_fn(xi.ctypes.data_as(FLOATP),
                            oi.ctypes.data_as(FLOATP), wp)

        with ThreadPoolExecutor(max_workers=k) as ex:
            list(ex.map(run, range(k)))

    def time_per_call_us(self, x: np.ndarray, iters: int = 2000,
                         warmup: int = 50) -> float:
        x = np.ascontiguousarray(x, dtype=np.float32)
        out = np.empty(self.out_size, dtype=np.float32)
        xp = x.ctypes.data_as(ctypes.POINTER(ctypes.c_float))
        op = out.ctypes.data_as(ctypes.POINTER(ctypes.c_float))
        fn = self._fn
        for _ in range(warmup):
            fn(xp, op)
        t0 = time.perf_counter()
        for _ in range(iters):
            fn(xp, op)
        return (time.perf_counter() - t0) / iters * 1e6


def build(graph: CNNGraph, opts: Optional[CodegenOptions] = None,
          extra_flags: Sequence[str] = ()) -> CompiledNet:
    """graph -> C -> .so -> callable."""
    opts = opts or CodegenOptions()
    gen = CGenerator(graph, opts)
    src = gen.generate()
    so = compile_c(src, simd=opts.simd, extra_flags=extra_flags)
    plan = gen.plan  # the exact plan the emitted code was carved from
    return CompiledNet(
        so_path=so,
        func_name=opts.func_name,
        in_size=int(np.prod(graph.input_shape)),
        out_size=int(np.prod(graph.output_shape)),
        c_source_bytes=len(src),
        batch_func_name=opts.batch_func_name if opts.emit_batch else None,
        workspace_floats=plan.total_floats,
        arena_bytes=plan.total_bytes,
        arena_buffer_sum_bytes=plan.buffer_sum_bytes,
        per_layer_live_bytes={k: v * 4
                              for k, v in plan.per_layer_live.items()},
    )


def build_quantized(qgraph, opts: Optional[CodegenOptions] = None,
                    extra_flags: Sequence[str] = ()) -> CompiledNet:
    """Calibrated int8 graph -> C -> .so -> callable (float32 in/out).

    ``qgraph`` is a :class:`repro.core.quantize.QuantizedGraph`; the
    compiled net's workspace is the byte-planned int8 arena (~4x
    smaller than the float build's)."""
    from .cgen import QuantCGenerator
    opts = opts or CodegenOptions()
    gen = QuantCGenerator(qgraph, opts)
    src = gen.generate()
    so = compile_c(src, simd=opts.simd, extra_flags=extra_flags)
    plan = gen.plan
    graph = qgraph.graph
    return CompiledNet(
        so_path=so,
        func_name=opts.func_name,
        in_size=int(np.prod(graph.input_shape)),
        out_size=int(np.prod(graph.output_shape)),
        c_source_bytes=len(src),
        batch_func_name=opts.batch_func_name if opts.emit_batch else None,
        workspace_floats=0,
        arena_bytes=plan.total_bytes,
        arena_buffer_sum_bytes=plan.buffer_sum_bytes,
        per_layer_live_bytes={k: v * plan.elem_bytes
                              for k, v in plan.per_layer_live.items()},
        precision="int8",
        workspace_bytes=plan.total_bytes,
    )


def host_supports_ssse3() -> bool:
    return _cpu_has("ssse3")


def host_supports_avx2() -> bool:
    return _cpu_has("avx2") and _cpu_has("fma")


def best_isa() -> str:
    """Pick the widest supported vector mode (paper: 'extension of NNCG
    to other instruction sets like AVX can be realized rapidly')."""
    if host_supports_avx2():
        return "avx"
    if host_supports_ssse3():
        return "sse"
    return "structured"


def _cpu_has(flag: str) -> bool:
    try:
        with open("/proc/cpuinfo") as f:
            return flag in f.read()
    except OSError:  # pragma: no cover
        return False
