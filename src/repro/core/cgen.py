"""NNCG — the ANSI C code generator (paper §II).

Generates, from a trained :class:`CNNGraph` (a DAG — residual Adds,
Concats, depthwise convs and pooling all supported), one plain C file
exposing:

    void <func>_ws(const float *x, float *out, float *workspace);
    void <func>(const float *x, float *out);          /* static arena */
    void <func>_batch(const float *x, float *out, int n);
    long <func>_workspace_floats(void);

implementing the four design principles:

* **P1 unroll levels** — per-layer ``level``: 0 = all loops unrolled
  (straight-line code), 1 = keep the outermost spatial loop, 2 = keep both
  spatial loops, ``None`` = no unrolling (plain loop nest).  Matches the
  paper: "At level 0 all loops are unrolled. Level 1 does not unroll the
  outer most loop and so forth."
* **P2 conditional moves** — activations and pooling emit the C ternary
  operator, never an ``if`` block.
* **P3 constants** — with any unrolling the trained weights are printed
  as literals into the code line; without unrolling they are emitted as
  ``static const`` arrays.  Zero padding taps are *elided entirely* at
  level 0 (a static-knowledge win no generic library has).
* **P4 SIMD structure** — three modes: ``generic`` (paper's scalar
  baseline, output-channel loop outside the tap loops), ``structured``
  (channel loop innermost over contiguous memory → auto-vectorizable),
  and ``sse`` (explicit SSSE3/SSE intrinsics over groups of 4 output
  channels, the paper's shipped mode).

**Memory**: instead of one never-reused ``static float`` buffer per
layer, a liveness-based **arena planner** (:func:`plan_arena`) computes
tensor lifetimes over the topological order and packs all intermediate
buffers — including zero-padding scratch — into one workspace via
interval-interference best-fit.  ``<func>_ws`` takes the workspace from
the caller, making the generated code **reentrant** (thread-parallel
batch serving); ``<func>`` binds the planned static arena for the
paper's single-image embedded deployment.

The emitted file is strict ANSI C89 (declarations first, no ``//``
comments, ``restrict`` behind a feature macro), so ``gcc -std=c89
-Wall -Wextra -Werror -pedantic-errors`` accepts it — the paper's
"plain C compilable by any ANSI compiler" claim, enforced in CI.  The
only dependencies are ``math.h`` (softmax) and, in ``sse``/``avx``
mode, the intrinsics header — exactly the paper's dependency set.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from .graph import (
    Add,
    AvgPool,
    BatchNorm,
    CNNGraph,
    Concat,
    Conv2D,
    Dense,
    DepthwiseConv2D,
    Dropout,
    Flatten,
    GlobalAvgPool,
    Input,
    LeakyReLU,
    MaxPool,
    ReLU,
    Softmax,
    pool_window_counts,
)

from .lowering import (
    AddFuse,
    Buffer,
    ConcatFuse,
    Epilogue,
    FuseNode,
    KernelCall,
    Loop,
    LoopNest,
    PoolFuse,
    Program,
    render,
)
from .numerics import flit
from .schedule import Schedule, make_schedule  # noqa: F401  (re-export)

Level = Optional[int]  # 0 | 1 | 2 | None (no unroll)

# bump whenever the emitted C changes for the same (graph, options) —
# cached artifacts measured on older generated code must not be reused
CODEGEN_VERSION = 8

# the single source of truth for the unroll/icache emission budget
# (both CodegenOptions.term_budget and choose_levels read it)
TERM_BUDGET_DEFAULT = 60_000

# layers that emit no code: Input is the function argument, Dropout is
# identity at inference, Flatten is a no-op on flat NHWC memory
IDENTITY_LAYERS = (Input, Dropout, Flatten)


@dataclass(frozen=True)
class ISA:
    """Vector instruction-set descriptor (P4). The paper ships SSSE3 and
    names AVX as future work — ``avx`` implements it (8-wide + FMA)."""

    name: str
    width: int
    reg: str
    header: str
    cc_flags: tuple
    prefix: str

    def load(self, ptr: str) -> str:
        return f"{self.prefix}_loadu_ps(&{ptr})"

    def store(self, ptr: str, reg: str) -> str:
        return f"{self.prefix}_storeu_ps(&{ptr}, {reg});"

    def set1(self, x: str) -> str:
        return f"{self.prefix}_set1_ps({x})"

    def zero(self) -> str:
        return f"{self.prefix}_setzero_ps()"

    def add(self, a: str, b: str) -> str:
        return f"{self.prefix}_add_ps({a}, {b})"

    def mul(self, a: str, b: str) -> str:
        return f"{self.prefix}_mul_ps({a}, {b})"

    def vmax(self, a: str, b: str) -> str:
        return f"{self.prefix}_max_ps({a}, {b})"

    def fmadd(self, a: str, b: str, c: str) -> str:
        """a*b + c."""
        if self.name == "avx":
            return f"{self.prefix}_fmadd_ps({a}, {b}, {c})"
        return self.add(c, self.mul(a, b))

    def set_lits(self, vals) -> str:
        lits = ", ".join(_flit(v) for v in reversed(list(vals)))
        return f"{self.prefix}_set_ps({lits})"


SSE = ISA(name="sse", width=4, reg="__m128", header="emmintrin.h",
          cc_flags=("-mssse3",), prefix="_mm")
AVX = ISA(name="avx", width=8, reg="__m256", header="immintrin.h",
          cc_flags=("-mavx2", "-mfma"), prefix="_mm256")
ISAS = {"sse": SSE, "avx": AVX}


@dataclass(frozen=True)
class QISA:
    """Int8 dot-product kernel descriptor — the integer analogue of
    :class:`ISA`, one entry per tiled kernel variant.

    The quantized conv/dense emitters tile ``group`` output channels
    into one int32 accumulator vector and fold ``lane_taps`` input taps
    into every 32-bit lane per dot-product instruction; the requant
    epilogue (rescale, activation, round-half-up, zero point, saturate)
    runs vectorized on the same accumulator, so int8 results go from
    register file to arena without a scalar round trip.

    ``unsigned_x`` marks the u8·s8 instructions (``vpmaddubsw``,
    ``vpdpbusd``): activations are re-biased to unsigned by XORing the
    sign bit and the matching ``128 * sum(w)`` correction is folded into
    the int32 bias (:meth:`QuantizedGraph.effective_bias`), keeping the
    accumulator bit-identical to the signed variants.

    ``cpu_flags`` are the /proc/cpuinfo tokens the compiled object
    needs at *load* time; :func:`repro.core.runtime.resolve_int8_simd`
    walks ``fallback`` until it reaches a variant the host supports, so
    e.g. an AVX-512-VNNI .so is never loaded on a non-VNNI machine.
    """

    name: str
    arch: str                 # 'x86' | 'arm'
    group: int                # output channels per accumulator vector
    lane_taps: int            # taps folded into each 32-bit lane
    header: str
    cc_flags: tuple
    cpu_flags: tuple          # /proc/cpuinfo tokens required to run
    unsigned_x: bool = False  # u8*s8 dot: x codes re-biased by +128
    fallback: Optional[str] = None  # next-best variant when unsupported

    @property
    def wide(self) -> bool:
        """256-bit x86 variant (group of 8) vs 128-bit (group of 4)."""
        return self.group == 8


QISAS = {
    # SSE2 pair-madd: 2 sign-extended int16 taps per lane,
    # _mm_madd_epi16 (exact: every i16*i16 pair sum fits int32)
    "sse": QISA(name="sse", arch="x86", group=4, lane_taps=2,
                header="emmintrin.h", cc_flags=("-mssse3",),
                cpu_flags=("ssse3",), fallback="generic"),
    # AVX2 pair-madd: the 256-bit _mm256_madd_epi16 form
    "avx": QISA(name="avx", arch="x86", group=8, lane_taps=2,
                header="immintrin.h", cc_flags=("-mavx2", "-mfma"),
                cpu_flags=("avx2", "fma"), fallback="sse"),
    # AVX2 u8*s8 quad: vpmaddubsw + vpmaddwd(1).  vpmaddubsw saturates
    # its int16 pair sums, so this variant is emitted per layer ONLY
    # when the trained weights *prove* saturation impossible
    # (maddubsw_safe); otherwise the layer falls back to pair-madd.
    "avx_ubs": QISA(name="avx_ubs", arch="x86", group=8, lane_taps=4,
                    header="immintrin.h", cc_flags=("-mavx2", "-mfma"),
                    cpu_flags=("avx2", "fma"), unsigned_x=True,
                    fallback="avx"),
    # AVX-512-VNNI u8*s8 quad on 256-bit registers: one vpdpbusd per 4
    # taps x 8 channels, products widened to int32 before summing —
    # exact for every weight, no saturation proof needed
    "avx_vnni": QISA(name="avx_vnni", arch="x86", group=8, lane_taps=4,
                     header="immintrin.h",
                     cc_flags=("-mavx512vnni", "-mavx512vl",
                               "-mavx512bw", "-mavx512f",
                               "-mavx2", "-mfma"),
                     cpu_flags=("avx512f", "avx512bw", "avx512vl",
                                "avx512_vnni"),
                     unsigned_x=True, fallback="avx"),
    # NEON baseline (every ARMv8-A core): widening multiply-accumulate,
    # one vmlal_s16 per tap x 4 channels
    "neon": QISA(name="neon", arch="arm", group=4, lane_taps=1,
                 header="arm_neon.h", cc_flags=(),
                 cpu_flags=("asimd",), fallback="generic"),
    # ARMv8.2 dot product: one s8*s8 vdotq_s32 per 4 taps x 4 channels
    "neon_dot": QISA(name="neon_dot", arch="arm", group=4, lane_taps=4,
                     header="arm_neon.h",
                     cc_flags=("-march=armv8.2-a+dotprod",),
                     cpu_flags=("asimddp",), fallback="neon"),
}

# channel-group chunk cap: at most 8 int32 accumulator vectors live at
# once (plus the broadcast and a weight load, the 16-register budget of
# SSE/AVX/NEON); wider layers run multiple passes per output position
_QTILE_MAX_GROUPS = 8


@dataclass
class CodegenOptions:
    simd: str = "sse"            # 'generic' | 'structured' | 'sse' | 'avx'
                                 # int8 builds additionally accept the
                                 # QISAS kernel variants ('avx_ubs',
                                 # 'avx_vnni', 'neon', 'neon_dot')
    unroll: Union[Level, Dict[str, Level]] = 0
    func_name: str = "nncg_net"
    term_budget: int = TERM_BUDGET_DEFAULT
    # max emitted FMA terms per layer before the level is demoted
    # (icache trade-off)
    emit_batch: bool = True      # also emit `<func>_batch(x, out, n)` —
                                 # a loop-over-images serving entry point

    @property
    def isa(self) -> Optional[ISA]:
        return ISAS.get(self.simd)

    @property
    def batch_func_name(self) -> str:
        return self.func_name + "_batch"

    @property
    def batch_ws_func_name(self) -> str:
        """Reentrant batch entry: N images through one foreign call,
        caller-provided workspace — the serving worker-pool hot path."""
        return self.func_name + "_batch_ws"

    @property
    def ws_func_name(self) -> str:
        """The reentrant entry point taking a caller-provided workspace."""
        return self.func_name + "_ws"

    @property
    def pipeline_func_name(self) -> str:
        """Multi-stage entry: `<func>_pipeline(x, out, ws, nstages)` —
        emitted when the schedule has more than one stage."""
        return self.func_name + "_pipeline"

    @property
    def pipeline_nstages_func_name(self) -> str:
        return self.func_name + "_pipeline_nstages"

    def stage_func_name(self, s: int) -> str:
        """Per-stage function of the pipelined build."""
        return f"{self.func_name}_stage{s}"

    @property
    def ws_size_func_name(self) -> str:
        return self.func_name + "_workspace_floats"

    @property
    def ws_bytes_func_name(self) -> str:
        """Workspace size entry of the quantized build (int8 arena)."""
        return self.func_name + "_workspace_bytes"

    def level_for(self, layer_name: str) -> Level:
        if isinstance(self.unroll, dict):
            return self.unroll.get(layer_name, None)
        return self.unroll


# float32 -> shortest round-trip C literal (paper P3) — shared with the
# quantizer so printed constants are bit-identical across modules
_flit = flit


# most-negative finite float32 — the padding fill for max pooling (C89
# has no INFINITY); a window always covers >=1 valid tap, so the fill
# can never be the result
_NEG_FLT_MAX = _flit(np.finfo(np.float32).min)


def _cfor(var: str, bound, body: str, start: int = 0, step: int = 1) -> str:
    """A one-line C89 counted loop: the index is declared in its own
    block so the statement is legal anywhere."""
    inc = f"++{var}" if step == 1 else f"{var} += {step}"
    return (f"{{ int {var}; for ({var} = {start}; {var} < {bound}; {inc}) "
            f"{body} }}")


class _W:
    """Tiny indented writer."""

    def __init__(self) -> None:
        self.lines: List[str] = []
        self._ind = 0

    def __call__(self, line: str = "") -> None:
        self.lines.append("    " * self._ind + line if line else "")

    def open(self, line: str) -> None:
        self(line + " {" if line else "{")
        self._ind += 1

    def close(self) -> None:
        self._ind -= 1
        self("}")

    def text(self) -> str:
        return "\n".join(self.lines) + "\n"


def estimate_terms(layer, in_shape, level: Level) -> int:
    """Emitted multiply-add terms for a conv/pool at an unroll level —
    the code-size side of the paper's unroll/icache trade-off."""
    if isinstance(layer, Conv2D):
        oh, ow, co = layer.out_shape(in_shape)
        taps = layer.kh * layer.kw * layer.c_in
        per_out = taps
        n_out = {0: oh * ow * co, 1: ow * co, 2: co}.get(level, 0)
        return n_out * per_out if level is not None else taps
    if isinstance(layer, MaxPool):
        oh, ow, c = layer.out_shape(in_shape)
        taps = layer.size[0] * layer.size[1]
        n_out = {0: oh * ow * c, 1: ow * c, 2: c}.get(level, 0)
        return n_out * taps if level is not None else taps
    return 0


def effective_level(layer, in_shape, opts: "CodegenOptions") -> Level:
    """The unroll level actually emitted: the configured level, demoted
    until the emitted-term count fits the budget (icache trade-off, P1).
    The arena planner calls this too, so scratch planning and emission
    can never disagree."""
    level = opts.level_for(layer.name)
    while level is not None and \
            estimate_terms(layer, in_shape, level) > opts.term_budget:
        level = {0: 1, 1: 2, 2: None}[level]
    return level


def enumerate_variants(layer, in_shape, term_cap: int = 200_000) -> List[Level]:
    """Candidate unroll levels for one layer, deepest (level 0) first.

    This is the variant space the paper benchmarks per layer ("we
    independently benchmark every code version and select the one with
    the best runtime performance").  Levels whose emitted-term count
    exceeds ``term_cap`` are dropped — they would blow the icache (and
    the compile time) before they could win; ``None`` (rolled loops) is
    always feasible.  Returns ``[]`` for layers with no codegen variants.
    """
    if not isinstance(layer, (Conv2D, MaxPool)):
        return []
    return [lvl for lvl in (0, 1, 2, None)
            if lvl is None or estimate_terms(layer, in_shape, lvl) <= term_cap]


def choose_levels(graph: CNNGraph,
                  budget: int = TERM_BUDGET_DEFAULT) -> Dict[str, Level]:
    """Pick, per layer, the deepest unroll level within the term budget.

    This is the static analogue of the paper's per-layer variant
    benchmarking — the :mod:`repro.engine.autotune` tuner explores the
    same :func:`enumerate_variants` space dynamically and can override
    any choice made here.  Walks the DAG via edges, so branch layers get
    their true input shapes.
    """
    levels: Dict[str, Level] = {}
    smap = graph.shape_map()
    for layer in graph.layers:
        ish = smap[layer.inputs[0]] if layer.inputs else None
        for lvl in enumerate_variants(layer, ish, term_cap=budget):
            levels[layer.name] = lvl
            break
    return levels


# ---------------------------------------------------------------------------
# arena planning (liveness over the topological order)
# ---------------------------------------------------------------------------


@dataclass
class ArenaInterval:
    """One planned allocation: a value live over ``[start, end]`` layer
    steps, placed at ``offset`` floats into the arena.  ``align`` (in
    arena elements) constrains the placement — int32 scratch inside the
    int8 byte arena plans with ``align=4``."""

    value: str
    start: int
    end: int
    size: int
    offset: int = -1
    align: int = 1


@dataclass
class ArenaPlan:
    """The packed workspace: element offsets for every intermediate
    tensor (and padding scratch), sized by interval interference.

    Elements are float32 for the float path and int8 for the quantized
    path (``elem_bytes`` 4 vs 1) — ``total_floats`` keeps its historic
    name but counts *elements*."""

    total_floats: int
    offsets: Dict[str, int] = field(default_factory=dict)
    intervals: List[ArenaInterval] = field(default_factory=list)
    per_layer_live: Dict[str, int] = field(default_factory=dict)
    buffer_sum_floats: int = 0  # what one-static-buffer-per-tensor costs
    elem_bytes: int = 4

    @property
    def total_bytes(self) -> int:
        return self.total_floats * self.elem_bytes

    @property
    def buffer_sum_bytes(self) -> int:
        return self.buffer_sum_floats * self.elem_bytes

    @property
    def peak_live_floats(self) -> int:
        return max(self.per_layer_live.values(), default=0)


def _value_map(graph: CNNGraph, quantized: bool = False,
               schedule: Optional[Schedule] = None) -> Dict[str, str]:
    """Layer name -> the value (buffer) holding its output. Identity
    layers alias their producer; Input aliases the ``x`` argument — in
    quantized mode the input is itself quantized into an arena buffer
    (``xq``), so Input *defines* a value.

    Under a fusing ``schedule``, a fused producer writes straight into
    its consumer's buffer (Add, pool or Concat) — its own tensor never
    exists, so its name aliases the consumer's value.  (No identity
    layer can alias a fused producer: every fusion predicate requires
    the consumer to be the producer's sole consumer.)"""
    val: Dict[str, str] = {}
    for l in graph.layers:
        if isinstance(l, Input):
            val[l.name] = "xq" if quantized else "x"
        elif isinstance(l, (Dropout, Flatten)):
            val[l.name] = val[l.inputs[0]]
        else:
            val[l.name] = l.name
    if schedule is not None:
        for p, cname in schedule.fused_by_producer.items():
            val[p] = val[cname]
    return val


def _pad_scratch_elems(layer, in_shape, opts: CodegenOptions,
                       elide_static: bool = True) -> int:
    """Elements of padding scratch the emitter will request for this
    layer (0 when padding is statically elided or absent).

    ``elide_static=False`` is the quantized planner's view: the int8
    emitters are rolled (no unroll levels), so padding scratch is
    always materialized."""
    if not isinstance(layer, (Conv2D, DepthwiseConv2D, MaxPool, AvgPool)):
        return 0
    pads = layer.pad_amounts(in_shape)
    if not any(pads):
        return 0
    if elide_static and isinstance(layer, (Conv2D, MaxPool)) and \
            effective_level(layer, in_shape, opts) == 0:
        return 0  # level 0 elides out-of-bounds taps statically
    h, w, c = in_shape
    pt, pb, pl, pr = pads
    return (h + pt + pb) * (w + pl + pr) * c


def _pack_qweights(wt: np.ndarray, co: int, kh: int, row: int,
                   G: int, L: int) -> Tuple[np.ndarray, int]:
    """Tile int8 weight codes for the register-blocked kernels.

    ``wt`` is ``(co, kh*row)`` (taps of one output channel contiguous,
    window rows of ``row`` taps).  Returns ``(packed, P)`` where ``P =
    ceil(row/L)`` lane blocks per window row and ``packed`` is the flat
    ``[n][p][g][k][l]`` layout: for window row ``n`` and lane block
    ``p``, the ``G*L`` codes of channel group ``g`` sit contiguously —
    lane ``k`` holds the ``L`` consecutive taps of output channel
    ``g*G+k`` (zero-padded past the row end), which is exactly the
    operand layout of one madd/dpbusd/dot against a broadcast of those
    ``L`` input taps.  Only the ``(co // G) * G`` fully-grouped channels
    are packed; the remainder runs the per-channel fallback loop."""
    ng = co // G
    P = -(-row // L)
    full = np.zeros((ng * G, kh, P * L), dtype=np.int64)
    full[:, :, :row] = wt[:ng * G].reshape(ng * G, kh, row)
    packed = full.reshape(ng, G, kh, P, L).transpose(2, 3, 0, 1, 4)
    return np.ascontiguousarray(packed).reshape(-1), P


def maddubsw_safe(wt: np.ndarray, co: int, kh: int, row: int) -> bool:
    """Static saturation proof for the ``avx_ubs`` variant.

    ``vpmaddubsw`` sums each pair of adjacent u8*s8 products into a
    *saturating* int16.  With activations re-biased to u8 (0..255), the
    pair over weights ``(a, b)`` spans ``[255*min(a,0)+255*min(b,0),
    255*max(a,0)+255*max(b,0)]`` — in range iff the positive pair sum
    is <= 128 and the negative pair sum is >= -128.  The weights are
    compile-time constants (paper P3), so this is decidable per layer:
    eligible layers get the 4-tap maddubsw kernel, the rest fall back
    to the always-exact pair-madd tile in the same build."""
    packed, _ = _pack_qweights(wt, co, kh, row, G=8, L=4)
    pairs = packed.reshape(-1, 2)
    pos = np.clip(pairs, 0, None).sum(axis=1)
    neg = np.clip(pairs, None, 0).sum(axis=1)
    return bool(pos.max(initial=0) <= 128 and neg.min(initial=0) >= -128)


def maddubsw_any_eligible(qgraph) -> bool:
    """True when at least one conv/dense layer of ``qgraph`` would
    actually use the u8*s8 ``vpmaddubsw`` scheme under 'avx_ubs'.
    When no layer qualifies the variant degenerates layer-by-layer to
    the 'avx' pair-madd build, so it isn't worth enumerating."""
    for layer in qgraph.graph.layers:
        if isinstance(layer, Conv2D):
            co = int(layer.weights.shape[3])
            kh, row = layer.kh, layer.kw * layer.c_in
            wt = np.transpose(qgraph.weights[layer.name].w_q,
                              (3, 0, 1, 2)).reshape(co, kh * row)
        elif isinstance(layer, Dense):
            wt = qgraph.weights[layer.name].w_q.T
            co, row = wt.shape
            kh = 1
        else:
            continue
        if co >= QISAS["avx_ubs"].group and maddubsw_safe(wt, co, kh, row):
            return True
    return False


def plan_arena(graph: CNNGraph,
               opts: Optional[CodegenOptions] = None,
               *, quantized: bool = False,
               schedule: Optional[Schedule] = None) -> ArenaPlan:
    """Liveness-planned packing of every intermediate tensor.

    A value is live from the step of its defining layer to the step of
    its last consumer (interval interference over the topological
    order); padding scratch is live only during its own layer.  The
    network input (``x``) and output (``out``) are caller memory and
    never enter the arena — except in quantized mode, where the int8
    code of the input (``xq``) is itself an arena value.  Placement is
    first-fit at the lowest offset not overlapping any time-overlapping
    interval — for chains this degenerates to ping-pong double
    buffering, for DAGs the skip edges extend lifetimes exactly as long
    as needed.  Quantized plans are in int8 elements (1 byte each), the
    ~4x memory win the int8 path exists for.

    Under a fusing ``schedule`` a fused producer *defines* its
    consumer's value (the store happens inside the producer's loop), so
    the interval starts at the producer's step — the earliest producer
    for a multi-edge fused Concat — and is sized by the *consumer's*
    shape (equal for Add, smaller for a fused pool, larger for a fused
    Concat slice); the consumer's own step only extends lifetimes (the
    other operands are read there in the unfused reference semantics,
    and reading them during the producer's loop is covered because
    their intervals span it).
    """
    opts = opts or CodegenOptions()
    smap = graph.shape_map()
    val = _value_map(graph, quantized, schedule)
    out_value = val[graph.sink.name]
    fused_by_p = schedule.fused_by_producer if schedule is not None else {}

    defs: Dict[str, int] = {}
    last: Dict[str, int] = {}
    sizes: Dict[str, int] = {}
    ivals: List[ArenaInterval] = []
    for i, layer in enumerate(graph.layers):
        if quantized and isinstance(layer, Input):
            defs["xq"] = i
            sizes["xq"] = int(np.prod(smap[layer.name]))
        elif not isinstance(layer, IDENTITY_LAYERS):
            v = val[layer.name]
            defines = v == layer.name or fused_by_p.get(layer.name) == v
            if defines and v not in defs:  # first (producer) def wins
                defs[v] = i
                sizes[v] = int(np.prod(smap[v]))
            scratch = _pad_scratch_elems(layer, smap[layer.inputs[0]],
                                         opts, elide_static=not quantized)
            if scratch:
                ivals.append(ArenaInterval(
                    value=layer.name + "__pad", start=i, end=i,
                    size=scratch))
        for src in layer.inputs:
            sv = val[src]
            if sv != "x":
                last[sv] = i
    for v, d in defs.items():
        if v == out_value:
            continue  # written straight to the caller's `out`
        ivals.append(ArenaInterval(value=v, start=d,
                                   end=last.get(v, d), size=sizes[v]))

    if quantized and schedule is not None:
        # int32 window-sum scratch for fused average pools: the
        # producer's stores accumulate here, the finalize pass requants
        # — live only during the producer's step, 4-byte aligned
        step = {l.name: i for i, l in enumerate(graph.layers)}
        for p, cname in getattr(schedule, "fused_pools", ()):
            if isinstance(graph.layer(cname), AvgPool):
                i = step[p]
                ivals.append(ArenaInterval(
                    value=cname + "__acc", start=i, end=i,
                    size=int(np.prod(smap[cname])) * 4, align=4))

    # first-fit placement over interfering intervals
    ivals.sort(key=lambda iv: (iv.start, -iv.size, iv.value))
    placed: List[ArenaInterval] = []
    for iv in ivals:
        overlap = [p for p in placed
                   if not (iv.end < p.start or p.end < iv.start)]
        for cand in sorted({0} | {p.offset + p.size for p in overlap}):
            cand = -(-cand // iv.align) * iv.align
            if all(cand + iv.size <= p.offset or p.offset + p.size <= cand
                   for p in overlap):
                iv.offset = cand
                break
        placed.append(iv)

    total = max((iv.offset + iv.size for iv in placed), default=0)
    per_layer_live = {
        layer.name: sum(iv.size for iv in placed
                        if iv.start <= i <= iv.end)
        for i, layer in enumerate(graph.layers)
    }
    return ArenaPlan(
        total_floats=total,
        offsets={iv.value: iv.offset for iv in placed},
        intervals=placed,
        per_layer_live=per_layer_live,
        buffer_sum_floats=sum(iv.size for iv in placed),
        elem_bytes=1 if quantized else 4,
    )


# ---------------------------------------------------------------------------
# code generation
# ---------------------------------------------------------------------------


def _cname(value: str) -> str:
    """Sanitize a value name into a C identifier."""
    return "t_" + re.sub(r"[^0-9A-Za-z_]", "_", value)


# back-compat alias: the Add fusion context now lives in the lowering
# IR module alongside the pool/concat variants
_FuseCtx = AddFuse


class CGenerator:
    def __init__(self, graph: CNNGraph, opts: CodegenOptions,
                 schedule: Optional[Schedule] = None):
        self.g = graph
        self.opts = opts
        self.schedule = schedule if schedule is not None else \
            make_schedule(graph, fusion=True, nstages=1)
        self.w = _W()
        self.decls = _W()
        self._uid = 0
        self._epi: Optional[FuseNode] = None   # active store-site fusion
        self._stage = 0                        # pipeline stage being emitted
        self.nests: List[LoopNest] = []        # lowered IR, filled by lower()
        self.plan: Optional[ArenaPlan] = None  # filled by lower()
        self._program: Optional[Program] = None  # lower() result, cached
        self.ws_total_elems: int = 0           # arena + stage interfaces
        self.iface_elems: Tuple[int, ...] = ()
        self.stage_syms: Tuple[str, ...] = ()

    @property
    def _fuse(self) -> Optional[AddFuse]:
        """The active Add fusion context, if any — the legacy view used
        by the Add-specific store paths."""
        return self._epi if isinstance(self._epi, AddFuse) else None

    @property
    def _pool_fuse(self) -> Optional[PoolFuse]:
        return self._epi if isinstance(self._epi, PoolFuse) else None

    @property
    def _concat_fuse(self) -> Optional[ConcatFuse]:
        return self._epi if isinstance(self._epi, ConcatFuse) else None

    # -- helpers ------------------------------------------------------------

    def uid(self) -> int:
        self._uid += 1
        return self._uid

    def const_array(self, name: str, arr: np.ndarray) -> str:
        vals = ", ".join(_flit(v) for v in np.asarray(arr, np.float32).ravel())
        self.decls(f"static const float {name}[{arr.size}] = {{{vals}}};")
        return name

    def floop(self, var: str, bound, step: int = 1) -> None:
        """Open a counted loop with a C89-scoped index; pair with
        :meth:`fclose`."""
        w = self.w
        w.open("")
        w(f"int {var};")
        inc = f"++{var}" if step == 1 else f"{var} += {step}"
        w.open(f"for ({var} = 0; {var} < {bound}; {inc})")

    def fclose(self, n: int = 1) -> None:
        for _ in range(n):
            self.w.close()
            self.w.close()

    # -- activation epilogues (P2: ternary, never a branch) ------------------

    def act_scalar(self, expr: str, act: Optional[str], alpha: float) -> str:
        if act == "relu":
            return f"(({expr}) > 0.0f ? ({expr}) : 0.0f)"
        if act == "leaky_relu":
            return f"(({expr}) > 0.0f ? ({expr}) : {_flit(alpha)} * ({expr}))"
        return expr

    def act_sse(self, reg: str, act: Optional[str], alpha: float) -> List[str]:
        isa = self.opts.isa
        if act == "relu":
            return [f"{reg} = {isa.vmax(reg, isa.zero())};"]
        if act == "leaky_relu":
            # max(x, a*x) == leaky_relu(x) for 0 < a < 1 — branch-free
            return [f"{reg} = {isa.vmax(reg, isa.mul(reg, isa.set1(_flit(alpha))))};"]
        return []

    # -- fused stores (graph-level epilogue fusion) --------------------------
    #
    # With an active AddFuse the producer's store site performs the
    # downstream Add: the activated accumulator is substituted at the
    # producer's position in the Add's left-associated input-order sum,
    # then the Add's activation is applied — the exact float op order
    # of the unfused graph (emit_add), so fusion is bitwise identical.
    #
    # A PoolFuse reduces the producer's store into the pooled output
    # element its position maps to (stride == window, so each element
    # lands in exactly one window): max via the same strict-> ternary
    # chain the unfused pool emits, avg via the same in-order sum; the
    # divisor multiply runs in a finalize pass.  A ConcatFuse stores
    # straight into the producer's channel slice of the Concat output.
    # Both visit window taps / elements in the producer's row-major
    # order — the unfused op order — so float results stay bitwise.

    def _fused_rhs(self, layer, expr: str, oidx: str) -> str:
        """RHS stored for output element ``oidx`` of ``layer`` given
        its (pre-activation) accumulator expression."""
        act = layer.activation if layer.activation != "softmax" else None
        term = self.act_scalar(expr, act, layer.alpha)
        fc = self._fuse
        if fc is None:
            return term
        terms = [term if i == fc.pos else f"{s}[{oidx}]"
                 for i, s in enumerate(fc.srcs)]
        return self.act_scalar(" + ".join(terms), fc.add.activation,
                               fc.add.alpha)

    def _store_stmt(self, layer, expr: str, oidx: str, dst: str,
                    pos=None) -> str:
        """The C statement storing output element ``oidx`` (producer
        position ``pos`` = (i, j, k)) with the active epilogue chain
        applied."""
        pf, cf = self._pool_fuse, self._concat_fuse
        if pf is not None or cf is not None:
            assert pos is not None, \
                f"{layer.name}: fused store needs an output position"
        act = layer.activation if layer.activation != "softmax" else None
        if pf is not None:
            di = pf.dst_index(pos)
            term = self.act_scalar(expr, act, layer.alpha)
            if pf.kind == "max":
                return (f"{{ const float pv = {term}; {dst}[{di}] = "
                        f"pv > {dst}[{di}] ? pv : {dst}[{di}]; }}")
            return f"{dst}[{di}] += {term};"
        if cf is not None:
            return (f"{dst}[{cf.dst_index(pos)}] = "
                    f"{self.act_scalar(expr, act, layer.alpha)};")
        return f"{dst}[{oidx}] = {self._fused_rhs(layer, expr, oidx)};"

    def _store_scalar(self, layer, expr: str, oidx: str, dst: str,
                      pos=None) -> None:
        self.w(self._store_stmt(layer, expr, oidx, dst, pos))

    def _store_vec(self, layer, reg: str, oidx: str, dst: str,
                   pos=None) -> None:
        """Vector store of ``reg`` (one ISA-width channel group at flat
        output index ``oidx``), with the producer's activation and, when
        fusing, the consumer's epilogue applied in-register."""
        w, isa = self.w, self.opts.isa
        act = layer.activation if layer.activation != "softmax" else None
        for ln in self.act_sse(reg, act, layer.alpha):
            w(ln)
        pf, cf = self._pool_fuse, self._concat_fuse
        if pf is not None:
            di = pf.dst_index(pos)
            if pf.kind == "max":
                # new > old ? new : old — the scalar ternary's order
                w(f"{reg} = {isa.vmax(reg, isa.load(f'{dst}[{di}]'))};")
            else:
                # old + new — the scalar `dst += term` order
                w(f"{reg} = {isa.add(isa.load(f'{dst}[{di}]'), reg)};")
            w(isa.store(f"{dst}[{di}]", reg))
            return
        if cf is not None:
            w(isa.store(f"{dst}[{cf.dst_index(pos)}]", reg))
            return
        fc = self._fuse
        if fc is not None:
            expr = None
            for i, s in enumerate(fc.srcs):
                t = reg if i == fc.pos else isa.load(f"{s}[{oidx}]")
                expr = t if expr is None else isa.add(expr, t)
            w(f"{reg} = {expr};")
            for ln in self.act_sse(reg, fc.add.activation, fc.add.alpha):
                w(ln)
        w(isa.store(f"{dst}[{oidx}]", reg))

    # -- padding ------------------------------------------------------------

    def emit_padded_copy(self, src: str, in_shape, pads, buf: str,
                         fill: str = "0.0f"
                         ) -> Tuple[str, Tuple[int, int, int]]:
        """Materialize a padded copy (paper Eq. 1) into the planned
        arena scratch ``buf``, for the looped modes where tap bounds are
        not static.  ``fill`` is the pad value — zero for conv/avg-pool
        sums, ``-FLT_MAX`` for max pooling."""
        h, wdt, c = in_shape
        pt, pb, pl, pr = pads
        ph, pw = h + pt + pb, wdt + pl + pr
        w = self.w
        w(f"/* pad {src} with {fill}: ({h}x{wdt}x{c}) -> "
          f"({ph}x{pw}x{c}) */")
        w(_cfor("z", ph * pw * c, f"{buf}[z] = {fill};"))
        self.floop("i", h)
        w(_cfor("z", wdt * c,
                f"{buf}[((i + {pt}) * {pw} + {pl}) * {c} + z] = "
                f"{src}[i * {wdt * c} + z];"))
        self.fclose()
        return buf, (ph, pw, c)

    # -- conv ---------------------------------------------------------------

    def emit_conv(self, layer: Conv2D, in_shape, src: str, dst: str,
                  pad_buf: Optional[str] = None) -> None:
        opts, w = self.opts, self.w
        level = effective_level(layer, in_shape, opts)
        oh, ow, co = layer.out_shape(in_shape)
        sh, sw = layer.strides
        pads = layer.pad_amounts(in_shape)
        kh, kw_, ci = layer.kh, layer.kw, layer.c_in
        W_ = layer.weights  # HWIO
        B_ = layer.bias

        w(f"/* Conv2D {layer.name}: {in_shape}->{(oh, ow, co)} "
          f"k={kh}x{kw_} s={sh}x{sw} pad={layer.padding} "
          f"act={layer.activation} level={level} simd={opts.simd} */")

        use_pad_buf = any(pads) and level != 0
        if use_pad_buf:
            assert pad_buf is not None, f"{layer.name}: unplanned pad scratch"
            src, in_shape = self.emit_padded_copy(src, in_shape, pads, pad_buf)
            pads = (0, 0, 0, 0)
        h, wdt, _ = in_shape
        pt, _pb, pl, _pr = pads

        literals = level is not None
        wname = bname = None
        if not literals:
            wname = self.const_array(f"w{self.uid()}", W_)
            bname = self.const_array(f"b{self.uid()}", B_)

        def x_index(i, j, n, m, o) -> str:
            """Index into src for output (i,j) tap (n,m,o); i/j may be C exprs."""
            if isinstance(i, int):
                row = i * sh + n - pt
            else:
                row = f"({i} * {sh} + {n - pt})"
            if isinstance(j, int):
                col = j * sw + m - pl
            else:
                col = f"({j} * {sw} + {m - pl})"
            if isinstance(row, int) and isinstance(col, int):
                return str((row * wdt + col) * ci + o)
            return f"(({row}) * {wdt} + ({col})) * {ci} + {o}"

        def out_index(i, j, k) -> str:
            if isinstance(i, int) and isinstance(j, int) and isinstance(k, int):
                return str((i * ow + j) * co + k)
            ke = str(k)
            return f"(({i}) * {ow} + ({j})) * {co} + {ke}"

        def in_bounds(i, j, n, m) -> bool:
            """Static OOB elision (only callable when i and j are ints)."""
            r, c = i * sh + n - pt, j * sw + m - pl
            return 0 <= r < h and 0 <= c < wdt

        def emit_body(i, j) -> None:
            static_ij = isinstance(i, int) and isinstance(j, int)
            if opts.isa is not None:
                self._conv_body_sse(layer, W_, B_, wname, bname, literals,
                                    i, j, static_ij, x_index, out_index,
                                    in_bounds, dst, src)
            else:
                self._conv_body_generic(layer, W_, B_, wname, bname, literals,
                                        i, j, static_ij, x_index, out_index,
                                        in_bounds, dst, src)

        if level == 0:
            for i in range(oh):
                for j in range(ow):
                    emit_body(i, j)
        elif level == 1:
            self.floop("i", oh)
            for j in range(ow):
                emit_body("i", j)
            self.fclose()
        elif level == 2:
            self.floop("i", oh)
            self.floop("j", ow)
            emit_body("i", "j")
            self.fclose(2)
        else:
            self.floop("i", oh)
            self.floop("j", ow)
            self._conv_loops_rolled(layer, wname, bname, in_shape,
                                    (oh, ow, co), dst, src, pads)
            self.fclose(2)

        if layer.activation == "softmax":
            self.emit_softmax((oh, ow, co), dst)

    # rolled inner loops (level=None): weights from const arrays
    def _conv_loops_rolled(self, layer, wname, bname, in_shape, out_shape,
                           dst, src, pads):
        w = self.w
        h, wdt, ci = in_shape
        oh, ow, co = out_shape
        kh, kw_ = layer.kh, layer.kw
        sh, sw = layer.strides
        pt, _, pl, _ = pads
        assert pt == 0 and pl == 0, "rolled mode uses padded buffers"
        act = layer.activation if layer.activation != "softmax" else None
        if self.opts.isa is not None:
            isa = self.opts.isa
            co4 = co - co % isa.width
            if co4:
                self.floop("k", co4, step=isa.width)
                w(f"{isa.reg} acc = {isa.load(f'{bname}[k]')};")
                self.floop("n", kh)
                self.floop("m", kw_)
                self.floop("o", ci)
                xv = f"{src}[((i * {sh} + n) * {wdt} + (j * {sw} + m)) * {ci} + o]"
                wv = f"{wname}[((n * {kw_} + m) * {ci} + o) * {co} + k]"
                w(f"acc = {isa.fmadd(isa.set1(xv), isa.load(wv), 'acc')};")
                self.fclose(3)
                self._store_vec(layer, "acc", f"(i * {ow} + j) * {co} + k",
                                dst, pos=("i", "j", "k"))
                self.fclose()
            ks = range(co4, co)
        elif self.opts.simd == "structured":
            # channel loop innermost over contiguous memory -> auto-vec
            w.open("")
            w(f"float acc[{co}];")
            w(_cfor("k", co, f"acc[k] = {bname}[k];"))
            self.floop("n", kh)
            self.floop("m", kw_)
            self.floop("o", ci)
            w(f"const float xv = {src}[((i * {sh} + n) * {wdt} + "
              f"(j * {sw} + m)) * {ci} + o];")
            w(_cfor("k", co,
                    f"acc[k] += xv * "
                    f"{wname}[((n * {kw_} + m) * {ci} + o) * {co} + k];"))
            self.fclose(3)
            w(_cfor("k", co,
                    self._store_stmt(layer, "acc[k]",
                                     f"(i * {ow} + j) * {co} + k", dst,
                                     ("i", "j", "k"))))
            w.close()
            ks = ()
        else:
            self.floop("k", co)
            w(f"float acc = {bname}[k];")
            self.floop("n", kh)
            self.floop("m", kw_)
            self.floop("o", ci)
            w(f"acc += {wname}[((n * {kw_} + m) * {ci} + o) * {co} + k] * "
              f"{src}[((i * {sh} + n) * {wdt} + (j * {sw} + m)) * {ci} + o];")
            self.fclose(3)
            self._store_scalar(layer, "acc", f"(i * {ow} + j) * {co} + k",
                               dst, pos=("i", "j", "k"))
            self.fclose()
            ks = ()
        # scalar tail for sse mode
        for k in ks:
            w.open("")
            w(f"float acc = {bname}[{k}];")
            w(_cfor("n", kh, _cfor("m", kw_, _cfor(
                "o", ci,
                f"acc += {wname}[((n * {kw_} + m) * {ci} + o) * {co} + {k}] * "
                f"{src}[((i * {sh} + n) * {wdt} + (j * {sw} + m)) * {ci} + o];"
            ))))
            self._store_scalar(layer, "acc",
                               f"(i * {ow} + j) * {co} + {k}", dst,
                               pos=("i", "j", k))
            w.close()

    # unrolled bodies --------------------------------------------------------

    def _taps(self, layer, i, j, static_ij, in_bounds):
        for n in range(layer.kh):
            for m in range(layer.kw):
                if static_ij and not in_bounds(i, j, n, m):
                    continue  # P3: zero tap elided entirely
                for o in range(layer.c_in):
                    yield n, m, o

    def _conv_body_generic(self, layer, W_, B_, wname, bname, literals,
                           i, j, static_ij, x_index, out_index, in_bounds,
                           dst, src):
        w = self.w
        co = layer.c_out
        act = layer.activation if layer.activation != "softmax" else None
        w.open("")  # scope block
        for k in range(co):
            bias = _flit(B_[k]) if literals else f"{bname}[{k}]"
            w(f"float a{k} = {bias};")
        for n, m, o in self._taps(layer, i, j, static_ij, in_bounds):
            xv = f"{src}[{x_index(i, j, n, m, o)}]"
            for k in range(co):
                wv = (_flit(W_[n, m, o, k]) if literals
                      else f"{wname}[{((n * layer.kw + m) * layer.c_in + o) * co + k}]")
                w(f"a{k} += {xv} * {wv};")
        for k in range(co):
            self._store_scalar(layer, f"a{k}", out_index(i, j, k), dst,
                               pos=(i, j, k))
        w.close()

    def _conv_body_sse(self, layer, W_, B_, wname, bname, literals,
                       i, j, static_ij, x_index, out_index, in_bounds,
                       dst, src):
        w = self.w
        isa = self.opts.isa
        vw = isa.width
        co = layer.c_out
        co4 = co - co % vw
        act = layer.activation if layer.activation != "softmax" else None
        w.open("")
        for kg in range(0, co4, vw):
            if literals:
                w(f"{isa.reg} v{kg} = "
                  f"{isa.set_lits(B_[kg:kg + vw])};")
            else:
                w(f"{isa.reg} v{kg} = {isa.load(f'{bname}[{kg}]')};")
        for n, m, o in self._taps(layer, i, j, static_ij, in_bounds):
            xv = f"{src}[{x_index(i, j, n, m, o)}]"
            w(f"{{ const {isa.reg} xb = {isa.set1(xv)};")
            for kg in range(0, co4, vw):
                if literals:
                    wreg = isa.set_lits(W_[n, m, o, kg:kg + vw])
                else:
                    off = ((n * layer.kw + m) * layer.c_in + o) * co + kg
                    wreg = isa.load(f"{wname}[{off}]")
                w(f"  v{kg} = {isa.fmadd('xb', wreg, f'v{kg}')};")
            w("}")
        for kg in range(0, co4, vw):
            self._store_vec(layer, f"v{kg}", out_index(i, j, kg), dst,
                            pos=(i, j, kg))
        # scalar tail, each channel in its own block (C89: decls first)
        for k in range(co4, co):
            bias = _flit(B_[k]) if literals else f"{bname}[{k}]"
            w.open("")
            w(f"float t{k} = {bias};")
            for n, m, o in self._taps(layer, i, j, static_ij, in_bounds):
                xv = f"{src}[{x_index(i, j, n, m, o)}]"
                wv = (_flit(W_[n, m, o, k]) if literals
                      else f"{wname}[{((n * layer.kw + m) * layer.c_in + o) * co + k}]")
                w(f"t{k} += {xv} * {wv};")
            self._store_scalar(layer, f"t{k}", out_index(i, j, k), dst,
                               pos=(i, j, k))
            w.close()
        w.close()

    # -- depthwise conv ------------------------------------------------------

    def emit_depthwise(self, layer: DepthwiseConv2D, in_shape, src: str,
                       dst: str, pad_buf: Optional[str] = None) -> None:
        w = self.w
        oh, ow, co = layer.out_shape(in_shape)
        pads = layer.pad_amounts(in_shape)
        kh, kw_, ci, mult = layer.kh, layer.kw, layer.c_in, layer.multiplier
        sh, sw = layer.strides
        w(f"/* DepthwiseConv2D {layer.name}: {in_shape}->{(oh, ow, co)} "
          f"k={kh}x{kw_} s={sh}x{sw} mult={mult} pad={layer.padding} "
          f"act={layer.activation} */")
        if any(pads):
            assert pad_buf is not None, f"{layer.name}: unplanned pad scratch"
            src, in_shape = self.emit_padded_copy(src, in_shape, pads, pad_buf)
        h, wdt, _ = in_shape
        wname = self.const_array(f"w{self.uid()}", layer.weights)
        bname = self.const_array(f"b{self.uid()}", layer.bias)
        act = layer.activation if layer.activation != "softmax" else None
        self.floop("i", oh)
        self.floop("j", ow)
        self.floop("c", ci)
        for m_ in range(mult):
            w.open("")
            w(f"float acc = {bname}[c * {mult} + {m_}];")
            w(_cfor("n", kh, _cfor(
                "m", kw_,
                f"acc += {src}[((i * {sh} + n) * {wdt} + "
                f"(j * {sw} + m)) * {ci} + c] * "
                f"{wname}[((n * {kw_} + m) * {ci} + c) * {mult} + {m_}];")))
            self._store_scalar(layer, "acc",
                               f"(i * {ow} + j) * {co} + c * {mult} + {m_}",
                               dst, pos=("i", "j", f"c * {mult} + {m_}"))
            w.close()
        self.fclose(3)
        if layer.activation == "softmax":
            self.emit_softmax((oh, ow, co), dst)

    # -- pooling / merge / elementwise / softmax / dense ---------------------

    def emit_maxpool(self, layer: MaxPool, in_shape, src: str, dst: str,
                     pad_buf: Optional[str] = None) -> None:
        w, opts = self.w, self.opts
        oh, ow, co = layer.out_shape(in_shape)
        kh, kw_ = layer.size
        sh, sw = layer.strides
        pads = layer.pad_amounts(in_shape)
        level = effective_level(layer, in_shape, opts)
        w(f"/* MaxPool {layer.name}: {in_shape}->{(oh, ow, co)} "
          f"k={kh}x{kw_} s={sh}x{sw} pad={layer.padding} level={level} */")

        # like conv: level 0 elides out-of-bounds taps statically; any
        # looped level materializes a -FLT_MAX-padded copy (the fill
        # never wins — every window covers >=1 valid tap)
        if any(pads) and level != 0:
            assert pad_buf is not None, f"{layer.name}: unplanned pad scratch"
            src, in_shape = self.emit_padded_copy(src, in_shape, pads,
                                                  pad_buf, _NEG_FLT_MAX)
            pads = (0, 0, 0, 0)
        h, wdt, c = in_shape
        pt, _pb, pl, _pr = pads

        def in_bounds(i, j, n, m) -> bool:
            r, cc = i * sh + n - pt, j * sw + m - pl
            return 0 <= r < h and 0 <= cc < wdt

        def taps(i, j):
            static_ij = isinstance(i, int) and isinstance(j, int)
            for n in range(kh):
                for m in range(kw_):
                    if static_ij and not in_bounds(i, j, n, m):
                        continue  # P3: padding tap elided entirely
                    yield n, m

        def body(i, j):
            isa = opts.isa
            if isa is not None and c % isa.width == 0:
                for kg in range(0, c, isa.width):
                    w.open("")
                    first = True
                    for n, m in taps(i, j):
                        idx = x_idx(i, j, n, m, kg)
                        if first:
                            w(f"{isa.reg} p = "
                              f"{isa.load(f'{src}[{idx}]')};")
                            first = False
                        else:
                            w(f"p = {isa.vmax('p', isa.load(f'{src}[{idx}]'))};")
                    w(isa.store(f"{dst}[{o_idx(i, j, kg)}]", "p"))
                    w.close()
            else:
                for k in range(c):
                    w.open("")
                    first = True
                    for n, m in taps(i, j):
                        idx = x_idx(i, j, n, m, k)
                        if first:
                            w(f"float q = {src}[{idx}];")
                            first = False
                        else:
                            # P2: ternary, not an if
                            w(f"q = {src}[{idx}] > q ? "
                              f"{src}[{idx}] : q;")
                    w(f"{dst}[{o_idx(i, j, k)}] = q;")
                    w.close()

        def x_idx(i, j, n, m, k):
            if isinstance(i, int) and isinstance(j, int):
                return str(((i * sh + n - pt) * wdt + (j * sw + m - pl))
                           * c + k)
            return (f"(({i} * {sh} + {n - pt}) * {wdt} + "
                    f"({j} * {sw} + {m - pl})) * {c} + {k}")

        def o_idx(i, j, k):
            if isinstance(i, int) and isinstance(j, int):
                return str((i * ow + j) * co + k)
            return f"(({i}) * {ow} + ({j})) * {co} + {k}"

        if level == 0:
            for i in range(oh):
                for j in range(ow):
                    body(i, j)
        elif level == 1:
            self.floop("i", oh)
            for j in range(ow):
                body("i", j)
            self.fclose()
        elif level == 2:
            self.floop("i", oh)
            self.floop("j", ow)
            body("i", "j")
            self.fclose(2)
        else:
            self.floop("i", oh)
            self.floop("j", ow)
            if opts.isa is not None and c % opts.isa.width == 0:
                isa = opts.isa
                self.floop("k", c, step=isa.width)
                w(f"{isa.reg} p = "
                  f"{isa.load(f'{src}[' + x_idx('i', 'j', 0, 0, 0) + ' + k]')};")
                for n in range(kh):
                    for m in range(kw_):
                        if n == 0 and m == 0:
                            continue
                        ld = isa.load(f"{src}[" + x_idx('i', 'j', n, m, 0)
                                      + " + k]")
                        w(f"p = {isa.vmax('p', ld)};")
                w(isa.store(f"{dst}[(i * {ow} + j) * {co} + k]", "p"))
                self.fclose()
            else:
                self.floop("k", c)
                w(f"float q = {src}[{x_idx('i', 'j', 0, 0, 0)} + k];")
                for n in range(kh):
                    for m in range(kw_):
                        if n == 0 and m == 0:
                            continue
                        w(f"q = {src}[{x_idx('i', 'j', n, m, 0)} + k] > q ? "
                          f"{src}[{x_idx('i', 'j', n, m, 0)} + k] : q;")
                w(f"{dst}[(i * {ow} + j) * {co} + k] = q;")
                self.fclose()
            self.fclose(2)

    def emit_avgpool(self, layer: AvgPool, in_shape, src: str, dst: str,
                     pad_buf: Optional[str] = None) -> None:
        w = self.w
        oh, ow, co = layer.out_shape(in_shape)
        kh, kw_ = layer.size
        sh, sw = layer.strides
        pads = layer.pad_amounts(in_shape)
        counts = pool_window_counts(in_shape, layer.size, layer.strides,
                                    pads)
        w(f"/* AvgPool {layer.name}: {in_shape}->{(oh, ow, co)} "
          f"k={kh}x{kw_} s={sh}x{sw} pad={layer.padding} */")
        if any(pads):
            # zero fill keeps the window sum correct; the divisor below
            # counts only the valid taps (edge-correct, not 1/(kh*kw))
            assert pad_buf is not None, f"{layer.name}: unplanned pad scratch"
            src, in_shape = self.emit_padded_copy(src, in_shape, pads,
                                                  pad_buf)
        h, wdt, c = in_shape
        if counts.min() == counts.max():
            inv_expr = _flit(1.0 / counts.max())
        else:
            # edge windows cover fewer valid taps: per-window inverse
            # divisor table, indexed by the output position
            invm = self.const_array(
                f"pinv{self.uid()}",
                (1.0 / counts.astype(np.float64)).astype(np.float32))
            inv_expr = f"{invm}[i * {ow} + j]"
        self.floop("i", oh)
        self.floop("j", ow)
        self.floop("k", c)
        w("float s = 0.0f;")
        w(_cfor("n", kh, _cfor(
            "m", kw_,
            f"s += {src}[((i * {sh} + n) * {wdt} + "
            f"(j * {sw} + m)) * {c} + k];")))
        w(f"{dst}[(i * {ow} + j) * {co} + k] = s * {inv_expr};")
        self.fclose(3)

    def emit_global_avgpool(self, layer: GlobalAvgPool, in_shape,
                            src: str, dst: str) -> None:
        w = self.w
        h, wdt, c = in_shape
        inv = _flit(1.0 / (h * wdt))
        w(f"/* GlobalAvgPool {layer.name}: {in_shape}->(1, 1, {c}) */")
        self.floop("k", c)
        w("float s = 0.0f;")
        w(_cfor("p", h * wdt, f"s += {src}[p * {c} + k];"))
        w(f"{dst}[k] = s * {inv};")
        self.fclose()

    def emit_add(self, layer: Add, shape, srcs: List[str], dst: str) -> None:
        w = self.w
        n = int(np.prod(shape))
        isa = self.opts.isa
        act = layer.activation if layer.activation != "softmax" else None
        w(f"/* Add {layer.name}: {len(srcs)} inputs, {shape}, "
          f"act={layer.activation} */")
        if isa is not None and n % isa.width == 0 and len(srcs) >= 2:
            self.floop("z", n, step=isa.width)
            w(f"{isa.reg} v = {isa.load(f'{srcs[0]}[z]')};")
            for s in srcs[1:]:
                w(f"v = {isa.add('v', isa.load(f'{s}[z]'))};")
            for ln in self.act_sse("v", act, layer.alpha):
                w(ln)
            w(isa.store(f"{dst}[z]", "v"))
            self.fclose()
        else:
            expr = " + ".join(f"{s}[z]" for s in srcs)
            w(_cfor("z", n,
                    f"{dst}[z] = {self.act_scalar(expr, act, layer.alpha)};"))

    def emit_concat(self, layer: Concat, in_shapes, srcs: List[str],
                    dst: str) -> None:
        w = self.w
        h, wdt, _ = in_shapes[0]
        co = int(sum(s[2] for s in in_shapes))
        fused_by_p = self.schedule.fused_by_producer
        fused = [fused_by_p.get(n) == layer.name for n in layer.inputs]
        w(f"/* Concat {layer.name}: {[tuple(s) for s in in_shapes]} -> "
          f"({h}, {wdt}, {co}) */")
        if all(fused):
            w("/* all inputs fused into their producers' stores */")
            return
        self.floop("p", h * wdt)
        off = 0
        for s, ish, fz in zip(srcs, in_shapes, fused):
            ck = int(ish[2])
            if not fz:
                w(_cfor("z", ck,
                        f"{dst}[p * {co} + {off} + z] = {s}[p * {ck} + z];"))
            off += ck
        self.fclose()

    def emit_elementwise(self, in_shape, src, dst, act, alpha) -> None:
        w = self.w
        n = int(np.prod(in_shape))
        isa = self.opts.isa
        if isa is not None and n % isa.width == 0 and act in (
                "relu", "leaky_relu"):
            self.floop("z", n, step=isa.width)
            w(f"{isa.reg} v = {isa.load(f'{src}[z]')};")
            for ln in self.act_sse("v", act, alpha):
                w(ln)
            w(isa.store(f"{dst}[z]", "v"))
            self.fclose()
        else:
            w(_cfor("z", n,
                    f"{dst}[z] = {self.act_scalar(f'{src}[z]', act, alpha)};"))

    def emit_batchnorm(self, layer: BatchNorm, in_shape, src, dst) -> None:
        w = self.w
        scale, shift = layer.scale_shift()
        c = in_shape[2]
        sname = self.const_array(f"s{self.uid()}", scale)
        tname = self.const_array(f"t{self.uid()}", shift)
        n = int(np.prod(in_shape))
        w(_cfor("z", n,
                f"{dst}[z] = {src}[z] * {sname}[z % {c}] + "
                f"{tname}[z % {c}];"))

    def emit_softmax(self, shape, buf) -> None:
        w = self.w
        h, wdt, c = shape
        w(f"/* softmax over {c} channels */")
        self.floop("p", h * wdt)
        w(f"float mx = {buf}[p * {c}];")
        w("float s = 0.0f;")
        w(_cfor("k", c,
                f"mx = {buf}[p * {c} + k] > mx ? {buf}[p * {c} + k] : mx;",
                start=1))
        w(_cfor("k", c,
                f"{{ {buf}[p * {c} + k] = expf({buf}[p * {c} + k] - mx); "
                f"s += {buf}[p * {c} + k]; }}"))
        w(_cfor("k", c, f"{buf}[p * {c} + k] /= s;"))
        self.fclose()

    def emit_dense(self, layer: Dense, in_shape, src, dst) -> None:
        w = self.w
        d_in, d_out = layer.weights.shape
        wname = self.const_array(f"w{self.uid()}", layer.weights)
        bname = self.const_array(f"b{self.uid()}", layer.bias)
        act = layer.activation if layer.activation != "softmax" else None
        w(f"/* Dense {layer.name}: {d_in}->{d_out} */")
        self.floop("k", d_out)
        w(f"float acc = {bname}[k];")
        w(_cfor("z", d_in, f"acc += {src}[z] * {wname}[z * {d_out} + k];"))
        self._store_scalar(layer, "acc", "k", dst, pos=(0, 0, "k"))
        self.fclose()
        if layer.activation == "softmax":
            self.emit_softmax((1, 1, d_out), dst)

    # -- driver ---------------------------------------------------------------

    _elem = "float"       # arena / intermediate element C type
    _quantized = False

    def _emit_layer(self, layer, smap, val, ref, plan) -> None:
        """Emit one layer's code with sources/destination resolved by
        ``ref`` — shared by the monolithic body and the stage bodies."""
        w = self.w
        ishs = [smap[n] for n in layer.inputs]
        srcs = [ref(val[n]) for n in layer.inputs]
        dst = ref(val[layer.name])
        pad_buf = (_cname(layer.name + "__pad")
                   if layer.name + "__pad" in plan.offsets else None)
        if isinstance(layer, Conv2D):
            self.emit_conv(layer, ishs[0], srcs[0], dst, pad_buf)
        elif isinstance(layer, DepthwiseConv2D):
            self.emit_depthwise(layer, ishs[0], srcs[0], dst, pad_buf)
        elif isinstance(layer, MaxPool):
            self.emit_maxpool(layer, ishs[0], srcs[0], dst, pad_buf)
        elif isinstance(layer, AvgPool):
            self.emit_avgpool(layer, ishs[0], srcs[0], dst, pad_buf)
        elif isinstance(layer, GlobalAvgPool):
            self.emit_global_avgpool(layer, ishs[0], srcs[0], dst)
        elif isinstance(layer, Add):
            self.emit_add(layer, smap[layer.name], srcs, dst)
        elif isinstance(layer, Concat):
            self.emit_concat(layer, ishs, srcs, dst)
        elif isinstance(layer, ReLU):
            self.emit_elementwise(ishs[0], srcs[0], dst, "relu", 0.0)
        elif isinstance(layer, LeakyReLU):
            self.emit_elementwise(ishs[0], srcs[0], dst, "leaky_relu",
                                  layer.alpha)
        elif isinstance(layer, Softmax):
            if srcs[0] != dst:
                w(_cfor("z", int(np.prod(ishs[0])),
                        f"{dst}[z] = {srcs[0]}[z];"))
            self.emit_softmax(ishs[0], dst)
        elif isinstance(layer, BatchNorm):
            self.emit_batchnorm(layer, ishs[0], srcs[0], dst)
        elif isinstance(layer, Dense):
            self.emit_dense(layer, ishs[0], srcs[0], dst)
        else:  # pragma: no cover
            raise TypeError(f"cgen: unhandled layer {type(layer).__name__}")

    def _fuse_node(self, layer, smap, val, ref) -> Optional[FuseNode]:
        """Build the live fusion context for ``layer`` when the schedule
        folds one of its consumers into its store site."""
        cname = self.schedule.fused_by_producer.get(layer.name)
        if cname is None:
            return None
        cons = self.g.layer(cname)
        if isinstance(cons, Add):
            return AddFuse(add=cons, pos=cons.inputs.index(layer.name),
                           srcs=[ref(val[n]) for n in cons.inputs])
        if isinstance(cons, (MaxPool, AvgPool)):
            ph, pw, c = smap[cname]
            sh, sw = cons.strides
            kh, kw_ = cons.size
            kind = "max" if isinstance(cons, MaxPool) else "avg"
            return PoolFuse(
                pool=cons, kind=kind, pw=pw, c=c, sh=sh, sw=sw,
                dst=ref(val[cname]), n=ph * pw * c,
                inv=_flit(1.0 / (kh * kw_)),
                acc=(_cname(cname + "__acc")
                     if self._quantized and kind == "avg" else ""))
        pos = cons.inputs.index(layer.name)
        return ConcatFuse(
            concat=cons, pos=pos,
            c_off=int(sum(smap[n][2] for n in cons.inputs[:pos])),
            c_total=int(smap[cname][2]), ow=int(smap[cname][1]))

    def _emit_fuse_init(self, node: FuseNode, smap) -> None:
        """Prologue before a fused producer's loops: pooling fills the
        consumer's output with the reduction identity."""
        if isinstance(node, PoolFuse):
            p = node.pool
            self.w(f"/* fused {type(p).__name__} {p.name}: producer "
                   f"stores reduce straight into the {node.kind} "
                   f"windows */")
            fill = _NEG_FLT_MAX if node.kind == "max" else "0.0f"
            self.w(_cfor("z", node.n, f"{node.dst}[z] = {fill};"))
        elif isinstance(node, ConcatFuse):
            self.w(f"/* fused Concat {node.concat.name} edge {node.pos}: "
                   f"producer writes its channel slice at offset "
                   f"{node.c_off} directly */")

    def _emit_fuse_finalize(self, node: FuseNode, smap) -> None:
        """Epilogue after a fused producer's loops: average pooling
        applies the window divisor (same op order as the unfused
        ``s * inv`` store, so results stay bitwise)."""
        if isinstance(node, PoolFuse) and node.kind == "avg":
            self.w(_cfor("z", node.n, f"{node.dst}[z] *= {node.inv};"))

    def _record_nest(self, layer, smap, node: Optional[FuseNode],
                     start: int, end: int) -> None:
        """Append the typed :class:`LoopNest` for the layer span just
        emitted into ``self.w.lines[start:end]``."""
        out_shape = tuple(smap[layer.name])
        if isinstance(layer, (Conv2D, DepthwiseConv2D, MaxPool, AvgPool)):
            oh, ow, c = out_shape
            lvl = (effective_level(layer, smap[layer.inputs[0]], self.opts)
                   if not self._quantized
                   and isinstance(layer, (Conv2D, MaxPool)) else None)
            loops = (Loop("i", oh, unrolled=lvl == 0),
                     Loop("j", ow, unrolled=lvl in (0, 1)),
                     Loop("k", c, unrolled=lvl is not None))
            variant = f"level={lvl} simd={self.opts.simd}"
        elif isinstance(layer, Dense):
            loops = (Loop("k", out_shape[-1]),)
            variant = f"simd={self.opts.simd}"
        else:
            loops = (Loop("z", int(np.prod(out_shape))),)
            variant = f"simd={self.opts.simd}"
        kind = ("q" if self._quantized else "") + type(layer).__name__.lower()
        eps: List[Epilogue] = []
        act = getattr(layer, "activation", None)
        if act == "softmax":
            eps.append(Epilogue("softmax", layer.name))
        elif act:
            eps.append(Epilogue("act", layer.name, act))
        if self._quantized and layer is not self.g.sink and \
                isinstance(layer, (Conv2D, DepthwiseConv2D, Dense)):
            eps.append(Epilogue("requant", layer.name))
        if isinstance(node, AddFuse):
            eps.append(Epilogue("add_fuse", node.add.name,
                                f"pos={node.pos}"))
            if node.add.activation:
                eps.append(Epilogue("act", node.add.name,
                                    node.add.activation))
            if self._quantized:
                eps.append(Epilogue("requant", node.add.name))
        elif isinstance(node, PoolFuse):
            eps.append(Epilogue(f"{node.kind}pool_fuse", node.pool.name))
            if self._quantized:
                eps.append(Epilogue("requant", node.pool.name))
        elif isinstance(node, ConcatFuse):
            eps.append(Epilogue("concat_fuse", node.concat.name,
                                f"edge={node.pos} c_off={node.c_off}"))
            if self._quantized:
                eps.append(Epilogue("requant", node.concat.name))
        self.nests.append(LoopNest(
            layer=layer.name, op=type(layer).__name__,
            out_shape=out_shape, loops=loops,
            kernel=KernelCall(kind=kind, layer=layer.name,
                              variant=variant, span=(start, end)),
            epilogue=tuple(eps), stage=self._stage))

    def _emit_graph_body(self, layers, smap, val, ref, plan) -> None:
        """Emit ``layers`` in order, skipping identity layers and
        absorbed consumers (fused Adds/pools), arming the fusion
        context around fused producers."""
        absorbed = self.schedule.absorbed_consumers
        for layer in layers:
            if isinstance(layer, IDENTITY_LAYERS) or \
                    layer.name in absorbed:
                continue
            node = self._fuse_node(layer, smap, val, ref)
            start = len(self.w.lines)
            if node is not None:
                self._emit_fuse_init(node, smap)
            self._epi = node
            try:
                self._emit_layer(layer, smap, val, ref, plan)
            finally:
                self._epi = None
            if node is not None:
                self._emit_fuse_finalize(node, smap)
            self._record_nest(layer, smap, node, start, len(self.w.lines))

    # -- pipeline emission ---------------------------------------------------

    def _emit_pipeline(self, smap, val, out_value, plan) -> None:
        """Emit one function per schedule stage plus the
        ``<func>_pipeline`` driver.

        Stage ``s`` is ``void <func>_stage<s>(in, out, ws)``: ``in`` is
        the interface buffer written by stage ``s-1`` (the raw network
        input for stage 0), ``out`` the interface it feeds stage ``s+1``
        (the network output for the last stage), ``ws`` the ordinary
        arena for values that never cross a stage boundary (plus pad
        scratch).  A value defined in one stage and consumed two or more
        stages later is forwarded by memcpy through every interface in
        between.  The sequential driver carves the interfaces from the
        tail of one workspace; ``runtime.PipelineRunner`` instead
        double-buffers each interface and runs the stages on separate
        threads for batch-1 stream throughput."""
        g, opts, w, sched = self.g, self.opts, self.w, self.schedule
        elem = self._elem
        quantized = self._quantized
        S = sched.nstages
        stage_of = {u: s for s, us in enumerate(sched.stages) for u in us}
        absorbed_by = sched.fused_by_consumer

        def eff_stage(name: str) -> int:
            """Stage where layer ``name``'s reads/writes actually run."""
            if name in absorbed_by:
                return stage_of[absorbed_by[name]]
            return stage_of[name]

        # def/last-use stages per value; sizes in elements
        def_stage: Dict[str, int] = {}
        vsizes: Dict[str, int] = {}
        if quantized:
            def_stage["xq"] = 0  # the input-quant prologue runs in stage 0
            vsizes["xq"] = int(np.prod(g.input_shape))
        else:
            def_stage["x"] = -1  # the caller's input argument
            vsizes["x"] = int(np.prod(g.input_shape))
        for u in stage_of:
            v = val[u]
            if v not in def_stage:
                def_stage[v] = stage_of[u]
                vsizes[v] = int(np.prod(smap[u]))
        last_stage: Dict[str, int] = {}
        for layer in g.layers:
            if isinstance(layer, IDENTITY_LAYERS):
                continue
            s_l = eff_stage(layer.name)
            for n in layer.inputs:
                v = val[n]
                if v in def_stage:
                    last_stage[v] = max(last_stage.get(v, s_l), s_l)

        def crosses(v: str, b: int) -> bool:
            """Value ``v`` is transported over boundary ``b`` (between
            stage ``b`` and ``b+1``)."""
            return def_stage[v] <= b < last_stage.get(v, def_stage[v])

        iface_vals: List[List[str]] = []
        iface_off: List[Dict[str, int]] = []
        iface_sz: List[int] = []
        for b in range(S - 1):
            vs = sorted(v for v in def_stage if crosses(v, b))
            offs, cum = {}, 0
            for v in vs:
                offs[v] = cum
                cum += vsizes[v]
            iface_vals.append(vs)
            iface_off.append(offs)
            iface_sz.append(cum)
        self.iface_elems = tuple(iface_sz)

        copy_n = (f"{{n}} * sizeof(float)" if not quantized else "{n}")
        for s in range(S):
            self._stage = s
            in_ty = "const float" if s == 0 or not quantized \
                else f"const {elem}"
            out_ty = "float" if s == S - 1 else elem
            units = sched.stages[s]
            layers = [g.layer(u) for u in units]

            # every value touched in this stage, in a stable order
            used: List[str] = []

            def need(v: str) -> None:
                if v not in used:
                    used.append(v)
            pads: List[str] = []
            accs: List[str] = []
            for layer in layers:
                need(val[layer.name])
                for n in layer.inputs:
                    need(val[n])
                a = self.schedule.fused_by_producer.get(layer.name)
                if a is not None:
                    for n in g.layer(a).inputs:
                        need(val[n])
                    if a + "__acc" in plan.offsets:
                        accs.append(a + "__acc")
                if layer.name + "__pad" in plan.offsets:
                    pads.append(layer.name + "__pad")
            passthrough = [] if s == S - 1 else \
                [v for v in iface_vals[s] if def_stage[v] != s]
            for v in passthrough:
                need(v)
            if quantized and s == 0:
                need("xq")

            names: Dict[str, str] = {}
            decls: List[str] = []
            uses_ws = bool(pads or accs)
            for v in sorted(used):
                if not quantized and v == "x" and s == 0:
                    names[v] = "in"
                elif v == out_value and s == S - 1:
                    names[v] = "out"
                elif def_stage[v] == s:
                    names[v] = _cname(v)
                    if s < S - 1 and crosses(v, s):
                        decls.append(f"{out_ty} *const {names[v]} = "
                                     f"out + {iface_off[s][v]};")
                    else:
                        decls.append(f"{elem} *const {names[v]} = "
                                     f"ws + {plan.offsets[v]};")
                        uses_ws = True
                else:  # defined in an earlier stage: read the in iface
                    names[v] = _cname(v)
                    decls.append(f"{in_ty} *const {names[v]} = "
                                 f"in + {iface_off[s - 1][v]};")

            w.open(f"void {opts.stage_func_name(s)}("
                   f"{in_ty} *NNCG_RESTRICT in, "
                   f"{out_ty} *NNCG_RESTRICT out, "
                   f"{elem} *NNCG_RESTRICT ws)")
            for d in decls:
                w(d)
            for p in pads:
                w(f"{elem} *const {_cname(p)} = ws + {plan.offsets[p]};")
            for acc in accs:
                w(f"int *const {_cname(acc)} = "
                  f"(int *)(void *)(ws + {plan.offsets[acc]});")
            if not uses_ws:
                w("(void) ws;")
            for v in passthrough:
                src = names[v]  # "in" for x at stage 0, a decl otherwise
                w(f"memcpy(out + {iface_off[s][v]}, {src}, "
                  f"{copy_n.format(n=vsizes[v])});")
            if quantized and s == 0:
                self._emit_input_quant("in")
            self._emit_graph_body(layers, smap, val,
                                  lambda v: names[v], plan)
            w.close()
            w("")
        self._stage = 0

        # sequential driver: interfaces carved from the workspace tail,
        # every stage sharing one arena (interface and arena subranges
        # are disjoint, so the restrict contract holds)
        w.open(f"void {opts.pipeline_func_name}("
               f"const float *NNCG_RESTRICT x, "
               f"float *NNCG_RESTRICT out, "
               f"{elem} *NNCG_RESTRICT ws, int nstages)")
        cum = plan.total_floats
        for b in range(S - 1):
            w(f"{elem} *const iface{b} = ws + {cum}; "
              f"/* stage {b} -> {b + 1}: {iface_sz[b]} elems */")
            cum += iface_sz[b]
        w("(void) nstages;")
        for s in range(S):
            a = "x" if s == 0 else f"iface{s - 1}"
            o = "out" if s == S - 1 else f"iface{s}"
            w(f"{opts.stage_func_name(s)}({a}, {o}, ws);")
        w.close()
        w("")
        w.open(f"long {opts.pipeline_nstages_func_name}(void)")
        w(f"return {S}L;")
        w.close()
        w("")
        self.ws_total_elems = cum
        self.stage_syms = tuple(opts.stage_func_name(s) for s in range(S))

    def lower(self) -> Program:
        """Lower the scheduled graph into the loop-nest IR — fills the
        writer blocks and ``self.nests`` and returns the
        :class:`Program` that :func:`repro.core.lowering.render` turns
        into C source."""
        if self._program is not None:
            return self._program
        g, opts, w = self.g, self.opts, self.w
        sched = self.schedule
        smap = g.shape_map()
        plan = self.plan = plan_arena(g, opts, schedule=sched)
        val = _value_map(g, schedule=sched)
        out_value = val[g.sink.name]
        S = sched.nstages
        self.ws_total_elems = plan.total_floats

        def ref(v: str) -> str:
            if v == "x":
                return "x"
            if v == out_value:
                return "out"
            return _cname(v)

        if S > 1:
            self._emit_pipeline(smap, val, out_value, plan)

        w.open(f"void {opts.ws_func_name}(const float *NNCG_RESTRICT x, "
               f"float *NNCG_RESTRICT out, float *NNCG_RESTRICT ws)")
        if S > 1:
            # the layer code lives in the stage functions exactly once;
            # the classic entry routes through the sequential driver
            w(f"{opts.pipeline_func_name}(x, out, ws, {S});")
        else:
            # workspace carving: all pointer declarations first (C89)
            for iv in sorted(plan.intervals,
                             key=lambda iv: (iv.offset, iv.value)):
                w(f"float *const {_cname(iv.value)} = ws + {iv.offset}; "
                  f"/* {iv.size} floats, live layers "
                  f"[{iv.start}, {iv.end}] */")
            if not plan.intervals:
                w("(void) ws;")
            self._emit_graph_body(g.layers, smap, val, ref, plan)
            if out_value == "x":  # degenerate identity graph
                w(_cfor("z", int(np.prod(g.input_shape)), "out[z] = x[z];"))
        w.close()

        # static-arena wrapper: the paper's embedded single-image entry
        arena = f"{opts.func_name}_arena"
        self.decls(f"static float {arena}[{max(self.ws_total_elems, 1)}];")
        w("")
        w.open(f"void {opts.func_name}(const float *NNCG_RESTRICT x, "
               f"float *NNCG_RESTRICT out)")
        w(f"{opts.ws_func_name}(x, out, {arena});")
        w.close()
        w("")
        w.open(f"long {opts.ws_size_func_name}(void)")
        w(f"return {self.ws_total_elems}L;")
        w.close()

        if opts.emit_batch:
            # serving entry points: N images through the single-image
            # function.  <func>_batch runs over the static arena;
            # <func>_batch_ws takes a caller workspace, so a server
            # worker pool pushes whole batches through one foreign call
            # per batch, each worker on its own arena.
            in_n = int(np.prod(g.input_shape))
            out_n = int(np.prod(smap[g.sink.name]))
            w("")
            w.open(f"void {opts.batch_ws_func_name}("
                   f"const float *NNCG_RESTRICT x, "
                   f"float *NNCG_RESTRICT out, int n, "
                   f"float *NNCG_RESTRICT workspace)")
            w("int b;")
            w(f"for (b = 0; b < n; ++b) "
              f"{opts.ws_func_name}(x + (long)b * {in_n}, "
              f"out + (long)b * {out_n}, workspace);")
            w.close()
            w("")
            w.open(f"void {opts.batch_func_name}("
                   f"const float *NNCG_RESTRICT x, "
                   f"float *NNCG_RESTRICT out, int n)")
            w(f"{opts.batch_ws_func_name}(x, out, n, {arena});")
            w.close()

        hdr = _W()
        hdr("/* Generated by NNCG-JAX (repro of Urbann et al., 2020).")
        hdr(f" * net: in {g.input_shape} -> out {smap[g.sink.name]}, "
            f"{g.param_count()} params, simd={opts.simd},")
        hdr(f" * arena {plan.total_bytes} B "
            f"(one-buffer-per-layer would be {plan.buffer_sum_bytes} B)"
            f"{f', pipeline stages={S}' if S > 1 else ''} */")
        hdr("#include <math.h>")
        if S > 1:
            hdr("#include <string.h>")  # stage pass-through memcpy
        if opts.isa is not None:
            hdr(f"#include <{opts.isa.header}>")
        hdr("#if defined(__STDC_VERSION__) && __STDC_VERSION__ >= 199901L")
        hdr("#define NNCG_RESTRICT restrict")
        hdr("#else")
        hdr("#define NNCG_RESTRICT")
        hdr("extern float expf(float);")
        hdr("#endif")
        hdr("")
        return self._finish_program(hdr, plan, "fp32")

    def _finish_program(self, hdr: _W, plan: ArenaPlan,
                        precision: str) -> Program:
        self._program = Program(
            func_name=self.opts.func_name, precision=precision,
            header=hdr.lines, decls=self.decls.lines, body=self.w.lines,
            nests=self.nests,
            buffers=[Buffer(name=iv.value, cname=_cname(iv.value),
                            offset=iv.offset, size=iv.size,
                            elem=("int" if iv.value.endswith("__acc")
                                  else self._elem),
                            start=iv.start, end=iv.end)
                     for iv in plan.intervals],
            arena_elems=plan.total_floats, elem_bytes=plan.elem_bytes)
        return self._program

    def generate(self) -> str:
        """Emit the complete C translation unit (``render`` over the
        lowered :class:`Program` — the single rendering path)."""
        return render(self.lower())


# one warning per process, shared by both legacy entry points
_LEGACY_WARNED = [False]


def _warn_legacy(fn: str) -> None:
    if not _LEGACY_WARNED[0]:
        _LEGACY_WARNED[0] = True
        import warnings
        warnings.warn(
            f"{fn}() is deprecated; use repro.core.codegen.compile() — "
            f"it returns a GeneratedSource with entry symbols, workspace "
            f"sizes and the schedule", DeprecationWarning, stacklevel=3)


def generate_c(graph: CNNGraph, opts: Optional[CodegenOptions] = None) -> str:
    """Deprecated: use :func:`repro.core.codegen.compile`.

    Kept as a byte-compatible shim: emits the pre-schedule (unfused,
    single-stage) code exactly as before."""
    _warn_legacy("generate_c")
    return CGenerator(graph, opts or CodegenOptions(),
                      schedule=make_schedule(graph, fusion=False)).generate()


# ---------------------------------------------------------------------------
# quantized code generation (int8 weights/intermediates, int32 accumulators)
# ---------------------------------------------------------------------------


class QuantCGenerator(CGenerator):
    """Int8 code generator for a calibrated
    :class:`repro.core.quantize.QuantizedGraph`.

    Same external contract as the float generator (float in, float out,
    reentrant ``_ws`` entry, static-arena wrapper, batch loop) but every
    weight is a ``static const signed char`` array, every intermediate
    tensor is an int8 code in a **byte**-planned arena (~4x smaller),
    accumulation is int32, and requantization multiplies by float32
    constants shared bit-exactly with the jax reference
    (:func:`repro.core.jax_exec.forward_quantized`).

    ``simd='sse'``/``'avx'`` vectorizes the conv/dense inner dot product
    with SSE2 integer intrinsics (sign-extend + ``_mm_madd_epi16``, 16
    taps per iteration).  Integer addition is associative, so the SIMD
    build produces *identical* results to the scalar one.  Any other
    mode emits portable scalar code — strict ANSI C89, like the float
    path (CI-enforced).
    """

    _elem = "signed char"
    _quantized = True

    def __init__(self, qgraph, opts: CodegenOptions,
                 schedule: Optional[Schedule] = None):
        super().__init__(qgraph.graph, opts, schedule)
        self.qg = qgraph

    # -- const emitters -------------------------------------------------------

    def const_i8(self, name: str, arr: np.ndarray) -> str:
        vals = ", ".join(str(int(v))
                         for v in np.asarray(arr, np.int8).ravel())
        self.decls(f"static const signed char {name}[{arr.size}] = "
                   f"{{{vals}}};")
        return name

    def const_i16(self, name: str, arr: np.ndarray) -> str:
        """Int8 weight codes pre-widened to int16 for the SSE madd
        path (values still fit int8; layout-only)."""
        vals = ", ".join(str(int(v))
                         for v in np.asarray(arr, np.int16).ravel())
        self.decls(f"static const short {name}[{arr.size}] = {{{vals}}};")
        return name

    def const_i32(self, name: str, arr: np.ndarray) -> str:
        vals = ", ".join(str(int(v))
                         for v in np.asarray(arr, np.int32).ravel())
        self.decls(f"static const int {name}[{arr.size}] = {{{vals}}};")
        return name

    # -- shared emission fragments -------------------------------------------

    _REQ_DECLS = "float t; float u; int q;"

    @property
    def _req_decls(self) -> str:
        """Requant scratch decls for a weighted layer's store block —
        fused stores additionally hold the producer's own int8 code in
        ``qf`` before feeding the consumer's epilogue."""
        return self._REQ_DECLS + (" signed char qf;"
                                  if self._epi is not None else "")

    def _q_store(self, zp_out, oidx: str, dst: str,
                 pos=None) -> None:
        """Store the requant result ``t`` as the int8 code of output
        element ``oidx``.  ``zp_out`` is an int, or a C expression
        string indexing a per-channel zero-point table.
        Unfused: the ordinary round/clamp into ``dst``.  Fused: requantize to the producer's own code first
        (``qf`` — exactly the value the unfused kernel would have
        written to memory), then run the fused consumer's arithmetic on
        it: the Add's per-edge dequant sum (bit-exact with
        :meth:`_qadd_scalar_body`), the max-pool code ternary
        (:meth:`emit_qmaxpool`), the avg-pool int32 window sum
        (:meth:`emit_qavgpool` — finalized after the producer loops), or
        the Concat per-edge requant (:meth:`emit_qconcat`)."""
        epi = self._epi
        if epi is None:
            self._round_clamp(zp_out, f"{dst}[{oidx}]")
            return
        qg, w = self.qg, self.w
        if isinstance(epi, PoolFuse):
            di = epi.dst_index(pos)
            self._round_clamp(zp_out, "qf")
            if epi.kind == "max":
                w(f"{dst}[{di}] = qf > {dst}[{di}] ? qf : {dst}[{di}];")
            else:
                w(f"{epi.acc}[{di}] += qf;")
            return
        if isinstance(epi, ConcatFuse):
            cat = epi.concat
            di = epi.dst_index(pos)
            self._round_clamp(zp_out, "qf")
            w(f"t = (float)(qf - {qg.in_qp(cat, epi.pos).zero_point}) * "
              f"{_flit(qg.rescale(cat, epi.pos))};")
            self._round_clamp(qg.out_qp(cat).zero_point, f"{dst}[{di}]")
            return
        fc, add = epi, epi.add
        self._round_clamp(zp_out, "qf")
        for i, s in enumerate(fc.srcs):
            op = "=" if i == 0 else "+="
            qp = qg.in_qp(add, i)
            sref = "qf" if i == fc.pos else f"{s}[{oidx}]"
            w(f"t {op} (float)({sref} - {qp.zero_point}) * "
              f"{_flit(qg.rescale(add, i))};")
        self._act_float(add.activation, add.alpha)
        self._round_clamp(qg.out_qp(add).zero_point, f"{dst}[{oidx}]")

    def _fused_lane_loop(self, G: int, base: str, dst: str,
                         pos=None) -> None:
        """Fused epilogue after a vector requant into ``qtmp``: for each
        of the ``G`` just-produced producer codes, run the consumer's
        scalar reference arithmetic — the Add dequant sum, the max-pool
        code ternary, the avg-pool int32 window accumulate, or the
        Concat per-edge requant — so the tiled kernels stay bit-exact
        with the unfused emission."""
        epi = self._epi
        qg, w = self.qg, self.w
        if isinstance(epi, PoolFuse):
            i, j, k = pos
            di = epi.dst_index((i, j, f"{k} + lz"))
            w.open("")
            w("int lz;")
            w.open(f"for (lz = 0; lz < {G}; ++lz)")
            if epi.kind == "max":
                w(f"{dst}[{di}] = qtmp[lz] > {dst}[{di}] ? "
                  f"qtmp[lz] : {dst}[{di}];")
            else:
                w(f"{epi.acc}[{di}] += qtmp[lz];")
            w.close()
            w.close()
            return
        if isinstance(epi, ConcatFuse):
            i, j, k = pos
            cat = epi.concat
            di = epi.dst_index((i, j, f"{k} + lz"))
            w.open("")
            w("int lz; float t; float u; int q;")
            w.open(f"for (lz = 0; lz < {G}; ++lz)")
            w(f"t = (float)(qtmp[lz] - "
              f"{qg.in_qp(cat, epi.pos).zero_point}) * "
              f"{_flit(qg.rescale(cat, epi.pos))};")
            self._round_clamp(qg.out_qp(cat).zero_point, f"{dst}[{di}]")
            w.close()
            w.close()
            return
        fc, add = epi, epi.add
        w.open("")
        w("int lz; float t; float u; int q;")
        w.open(f"for (lz = 0; lz < {G}; ++lz)")
        for i, s in enumerate(fc.srcs):
            op = "=" if i == 0 else "+="
            qp = qg.in_qp(add, i)
            sref = "qtmp[lz]" if i == fc.pos else f"{s}[{base} + lz]"
            w(f"t {op} (float)({sref} - {qp.zero_point}) * "
              f"{_flit(qg.rescale(add, i))};")
        self._act_float(add.activation, add.alpha)
        self._round_clamp(qg.out_qp(add).zero_point, f"{dst}[{base} + lz]")
        w.close()
        w.close()

    def _vec_requant_fused(self, eff: QISA, tf_init: str, mexpr: str,
                           act: Optional[str], alpha: float, zp_mid: int,
                           base: str, dst: str) -> None:
        """Wide-x86 vector form of the fused-Add epilogue: requantize
        the producer vector to its int8 codes in-register (same
        trunc+fixup floor, with an explicit min/max standing in for the
        pack instruction's saturation), then run the Add's per-edge
        dequant sum, activation and output requant on the whole group.
        Bit-exact with :meth:`_fused_lane_loop` — mul and add stay
        separate intrinsics, so no contraction can change a rounding —
        which remains the fallback for the 128-bit variants (the SSE2
        tier has no ``_mm_min_epi32``) and NEON."""
        fc = self._fuse
        qg, w, add = self.qg, self.w, fc.add
        w.open("")
        w(f"__m256 tf = {tf_init};")
        w(f"tf = _mm256_mul_ps(tf, {mexpr});")
        if act == "relu":
            w("tf = _mm256_max_ps(tf, _mm256_setzero_ps());")
        elif act == "leaky_relu":
            w(f"tf = _mm256_max_ps(tf, _mm256_mul_ps(tf, "
              f"_mm256_set1_ps({_flit(alpha)})));")
        w("__m256 uf = _mm256_add_ps(tf, _mm256_set1_ps(0.5f));")
        w("__m256i qi = _mm256_cvttps_epi32(uf);")
        w("qi = _mm256_add_epi32(qi, _mm256_castps_si256("
          "_mm256_cmp_ps(_mm256_cvtepi32_ps(qi), uf, _CMP_GT_OQ)));")
        w(f"qi = _mm256_add_epi32(qi, _mm256_set1_epi32({zp_mid}));")
        w("qi = _mm256_min_epi32(_mm256_max_epi32(qi, "
          "_mm256_set1_epi32(-128)), _mm256_set1_epi32(127));")
        for i, s in enumerate(fc.srcs):
            qp = qg.in_qp(add, i)
            if i == fc.pos:
                vi = f"_mm256_sub_epi32(qi, _mm256_set1_epi32({qp.zero_point}))"
            else:
                w(f"__m256i v{i} = _mm256_cvtepi8_epi32(_mm_loadl_epi64("
                  f"(const __m128i *)({s} + {base})));")
                vi = (f"_mm256_sub_epi32(v{i}, "
                      f"_mm256_set1_epi32({qp.zero_point}))")
            term = (f"_mm256_mul_ps(_mm256_cvtepi32_ps({vi}), "
                    f"_mm256_set1_ps({_flit(qg.rescale(add, i))}))")
            w(f"tf = {term};" if i == 0
              else f"tf = _mm256_add_ps(tf, {term});")
        if add.activation == "relu":
            w("tf = _mm256_max_ps(tf, _mm256_setzero_ps());")
        elif add.activation == "leaky_relu":
            w(f"tf = _mm256_max_ps(tf, _mm256_mul_ps(tf, "
              f"_mm256_set1_ps({_flit(add.alpha)})));")
        w("uf = _mm256_add_ps(tf, _mm256_set1_ps(0.5f));")
        w("qi = _mm256_cvttps_epi32(uf);")
        w("qi = _mm256_add_epi32(qi, _mm256_castps_si256("
          "_mm256_cmp_ps(_mm256_cvtepi32_ps(qi), uf, _CMP_GT_OQ)));")
        w(f"qi = _mm256_add_epi32(qi, "
          f"_mm256_set1_epi32({qg.out_qp(add).zero_point}));")
        w.open("")
        w("__m128i pk = _mm_packs_epi32(_mm256_castsi256_si128(qi), "
          "_mm256_extracti128_si256(qi, 1));")
        w("pk = _mm_packs_epi16(pk, pk);")
        w(f"_mm_storel_epi64((__m128i *)({dst} + {base}), pk);")
        w.close()
        w.close()

    def _round_clamp(self, zp_out, dst_expr: str) -> None:
        """``t`` (float, s_out units) -> int8 code at ``dst_expr``;
        round half up (``floor(t + 0.5)``), add the zero point
        (an int, or a per-channel table index expression), saturate.  The floor is truncate-then-fixup — exact for every
        in-range value and, unlike ``floorf``, never a libm call on
        pre-SSE4.1 targets (it was the requant hot spot).  Requires
        ``float t; float u; int q;`` declared in the enclosing block."""
        w = self.w
        w("u = t + 0.5f;")
        w("q = (int)u;")                      # trunc toward zero
        w(f"q = (q - ((float)q > u)) + {zp_out};")  # fix-up -> floor
        w(f"{dst_expr} = (signed char)"
          f"(q < -128 ? -128 : (q > 127 ? 127 : q));")

    def _act_float(self, act: Optional[str], alpha: float) -> None:
        if act in ("relu", "leaky_relu"):
            self.w(f"t = {self.act_scalar('t', act, alpha)};")

    def emit_padded_copy_i8(self, src: str, in_shape, pads, buf: str,
                            fill: str) -> Tuple[str, Tuple[int, int, int]]:
        """Int8 padded copy — byte-identical emission to the float
        version (element type comes from the arena declaration);
        ``fill`` is the input zero-point code for conv/avg sums
        (cancelled by the folded bias correction) or -128 for max
        pooling."""
        return self.emit_padded_copy(src, in_shape, pads, buf, fill)

    def _madd16(self, x_expr: str, w_expr: str) -> None:
        """One SSE2 iteration: 16 int8 taps x 16 int8 weights summed
        into ``vacc`` (4 x int32) — sign-extend via unpack+srai, then
        ``_mm_madd_epi16``.  Emits the body of a block (decls first)."""
        w = self.w
        w(f"__m128i xv = _mm_loadu_si128((const __m128i *)({x_expr}));")
        w(f"__m128i wv = _mm_loadu_si128((const __m128i *)({w_expr}));")
        w("__m128i xlo = _mm_srai_epi16(_mm_unpacklo_epi8(xv, xv), 8);")
        w("__m128i xhi = _mm_srai_epi16(_mm_unpackhi_epi8(xv, xv), 8);")
        w("__m128i wlo = _mm_srai_epi16(_mm_unpacklo_epi8(wv, wv), 8);")
        w("__m128i whi = _mm_srai_epi16(_mm_unpackhi_epi8(wv, wv), 8);")
        w("vacc = _mm_add_epi32(vacc, _mm_madd_epi16(xlo, wlo));")
        w("vacc = _mm_add_epi32(vacc, _mm_madd_epi16(xhi, whi));")

    def _dot_inner(self, src: str, wname: str, row: int, use_sse: bool,
                   x_base: str, w_base: str) -> None:
        """``acc += sum_z src[x_base+z] * w[w_base+z]`` over a
        contiguous run of ``row`` taps (one window row, all channels).
        SSE2 path: 16 int8 taps/iteration via sign-extend + madd; the
        remainder and the scalar mode share the same exact int32 sum."""
        w = self.w
        w.open("")
        w(f"const signed char *xr = {src} + {x_base};")
        w(f"const signed char *wr = {wname} + {w_base};")
        if use_sse:
            w.open("")
            w("int z;")
            w.open(f"for (z = 0; z + 16 <= {row}; z += 16)")
            self._madd16("xr + z", "wr + z")
            w.close()
            w(f"for (; z < {row}; ++z) acc += xr[z] * wr[z];")
            w.close()
        else:
            w(_cfor("z", row, "acc += xr[z] * wr[z];"))
        w.close()

    def _hsum_sse(self) -> None:
        w = self.w
        w("vacc = _mm_add_epi32(vacc, _mm_srli_si128(vacc, 8));")
        w("vacc = _mm_add_epi32(vacc, _mm_srli_si128(vacc, 4));")
        w("acc += _mm_cvtsi128_si32(vacc);")

    # -- tiled dot-product kernels --------------------------------------------

    @property
    def qisa(self) -> Optional[QISA]:
        return QISAS.get(self.opts.simd)

    @property
    def _x86(self) -> bool:
        q = self.qisa
        return q is not None and q.arch == "x86"

    def _layer_qisa(self, wt: np.ndarray, co: int, kh: int,
                    row: int) -> Optional[QISA]:
        """The kernel variant actually emitted for one weighted layer:
        the session's variant when the layer tiles (>= one full channel
        group), with the per-layer ``avx_ubs`` -> ``avx`` demotion when
        the weights cannot prove ``vpmaddubsw`` saturation-free."""
        q = self.qisa
        if q is None or co < q.group:
            return None
        if q.name == "avx_ubs" and not maddubsw_safe(wt, co, kh, row):
            return QISAS["avx"]
        return q

    def _vec_requant(self, eff: QISA, tf_init: str, mexpr: Optional[str],
                     act: Optional[str], alpha: float, is_sink: bool,
                     zp: int, dstp: str,
                     zp_vec: Optional[str] = None) -> None:
        """The fused requant epilogue on one ``group``-wide vector:
        float rescale, activation, round-half-up (trunc+fixup floor, the
        scalar emitter's exact sequence), zero point, saturating int8
        pack, one store — no scalar round trip.  ``tf_init`` yields the
        pre-scale float vector (an int32 accumulator convert, or a raw
        float load for input quantization); ``mexpr`` the multiplier
        vector (``None`` to skip); ``dstp`` the destination pointer
        (float for the sink, int8 codes otherwise).  ``zp_vec`` (a
        pointer expression into a per-channel zero-point table)
        replaces the scalar ``zp`` broadcast for per-channel requant."""
        w = self.w
        if eff.arch == "arm":
            w.open("")
            w(f"float32x4_t tf = {tf_init};")
            if mexpr is not None:
                w(f"tf = vmulq_f32(tf, {mexpr});")
            if act == "relu":
                w("tf = vmaxq_f32(tf, vdupq_n_f32(0.0f));")
            elif act == "leaky_relu":
                w(f"tf = vmaxq_f32(tf, vmulq_f32(tf, "
                  f"vdupq_n_f32({_flit(alpha)})));")
            if is_sink:
                w(f"vst1q_f32({dstp}, tf);")
                w.close()
                return
            # vrndm (floor) then truncating convert == the scalar
            # trunc+fixup floor for every non-saturating value
            w("float32x4_t uf = vaddq_f32(tf, vdupq_n_f32(0.5f));")
            w("int32x4_t qi = vcvtq_s32_f32(vrndmq_f32(uf));")
            if zp_vec is not None:
                w(f"qi = vaddq_s32(qi, vld1q_s32({zp_vec}));")
            else:
                w(f"qi = vaddq_s32(qi, vdupq_n_s32({zp}));")
            w.open("")
            w("int16x4_t q16 = vqmovn_s32(qi);")
            w("int8x8_t q8 = vqmovn_s16(vcombine_s16(q16, q16));")
            w("int s4 = vget_lane_s32(vreinterpret_s32_s8(q8), 0);")
            w(f"memcpy({dstp}, &s4, 4);")
            w.close()
            w.close()
            return
        pfx = "_mm256" if eff.wide else "_mm"
        rf = "__m256" if eff.wide else "__m128"
        w.open("")
        w(f"{rf} tf = {tf_init};")
        if mexpr is not None:
            w(f"tf = {pfx}_mul_ps(tf, {mexpr});")
        if act == "relu":
            w(f"tf = {pfx}_max_ps(tf, {pfx}_setzero_ps());")
        elif act == "leaky_relu":
            w(f"tf = {pfx}_max_ps(tf, {pfx}_mul_ps(tf, "
              f"{pfx}_set1_ps({_flit(alpha)})));")
        if is_sink:
            w(f"{pfx}_storeu_ps({dstp}, tf);")
            w.close()
            return
        w(f"{rf} uf = {pfx}_add_ps(tf, {pfx}_set1_ps(0.5f));")
        w(f"{rf}i qi = {pfx}_cvttps_epi32(uf);")
        if eff.wide:
            w("qi = _mm256_add_epi32(qi, _mm256_castps_si256("
              "_mm256_cmp_ps(_mm256_cvtepi32_ps(qi), uf, _CMP_GT_OQ)));")
        else:
            w("qi = _mm_add_epi32(qi, _mm_castps_si128("
              "_mm_cmpgt_ps(_mm_cvtepi32_ps(qi), uf)));")
        if zp_vec is not None:
            ri = "__m256i" if eff.wide else "__m128i"
            ld = "_mm256_loadu_si256" if eff.wide else "_mm_loadu_si128"
            w(f"qi = {pfx}_add_epi32(qi, {ld}((const {ri} *)"
              f"({zp_vec})));")
        else:
            w(f"qi = {pfx}_add_epi32(qi, {pfx}_set1_epi32({zp}));")
        w.open("")
        if eff.wide:
            w("__m128i pk = _mm_packs_epi32(_mm256_castsi256_si128(qi), "
              "_mm256_extracti128_si256(qi, 1));")
            w("pk = _mm_packs_epi16(pk, pk);")
            w(f"_mm_storel_epi64((__m128i *)({dstp}), pk);")
        else:
            w("__m128i pk = _mm_packs_epi16(_mm_packs_epi32(qi, qi), qi);")
            w("int s4 = _mm_cvtsi128_si32(pk);")
            w(f"memcpy({dstp}, &s4, 4);")
        w.close()
        w.close()

    def _tiled_x_block(self, eff: QISA, n: int, t0: int, real: int) -> None:
        """Broadcast ``lane_taps`` consecutive input codes from window
        row ``n`` into ``vx`` (declared here).  Full blocks are one
        4-byte load (or a 2-tap sign-extended pair); the statically-last
        partial block of each row builds the word from single bytes so
        the zero-padded weight lanes never read past the row."""
        w = self.w
        L = eff.lane_taps
        if eff.arch == "arm" and L == 1:
            w(f"const int16x4_t vx = vdup_n_s16((short)xr{n}[{t0}]);")
            return
        w("int xp;")
        if L == 2:
            if real == 2:
                w(f"xp = (int)(((unsigned)xr{n}[{t0 + 1}] << 16) | "
                  f"((unsigned)xr{n}[{t0}] & 0xffffu));")
            else:
                w(f"xp = (int)((unsigned)xr{n}[{t0}] & 0xffffu);")
            w(f"{'__m256i' if eff.wide else '__m128i'} vx = "
              f"{'_mm256' if eff.wide else '_mm'}_set1_epi32(xp);")
            return
        # L == 4 (vnni / maddubsw / neon_dot)
        if real == 4:
            w(f"memcpy(&xp, xr{n} + {t0}, 4);")
        else:
            parts = [f"((unsigned)(xr{n}[{t0 + i}] & 255) << {8 * i})"
                     for i in range(real)]
            w(f"xp = (int)({' | '.join(parts)});")
        if eff.arch == "arm":
            w("int8x16_t vx = vreinterpretq_s8_s32(vdupq_n_s32(xp));")
        elif eff.unsigned_x:
            w("__m256i vx = _mm256_xor_si256(_mm256_set1_epi32(xp), "
              "vflip);")
        else:
            w("__m256i vx = _mm256_set1_epi32(xp);")

    def _tiled_acc_line(self, eff: QISA, g: int, wname: str,
                        off: int) -> str:
        if eff.name == "avx_vnni":
            return (f"acc{g} = _mm256_dpbusd_epi32(acc{g}, vx, "
                    f"_mm256_loadu_si256((const __m256i *)"
                    f"({wname} + {off})));")
        if eff.name == "avx_ubs":
            return (f"acc{g} = _mm256_add_epi32(acc{g}, _mm256_madd_epi16("
                    f"_mm256_maddubs_epi16(vx, _mm256_loadu_si256("
                    f"(const __m256i *)({wname} + {off}))), vone16));")
        if eff.name == "avx":
            return (f"acc{g} = _mm256_add_epi32(acc{g}, _mm256_madd_epi16("
                    f"vx, _mm256_loadu_si256((const __m256i *)"
                    f"({wname} + {off}))));")
        if eff.name == "sse":
            return (f"acc{g} = _mm_add_epi32(acc{g}, _mm_madd_epi16(vx, "
                    f"_mm_loadu_si128((const __m128i *)({wname} + {off}))));")
        if eff.name == "neon_dot":
            return (f"acc{g} = vdotq_s32(acc{g}, vx, "
                    f"vld1q_s8({wname} + {off}));")
        return (f"acc{g} = vmlal_s16(acc{g}, vx, "
                f"vld1_s16({wname} + {off}));")  # neon vmlal_s16

    def _emit_tiled_layer(self, eff: QISA, *, src: str, dst: str, co: int,
                          kh: int, row: int, wt: np.ndarray,
                          bias_main: np.ndarray, bias_plain: np.ndarray,
                          scales: np.ndarray, act: Optional[str],
                          alpha: float, is_sink: bool, zp_out: int,
                          xbase, xbase_var: str, oidx: str,
                          opos=(0, 0),
                          zp_tab: Optional[str] = None) -> None:
        """The register-tiled channel-group kernel for one conv/dense
        layer (caller has opened the output-position loops and resolved
        padding).  Weight tiles are packed so each dot instruction feeds
        ``group`` output-channel accumulators from one broadcast of the
        input taps; accumulators stay in registers from the int32 bias
        load to the fused requant store.  Channels past the last full
        group run the per-channel rolled fallback (bit-identical: int32
        sums are exact in any order)."""
        w = self.w
        G, L = eff.group, eff.lane_taps
        ng = co // G
        taps = kh * row
        packed, P = _pack_qweights(wt, co, kh, row, G, L)
        if L == 4:
            wname = self.const_i8(f"w{self.uid()}", packed)
        else:
            wname = self.const_i16(f"w{self.uid()}", packed)
        bname = self.const_i32(f"b{self.uid()}", bias_main[:ng * G])
        mname = self.const_array(f"m{self.uid()}", scales)
        k0 = ng * G
        if k0 < co:
            wtail = self.const_i8(f"wt{self.uid()}", wt[k0:])
            btail = self.const_i32(f"bt{self.uid()}", bias_plain[k0:])
        x86 = eff.arch == "x86"
        for c0 in range(0, ng, _QTILE_MAX_GROUPS):
            gs = list(range(c0, min(c0 + _QTILE_MAX_GROUPS, ng)))
            w.open("")
            for n in range(kh):
                w(f"const signed char *xr{n} = {src} + {xbase(n)};")
            if eff.unsigned_x:
                w("const __m256i vflip = _mm256_set1_epi8(-128);")
            if eff.name == "avx_ubs":
                w("const __m256i vone16 = _mm256_set1_epi16(1);")
            for g in gs:
                if eff.name == "sse":
                    w(f"__m128i acc{g} = _mm_loadu_si128((const __m128i *)"
                      f"({bname} + {g * G}));")
                elif x86:
                    w(f"__m256i acc{g} = _mm256_loadu_si256("
                      f"(const __m256i *)({bname} + {g * G}));")
                else:
                    w(f"int32x4_t acc{g} = vld1q_s32({bname} + {g * G});")
            if self._epi is not None and not (
                    isinstance(self._epi, AddFuse) and x86 and eff.wide):
                # fused epilogue, lane-loop fallback: the vector requant
                # packs the producer's codes here, the scalar lane loop
                # then runs the consumer arithmetic (bit-exact with the
                # unfused path)
                w(f"signed char qtmp[{G}];")
            for n in range(kh):
                for p in range(P):
                    t0 = p * L
                    real = min(L, row - t0)
                    w.open("")
                    self._tiled_x_block(eff, n, t0, real)
                    for g in gs:
                        off = ((n * P + p) * ng + g) * (G * L)
                        w(self._tiled_acc_line(eff, g, wname, off))
                    w.close()
            for g in gs:
                if x86:
                    pfx = "_mm256" if eff.wide else "_mm"
                    tf_init = f"{pfx}_cvtepi32_ps(acc{g})"
                    mexpr = f"{pfx}_loadu_ps({mname} + {g * G})"
                else:
                    tf_init = f"vcvtq_f32_s32(acc{g})"
                    mexpr = f"vld1q_f32({mname} + {g * G})"
                if self._fuse is not None and x86 and eff.wide:
                    self._vec_requant_fused(eff, tf_init, mexpr, act,
                                            alpha, zp_out,
                                            f"{oidx} + {g * G}", dst)
                elif self._epi is not None:
                    self._vec_requant(eff, tf_init, mexpr, act, alpha,
                                      False, zp_out, "qtmp")
                    self._fused_lane_loop(G, f"{oidx} + {g * G}", dst,
                                          pos=(opos[0], opos[1], g * G))
                else:
                    dstp = (f"out + {oidx} + {g * G}" if is_sink
                            else f"{dst} + {oidx} + {g * G}")
                    self._vec_requant(
                        eff, tf_init, mexpr, act, alpha, is_sink,
                        zp_out, dstp,
                        zp_vec=(f"{zp_tab} + {g * G}"
                                if zp_tab is not None else None))
            w.close()
        if k0 < co:
            use_sse = x86 and row >= 16
            w.open("")
            w("int kk;")
            w.open(f"for (kk = 0; kk < {co - k0}; ++kk)")
            w.open("")
            w(f"int acc = {btail}[kk];")
            w("float t;" if is_sink else self._req_decls)
            if use_sse:
                w("__m128i vacc = _mm_setzero_si128();")
            self.floop("n", kh)
            self._dot_inner(src, wtail, row, use_sse, xbase_var,
                            f"kk * {taps} + n * {row}")
            self.fclose()
            if use_sse:
                self._hsum_sse()
            w(f"t = (float)acc * {mname}[{k0} + kk];")
            self._act_float(act, alpha)
            if is_sink:
                w(f"out[{oidx} + {k0} + kk] = t;")
            else:
                self._q_store(
                    f"{zp_tab}[{k0} + kk]" if zp_tab is not None
                    else zp_out,
                    f"{oidx} + {k0} + kk", dst,
                    pos=(opos[0], opos[1], f"{k0} + kk"))
            w.close()
            w.close()
            w.close()

    # -- weighted layers ------------------------------------------------------

    def emit_qconv(self, layer: Conv2D, in_shape, src: str, dst: str,
                   pad_buf: Optional[str], is_sink: bool) -> None:
        qg, w = self.qg, self.w
        oh, ow, co = layer.out_shape(in_shape)
        sh, sw = layer.strides
        kh, kw_, ci = layer.kh, layer.kw, layer.c_in
        pads = layer.pad_amounts(in_shape)
        zp_in = qg.in_qp(layer).zero_point
        act = layer.activation
        w(f"/* QConv2D {layer.name}: {in_shape}->{(oh, ow, co)} "
          f"k={kh}x{kw_} s={sh}x{sw} pad={layer.padding} act={act} "
          f"int8/int32 */")
        if any(pads):
            assert pad_buf is not None, f"{layer.name}: unplanned pad scratch"
            src, in_shape = self.emit_padded_copy_i8(
                src, in_shape, pads, pad_buf, str(zp_in))
        h, wdt, _ = in_shape
        row = kw_ * ci
        taps = kh * row
        # taps of one output channel contiguous: (co, kh, kw, ci)
        wt = np.transpose(qg.weights[layer.name].w_q,
                          (3, 0, 1, 2)).reshape(co, taps)
        scales = (qg.dequant_scales(layer) if is_sink
                  else qg.requant_scales(layer))
        eff = self._layer_qisa(wt, co, kh, row)
        use_sse = self._x86 and row >= 16
        zp_out = 0 if is_sink else qg.out_qp(layer).zero_point
        cq = None if is_sink else qg.channel_qp(layer.name)
        if eff is not None:
            if eff.name != self.opts.simd:
                w(f"/* {layer.name}: maddubsw saturation unprovable, "
                  f"pair-madd variant */")
            zname = (self.const_i32(f"z{self.uid()}", cq.zero_point)
                     if cq is not None else None)
            self.floop("i", oh)
            self.floop("j", ow)
            self._emit_tiled_layer(
                eff, src=src, dst=dst, co=co, kh=kh, row=row, wt=wt,
                bias_main=qg.effective_bias(
                    layer, 128 if eff.unsigned_x else 0),
                bias_plain=qg.effective_bias(layer), scales=scales,
                act=act, alpha=layer.alpha, is_sink=is_sink,
                zp_out=zp_out,
                xbase=lambda n: (f"((i * {sh} + {n}) * {wdt} + "
                                 f"j * {sw}) * {ci}"),
                xbase_var=f"((i * {sh} + n) * {wdt} + j * {sw}) * {ci}",
                oidx=f"(i * {ow} + j) * {co}", opos=("i", "j"),
                zp_tab=zname)
            self.fclose(2)
            if is_sink and act == "softmax":
                self.emit_softmax((oh, ow, co), "out")
            return
        if taps >= 16:  # tiny-window branch uses literals
            bname = self.const_i32(f"b{self.uid()}",
                                   qg.effective_bias(layer))
            mname = self.const_array(f"m{self.uid()}", scales)
            zname = (self.const_i32(f"z{self.uid()}", cq.zero_point)
                     if cq is not None else None)

        def requant_one(oidx: str, pos) -> None:
            w(f"t = (float)acc * {mname}[k];")
            self._act_float(act, layer.alpha)
            if is_sink:
                w(f"out[{oidx}] = t;")
            else:
                self._q_store(
                    f"{zname}[k]" if cq is not None
                    else qg.out_qp(layer).zero_point,
                    oidx, dst, pos=pos)

        if taps < 16:
            # tiny window (e.g. first conv on a 1-channel image):
            # straight-line taps with the int8 weight codes as literals
            # (P3) — no const arrays, no inner loop overhead
            bias_eff = qg.effective_bias(layer)
            self.floop("i", oh)
            self.floop("j", ow)
            for k in range(co):
                w.open("")
                w(f"int acc = {int(bias_eff[k])};")
                w("float t;" if is_sink else self._req_decls)
                for n in range(kh):
                    for m in range(kw_):
                        for o in range(ci):
                            c_w = int(wt[k, (n * kw_ + m) * ci + o])
                            if c_w == 0:
                                continue
                            w(f"acc += {c_w} * {src}[((i * {sh} + {n}) * "
                              f"{wdt} + (j * {sw} + {m})) * {ci} + {o}];")
                w(f"t = (float)acc * {_flit(scales[k])};")
                self._act_float(act, layer.alpha)
                if is_sink:
                    w(f"out[(i * {ow} + j) * {co} + {k}] = t;")
                else:
                    self._q_store(int(cq.zero_point[k]) if cq is not None
                                  else qg.out_qp(layer).zero_point,
                                  f"(i * {ow} + j) * {co} + {k}", dst,
                                  pos=("i", "j", k))
                w.close()
            self.fclose(2)
        else:
            wname = self.const_i8(f"w{self.uid()}", wt)
            self.floop("i", oh)
            self.floop("j", ow)
            self.floop("k", co)
            w.open("")
            w(f"int acc = {bname}[k];")
            w("float t;" if is_sink else self._req_decls)
            if use_sse:
                w("__m128i vacc = _mm_setzero_si128();")
            self.floop("n", kh)
            self._dot_inner(src, wname, row, use_sse,
                            f"((i * {sh} + n) * {wdt} + j * {sw}) * {ci}",
                            f"k * {taps} + n * {row}")
            self.fclose()
            if use_sse:
                self._hsum_sse()
            requant_one(f"(i * {ow} + j) * {co} + k", ("i", "j", "k"))
            w.close()
            self.fclose(3)
        if is_sink and act == "softmax":
            self.emit_softmax((oh, ow, co), "out")

    def emit_qdepthwise(self, layer: DepthwiseConv2D, in_shape, src: str,
                        dst: str, pad_buf: Optional[str],
                        is_sink: bool) -> None:
        qg, w = self.qg, self.w
        oh, ow, co = layer.out_shape(in_shape)
        sh, sw = layer.strides
        kh, kw_, ci, mult = layer.kh, layer.kw, layer.c_in, layer.multiplier
        pads = layer.pad_amounts(in_shape)
        zp_in = qg.in_qp(layer).zero_point
        act = layer.activation
        w(f"/* QDepthwiseConv2D {layer.name}: {in_shape}->{(oh, ow, co)} "
          f"k={kh}x{kw_} s={sh}x{sw} mult={mult} pad={layer.padding} "
          f"act={act} int8/int32 */")
        if any(pads):
            assert pad_buf is not None, f"{layer.name}: unplanned pad scratch"
            src, in_shape = self.emit_padded_copy_i8(
                src, in_shape, pads, pad_buf, str(zp_in))
        h, wdt, _ = in_shape
        wname = self.const_i8(f"w{self.uid()}",
                              qg.weights[layer.name].w_q)  # HWCM layout
        bname = self.const_i32(f"b{self.uid()}", qg.effective_bias(layer))
        scales = (qg.dequant_scales(layer) if is_sink
                  else qg.requant_scales(layer))
        mname = self.const_array(f"m{self.uid()}", scales)
        cq = None if is_sink else qg.channel_qp(layer.name)
        zname = (self.const_i32(f"z{self.uid()}", cq.zero_point)
                 if cq is not None else None)
        self.floop("i", oh)
        self.floop("j", ow)
        self.floop("c", ci)
        for m_ in range(mult):
            w.open("")
            w(f"int acc = {bname}[c * {mult} + {m_}];")
            w("float t;" if is_sink else self._req_decls)
            w(_cfor("n", kh, _cfor(
                "m", kw_,
                f"acc += {src}[((i * {sh} + n) * {wdt} + "
                f"(j * {sw} + m)) * {ci} + c] * "
                f"{wname}[((n * {kw_} + m) * {ci} + c) * {mult} + {m_}];")))
            oidx = f"(i * {ow} + j) * {co} + c * {mult} + {m_}"
            w(f"t = (float)acc * {mname}[c * {mult} + {m_}];")
            self._act_float(act, layer.alpha)
            if is_sink:
                w(f"out[{oidx}] = t;")
            else:
                self._q_store(
                    f"{zname}[c * {mult} + {m_}]" if cq is not None
                    else qg.out_qp(layer).zero_point,
                    oidx, dst, pos=("i", "j", f"c * {mult} + {m_}"))
            w.close()
        self.fclose(3)
        if is_sink and act == "softmax":
            self.emit_softmax((oh, ow, co), "out")

    def emit_qdense(self, layer: Dense, in_shape, src: str, dst: str,
                    is_sink: bool) -> None:
        qg, w = self.qg, self.w
        d_in, d_out = layer.weights.shape
        act = layer.activation
        w(f"/* QDense {layer.name}: {d_in}->{d_out} int8/int32 */")
        wt = qg.weights[layer.name].w_q.T  # (d_out, d_in)
        scales = (qg.dequant_scales(layer) if is_sink
                  else qg.requant_scales(layer))
        cq = None if is_sink else qg.channel_qp(layer.name)
        eff = self._layer_qisa(wt, d_out, 1, d_in)
        if eff is not None:
            if eff.name != self.opts.simd:
                w(f"/* {layer.name}: maddubsw saturation unprovable, "
                  f"pair-madd variant */")
            zname = (self.const_i32(f"z{self.uid()}", cq.zero_point)
                     if cq is not None else None)
            self._emit_tiled_layer(
                eff, src=src, dst=dst, co=d_out, kh=1, row=d_in, wt=wt,
                bias_main=qg.effective_bias(
                    layer, 128 if eff.unsigned_x else 0),
                bias_plain=qg.effective_bias(layer), scales=scales,
                act=act, alpha=layer.alpha, is_sink=is_sink,
                zp_out=0 if is_sink else qg.out_qp(layer).zero_point,
                xbase=lambda n: "0", xbase_var="0", oidx="0",
                zp_tab=zname)
            if is_sink and act == "softmax":
                self.emit_softmax((1, 1, d_out), "out")
            return
        wname = self.const_i8(f"w{self.uid()}", wt)
        bname = self.const_i32(f"b{self.uid()}", qg.effective_bias(layer))
        mname = self.const_array(f"m{self.uid()}", scales)
        zname = (self.const_i32(f"z{self.uid()}", cq.zero_point)
                 if cq is not None else None)
        use_sse = self._x86 and d_in >= 16
        self.floop("k", d_out)
        w.open("")
        w(f"int acc = {bname}[k];")
        w("float t;" if is_sink else self._req_decls)
        if use_sse:
            w("__m128i vacc = _mm_setzero_si128();")
        self._dot_inner(src, wname, d_in, use_sse, "0", f"k * {d_in}")
        if use_sse:
            self._hsum_sse()
        w(f"t = (float)acc * {mname}[k];")
        self._act_float(act, layer.alpha)
        if is_sink:
            w("out[k] = t;")
        else:
            self._q_store(f"{zname}[k]" if cq is not None
                          else qg.out_qp(layer).zero_point,
                          "k", dst, pos=(0, 0, "k"))
        w.close()
        self.fclose()
        if is_sink and act == "softmax":
            self.emit_softmax((1, 1, d_out), "out")

    # -- pooling / merge / elementwise ---------------------------------------

    def emit_qmaxpool(self, layer: MaxPool, in_shape, src: str, dst: str,
                      pad_buf: Optional[str]) -> None:
        w = self.w
        oh, ow, co = layer.out_shape(in_shape)
        kh, kw_ = layer.size
        sh, sw = layer.strides
        pads = layer.pad_amounts(in_shape)
        w(f"/* QMaxPool {layer.name}: {in_shape}->{(oh, ow, co)} "
          f"k={kh}x{kw_} s={sh}x{sw} pad={layer.padding} (pure int8, "
          f"shared qparams) */")
        if any(pads):
            assert pad_buf is not None, f"{layer.name}: unplanned pad scratch"
            src, in_shape = self.emit_padded_copy_i8(
                src, in_shape, pads, pad_buf, "-128")
        h, wdt, c = in_shape

        def idx(n, m):
            return (f"((i * {sh} + {n}) * {wdt} + (j * {sw} + {m})) "
                    f"* {c} + k")

        def scalar_max(qv: str) -> None:
            w(f"signed char {qv} = {src}[{idx(0, 0)}];")
            for n in range(kh):
                for m in range(kw_):
                    if n == 0 and m == 0:
                        continue
                    w(f"{qv} = {src}[{idx(n, m)}] > {qv} ? "
                      f"{src}[{idx(n, m)}] : {qv};")
            w(f"{dst}[(i * {ow} + j) * {co} + k] = {qv};")

        q = self.qisa
        if q is not None and c >= 16:
            self.floop("i", oh)
            self.floop("j", ow)
            w.open("")
            w("int k;")
            w.open(f"for (k = 0; k + 16 <= {c}; k += 16)")
            w.open("")
            if q.arch == "x86":
                # pmaxsb needs SSE4.1 — xor 0x80 / max_epu8 / xor is
                # the SSE2-safe signed byte max
                w("const __m128i vf = _mm_set1_epi8(-128);")
                w(f"__m128i mx = _mm_xor_si128(_mm_loadu_si128("
                  f"(const __m128i *)({src} + {idx(0, 0)})), vf);")
                for n in range(kh):
                    for m in range(kw_):
                        if n == 0 and m == 0:
                            continue
                        w(f"mx = _mm_max_epu8(mx, _mm_xor_si128("
                          f"_mm_loadu_si128((const __m128i *)"
                          f"({src} + {idx(n, m)})), vf));")
                w(f"_mm_storeu_si128((__m128i *)({dst} + "
                  f"(i * {ow} + j) * {co} + k), _mm_xor_si128(mx, vf));")
            else:
                w(f"int8x16_t mx = vld1q_s8({src} + {idx(0, 0)});")
                for n in range(kh):
                    for m in range(kw_):
                        if n == 0 and m == 0:
                            continue
                        w(f"mx = vmaxq_s8(mx, "
                          f"vld1q_s8({src} + {idx(n, m)}));")
                w(f"vst1q_s8({dst} + (i * {ow} + j) * {co} + k, mx);")
            w.close()
            w.close()
            if c % 16:
                w.open(f"for (; k < {c}; ++k)")
                w.open("")
                scalar_max("qv")
                w.close()
                w.close()
            w.close()
            self.fclose(2)
            return
        self.floop("i", oh)
        self.floop("j", ow)
        self.floop("k", c)
        w.open("")
        scalar_max("q")
        w.close()
        self.fclose(3)

    def emit_qavgpool(self, layer: AvgPool, in_shape, src: str, dst: str,
                      pad_buf: Optional[str]) -> None:
        qg, w = self.qg, self.w
        oh, ow, co = layer.out_shape(in_shape)
        kh, kw_ = layer.size
        sh, sw = layer.strides
        pads = layer.pad_amounts(in_shape)
        zp_in = qg.in_qp(layer).zero_point
        minv = qg.pool_scales(layer, in_shape)  # (oh, ow) float32
        w(f"/* QAvgPool {layer.name}: {in_shape}->{(oh, ow, co)} "
          f"k={kh}x{kw_} s={sh}x{sw} pad={layer.padding} int8/int32 */")
        if any(pads):
            # zp fill: padded taps sum as zp and the fixed kh*kw*zp
            # correction below cancels them exactly
            assert pad_buf is not None, f"{layer.name}: unplanned pad scratch"
            src, in_shape = self.emit_padded_copy_i8(
                src, in_shape, pads, pad_buf, str(zp_in))
        h, wdt, c = in_shape
        if np.unique(minv).size == 1:
            mexpr = _flit(minv.ravel()[0])
        else:
            mname = self.const_array(f"pinv{self.uid()}", minv)
            mexpr = f"{mname}[i * {ow} + j]"
        self.floop("i", oh)
        self.floop("j", ow)
        self.floop("k", c)
        w.open("")
        w("int acc = 0;")
        w(self._REQ_DECLS)
        w(_cfor("n", kh, _cfor(
            "m", kw_,
            f"acc += {src}[((i * {sh} + n) * {wdt} + "
            f"(j * {sw} + m)) * {c} + k];")))
        w(f"t = (float)(acc - {kh * kw_ * zp_in}) * {mexpr};")
        self._round_clamp(qg.out_qp(layer).zero_point,
                          f"{dst}[(i * {ow} + j) * {co} + k]")
        w.close()
        self.fclose(3)

    def emit_qglobal_avgpool(self, layer: GlobalAvgPool, in_shape,
                             src: str, dst: str) -> None:
        qg, w = self.qg, self.w
        h, wdt, c = in_shape
        zp_in = qg.in_qp(layer).zero_point
        minv = qg.pool_scales(layer, in_shape)  # scalar float32
        w(f"/* QGlobalAvgPool {layer.name}: {in_shape}->(1, 1, {c}) */")
        self.floop("k", c)
        w.open("")
        w("int acc = 0;")
        w(self._REQ_DECLS)
        w(_cfor("p", h * wdt, f"acc += {src}[p * {c} + k];"))
        w(f"t = (float)(acc - {h * wdt * zp_in}) * {_flit(minv)};")
        self._round_clamp(qg.out_qp(layer).zero_point, f"{dst}[k]")
        w.close()
        self.fclose()

    def emit_qadd(self, layer: Add, shape, srcs: List[str],
                  dst: str) -> None:
        qg, w = self.qg, self.w
        n = int(np.prod(shape))
        act = layer.activation
        w(f"/* QAdd {layer.name}: {len(srcs)} inputs, {shape}, "
          f"act={act} */")
        q = self.qisa
        zp_out = qg.out_qp(layer).zero_point
        nf = (n // 8) * 8
        if q is not None and q.arch == "x86" and q.wide and nf:
            # widen 8 codes, dequant per input, sum left-associated in
            # source order (same float op order as the scalar loop),
            # then the fused epilogue
            tf = None
            for i, s in enumerate(srcs):
                qp = qg.in_qp(layer, i)
                term = (f"_mm256_mul_ps(_mm256_cvtepi32_ps("
                        f"_mm256_sub_epi32(_mm256_cvtepi8_epi32("
                        f"_mm_loadl_epi64((const __m128i *)({s} + z))), "
                        f"_mm256_set1_epi32({qp.zero_point}))), "
                        f"_mm256_set1_ps({_flit(qg.rescale(layer, i))}))")
                tf = term if tf is None else f"_mm256_add_ps({tf}, {term})"
            w.open("")
            w("int z;")
            w.open(f"for (z = 0; z < {nf}; z += 8)")
            self._vec_requant(q, tf, None, act, layer.alpha, False,
                              zp_out, f"{dst} + z")
            w.close()
            w.open(f"for (z = {nf}; z < {n}; ++z)")
            self._qadd_scalar_body(layer, srcs, dst, act, zp_out)
            w.close()
            w.close()
            return
        self.floop("z", n)
        self._qadd_scalar_body(layer, srcs, dst, act, zp_out)
        self.fclose()

    def _qadd_scalar_body(self, layer: Add, srcs: List[str], dst: str,
                          act: Optional[str], zp_out: int) -> None:
        qg, w = self.qg, self.w
        w.open("")
        w(self._REQ_DECLS)
        for i, s in enumerate(srcs):
            op = "=" if i == 0 else "+="
            qp = qg.in_qp(layer, i)
            w(f"t {op} (float)({s}[z] - {qp.zero_point}) * "
              f"{_flit(qg.rescale(layer, i))};")
        self._act_float(act, layer.alpha)
        self._round_clamp(zp_out, f"{dst}[z]")
        w.close()

    def emit_qconcat(self, layer: Concat, in_shapes, srcs: List[str],
                     dst: str) -> None:
        qg, w = self.qg, self.w
        h, wdt, _ = in_shapes[0]
        co = int(sum(s[2] for s in in_shapes))
        zp_out = qg.out_qp(layer).zero_point
        fused_by_p = self.schedule.fused_by_producer
        fused = [fused_by_p.get(n) == layer.name for n in layer.inputs]
        w(f"/* QConcat {layer.name}: {[tuple(s) for s in in_shapes]} -> "
          f"({h}, {wdt}, {co}) (per-input requant) */")
        if all(fused):
            w("/* all inputs fused into their producers' stores */")
            return
        self.floop("p", h * wdt)
        off = 0
        for i, (s, ish) in enumerate(zip(srcs, in_shapes)):
            ck = int(ish[2])
            if fused[i]:
                off += ck
                continue
            qp = qg.in_qp(layer, i)
            # the multiply and the +0.5f stay separate statements: in
            # one expression an FP_CONTRACT-honoring compiler could
            # fuse them into an FMA (single rounding) and break the
            # bit-exact contract with the jax reference
            w(_cfor(
                "z", ck,
                f"{{ float t; float u; int q; "
                f"t = (float)({s}[p * {ck} + z] - {qp.zero_point}) * "
                f"{_flit(qg.rescale(layer, i))}; "
                f"u = t + 0.5f; "
                f"q = (int)u; "
                f"q = (q - ((float)q > u)) + {zp_out}; "
                f"{dst}[p * {co} + {off} + z] = (signed char)"
                f"(q < -128 ? -128 : (q > 127 ? 127 : q)); }}"))
            off += ck
        self.fclose()

    def emit_qrelu(self, layer, in_shape, src: str, dst: str,
                   act: str, alpha: float) -> None:
        qg, w = self.qg, self.w
        n = int(np.prod(in_shape))
        qp = qg.in_qp(layer)
        w(f"/* Q{type(layer).__name__} {layer.name}: {in_shape} */")
        self.floop("z", n)
        w.open("")
        w(self._REQ_DECLS)
        w(f"t = (float)({src}[z] - {qp.zero_point}) * "
          f"{_flit(qg.rescale(layer))};")
        self._act_float(act, alpha)
        self._round_clamp(qg.out_qp(layer).zero_point, f"{dst}[z]")
        w.close()
        self.fclose()

    def emit_qsoftmax_sink(self, layer: Softmax, in_shape,
                           src: str) -> None:
        qg, w = self.qg, self.w
        n = int(np.prod(in_shape))
        qp = qg.in_qp(layer)
        w(f"/* QSoftmax {layer.name} (sink): dequantize + float "
          f"softmax */")
        w(_cfor("z", n,
                f"out[z] = (float)({src}[z] - {qp.zero_point}) * "
                f"{_flit(np.float32(qp.scale))};"))
        self.emit_softmax(in_shape, "out")

    # -- driver ---------------------------------------------------------------

    def _emit_fuse_init(self, node: FuseNode, smap) -> None:
        """Int8 fusion prologue: max pooling fills the consumer's codes
        with -128 (the reduction identity — every window sees >= 1
        producer code), average pooling zeroes the int32 window-sum
        scratch the producer stores accumulate into."""
        if isinstance(node, PoolFuse):
            p = node.pool
            self.w(f"/* fused Q{type(p).__name__} {p.name}: producer "
                   f"stores reduce straight into the {node.kind} "
                   f"windows */")
            if node.kind == "max":
                self.w(_cfor("z", node.n, f"{node.dst}[z] = -128;"))
            else:
                self.w(_cfor("z", node.n, f"{node.acc}[z] = 0;"))
        elif isinstance(node, ConcatFuse):
            self.w(f"/* fused QConcat {node.concat.name} edge "
                   f"{node.pos}: producer writes its channel slice at "
                   f"offset {node.c_off} directly */")

    def _emit_fuse_finalize(self, node: FuseNode, smap) -> None:
        """Int8 fusion epilogue: average pooling requantizes the int32
        window sums — the exact :meth:`emit_qavgpool` arithmetic, so
        fused results stay bit-identical."""
        if not (isinstance(node, PoolFuse) and node.kind == "avg"):
            return
        qg, w = self.qg, self.w
        p = node.pool
        kh, kw_ = p.size
        zp_in = qg.in_qp(p).zero_point
        minv = qg.pool_scales(p, smap[p.inputs[0]])
        assert np.unique(minv).size == 1, \
            f"{p.name}: fused avg pool requires a uniform window divisor"
        mexpr = _flit(minv.ravel()[0])
        self.floop("z", node.n)
        w.open("")
        w(self._REQ_DECLS)
        w(f"t = (float)({node.acc}[z] - {kh * kw_ * zp_in}) * {mexpr};")
        self._round_clamp(qg.out_qp(p).zero_point, f"{node.dst}[z]")
        w.close()
        self.fclose()

    def _emit_input_quant(self, xsrc: str) -> None:
        """Input quantization prologue: float ``xsrc`` -> int8 codes in
        the ``xq`` arena value (vectorized when a QISA is active)."""
        g, w = self.g, self.w
        in_qp = self.qg.input_qp
        q = self.qisa
        n_in = int(np.prod(g.input_shape))
        w(f"/* quantize input: q = floor(x * {in_qp.inv_scale} + 0.5) "
          f"+ {in_qp.zero_point} */")
        nf = (n_in // q.group) * q.group if q is not None else 0
        w.open("")
        w("int z;")
        if nf:
            if q.arch == "x86":
                pfx = "_mm256" if q.wide else "_mm"
                tf_init = f"{pfx}_loadu_ps({xsrc} + z)"
                mexpr = f"{pfx}_set1_ps({_flit(in_qp.inv_scale)})"
            else:
                tf_init = f"vld1q_f32({xsrc} + z)"
                mexpr = f"vdupq_n_f32({_flit(in_qp.inv_scale)})"
            w.open(f"for (z = 0; z < {nf}; z += {q.group})")
            self._vec_requant(q, tf_init, mexpr, None, 0.0, False,
                              in_qp.zero_point, f"{_cname('xq')} + z")
            w.close()
        if nf < n_in:
            w.open(f"for (z = {nf}; z < {n_in}; ++z)")
            w.open("")
            w(self._REQ_DECLS)
            w(f"t = {xsrc}[z] * {_flit(in_qp.inv_scale)};")
            self._round_clamp(in_qp.zero_point, f"{_cname('xq')}[z]")
            w.close()
            w.close()
        w.close()

    def _emit_layer(self, layer, smap, val, ref, plan) -> None:
        ishs = [smap[n] for n in layer.inputs]
        srcs = [ref(val[n]) for n in layer.inputs]
        dst = ref(val[layer.name])
        is_sink = layer is self.g.sink
        pad_buf = (_cname(layer.name + "__pad")
                   if layer.name + "__pad" in plan.offsets else None)
        if isinstance(layer, Conv2D):
            self.emit_qconv(layer, ishs[0], srcs[0], dst, pad_buf,
                            is_sink)
        elif isinstance(layer, DepthwiseConv2D):
            self.emit_qdepthwise(layer, ishs[0], srcs[0], dst,
                                 pad_buf, is_sink)
        elif isinstance(layer, Dense):
            self.emit_qdense(layer, ishs[0], srcs[0], dst, is_sink)
        elif isinstance(layer, MaxPool):
            self.emit_qmaxpool(layer, ishs[0], srcs[0], dst, pad_buf)
        elif isinstance(layer, AvgPool):
            self.emit_qavgpool(layer, ishs[0], srcs[0], dst, pad_buf)
        elif isinstance(layer, GlobalAvgPool):
            self.emit_qglobal_avgpool(layer, ishs[0], srcs[0], dst)
        elif isinstance(layer, Add):
            self.emit_qadd(layer, smap[layer.name], srcs, dst)
        elif isinstance(layer, Concat):
            self.emit_qconcat(layer, ishs, srcs, dst)
        elif isinstance(layer, ReLU):
            self.emit_qrelu(layer, ishs[0], srcs[0], dst, "relu", 0.0)
        elif isinstance(layer, LeakyReLU):
            self.emit_qrelu(layer, ishs[0], srcs[0], dst, "leaky_relu",
                            layer.alpha)
        elif isinstance(layer, Softmax):
            assert is_sink, "standalone Softmax only supported as sink"
            self.emit_qsoftmax_sink(layer, ishs[0], srcs[0])
        else:
            raise TypeError(
                f"quantized cgen: unhandled layer "
                f"{type(layer).__name__} "
                f"(run passes.optimize before quantizing)")

    def lower(self) -> Program:
        if self._program is not None:
            return self._program
        g, opts, w = self.g, self.opts, self.w
        sched = self.schedule
        smap = g.shape_map()
        plan = self.plan = plan_arena(g, opts, quantized=True,
                                      schedule=sched)
        val = _value_map(g, quantized=True, schedule=sched)
        sink = g.sink
        out_value = val[sink.name]
        assert out_value != "xq", "degenerate identity graph"
        S = sched.nstages
        self.ws_total_elems = plan.total_floats
        q = self.qisa

        def ref(v: str) -> str:
            return "out" if v == out_value else _cname(v)

        if S > 1:
            self._emit_pipeline(smap, val, out_value, plan)

        w.open(f"void {opts.ws_func_name}(const float *NNCG_RESTRICT x, "
               f"float *NNCG_RESTRICT out, "
               f"signed char *NNCG_RESTRICT ws)")
        if S > 1:
            w(f"{opts.pipeline_func_name}(x, out, ws, {S});")
        else:
            for iv in sorted(plan.intervals,
                             key=lambda iv: (iv.offset, iv.value)):
                if iv.value.endswith("__acc"):
                    # int32 window-sum scratch of a fused avg pool:
                    # planned 4-aligned inside the byte arena
                    w(f"int *const {_cname(iv.value)} = "
                      f"(int *)(void *)(ws + {iv.offset}); "
                      f"/* {iv.size} bytes, live layers "
                      f"[{iv.start}, {iv.end}] */")
                    continue
                w(f"signed char *const {_cname(iv.value)} = "
                  f"ws + {iv.offset}; "
                  f"/* {iv.size} bytes, live layers "
                  f"[{iv.start}, {iv.end}] */")
            if not plan.intervals:
                w("(void) ws;")
            self._emit_input_quant("x")
            self._emit_graph_body(g.layers, smap, val, ref, plan)
        w.close()

        arena = f"{opts.func_name}_arena"
        if any(iv.value.endswith("__acc") for iv in plan.intervals):
            # the byte arena hosts int32 scratch: declare it as an int
            # array so the fused avg-pool pointers are aligned
            self.decls(f"static int {arena}_i4"
                       f"[{(max(self.ws_total_elems, 1) + 3) // 4}];")
            arena_arg = f"(signed char *){arena}_i4"
        else:
            self.decls(f"static signed char {arena}"
                       f"[{max(self.ws_total_elems, 1)}];")
            arena_arg = arena
        w("")
        w.open(f"void {opts.func_name}(const float *NNCG_RESTRICT x, "
               f"float *NNCG_RESTRICT out)")
        w(f"{opts.ws_func_name}(x, out, {arena_arg});")
        w.close()
        w("")
        w.open(f"long {opts.ws_bytes_func_name}(void)")
        w(f"return {self.ws_total_elems}L;")
        w.close()

        if opts.emit_batch:
            in_n = int(np.prod(g.input_shape))
            out_n = int(np.prod(smap[sink.name]))
            w("")
            w.open(f"void {opts.batch_ws_func_name}("
                   f"const float *NNCG_RESTRICT x, "
                   f"float *NNCG_RESTRICT out, int n, "
                   f"signed char *NNCG_RESTRICT workspace)")
            w("int b;")
            w(f"for (b = 0; b < n; ++b) "
              f"{opts.ws_func_name}(x + (long)b * {in_n}, "
              f"out + (long)b * {out_n}, workspace);")
            w.close()
            w("")
            w.open(f"void {opts.batch_func_name}("
                   f"const float *NNCG_RESTRICT x, "
                   f"float *NNCG_RESTRICT out, int n)")
            w(f"{opts.batch_ws_func_name}(x, out, n, {arena_arg});")
            w.close()

        hdr = _W()
        hdr("/* Generated by NNCG-JAX (repro of Urbann et al., 2020) — "
            "int8 PTQ build.")
        hdr(f" * net: in {g.input_shape} -> out {smap[sink.name]}, "
            f"{g.param_count()} params, simd={opts.simd},")
        hdr(f" * calibration={getattr(self.qg, 'method', 'minmax')} "
            f"(per-branch activation qparams on multi-input edges),")
        hdr(f" * int8 arena {plan.total_bytes} B "
            f"(float32 intermediates would be ~4x)"
            f"{f', pipeline stages={S}' if S > 1 else ''} */")
        hdr("#include <math.h>")
        if q is not None:
            hdr(f"#include <{q.header}>")
        if q is not None or S > 1:
            hdr("#include <string.h>")  # memcpy: strict-aliasing-safe
                                        # unaligned loads + stage
                                        # pass-through forwarding
        hdr("#if defined(__STDC_VERSION__) && __STDC_VERSION__ >= 199901L")
        hdr("#define NNCG_RESTRICT restrict")
        hdr("#else")
        hdr("#define NNCG_RESTRICT")
        hdr("extern float expf(float);")
        hdr("#endif")
        hdr("")
        return self._finish_program(hdr, plan, "int8")


def generate_quantized_c(qgraph,
                         opts: Optional[CodegenOptions] = None) -> str:
    """Deprecated: use :func:`repro.core.codegen.compile`.

    Kept as a shim; emits the legacy (unfused, single-stage) code so
    existing structural expectations hold byte-for-byte.
    """
    _warn_legacy("generate_quantized_c")
    return QuantCGenerator(
        qgraph, opts or CodegenOptions(),
        schedule=make_schedule(qgraph.graph, fusion=False)).generate()
