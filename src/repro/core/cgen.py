"""NNCG — the ANSI C code generator (paper §II).

Generates, from a trained :class:`CNNGraph`, one plain C file exposing

    void <func>(const float *restrict x, float *restrict out);

implementing the four design principles:

* **P1 unroll levels** — per-layer ``level``: 0 = all loops unrolled
  (straight-line code), 1 = keep the outermost spatial loop, 2 = keep both
  spatial loops, ``None`` = no unrolling (plain loop nest).  Matches the
  paper: "At level 0 all loops are unrolled. Level 1 does not unroll the
  outer most loop and so forth."
* **P2 conditional moves** — activations and pooling emit the C ternary
  operator, never an ``if`` block.
* **P3 constants** — with any unrolling the trained weights are printed
  as literals into the code line; without unrolling they are emitted as
  ``static const`` arrays.  Zero padding taps are *elided entirely* at
  level 0 (a static-knowledge win no generic library has).
* **P4 SIMD structure** — three modes: ``generic`` (paper's scalar
  baseline, output-channel loop outside the tap loops), ``structured``
  (channel loop innermost over contiguous memory → auto-vectorizable),
  and ``sse`` (explicit SSSE3/SSE intrinsics over groups of 4 output
  channels, the paper's shipped mode).

The only dependencies of the generated file are ``math.h`` (softmax) and,
in ``sse`` mode, ``emmintrin.h`` — exactly the paper's dependency set.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .graph import (
    BatchNorm,
    CNNGraph,
    Conv2D,
    Dense,
    Dropout,
    Flatten,
    Input,
    LeakyReLU,
    MaxPool,
    ReLU,
    Softmax,
)

Level = Optional[int]  # 0 | 1 | 2 | None (no unroll)

# bump whenever the emitted C changes for the same (graph, options) —
# cached artifacts measured on older generated code must not be reused
CODEGEN_VERSION = 2


@dataclass(frozen=True)
class ISA:
    """Vector instruction-set descriptor (P4). The paper ships SSSE3 and
    names AVX as future work — ``avx`` implements it (8-wide + FMA)."""

    name: str
    width: int
    reg: str
    header: str
    cc_flags: tuple
    prefix: str

    def load(self, ptr: str) -> str:
        return f"{self.prefix}_loadu_ps(&{ptr})"

    def store(self, ptr: str, reg: str) -> str:
        return f"{self.prefix}_storeu_ps(&{ptr}, {reg});"

    def set1(self, x: str) -> str:
        return f"{self.prefix}_set1_ps({x})"

    def zero(self) -> str:
        return f"{self.prefix}_setzero_ps()"

    def add(self, a: str, b: str) -> str:
        return f"{self.prefix}_add_ps({a}, {b})"

    def mul(self, a: str, b: str) -> str:
        return f"{self.prefix}_mul_ps({a}, {b})"

    def vmax(self, a: str, b: str) -> str:
        return f"{self.prefix}_max_ps({a}, {b})"

    def fmadd(self, a: str, b: str, c: str) -> str:
        """a*b + c."""
        if self.name == "avx":
            return f"{self.prefix}_fmadd_ps({a}, {b}, {c})"
        return self.add(c, self.mul(a, b))

    def set_lits(self, vals) -> str:
        lits = ", ".join(_flit(v) for v in reversed(list(vals)))
        return f"{self.prefix}_set_ps({lits})"


SSE = ISA(name="sse", width=4, reg="__m128", header="emmintrin.h",
          cc_flags=("-mssse3",), prefix="_mm")
AVX = ISA(name="avx", width=8, reg="__m256", header="immintrin.h",
          cc_flags=("-mavx2", "-mfma"), prefix="_mm256")
ISAS = {"sse": SSE, "avx": AVX}


@dataclass
class CodegenOptions:
    simd: str = "sse"            # 'generic' | 'structured' | 'sse' | 'avx'
    unroll: Union[Level, Dict[str, Level]] = 0
    func_name: str = "nncg_net"
    term_budget: int = 60_000    # max emitted FMA terms per layer before
                                 # the level is demoted (icache trade-off)
    emit_batch: bool = True      # also emit `<func>_batch(x, out, n)` —
                                 # a loop-over-images serving entry point

    @property
    def isa(self) -> Optional[ISA]:
        return ISAS.get(self.simd)

    @property
    def batch_func_name(self) -> str:
        return self.func_name + "_batch"

    def level_for(self, layer_name: str) -> Level:
        if isinstance(self.unroll, dict):
            return self.unroll.get(layer_name, None)
        return self.unroll


def _flit(v: float) -> str:
    """Format a float32 as a C literal (paper P3)."""
    s = np.format_float_scientific(np.float32(v), unique=True, trim="0")
    return f"{s}f"


class _W:
    """Tiny indented writer."""

    def __init__(self) -> None:
        self.lines: List[str] = []
        self._ind = 0

    def __call__(self, line: str = "") -> None:
        self.lines.append("    " * self._ind + line if line else "")

    def open(self, line: str) -> None:
        self(line + " {")
        self._ind += 1

    def close(self) -> None:
        self._ind -= 1
        self("}")

    def text(self) -> str:
        return "\n".join(self.lines) + "\n"


def estimate_terms(layer, in_shape, level: Level) -> int:
    """Emitted multiply-add terms for a conv/pool at an unroll level —
    the code-size side of the paper's unroll/icache trade-off."""
    if isinstance(layer, Conv2D):
        oh, ow, co = layer.out_shape(in_shape)
        taps = layer.kh * layer.kw * layer.c_in
        per_out = taps
        n_out = {0: oh * ow * co, 1: ow * co, 2: co}.get(level, 0)
        return n_out * per_out if level is not None else taps
    if isinstance(layer, MaxPool):
        oh, ow, c = layer.out_shape(in_shape)
        taps = layer.size[0] * layer.size[1]
        n_out = {0: oh * ow * c, 1: ow * c, 2: c}.get(level, 0)
        return n_out * taps if level is not None else taps
    return 0


def enumerate_variants(layer, in_shape, term_cap: int = 200_000) -> List[Level]:
    """Candidate unroll levels for one layer, deepest (level 0) first.

    This is the variant space the paper benchmarks per layer ("we
    independently benchmark every code version and select the one with
    the best runtime performance").  Levels whose emitted-term count
    exceeds ``term_cap`` are dropped — they would blow the icache (and
    the compile time) before they could win; ``None`` (rolled loops) is
    always feasible.  Returns ``[]`` for layers with no codegen variants.
    """
    if not isinstance(layer, (Conv2D, MaxPool)):
        return []
    return [lvl for lvl in (0, 1, 2, None)
            if lvl is None or estimate_terms(layer, in_shape, lvl) <= term_cap]


def choose_levels(graph: CNNGraph, budget: int = 60_000) -> Dict[str, Level]:
    """Pick, per layer, the deepest unroll level within the term budget.

    This is the static analogue of the paper's per-layer variant
    benchmarking — the :mod:`repro.engine.autotune` tuner explores the
    same :func:`enumerate_variants` space dynamically and can override
    any choice made here.
    """
    levels: Dict[str, Level] = {}
    shape = graph.input_shape
    for layer in graph.layers:
        for lvl in enumerate_variants(layer, shape, term_cap=budget):
            levels[layer.name] = lvl
            break
        shape = layer.out_shape(shape)
    return levels


# ---------------------------------------------------------------------------
# code generation
# ---------------------------------------------------------------------------


class CGenerator:
    def __init__(self, graph: CNNGraph, opts: CodegenOptions):
        self.g = graph
        self.opts = opts
        self.w = _W()
        self.decls = _W()
        self._uid = 0

    # -- helpers ------------------------------------------------------------

    def uid(self) -> int:
        self._uid += 1
        return self._uid

    def const_array(self, name: str, arr: np.ndarray) -> str:
        vals = ", ".join(_flit(v) for v in np.asarray(arr, np.float32).ravel())
        self.decls(f"static const float {name}[{arr.size}] = {{{vals}}};")
        return name

    def buffer(self, name: str, size: int) -> str:
        self.decls(f"static float {name}[{size}];")
        return name

    # -- activation epilogues (P2: ternary, never a branch) ------------------

    def act_scalar(self, expr: str, act: Optional[str], alpha: float) -> str:
        if act == "relu":
            return f"(({expr}) > 0.0f ? ({expr}) : 0.0f)"
        if act == "leaky_relu":
            return f"(({expr}) > 0.0f ? ({expr}) : {_flit(alpha)} * ({expr}))"
        return expr

    def act_sse(self, reg: str, act: Optional[str], alpha: float) -> List[str]:
        isa = self.opts.isa
        if act == "relu":
            return [f"{reg} = {isa.vmax(reg, isa.zero())};"]
        if act == "leaky_relu":
            # max(x, a*x) == leaky_relu(x) for 0 < a < 1 — branch-free
            return [f"{reg} = {isa.vmax(reg, isa.mul(reg, isa.set1(_flit(alpha))))};"]
        return []

    # -- padding ------------------------------------------------------------

    def emit_padded_copy(self, src: str, in_shape, pads) -> Tuple[str, Tuple[int, int, int]]:
        """Materialize a zero-padded copy (paper Eq. 1) for the looped modes
        where tap bounds are not static."""
        h, wdt, c = in_shape
        pt, pb, pl, pr = pads
        ph, pw = h + pt + pb, wdt + pl + pr
        name = f"pad{self.uid()}"
        self.buffer(name, ph * pw * c)
        w = self.w
        w(f"/* zero-pad {src}: ({h}x{wdt}x{c}) -> ({ph}x{pw}x{c}) */")
        w(f"for (int z = 0; z < {ph * pw * c}; ++z) {name}[z] = 0.0f;")
        w.open(f"for (int i = 0; i < {h}; ++i)")
        w(f"for (int z = 0; z < {wdt * c}; ++z) "
          f"{name}[((i + {pt}) * {pw} + {pl}) * {c} + z] = "
          f"{src}[i * {wdt * c} + z];")
        w.close()
        return name, (ph, pw, c)

    # -- conv ---------------------------------------------------------------

    def emit_conv(self, layer: Conv2D, in_shape, src: str, dst: str) -> None:
        opts, w = self.opts, self.w
        level = opts.level_for(layer.name)
        oh, ow, co = layer.out_shape(in_shape)
        sh, sw = layer.strides
        pads = layer.pad_amounts(in_shape)
        kh, kw_, ci = layer.kh, layer.kw, layer.c_in
        W_ = layer.weights  # HWIO
        B_ = layer.bias
        # demote level if over budget (icache trade-off, P1)
        while level is not None and estimate_terms(layer, in_shape, level) > opts.term_budget:
            level = {0: 1, 1: 2, 2: None}[level]

        w(f"/* Conv2D {layer.name}: {in_shape}->{(oh, ow, co)} "
          f"k={kh}x{kw_} s={sh}x{sw} pad={layer.padding} "
          f"act={layer.activation} level={level} simd={opts.simd} */")

        use_pad_buf = any(pads) and level != 0
        if use_pad_buf:
            src, in_shape = self.emit_padded_copy(src, in_shape, pads)
            pads = (0, 0, 0, 0)
        h, wdt, _ = in_shape
        pt, _pb, pl, _pr = pads

        literals = level is not None
        wname = bname = None
        if not literals:
            wname = self.const_array(f"w{self.uid()}", W_)
            bname = self.const_array(f"b{self.uid()}", B_)

        def x_index(i, j, n, m, o) -> str:
            """Index into src for output (i,j) tap (n,m,o); i/j may be C exprs."""
            if isinstance(i, int):
                row = i * sh + n - pt
            else:
                row = f"({i} * {sh} + {n - pt})"
            if isinstance(j, int):
                col = j * sw + m - pl
            else:
                col = f"({j} * {sw} + {m - pl})"
            if isinstance(row, int) and isinstance(col, int):
                return str((row * wdt + col) * ci + o)
            return f"(({row}) * {wdt} + ({col})) * {ci} + {o}"

        def out_index(i, j, k) -> str:
            if isinstance(i, int) and isinstance(j, int) and isinstance(k, int):
                return str((i * ow + j) * co + k)
            ke = str(k)
            return f"(({i}) * {ow} + ({j})) * {co} + {ke}"

        def in_bounds(i, j, n, m) -> bool:
            """Static OOB elision (only callable when i and j are ints)."""
            r, c = i * sh + n - pt, j * sw + m - pl
            return 0 <= r < h and 0 <= c < wdt

        def emit_body(i, j) -> None:
            static_ij = isinstance(i, int) and isinstance(j, int)
            if opts.isa is not None:
                self._conv_body_sse(layer, W_, B_, wname, bname, literals,
                                    i, j, static_ij, x_index, out_index,
                                    in_bounds, dst, src)
            elif opts.simd == "structured":
                self._conv_body_structured(layer, W_, B_, wname, bname, literals,
                                           i, j, static_ij, x_index, out_index,
                                           in_bounds, dst, src)
            else:
                self._conv_body_generic(layer, W_, B_, wname, bname, literals,
                                        i, j, static_ij, x_index, out_index,
                                        in_bounds, dst, src)

        if level == 0:
            for i in range(oh):
                for j in range(ow):
                    emit_body(i, j)
        elif level == 1:
            w.open(f"for (int i = 0; i < {oh}; ++i)")
            for j in range(ow):
                emit_body("i", j)
            w.close()
        elif level == 2:
            w.open(f"for (int i = 0; i < {oh}; ++i)")
            w.open(f"for (int j = 0; j < {ow}; ++j)")
            emit_body("i", "j")
            w.close()
            w.close()
        else:
            w.open(f"for (int i = 0; i < {oh}; ++i)")
            w.open(f"for (int j = 0; j < {ow}; ++j)")
            self._conv_loops_rolled(layer, wname, bname, in_shape,
                                    (oh, ow, co), dst, src, pads)
            w.close()
            w.close()

        if layer.activation == "softmax":
            self.emit_softmax((oh, ow, co), dst)

    # rolled inner loops (level=None): weights from const arrays
    def _conv_loops_rolled(self, layer, wname, bname, in_shape, out_shape,
                           dst, src, pads):
        w = self.w
        h, wdt, ci = in_shape
        oh, ow, co = out_shape
        kh, kw_ = layer.kh, layer.kw
        sh, sw = layer.strides
        pt, _, pl, _ = pads
        assert pt == 0 and pl == 0, "rolled mode uses padded buffers"
        if self.opts.isa is not None:
            isa = self.opts.isa
            co4 = co - co % isa.width
            w.open(f"for (int k = 0; k < {co4}; k += {isa.width})")
            w(f"{isa.reg} acc = {isa.load(f'{bname}[k]')};")
            w.open(f"for (int n = 0; n < {kh}; ++n)")
            w.open(f"for (int m = 0; m < {kw_}; ++m)")
            w.open(f"for (int o = 0; o < {ci}; ++o)")
            xv = f"{src}[((i * {sh} + n) * {wdt} + (j * {sw} + m)) * {ci} + o]"
            wv = f"{wname}[((n * {kw_} + m) * {ci} + o) * {co} + k]"
            w(f"acc = {isa.fmadd(isa.set1(xv), isa.load(wv), 'acc')};")
            w.close(); w.close(); w.close()
            for ln in self.act_sse("acc", layer.activation
                                   if layer.activation != "softmax" else None,
                                   layer.alpha):
                w(ln)
            w(isa.store(f"{dst}[(i * {ow} + j) * {co} + k]", "acc"))
            w.close()
            ks = range(co4, co)
        elif self.opts.simd == "structured":
            # channel loop innermost over contiguous memory -> auto-vec
            w(f"float acc[{co}];")
            w(f"for (int k = 0; k < {co}; ++k) acc[k] = {bname}[k];")
            w.open(f"for (int n = 0; n < {kh}; ++n)")
            w.open(f"for (int m = 0; m < {kw_}; ++m)")
            w.open(f"for (int o = 0; o < {ci}; ++o)")
            w(f"const float xv = {src}[((i * {sh} + n) * {wdt} + "
              f"(j * {sw} + m)) * {ci} + o];")
            w(f"for (int k = 0; k < {co}; ++k) "
              f"acc[k] += xv * {wname}[((n * {kw_} + m) * {ci} + o) * {co} + k];")
            w.close(); w.close(); w.close()
            act = layer.activation if layer.activation != "softmax" else None
            w(f"for (int k = 0; k < {co}; ++k) "
              f"{dst}[(i * {ow} + j) * {co} + k] = "
              f"{self.act_scalar('acc[k]', act, layer.alpha)};")
            ks = ()
        else:
            w.open(f"for (int k = 0; k < {co}; ++k)")
            w(f"float acc = {bname}[k];")
            w.open(f"for (int n = 0; n < {kh}; ++n)")
            w.open(f"for (int m = 0; m < {kw_}; ++m)")
            w.open(f"for (int o = 0; o < {ci}; ++o)")
            w(f"acc += {wname}[((n * {kw_} + m) * {ci} + o) * {co} + k] * "
              f"{src}[((i * {sh} + n) * {wdt} + (j * {sw} + m)) * {ci} + o];")
            w.close(); w.close(); w.close()
            act = layer.activation if layer.activation != "softmax" else None
            w(f"{dst}[(i * {ow} + j) * {co} + k] = "
              f"{self.act_scalar('acc', act, layer.alpha)};")
            w.close()
            ks = ()
        # scalar tail for sse mode
        for k in ks:
            w(f"{{ float acc = {bname}[{k}];")
            w(f"  for (int n = 0; n < {kh}; ++n) for (int m = 0; m < {kw_}; ++m) "
              f"for (int o = 0; o < {ci}; ++o) "
              f"acc += {wname}[((n * {kw_} + m) * {ci} + o) * {co} + {k}] * "
              f"{src}[((i * {sh} + n) * {wdt} + (j * {sw} + m)) * {ci} + o];")
            act = layer.activation if layer.activation != "softmax" else None
            w(f"  {dst}[(i * {ow} + j) * {co} + {k}] = "
              f"{self.act_scalar('acc', act, layer.alpha)}; }}")

    # unrolled bodies --------------------------------------------------------

    def _taps(self, layer, i, j, static_ij, in_bounds):
        for n in range(layer.kh):
            for m in range(layer.kw):
                if static_ij and not in_bounds(i, j, n, m):
                    continue  # P3: zero tap elided entirely
                for o in range(layer.c_in):
                    yield n, m, o

    def _conv_body_generic(self, layer, W_, B_, wname, bname, literals,
                           i, j, static_ij, x_index, out_index, in_bounds,
                           dst, src):
        w = self.w
        co = layer.c_out
        act = layer.activation if layer.activation != "softmax" else None
        w.open("")  # scope block
        for k in range(co):
            bias = _flit(B_[k]) if literals else f"{bname}[{k}]"
            w(f"float a{k} = {bias};")
        for n, m, o in self._taps(layer, i, j, static_ij, in_bounds):
            xv = f"{src}[{x_index(i, j, n, m, o)}]"
            for k in range(co):
                wv = (_flit(W_[n, m, o, k]) if literals
                      else f"{wname}[{((n * layer.kw + m) * layer.c_in + o) * co + k}]")
                w(f"a{k} += {xv} * {wv};")
        for k in range(co):
            w(f"{dst}[{out_index(i, j, k)}] = "
              f"{self.act_scalar(f'a{k}', act, layer.alpha)};")
        w.close()

    def _conv_body_structured(self, layer, W_, B_, wname, bname, literals,
                              i, j, static_ij, x_index, out_index, in_bounds,
                              dst, src):
        # identical accumulators but channel-contiguous arrays
        self._conv_body_generic(layer, W_, B_, wname, bname, literals, i, j,
                                static_ij, x_index, out_index, in_bounds,
                                dst, src)

    def _conv_body_sse(self, layer, W_, B_, wname, bname, literals,
                       i, j, static_ij, x_index, out_index, in_bounds,
                       dst, src):
        w = self.w
        isa = self.opts.isa
        vw = isa.width
        co = layer.c_out
        co4 = co - co % vw
        act = layer.activation if layer.activation != "softmax" else None
        w.open("")
        for kg in range(0, co4, vw):
            if literals:
                w(f"{isa.reg} v{kg} = "
                  f"{isa.set_lits(B_[kg:kg + vw])};")
            else:
                w(f"{isa.reg} v{kg} = {isa.load(f'{bname}[{kg}]')};")
        for n, m, o in self._taps(layer, i, j, static_ij, in_bounds):
            xv = f"{src}[{x_index(i, j, n, m, o)}]"
            w(f"{{ const {isa.reg} xb = {isa.set1(xv)};")
            for kg in range(0, co4, vw):
                if literals:
                    wreg = isa.set_lits(W_[n, m, o, kg:kg + vw])
                else:
                    off = ((n * layer.kw + m) * layer.c_in + o) * co + kg
                    wreg = isa.load(f"{wname}[{off}]")
                w(f"  v{kg} = {isa.fmadd('xb', wreg, f'v{kg}')};")
            w("}")
        for kg in range(0, co4, vw):
            for ln in self.act_sse(f"v{kg}", act, layer.alpha):
                w(ln)
            w(isa.store(f"{dst}[{out_index(i, j, kg)}]", f"v{kg}"))
        # scalar tail
        for k in range(co4, co):
            bias = _flit(B_[k]) if literals else f"{bname}[{k}]"
            w(f"float t{k} = {bias};")
            for n, m, o in self._taps(layer, i, j, static_ij, in_bounds):
                xv = f"{src}[{x_index(i, j, n, m, o)}]"
                wv = (_flit(W_[n, m, o, k]) if literals
                      else f"{wname}[{((n * layer.kw + m) * layer.c_in + o) * co + k}]")
                w(f"t{k} += {xv} * {wv};")
            w(f"{dst}[{out_index(i, j, k)}] = "
              f"{self.act_scalar(f't{k}', act, layer.alpha)};")
        w.close()

    # -- pooling / elementwise / softmax / dense -----------------------------

    def emit_maxpool(self, layer: MaxPool, in_shape, src: str, dst: str) -> None:
        w, opts = self.w, self.opts
        h, wdt, c = in_shape
        oh, ow, co = layer.out_shape(in_shape)
        kh, kw_ = layer.size
        sh, sw = layer.strides
        level = opts.level_for(layer.name)
        while level is not None and estimate_terms(layer, in_shape, level) > opts.term_budget:
            level = {0: 1, 1: 2, 2: None}[level]
        w(f"/* MaxPool {layer.name}: {in_shape}->{(oh, ow, co)} "
          f"k={kh}x{kw_} s={sh}x{sw} level={level} */")

        def body(i, j):
            isa = opts.isa
            if isa is not None and c % isa.width == 0:
                w.open("")
                for kg in range(0, c, isa.width):
                    first = True
                    for n in range(kh):
                        for m in range(kw_):
                            idx = x_idx(i, j, n, m, kg)
                            if first:
                                w(f"{isa.reg} p{kg} = "
                                  f"{isa.load(f'{src}[{idx}]')};")
                                first = False
                            else:
                                w(f"p{kg} = {isa.vmax(f'p{kg}', isa.load(f'{src}[{idx}]'))};")
                    w(isa.store(f"{dst}[{o_idx(i, j, kg)}]", f"p{kg}"))
                w.close()
            else:
                w.open("")
                for k in range(c):
                    first = True
                    for n in range(kh):
                        for m in range(kw_):
                            idx = x_idx(i, j, n, m, k)
                            if first:
                                w(f"float q{k} = {src}[{idx}];")
                                first = False
                            else:
                                # P2: ternary, not an if
                                w(f"q{k} = {src}[{idx}] > q{k} ? "
                                  f"{src}[{idx}] : q{k};")
                    w(f"{dst}[{o_idx(i, j, k)}] = q{k};")
                w.close()

        def x_idx(i, j, n, m, k):
            if isinstance(i, int) and isinstance(j, int):
                return str(((i * sh + n) * wdt + (j * sw + m)) * c + k)
            return (f"(({i} * {sh} + {n}) * {wdt} + ({j} * {sw} + {m})) "
                    f"* {c} + {k}")

        def o_idx(i, j, k):
            if isinstance(i, int) and isinstance(j, int):
                return str((i * ow + j) * co + k)
            return f"(({i}) * {ow} + ({j})) * {co} + {k}"

        if level == 0:
            for i in range(oh):
                for j in range(ow):
                    body(i, j)
        elif level == 1:
            w.open(f"for (int i = 0; i < {oh}; ++i)")
            for j in range(ow):
                body("i", j)
            w.close()
        elif level == 2:
            w.open(f"for (int i = 0; i < {oh}; ++i)")
            w.open(f"for (int j = 0; j < {ow}; ++j)")
            body("i", "j")
            w.close(); w.close()
        else:
            w.open(f"for (int i = 0; i < {oh}; ++i)")
            w.open(f"for (int j = 0; j < {ow}; ++j)")
            if opts.isa is not None and c % opts.isa.width == 0:
                isa = opts.isa
                w.open(f"for (int k = 0; k < {c}; k += {isa.width})")
                w(f"{isa.reg} p = "
                  f"{isa.load(f'{src}[' + x_idx('i', 'j', 0, 0, 0) + ' + k]')};")
                for n in range(kh):
                    for m in range(kw_):
                        if n == 0 and m == 0:
                            continue
                        ld = isa.load(f"{src}[" + x_idx('i', 'j', n, m, 0)
                                      + " + k]")
                        w(f"p = {isa.vmax('p', ld)};")
                w(isa.store(f"{dst}[(i * {ow} + j) * {co} + k]", "p"))
                w.close()
            else:
                w.open(f"for (int k = 0; k < {c}; ++k)")
                w(f"float q = {src}[{x_idx('i', 'j', 0, 0, 0)} + k];")
                for n in range(kh):
                    for m in range(kw_):
                        if n == 0 and m == 0:
                            continue
                        w(f"q = {src}[{x_idx('i', 'j', n, m, 0)} + k] > q ? "
                          f"{src}[{x_idx('i', 'j', n, m, 0)} + k] : q;")
                w(f"{dst}[(i * {ow} + j) * {co} + k] = q;")
                w.close()
            w.close(); w.close()

    def emit_elementwise(self, in_shape, src, dst, act, alpha) -> None:
        w = self.w
        n = int(np.prod(in_shape))
        isa = self.opts.isa
        if isa is not None and n % isa.width == 0 and act in (
                "relu", "leaky_relu"):
            w.open(f"for (int z = 0; z < {n}; z += {isa.width})")
            w(f"{isa.reg} v = {isa.load(f'{src}[z]')};")
            for ln in self.act_sse("v", act, alpha):
                w(ln)
            w(isa.store(f"{dst}[z]", "v"))
            w.close()
        else:
            w(f"for (int z = 0; z < {n}; ++z) {dst}[z] = "
              f"{self.act_scalar(f'{src}[z]', act, alpha)};")

    def emit_batchnorm(self, layer: BatchNorm, in_shape, src, dst) -> None:
        w = self.w
        scale, shift = layer.scale_shift()
        c = in_shape[2]
        sname = self.const_array(f"s{self.uid()}", scale)
        tname = self.const_array(f"t{self.uid()}", shift)
        n = int(np.prod(in_shape))
        w(f"for (int z = 0; z < {n}; ++z) "
          f"{dst}[z] = {src}[z] * {sname}[z % {c}] + {tname}[z % {c}];")

    def emit_softmax(self, shape, buf) -> None:
        w = self.w
        h, wdt, c = shape
        w(f"/* softmax over {c} channels */")
        w.open(f"for (int p = 0; p < {h * wdt}; ++p)")
        w(f"float mx = {buf}[p * {c}];")
        w(f"for (int k = 1; k < {c}; ++k) "
          f"mx = {buf}[p * {c} + k] > mx ? {buf}[p * {c} + k] : mx;")
        w("float s = 0.0f;")
        w(f"for (int k = 0; k < {c}; ++k) "
          f"{{ {buf}[p * {c} + k] = expf({buf}[p * {c} + k] - mx); "
          f"s += {buf}[p * {c} + k]; }}")
        w(f"for (int k = 0; k < {c}; ++k) {buf}[p * {c} + k] /= s;")
        w.close()

    def emit_dense(self, layer: Dense, in_shape, src, dst) -> None:
        w = self.w
        d_in, d_out = layer.weights.shape
        wname = self.const_array(f"w{self.uid()}", layer.weights)
        bname = self.const_array(f"b{self.uid()}", layer.bias)
        act = layer.activation if layer.activation != "softmax" else None
        w(f"/* Dense {layer.name}: {d_in}->{d_out} */")
        w.open(f"for (int k = 0; k < {d_out}; ++k)")
        w(f"float acc = {bname}[k];")
        w(f"for (int z = 0; z < {d_in}; ++z) "
          f"acc += {src}[z] * {wname}[z * {d_out} + k];")
        w(f"{dst}[k] = {self.act_scalar('acc', act, layer.alpha)};")
        w.close()
        if layer.activation == "softmax":
            self.emit_softmax((1, 1, d_out), dst)

    # -- driver ---------------------------------------------------------------

    def generate(self) -> str:
        g, opts = self.g, self.opts
        shapes = g.shapes()
        body_layers = [
            (l, shapes[i - 1] if i > 0 else g.input_shape, shapes[i])
            for i, l in enumerate(g.layers)
            if not isinstance(l, (Input, Dropout, Flatten))
        ]
        # buffer per producing layer; last one writes to `out`
        src = "x"
        self.w.open(f"void {opts.func_name}(const float *restrict x, "
                    f"float *restrict out)")
        for idx, (layer, ish, osh) in enumerate(body_layers):
            last = idx == len(body_layers) - 1
            dst = "out" if last else self.buffer(
                f"buf{self.uid()}", int(np.prod(osh)))
            if isinstance(layer, Conv2D):
                self.emit_conv(layer, ish, src, dst)
            elif isinstance(layer, MaxPool):
                self.emit_maxpool(layer, ish, src, dst)
            elif isinstance(layer, ReLU):
                self.emit_elementwise(ish, src, dst, "relu", 0.0)
            elif isinstance(layer, LeakyReLU):
                self.emit_elementwise(ish, src, dst, "leaky_relu", layer.alpha)
            elif isinstance(layer, Softmax):
                if src != dst:
                    self.w(f"for (int z = 0; z < {int(np.prod(ish))}; ++z) "
                           f"{dst}[z] = {src}[z];")
                self.emit_softmax(ish, dst)
            elif isinstance(layer, BatchNorm):
                self.emit_batchnorm(layer, ish, src, dst)
            elif isinstance(layer, Dense):
                self.emit_dense(layer, ish, src, dst)
            else:  # pragma: no cover
                raise TypeError(f"cgen: unhandled layer {type(layer).__name__}")
            src = dst
        self.w.close()

        if opts.emit_batch:
            # serving entry point: N images through the single-image
            # function (the static scratch buffers make it sequential)
            in_n = int(np.prod(g.input_shape))
            out_n = int(np.prod(g.output_shape))
            self.w("")
            self.w.open(f"void {opts.batch_func_name}("
                        f"const float *restrict x, float *restrict out, "
                        f"int n)")
            self.w(f"for (int b = 0; b < n; ++b) "
                   f"{opts.func_name}(x + (long)b * {in_n}, "
                   f"out + (long)b * {out_n});")
            self.w.close()

        hdr = _W()
        hdr("/* Generated by NNCG-JAX (repro of Urbann et al., 2020).")
        hdr(f" * net: in {g.input_shape} -> out {g.output_shape}, "
            f"{g.param_count()} params, simd={opts.simd} */")
        hdr("#include <math.h>")
        if opts.isa is not None:
            hdr(f"#include <{opts.isa.header}>")
        hdr("")
        return hdr.text() + self.decls.text() + "\n" + self.w.text()


def generate_c(graph: CNNGraph, opts: Optional[CodegenOptions] = None) -> str:
    """Generate the single ANSI C file for a trained CNN."""
    return CGenerator(graph, opts or CodegenOptions()).generate()
