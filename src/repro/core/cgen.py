"""NNCG — the ANSI C code generator (paper §II).

Generates, from a trained :class:`CNNGraph` (a DAG — residual Adds,
Concats, depthwise convs and pooling all supported), one plain C file
exposing:

    void <func>_ws(const float *x, float *out, float *workspace);
    void <func>(const float *x, float *out);          /* static arena */
    void <func>_batch(const float *x, float *out, int n);
    long <func>_workspace_floats(void);

implementing the four design principles:

* **P1 unroll levels** — per-layer ``level``: 0 = all loops unrolled
  (straight-line code), 1 = keep the outermost spatial loop, 2 = keep both
  spatial loops, ``None`` = no unrolling (plain loop nest).  Matches the
  paper: "At level 0 all loops are unrolled. Level 1 does not unroll the
  outer most loop and so forth."
* **P2 conditional moves** — activations and pooling emit the C ternary
  operator, never an ``if`` block.
* **P3 constants** — with any unrolling the trained weights are printed
  as literals into the code line; without unrolling they are emitted as
  ``static const`` arrays.  Zero padding taps are *elided entirely* at
  level 0 (a static-knowledge win no generic library has).
* **P4 SIMD structure** — three modes: ``generic`` (paper's scalar
  baseline, output-channel loop outside the tap loops), ``structured``
  (channel loop innermost over contiguous memory → auto-vectorizable),
  and ``sse`` (explicit SSSE3/SSE intrinsics over groups of 4 output
  channels, the paper's shipped mode).

**Memory**: instead of one never-reused ``static float`` buffer per
layer, a liveness-based **arena planner** (:func:`plan_arena`) computes
tensor lifetimes over the topological order and packs all intermediate
buffers — including zero-padding scratch — into one workspace via
interval-interference best-fit.  ``<func>_ws`` takes the workspace from
the caller, making the generated code **reentrant** (thread-parallel
batch serving); ``<func>`` binds the planned static arena for the
paper's single-image embedded deployment.

The emitted file is strict ANSI C89 (declarations first, no ``//``
comments, ``restrict`` behind a feature macro), so ``gcc -std=c89
-Wall -Wextra -Werror -pedantic-errors`` accepts it — the paper's
"plain C compilable by any ANSI compiler" claim, enforced in CI.  The
only dependencies are ``math.h`` (softmax) and, in ``sse``/``avx``
mode, the intrinsics header — exactly the paper's dependency set.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from .graph import (
    Add,
    AvgPool,
    BatchNorm,
    CNNGraph,
    Concat,
    Conv2D,
    Dense,
    DepthwiseConv2D,
    Dropout,
    Flatten,
    GlobalAvgPool,
    Input,
    LeakyReLU,
    MaxPool,
    ReLU,
    Softmax,
    pool_window_counts,
)

Level = Optional[int]  # 0 | 1 | 2 | None (no unroll)

# bump whenever the emitted C changes for the same (graph, options) —
# cached artifacts measured on older generated code must not be reused
CODEGEN_VERSION = 5

# the single source of truth for the unroll/icache emission budget
# (both CodegenOptions.term_budget and choose_levels read it)
TERM_BUDGET_DEFAULT = 60_000

# layers that emit no code: Input is the function argument, Dropout is
# identity at inference, Flatten is a no-op on flat NHWC memory
IDENTITY_LAYERS = (Input, Dropout, Flatten)


@dataclass(frozen=True)
class ISA:
    """Vector instruction-set descriptor (P4). The paper ships SSSE3 and
    names AVX as future work — ``avx`` implements it (8-wide + FMA)."""

    name: str
    width: int
    reg: str
    header: str
    cc_flags: tuple
    prefix: str

    def load(self, ptr: str) -> str:
        return f"{self.prefix}_loadu_ps(&{ptr})"

    def store(self, ptr: str, reg: str) -> str:
        return f"{self.prefix}_storeu_ps(&{ptr}, {reg});"

    def set1(self, x: str) -> str:
        return f"{self.prefix}_set1_ps({x})"

    def zero(self) -> str:
        return f"{self.prefix}_setzero_ps()"

    def add(self, a: str, b: str) -> str:
        return f"{self.prefix}_add_ps({a}, {b})"

    def mul(self, a: str, b: str) -> str:
        return f"{self.prefix}_mul_ps({a}, {b})"

    def vmax(self, a: str, b: str) -> str:
        return f"{self.prefix}_max_ps({a}, {b})"

    def fmadd(self, a: str, b: str, c: str) -> str:
        """a*b + c."""
        if self.name == "avx":
            return f"{self.prefix}_fmadd_ps({a}, {b}, {c})"
        return self.add(c, self.mul(a, b))

    def set_lits(self, vals) -> str:
        lits = ", ".join(_flit(v) for v in reversed(list(vals)))
        return f"{self.prefix}_set_ps({lits})"


SSE = ISA(name="sse", width=4, reg="__m128", header="emmintrin.h",
          cc_flags=("-mssse3",), prefix="_mm")
AVX = ISA(name="avx", width=8, reg="__m256", header="immintrin.h",
          cc_flags=("-mavx2", "-mfma"), prefix="_mm256")
ISAS = {"sse": SSE, "avx": AVX}


@dataclass
class CodegenOptions:
    simd: str = "sse"            # 'generic' | 'structured' | 'sse' | 'avx'
    unroll: Union[Level, Dict[str, Level]] = 0
    func_name: str = "nncg_net"
    term_budget: int = TERM_BUDGET_DEFAULT
    # max emitted FMA terms per layer before the level is demoted
    # (icache trade-off)
    emit_batch: bool = True      # also emit `<func>_batch(x, out, n)` —
                                 # a loop-over-images serving entry point

    @property
    def isa(self) -> Optional[ISA]:
        return ISAS.get(self.simd)

    @property
    def batch_func_name(self) -> str:
        return self.func_name + "_batch"

    @property
    def batch_ws_func_name(self) -> str:
        """Reentrant batch entry: N images through one foreign call,
        caller-provided workspace — the serving worker-pool hot path."""
        return self.func_name + "_batch_ws"

    @property
    def ws_func_name(self) -> str:
        """The reentrant entry point taking a caller-provided workspace."""
        return self.func_name + "_ws"

    @property
    def ws_size_func_name(self) -> str:
        return self.func_name + "_workspace_floats"

    @property
    def ws_bytes_func_name(self) -> str:
        """Workspace size entry of the quantized build (int8 arena)."""
        return self.func_name + "_workspace_bytes"

    def level_for(self, layer_name: str) -> Level:
        if isinstance(self.unroll, dict):
            return self.unroll.get(layer_name, None)
        return self.unroll


def _flit(v: float) -> str:
    """Format a float32 as a C literal (paper P3).

    ``unique=True`` guarantees the shortest decimal that parses back to
    the exact same float32 bit pattern (property-tested)."""
    s = np.format_float_scientific(np.float32(v), unique=True, trim="0")
    return f"{s}f"


# most-negative finite float32 — the padding fill for max pooling (C89
# has no INFINITY); a window always covers >=1 valid tap, so the fill
# can never be the result
_NEG_FLT_MAX = _flit(np.finfo(np.float32).min)


def _cfor(var: str, bound, body: str, start: int = 0, step: int = 1) -> str:
    """A one-line C89 counted loop: the index is declared in its own
    block so the statement is legal anywhere."""
    inc = f"++{var}" if step == 1 else f"{var} += {step}"
    return (f"{{ int {var}; for ({var} = {start}; {var} < {bound}; {inc}) "
            f"{body} }}")


class _W:
    """Tiny indented writer."""

    def __init__(self) -> None:
        self.lines: List[str] = []
        self._ind = 0

    def __call__(self, line: str = "") -> None:
        self.lines.append("    " * self._ind + line if line else "")

    def open(self, line: str) -> None:
        self(line + " {" if line else "{")
        self._ind += 1

    def close(self) -> None:
        self._ind -= 1
        self("}")

    def text(self) -> str:
        return "\n".join(self.lines) + "\n"


def estimate_terms(layer, in_shape, level: Level) -> int:
    """Emitted multiply-add terms for a conv/pool at an unroll level —
    the code-size side of the paper's unroll/icache trade-off."""
    if isinstance(layer, Conv2D):
        oh, ow, co = layer.out_shape(in_shape)
        taps = layer.kh * layer.kw * layer.c_in
        per_out = taps
        n_out = {0: oh * ow * co, 1: ow * co, 2: co}.get(level, 0)
        return n_out * per_out if level is not None else taps
    if isinstance(layer, MaxPool):
        oh, ow, c = layer.out_shape(in_shape)
        taps = layer.size[0] * layer.size[1]
        n_out = {0: oh * ow * c, 1: ow * c, 2: c}.get(level, 0)
        return n_out * taps if level is not None else taps
    return 0


def effective_level(layer, in_shape, opts: "CodegenOptions") -> Level:
    """The unroll level actually emitted: the configured level, demoted
    until the emitted-term count fits the budget (icache trade-off, P1).
    The arena planner calls this too, so scratch planning and emission
    can never disagree."""
    level = opts.level_for(layer.name)
    while level is not None and \
            estimate_terms(layer, in_shape, level) > opts.term_budget:
        level = {0: 1, 1: 2, 2: None}[level]
    return level


def enumerate_variants(layer, in_shape, term_cap: int = 200_000) -> List[Level]:
    """Candidate unroll levels for one layer, deepest (level 0) first.

    This is the variant space the paper benchmarks per layer ("we
    independently benchmark every code version and select the one with
    the best runtime performance").  Levels whose emitted-term count
    exceeds ``term_cap`` are dropped — they would blow the icache (and
    the compile time) before they could win; ``None`` (rolled loops) is
    always feasible.  Returns ``[]`` for layers with no codegen variants.
    """
    if not isinstance(layer, (Conv2D, MaxPool)):
        return []
    return [lvl for lvl in (0, 1, 2, None)
            if lvl is None or estimate_terms(layer, in_shape, lvl) <= term_cap]


def choose_levels(graph: CNNGraph,
                  budget: int = TERM_BUDGET_DEFAULT) -> Dict[str, Level]:
    """Pick, per layer, the deepest unroll level within the term budget.

    This is the static analogue of the paper's per-layer variant
    benchmarking — the :mod:`repro.engine.autotune` tuner explores the
    same :func:`enumerate_variants` space dynamically and can override
    any choice made here.  Walks the DAG via edges, so branch layers get
    their true input shapes.
    """
    levels: Dict[str, Level] = {}
    smap = graph.shape_map()
    for layer in graph.layers:
        ish = smap[layer.inputs[0]] if layer.inputs else None
        for lvl in enumerate_variants(layer, ish, term_cap=budget):
            levels[layer.name] = lvl
            break
    return levels


# ---------------------------------------------------------------------------
# arena planning (liveness over the topological order)
# ---------------------------------------------------------------------------


@dataclass
class ArenaInterval:
    """One planned allocation: a value live over ``[start, end]`` layer
    steps, placed at ``offset`` floats into the arena."""

    value: str
    start: int
    end: int
    size: int
    offset: int = -1


@dataclass
class ArenaPlan:
    """The packed workspace: element offsets for every intermediate
    tensor (and padding scratch), sized by interval interference.

    Elements are float32 for the float path and int8 for the quantized
    path (``elem_bytes`` 4 vs 1) — ``total_floats`` keeps its historic
    name but counts *elements*."""

    total_floats: int
    offsets: Dict[str, int] = field(default_factory=dict)
    intervals: List[ArenaInterval] = field(default_factory=list)
    per_layer_live: Dict[str, int] = field(default_factory=dict)
    buffer_sum_floats: int = 0  # what one-static-buffer-per-tensor costs
    elem_bytes: int = 4

    @property
    def total_bytes(self) -> int:
        return self.total_floats * self.elem_bytes

    @property
    def buffer_sum_bytes(self) -> int:
        return self.buffer_sum_floats * self.elem_bytes

    @property
    def peak_live_floats(self) -> int:
        return max(self.per_layer_live.values(), default=0)


def _value_map(graph: CNNGraph, quantized: bool = False) -> Dict[str, str]:
    """Layer name -> the value (buffer) holding its output. Identity
    layers alias their producer; Input aliases the ``x`` argument — in
    quantized mode the input is itself quantized into an arena buffer
    (``xq``), so Input *defines* a value."""
    val: Dict[str, str] = {}
    for l in graph.layers:
        if isinstance(l, Input):
            val[l.name] = "xq" if quantized else "x"
        elif isinstance(l, (Dropout, Flatten)):
            val[l.name] = val[l.inputs[0]]
        else:
            val[l.name] = l.name
    return val


def _pad_scratch_elems(layer, in_shape, opts: CodegenOptions,
                       elide_static: bool = True) -> int:
    """Elements of padding scratch the emitter will request for this
    layer (0 when padding is statically elided or absent).

    ``elide_static=False`` is the quantized planner's view: the int8
    emitters are rolled (no unroll levels), so padding scratch is
    always materialized."""
    if not isinstance(layer, (Conv2D, DepthwiseConv2D, MaxPool, AvgPool)):
        return 0
    pads = layer.pad_amounts(in_shape)
    if not any(pads):
        return 0
    if elide_static and isinstance(layer, (Conv2D, MaxPool)) and \
            effective_level(layer, in_shape, opts) == 0:
        return 0  # level 0 elides out-of-bounds taps statically
    h, w, c = in_shape
    pt, pb, pl, pr = pads
    return (h + pt + pb) * (w + pl + pr) * c


def _qconv_use_patch(layer, opts: CodegenOptions) -> bool:
    """Whether the quantized conv emitter uses the im2row int16 patch:
    the window's taps are widened into a stack-local ``short`` array
    once per output position (amortized over all output channels), so
    every channel runs one flat, tail-free ``_mm_madd_epi16`` dot
    product against int16-widened weights."""
    if not isinstance(layer, Conv2D) or opts.isa is None:
        return False
    taps = layer.kh * layer.kw * layer.c_in
    return layer.kh * layer.kw > 1 and taps >= 16


def plan_arena(graph: CNNGraph,
               opts: Optional[CodegenOptions] = None,
               *, quantized: bool = False) -> ArenaPlan:
    """Liveness-planned packing of every intermediate tensor.

    A value is live from the step of its defining layer to the step of
    its last consumer (interval interference over the topological
    order); padding scratch is live only during its own layer.  The
    network input (``x``) and output (``out``) are caller memory and
    never enter the arena — except in quantized mode, where the int8
    code of the input (``xq``) is itself an arena value.  Placement is
    first-fit at the lowest offset not overlapping any time-overlapping
    interval — for chains this degenerates to ping-pong double
    buffering, for DAGs the skip edges extend lifetimes exactly as long
    as needed.  Quantized plans are in int8 elements (1 byte each), the
    ~4x memory win the int8 path exists for.
    """
    opts = opts or CodegenOptions()
    smap = graph.shape_map()
    val = _value_map(graph, quantized)
    out_value = val[graph.sink.name]

    defs: Dict[str, int] = {}
    last: Dict[str, int] = {}
    sizes: Dict[str, int] = {}
    ivals: List[ArenaInterval] = []
    for i, layer in enumerate(graph.layers):
        if quantized and isinstance(layer, Input):
            defs["xq"] = i
            sizes["xq"] = int(np.prod(smap[layer.name]))
        elif not isinstance(layer, IDENTITY_LAYERS):
            v = val[layer.name]
            if v == layer.name:  # defines a fresh value
                defs[v] = i
                sizes[v] = int(np.prod(smap[layer.name]))
            scratch = _pad_scratch_elems(layer, smap[layer.inputs[0]],
                                         opts, elide_static=not quantized)
            if scratch:
                ivals.append(ArenaInterval(
                    value=layer.name + "__pad", start=i, end=i,
                    size=scratch))
        for src in layer.inputs:
            sv = val[src]
            if sv != "x":
                last[sv] = i
    for v, d in defs.items():
        if v == out_value:
            continue  # written straight to the caller's `out`
        ivals.append(ArenaInterval(value=v, start=d,
                                   end=last.get(v, d), size=sizes[v]))

    # first-fit placement over interfering intervals
    ivals.sort(key=lambda iv: (iv.start, -iv.size, iv.value))
    placed: List[ArenaInterval] = []
    for iv in ivals:
        overlap = [p for p in placed
                   if not (iv.end < p.start or p.end < iv.start)]
        for cand in sorted({0} | {p.offset + p.size for p in overlap}):
            if all(cand + iv.size <= p.offset or p.offset + p.size <= cand
                   for p in overlap):
                iv.offset = cand
                break
        placed.append(iv)

    total = max((iv.offset + iv.size for iv in placed), default=0)
    per_layer_live = {
        layer.name: sum(iv.size for iv in placed
                        if iv.start <= i <= iv.end)
        for i, layer in enumerate(graph.layers)
    }
    return ArenaPlan(
        total_floats=total,
        offsets={iv.value: iv.offset for iv in placed},
        intervals=placed,
        per_layer_live=per_layer_live,
        buffer_sum_floats=sum(iv.size for iv in placed),
        elem_bytes=1 if quantized else 4,
    )


# ---------------------------------------------------------------------------
# code generation
# ---------------------------------------------------------------------------


def _cname(value: str) -> str:
    """Sanitize a value name into a C identifier."""
    return "t_" + re.sub(r"[^0-9A-Za-z_]", "_", value)


class CGenerator:
    def __init__(self, graph: CNNGraph, opts: CodegenOptions):
        self.g = graph
        self.opts = opts
        self.w = _W()
        self.decls = _W()
        self._uid = 0
        self.plan: Optional[ArenaPlan] = None  # filled by generate()

    # -- helpers ------------------------------------------------------------

    def uid(self) -> int:
        self._uid += 1
        return self._uid

    def const_array(self, name: str, arr: np.ndarray) -> str:
        vals = ", ".join(_flit(v) for v in np.asarray(arr, np.float32).ravel())
        self.decls(f"static const float {name}[{arr.size}] = {{{vals}}};")
        return name

    def floop(self, var: str, bound, step: int = 1) -> None:
        """Open a counted loop with a C89-scoped index; pair with
        :meth:`fclose`."""
        w = self.w
        w.open("")
        w(f"int {var};")
        inc = f"++{var}" if step == 1 else f"{var} += {step}"
        w.open(f"for ({var} = 0; {var} < {bound}; {inc})")

    def fclose(self, n: int = 1) -> None:
        for _ in range(n):
            self.w.close()
            self.w.close()

    # -- activation epilogues (P2: ternary, never a branch) ------------------

    def act_scalar(self, expr: str, act: Optional[str], alpha: float) -> str:
        if act == "relu":
            return f"(({expr}) > 0.0f ? ({expr}) : 0.0f)"
        if act == "leaky_relu":
            return f"(({expr}) > 0.0f ? ({expr}) : {_flit(alpha)} * ({expr}))"
        return expr

    def act_sse(self, reg: str, act: Optional[str], alpha: float) -> List[str]:
        isa = self.opts.isa
        if act == "relu":
            return [f"{reg} = {isa.vmax(reg, isa.zero())};"]
        if act == "leaky_relu":
            # max(x, a*x) == leaky_relu(x) for 0 < a < 1 — branch-free
            return [f"{reg} = {isa.vmax(reg, isa.mul(reg, isa.set1(_flit(alpha))))};"]
        return []

    # -- padding ------------------------------------------------------------

    def emit_padded_copy(self, src: str, in_shape, pads, buf: str,
                         fill: str = "0.0f"
                         ) -> Tuple[str, Tuple[int, int, int]]:
        """Materialize a padded copy (paper Eq. 1) into the planned
        arena scratch ``buf``, for the looped modes where tap bounds are
        not static.  ``fill`` is the pad value — zero for conv/avg-pool
        sums, ``-FLT_MAX`` for max pooling."""
        h, wdt, c = in_shape
        pt, pb, pl, pr = pads
        ph, pw = h + pt + pb, wdt + pl + pr
        w = self.w
        w(f"/* pad {src} with {fill}: ({h}x{wdt}x{c}) -> "
          f"({ph}x{pw}x{c}) */")
        w(_cfor("z", ph * pw * c, f"{buf}[z] = {fill};"))
        self.floop("i", h)
        w(_cfor("z", wdt * c,
                f"{buf}[((i + {pt}) * {pw} + {pl}) * {c} + z] = "
                f"{src}[i * {wdt * c} + z];"))
        self.fclose()
        return buf, (ph, pw, c)

    # -- conv ---------------------------------------------------------------

    def emit_conv(self, layer: Conv2D, in_shape, src: str, dst: str,
                  pad_buf: Optional[str] = None) -> None:
        opts, w = self.opts, self.w
        level = effective_level(layer, in_shape, opts)
        oh, ow, co = layer.out_shape(in_shape)
        sh, sw = layer.strides
        pads = layer.pad_amounts(in_shape)
        kh, kw_, ci = layer.kh, layer.kw, layer.c_in
        W_ = layer.weights  # HWIO
        B_ = layer.bias

        w(f"/* Conv2D {layer.name}: {in_shape}->{(oh, ow, co)} "
          f"k={kh}x{kw_} s={sh}x{sw} pad={layer.padding} "
          f"act={layer.activation} level={level} simd={opts.simd} */")

        use_pad_buf = any(pads) and level != 0
        if use_pad_buf:
            assert pad_buf is not None, f"{layer.name}: unplanned pad scratch"
            src, in_shape = self.emit_padded_copy(src, in_shape, pads, pad_buf)
            pads = (0, 0, 0, 0)
        h, wdt, _ = in_shape
        pt, _pb, pl, _pr = pads

        literals = level is not None
        wname = bname = None
        if not literals:
            wname = self.const_array(f"w{self.uid()}", W_)
            bname = self.const_array(f"b{self.uid()}", B_)

        def x_index(i, j, n, m, o) -> str:
            """Index into src for output (i,j) tap (n,m,o); i/j may be C exprs."""
            if isinstance(i, int):
                row = i * sh + n - pt
            else:
                row = f"({i} * {sh} + {n - pt})"
            if isinstance(j, int):
                col = j * sw + m - pl
            else:
                col = f"({j} * {sw} + {m - pl})"
            if isinstance(row, int) and isinstance(col, int):
                return str((row * wdt + col) * ci + o)
            return f"(({row}) * {wdt} + ({col})) * {ci} + {o}"

        def out_index(i, j, k) -> str:
            if isinstance(i, int) and isinstance(j, int) and isinstance(k, int):
                return str((i * ow + j) * co + k)
            ke = str(k)
            return f"(({i}) * {ow} + ({j})) * {co} + {ke}"

        def in_bounds(i, j, n, m) -> bool:
            """Static OOB elision (only callable when i and j are ints)."""
            r, c = i * sh + n - pt, j * sw + m - pl
            return 0 <= r < h and 0 <= c < wdt

        def emit_body(i, j) -> None:
            static_ij = isinstance(i, int) and isinstance(j, int)
            if opts.isa is not None:
                self._conv_body_sse(layer, W_, B_, wname, bname, literals,
                                    i, j, static_ij, x_index, out_index,
                                    in_bounds, dst, src)
            else:
                self._conv_body_generic(layer, W_, B_, wname, bname, literals,
                                        i, j, static_ij, x_index, out_index,
                                        in_bounds, dst, src)

        if level == 0:
            for i in range(oh):
                for j in range(ow):
                    emit_body(i, j)
        elif level == 1:
            self.floop("i", oh)
            for j in range(ow):
                emit_body("i", j)
            self.fclose()
        elif level == 2:
            self.floop("i", oh)
            self.floop("j", ow)
            emit_body("i", "j")
            self.fclose(2)
        else:
            self.floop("i", oh)
            self.floop("j", ow)
            self._conv_loops_rolled(layer, wname, bname, in_shape,
                                    (oh, ow, co), dst, src, pads)
            self.fclose(2)

        if layer.activation == "softmax":
            self.emit_softmax((oh, ow, co), dst)

    # rolled inner loops (level=None): weights from const arrays
    def _conv_loops_rolled(self, layer, wname, bname, in_shape, out_shape,
                           dst, src, pads):
        w = self.w
        h, wdt, ci = in_shape
        oh, ow, co = out_shape
        kh, kw_ = layer.kh, layer.kw
        sh, sw = layer.strides
        pt, _, pl, _ = pads
        assert pt == 0 and pl == 0, "rolled mode uses padded buffers"
        act = layer.activation if layer.activation != "softmax" else None
        if self.opts.isa is not None:
            isa = self.opts.isa
            co4 = co - co % isa.width
            if co4:
                self.floop("k", co4, step=isa.width)
                w(f"{isa.reg} acc = {isa.load(f'{bname}[k]')};")
                self.floop("n", kh)
                self.floop("m", kw_)
                self.floop("o", ci)
                xv = f"{src}[((i * {sh} + n) * {wdt} + (j * {sw} + m)) * {ci} + o]"
                wv = f"{wname}[((n * {kw_} + m) * {ci} + o) * {co} + k]"
                w(f"acc = {isa.fmadd(isa.set1(xv), isa.load(wv), 'acc')};")
                self.fclose(3)
                for ln in self.act_sse("acc", act, layer.alpha):
                    w(ln)
                w(isa.store(f"{dst}[(i * {ow} + j) * {co} + k]", "acc"))
                self.fclose()
            ks = range(co4, co)
        elif self.opts.simd == "structured":
            # channel loop innermost over contiguous memory -> auto-vec
            w.open("")
            w(f"float acc[{co}];")
            w(_cfor("k", co, f"acc[k] = {bname}[k];"))
            self.floop("n", kh)
            self.floop("m", kw_)
            self.floop("o", ci)
            w(f"const float xv = {src}[((i * {sh} + n) * {wdt} + "
              f"(j * {sw} + m)) * {ci} + o];")
            w(_cfor("k", co,
                    f"acc[k] += xv * "
                    f"{wname}[((n * {kw_} + m) * {ci} + o) * {co} + k];"))
            self.fclose(3)
            w(_cfor("k", co,
                    f"{dst}[(i * {ow} + j) * {co} + k] = "
                    f"{self.act_scalar('acc[k]', act, layer.alpha)};"))
            w.close()
            ks = ()
        else:
            self.floop("k", co)
            w(f"float acc = {bname}[k];")
            self.floop("n", kh)
            self.floop("m", kw_)
            self.floop("o", ci)
            w(f"acc += {wname}[((n * {kw_} + m) * {ci} + o) * {co} + k] * "
              f"{src}[((i * {sh} + n) * {wdt} + (j * {sw} + m)) * {ci} + o];")
            self.fclose(3)
            w(f"{dst}[(i * {ow} + j) * {co} + k] = "
              f"{self.act_scalar('acc', act, layer.alpha)};")
            self.fclose()
            ks = ()
        # scalar tail for sse mode
        for k in ks:
            w.open("")
            w(f"float acc = {bname}[{k}];")
            w(_cfor("n", kh, _cfor("m", kw_, _cfor(
                "o", ci,
                f"acc += {wname}[((n * {kw_} + m) * {ci} + o) * {co} + {k}] * "
                f"{src}[((i * {sh} + n) * {wdt} + (j * {sw} + m)) * {ci} + o];"
            ))))
            w(f"{dst}[(i * {ow} + j) * {co} + {k}] = "
              f"{self.act_scalar('acc', act, layer.alpha)};")
            w.close()

    # unrolled bodies --------------------------------------------------------

    def _taps(self, layer, i, j, static_ij, in_bounds):
        for n in range(layer.kh):
            for m in range(layer.kw):
                if static_ij and not in_bounds(i, j, n, m):
                    continue  # P3: zero tap elided entirely
                for o in range(layer.c_in):
                    yield n, m, o

    def _conv_body_generic(self, layer, W_, B_, wname, bname, literals,
                           i, j, static_ij, x_index, out_index, in_bounds,
                           dst, src):
        w = self.w
        co = layer.c_out
        act = layer.activation if layer.activation != "softmax" else None
        w.open("")  # scope block
        for k in range(co):
            bias = _flit(B_[k]) if literals else f"{bname}[{k}]"
            w(f"float a{k} = {bias};")
        for n, m, o in self._taps(layer, i, j, static_ij, in_bounds):
            xv = f"{src}[{x_index(i, j, n, m, o)}]"
            for k in range(co):
                wv = (_flit(W_[n, m, o, k]) if literals
                      else f"{wname}[{((n * layer.kw + m) * layer.c_in + o) * co + k}]")
                w(f"a{k} += {xv} * {wv};")
        for k in range(co):
            w(f"{dst}[{out_index(i, j, k)}] = "
              f"{self.act_scalar(f'a{k}', act, layer.alpha)};")
        w.close()

    def _conv_body_sse(self, layer, W_, B_, wname, bname, literals,
                       i, j, static_ij, x_index, out_index, in_bounds,
                       dst, src):
        w = self.w
        isa = self.opts.isa
        vw = isa.width
        co = layer.c_out
        co4 = co - co % vw
        act = layer.activation if layer.activation != "softmax" else None
        w.open("")
        for kg in range(0, co4, vw):
            if literals:
                w(f"{isa.reg} v{kg} = "
                  f"{isa.set_lits(B_[kg:kg + vw])};")
            else:
                w(f"{isa.reg} v{kg} = {isa.load(f'{bname}[{kg}]')};")
        for n, m, o in self._taps(layer, i, j, static_ij, in_bounds):
            xv = f"{src}[{x_index(i, j, n, m, o)}]"
            w(f"{{ const {isa.reg} xb = {isa.set1(xv)};")
            for kg in range(0, co4, vw):
                if literals:
                    wreg = isa.set_lits(W_[n, m, o, kg:kg + vw])
                else:
                    off = ((n * layer.kw + m) * layer.c_in + o) * co + kg
                    wreg = isa.load(f"{wname}[{off}]")
                w(f"  v{kg} = {isa.fmadd('xb', wreg, f'v{kg}')};")
            w("}")
        for kg in range(0, co4, vw):
            for ln in self.act_sse(f"v{kg}", act, layer.alpha):
                w(ln)
            w(isa.store(f"{dst}[{out_index(i, j, kg)}]", f"v{kg}"))
        # scalar tail, each channel in its own block (C89: decls first)
        for k in range(co4, co):
            bias = _flit(B_[k]) if literals else f"{bname}[{k}]"
            w.open("")
            w(f"float t{k} = {bias};")
            for n, m, o in self._taps(layer, i, j, static_ij, in_bounds):
                xv = f"{src}[{x_index(i, j, n, m, o)}]"
                wv = (_flit(W_[n, m, o, k]) if literals
                      else f"{wname}[{((n * layer.kw + m) * layer.c_in + o) * co + k}]")
                w(f"t{k} += {xv} * {wv};")
            w(f"{dst}[{out_index(i, j, k)}] = "
              f"{self.act_scalar(f't{k}', act, layer.alpha)};")
            w.close()
        w.close()

    # -- depthwise conv ------------------------------------------------------

    def emit_depthwise(self, layer: DepthwiseConv2D, in_shape, src: str,
                       dst: str, pad_buf: Optional[str] = None) -> None:
        w = self.w
        oh, ow, co = layer.out_shape(in_shape)
        pads = layer.pad_amounts(in_shape)
        kh, kw_, ci, mult = layer.kh, layer.kw, layer.c_in, layer.multiplier
        sh, sw = layer.strides
        w(f"/* DepthwiseConv2D {layer.name}: {in_shape}->{(oh, ow, co)} "
          f"k={kh}x{kw_} s={sh}x{sw} mult={mult} pad={layer.padding} "
          f"act={layer.activation} */")
        if any(pads):
            assert pad_buf is not None, f"{layer.name}: unplanned pad scratch"
            src, in_shape = self.emit_padded_copy(src, in_shape, pads, pad_buf)
        h, wdt, _ = in_shape
        wname = self.const_array(f"w{self.uid()}", layer.weights)
        bname = self.const_array(f"b{self.uid()}", layer.bias)
        act = layer.activation if layer.activation != "softmax" else None
        self.floop("i", oh)
        self.floop("j", ow)
        self.floop("c", ci)
        for m_ in range(mult):
            w.open("")
            w(f"float acc = {bname}[c * {mult} + {m_}];")
            w(_cfor("n", kh, _cfor(
                "m", kw_,
                f"acc += {src}[((i * {sh} + n) * {wdt} + "
                f"(j * {sw} + m)) * {ci} + c] * "
                f"{wname}[((n * {kw_} + m) * {ci} + c) * {mult} + {m_}];")))
            w(f"{dst}[(i * {ow} + j) * {co} + c * {mult} + {m_}] = "
              f"{self.act_scalar('acc', act, layer.alpha)};")
            w.close()
        self.fclose(3)
        if layer.activation == "softmax":
            self.emit_softmax((oh, ow, co), dst)

    # -- pooling / merge / elementwise / softmax / dense ---------------------

    def emit_maxpool(self, layer: MaxPool, in_shape, src: str, dst: str,
                     pad_buf: Optional[str] = None) -> None:
        w, opts = self.w, self.opts
        oh, ow, co = layer.out_shape(in_shape)
        kh, kw_ = layer.size
        sh, sw = layer.strides
        pads = layer.pad_amounts(in_shape)
        level = effective_level(layer, in_shape, opts)
        w(f"/* MaxPool {layer.name}: {in_shape}->{(oh, ow, co)} "
          f"k={kh}x{kw_} s={sh}x{sw} pad={layer.padding} level={level} */")

        # like conv: level 0 elides out-of-bounds taps statically; any
        # looped level materializes a -FLT_MAX-padded copy (the fill
        # never wins — every window covers >=1 valid tap)
        if any(pads) and level != 0:
            assert pad_buf is not None, f"{layer.name}: unplanned pad scratch"
            src, in_shape = self.emit_padded_copy(src, in_shape, pads,
                                                  pad_buf, _NEG_FLT_MAX)
            pads = (0, 0, 0, 0)
        h, wdt, c = in_shape
        pt, _pb, pl, _pr = pads

        def in_bounds(i, j, n, m) -> bool:
            r, cc = i * sh + n - pt, j * sw + m - pl
            return 0 <= r < h and 0 <= cc < wdt

        def taps(i, j):
            static_ij = isinstance(i, int) and isinstance(j, int)
            for n in range(kh):
                for m in range(kw_):
                    if static_ij and not in_bounds(i, j, n, m):
                        continue  # P3: padding tap elided entirely
                    yield n, m

        def body(i, j):
            isa = opts.isa
            if isa is not None and c % isa.width == 0:
                for kg in range(0, c, isa.width):
                    w.open("")
                    first = True
                    for n, m in taps(i, j):
                        idx = x_idx(i, j, n, m, kg)
                        if first:
                            w(f"{isa.reg} p = "
                              f"{isa.load(f'{src}[{idx}]')};")
                            first = False
                        else:
                            w(f"p = {isa.vmax('p', isa.load(f'{src}[{idx}]'))};")
                    w(isa.store(f"{dst}[{o_idx(i, j, kg)}]", "p"))
                    w.close()
            else:
                for k in range(c):
                    w.open("")
                    first = True
                    for n, m in taps(i, j):
                        idx = x_idx(i, j, n, m, k)
                        if first:
                            w(f"float q = {src}[{idx}];")
                            first = False
                        else:
                            # P2: ternary, not an if
                            w(f"q = {src}[{idx}] > q ? "
                              f"{src}[{idx}] : q;")
                    w(f"{dst}[{o_idx(i, j, k)}] = q;")
                    w.close()

        def x_idx(i, j, n, m, k):
            if isinstance(i, int) and isinstance(j, int):
                return str(((i * sh + n - pt) * wdt + (j * sw + m - pl))
                           * c + k)
            return (f"(({i} * {sh} + {n - pt}) * {wdt} + "
                    f"({j} * {sw} + {m - pl})) * {c} + {k}")

        def o_idx(i, j, k):
            if isinstance(i, int) and isinstance(j, int):
                return str((i * ow + j) * co + k)
            return f"(({i}) * {ow} + ({j})) * {co} + {k}"

        if level == 0:
            for i in range(oh):
                for j in range(ow):
                    body(i, j)
        elif level == 1:
            self.floop("i", oh)
            for j in range(ow):
                body("i", j)
            self.fclose()
        elif level == 2:
            self.floop("i", oh)
            self.floop("j", ow)
            body("i", "j")
            self.fclose(2)
        else:
            self.floop("i", oh)
            self.floop("j", ow)
            if opts.isa is not None and c % opts.isa.width == 0:
                isa = opts.isa
                self.floop("k", c, step=isa.width)
                w(f"{isa.reg} p = "
                  f"{isa.load(f'{src}[' + x_idx('i', 'j', 0, 0, 0) + ' + k]')};")
                for n in range(kh):
                    for m in range(kw_):
                        if n == 0 and m == 0:
                            continue
                        ld = isa.load(f"{src}[" + x_idx('i', 'j', n, m, 0)
                                      + " + k]")
                        w(f"p = {isa.vmax('p', ld)};")
                w(isa.store(f"{dst}[(i * {ow} + j) * {co} + k]", "p"))
                self.fclose()
            else:
                self.floop("k", c)
                w(f"float q = {src}[{x_idx('i', 'j', 0, 0, 0)} + k];")
                for n in range(kh):
                    for m in range(kw_):
                        if n == 0 and m == 0:
                            continue
                        w(f"q = {src}[{x_idx('i', 'j', n, m, 0)} + k] > q ? "
                          f"{src}[{x_idx('i', 'j', n, m, 0)} + k] : q;")
                w(f"{dst}[(i * {ow} + j) * {co} + k] = q;")
                self.fclose()
            self.fclose(2)

    def emit_avgpool(self, layer: AvgPool, in_shape, src: str, dst: str,
                     pad_buf: Optional[str] = None) -> None:
        w = self.w
        oh, ow, co = layer.out_shape(in_shape)
        kh, kw_ = layer.size
        sh, sw = layer.strides
        pads = layer.pad_amounts(in_shape)
        counts = pool_window_counts(in_shape, layer.size, layer.strides,
                                    pads)
        w(f"/* AvgPool {layer.name}: {in_shape}->{(oh, ow, co)} "
          f"k={kh}x{kw_} s={sh}x{sw} pad={layer.padding} */")
        if any(pads):
            # zero fill keeps the window sum correct; the divisor below
            # counts only the valid taps (edge-correct, not 1/(kh*kw))
            assert pad_buf is not None, f"{layer.name}: unplanned pad scratch"
            src, in_shape = self.emit_padded_copy(src, in_shape, pads,
                                                  pad_buf)
        h, wdt, c = in_shape
        if counts.min() == counts.max():
            inv_expr = _flit(1.0 / counts.max())
        else:
            # edge windows cover fewer valid taps: per-window inverse
            # divisor table, indexed by the output position
            invm = self.const_array(
                f"pinv{self.uid()}",
                (1.0 / counts.astype(np.float64)).astype(np.float32))
            inv_expr = f"{invm}[i * {ow} + j]"
        self.floop("i", oh)
        self.floop("j", ow)
        self.floop("k", c)
        w("float s = 0.0f;")
        w(_cfor("n", kh, _cfor(
            "m", kw_,
            f"s += {src}[((i * {sh} + n) * {wdt} + "
            f"(j * {sw} + m)) * {c} + k];")))
        w(f"{dst}[(i * {ow} + j) * {co} + k] = s * {inv_expr};")
        self.fclose(3)

    def emit_global_avgpool(self, layer: GlobalAvgPool, in_shape,
                            src: str, dst: str) -> None:
        w = self.w
        h, wdt, c = in_shape
        inv = _flit(1.0 / (h * wdt))
        w(f"/* GlobalAvgPool {layer.name}: {in_shape}->(1, 1, {c}) */")
        self.floop("k", c)
        w("float s = 0.0f;")
        w(_cfor("p", h * wdt, f"s += {src}[p * {c} + k];"))
        w(f"{dst}[k] = s * {inv};")
        self.fclose()

    def emit_add(self, layer: Add, shape, srcs: List[str], dst: str) -> None:
        w = self.w
        n = int(np.prod(shape))
        isa = self.opts.isa
        act = layer.activation if layer.activation != "softmax" else None
        w(f"/* Add {layer.name}: {len(srcs)} inputs, {shape}, "
          f"act={layer.activation} */")
        if isa is not None and n % isa.width == 0 and len(srcs) >= 2:
            self.floop("z", n, step=isa.width)
            w(f"{isa.reg} v = {isa.load(f'{srcs[0]}[z]')};")
            for s in srcs[1:]:
                w(f"v = {isa.add('v', isa.load(f'{s}[z]'))};")
            for ln in self.act_sse("v", act, layer.alpha):
                w(ln)
            w(isa.store(f"{dst}[z]", "v"))
            self.fclose()
        else:
            expr = " + ".join(f"{s}[z]" for s in srcs)
            w(_cfor("z", n,
                    f"{dst}[z] = {self.act_scalar(expr, act, layer.alpha)};"))

    def emit_concat(self, layer: Concat, in_shapes, srcs: List[str],
                    dst: str) -> None:
        w = self.w
        h, wdt, _ = in_shapes[0]
        co = int(sum(s[2] for s in in_shapes))
        w(f"/* Concat {layer.name}: {[tuple(s) for s in in_shapes]} -> "
          f"({h}, {wdt}, {co}) */")
        self.floop("p", h * wdt)
        off = 0
        for s, ish in zip(srcs, in_shapes):
            ck = int(ish[2])
            w(_cfor("z", ck,
                    f"{dst}[p * {co} + {off} + z] = {s}[p * {ck} + z];"))
            off += ck
        self.fclose()

    def emit_elementwise(self, in_shape, src, dst, act, alpha) -> None:
        w = self.w
        n = int(np.prod(in_shape))
        isa = self.opts.isa
        if isa is not None and n % isa.width == 0 and act in (
                "relu", "leaky_relu"):
            self.floop("z", n, step=isa.width)
            w(f"{isa.reg} v = {isa.load(f'{src}[z]')};")
            for ln in self.act_sse("v", act, alpha):
                w(ln)
            w(isa.store(f"{dst}[z]", "v"))
            self.fclose()
        else:
            w(_cfor("z", n,
                    f"{dst}[z] = {self.act_scalar(f'{src}[z]', act, alpha)};"))

    def emit_batchnorm(self, layer: BatchNorm, in_shape, src, dst) -> None:
        w = self.w
        scale, shift = layer.scale_shift()
        c = in_shape[2]
        sname = self.const_array(f"s{self.uid()}", scale)
        tname = self.const_array(f"t{self.uid()}", shift)
        n = int(np.prod(in_shape))
        w(_cfor("z", n,
                f"{dst}[z] = {src}[z] * {sname}[z % {c}] + "
                f"{tname}[z % {c}];"))

    def emit_softmax(self, shape, buf) -> None:
        w = self.w
        h, wdt, c = shape
        w(f"/* softmax over {c} channels */")
        self.floop("p", h * wdt)
        w(f"float mx = {buf}[p * {c}];")
        w("float s = 0.0f;")
        w(_cfor("k", c,
                f"mx = {buf}[p * {c} + k] > mx ? {buf}[p * {c} + k] : mx;",
                start=1))
        w(_cfor("k", c,
                f"{{ {buf}[p * {c} + k] = expf({buf}[p * {c} + k] - mx); "
                f"s += {buf}[p * {c} + k]; }}"))
        w(_cfor("k", c, f"{buf}[p * {c} + k] /= s;"))
        self.fclose()

    def emit_dense(self, layer: Dense, in_shape, src, dst) -> None:
        w = self.w
        d_in, d_out = layer.weights.shape
        wname = self.const_array(f"w{self.uid()}", layer.weights)
        bname = self.const_array(f"b{self.uid()}", layer.bias)
        act = layer.activation if layer.activation != "softmax" else None
        w(f"/* Dense {layer.name}: {d_in}->{d_out} */")
        self.floop("k", d_out)
        w(f"float acc = {bname}[k];")
        w(_cfor("z", d_in, f"acc += {src}[z] * {wname}[z * {d_out} + k];"))
        w(f"{dst}[k] = {self.act_scalar('acc', act, layer.alpha)};")
        self.fclose()
        if layer.activation == "softmax":
            self.emit_softmax((1, 1, d_out), dst)

    # -- driver ---------------------------------------------------------------

    def generate(self) -> str:
        g, opts, w = self.g, self.opts, self.w
        smap = g.shape_map()
        plan = self.plan = plan_arena(g, opts)
        val = _value_map(g)
        out_value = val[g.sink.name]

        def ref(v: str) -> str:
            if v == "x":
                return "x"
            if v == out_value:
                return "out"
            return _cname(v)

        w.open(f"void {opts.ws_func_name}(const float *NNCG_RESTRICT x, "
               f"float *NNCG_RESTRICT out, float *NNCG_RESTRICT ws)")
        # workspace carving: all pointer declarations first (C89)
        for iv in sorted(plan.intervals, key=lambda iv: (iv.offset, iv.value)):
            w(f"float *const {_cname(iv.value)} = ws + {iv.offset}; "
              f"/* {iv.size} floats, live layers "
              f"[{iv.start}, {iv.end}] */")
        if not plan.intervals:
            w("(void) ws;")
        for layer in g.layers:
            if isinstance(layer, IDENTITY_LAYERS):
                continue
            ishs = [smap[n] for n in layer.inputs]
            srcs = [ref(val[n]) for n in layer.inputs]
            v = val[layer.name]
            dst = "out" if v == out_value else _cname(v)
            pad_buf = (_cname(layer.name + "__pad")
                       if layer.name + "__pad" in plan.offsets else None)
            if isinstance(layer, Conv2D):
                self.emit_conv(layer, ishs[0], srcs[0], dst, pad_buf)
            elif isinstance(layer, DepthwiseConv2D):
                self.emit_depthwise(layer, ishs[0], srcs[0], dst, pad_buf)
            elif isinstance(layer, MaxPool):
                self.emit_maxpool(layer, ishs[0], srcs[0], dst, pad_buf)
            elif isinstance(layer, AvgPool):
                self.emit_avgpool(layer, ishs[0], srcs[0], dst, pad_buf)
            elif isinstance(layer, GlobalAvgPool):
                self.emit_global_avgpool(layer, ishs[0], srcs[0], dst)
            elif isinstance(layer, Add):
                self.emit_add(layer, smap[layer.name], srcs, dst)
            elif isinstance(layer, Concat):
                self.emit_concat(layer, ishs, srcs, dst)
            elif isinstance(layer, ReLU):
                self.emit_elementwise(ishs[0], srcs[0], dst, "relu", 0.0)
            elif isinstance(layer, LeakyReLU):
                self.emit_elementwise(ishs[0], srcs[0], dst, "leaky_relu",
                                      layer.alpha)
            elif isinstance(layer, Softmax):
                if srcs[0] != dst:
                    w(_cfor("z", int(np.prod(ishs[0])),
                            f"{dst}[z] = {srcs[0]}[z];"))
                self.emit_softmax(ishs[0], dst)
            elif isinstance(layer, BatchNorm):
                self.emit_batchnorm(layer, ishs[0], srcs[0], dst)
            elif isinstance(layer, Dense):
                self.emit_dense(layer, ishs[0], srcs[0], dst)
            else:  # pragma: no cover
                raise TypeError(f"cgen: unhandled layer {type(layer).__name__}")
        if out_value == "x":  # degenerate identity graph
            w(_cfor("z", int(np.prod(g.input_shape)), "out[z] = x[z];"))
        w.close()

        # static-arena wrapper: the paper's embedded single-image entry
        arena = f"{opts.func_name}_arena"
        self.decls(f"static float {arena}[{max(plan.total_floats, 1)}];")
        w("")
        w.open(f"void {opts.func_name}(const float *NNCG_RESTRICT x, "
               f"float *NNCG_RESTRICT out)")
        w(f"{opts.ws_func_name}(x, out, {arena});")
        w.close()
        w("")
        w.open(f"long {opts.ws_size_func_name}(void)")
        w(f"return {plan.total_floats}L;")
        w.close()

        if opts.emit_batch:
            # serving entry points: N images through the single-image
            # function.  <func>_batch runs over the static arena;
            # <func>_batch_ws takes a caller workspace, so a server
            # worker pool pushes whole batches through one foreign call
            # per batch, each worker on its own arena.
            in_n = int(np.prod(g.input_shape))
            out_n = int(np.prod(smap[g.sink.name]))
            w("")
            w.open(f"void {opts.batch_ws_func_name}("
                   f"const float *NNCG_RESTRICT x, "
                   f"float *NNCG_RESTRICT out, int n, "
                   f"float *NNCG_RESTRICT workspace)")
            w("int b;")
            w(f"for (b = 0; b < n; ++b) "
              f"{opts.ws_func_name}(x + (long)b * {in_n}, "
              f"out + (long)b * {out_n}, workspace);")
            w.close()
            w("")
            w.open(f"void {opts.batch_func_name}("
                   f"const float *NNCG_RESTRICT x, "
                   f"float *NNCG_RESTRICT out, int n)")
            w(f"{opts.batch_ws_func_name}(x, out, n, {arena});")
            w.close()

        hdr = _W()
        hdr("/* Generated by NNCG-JAX (repro of Urbann et al., 2020).")
        hdr(f" * net: in {g.input_shape} -> out {smap[g.sink.name]}, "
            f"{g.param_count()} params, simd={opts.simd},")
        hdr(f" * arena {plan.total_bytes} B "
            f"(one-buffer-per-layer would be {plan.buffer_sum_bytes} B) */")
        hdr("#include <math.h>")
        if opts.isa is not None:
            hdr(f"#include <{opts.isa.header}>")
        hdr("#if defined(__STDC_VERSION__) && __STDC_VERSION__ >= 199901L")
        hdr("#define NNCG_RESTRICT restrict")
        hdr("#else")
        hdr("#define NNCG_RESTRICT")
        hdr("extern float expf(float);")
        hdr("#endif")
        hdr("")
        return hdr.text() + self.decls.text() + "\n" + self.w.text()


def generate_c(graph: CNNGraph, opts: Optional[CodegenOptions] = None) -> str:
    """Generate the single ANSI C file for a trained CNN."""
    return CGenerator(graph, opts or CodegenOptions()).generate()


# ---------------------------------------------------------------------------
# quantized code generation (int8 weights/intermediates, int32 accumulators)
# ---------------------------------------------------------------------------


class QuantCGenerator(CGenerator):
    """Int8 code generator for a calibrated
    :class:`repro.core.quantize.QuantizedGraph`.

    Same external contract as the float generator (float in, float out,
    reentrant ``_ws`` entry, static-arena wrapper, batch loop) but every
    weight is a ``static const signed char`` array, every intermediate
    tensor is an int8 code in a **byte**-planned arena (~4x smaller),
    accumulation is int32, and requantization multiplies by float32
    constants shared bit-exactly with the jax reference
    (:func:`repro.core.jax_exec.forward_quantized`).

    ``simd='sse'``/``'avx'`` vectorizes the conv/dense inner dot product
    with SSE2 integer intrinsics (sign-extend + ``_mm_madd_epi16``, 16
    taps per iteration).  Integer addition is associative, so the SIMD
    build produces *identical* results to the scalar one.  Any other
    mode emits portable scalar code — strict ANSI C89, like the float
    path (CI-enforced).
    """

    def __init__(self, qgraph, opts: CodegenOptions):
        super().__init__(qgraph.graph, opts)
        self.qg = qgraph

    # -- const emitters -------------------------------------------------------

    def const_i8(self, name: str, arr: np.ndarray) -> str:
        vals = ", ".join(str(int(v))
                         for v in np.asarray(arr, np.int8).ravel())
        self.decls(f"static const signed char {name}[{arr.size}] = "
                   f"{{{vals}}};")
        return name

    def const_i16(self, name: str, arr: np.ndarray) -> str:
        """Int8 weight codes pre-widened to int16 for the SSE madd
        path (values still fit int8; layout-only)."""
        vals = ", ".join(str(int(v))
                         for v in np.asarray(arr, np.int16).ravel())
        self.decls(f"static const short {name}[{arr.size}] = {{{vals}}};")
        return name

    def const_i32(self, name: str, arr: np.ndarray) -> str:
        vals = ", ".join(str(int(v))
                         for v in np.asarray(arr, np.int32).ravel())
        self.decls(f"static const int {name}[{arr.size}] = {{{vals}}};")
        return name

    # -- shared emission fragments -------------------------------------------

    _REQ_DECLS = "float t; float u; int q;"

    def _round_clamp(self, zp_out: int, dst_expr: str) -> None:
        """``t`` (float, s_out units) -> int8 code at ``dst_expr``;
        round half up (``floor(t + 0.5)``), add the zero point,
        saturate.  The floor is truncate-then-fixup — exact for every
        in-range value and, unlike ``floorf``, never a libm call on
        pre-SSE4.1 targets (it was the requant hot spot).  Requires
        ``float t; float u; int q;`` declared in the enclosing block."""
        w = self.w
        w("u = t + 0.5f;")
        w("q = (int)u;")                      # trunc toward zero
        w(f"q = (q - ((float)q > u)) + {zp_out};")  # fix-up -> floor
        w(f"{dst_expr} = (signed char)"
          f"(q < -128 ? -128 : (q > 127 ? 127 : q));")

    def _act_float(self, act: Optional[str], alpha: float) -> None:
        if act in ("relu", "leaky_relu"):
            self.w(f"t = {self.act_scalar('t', act, alpha)};")

    def emit_padded_copy_i8(self, src: str, in_shape, pads, buf: str,
                            fill: str) -> Tuple[str, Tuple[int, int, int]]:
        """Int8 padded copy — byte-identical emission to the float
        version (element type comes from the arena declaration);
        ``fill`` is the input zero-point code for conv/avg sums
        (cancelled by the folded bias correction) or -128 for max
        pooling."""
        return self.emit_padded_copy(src, in_shape, pads, buf, fill)

    def _madd16(self, x_expr: str, w_expr: str) -> None:
        """One SSE2 iteration: 16 int8 taps x 16 int8 weights summed
        into ``vacc`` (4 x int32) — sign-extend via unpack+srai, then
        ``_mm_madd_epi16``.  Emits the body of a block (decls first)."""
        w = self.w
        w(f"__m128i xv = _mm_loadu_si128((const __m128i *)({x_expr}));")
        w(f"__m128i wv = _mm_loadu_si128((const __m128i *)({w_expr}));")
        w("__m128i xlo = _mm_srai_epi16(_mm_unpacklo_epi8(xv, xv), 8);")
        w("__m128i xhi = _mm_srai_epi16(_mm_unpackhi_epi8(xv, xv), 8);")
        w("__m128i wlo = _mm_srai_epi16(_mm_unpacklo_epi8(wv, wv), 8);")
        w("__m128i whi = _mm_srai_epi16(_mm_unpackhi_epi8(wv, wv), 8);")
        w("vacc = _mm_add_epi32(vacc, _mm_madd_epi16(xlo, wlo));")
        w("vacc = _mm_add_epi32(vacc, _mm_madd_epi16(xhi, whi));")

    def _dot_inner(self, src: str, wname: str, row: int, use_sse: bool,
                   x_base: str, w_base: str) -> None:
        """``acc += sum_z src[x_base+z] * w[w_base+z]`` over a
        contiguous run of ``row`` taps (one window row, all channels).
        SSE2 path: 16 int8 taps/iteration via sign-extend + madd; the
        remainder and the scalar mode share the same exact int32 sum."""
        w = self.w
        w.open("")
        w(f"const signed char *xr = {src} + {x_base};")
        w(f"const signed char *wr = {wname} + {w_base};")
        if use_sse:
            w.open("")
            w("int z;")
            w.open(f"for (z = 0; z + 16 <= {row}; z += 16)")
            self._madd16("xr + z", "wr + z")
            w.close()
            w(f"for (; z < {row}; ++z) acc += xr[z] * wr[z];")
            w.close()
        else:
            w(_cfor("z", row, "acc += xr[z] * wr[z];"))
        w.close()

    def _hsum_sse(self) -> None:
        w = self.w
        w("vacc = _mm_add_epi32(vacc, _mm_srli_si128(vacc, 8));")
        w("vacc = _mm_add_epi32(vacc, _mm_srli_si128(vacc, 4));")
        w("acc += _mm_cvtsi128_si32(vacc);")

    # -- weighted layers ------------------------------------------------------

    def emit_qconv(self, layer: Conv2D, in_shape, src: str, dst: str,
                   pad_buf: Optional[str], is_sink: bool) -> None:
        qg, w = self.qg, self.w
        oh, ow, co = layer.out_shape(in_shape)
        sh, sw = layer.strides
        kh, kw_, ci = layer.kh, layer.kw, layer.c_in
        pads = layer.pad_amounts(in_shape)
        zp_in = qg.in_qp(layer).zero_point
        act = layer.activation
        w(f"/* QConv2D {layer.name}: {in_shape}->{(oh, ow, co)} "
          f"k={kh}x{kw_} s={sh}x{sw} pad={layer.padding} act={act} "
          f"int8/int32 */")
        if any(pads):
            assert pad_buf is not None, f"{layer.name}: unplanned pad scratch"
            src, in_shape = self.emit_padded_copy_i8(
                src, in_shape, pads, pad_buf, str(zp_in))
        h, wdt, _ = in_shape
        row = kw_ * ci
        taps = kh * row
        # taps of one output channel contiguous: (co, kh, kw, ci)
        wt = np.transpose(qg.weights[layer.name].w_q,
                          (3, 0, 1, 2)).reshape(co, taps)
        use_patch = _qconv_use_patch(layer, self.opts)
        # patch taps padded to the paired-madd granularity (2 vectors)
        vstep16 = 16 if self.opts.simd == "avx" else 8
        wtaps = (-(-taps // (2 * vstep16)) * (2 * vstep16)
                 if use_patch else taps)
        scales = (qg.dequant_scales(layer) if is_sink
                  else qg.requant_scales(layer))
        use_sse = self.opts.isa is not None and (use_patch or row >= 16)
        if use_patch or taps >= 16:  # tiny-window branch uses literals
            bname = self.const_i32(f"b{self.uid()}",
                                   qg.effective_bias(layer))
            mname = self.const_array(f"m{self.uid()}", scales)

        def requant_one(oidx: str) -> None:
            w(f"t = (float)acc * {mname}[k];")
            self._act_float(act, layer.alpha)
            if is_sink:
                w(f"out[{oidx}] = t;")
            else:
                self._round_clamp(qg.out_qp(layer).zero_point,
                                  f"{dst}[{oidx}]")

        if use_patch:
            # im2row the window into a stack-local int16 patch (C89
            # constant size, reentrant), zero-padded to a 16-multiple;
            # weights are the same int8 codes pre-widened to int16, so
            # the per-channel loop is pure _mm_madd_epi16 — the widened
            # layout changes nothing numerically (int sums are exact)
            wname = self.const_i16(
                f"w{self.uid()}", np.pad(wt, ((0, 0), (0, wtaps - taps))))
            w.open("")
            w(f"short patch[{wtaps}];")
            if wtaps > taps:  # the constant zero tail, filled once
                w(_cfor("z", wtaps - taps, f"patch[{taps} + z] = 0;"))
            self.floop("i", oh)
            self.floop("j", ow)
            self.floop("n", kh)
            w(_cfor("z", row,
                    f"patch[n * {row} + z] = "
                    f"{src}[((i * {sh} + n) * {wdt} + j * {sw}) "
                    f"* {ci} + z];"))
            self.fclose()
            # vector plumbing: 256-bit integer madd on AVX2 (16 int16
            # MACs/op), 128-bit SSE2 otherwise
            wide = self.opts.simd == "avx"
            vstep = vstep16
            vreg = "__m256i" if wide else "__m128i"
            pfx = "_mm256" if wide else "_mm"
            cast = "(const __m256i *)" if wide else "(const __m128i *)"
            ld = (f"{pfx}_loadu_si256" if wide else f"{pfx}_loadu_si128")
            zero = (f"{pfx}_setzero_si256()" if wide
                    else f"{pfx}_setzero_si128()")
            groups = wtaps // vstep
            cache_regs = groups <= 10  # window fits the vector file
            if cache_regs:
                # hoist the widened window into registers once per
                # output position — per channel only the weight loads
                # and madds remain (straight-line, no loop control)
                w.open("")
                for gi in range(groups):
                    w(f"const {vreg} x{gi} = {ld}("
                      f"{cast}(patch + {gi * vstep}));")
            self.floop("k", co)
            w.open("")
            w(f"int acc = {bname}[k];")
            w("float t;" if is_sink else self._REQ_DECLS)
            w(f"{vreg} v0 = {zero};")
            w(f"{vreg} v1 = {zero};")
            w(f"const short *wr = {wname} + k * {wtaps};")
            if cache_regs:
                for gi in range(groups):
                    acc_reg = f"v{gi % 2}"
                    w(f"{acc_reg} = {pfx}_add_epi32({acc_reg}, "
                      f"{pfx}_madd_epi16(x{gi}, {ld}("
                      f"{cast}(wr + {gi * vstep}))));")
            else:
                w.open("")
                w("int z;")
                w.open(f"for (z = 0; z < {wtaps}; z += {2 * vstep})")
                w(f"v0 = {pfx}_add_epi32(v0, {pfx}_madd_epi16(")
                w(f"    {ld}({cast}(patch + z)),")
                w(f"    {ld}({cast}(wr + z))));")
                w(f"v1 = {pfx}_add_epi32(v1, {pfx}_madd_epi16(")
                w(f"    {ld}({cast}(patch + z + {vstep})),")
                w(f"    {ld}({cast}(wr + z + {vstep}))));")
                w.close()
                w.close()
            w(f"v0 = {pfx}_add_epi32(v0, v1);")
            if wide:
                w("{ __m128i s = _mm_add_epi32("
                  "_mm256_castsi256_si128(v0), "
                  "_mm256_extracti128_si256(v0, 1));")
                w("s = _mm_add_epi32(s, _mm_srli_si128(s, 8));")
                w("s = _mm_add_epi32(s, _mm_srli_si128(s, 4));")
                w("acc += _mm_cvtsi128_si32(s); }")
            else:
                w("v0 = _mm_add_epi32(v0, _mm_srli_si128(v0, 8));")
                w("v0 = _mm_add_epi32(v0, _mm_srli_si128(v0, 4));")
                w("acc += _mm_cvtsi128_si32(v0);")
            requant_one(f"(i * {ow} + j) * {co} + k")
            w.close()
            self.fclose()
            if cache_regs:
                w.close()
            self.fclose(2)
            w.close()
        elif taps < 16:
            # tiny window (e.g. first conv on a 1-channel image):
            # straight-line taps with the int8 weight codes as literals
            # (P3) — no const arrays, no inner loop overhead
            bias_eff = qg.effective_bias(layer)
            self.floop("i", oh)
            self.floop("j", ow)
            for k in range(co):
                w.open("")
                w(f"int acc = {int(bias_eff[k])};")
                w("float t;" if is_sink else self._REQ_DECLS)
                for n in range(kh):
                    for m in range(kw_):
                        for o in range(ci):
                            c_w = int(wt[k, (n * kw_ + m) * ci + o])
                            if c_w == 0:
                                continue
                            w(f"acc += {c_w} * {src}[((i * {sh} + {n}) * "
                              f"{wdt} + (j * {sw} + {m})) * {ci} + {o}];")
                w(f"t = (float)acc * {_flit(scales[k])};")
                self._act_float(act, layer.alpha)
                if is_sink:
                    w(f"out[(i * {ow} + j) * {co} + {k}] = t;")
                else:
                    self._round_clamp(
                        qg.out_qp(layer).zero_point,
                        f"{dst}[(i * {ow} + j) * {co} + {k}]")
                w.close()
            self.fclose(2)
        else:
            wname = self.const_i8(f"w{self.uid()}", wt)
            self.floop("i", oh)
            self.floop("j", ow)
            self.floop("k", co)
            w.open("")
            w(f"int acc = {bname}[k];")
            w("float t;" if is_sink else self._REQ_DECLS)
            if use_sse:
                w("__m128i vacc = _mm_setzero_si128();")
            self.floop("n", kh)
            self._dot_inner(src, wname, row, use_sse,
                            f"((i * {sh} + n) * {wdt} + j * {sw}) * {ci}",
                            f"k * {taps} + n * {row}")
            self.fclose()
            if use_sse:
                self._hsum_sse()
            requant_one(f"(i * {ow} + j) * {co} + k")
            w.close()
            self.fclose(3)
        if is_sink and act == "softmax":
            self.emit_softmax((oh, ow, co), "out")

    def emit_qdepthwise(self, layer: DepthwiseConv2D, in_shape, src: str,
                        dst: str, pad_buf: Optional[str],
                        is_sink: bool) -> None:
        qg, w = self.qg, self.w
        oh, ow, co = layer.out_shape(in_shape)
        sh, sw = layer.strides
        kh, kw_, ci, mult = layer.kh, layer.kw, layer.c_in, layer.multiplier
        pads = layer.pad_amounts(in_shape)
        zp_in = qg.in_qp(layer).zero_point
        act = layer.activation
        w(f"/* QDepthwiseConv2D {layer.name}: {in_shape}->{(oh, ow, co)} "
          f"k={kh}x{kw_} s={sh}x{sw} mult={mult} pad={layer.padding} "
          f"act={act} int8/int32 */")
        if any(pads):
            assert pad_buf is not None, f"{layer.name}: unplanned pad scratch"
            src, in_shape = self.emit_padded_copy_i8(
                src, in_shape, pads, pad_buf, str(zp_in))
        h, wdt, _ = in_shape
        wname = self.const_i8(f"w{self.uid()}",
                              qg.weights[layer.name].w_q)  # HWCM layout
        bname = self.const_i32(f"b{self.uid()}", qg.effective_bias(layer))
        scales = (qg.dequant_scales(layer) if is_sink
                  else qg.requant_scales(layer))
        mname = self.const_array(f"m{self.uid()}", scales)
        self.floop("i", oh)
        self.floop("j", ow)
        self.floop("c", ci)
        for m_ in range(mult):
            w.open("")
            w(f"int acc = {bname}[c * {mult} + {m_}];")
            w("float t;" if is_sink else self._REQ_DECLS)
            w(_cfor("n", kh, _cfor(
                "m", kw_,
                f"acc += {src}[((i * {sh} + n) * {wdt} + "
                f"(j * {sw} + m)) * {ci} + c] * "
                f"{wname}[((n * {kw_} + m) * {ci} + c) * {mult} + {m_}];")))
            oidx = f"(i * {ow} + j) * {co} + c * {mult} + {m_}"
            w(f"t = (float)acc * {mname}[c * {mult} + {m_}];")
            self._act_float(act, layer.alpha)
            if is_sink:
                w(f"out[{oidx}] = t;")
            else:
                self._round_clamp(qg.out_qp(layer).zero_point,
                                  f"{dst}[{oidx}]")
            w.close()
        self.fclose(3)
        if is_sink and act == "softmax":
            self.emit_softmax((oh, ow, co), "out")

    def emit_qdense(self, layer: Dense, in_shape, src: str, dst: str,
                    is_sink: bool) -> None:
        qg, w = self.qg, self.w
        d_in, d_out = layer.weights.shape
        act = layer.activation
        w(f"/* QDense {layer.name}: {d_in}->{d_out} int8/int32 */")
        wname = self.const_i8(f"w{self.uid()}",
                              qg.weights[layer.name].w_q.T)  # (d_out, d_in)
        bname = self.const_i32(f"b{self.uid()}", qg.effective_bias(layer))
        scales = (qg.dequant_scales(layer) if is_sink
                  else qg.requant_scales(layer))
        mname = self.const_array(f"m{self.uid()}", scales)
        use_sse = self.opts.isa is not None and d_in >= 16
        self.floop("k", d_out)
        w.open("")
        w(f"int acc = {bname}[k];")
        w("float t;" if is_sink else self._REQ_DECLS)
        if use_sse:
            w("__m128i vacc = _mm_setzero_si128();")
        self._dot_inner(src, wname, d_in, use_sse, "0", f"k * {d_in}")
        if use_sse:
            self._hsum_sse()
        w(f"t = (float)acc * {mname}[k];")
        self._act_float(act, layer.alpha)
        if is_sink:
            w("out[k] = t;")
        else:
            self._round_clamp(qg.out_qp(layer).zero_point, f"{dst}[k]")
        w.close()
        self.fclose()
        if is_sink and act == "softmax":
            self.emit_softmax((1, 1, d_out), "out")

    # -- pooling / merge / elementwise ---------------------------------------

    def emit_qmaxpool(self, layer: MaxPool, in_shape, src: str, dst: str,
                      pad_buf: Optional[str]) -> None:
        w = self.w
        oh, ow, co = layer.out_shape(in_shape)
        kh, kw_ = layer.size
        sh, sw = layer.strides
        pads = layer.pad_amounts(in_shape)
        w(f"/* QMaxPool {layer.name}: {in_shape}->{(oh, ow, co)} "
          f"k={kh}x{kw_} s={sh}x{sw} pad={layer.padding} (pure int8, "
          f"shared qparams) */")
        if any(pads):
            assert pad_buf is not None, f"{layer.name}: unplanned pad scratch"
            src, in_shape = self.emit_padded_copy_i8(
                src, in_shape, pads, pad_buf, "-128")
        h, wdt, c = in_shape

        def idx(n, m):
            return (f"((i * {sh} + {n}) * {wdt} + (j * {sw} + {m})) "
                    f"* {c} + k")

        self.floop("i", oh)
        self.floop("j", ow)
        self.floop("k", c)
        w.open("")
        w(f"signed char q = {src}[{idx(0, 0)}];")
        for n in range(kh):
            for m in range(kw_):
                if n == 0 and m == 0:
                    continue
                w(f"q = {src}[{idx(n, m)}] > q ? {src}[{idx(n, m)}] : q;")
        w(f"{dst}[(i * {ow} + j) * {co} + k] = q;")
        w.close()
        self.fclose(3)

    def emit_qavgpool(self, layer: AvgPool, in_shape, src: str, dst: str,
                      pad_buf: Optional[str]) -> None:
        qg, w = self.qg, self.w
        oh, ow, co = layer.out_shape(in_shape)
        kh, kw_ = layer.size
        sh, sw = layer.strides
        pads = layer.pad_amounts(in_shape)
        zp_in = qg.in_qp(layer).zero_point
        minv = qg.pool_scales(layer, in_shape)  # (oh, ow) float32
        w(f"/* QAvgPool {layer.name}: {in_shape}->{(oh, ow, co)} "
          f"k={kh}x{kw_} s={sh}x{sw} pad={layer.padding} int8/int32 */")
        if any(pads):
            # zp fill: padded taps sum as zp and the fixed kh*kw*zp
            # correction below cancels them exactly
            assert pad_buf is not None, f"{layer.name}: unplanned pad scratch"
            src, in_shape = self.emit_padded_copy_i8(
                src, in_shape, pads, pad_buf, str(zp_in))
        h, wdt, c = in_shape
        if np.unique(minv).size == 1:
            mexpr = _flit(minv.ravel()[0])
        else:
            mname = self.const_array(f"pinv{self.uid()}", minv)
            mexpr = f"{mname}[i * {ow} + j]"
        self.floop("i", oh)
        self.floop("j", ow)
        self.floop("k", c)
        w.open("")
        w("int acc = 0;")
        w(self._REQ_DECLS)
        w(_cfor("n", kh, _cfor(
            "m", kw_,
            f"acc += {src}[((i * {sh} + n) * {wdt} + "
            f"(j * {sw} + m)) * {c} + k];")))
        w(f"t = (float)(acc - {kh * kw_ * zp_in}) * {mexpr};")
        self._round_clamp(qg.out_qp(layer).zero_point,
                          f"{dst}[(i * {ow} + j) * {co} + k]")
        w.close()
        self.fclose(3)

    def emit_qglobal_avgpool(self, layer: GlobalAvgPool, in_shape,
                             src: str, dst: str) -> None:
        qg, w = self.qg, self.w
        h, wdt, c = in_shape
        zp_in = qg.in_qp(layer).zero_point
        minv = qg.pool_scales(layer, in_shape)  # scalar float32
        w(f"/* QGlobalAvgPool {layer.name}: {in_shape}->(1, 1, {c}) */")
        self.floop("k", c)
        w.open("")
        w("int acc = 0;")
        w(self._REQ_DECLS)
        w(_cfor("p", h * wdt, f"acc += {src}[p * {c} + k];"))
        w(f"t = (float)(acc - {h * wdt * zp_in}) * {_flit(minv)};")
        self._round_clamp(qg.out_qp(layer).zero_point, f"{dst}[k]")
        w.close()
        self.fclose()

    def emit_qadd(self, layer: Add, shape, srcs: List[str],
                  dst: str) -> None:
        qg, w = self.qg, self.w
        n = int(np.prod(shape))
        act = layer.activation
        w(f"/* QAdd {layer.name}: {len(srcs)} inputs, {shape}, "
          f"act={act} */")
        self.floop("z", n)
        w.open("")
        w(self._REQ_DECLS)
        for i, s in enumerate(srcs):
            op = "=" if i == 0 else "+="
            qp = qg.in_qp(layer, i)
            w(f"t {op} (float)({s}[z] - {qp.zero_point}) * "
              f"{_flit(qg.rescale(layer, i))};")
        self._act_float(act, layer.alpha)
        self._round_clamp(qg.out_qp(layer).zero_point, f"{dst}[z]")
        w.close()
        self.fclose()

    def emit_qconcat(self, layer: Concat, in_shapes, srcs: List[str],
                     dst: str) -> None:
        qg, w = self.qg, self.w
        h, wdt, _ = in_shapes[0]
        co = int(sum(s[2] for s in in_shapes))
        zp_out = qg.out_qp(layer).zero_point
        w(f"/* QConcat {layer.name}: {[tuple(s) for s in in_shapes]} -> "
          f"({h}, {wdt}, {co}) (per-input requant) */")
        self.floop("p", h * wdt)
        off = 0
        for i, (s, ish) in enumerate(zip(srcs, in_shapes)):
            ck = int(ish[2])
            qp = qg.in_qp(layer, i)
            # the multiply and the +0.5f stay separate statements: in
            # one expression an FP_CONTRACT-honoring compiler could
            # fuse them into an FMA (single rounding) and break the
            # bit-exact contract with the jax reference
            w(_cfor(
                "z", ck,
                f"{{ float t; float u; int q; "
                f"t = (float)({s}[p * {ck} + z] - {qp.zero_point}) * "
                f"{_flit(qg.rescale(layer, i))}; "
                f"u = t + 0.5f; "
                f"q = (int)u; "
                f"q = (q - ((float)q > u)) + {zp_out}; "
                f"{dst}[p * {co} + {off} + z] = (signed char)"
                f"(q < -128 ? -128 : (q > 127 ? 127 : q)); }}"))
            off += ck
        self.fclose()

    def emit_qrelu(self, layer, in_shape, src: str, dst: str,
                   act: str, alpha: float) -> None:
        qg, w = self.qg, self.w
        n = int(np.prod(in_shape))
        qp = qg.in_qp(layer)
        w(f"/* Q{type(layer).__name__} {layer.name}: {in_shape} */")
        self.floop("z", n)
        w.open("")
        w(self._REQ_DECLS)
        w(f"t = (float)({src}[z] - {qp.zero_point}) * "
          f"{_flit(qg.rescale(layer))};")
        self._act_float(act, alpha)
        self._round_clamp(qg.out_qp(layer).zero_point, f"{dst}[z]")
        w.close()
        self.fclose()

    def emit_qsoftmax_sink(self, layer: Softmax, in_shape,
                           src: str) -> None:
        qg, w = self.qg, self.w
        n = int(np.prod(in_shape))
        qp = qg.in_qp(layer)
        w(f"/* QSoftmax {layer.name} (sink): dequantize + float "
          f"softmax */")
        w(_cfor("z", n,
                f"out[z] = (float)({src}[z] - {qp.zero_point}) * "
                f"{_flit(np.float32(qp.scale))};"))
        self.emit_softmax(in_shape, "out")

    # -- driver ---------------------------------------------------------------

    def generate(self) -> str:
        g, opts, w = self.g, self.opts, self.w
        smap = g.shape_map()
        plan = self.plan = plan_arena(g, opts, quantized=True)
        val = _value_map(g, quantized=True)
        sink = g.sink
        out_value = val[sink.name]
        assert out_value != "xq", "degenerate identity graph"

        def ref(v: str) -> str:
            return "out" if v == out_value else _cname(v)

        w.open(f"void {opts.ws_func_name}(const float *NNCG_RESTRICT x, "
               f"float *NNCG_RESTRICT out, "
               f"signed char *NNCG_RESTRICT ws)")
        for iv in sorted(plan.intervals, key=lambda iv: (iv.offset, iv.value)):
            w(f"signed char *const {_cname(iv.value)} = ws + {iv.offset}; "
              f"/* {iv.size} bytes, live layers [{iv.start}, {iv.end}] */")
        if not plan.intervals:
            w("(void) ws;")

        # input quantization: float x -> int8 codes
        in_qp = self.qg.input_qp
        w(f"/* quantize input: q = floor(x * {in_qp.inv_scale} + 0.5) "
          f"+ {in_qp.zero_point} */")
        self.floop("z", int(np.prod(g.input_shape)))
        w.open("")
        w(self._REQ_DECLS)
        w(f"t = x[z] * {_flit(in_qp.inv_scale)};")
        self._round_clamp(in_qp.zero_point, f"{_cname('xq')}[z]")
        w.close()
        self.fclose()

        for layer in g.layers:
            if isinstance(layer, IDENTITY_LAYERS):
                continue
            ishs = [smap[n] for n in layer.inputs]
            srcs = [ref(val[n]) for n in layer.inputs]
            v = val[layer.name]
            is_sink = layer is sink
            dst = "out" if v == out_value else _cname(v)
            pad_buf = (_cname(layer.name + "__pad")
                       if layer.name + "__pad" in plan.offsets else None)
            if isinstance(layer, Conv2D):
                self.emit_qconv(layer, ishs[0], srcs[0], dst, pad_buf,
                                is_sink)
            elif isinstance(layer, DepthwiseConv2D):
                self.emit_qdepthwise(layer, ishs[0], srcs[0], dst,
                                     pad_buf, is_sink)
            elif isinstance(layer, Dense):
                self.emit_qdense(layer, ishs[0], srcs[0], dst, is_sink)
            elif isinstance(layer, MaxPool):
                self.emit_qmaxpool(layer, ishs[0], srcs[0], dst, pad_buf)
            elif isinstance(layer, AvgPool):
                self.emit_qavgpool(layer, ishs[0], srcs[0], dst, pad_buf)
            elif isinstance(layer, GlobalAvgPool):
                self.emit_qglobal_avgpool(layer, ishs[0], srcs[0], dst)
            elif isinstance(layer, Add):
                self.emit_qadd(layer, smap[layer.name], srcs, dst)
            elif isinstance(layer, Concat):
                self.emit_qconcat(layer, ishs, srcs, dst)
            elif isinstance(layer, ReLU):
                self.emit_qrelu(layer, ishs[0], srcs[0], dst, "relu", 0.0)
            elif isinstance(layer, LeakyReLU):
                self.emit_qrelu(layer, ishs[0], srcs[0], dst, "leaky_relu",
                                layer.alpha)
            elif isinstance(layer, Softmax):
                assert is_sink, "standalone Softmax only supported as sink"
                self.emit_qsoftmax_sink(layer, ishs[0], srcs[0])
            else:
                raise TypeError(
                    f"quantized cgen: unhandled layer "
                    f"{type(layer).__name__} "
                    f"(run passes.optimize before quantizing)")
        w.close()

        arena = f"{opts.func_name}_arena"
        self.decls(f"static signed char {arena}"
                   f"[{max(plan.total_floats, 1)}];")
        w("")
        w.open(f"void {opts.func_name}(const float *NNCG_RESTRICT x, "
               f"float *NNCG_RESTRICT out)")
        w(f"{opts.ws_func_name}(x, out, {arena});")
        w.close()
        w("")
        w.open(f"long {opts.ws_bytes_func_name}(void)")
        w(f"return {plan.total_bytes}L;")
        w.close()

        if opts.emit_batch:
            in_n = int(np.prod(g.input_shape))
            out_n = int(np.prod(smap[sink.name]))
            w("")
            w.open(f"void {opts.batch_ws_func_name}("
                   f"const float *NNCG_RESTRICT x, "
                   f"float *NNCG_RESTRICT out, int n, "
                   f"signed char *NNCG_RESTRICT workspace)")
            w("int b;")
            w(f"for (b = 0; b < n; ++b) "
              f"{opts.ws_func_name}(x + (long)b * {in_n}, "
              f"out + (long)b * {out_n}, workspace);")
            w.close()
            w("")
            w.open(f"void {opts.batch_func_name}("
                   f"const float *NNCG_RESTRICT x, "
                   f"float *NNCG_RESTRICT out, int n)")
            w(f"{opts.batch_ws_func_name}(x, out, n, {arena});")
            w.close()

        hdr = _W()
        hdr("/* Generated by NNCG-JAX (repro of Urbann et al., 2020) — "
            "int8 PTQ build.")
        hdr(f" * net: in {g.input_shape} -> out {smap[sink.name]}, "
            f"{g.param_count()} params, simd={opts.simd},")
        hdr(f" * calibration={getattr(self.qg, 'method', 'minmax')} "
            f"(per-branch activation qparams on multi-input edges),")
        hdr(f" * int8 arena {plan.total_bytes} B "
            f"(float32 intermediates would be ~4x) */")
        hdr("#include <math.h>")
        if opts.isa is not None:
            hdr(f"#include <{opts.isa.header}>")
        hdr("#if defined(__STDC_VERSION__) && __STDC_VERSION__ >= 199901L")
        hdr("#define NNCG_RESTRICT restrict")
        hdr("#else")
        hdr("#define NNCG_RESTRICT")
        hdr("extern float expf(float);")
        hdr("#endif")
        hdr("")
        return hdr.text() + self.decls.text() + "\n" + self.w.text()


def generate_quantized_c(qgraph,
                         opts: Optional[CodegenOptions] = None) -> str:
    """Generate the single ANSI C file for a calibrated int8 net."""
    return QuantCGenerator(qgraph, opts or CodegenOptions()).generate()
