"""Typed loop-nest IR between the graph schedule and the C renderer.

``cgen`` used to go straight from a :class:`~repro.core.graph.CNNGraph`
to one flat C string.  This module splits that pipeline into an explicit
intermediate form::

    graph  --schedule-->  emission units  --lowering-->  Program  --render-->  C

A :class:`Program` is the complete lowered translation unit: the header
and declaration line blocks, the ordered body lines, and — the typed
part — one :class:`LoopNest` per emitted layer recording its loop
structure, the :class:`KernelCall` that filled its body span, the
planned :class:`Buffer` set and the **epilogue chain** applied at the
store site.  Epilogue fusion (residual Adds, pooling, Concat) is
literally chain concatenation: the consumer's epilogue ops are appended
to the producer's chain instead of becoming their own nest.

:func:`render` is the single place a ``Program`` becomes C source; it
reproduces the historic ``hdr + decls + "\\n" + body`` byte layout, so
``CGenerator.generate()`` == ``render(CGenerator.lower())`` exactly.

The three ``*Fuse`` dataclasses are the *live* fusion contexts the
emitters consult while a producer's loops are generated; they also know
how a producer-space output position maps into the fused consumer's
buffer (:meth:`PoolFuse.dst_index`, :meth:`ConcatFuse.dst_index`).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple, Union

Pos = Tuple[Union[int, str], Union[int, str], Union[int, str]]


# ---------------------------------------------------------------------------
# IR node types
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Buffer:
    """One planned arena allocation (a tensor value or scratch)."""

    name: str           # value name (layer name, 'xq', '<layer>__pad', ...)
    cname: str          # the C identifier the emitters use
    offset: int         # element offset into the workspace
    size: int           # elements (floats for fp32, bytes for int8)
    elem: str           # C element type ('float' | 'signed char' | 'int')
    start: int          # first live layer step
    end: int            # last live layer step


@dataclass(frozen=True)
class Loop:
    """One counted loop of a nest. ``unrolled`` marks loops the paper's
    P1 specialization turned into straight-line code (no C loop is
    emitted for them)."""

    var: str
    bound: int
    step: int = 1
    unrolled: bool = False


@dataclass(frozen=True)
class KernelCall:
    """The innermost computation of a nest: which kernel family filled
    the body span and with which variant (unroll level, ISA, tiling)."""

    kind: str           # 'conv' | 'dense' | 'maxpool' | 'qconv' | ...
    layer: str
    variant: str        # human-readable variant tag
    span: Tuple[int, int] = (0, 0)  # [start, end) line range in Program.body


@dataclass(frozen=True)
class Epilogue:
    """One store-site epilogue op.  Chains are ordered: the producer's
    own ops first, then any fused consumer's ops."""

    kind: str           # 'act' | 'softmax' | 'requant' | 'add_fuse' |
                        # 'maxpool_fuse' | 'avgpool_fuse' | 'concat_fuse'
    layer: str          # the layer the op belongs to
    detail: str = ""


@dataclass(frozen=True)
class LoopNest:
    """One emitted layer: its loops, kernel and epilogue chain."""

    layer: str
    op: str             # graph layer class name
    out_shape: Tuple[int, ...]
    loops: Tuple[Loop, ...]
    kernel: KernelCall
    epilogue: Tuple[Epilogue, ...] = ()
    stage: int = 0      # pipeline stage hosting this nest


@dataclass
class Program:
    """A lowered translation unit, ready for :func:`render`."""

    func_name: str
    precision: str                      # 'fp32' | 'int8'
    header: List[str] = field(default_factory=list)
    decls: List[str] = field(default_factory=list)
    body: List[str] = field(default_factory=list)
    nests: List[LoopNest] = field(default_factory=list)
    buffers: List[Buffer] = field(default_factory=list)
    arena_elems: int = 0
    elem_bytes: int = 4


def render(program: Program) -> str:
    """The one place a :class:`Program` becomes C source.

    Layout is the historic ``header + decls + blank line + body`` byte
    order, so lowering through the IR is byte-identical to the previous
    direct emission."""
    return ("\n".join(program.header) + "\n"
            + "\n".join(program.decls) + "\n"
            + "\n"
            + "\n".join(program.body) + "\n")


def format_program(program: Program, *, bodies: bool = False) -> str:
    """Pretty-print a :class:`Program` (the ``tools/dump_ir.py`` view):
    every nest with its loops, kernel variant and epilogue chain, then
    the planned buffers with offsets and live ranges."""
    out: List[str] = []
    out.append(f"Program {program.func_name} [{program.precision}] "
               f"arena={program.arena_elems} elems "
               f"x {program.elem_bytes} B")
    for nest in program.nests:
        loops = " ".join(
            f"{'~' if lp.unrolled else ''}{lp.var}<{lp.bound}"
            + (f":{lp.step}" if lp.step != 1 else "")
            for lp in nest.loops) or "(straight-line)"
        out.append(f"  nest {nest.layer} [{nest.op}] "
                   f"out={nest.out_shape} stage={nest.stage}")
        out.append(f"    loops   {loops}")
        s0, s1 = nest.kernel.span
        out.append(f"    kernel  {nest.kernel.kind} <{nest.kernel.variant}> "
                   f"lines [{s0}, {s1})")
        for ep in nest.epilogue:
            det = f" {ep.detail}" if ep.detail else ""
            out.append(f"    epilog  {ep.kind} @{ep.layer}{det}")
        if bodies:
            for ln in program.body[s0:s1]:
                out.append("      | " + ln)
    for b in program.buffers:
        out.append(f"  buffer {b.name}: {b.elem} x{b.size} @ +{b.offset} "
                   f"live [{b.start}, {b.end}]")
    return "\n".join(out)


# ---------------------------------------------------------------------------
# live fusion contexts (consulted by the emitters at the store site)
# ---------------------------------------------------------------------------


def _as_index(i, j, k, *, div: Tuple[int, int], pitch: int, c: int,
              off: int = 0) -> str:
    """Build ``((i/di) * pitch + j/dj) * c + off + k`` as a C index
    expression, statically folded when every component is an int."""
    di, dj = div
    if isinstance(i, int) and isinstance(j, int):
        base = ((i // di) * pitch + j // dj) * c + off
        return str(base + k) if isinstance(k, int) else f"{base} + {k}"
    ie = f"({i})" if di == 1 else f"({i}) / {di}"
    je = f"({j})" if dj == 1 else f"({j}) / {dj}"
    pre = f"({ie} * {pitch} + {je}) * {c}"
    if isinstance(k, int):
        return f"{pre} + {off + k}"
    return f"{pre} + {k}" if off == 0 else f"{pre} + {off} + {k}"


@dataclass
class AddFuse:
    """Active residual-Add fusion while a producer's loops are emitted:
    the Add folded into the store site, the producer's position in the
    Add's (order-significant) input list, and the resolved source
    expressions of every Add operand."""

    add: object         # the Add layer
    pos: int
    srcs: List[str]


@dataclass
class PoolFuse:
    """Active pooling fusion: the producer's store site feeds the
    MaxPool/AvgPool window reduction directly (stride == window, no
    padding, so every producer element lands in exactly one window)."""

    pool: object        # the MaxPool/AvgPool layer
    kind: str           # 'max' | 'avg'
    pw: int             # pooled output width
    c: int              # channels
    sh: int             # window/stride height
    sw: int             # window/stride width
    dst: str = ""       # the pool output buffer (init/finalize target)
    n: int = 0          # pooled output element count
    inv: str = ""       # float path: 1/(kh*kw) literal for the finalize
    acc: str = ""       # int8 avg: the int32 window-sum scratch cname

    def dst_index(self, pos: Pos) -> str:
        i, j, k = pos
        return _as_index(i, j, k, div=(self.sh, self.sw),
                         pitch=self.pw, c=self.c)


@dataclass
class ConcatFuse:
    """Active Concat fusion: the producer writes its channel slice of
    the Concat output directly (its own tensor never exists)."""

    concat: object      # the Concat layer
    pos: int            # edge index in the Concat input list
    c_off: int          # channel offset of this producer's slice
    c_total: int        # Concat output channels
    ow: int             # producer (== Concat) output width

    def dst_index(self, pos: Pos) -> str:
        i, j, k = pos
        return _as_index(i, j, k, div=(1, 1), pitch=self.ow,
                         c=self.c_total, off=self.c_off)


FuseNode = Union[AddFuse, PoolFuse, ConcatFuse]
