"""Sharded checkpointing with atomic writes, resume, and elastic
resharding.

Fault-tolerance contract (DESIGN.md §9):
  * **atomic**: write to ``<dir>/tmp.<step>`` then ``rename`` — a crash
    mid-write never corrupts the latest checkpoint;
  * **restart**: ``latest_step`` + ``restore`` resume exactly;
  * **elastic**: ``restore(..., shardings=...)`` device_puts every leaf
    onto the *current* mesh, so a job restarted on a different topology
    (fewer/more pods) resumes from the same state;
  * **bounded**: ``keep`` old checkpoints are garbage-collected.

The on-disk format is one ``.npz`` per checkpoint plus a json manifest of
the pytree structure — dependency-free and host-count independent (every
host writes the same global view after an allgather-on-host; for the
1000-node deployment the same layout is written per-host-shard with the
manifest recording ownership — see ``shard_by_host``).
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, List, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    """Atomically write ``tree`` as checkpoint ``step``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    treedef = jax.tree_util.tree_structure(tree)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "treedef": str(treedef),
                   "keys": sorted(arrays)}, f)
    os.replace(tmp, final) if not os.path.exists(final) else shutil.rmtree(tmp)
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = all_steps(ckpt_dir)
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "arrays.npz")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like, *, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional pytree of NamedShardings —
    leaves are device_put onto the *current* mesh (elastic restart)."""
    path = os.path.join(ckpt_dir, f"step_{step}", "arrays.npz")
    with np.load(path) as z:
        flat_loaded = {k: z[k] for k in z.files}
    flat_like = _flatten(like)
    missing = set(flat_like) - set(flat_loaded)
    if missing:
        raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys_in_order = list(flat_like.keys())
    leaves = [flat_loaded[k].astype(l.dtype) if hasattr(l, "dtype")
              else flat_loaded[k]
              for k, l in zip(keys_in_order, leaves_like)]
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree
