"""End-to-end training driver (deliverable (b): the ~100M-param run).

Single-host by default (CPU-friendly), same code path as the production
mesh: sharded state, deterministic data, checkpoint/restart, elastic
resume. ``--preempt-at N`` kills the process after N steps to exercise
the fault-tolerance path (the integration test does exactly this and
verifies the resumed loss curve is bit-identical).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch lm-100m --steps 200
  PYTHONPATH=src python -m repro.launch.train --arch gemma3-4b --smoke
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import latest_step, restore, save
from repro.configs.lm_archs import ARCHS
from repro.data.pipeline import Prefetcher, TokenStreamConfig, token_stream
from repro.models import make_train_step
from repro.models.config import ModelConfig
from repro.models.stack import init_params
from repro.optim import AdamW, warmup_cosine

# a ~100M dense model for the end-to-end driver
LM_100M = ModelConfig(
    name="lm-100m", family="dense", n_layers=12, d_model=768, n_heads=12,
    n_kv_heads=12, d_ff=3072, vocab_size=8192, pattern="A",
    dtype="float32", remat="none")


def get_cfg(name: str, smoke: bool) -> ModelConfig:
    if name == "lm-100m":
        return LM_100M
    cfg = ARCHS[name]
    return cfg.smoke() if smoke else cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm-100m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config for a full-size arch")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--preempt-at", type=int, default=0,
                    help="simulate preemption: exit(17) after N steps")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_cfg(args.arch, args.smoke)
    cfg = dataclasses.replace(cfg, grad_accum=1)
    opt = AdamW(learning_rate=warmup_cosine(args.lr, 20, args.steps))
    step_fn = jax.jit(make_train_step(cfg, opt), donate_argnums=(0,))

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    state = (params, opt.init(params), jnp.int32(0))
    start = 0

    ckpt_dir = args.ckpt_dir or os.path.join("results", "ckpt", cfg.name)
    last = latest_step(ckpt_dir)
    if last is not None:
        state = restore(ckpt_dir, last, jax.eval_shape(lambda: state))
        state = jax.tree.map(jnp.asarray, state)
        start = last
        print(f"[train] resumed from step {last}", flush=True)

    tc = TokenStreamConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                           global_batch=args.batch, seed=args.seed)
    data = Prefetcher(token_stream(tc, start_step=start))

    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"batch={args.batch}x{args.seq}, steps {start}->{args.steps}",
          flush=True)
    t0 = time.time()
    losses = []
    for step in range(start, args.steps):
        batch = next(data)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % args.log_every == 0 or step == start:
            dt = (time.time() - t0)
            print(f"[train] step {step+1:5d} loss={losses[-1]:.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"({dt/(step-start+1):.2f}s/step)", flush=True)
        if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            save(ckpt_dir, step + 1, jax.device_get(state))
            print(f"[train] checkpoint @ {step+1}", flush=True)
        if args.preempt_at and (step + 1) == args.preempt_at:
            print("[train] simulated preemption!", flush=True)
            sys.exit(17)

    save(ckpt_dir, args.steps, jax.device_get(state))
    out = {"arch": cfg.name, "params": n_params,
           "first_loss": losses[0] if losses else None,
           "last_loss": losses[-1] if losses else None,
           "loss_curve": losses[:: max(1, len(losses) // 50)]}
    print("[train] done:", json.dumps({k: v for k, v in out.items()
                                       if k != "loss_curve"}), flush=True)
    return out


if __name__ == "__main__":
    main()
