"""Sharding rules: params, batches, caches, and the MeshPar context.

Strategy (baseline — see EXPERIMENTS.md §Perf for hillclimbed variants):

* **DP**   batch over ('pod','data') — the pod axis is pure DP, so the
           inter-pod traffic is exactly one gradient all-reduce.
* **FSDP** every weight matrix also shards one dim over 'data'; XLA
           all-gathers per layer inside the scan (ZeRO-3 style) and
           reduce-scatters gradients.
* **TP**   heads / ffw / vocab / experts-hidden shard over 'model'.
* **EP/SP** expert and sequence dims shard where divisible; any dim that
           does not divide its axis stays replicated (``_fit`` guard), so
           every (arch x shape) cell lowers without manual exceptions.
"""
from __future__ import annotations

import functools
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.moe import moe_mlp
from repro.models.stack import Par

from .mesh import axis_size, dp_axes


def _fit(mesh, dim_size: int, axes) -> Optional[Any]:
    """Return ``axes`` if dim_size divides the axis product, else None."""
    if axes is None:
        return None
    names = (axes,) if isinstance(axes, str) else tuple(axes)
    names = tuple(n for n in names if n in mesh.axis_names)
    if not names:
        return None
    total = axis_size(mesh, *names)
    if dim_size % total:
        return None
    return names if len(names) > 1 else names[0]


def spec_for(mesh, shape, axes_per_dim) -> P:
    """Build a PartitionSpec, dropping any entry that does not divide."""
    assert len(shape) == len(axes_per_dim)
    return P(*[_fit(mesh, s, a) for s, a in zip(shape, axes_per_dim)])


# ------------------------------------------------------------- param rules --

# rules keyed by leaf name -> axes for the *unstacked* trailing dims.
_PARAM_RULES: Dict[str, Tuple] = {
    "embed":     ("model", "data"),
    "head":      ("data", "model"),
    "wq":        ("data", "model"), "wk": ("data", "model"),
    "wv":        ("data", "model"), "wo": ("model", "data"),
    "bq":        ("model",), "bk": ("model",), "bv": ("model",),
    "wg":        ("data", "model"), "wu": ("data", "model"),
    "wd":        ("model", "data"),
    "router":    ("data", None),
    "shared_wg": ("data", "model"), "shared_wu": ("data", "model"),
    "shared_wd": ("model", "data"),
    # mamba2
    "w_in":      ("data", "model"), "w_out": ("model", "data"),
    "conv_w":    (None, "model"), "conv_b": ("model",),
    "w_B":       ("model", None), "w_C": ("model", None),
    "w_dt":      ("model", None),
    # rwkv6
    "w_r":       ("data", "model"), "w_k": ("data", "model"),
    "w_v":       ("data", "model"), "w_g": ("data", "model"),
    "w_o":       ("model", "data"),
    "w_dec_A":   ("data", None), "w_dec_B": (None, "data"),
    "w_ck":      ("data", "model"), "w_cv": ("model", "data"),
    "w_cr":      ("data", "model"),
}

_MOE_3D = {"wg", "wu", "wd"}  # under an (E, ., .) expert stack


def _leaf_spec(mesh, path: str, leaf) -> P:
    name = path.split("/")[-1]
    rule = _PARAM_RULES.get(name)
    if rule is None:
        return P()  # norms, scalars, decay vectors: replicated
    shape = leaf.shape
    rule = tuple(rule)
    # MoE expert stacks carry a leading E dim before the matrix dims
    if name in _MOE_3D and "mlp" in path and len(shape) >= 3 \
            and len(rule) + 1 <= len(shape):
        if os.environ.get("NNCG_MOE") == "ep":
            # EP-native storage: E over 'model', D over 'data' (FSDP),
            # full hidden — no per-layer reshard into the EP shard_map
            rule = ("model", "data", None) if name in ("wg", "wu") \
                else ("model", None, "data")
        else:
            rule = (None,) + rule
    # stacked group dim(s) in front
    pad = len(shape) - len(rule)
    rule = (None,) * pad + rule
    return spec_for(mesh, shape, rule)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


def param_specs(mesh, params_shape_tree):
    """PartitionSpec tree congruent with the params pytree (works on
    ShapeDtypeStructs from eval_shape — no allocation)."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(mesh, _path_str(path), leaf),
        params_shape_tree)


def to_named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------- batches --

def batch_specs(mesh, cfg: ModelConfig, batch_shapes: Dict[str, Any]):
    dp = dp_axes(mesh)
    out = {}
    for k, sds in batch_shapes.items():
        if k == "positions3":  # (3, B, T)
            out[k] = spec_for(mesh, sds.shape, (None, dp, None))
        elif k == "embeds":    # (B, T, D)
            out[k] = spec_for(mesh, sds.shape, (dp, None, None))
        else:                  # tokens/labels/mask/positions (B, T) or (B,1)
            out[k] = spec_for(mesh, sds.shape, (dp, None))
    return out


def cache_specs(mesh, cfg: ModelConfig, cache_shape_tree):
    """KV caches: batch over dp; kv-heads over 'model' when divisible,
    else head_dim — the SAME dim the attention einsum shards, so decode
    reads/updates are collective-free. The sequence dim stays unsharded
    (dynamic_update_slice on a sharded dim forces SPMD resharding).
    SSM/RWKV states shard their head dim. Prologue caches have one fewer
    leading dim than group caches — rules are anchored at the tail."""
    dp = dp_axes(mesh)
    model_n = axis_size(mesh, "model")
    kv_on_heads = cfg.n_kv_heads and cfg.n_kv_heads % model_n == 0

    def tail_rule(name, ndim):
        if name.endswith("k") or name.endswith("v"):   # (...,B,S,Hkv,Dh)
            tail = ((dp, None, "model", None) if kv_on_heads
                    else (dp, None, None, "model"))
        elif "ssm" in name:                             # (...,B,H,N,P)
            tail = (dp, "model", None, None)
        elif "conv" in name:                            # (...,B,K-1,d_inner)
            tail = (dp, None, "model")
        elif "wkv" in name:                             # (...,B,H,N,N)
            tail = (dp, "model", None, None)
        elif "prev" in name:                            # (...,B,D)
            tail = (dp, None)
        else:
            return (None,) * ndim
        return (None,) * (ndim - len(tail)) + tail

    def leaf(path, l):
        name = _path_str(path)
        return spec_for(mesh, l.shape, tail_rule(name, l.ndim))

    return jax.tree_util.tree_map_with_path(leaf, cache_shape_tree)


# ------------------------------------------------------------------ MoE -----

def _moe_local_specs(p_tree):
    """shard_map in_specs for the expert params: TP on the hidden dim."""
    def leaf(path, l):
        name = _path_str(path).split("/")[-1]
        if name in ("wg", "wu", "shared_wg", "shared_wu"):
            return P(*([None] * (l.ndim - 1) + ["model"]))
        if name in ("wd", "shared_wd"):
            return P(*([None] * (l.ndim - 2) + ["model", None]))
        return P()
    return jax.tree_util.tree_map_with_path(leaf, p_tree)


class MeshPar(Par):
    """Parallelism context bound to a mesh: sharding constraints on the
    GSPMD path plus a shard_map'd MoE with an explicit psum schedule."""

    def __init__(self, mesh, cfg: ModelConfig, *,
                 attn_rule: Optional[str] = None):
        self.mesh = mesh
        self.cfg = cfg
        self.dp = dp_axes(mesh)
        # hillclimb knobs (EXPERIMENTS.md §Perf); env overrides for A/B
        self.attn_rule = attn_rule or os.environ.get(
            "NNCG_ATTN_RULE", "auto")

    def _c(self, x, rule):
        spec = spec_for(self.mesh, x.shape, rule)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def constraint(self, x, kind: str):
        dp, cfg = self.dp, self.cfg
        model_n = axis_size(self.mesh, "model")
        if kind == "activations":          # (B,T,D)
            # sequence parallelism: shard T over 'model' between the TP
            # regions (falls back to replicated T when T % model != 0,
            # e.g. decode T == 1) — keeps the scan carry 1/model_n sized.
            return self._c(x, (dp, "model", None))
        if kind == "logits":               # (B,T,V)
            return self._c(x, (dp, None, "model"))
        if kind == "ssm_heads":            # (B,T,H,N) rwkv/mamba heads
            return self._c(x, (dp, None, "model", None))
        if kind in ("heads", "kv_heads"):  # (B,T,H|Hkv,Dh)
            # q and kv must shard compatibly or SPMD re-shards the
            # attention einsum (involuntary remat). Priority:
            #   1. kv heads divide 'model'  -> shard heads on q and kv
            #   2. (rule 'qshard_kvrep') q heads divide -> shard q heads,
            #      replicate kv (GQA kv-replication; attention is local)
            #   3. head_dim divides -> shard Dh on both (contraction dim)
            #   4. replicate
            if cfg.n_kv_heads and cfg.n_kv_heads % model_n == 0:
                return self._c(x, (dp, None, "model", None))
            if (self.attn_rule == "qshard_kvrep" and cfg.n_heads
                    and cfg.n_heads % model_n == 0):
                if kind == "heads":
                    return self._c(x, (dp, None, "model", None))
                return self._c(x, (dp, None, None, None))
            if cfg.head_dim and cfg.head_dim % model_n == 0:
                return self._c(x, (dp, None, None, "model"))
            return self._c(x, (dp, None, None, None))
        return x

    def moe(self, x, p, cfg: ModelConfig):
        """x: (B,T,D) — kept 3-D so the shard_map in_specs mirror the
        (dp, model-SP) activation layout exactly (flattening outside the
        shard_map loses the merged-dim tiling and forces a gather)."""
        mesh, dp = self.mesh, self.dp
        model_n = axis_size(mesh, "model")
        moe_rule = os.environ.get("NNCG_MOE", "tp")
        B, T, D = x.shape
        if moe_rule == "ep" and cfg.n_experts % model_n == 0 \
                and B % axis_size(mesh, *dp) == 0 and T % model_n == 0:
            return self._moe_ep(x, p, cfg, model_n)
        in_specs = (P(dp, None, None), _moe_local_specs(p))
        out_spec = P(dp, None, None)

        @functools.partial(shard_map, mesh=mesh, in_specs=in_specs,
                           out_specs=out_spec, check_rep=False)
        def _moe(x_local, p_local):
            b, t, d = x_local.shape
            y = moe_mlp(x_local.reshape(b * t, d), p_local,
                        top_k=cfg.top_k, act=cfg.act,
                        capacity_factor=cfg.capacity_factor)
            return jax.lax.psum(y.reshape(b, t, d), "model")

        return _moe(x, p)

    def ulysses_ok(self, cfg: ModelConfig, T: int) -> bool:
        """Ulysses sequence-parallel attention (hillclimb, §Perf):
        q heads and T must divide the model axis; kv heads either divide
        (a2a) or are small enough to all-gather (GQA kv-replication).
        Training/prefill only."""
        model_n = axis_size(self.mesh, "model")
        if not (os.environ.get("NNCG_ULYSSES") == "1" and cfg.n_heads
                and cfg.n_heads % model_n == 0 and T % model_n == 0
                and cfg.mrope_sections is None):
            return False
        if cfg.n_kv_heads % model_n == 0:
            return True
        h_loc = cfg.n_heads // model_n
        G = cfg.n_heads // cfg.n_kv_heads
        return h_loc % G == 0 or G % h_loc == 0  # group-aligned kv slice

    def ulysses_attention(self, x, p, cfg: ModelConfig, kind: str,
                          positions):
        """qkv on T-sharded activations -> all_to_all(T<->heads) ->
        full-T attention on H/model local heads -> all_to_all back.
        Wire bytes per tensor are 1/model of the Megatron-SP all-gather.
        Weights are gathered whole (FSDP gather; NOT model-sharded), so
        this trades weight residency for collective volume."""
        from repro.models.attention_vjp import flash_mha, local_mha
        from repro.models.layers import rope
        mesh, dp = self.mesh, self.dp
        B, T, D = x.shape
        H, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        w_specs = jax.tree.map(lambda l: P(*([None] * l.ndim)), p)

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(dp, "model", None), w_specs, P(dp, None)),
            out_specs=P(dp, "model", None), check_rep=False)
        def _attn(x_loc, w, pos_loc):
            b, t_loc, _ = x_loc.shape

            model_n = axis_size(mesh, "model")

            def proj(name, bias, heads):
                y = jnp.einsum("btd,df->btf", x_loc,
                               w[name].astype(x_loc.dtype))
                if bias in w:
                    y = y + w[bias].astype(y.dtype)
                y = y.reshape(b, t_loc, heads, Dh)
                if heads % model_n == 0:
                    # T-shard -> head-shard (full T locally)
                    return jax.lax.all_to_all(y, "model", split_axis=2,
                                              concat_axis=1, tiled=True)
                # GQA kv-replication: gather the (small) kv over T, then
                # keep only the kv group(s) of this device's q heads
                y = jax.lax.all_gather(y, "model", axis=1, tiled=True)
                h_loc = H // model_n
                G = H // Hkv
                n_kv_loc = max(h_loc // G, 1)
                start = (jax.lax.axis_index("model") * h_loc) // G
                return jax.lax.dynamic_slice_in_dim(y, start, n_kv_loc, 2)

            q = proj("wq", "bq", H)
            k = proj("wk", "bk", Hkv)
            v = proj("wv", "bv", Hkv)
            q = rope(q, pos_loc, cfg.rope_theta, cfg.rope_dim)
            k = rope(k, pos_loc, cfg.rope_theta, cfg.rope_dim)
            if kind == "L" and cfg.window is not None:
                o = local_mha(q, k, v, cfg.window)
            else:
                o = flash_mha(q, k, v, cfg.causal, None)
            o = jax.lax.all_to_all(o, "model", split_axis=1,
                                   concat_axis=2, tiled=True)
            o = o.reshape(b, t_loc, H * Dh)
            return jnp.einsum("btf,fd->btd", o, w["wo"].astype(o.dtype))

        return _attn(x, p, positions)

    def _moe_ep(self, x, p, cfg: ModelConfig, model_n: int):
        """Expert-parallel MoE: tokens stay (dp, model-SP) sharded,
        experts sharded over 'model' (full hidden), all_to_all routing."""
        from repro.models.moe import moe_mlp_ep
        mesh, dp = self.mesh, self.dp

        def pspec(path, l):
            name = _path_str(path).split("/")[-1]
            if name in ("wg", "wu", "wd"):
                lead = (None,) * (l.ndim - 3)
                return P(*lead, "model", None, None)   # shard E
            return P(*([None] * l.ndim))               # router/shared: repl
        p_specs = jax.tree_util.tree_map_with_path(pspec, p)

        @functools.partial(shard_map, mesh=mesh,
                           in_specs=(P(dp, "model", None), p_specs),
                           out_specs=P(dp, "model", None), check_rep=False)
        def _moe(x_local, p_local):
            b, t, d = x_local.shape
            y = moe_mlp_ep(x_local.reshape(b * t, d), p_local,
                           top_k=cfg.top_k, n_devices=model_n,
                           axis_name="model", act=cfg.act,
                           capacity_factor=cfg.capacity_factor)
            return y.reshape(b, t, d)

        return _moe(x, p)
