"""ShapeDtypeStruct stand-ins for every model input — shardable,
weak-type-correct, no device allocation (the dry-run contract)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.models.config import ModelConfig
from repro.models.stack import init_cache, init_params
from repro.optim import AdamW

from .sharding import (batch_specs, cache_specs, param_specs, spec_for,
                       to_named)


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def batch_shapes(cfg: ModelConfig, kind: str, batch: int, seq: int
                 ) -> Dict[str, Any]:
    """Abstract input batch for a (cfg, shape-kind) cell.

    Frontend-stubbed archs (audio/vlm) receive precomputed embeddings for
    train/prefill; decode always feeds tokens (text continuation)."""
    tok = jnp.int32
    if kind == "decode":
        return {"tokens": _sds((batch, 1), tok)}
    stubbed = (not cfg.embed_inputs) or cfg.mrope_sections is not None
    b: Dict[str, Any] = {}
    if stubbed:
        b["embeds"] = _sds((batch, seq, cfg.d_model), jnp.dtype(cfg.dtype))
    else:
        b["tokens"] = _sds((batch, seq), tok)
    if cfg.mrope_sections is not None:
        b["positions3"] = _sds((3, batch, seq), tok)
    if kind == "train":
        b["labels"] = _sds((batch, seq), tok)
    return b


def input_specs(cfg: ModelConfig, mesh, kind: str, batch: int, seq: int):
    """Returns (args_sds, out_shardings_hint) for the step function of
    ``kind`` — every leaf is a ShapeDtypeStruct carrying its
    NamedSharding, so ``jit(...).lower(*args_sds)`` is fully specified."""
    p_shapes = jax.eval_shape(lambda: init_params(cfg))
    p_specs = param_specs(mesh, p_shapes)
    p_named = to_named(mesh, p_specs)
    params = jax.tree.map(lambda l, s: _sds(l.shape, l.dtype, s),
                          p_shapes, p_named)

    b_shapes = batch_shapes(cfg, kind, batch, seq)
    b_named = to_named(mesh, batch_specs(mesh, cfg, b_shapes))
    batch_sds = jax.tree.map(lambda l, s: _sds(l.shape, l.dtype, s),
                             b_shapes, b_named)

    if kind == "train":
        opt = AdamW()
        o_shapes = jax.eval_shape(lambda: opt.init(p_shapes))
        opt_named_mu = to_named(mesh, param_specs(mesh, o_shapes.mu))
        opt_named_nu = to_named(mesh, param_specs(mesh, o_shapes.nu))
        mu = jax.tree.map(lambda l, s: _sds(l.shape, l.dtype, s),
                          o_shapes.mu, opt_named_mu)
        nu = jax.tree.map(lambda l, s: _sds(l.shape, l.dtype, s),
                          o_shapes.nu, opt_named_nu)
        step_sds = _sds((), jnp.int32)
        state = (params, type(o_shapes)(step=step_sds, mu=mu, nu=nu),
                 _sds((), jnp.int32))
        return (state, batch_sds)

    if kind == "prefill":
        return (params, batch_sds)

    if kind == "decode":
        c_shapes = jax.eval_shape(lambda: init_cache(cfg, batch, seq))
        c_named = to_named(mesh, cache_specs(mesh, cfg, c_shapes))
        caches = jax.tree.map(lambda l, s: _sds(l.shape, l.dtype, s),
                              c_shapes, c_named)
        pos = _sds((), jnp.int32)
        return (params, caches, batch_sds["tokens"], pos)

    raise ValueError(kind)


def output_shardings(cfg: ModelConfig, mesh, kind: str, args):
    """Pin step-function output shardings (otherwise SPMD propagation may
    materialize e.g. *unsharded* gradient trees — measured 60 GiB/buffer
    on qwen1.5-110b)."""
    from jax.sharding import PartitionSpec as P
    from .mesh import dp_axes
    rep = NamedSharding(mesh, P())
    dp = dp_axes(mesh)
    shard_of = lambda tree: jax.tree.map(lambda l: l.sharding, tree)

    if kind == "train":
        state = args[0]
        metrics = {k: rep for k in ("loss", "xent", "z_loss", "grad_norm")}
        return (shard_of(state), metrics)
    if kind == "prefill":
        if cfg.is_encoder:
            return {k: rep for k in ("loss", "xent", "z_loss")}
        batch = args[1]
        some = next(iter(batch.values()))
        B = some.shape[0] if some.shape[0] != 3 else some.shape[1]
        logits = NamedSharding(
            mesh, spec_for(mesh, (B, cfg.vocab_size), (dp, "model")))
        c_shapes = jax.eval_shape(
            lambda: init_cache(cfg, B, _prefill_len(batch)))
        caches = to_named(mesh, cache_specs(mesh, cfg, c_shapes))
        return (logits, caches, rep)
    if kind == "decode":
        caches = shard_of(args[1])
        B = args[2].shape[0]
        logits = NamedSharding(
            mesh, spec_for(mesh, (B, cfg.vocab_size), (dp, "model")))
        return (logits, caches, rep)
    raise ValueError(kind)


def _prefill_len(batch) -> int:
    for k, v in batch.items():
        if k in ("tokens", "embeds"):
            return v.shape[1]
    raise KeyError("no tokens/embeds in batch")
