import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST precede any jax import: jax locks the device
# count on first init. Tests may shrink the placeholder device count:
if os.environ.get("NNCG_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["NNCG_DRYRUN_DEVICES"])

"""Multi-pod dry-run: ``.lower().compile()`` every (arch x shape x mesh)
cell on placeholder host devices, and record memory / cost / collective
metrics for the roofline analysis.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b \
      --shape train_4k [--multipod] [--probe] [--out results/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod]
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.lm_archs import ARCHS, SHAPES, all_cells, cell_supported
from repro.models.config import ModelConfig
from repro.models.lm import (make_decode_step, make_eval_step,
                             make_prefill_step, make_train_step)
from repro.models.stack import init_cache
from repro.optim import AdamW

from .mesh import dp_axes, make_mesh, make_production_mesh
from .sharding import MeshPar
from .specs import input_specs, output_shardings

_COLLECTIVE_RE = re.compile(
    r"\b(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\s*=\s*\(?([a-z0-9\[\],{}/ ]+?)\)?\s", re.I)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def parse_collectives(hlo_text: str):
    """Sum operand sizes of every collective op in post-SPMD HLO."""
    per_kind = {}
    count = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        kind = m.group(1).lower()
        shapes = m.group(2)
        nbytes = 0
        for sm in _SHAPE_RE.finditer(shapes):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in _DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _DTYPE_BYTES[dt]
        per_kind[kind] = per_kind.get(kind, 0) + nbytes
        count[kind] = count.get(kind, 0) + 1
    return {"bytes_by_kind": per_kind, "count_by_kind": count,
            "total_bytes": sum(per_kind.values())}


def build_step(cfg: ModelConfig, mesh, kind: str, batch: int, seq: int):
    par = MeshPar(mesh, cfg)
    if kind == "train":
        opt = AdamW()
        step = make_train_step(cfg, opt, par)
        return step
    if kind == "prefill":
        if cfg.is_encoder:
            ev = make_eval_step(cfg, par)
            return lambda params, b: ev(params, {**b, "labels":
                                                 jnp.zeros((batch, seq),
                                                           jnp.int32)})
        pf = make_prefill_step(cfg, max_len=seq, par=par)
        return pf
    if kind == "decode":
        return make_decode_step(cfg, par)
    raise ValueError(kind)


def _parse_overrides(pairs):
    out = {}
    for kv in pairs or ():
        k, v = kv.split("=", 1)
        try:
            v = int(v)
        except ValueError:
            try:
                v = float(v)
            except ValueError:
                pass
        out[k] = v
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             mesh_shape=None, probe: bool = False,
             mesh_axes=None, overrides=None) -> dict:
    cfg = ARCHS[arch]
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    sh = SHAPES[shape_name]
    kind, seq, gbatch = sh["kind"], sh["seq_len"], sh["global_batch"]
    if mesh_shape:
        mesh = make_mesh(mesh_shape, mesh_axes)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    result = {"arch": arch, "shape": shape_name, "kind": kind,
              "mesh": list(tuple(mesh.shape.values())),
              "axes": list(mesh.axis_names),
              "multi_pod": multi_pod, "probe": probe, "ok": False}
    t0 = time.time()
    try:
        variants = []
        if probe:
            # two small *unrolled* lowerings -> per-group cost by finite
            # difference (scan bodies are counted once by HloCostAnalysis,
            # so the roofline extrapolates from unrolled groups instead)
            for g in (1, 2):
                variants.append((f"g{g}", dataclasses.replace(
                    cfg, n_layers=len(cfg.prologue) + len(cfg.pattern) * g,
                    scan_layers=False, grad_accum=1)))
                # grad_accum=1: the microbatch loop is a lax.scan whose
                # body HloCostAnalysis counts once — probes must see the
                # whole batch in one step for correct FLOP extrapolation.
        else:
            variants.append(("full", cfg))
        for tag, vcfg in variants:
            step = build_step(vcfg, mesh, kind, gbatch, seq)
            args = input_specs(vcfg, mesh, kind, gbatch, seq)
            # donate the state/caches buffer (in-place update on device)
            # and pin output shardings (unpinned outputs can materialize
            # unsharded gradient/cache trees)
            donate = {"train": (0,), "decode": (1,)}.get(kind, ())
            out_sh = output_shardings(vcfg, mesh, kind, args)
            with mesh:
                t_lower = time.time()
                lowered = jax.jit(step, donate_argnums=donate,
                                  out_shardings=out_sh).lower(*args)
                t_compile = time.time()
                compiled = lowered.compile()
                t_done = time.time()
                mem = compiled.memory_analysis()
                cost = compiled.cost_analysis()
                # cost_analysis() returns a per-device list of dicts on
                # some jax versions and a bare dict on others
                if isinstance(cost, (list, tuple)):
                    cost = cost[0] if cost else {}
                hlo = compiled.as_text()
            coll = parse_collectives(hlo)
            result[tag] = {
                "lower_s": round(t_compile - t_lower, 2),
                "compile_s": round(t_done - t_compile, 2),
                "flops": float(cost.get("flops", -1)),
                "bytes_accessed": float(cost.get("bytes accessed", -1)),
                "utilization_ops": {k: v for k, v in cost.items()
                                    if k.startswith("utilization")},
                "memory": {
                    "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                    "output_bytes": getattr(mem, "output_size_in_bytes", None),
                    "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                    "generated_code_bytes": getattr(
                        mem, "generated_code_size_in_bytes", None),
                },
                "collectives": coll,
                "hlo_bytes": len(hlo),
            }
        result["ok"] = True
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    result["total_s"] = round(time.time() - t0, 2)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--probe", action="store_true",
                    help="two unrolled small lowerings for cost extrapolation")
    ap.add_argument("--mesh", help="debug mesh shape, e.g. 2,2,2")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--set", action="append", dest="overrides",
                    help="config override, e.g. --set head_dim=128")
    ap.add_argument("--tag", default=None,
                    help="output filename tag (default pod/multipod/probe)")
    args = ap.parse_args()

    mesh_shape = tuple(int(x) for x in args.mesh.split(",")) if args.mesh \
        else None
    cells = (all_cells() if args.all
             else [(args.arch, args.shape)])
    os.makedirs(args.out, exist_ok=True)
    for arch, shape in cells:
        if not cell_supported(arch, shape):
            print(f"SKIP {arch} x {shape} (unsupported per DESIGN.md)")
            continue
        tag = args.tag or ("probe" if args.probe else
                           ("multipod" if args.multipod else "pod"))
        path = os.path.join(args.out, f"{arch}__{shape}__{tag}.json")
        if args.skip_existing and os.path.exists(path):
            print(f"have {path}")
            continue
        r = run_cell(arch, shape, multi_pod=args.multipod,
                     mesh_shape=mesh_shape, probe=args.probe,
                     overrides=_parse_overrides(args.overrides))
        with open(path, "w") as f:
            json.dump(r, f, indent=1)
        status = "OK" if r["ok"] else f"FAIL {r.get('error', '')[:120]}"
        print(f"{arch} x {shape} [{tag}] {status} ({r['total_s']}s)",
              flush=True)
        if r["ok"]:
            key = "full" if not args.probe else "g2"
            m = r[key]["memory"]
            print(f"   flops={r[key]['flops']:.3g} "
                  f"coll={r[key]['collectives']['total_bytes']:.3g}B "
                  f"args={m['argument_bytes']} temp={m['temp_bytes']}",
                  flush=True)


if __name__ == "__main__":
    main()
