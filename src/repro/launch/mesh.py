"""Production mesh construction.

A function, not a module-level constant — importing this module never
touches jax device state. Shapes: one v5e pod = 16x16 = 256 chips
(data, model); multi-pod = 2 pods = 512 chips with a leading 'pod' axis
that extends data parallelism across the inter-pod links.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Optional[Sequence[str]] = None):
    """Arbitrary mesh for tests/debug (e.g. (2,2,2) on 8 host devices)."""
    shape = tuple(shape)
    if axes is None:
        axes = {2: ("data", "model"),
                3: ("pod", "data", "model")}[len(shape)]
    return jax.make_mesh(shape, tuple(axes))


def dp_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axes: ('pod','data') on multi-pod, ('data',)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh, *names) -> int:
    s = 1
    for n in names:
        if n in mesh.axis_names:
            s *= mesh.shape[n]
    return s
