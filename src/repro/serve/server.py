"""Continuous-batching inference server over an :class:`InferenceSession`.

The engine predicts; this module *serves*.  Architecture::

    submit(frame) ──► bounded queue ──► worker 0 ─┐
                      (backpressure)   worker 1 ─┼─► Backend.worker()
                                       ...       │   handles (per-thread
                                                 ┘   arena workspaces)

* **Bounded request queue** — ``submit()`` on a full queue raises
  :class:`ServerOverloaded` immediately (backpressure, never a hang);
  after :meth:`InferenceServer.close` it raises :class:`ServerClosed`.
* **Dynamic batch aggregation** — a worker takes the oldest request,
  then keeps gathering until the batch hits ``max_batch`` *or* the
  oldest request's age reaches the ``batch_deadline_ms`` latency SLO,
  whichever comes first.  Workers batch independently: request A can
  be executing while request B is still aggregating (continuous
  batching, no global barrier).
* **Worker pool** — each worker thread asks the session's backend for
  a :meth:`~repro.engine.backends.Backend.worker` handle.  For the C
  backend that is a private warm liveness-planned arena driving the
  reentrant ``<func>_ws`` entry, so workers run truly in parallel
  (ctypes releases the GIL); jit-backends hand back themselves.  The
  session's autotuning already persisted to the on-disk tuning cache,
  so every worker starts warm — no per-worker compiles.
* **Per-request timeout** — a request that waited longer than
  ``request_timeout_ms`` in the queue fails with
  :class:`RequestTimeout` instead of wasting a batch slot.
* **Graceful shutdown** — ``close(drain=True)`` stops intake, lets the
  workers drain every queued request, then joins them; ``drain=False``
  fails queued requests with :class:`ServerClosed`.
* **Observability** — per-request stage timestamps on the returned
  :class:`InferenceResult`, and rolling p50/p99 latency, queue depth,
  batch occupancy, QPS and rejection counters via :meth:`stats`.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Dict, Optional, Union

import numpy as np

from repro.engine.backends import Backend
from repro.engine.session import InferenceSession

from .stats import ServerStats


class ServeError(RuntimeError):
    """Base class for serving failures."""


class ServerOverloaded(ServeError):
    """Bounded queue is full — backpressure; retry later or shed load."""


class ServerClosed(ServeError):
    """The server is shutting down (or closed) and rejects new work."""


class RequestTimeout(ServeError):
    """The request exceeded ``request_timeout_ms`` before execution."""


@dataclass(frozen=True)
class ServerConfig:
    """Serving knobs (the session's build knobs live in
    :class:`repro.engine.SessionConfig`).

    ``batch_deadline_ms`` is the aggregation SLO: a batch closes when
    its *oldest* request has waited this long, even at occupancy 1 —
    the knob trades batch efficiency against queueing latency.
    ``request_timeout_ms=None`` disables the per-request timeout.
    """

    workers: int = 2
    max_batch: int = 8
    max_queue: int = 256
    batch_deadline_ms: float = 2.0
    request_timeout_ms: Optional[float] = 1000.0
    stats_window: int = 2048
    warmup: bool = True

    def __post_init__(self):
        if self.workers < 1:
            raise ValueError(f"workers {self.workers} < 1")
        if self.max_batch < 1:
            raise ValueError(f"max_batch {self.max_batch} < 1")
        if self.max_queue < 1:
            raise ValueError(f"max_queue {self.max_queue} < 1")
        if self.batch_deadline_ms < 0:
            raise ValueError(
                f"batch_deadline_ms {self.batch_deadline_ms} < 0")


class InferenceResult:
    """Future for one submitted frame.

    ``result()`` blocks for the output (re-raising the server-side
    failure, e.g. :class:`RequestTimeout`); ``timestamps`` carries the
    per-stage ``perf_counter`` stamps (``submit``, ``dequeue``,
    ``exec_start``, ``done``) once complete, plus the batch size the
    request rode in — the raw material for any latency breakdown.

    Completion signalling rides one server-wide condition variable
    (a per-request ``threading.Event`` costs ~3µs to allocate and a
    wakeup to set — at tens of kQPS that is real throughput; one
    ``notify_all`` per *batch* is ~free)."""

    __slots__ = ("x", "_cond", "_done", "_value", "_error", "timestamps",
                 "batch_size")

    def __init__(self, x: np.ndarray, cond: threading.Condition):
        self.x = x
        self._cond = cond
        self._done = False
        self._value: Optional[np.ndarray] = None
        self._error: Optional[BaseException] = None
        self.timestamps: Dict[str, float] = {"submit": time.perf_counter()}
        self.batch_size: Optional[int] = None

    def done(self) -> bool:
        return self._done

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        if not self._done:
            with self._cond:
                if not self._cond.wait_for(lambda: self._done, timeout):
                    raise TimeoutError(
                        "result() timed out waiting for the server")
        if self._error is not None:
            raise self._error
        return self._value

    # server side: set the payload, then publish under the condition —
    # callers go through InferenceServer._finish/_finish_many
    def _set(self, value: Optional[np.ndarray],
             error: Optional[BaseException] = None,
             done_at: Optional[float] = None) -> None:
        self._value = value
        self._error = error
        self.timestamps["done"] = (time.perf_counter()
                                   if done_at is None else done_at)


class InferenceServer:
    """Continuous-batching server over a session (or bare backend).

    >>> sess = InferenceSession(graph, config=SessionConfig(autotune=True))
    >>> with InferenceServer(sess, config=ServerConfig(workers=4)) as srv:
    ...     y = srv.predict(frame)            # sync convenience
    ...     handle = srv.submit(frame)        # async
    ...     y2 = handle.result(timeout=1.0)
    ...     print(srv.stats()["latency_p99_us"])
    """

    def __init__(self, session: Union[InferenceSession, Backend], *,
                 config: Optional[ServerConfig] = None, **kw):
        if config is None:
            config = ServerConfig(**kw)
        elif kw:
            raise TypeError(
                "InferenceServer: pass either config= or kwargs, not both")
        self.config = config
        self._backend = (session.backend
                         if isinstance(session, InferenceSession)
                         else session)
        self.session = (session if isinstance(session, InferenceSession)
                        else None)
        graph = getattr(self._backend, "graph", None)
        self.in_shape = (tuple(graph.input_shape) if graph is not None
                         else None)  # LM backends: token-level, no frame shape
        # graph-level schedule fact, surfaced in stats(): a layer-
        # pipelined C build streams each aggregated batch through its
        # stage threads (the worker handle routes batches >1 to the
        # pipeline runner), so batch occupancy is also the pipeline's
        # fill — operators need to see both to read the numbers
        self._pipeline_stages = int(
            self._backend.describe().get("pipeline_stages") or 1)
        self._queue: "queue.Queue[InferenceResult]" = queue.Queue(
            maxsize=config.max_queue)
        self.stats_ = ServerStats(window=config.stats_window)
        self._cond = threading.Condition()   # completion signalling
        self._closing = threading.Event()
        self._drain = True
        self._workers = [
            threading.Thread(target=self._worker_loop, name=f"serve-w{i}",
                             daemon=True)
            for i in range(config.workers)]
        for t in self._workers:
            t.start()

    # -- client side ---------------------------------------------------------

    def submit(self, x: np.ndarray) -> InferenceResult:
        """Enqueue one frame ``(*in_shape)``; returns a future.

        Raises :class:`ServerClosed` after shutdown began and
        :class:`ServerOverloaded` when the bounded queue is full — both
        immediately, never blocking the caller.
        """
        if self._closing.is_set():
            self.stats_.on_reject(closed=True)
            raise ServerClosed("server is shut down")
        x = np.ascontiguousarray(x, dtype=np.float32)
        if tuple(x.shape) != self.in_shape:
            raise ValueError(
                f"submit expects one frame of {self.in_shape}, "
                f"got {x.shape}")
        return self._enqueue(InferenceResult(x, self._cond))

    def _enqueue(self, req: InferenceResult) -> InferenceResult:
        """Bounded-queue admission shared by every request flavor."""
        try:
            self._queue.put_nowait(req)
        except queue.Full:
            self.stats_.on_reject(closed=False)
            raise ServerOverloaded(
                f"request queue full ({self.config.max_queue}); "
                f"retry later") from None
        self.stats_.on_submit()
        return req

    def predict(self, x: np.ndarray,
                timeout: Optional[float] = None) -> np.ndarray:
        """Synchronous submit + wait."""
        return self.submit(x).result(timeout)

    def stats(self) -> Dict[str, float]:
        """Rolling counters/percentiles; see :class:`ServerStats`."""
        d = self.stats_.snapshot()
        d["queue_depth"] = self._queue.qsize()
        d["workers"] = self.config.workers
        d["max_batch"] = self.config.max_batch
        d["pipeline_stages"] = self._pipeline_stages
        if d["batches"]:
            d["batch_occupancy"] = (d["batch_size_mean"]
                                    / self.config.max_batch)
        return d

    def close(self, *, drain: bool = True,
              timeout: Optional[float] = None) -> None:
        """Stop intake; with ``drain`` finish queued work, else fail it
        with :class:`ServerClosed`.  Idempotent."""
        self._drain = drain
        self._closing.set()
        if not drain:
            while True:
                try:
                    req = self._queue.get_nowait()
                except queue.Empty:
                    break
                self._finish(req, None, ServerClosed("server closed"))
        for t in self._workers:
            t.join(timeout)
        backend_close = getattr(self._backend, "close", None)
        if backend_close is not None:
            backend_close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- completion plumbing -------------------------------------------------

    def _finish(self, req: InferenceResult, value,
                error: Optional[BaseException] = None) -> None:
        req._set(value, error)
        with self._cond:
            req._done = True
            self._cond.notify_all()

    def _finish_many(self, reqs) -> None:
        """Publish a batch of already-``_set`` requests under one
        condition acquisition + one wakeup."""
        with self._cond:
            for r in reqs:
                r._done = True
            self._cond.notify_all()

    # -- worker side ---------------------------------------------------------

    def _warmup(self, handle: Backend) -> None:
        """Fault in the handle's arena pages / jit once, off the latency
        path of the first real request (overridden per workload)."""
        handle.predict_batch(
            np.zeros((1,) + self.in_shape, dtype=np.float32))

    def _execute(self, handle: Backend, live) -> list:
        """Run one aggregated batch; returns per-request outputs in
        order.  The frame workload stacks into one ``predict_batch``
        call; the token workload overrides this."""
        return list(handle.predict_batch(np.stack([r.x for r in live])))

    def _worker_loop(self) -> None:
        handle = self._backend.worker()
        if self.config.warmup:
            self._warmup(handle)
        deadline_s = self.config.batch_deadline_ms / 1e3
        try:
            while True:
                try:
                    first = self._queue.get(timeout=0.02)
                except queue.Empty:
                    if self._closing.is_set():
                        return
                    continue
                batch = [first]
                close_at = first.timestamps["submit"] + deadline_s
                while len(batch) < self.config.max_batch:
                    rest = close_at - time.perf_counter()
                    if rest <= 0:
                        # past the SLO deadline: take whatever is
                        # already queued (a backlog wants the biggest
                        # batch it can get) but never *wait* for more
                        try:
                            batch.append(self._queue.get_nowait())
                        except queue.Empty:
                            break
                    else:
                        try:
                            batch.append(self._queue.get(timeout=rest))
                        except queue.Empty:
                            break
                self._run_batch(handle, batch)
        finally:
            close = getattr(handle, "close", None)
            if close is not None and handle is not self._backend:
                close()

    def _run_batch(self, handle: Backend, batch) -> None:
        t_deq = time.perf_counter()
        live = []
        tmo = self.config.request_timeout_ms
        for req in batch:
            req.timestamps["dequeue"] = t_deq
            if (tmo is not None
                    and (t_deq - req.timestamps["submit"]) * 1e3 > tmo):
                self.stats_.on_timeout()
                self._finish(req, None, RequestTimeout(
                    f"spent >{tmo}ms queued (server overloaded?)"))
                continue
            if not self._drain and self._closing.is_set():
                self._finish(req, None, ServerClosed("server closed"))
                continue
            live.append(req)
        if not live:
            return
        self.stats_.on_batch(len(live))
        t_exec = time.perf_counter()
        try:
            out = self._execute(handle, live)
        except BaseException as e:  # surface to every waiter
            for req in live:
                self.stats_.on_failure()
                self._finish(req, None, e)
            return
        t_done = time.perf_counter()
        exec_us = (t_done - t_exec) * 1e6
        nlive = len(live)
        totals, qwaits = [], []
        for i, req in enumerate(live):
            req.timestamps["exec_start"] = t_exec
            req.batch_size = nlive
            req._set(out[i], done_at=t_done)
            t_sub = req.timestamps["submit"]
            totals.append((t_done - t_sub) * 1e6)
            qwaits.append((t_deq - t_sub) * 1e6)
        self._finish_many(live)
        self.stats_.on_complete_batch(totals, qwaits, exec_us, now=t_done)


class LMTokenServer(InferenceServer):
    """Token-level requests through the same bounded queue / worker pool
    / SLO aggregation / stats machinery the frame server uses.

    >>> sess = LMSession(config=SessionConfig(backend="pallas-lm",
    ...                                       lm=LMConfig(...)))
    >>> with LMTokenServer(sess, workers=1) as srv:
    ...     toks = srv.generate(prompt_ids, max_new=16)

    A request is a 1-D int prompt plus ``max_new``; the result is the
    ``(max_new,)`` greedy continuation.  Aggregated batches are grouped
    by ``(prompt_len, max_new)`` — compatible requests ride one
    :meth:`~repro.engine.backends.LMBackend.generate` call (one prefill,
    shared decode steps), incompatible ones still execute in the same
    dequeue round rather than waiting for a same-shape partner.
    """

    def __init__(self, session, *, config: Optional[ServerConfig] = None,
                 **kw):
        from repro.engine.backends import LMBackend
        from repro.engine.lm import LMSession
        self.lm_session = session if isinstance(session, LMSession) else None
        backend = (session.backend if self.lm_session is not None
                   else session)
        if not isinstance(backend, LMBackend):
            raise TypeError(
                f"LMTokenServer needs an LMSession or LMBackend, got "
                f"{type(session).__name__}")
        super().__init__(backend, config=config, **kw)

    # -- client side ---------------------------------------------------------

    def submit(self, tokens: np.ndarray,
               max_new: int = 16) -> InferenceResult:
        """Enqueue one 1-D int prompt; the future resolves to the
        ``(max_new,)`` int32 greedy continuation."""
        if self._closing.is_set():
            self.stats_.on_reject(closed=True)
            raise ServerClosed("server is shut down")
        toks = np.asarray(tokens)
        if toks.ndim != 1 or not np.issubdtype(toks.dtype, np.integer):
            raise ValueError(
                f"submit expects a 1-D int token prompt, got shape "
                f"{toks.shape} dtype {toks.dtype}")
        if max_new < 1:
            raise ValueError(f"max_new {max_new} < 1")
        req = InferenceResult((np.ascontiguousarray(toks, np.int32),
                               int(max_new)), self._cond)
        return self._enqueue(req)

    def generate(self, tokens: np.ndarray, max_new: int = 16,
                 timeout: Optional[float] = None) -> np.ndarray:
        """Synchronous submit + wait."""
        return self.submit(tokens, max_new).result(timeout)

    def predict(self, x, timeout: Optional[float] = None):
        raise TypeError("LMTokenServer serves tokens: use generate()")

    # -- worker side ---------------------------------------------------------

    def _warmup(self, handle) -> None:
        # prefill is shape-specialized per prompt length: a dummy-shape
        # warmup would compile a program no real request reuses
        pass

    def _execute(self, handle, live) -> list:
        outs: list = [None] * len(live)
        groups: Dict[tuple, list] = {}
        for i, req in enumerate(live):
            toks, max_new = req.x
            groups.setdefault((toks.shape[0], max_new), []).append(i)
        for (_, max_new), idxs in groups.items():
            prompts = np.stack([live[i].x[0] for i in idxs])
            gen = handle.generate(prompts, max_new)
            for j, i in enumerate(idxs):
                outs[i] = gen[j]
        return outs
