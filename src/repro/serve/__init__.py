"""Continuous-batching inference serving on top of the engine.

    from repro.engine import InferenceSession, SessionConfig
    from repro.serve import InferenceServer, ServerConfig

    sess = InferenceSession(graph, config=SessionConfig(autotune=True))
    with InferenceServer(sess, config=ServerConfig(workers=4,
                                                   max_batch=8,
                                                   batch_deadline_ms=2)) as srv:
        y = srv.predict(frame)
        print(srv.stats()["latency_p99_us"], srv.stats()["qps"])

See :mod:`repro.serve.server` for the architecture.
"""
from .server import (InferenceResult, InferenceServer, LMTokenServer,
                     RequestTimeout, ServeError, ServerClosed, ServerConfig,
                     ServerOverloaded)
from .stats import ServerStats

__all__ = [
    "InferenceResult",
    "InferenceServer",
    "LMTokenServer",
    "RequestTimeout",
    "ServeError",
    "ServerClosed",
    "ServerConfig",
    "ServerOverloaded",
    "ServerStats",
]
