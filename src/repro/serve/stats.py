"""Rolling server observability: latency percentiles, throughput,
queue/batch counters.

One :class:`ServerStats` instance per server, shared by every worker
thread; all mutation happens under one lock (the critical sections are
a few appends — contention is negligible next to a model forward).
Samples live in bounded deques so a long-running server reports
*recent* behavior, not its lifetime average.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, Optional

import numpy as np


def _pct(samples, q: float) -> float:
    if not samples:
        return float("nan")
    return float(np.percentile(np.asarray(samples, dtype=np.float64), q))


class ServerStats:
    """Counters + rolling windows for :class:`repro.serve.InferenceServer`.

    Latency samples are microseconds, split per stage:

    * ``queue_wait`` — submit -> picked up by a worker
    * ``exec``       — worker batch-forward wall time (per request)
    * ``total``      — submit -> result ready (what the client feels)
    """

    def __init__(self, window: int = 2048):
        self._lock = threading.Lock()
        self.window = int(window)
        self._total_us = deque(maxlen=self.window)
        self._queue_wait_us = deque(maxlen=self.window)
        self._exec_us = deque(maxlen=self.window)
        self._batch_sizes = deque(maxlen=self.window)
        self._done_at = deque(maxlen=self.window)   # completion stamps
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.timeouts = 0
        self.rejected_queue_full = 0
        self.rejected_closed = 0
        self.batches = 0

    # -- recording (called by server/workers) -----------------------------

    def on_submit(self) -> None:
        with self._lock:
            self.submitted += 1

    def on_reject(self, *, closed: bool) -> None:
        with self._lock:
            if closed:
                self.rejected_closed += 1
            else:
                self.rejected_queue_full += 1

    def on_timeout(self) -> None:
        with self._lock:
            self.timeouts += 1

    def on_failure(self) -> None:
        with self._lock:
            self.failed += 1

    def on_batch(self, size: int) -> None:
        with self._lock:
            self.batches += 1
            self._batch_sizes.append(size)

    def on_complete(self, *, total_us: float, queue_wait_us: float,
                    exec_us: float, now: Optional[float] = None) -> None:
        self.on_complete_batch([total_us], [queue_wait_us], exec_us, now=now)

    def on_complete_batch(self, totals_us, queue_waits_us, exec_us: float,
                          now: Optional[float] = None) -> None:
        """Record a whole batch under one lock acquisition — the server
        hot path calls this once per batch, not once per request."""
        if now is None:
            now = time.perf_counter()
        with self._lock:
            self.completed += len(totals_us)
            self._total_us.extend(totals_us)
            self._queue_wait_us.extend(queue_waits_us)
            self._exec_us.extend(exec_us for _ in totals_us)
            self._done_at.extend(now for _ in totals_us)

    # -- reading -----------------------------------------------------------

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            total = list(self._total_us)
            qwait = list(self._queue_wait_us)
            execu = list(self._exec_us)
            sizes = list(self._batch_sizes)
            done = list(self._done_at)
            counters = dict(
                submitted=self.submitted, completed=self.completed,
                failed=self.failed, timeouts=self.timeouts,
                rejected_queue_full=self.rejected_queue_full,
                rejected_closed=self.rejected_closed, batches=self.batches)
        qps = 0.0
        if len(done) >= 2:
            span = done[-1] - done[0]
            if span > 0:
                # the window holds len(done) completions over `span`
                # seconds between the first and last stamp
                qps = (len(done) - 1) / span
        out: Dict[str, float] = dict(counters)
        out.update(
            latency_p50_us=_pct(total, 50), latency_p99_us=_pct(total, 99),
            queue_wait_p50_us=_pct(qwait, 50),
            queue_wait_p99_us=_pct(qwait, 99),
            exec_p50_us=_pct(execu, 50), exec_p99_us=_pct(execu, 99),
            batch_size_mean=float(np.mean(sizes)) if sizes else float("nan"),
            batch_size_max=float(max(sizes)) if sizes else float("nan"),
            qps=qps,
            window=self.window,
        )
        return out
