"""Per-layer codegen-variant autotuner with an on-disk tuning cache.

The paper's headline speed-ups come from *measuring* every generated
code version per layer and keeping the fastest ("we independently
benchmark every code version and select the one with the best runtime
performance", Table VII).  This module makes that selection a reusable,
cached engine component:

* :class:`Autotuner` — greedy coordinate descent over the per-layer
  unroll-level space from :func:`repro.core.cgen.enumerate_variants`,
  timing each fully-compiled candidate net on the host.
* :class:`TuningCache` — JSON records keyed by
  ``(graph fingerprint, ISA, compiler fingerprint)`` so a repeat build
  of the same trained model on the same toolchain compiles nothing.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core import cgen, runtime
from repro.core.graph import CNNGraph
from repro.core.runtime import cc_fingerprint  # part of the cache key
from repro.core.schedule import Schedule, make_schedule

DEFAULT_CACHE_DIR = os.path.join(tempfile.gettempdir(), "nncg_cache",
                                 "tuning")


def graph_fingerprint(graph: CNNGraph) -> str:
    """Content hash of a trained graph: topology, layer names, structure,
    weights.

    Two graphs with the same fingerprint generate byte-identical C for
    any codegen options, so tuning results transfer exactly.  The DAG
    edges (``layer.inputs``) participate — two nets with identical layer
    stacks but different wiring (e.g. with/without a residual skip) are
    different programs.  Layer names participate because cached unroll
    selections are keyed by layer name (``CodegenOptions.level_for``).
    """
    h = hashlib.sha256()
    for layer in graph.layers:
        h.update(type(layer).__name__.encode())
        h.update(f"name={layer.name!r};".encode())
        h.update(f"inputs={list(layer.inputs)!r};".encode())
        for attr in ("shape", "strides", "padding", "activation", "alpha",
                     "size", "eps", "rate"):
            if hasattr(layer, attr):
                h.update(f"{attr}={getattr(layer, attr)!r};".encode())
        for attr in ("weights", "bias", "mean", "var", "gamma", "beta"):
            v = getattr(layer, attr, None)
            if v is not None:
                # shape participates: byte-identical weights factored
                # differently (HWIO vs HWCM splits) are different programs
                h.update(f"{attr}{tuple(np.shape(v))};".encode())
                h.update(np.ascontiguousarray(v, np.float32).tobytes())
    return h.hexdigest()


class TuningCache:
    """One JSON file per (graph, ISA, compiler) key under ``path``."""

    def __init__(self, path: Optional[str] = None):
        self.path = str(path) if path is not None else DEFAULT_CACHE_DIR

    def key(self, graph: CNNGraph, simd: str, extra: str = "") -> str:
        """Cache key over everything the measurement depends on: the
        trained graph, SIMD mode, compiler, codegen version, and (via
        ``extra``) the tuner's own search/measurement parameters."""
        raw = (f"{graph_fingerprint(graph)}:{simd}:{cc_fingerprint()}"
               f":v{cgen.CODEGEN_VERSION}:{extra}")
        return self.key_raw(raw)

    @staticmethod
    def key_raw(raw: str) -> str:
        """Key an arbitrary pre-built dependency string — the LM variant
        tuner keys on (arch, shape, device) instead of a CNNGraph."""
        return hashlib.sha256(raw.encode()).hexdigest()[:24]

    def _file(self, key: str) -> str:
        return os.path.join(self.path, f"{key}.json")

    def get(self, key: str) -> Optional[dict]:
        try:
            with open(self._file(key)) as f:
                return json.load(f)
        except (OSError, json.JSONDecodeError):
            return None

    def put(self, key: str, record: dict) -> None:
        os.makedirs(self.path, exist_ok=True)
        tmp = self._file(key) + ".tmp"
        with open(tmp, "w") as f:
            json.dump(record, f, indent=1)
        os.replace(tmp, self._file(key))


@dataclass
class TuneResult:
    levels: Dict[str, cgen.Level]  # per-layer unroll selection
    us_per_call: float             # measured latency of the winner
    from_cache: bool               # True if no benchmarking happened
    term_cap: int = 200_000        # emission budget the levels assume —
                                   # the final build must use the same


class Autotuner:
    """Greedy per-layer variant selection for the C backend.

    Starts from the static :func:`cgen.choose_levels` heuristic, then
    for each Conv2D/MaxPool layer tries every feasible unroll level
    (holding the others fixed) and keeps any strict improvement —
    exactly the paper's per-layer benchmark-and-select, with results
    persisted through :class:`TuningCache`.
    """

    def __init__(self, simd: str, *, start_budget: int = 20_000,
                 term_cap: int = 200_000, iters: int = 300,
                 repeats: int = 3, cache: Optional[TuningCache] = None,
                 schedule: Optional[Schedule] = None):
        self.simd = simd
        self.start_budget = start_budget
        self.term_cap = term_cap
        self.iters = iters
        self.repeats = max(1, repeats)
        self.cache = cache
        # the graph-level schedule (fusion + stage partition) the
        # deployed build will use: tuned levels are measured under the
        # same generated code, and the digest keys the cached record —
        # a different schedule is a different program to tune
        self.schedule = schedule

    def _params_key(self) -> str:
        key = (f"b{self.start_budget}:t{self.term_cap}:i{self.iters}"
               f":r{self.repeats}")
        if self.schedule is not None:
            key += f":sched:{self.schedule.digest()}"
        return key

    def _time(self, graph: CNNGraph, levels: Dict[str, cgen.Level],
              x: np.ndarray) -> float:
        # term_budget = term_cap so every explored level is actually
        # emitted as requested (the default budget would silently
        # demote deep levels and make distinct trials identical code)
        net = runtime.build(graph, cgen.CodegenOptions(
            simd=self.simd, unroll=dict(levels),
            term_budget=self.term_cap), schedule=self.schedule)
        # min over repeats: robust to scheduler noise, which would
        # otherwise persist a wrong selection into the tuning cache
        return min(
            net.time_per_call_us(x, iters=self.iters,
                                 warmup=max(10, self.iters // 10))
            for _ in range(self.repeats))

    def tune(self, graph: CNNGraph,
             x: Optional[np.ndarray] = None) -> TuneResult:
        if self.cache is not None:
            key = self.cache.key(graph, self.simd, self._params_key())
            rec = self.cache.get(key)
            if rec is not None:
                return TuneResult(levels=dict(rec["levels"]),
                                  us_per_call=float(rec["us_per_call"]),
                                  from_cache=True,
                                  term_cap=self.term_cap)
        if x is None:
            x = np.random.default_rng(0).normal(
                size=graph.input_shape).astype(np.float32)

        # per-layer *input* shapes via the DAG edges (branch layers get
        # their true producer shapes, not list-adjacent ones)
        smap = graph.shape_map()
        shapes: Dict[str, tuple] = {
            layer.name: (smap[layer.inputs[0]] if layer.inputs else None)
            for layer in graph.layers
        }

        levels = cgen.choose_levels(graph, self.start_budget)
        best = self._time(graph, levels, x)
        for layer in graph.layers:
            for lvl in cgen.enumerate_variants(layer, shapes[layer.name],
                                               term_cap=self.term_cap):
                if levels.get(layer.name) == lvl:
                    continue
                trial = dict(levels)
                trial[layer.name] = lvl
                t = self._time(graph, trial, x)
                if t < best:
                    best, levels = t, trial

        if self.cache is not None:
            self.cache.put(key, {
                "levels": levels,
                "us_per_call": best,
                "simd": self.simd,
                "cc": cc_fingerprint(),
                "graph": graph_fingerprint(graph),
            })
        return TuneResult(levels=levels, us_per_call=best, from_cache=False,
                          term_cap=self.term_cap)


def int8_variant_candidates(qgraph=None) -> List[str]:
    """The int8 kernel variants worth timing on this host, best-first.

    Starts from :func:`runtime.supported_int8_simds` (the CPU-feature
    guard — a variant the host can't execute is never enumerated, let
    alone loaded), then drops ``avx_ubs`` when no layer of ``qgraph``
    passes the static ``vpmaddubsw`` saturation proof: that build
    would demote every layer to the plain ``avx`` tile, so timing it
    would only duplicate the ``avx`` candidate."""
    cands = runtime.supported_int8_simds()
    if qgraph is not None and "avx_ubs" in cands \
            and not cgen.maddubsw_any_eligible(qgraph):
        cands = [c for c in cands if c != "avx_ubs"]
    return cands


def fusion_schedule_candidates(graph: CNNGraph, *,
                               nstages: int = 1) -> List[Schedule]:
    """Schedule variants the int8 autotuner times, deduped by digest.

    Fusion kinds are a code-variant axis: fused output is bit-identical
    to unfused, but on layers with channel-group tails a fused requant
    epilogue can lose more than the skipped memory round-trip buys — so
    each kind subset that yields a distinct program is timed like any
    other code version.  Subsets are nested (all kinds ⊃ Adds-only ⊃
    none) rather than the full power set: the pool/Concat fusions
    landed together and share the tail-sensitivity concern, while Add
    fusion predates them with its own track record."""
    cands: List[Schedule] = []
    seen = set()
    for kinds in (("add", "pool", "concat"), ("add",), ()):
        s = make_schedule(graph, nstages=nstages,
                          fusion=bool(kinds), kinds=kinds or ("add",))
        d = s.digest()
        if d not in seen:
            seen.add(d)
            cands.append(s)
    return cands


def pipeline_stage_candidates(max_stages: int = 4) -> List[int]:
    """Stage counts worth timing on this host: layer pipelining trades
    one inter-stage hand-off per frame for stage-level core
    parallelism, so counts beyond the core budget only add overhead —
    a single-core host gets ``[1]`` and times nothing."""
    cores = os.cpu_count() or 1
    return [1] + [s for s in range(2, max_stages + 1) if s <= cores]


def tune_pipeline_stages(graph: CNNGraph, *, simd: str, qgraph=None,
                         cache: Optional[TuningCache] = None,
                         fusion: bool = True, iters: int = 32,
                         func_name: str = "nncg_net",
                         candidates: Optional[List[int]] = None) -> int:
    """Third variant axis: the pipeline stage count.

    Times a batch-1 frame *stream* (the pipeline's target workload —
    per-frame latency through ``predict_batch``) for every viable stage
    count and returns the fastest; the winner persists in the tuning
    cache keyed alongside the fusion flag, host core count, simd and
    precision, so a repeat session streams nothing."""
    if candidates is None:
        candidates = pipeline_stage_candidates()
    if len(candidates) == 1:
        return candidates[0]
    cache = cache or TuningCache()
    extra = (f"pipe:{'+'.join(map(str, candidates))}:f{int(fusion)}"
             f":i{iters}:c{os.cpu_count() or 1}"
             + (":int8" if qgraph is not None else ""))
    key = cache.key(graph, simd, extra=extra)
    rec = cache.get(key)
    if rec is not None and rec.get("nstages") in candidates:
        return int(rec["nstages"])
    n = max(8, int(iters))
    x = np.random.default_rng(0).normal(
        size=(n,) + tuple(graph.input_shape)).astype(np.float32)
    # rolled loops for the stage-count trials: the relative stage
    # balance survives the emission style, and candidate builds at the
    # default full unroll would dwarf the measurement in compile time
    opts = cgen.CodegenOptions(simd=simd, func_name=func_name,
                               unroll=None)
    best = None
    for S in candidates:
        sched = make_schedule(graph, nstages=S, fusion=fusion)
        net = (runtime.build_quantized(qgraph, opts, schedule=sched)
               if qgraph is not None
               else runtime.build(graph, opts, schedule=sched))
        net.predict_batch(x[:min(4, n)])  # warm caches + threads
        t = None
        for _ in range(2):  # min over repeats: scheduler-noise guard
            t0 = time.perf_counter()
            net.predict_batch(x)
            dt = time.perf_counter() - t0
            t = dt if t is None else min(t, dt)
        if best is None or t < best[0]:
            best = (t, S)
    cache.put(key, {"nstages": best[1],
                    "stream_us_per_frame": round(best[0] / n * 1e6, 3)})
    return best[1]


def tune_best_simd(graph: CNNGraph, simds, *,
                   x: Optional[np.ndarray] = None,
                   cache: Optional[TuningCache] = None,
                   **tuner_kw):
    """Second variant axis: run the per-layer tuner under each SIMD mode
    and keep the overall fastest. Returns ``(simd, TuneResult)``.

    Cached candidates are re-*timed* (never re-tuned, and with the .so
    content cache no recompile happens) so the cross-mode comparison
    uses measurements taken under the same machine conditions — a
    cached number from an earlier, differently-loaded run must not
    decide the selection.
    """
    if x is None:
        x = np.random.default_rng(0).normal(
            size=graph.input_shape).astype(np.float32)
    best_simd, best_res, best_us = None, None, None
    for simd in simds:
        tuner = Autotuner(simd, cache=cache, **tuner_kw)
        res = tuner.tune(graph, x)
        us = (tuner._time(graph, res.levels, x) if res.from_cache
              else res.us_per_call)
        if best_us is None or us < best_us:
            best_simd, best_res, best_us = simd, res, us
    if best_simd is None:
        raise ValueError("tune_best_simd: empty simd candidate list")
    return best_simd, best_res


# ============================================================ LM variants ====

def lm_fingerprint(model_cfg) -> str:
    """Content hash of a ModelConfig: the LM analogue of
    :func:`graph_fingerprint`.  LM weights are randomly initialized or
    caller-supplied (no trained artifact to hash), so the *architecture*
    is the program identity the kernel-variant measurement depends on."""
    import dataclasses as _dc
    d = _dc.asdict(model_cfg)
    raw = json.dumps(d, sort_keys=True, default=str)
    return hashlib.sha256(raw.encode()).hexdigest()[:16]


def device_digest() -> str:
    """What the LM measurement runs on — the jax analogue of
    :func:`cc_fingerprint` in the C cache key."""
    import jax
    devs = jax.devices()
    return (f"{devs[0].platform}:{getattr(devs[0], 'device_kind', '?')}"
            f":n{len(devs)}")


@dataclass
class LMTuneResult:
    policy: "object"      # repro.models.kernel_policy.KernelPolicy
    prefill_us: float     # measured prefill latency of the winner
    from_cache: bool


_LM_BLOCK_CANDIDATES = (128, 256, 512)


def tune_lm_variants(model_cfg, params, *, max_context: int,
                     batch: int = 1, prompt: int = 16,
                     cache: Optional[TuningCache] = None, iters: int = 3,
                     fixed: Optional[dict] = None,
                     par=None) -> LMTuneResult:
    """Fourth variant axis family: the Pallas kernel variants of the LM
    stack, tuned exactly like C unroll levels — timed candidates, greedy
    per-axis descent, winner persisted in the tuning cache.

    Axes (each skipped when the arch has no such layer, or when the
    caller pinned it via ``fixed``):

    * attention kernel (``flash_jax`` / ``flash_pallas`` / ``reference``)
      for archs with A/L/S blocks,
    * flash block sizes for the attention winner,
    * RWKV scan kernel (``chunked`` / ``linear_scan``) for R blocks.

    The cache key is (arch fingerprint, prefill shape, device digest,
    measurement params) — no CNNGraph involved."""
    import jax
    import jax.numpy as jnp

    from repro.models import lm as lm_mod
    from repro.models.kernel_policy import (ATTENTION_VARIANTS,
                                            DEFAULT_KERNELS, KernelPolicy,
                                            SCAN_VARIANTS, fit_block)
    from repro.models.stack import DEFAULT_PAR

    fixed = dict(fixed or {})
    base = DEFAULT_KERNELS._replace(**fixed).validate()
    kinds = set(model_cfg.pattern) | set(model_cfg.prologue or "")
    tune_attn = bool(kinds & {"A", "L", "S"}) and "attention" not in fixed
    tune_blocks = bool(kinds & {"A", "L", "S"}) \
        and not {"block_q", "block_k"} & set(fixed)
    tune_scan = "R" in kinds and "scan" not in fixed

    cache = cache or TuningCache()
    raw = (f"lm:{lm_fingerprint(model_cfg)}:ctx{max_context}:b{batch}"
           f":p{prompt}:{device_digest()}:i{iters}"
           f":fx{sorted(fixed.items())}:v1")
    key = cache.key_raw(raw)
    rec = cache.get(key)
    if rec is not None:
        return LMTuneResult(policy=KernelPolicy(**rec["policy"]).validate(),
                            prefill_us=float(rec["prefill_us"]),
                            from_cache=True)

    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, model_cfg.vocab_size, size=(batch, prompt)), jnp.int32)
    par0 = DEFAULT_PAR if par is None else par

    def effective(pol: KernelPolicy):
        # distinct requested blocks that fit to the same tiles at this
        # prompt shape are the same program — time each once
        return (pol.attention,
                pol.scan if tune_scan else DEFAULT_KERNELS.scan,
                fit_block(prompt, pol.block_q), fit_block(prompt, pol.block_k))

    timed: Dict[tuple, float] = {}

    def time_policy(pol: KernelPolicy) -> float:
        eff = effective(pol)
        if eff in timed:
            return timed[eff]
        step = jax.jit(lm_mod.make_prefill_step(
            model_cfg, max_len=max_context, par=par0.with_kernels(pol)))
        jax.block_until_ready(step(params, {"tokens": toks}))  # compile
        best = None
        for _ in range(max(1, iters)):
            t0 = time.perf_counter()
            jax.block_until_ready(step(params, {"tokens": toks}))
            dt = time.perf_counter() - t0
            best = dt if best is None else min(best, dt)
        timed[eff] = best * 1e6
        return timed[eff]

    best_pol, best_us = base, time_policy(base)
    if tune_attn:
        for attn in ATTENTION_VARIANTS:
            trial = best_pol._replace(attention=attn)
            t = time_policy(trial)
            if t < best_us:
                best_pol, best_us = trial, t
    if tune_blocks:
        for b in _LM_BLOCK_CANDIDATES:
            trial = best_pol._replace(block_q=b, block_k=b)
            t = time_policy(trial)
            if t < best_us:
                best_pol, best_us = trial, t
    if tune_scan:
        for scan in SCAN_VARIANTS:
            trial = best_pol._replace(scan=scan)
            t = time_policy(trial)
            if t < best_us:
                best_pol, best_us = trial, t

    cache.put(key, {
        "policy": dict(best_pol._asdict()),
        "prefill_us": round(best_us, 3),
        "arch": model_cfg.name,
        "device": device_digest(),
        "shape": {"batch": batch, "prompt": prompt,
                  "max_context": max_context},
    })
    return LMTuneResult(policy=best_pol, prefill_us=best_us,
                        from_cache=False)
