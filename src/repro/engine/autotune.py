"""Per-layer codegen-variant autotuner with an on-disk tuning cache.

The paper's headline speed-ups come from *measuring* every generated
code version per layer and keeping the fastest ("we independently
benchmark every code version and select the one with the best runtime
performance", Table VII).  This module makes that selection a reusable,
cached engine component:

* :class:`Autotuner` — greedy coordinate descent over the per-layer
  unroll-level space from :func:`repro.core.cgen.enumerate_variants`,
  timing each fully-compiled candidate net on the host.
* :class:`TuningCache` — JSON records keyed by
  ``(graph fingerprint, ISA, compiler fingerprint)`` so a repeat build
  of the same trained model on the same toolchain compiles nothing.
"""
from __future__ import annotations

import hashlib
import json
import os
import tempfile
from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core import cgen, runtime
from repro.core.graph import CNNGraph
from repro.core.runtime import cc_fingerprint  # part of the cache key

DEFAULT_CACHE_DIR = os.path.join(tempfile.gettempdir(), "nncg_cache",
                                 "tuning")


def graph_fingerprint(graph: CNNGraph) -> str:
    """Content hash of a trained graph: topology, layer names, structure,
    weights.

    Two graphs with the same fingerprint generate byte-identical C for
    any codegen options, so tuning results transfer exactly.  The DAG
    edges (``layer.inputs``) participate — two nets with identical layer
    stacks but different wiring (e.g. with/without a residual skip) are
    different programs.  Layer names participate because cached unroll
    selections are keyed by layer name (``CodegenOptions.level_for``).
    """
    h = hashlib.sha256()
    for layer in graph.layers:
        h.update(type(layer).__name__.encode())
        h.update(f"name={layer.name!r};".encode())
        h.update(f"inputs={list(layer.inputs)!r};".encode())
        for attr in ("shape", "strides", "padding", "activation", "alpha",
                     "size", "eps", "rate"):
            if hasattr(layer, attr):
                h.update(f"{attr}={getattr(layer, attr)!r};".encode())
        for attr in ("weights", "bias", "mean", "var", "gamma", "beta"):
            v = getattr(layer, attr, None)
            if v is not None:
                # shape participates: byte-identical weights factored
                # differently (HWIO vs HWCM splits) are different programs
                h.update(f"{attr}{tuple(np.shape(v))};".encode())
                h.update(np.ascontiguousarray(v, np.float32).tobytes())
    return h.hexdigest()


class TuningCache:
    """One JSON file per (graph, ISA, compiler) key under ``path``."""

    def __init__(self, path: Optional[str] = None):
        self.path = str(path) if path is not None else DEFAULT_CACHE_DIR

    def key(self, graph: CNNGraph, simd: str, extra: str = "") -> str:
        """Cache key over everything the measurement depends on: the
        trained graph, SIMD mode, compiler, codegen version, and (via
        ``extra``) the tuner's own search/measurement parameters."""
        raw = (f"{graph_fingerprint(graph)}:{simd}:{cc_fingerprint()}"
               f":v{cgen.CODEGEN_VERSION}:{extra}")
        return hashlib.sha256(raw.encode()).hexdigest()[:24]

    def _file(self, key: str) -> str:
        return os.path.join(self.path, f"{key}.json")

    def get(self, key: str) -> Optional[dict]:
        try:
            with open(self._file(key)) as f:
                return json.load(f)
        except (OSError, json.JSONDecodeError):
            return None

    def put(self, key: str, record: dict) -> None:
        os.makedirs(self.path, exist_ok=True)
        tmp = self._file(key) + ".tmp"
        with open(tmp, "w") as f:
            json.dump(record, f, indent=1)
        os.replace(tmp, self._file(key))


@dataclass
class TuneResult:
    levels: Dict[str, cgen.Level]  # per-layer unroll selection
    us_per_call: float             # measured latency of the winner
    from_cache: bool               # True if no benchmarking happened
    term_cap: int = 200_000        # emission budget the levels assume —
                                   # the final build must use the same


class Autotuner:
    """Greedy per-layer variant selection for the C backend.

    Starts from the static :func:`cgen.choose_levels` heuristic, then
    for each Conv2D/MaxPool layer tries every feasible unroll level
    (holding the others fixed) and keeps any strict improvement —
    exactly the paper's per-layer benchmark-and-select, with results
    persisted through :class:`TuningCache`.
    """

    def __init__(self, simd: str, *, start_budget: int = 20_000,
                 term_cap: int = 200_000, iters: int = 300,
                 repeats: int = 3, cache: Optional[TuningCache] = None):
        self.simd = simd
        self.start_budget = start_budget
        self.term_cap = term_cap
        self.iters = iters
        self.repeats = max(1, repeats)
        self.cache = cache

    def _params_key(self) -> str:
        return (f"b{self.start_budget}:t{self.term_cap}:i{self.iters}"
                f":r{self.repeats}")

    def _time(self, graph: CNNGraph, levels: Dict[str, cgen.Level],
              x: np.ndarray) -> float:
        # term_budget = term_cap so every explored level is actually
        # emitted as requested (the default budget would silently
        # demote deep levels and make distinct trials identical code)
        net = runtime.build(graph, cgen.CodegenOptions(
            simd=self.simd, unroll=dict(levels),
            term_budget=self.term_cap))
        # min over repeats: robust to scheduler noise, which would
        # otherwise persist a wrong selection into the tuning cache
        return min(
            net.time_per_call_us(x, iters=self.iters,
                                 warmup=max(10, self.iters // 10))
            for _ in range(self.repeats))

    def tune(self, graph: CNNGraph,
             x: Optional[np.ndarray] = None) -> TuneResult:
        if self.cache is not None:
            key = self.cache.key(graph, self.simd, self._params_key())
            rec = self.cache.get(key)
            if rec is not None:
                return TuneResult(levels=dict(rec["levels"]),
                                  us_per_call=float(rec["us_per_call"]),
                                  from_cache=True,
                                  term_cap=self.term_cap)
        if x is None:
            x = np.random.default_rng(0).normal(
                size=graph.input_shape).astype(np.float32)

        # per-layer *input* shapes via the DAG edges (branch layers get
        # their true producer shapes, not list-adjacent ones)
        smap = graph.shape_map()
        shapes: Dict[str, tuple] = {
            layer.name: (smap[layer.inputs[0]] if layer.inputs else None)
            for layer in graph.layers
        }

        levels = cgen.choose_levels(graph, self.start_budget)
        best = self._time(graph, levels, x)
        for layer in graph.layers:
            for lvl in cgen.enumerate_variants(layer, shapes[layer.name],
                                               term_cap=self.term_cap):
                if levels.get(layer.name) == lvl:
                    continue
                trial = dict(levels)
                trial[layer.name] = lvl
                t = self._time(graph, trial, x)
                if t < best:
                    best, levels = t, trial

        if self.cache is not None:
            self.cache.put(key, {
                "levels": levels,
                "us_per_call": best,
                "simd": self.simd,
                "cc": cc_fingerprint(),
                "graph": graph_fingerprint(graph),
            })
        return TuneResult(levels=levels, us_per_call=best, from_cache=False,
                          term_cap=self.term_cap)


def int8_variant_candidates(qgraph=None) -> List[str]:
    """The int8 kernel variants worth timing on this host, best-first.

    Starts from :func:`runtime.supported_int8_simds` (the CPU-feature
    guard — a variant the host can't execute is never enumerated, let
    alone loaded), then drops ``avx_ubs`` when no layer of ``qgraph``
    passes the static ``vpmaddubsw`` saturation proof: that build
    would demote every layer to the plain ``avx`` tile, so timing it
    would only duplicate the ``avx`` candidate."""
    cands = runtime.supported_int8_simds()
    if qgraph is not None and "avx_ubs" in cands \
            and not cgen.maddubsw_any_eligible(qgraph):
        cands = [c for c in cands if c != "avx_ubs"]
    return cands


def tune_best_simd(graph: CNNGraph, simds, *,
                   x: Optional[np.ndarray] = None,
                   cache: Optional[TuningCache] = None,
                   **tuner_kw):
    """Second variant axis: run the per-layer tuner under each SIMD mode
    and keep the overall fastest. Returns ``(simd, TuneResult)``.

    Cached candidates are re-*timed* (never re-tuned, and with the .so
    content cache no recompile happens) so the cross-mode comparison
    uses measurements taken under the same machine conditions — a
    cached number from an earlier, differently-loaded run must not
    decide the selection.
    """
    if x is None:
        x = np.random.default_rng(0).normal(
            size=graph.input_shape).astype(np.float32)
    best_simd, best_res, best_us = None, None, None
    for simd in simds:
        tuner = Autotuner(simd, cache=cache, **tuner_kw)
        res = tuner.tune(graph, x)
        us = (tuner._time(graph, res.levels, x) if res.from_cache
              else res.us_per_call)
        if best_us is None or us < best_us:
            best_simd, best_res, best_us = simd, res, us
    if best_simd is None:
        raise ValueError("tune_best_simd: empty simd candidate list")
    return best_simd, best_res
