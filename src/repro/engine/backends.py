"""Backend registry for the unified inference engine.

One trained :class:`~repro.core.graph.CNNGraph`, three execution
substrates — the paper's deployment artifact plus its two baselines:

* ``"c"``      — NNCG-generated ANSI C, compiled with the host ``cc``
  and loaded via ctypes (the paper's shipped path).
* ``"xla"``    — ``jax.jit`` of the reference forward (the modern
  equivalent of the paper's TF-XLA rival); batches go through a
  ``vmap``'d single-image oracle.
* ``"pallas"`` — the Pallas TPU kernels (interpret mode on CPU,
  Mosaic on TPU).

New substrates register with :func:`register_backend` — the engine and
every caller dispatch purely by name.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Type

import numpy as np

from repro.core import cgen, jax_exec, runtime
from repro.core.graph import CNNGraph

_REGISTRY: Dict[str, Type["Backend"]] = {}


def register_backend(name: str):
    """Class decorator: make a backend constructible by name."""

    def deco(cls: Type["Backend"]) -> Type["Backend"]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_backend(name: str) -> Type["Backend"]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None


def available_backends() -> List[str]:
    return sorted(_REGISTRY)


class Backend:
    """One execution substrate. Constructed with an *optimized* graph
    (passes already applied); ``predict_batch`` maps ``(N, *in_shape)``
    float32 to ``(N, *out_shape)`` float32."""

    name = "?"

    def __init__(self, graph: CNNGraph):
        self.graph = graph
        self.out_shape = graph.output_shape

    def predict_batch(self, x: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def time_per_call_us(self, x: np.ndarray, iters: int = 500,
                         warmup: int = 20) -> float:
        """Single-image latency, mean over ``iters`` calls, in µs."""
        xb = np.ascontiguousarray(x[None], dtype=np.float32)
        for _ in range(warmup):
            self.predict_batch(xb)
        t0 = time.perf_counter()
        for _ in range(iters):
            self.predict_batch(xb)
        return (time.perf_counter() - t0) / iters * 1e6


@register_backend("c")
class CBackend(Backend):
    """NNCG: graph -> C -> cc -> ctypes. Batches run through the
    generated ``<func>_batch`` loop wrapper, or — with ``threads>1`` —
    thread-parallel over the reentrant ``<func>_ws`` workspace entry
    (each thread owns one liveness-planned arena).

    Passing ``qgraph`` (a calibrated
    :class:`repro.core.quantize.QuantizedGraph`) selects the int8
    codegen path: int8 weights/intermediates, int32 accumulators, a
    byte-planned arena, float32 in/out — same serving interface."""

    def __init__(self, graph: CNNGraph, *, simd: str = "sse",
                 unroll=0, func_name: str = "nncg_net",
                 term_budget: Optional[int] = None,
                 threads: Optional[int] = None,
                 qgraph=None):
        super().__init__(graph)
        kw = {} if term_budget is None else {"term_budget": term_budget}
        self.opts = cgen.CodegenOptions(simd=simd, unroll=unroll,
                                        func_name=func_name, **kw)
        self.threads = threads
        self.qgraph = qgraph
        if qgraph is not None:
            self.net = runtime.build_quantized(qgraph, self.opts)
        else:
            self.net = runtime.build(graph, self.opts)

    def predict_batch(self, x: np.ndarray) -> np.ndarray:
        n = x.shape[0]
        out = self.net.predict_batch(x, threads=self.threads)
        return out.reshape((n,) + self.out_shape)

    def time_per_call_us(self, x: np.ndarray, iters: int = 500,
                         warmup: int = 20) -> float:
        # ctypes-level loop: excludes Python dispatch, like the paper's
        # in-process measurement. One image only — a batch here would
        # silently time just its first image.
        assert x.size == self.net.in_size, (
            f"time_per_call_us expects one image of {self.graph.input_shape}, "
            f"got {x.shape}")
        return self.net.time_per_call_us(x, iters=iters, warmup=warmup)


class _JaxBackend(Backend):
    """Shared plumbing for the jit-compiled substrates."""

    def _make_fn(self, graph: CNNGraph):
        raise NotImplementedError

    def __init__(self, graph: CNNGraph):
        super().__init__(graph)
        self._fn = self._make_fn(graph)

    def predict_batch(self, x: np.ndarray) -> np.ndarray:
        import jax.numpy as jnp
        y = self._fn(jnp.asarray(x, jnp.float32))
        n = x.shape[0]
        return np.asarray(y, np.float32).reshape((n,) + self.out_shape)

    def time_per_call_us(self, x: np.ndarray, iters: int = 500,
                         warmup: int = 20) -> float:
        import jax.numpy as jnp
        xb = jnp.asarray(x[None], jnp.float32)
        self._fn(xb).block_until_ready()
        for _ in range(warmup):
            self._fn(xb).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            self._fn(xb).block_until_ready()
        return (time.perf_counter() - t0) / iters * 1e6


@register_backend("xla")
class XLABackend(_JaxBackend):
    """The paper's rival compiler stack: one XLA program per batch via a
    vmap'd single-image oracle."""

    def _make_fn(self, graph: CNNGraph):
        return jax_exec.make_vmap_forward(graph)


class QuantizedXLABackend(_JaxBackend):
    """XLA-compiled int8 reference
    (:func:`repro.core.jax_exec.forward_quantized`) — the parity oracle
    the quantized C build must match bit-for-bit on the integer path.
    Constructed directly by the session (not in the registry: it needs
    the calibrated ``QuantizedGraph``, not just a graph)."""

    name = "xla-int8"

    def __init__(self, qgraph):
        self.qgraph = qgraph
        super().__init__(qgraph.graph)

    def _make_fn(self, graph: CNNGraph):
        return jax_exec.make_jit_forward_quantized(self.qgraph)


@register_backend("pallas")
class PallasBackend(_JaxBackend):
    """TPU-native deployment path (interpret mode off-TPU). Requires an
    optimized graph — BN folded, activations fused, no Dense/Flatten."""

    def _make_fn(self, graph: CNNGraph):
        import jax

        @jax.jit
        def f(x):
            return jax_exec.forward_pallas(graph, x)

        return f
