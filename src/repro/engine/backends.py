"""Backend registry for the unified inference engine.

One trained :class:`~repro.core.graph.CNNGraph`, three execution
substrates — the paper's deployment artifact plus its two baselines:

* ``"c"``      — NNCG-generated ANSI C, compiled with the host ``cc``
  and loaded via ctypes (the paper's shipped path).
* ``"xla"``    — ``jax.jit`` of the reference forward (the modern
  equivalent of the paper's TF-XLA rival); batches go through a
  ``vmap``'d single-image oracle.
* ``"pallas"`` — the Pallas TPU kernels (interpret mode on CPU,
  Mosaic on TPU).

``Backend`` is a formal ABC, not duck typing: every substrate
implements ``predict_batch`` and inherits ``describe()`` (a stable
dict of what this backend is), ``close()`` (release native resources;
default no-op), and ``worker()`` (a reentrant execution handle for
server worker pools — see :mod:`repro.serve`).  New substrates
register with :func:`register_backend`; the engine and every caller
dispatch purely by name through :func:`get_backend`.
"""
from __future__ import annotations

import abc
import ctypes
import time
from dataclasses import replace
from typing import Dict, List, Optional, Type

import numpy as np

from repro.core import cgen, jax_exec, runtime
from repro.core.graph import CNNGraph

_REGISTRY: Dict[str, Type["Backend"]] = {}


def register_backend(name: str):
    """Class decorator: make a backend constructible by name."""

    def deco(cls: Type["Backend"]) -> Type["Backend"]:
        if not (isinstance(cls, type) and issubclass(cls, Backend)):
            raise TypeError(
                f"register_backend({name!r}): {cls!r} must subclass Backend")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get_backend(name: str) -> Type["Backend"]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None


def available_backends() -> List[str]:
    return sorted(_REGISTRY)


class Backend(abc.ABC):
    """One execution substrate — the engine's formal serving interface.

    Constructed with an *optimized* graph (passes already applied).
    Required: :meth:`predict_batch` maps ``(N, *in_shape)`` float32 to
    ``(N, *out_shape)`` float32.  Optional overrides: :meth:`describe`
    (extend the base dict with substrate facts), :meth:`close` (release
    native resources), :meth:`worker` (hand a server worker a handle it
    may call concurrently with other workers' handles).
    """

    name = "?"
    precision = "fp32"
    workload = "cnn"

    def __init__(self, graph: Optional[CNNGraph]):
        # LM backends (workload="lm") have no CNNGraph; everything that
        # reads .graph/.out_shape must tolerate None for them.
        self.graph = graph
        self.out_shape = graph.output_shape if graph is not None else None

    @abc.abstractmethod
    def predict_batch(self, x: np.ndarray) -> np.ndarray:
        """``(N, *in_shape)`` float32 -> ``(N, *out_shape)`` float32."""

    def describe(self) -> dict:
        """Stable facts about this backend (extended by subclasses)."""
        return {
            "name": self.name,
            "precision": self.precision,
            "input_shape": tuple(self.graph.input_shape),
            "output_shape": tuple(self.out_shape),
        }

    def close(self) -> None:
        """Release backend resources. Idempotent; default no-op."""

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def worker(self) -> "Backend":
        """An execution handle a server worker thread may use
        concurrently with other workers' handles.  Substrates whose
        ``predict_batch`` is already reentrant (jit-compiled jax
        functions) return ``self``; substrates with per-call scratch
        state (the C arena) return a handle owning private scratch."""
        return self

    def time_per_call_us(self, x: np.ndarray, iters: int = 500,
                         warmup: int = 20) -> float:
        """Single-image latency, mean over ``iters`` calls, in µs."""
        xb = np.ascontiguousarray(x[None], dtype=np.float32)
        for _ in range(warmup):
            self.predict_batch(xb)
        t0 = time.perf_counter()
        for _ in range(iters):
            self.predict_batch(xb)
        return (time.perf_counter() - t0) / iters * 1e6


class _CArenaWorker(Backend):
    """A per-thread handle on a compiled net: one warm liveness-planned
    workspace, driven through the reentrant ``<func>_ws`` entry.  Many
    of these can run concurrently against the same ``.so`` — ctypes
    releases the GIL during the call."""

    name = "c-worker"

    def __init__(self, parent: "CBackend"):
        super().__init__(parent.graph)
        self.name = parent.name + "-worker"
        self.precision = parent.precision
        self._net = parent.net
        self._ws = self._net._alloc_workspace()
        self._wp = self._ws.ctypes.data_as(
            ctypes.POINTER(self._net._ws_ctype))

    def predict_batch(self, x: np.ndarray) -> np.ndarray:
        net = self._net
        x = np.ascontiguousarray(x, dtype=np.float32)
        n = x.size // net.in_size
        if net._stage_fns and n > 1:
            # layer-pipelined build: stream the batch stage-overlapped
            # (the runner allocates its own buffers — reentrant across
            # concurrent server workers)
            return net.predict_batch(x).reshape((n,) + self.out_shape)
        out = np.empty(n * net.out_size, dtype=np.float32)
        FLOATP = ctypes.POINTER(ctypes.c_float)
        if net._batch_ws_fn is not None:
            # the whole batch in one GIL-releasing foreign call
            net._batch_ws_fn(x.ctypes.data_as(FLOATP),
                             out.ctypes.data_as(FLOATP),
                             ctypes.c_int(n), self._wp)
            return out.reshape((n,) + self.out_shape)
        xf = x.reshape(-1)
        for b in range(n):
            xi = xf[b * net.in_size:(b + 1) * net.in_size]
            oi = out[b * net.out_size:(b + 1) * net.out_size]
            net._ws_fn(xi.ctypes.data_as(FLOATP),
                       oi.ctypes.data_as(FLOATP), self._wp)
        return out.reshape((n,) + self.out_shape)


@register_backend("c")
class CBackend(Backend):
    """NNCG: graph -> C -> cc -> ctypes. Batches run through the
    generated ``<func>_batch`` loop wrapper, or — with ``threads>1`` —
    thread-parallel over the reentrant ``<func>_ws`` workspace entry
    (each thread owns one liveness-planned arena).

    Passing ``qgraph`` (a calibrated
    :class:`repro.core.quantize.QuantizedGraph`) selects the int8
    codegen path: int8 weights/intermediates, int32 accumulators, a
    byte-planned arena, float32 in/out — same serving interface."""

    def __init__(self, graph: CNNGraph, *, simd: str = "sse",
                 unroll=0, func_name: str = "nncg_net",
                 term_budget: Optional[int] = None,
                 threads: Optional[int] = None,
                 qgraph=None, schedule=None):
        super().__init__(graph)
        kw = {} if term_budget is None else {"term_budget": term_budget}
        self.opts = cgen.CodegenOptions(simd=simd, unroll=unroll,
                                        func_name=func_name, **kw)
        self.threads = threads
        self.qgraph = qgraph
        self.schedule = schedule
        if qgraph is not None:
            self.precision = "int8"
            self.net = runtime.build_quantized(qgraph, self.opts,
                                               schedule=schedule)
        else:
            self.net = runtime.build(graph, self.opts, schedule=schedule)
        if self.net.simd != self.opts.simd:
            # the runtime CPU-feature guard demoted the requested
            # variant; report what actually runs
            self.opts = replace(self.opts, simd=self.net.simd)

    def predict_batch(self, x: np.ndarray) -> np.ndarray:
        n = x.shape[0]
        out = self.net.predict_batch(x, threads=self.threads)
        return out.reshape((n,) + self.out_shape)

    def describe(self) -> dict:
        d = super().describe()
        d.update(simd=self.opts.simd, threads=self.threads,
                 so_path=self.net.so_path,
                 c_source_bytes=self.net.c_source_bytes,
                 arena_bytes=self.net.arena_bytes,
                 arena_buffer_sum_bytes=self.net.arena_buffer_sum_bytes,
                 per_layer_live_bytes=dict(
                     self.net.per_layer_live_bytes or {}),
                 pipeline_stages=self.net.nstages,
                 schedule_digest=self.net.schedule_digest)
        return d

    def worker(self) -> Backend:
        if self.net._ws_fn is None:  # pre-arena .so: not reentrant
            return self
        return _CArenaWorker(self)

    def time_per_call_us(self, x: np.ndarray, iters: int = 500,
                         warmup: int = 20) -> float:
        # ctypes-level loop: excludes Python dispatch, like the paper's
        # in-process measurement. One image only — a batch here would
        # silently time just its first image.
        assert x.size == self.net.in_size, (
            f"time_per_call_us expects one image of {self.graph.input_shape}, "
            f"got {x.shape}")
        return self.net.time_per_call_us(x, iters=iters, warmup=warmup)


class _JaxBackend(Backend):
    """Shared plumbing for the jit-compiled substrates."""

    def _make_fn(self, graph: CNNGraph):
        raise NotImplementedError

    def __init__(self, graph: CNNGraph):
        super().__init__(graph)
        self._fn = self._make_fn(graph)

    def predict_batch(self, x: np.ndarray) -> np.ndarray:
        import jax.numpy as jnp
        y = self._fn(jnp.asarray(x, jnp.float32))
        n = x.shape[0]
        return np.asarray(y, np.float32).reshape((n,) + self.out_shape)

    def time_per_call_us(self, x: np.ndarray, iters: int = 500,
                         warmup: int = 20) -> float:
        import jax.numpy as jnp
        xb = jnp.asarray(x[None], jnp.float32)
        self._fn(xb).block_until_ready()
        for _ in range(warmup):
            self._fn(xb).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            self._fn(xb).block_until_ready()
        return (time.perf_counter() - t0) / iters * 1e6


@register_backend("xla")
class XLABackend(_JaxBackend):
    """The paper's rival compiler stack: one XLA program per batch via a
    vmap'd single-image oracle."""

    def _make_fn(self, graph: CNNGraph):
        return jax_exec.make_vmap_forward(graph)


class QuantizedXLABackend(_JaxBackend):
    """XLA-compiled int8 reference
    (:func:`repro.core.jax_exec.forward_quantized`) — the parity oracle
    the quantized C build must match bit-for-bit on the integer path.
    Constructed directly by the session (not in the registry: it needs
    the calibrated ``QuantizedGraph``, not just a graph)."""

    name = "xla-int8"
    precision = "int8"

    def __init__(self, qgraph):
        self.qgraph = qgraph
        super().__init__(qgraph.graph)

    def _make_fn(self, graph: CNNGraph):
        return jax_exec.make_jit_forward_quantized(self.qgraph)


@register_backend("pallas")
class PallasBackend(_JaxBackend):
    """TPU-native deployment path (interpret mode off-TPU). Requires an
    optimized graph — BN folded, activations fused, no Dense/Flatten."""

    def _make_fn(self, graph: CNNGraph):
        import jax

        @jax.jit
        def f(x):
            return jax_exec.forward_pallas(graph, x)

        return f


# =========================================================== LM workload ====

class KVCacheHandle:
    """An opaque decode-state handle: the per-layer KV/recurrence caches
    plus the next write position.  Returned by :meth:`LMBackend.prefill`,
    advanced in place by :meth:`LMBackend.decode` — the token-server and
    session layers never look inside."""

    __slots__ = ("caches", "pos", "batch")

    def __init__(self, caches, pos, batch: int):
        self.caches = caches
        self.pos = pos
        self.batch = batch

    def __repr__(self):
        return f"KVCacheHandle(batch={self.batch}, pos={self.pos})"


class LMBackend(Backend):
    """The LM execution contract next to ``predict_batch``: explicit
    prefill/decode steps over a :class:`KVCacheHandle`.

    ``predict_batch`` stays in the interface — for an LM it maps int32
    token ids ``(N, T)`` to full-sequence logits ``(N, T, V)`` — so the
    registry, the server worker pool and ``describe()`` plumbing treat
    both workloads identically; the token-level serving path uses the
    three LM methods below."""

    workload = "lm"

    @abc.abstractmethod
    def prefill(self, tokens: np.ndarray):
        """``(B, T)`` int32 prompts -> ``(last_logits (B, V),
        KVCacheHandle)``."""

    @abc.abstractmethod
    def decode(self, handle: KVCacheHandle, tokens: np.ndarray) -> np.ndarray:
        """One step: ``(B,)`` int32 tokens against ``handle`` ->
        ``(B, V)`` logits.  Advances the handle in place."""

    def generate(self, prompts: np.ndarray, max_new: int) -> np.ndarray:
        """Greedy decode: ``(B, T)`` int32 -> ``(B, max_new)`` int32."""
        prompts = np.asarray(prompts, np.int32)
        if max_new < 1:
            return np.zeros((prompts.shape[0], 0), np.int32)
        logits, handle = self.prefill(prompts)
        tok = np.argmax(logits, axis=-1).astype(np.int32)
        out = [tok]
        for _ in range(max_new - 1):
            logits = self.decode(handle, tok)
            tok = np.argmax(logits, axis=-1).astype(np.int32)
            out.append(tok)
        return np.stack(out, axis=1)


@register_backend("pallas-lm")
class PallasLMBackend(LMBackend):
    """The gemma3-style LM stack (:mod:`repro.models`) as a registry
    citizen: jit-compiled prefill/decode closed over a
    :class:`~repro.models.kernel_policy.KernelPolicy` (the autotuned
    Pallas-variant choice) and an optional :class:`MeshPar` for
    data-parallel prefill.  Constructed by
    :class:`repro.engine.lm.LMSession`, not from a ``CNNGraph``."""

    def __init__(self, model_cfg, *, params=None, max_context: int = 128,
                 decode_batch: int = 1, policy=None, par=None, seed: int = 0):
        import jax

        from repro.models import lm as lm_mod
        from repro.models.kernel_policy import DEFAULT_KERNELS
        from repro.models.stack import DEFAULT_PAR

        super().__init__(None)
        self.model_cfg = model_cfg
        self.max_context = int(max_context)
        self.decode_batch = int(decode_batch)
        base_par = DEFAULT_PAR if par is None else par
        self.par = base_par.with_kernels(policy)
        self.policy = getattr(self.par, "kernels", DEFAULT_KERNELS)
        self.mesh = getattr(base_par, "mesh", None)
        if params is None:
            params = lm_mod.init_params(model_cfg, jax.random.PRNGKey(seed))
        if self.mesh is not None:
            from repro.launch.sharding import param_specs, to_named
            params = jax.device_put(
                params, to_named(self.mesh, param_specs(self.mesh, params)))
        self.params = params
        self._prefill_fn = jax.jit(lm_mod.make_prefill_step(
            model_cfg, max_len=self.max_context, par=self.par))
        self._decode_fn = (None if model_cfg.is_encoder else jax.jit(
            lm_mod.make_decode_step(model_cfg, par=self.par)))

        def _full(p, tokens):
            logits, _ = lm_mod.forward(p, model_cfg, {"tokens": tokens},
                                       self.par)
            return logits

        self._forward_fn = jax.jit(_full)

    # ----------------------------------------------------- LM contract --
    def prefill(self, tokens: np.ndarray):
        import jax.numpy as jnp
        tokens = np.asarray(tokens, np.int32)
        B, T = tokens.shape
        if T > self.max_context:
            raise ValueError(
                f"prompt length {T} > max_context {self.max_context}")
        logits, caches, pos = self._prefill_fn(
            self.params, {"tokens": jnp.asarray(tokens)})
        return (np.asarray(logits, np.float32),
                KVCacheHandle(caches, pos, batch=B))

    def decode(self, handle: KVCacheHandle, tokens: np.ndarray) -> np.ndarray:
        import jax.numpy as jnp
        if self._decode_fn is None:
            raise ValueError(
                f"{self.model_cfg.name} is encoder-only: no decode step")
        tokens = np.asarray(tokens, np.int32).reshape(handle.batch, 1)
        logits, handle.caches, handle.pos = self._decode_fn(
            self.params, handle.caches, jnp.asarray(tokens), handle.pos)
        return np.asarray(logits, np.float32)

    # ------------------------------------------------- shared contract --
    def predict_batch(self, x: np.ndarray) -> np.ndarray:
        import jax.numpy as jnp
        tokens = jnp.asarray(np.asarray(x, np.int32))
        return np.asarray(self._forward_fn(self.params, tokens), np.float32)

    def describe(self) -> dict:
        from repro.models.lm import param_count
        return {
            "name": self.name,
            "precision": self.precision,
            "workload": self.workload,
            "arch": self.model_cfg.name,
            "vocab_size": self.model_cfg.vocab_size,
            "max_context": self.max_context,
            "decode_batch": self.decode_batch,
            "kernel_policy": dict(self.policy._asdict()),
            "n_params": param_count(self.model_cfg),
            "mesh": (None if self.mesh is None
                     else dict(zip(self.mesh.axis_names,
                                   [self.mesh.shape[a]
                                    for a in self.mesh.axis_names]))),
        }
