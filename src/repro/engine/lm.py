"""The LM workload behind the unified session surface.

    cfg = SessionConfig(backend="pallas-lm", autotune=True,
                        lm=LMConfig(arch="gemma3-4b", max_context=64,
                                    decode_batch=4))
    sess = LMSession(config=cfg)
    tokens = sess.generate(prompts, max_new=16)   # greedy, (B, 16) int32

:class:`LMSession` shares every piece of engine machinery the CNN
session uses — :class:`SessionConfig` (with its ``lm`` sub-config), the
backend registry (the ``"pallas-lm"`` entry), and the on-disk
:class:`TuningCache` (Pallas kernel variants are timed candidates
exactly like C unroll levels; see
:func:`repro.engine.autotune.tune_lm_variants`).  A config with
``lm.mesh_shape`` set serves data-parallel prefill through
:class:`repro.launch.sharding.MeshPar`, falling back cleanly to
single-device when the host has fewer devices (the CPU CI path).
"""
from __future__ import annotations

import warnings
from typing import Optional

import numpy as np

from .autotune import LMTuneResult, TuningCache, tune_lm_variants
from .backends import KVCacheHandle, LMBackend, get_backend
from .config import LMConfig, SessionConfig
from .session import SessionInfo


class LMSession:
    """Build once, prefill/decode many — over any registered LM backend.

    Parameters
    ----------
    config:  a :class:`SessionConfig` with ``lm`` set (also accepts a
             bare :class:`LMConfig`, or a dict for either).  The default
             CNN backend ``"c"`` is upgraded to ``"pallas-lm"``; naming
             a non-LM backend explicitly is an error.
    params:  optional parameter pytree (defaults to a seeded
             ``init_params`` of the arch — the deterministic CI path).
    mesh:    optional pre-built jax mesh; otherwise ``lm.mesh_shape``
             (when set and satisfiable on this host) builds one.
    """

    def __init__(self, config=None, *, params=None, mesh=None):
        if config is None:
            config = SessionConfig(backend="pallas-lm", lm=LMConfig())
        if isinstance(config, LMConfig):
            config = SessionConfig(backend="pallas-lm", lm=config)
        if isinstance(config, dict):
            config = SessionConfig(**config)
        if config.lm is None:
            raise TypeError(
                "LMSession needs SessionConfig.lm (an LMConfig); for CNN "
                "graphs use InferenceSession")
        if config.backend == "c":  # the SessionConfig default, not a choice
            config = config.replace(backend="pallas-lm")
        self.config = config
        self.backend_name = config.backend
        lm = config.lm

        backend_cls = get_backend(config.backend)
        if not issubclass(backend_cls, LMBackend):
            raise ValueError(
                f"backend {config.backend!r} does not implement the LM "
                f"contract (prefill/decode); it serves CNN graphs")

        from repro.configs.lm_archs import ARCHS
        model_cfg = ARCHS[lm.arch]
        if lm.smoke:
            model_cfg = model_cfg.smoke()
        self.model_cfg = model_cfg

        self.mesh = mesh
        if self.mesh is None and lm.mesh_shape is not None:
            self.mesh = self._make_mesh(lm.mesh_shape)
        par = None
        if self.mesh is not None:
            from repro.launch.sharding import MeshPar
            par = MeshPar(self.mesh, model_cfg)

        if params is None:
            import jax
            from repro.models.lm import init_params
            params = init_params(model_cfg, jax.random.PRNGKey(lm.seed))

        # kernel policy: axes the LMConfig pins are fixed; the rest are
        # autotuned (winner persisted) or left at the defaults
        fixed = {}
        if lm.attn_variant is not None:
            fixed["attention"] = lm.attn_variant
        if lm.scan_variant is not None:
            fixed["scan"] = lm.scan_variant
        if lm.block_q is not None:
            fixed["block_q"] = int(lm.block_q)
        if lm.block_k is not None:
            fixed["block_k"] = int(lm.block_k)
        self.tuned: Optional[LMTuneResult] = None
        if config.autotune:
            self.tuned = tune_lm_variants(
                model_cfg, params,
                max_context=lm.max_context,
                batch=lm.decode_batch,
                prompt=min(16, lm.max_context),
                cache=self._tuning_cache(),
                iters=max(1, config.tune_iters // 100),
                fixed=fixed, par=par)
            policy = self.tuned.policy
        else:
            from repro.models.kernel_policy import DEFAULT_KERNELS
            policy = DEFAULT_KERNELS._replace(**fixed).validate()

        self._backend: LMBackend = backend_cls(
            model_cfg, params=params, max_context=lm.max_context,
            decode_batch=lm.decode_batch, policy=policy, par=par,
            seed=lm.seed)
        self.kernel_policy = self._backend.policy

    @staticmethod
    def _make_mesh(shape):
        """Build the requested mesh, or fall back to single-device when
        the host cannot satisfy it (CPU CI has one device)."""
        import math

        import jax

        from repro.launch.mesh import make_mesh
        need = math.prod(shape)
        have = len(jax.devices())
        if need > have:
            warnings.warn(
                f"lm.mesh_shape {tuple(shape)} needs {need} devices but "
                f"the host has {have}; falling back to single-device",
                RuntimeWarning, stacklevel=3)
            return None
        return make_mesh(shape)

    def _tuning_cache(self) -> TuningCache:
        tc = self.config.tune_cache
        return tc if isinstance(tc, TuningCache) else TuningCache(tc)

    # -- execution -----------------------------------------------------------

    def prefill(self, tokens: np.ndarray):
        """``(B, T)`` int32 prompts -> ``(last_logits, KVCacheHandle)``."""
        return self._backend.prefill(tokens)

    def decode(self, handle: KVCacheHandle, tokens: np.ndarray) -> np.ndarray:
        """One greedy-loop step: ``(B,)`` tokens -> ``(B, V)`` logits."""
        return self._backend.decode(handle, tokens)

    def generate(self, prompts: np.ndarray, max_new: int) -> np.ndarray:
        """Greedy decode: ``(B, T)`` int32 -> ``(B, max_new)`` int32."""
        return self._backend.generate(prompts, max_new)

    def predict(self, tokens: np.ndarray) -> np.ndarray:
        """Full-sequence logits ``(B, T)`` -> ``(B, T, V)`` (the
        ``predict_batch`` face of the shared Backend contract)."""
        return self._backend.predict_batch(tokens)

    @property
    def backend(self) -> LMBackend:
        return self._backend

    def close(self) -> None:
        self._backend.close()

    # -- introspection -------------------------------------------------------

    @property
    def info(self) -> SessionInfo:
        d = SessionInfo(
            backend=self.backend_name,
            workload="lm",
            arch=self.model_cfg.name,
            kernel_policy=dict(self.kernel_policy._asdict()),
            config=self.config.to_dict())
        if self.tuned is not None:
            d.update(tuned_prefill_us=self.tuned.prefill_us,
                     tuned_from_cache=self.tuned.from_cache)
        d.update(self._backend.describe())
        return d
