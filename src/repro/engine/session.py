"""The unified inference entry point: one session, three substrates.

    sess = InferenceSession(graph, backend="c", autotune=True)
    probs = sess.predict(batch)          # (N, *out_shape)

Post-training int8 quantization is one more argument:

    sess = InferenceSession(graph, backend="c", precision="int8",
                            calibration=sample_batch)

The session owns the whole deployment pipeline the repo previously
scattered across benchmarks/examples: the NNCG optimization passes,
ISA selection, per-layer variant autotuning (with the on-disk tuning
cache), calibration + quantization, codegen + compile, and batched
execution.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Union

import numpy as np

from repro.core import cgen, passes, quantize as quantize_mod, runtime
from repro.core.graph import CNNGraph

from .autotune import Autotuner, TuneResult, TuningCache, tune_best_simd
from .backends import (Backend, CBackend, QuantizedXLABackend, get_backend)


class InferenceSession:
    """Build once, predict many — over any registered backend.

    Parameters
    ----------
    graph:    trained :class:`CNNGraph` (raw; passes run here unless
              ``optimize=False``).
    backend:  ``"c"`` | ``"xla"`` | ``"pallas"`` (see
              :func:`repro.engine.backends.available_backends`).
    autotune: C backend only — benchmark every per-layer codegen variant
              and keep the fastest, consulting the on-disk tuning cache.
    simd:     C codegen mode (``'generic'|'structured'|'sse'|'avx'``);
              defaults to the widest ISA the host supports.
    simd_search: with ``autotune``, a list of simd modes to tune under —
              the engine keeps the fastest (mode, per-layer levels) pair.
    unroll:   C backend without autotune — ``"auto"`` (static heuristic),
              a single level, or a per-layer dict.
    threads:  C backend — drive batches thread-parallel through the
              reentrant ``<func>_ws`` entry point (one liveness-planned
              workspace per thread); ``None``/1 keeps the sequential
              generated batch loop.
    tune_cache: directory (or :class:`TuningCache`) for persisted tuning
              results; ``None`` uses the default cache dir.
    tune_iters: timing iterations per candidate during autotuning.
    precision: ``"fp32"`` (default) or ``"int8"`` — post-training
              quantization: calibrate activation ranges on sample
              inputs, then serve the int8 C build (int8 weights and
              intermediates, int32 accumulators, ~4x smaller arena) or,
              with ``backend="xla"``, the bit-faithful jax reference.
    calibration: sample inputs ``(N, *in_shape)`` for the int8
              calibration pass; defaults to ``calib_samples`` standard
              normal images (fine for smoke tests — use real data for
              deployment).
    calib_samples: size of the default calibration batch.
    calibration_method: activation range selection — ``"minmax"``
              (exact observed range, the default), ``"percentile"``
              (clip outlier tails at ``calibration_percentile``), or
              ``"mse"`` (histogram-MSE-optimal clipped range).  See
              :data:`repro.core.quantize.CALIBRATION_METHODS`.
    calibration_percentile: the two-sided keep-mass for
              ``calibration_method="percentile"`` (e.g. 99.99).
    """

    def __init__(self, graph: CNNGraph, backend: str = "c", *,
                 autotune: bool = False,
                 simd: Optional[str] = None,
                 simd_search: Optional[Sequence[str]] = None,
                 unroll: Union[str, int, None, Dict] = "auto",
                 optimize: bool = True,
                 threads: Optional[int] = None,
                 tune_cache: Union[None, str, TuningCache] = None,
                 tune_iters: int = 300,
                 func_name: str = "nncg_net",
                 precision: str = "fp32",
                 calibration: Optional[np.ndarray] = None,
                 calib_samples: int = 32,
                 calibration_method: str = "minmax",
                 calibration_percentile: float = 99.99):
        assert precision in ("fp32", "int8"), precision
        assert calibration_method in quantize_mod.CALIBRATION_METHODS, \
            calibration_method
        self.backend_name = backend
        self.precision = precision
        self.simd = simd or runtime.best_isa()
        candidates = list(simd_search) if (simd_search and autotune
                                           and backend == "c") else None
        widths = [cgen.ISAS[s].width if s in cgen.ISAS else 4
                  for s in (candidates or [self.simd])]
        # int8 kernels vectorize over window taps, not output channels —
        # SIMD channel alignment would only add dead compute
        multiple = 1 if precision == "int8" else max(widths)
        self.graph = (passes.optimize(graph, simd_multiple=multiple)
                      if optimize else graph)
        self.tuned: Optional[TuneResult] = None
        self.qgraph = None

        if precision == "int8":
            if calibration is None:
                calibration = np.random.default_rng(0).normal(
                    size=(calib_samples,) + tuple(self.graph.input_shape)
                ).astype(np.float32)
            self.qgraph = quantize_mod.quantize(
                self.graph, calibration, method=calibration_method,
                percentile=calibration_percentile)
            self._init_int8(backend, candidates, threads, func_name,
                            tune_iters, autotune, tune_cache)
            return

        if backend == "c":
            if autotune:
                cache = (tune_cache if isinstance(tune_cache, TuningCache)
                         else TuningCache(tune_cache))
                if candidates:
                    self.simd, self.tuned = tune_best_simd(
                        self.graph, candidates, cache=cache,
                        iters=tune_iters)
                else:
                    tuner = Autotuner(self.simd, iters=tune_iters,
                                      cache=cache)
                    self.tuned = tuner.tune(self.graph)
                unroll_cfg = self.tuned.levels
            elif unroll == "auto":
                unroll_cfg = cgen.choose_levels(self.graph, 20_000)
            else:
                unroll_cfg = unroll
            # tuned levels were measured at the tuner's emission budget;
            # the deployed build must emit the same code
            term_budget = (self.tuned.term_cap if self.tuned is not None
                           else None)
            self._backend: Backend = CBackend(
                self.graph, simd=self.simd, unroll=unroll_cfg,
                func_name=func_name, term_budget=term_budget,
                threads=threads)
        else:
            self._backend = get_backend(backend)(self.graph)

    def _init_int8(self, backend: str, candidates, threads, func_name: str,
                   tune_iters: int, autotune: bool, tune_cache) -> None:
        """Build the int8 serving backend.

        The quantized kernels' variant space is the SIMD mode (the int8
        emitters are rolled — unroll levels don't apply): with
        ``autotune`` the session times each candidate build and keeps
        the fastest; integer accumulation is order-independent, so all
        candidates are bit-identical and the choice is purely speed.
        The winning mode persists in the same on-disk tuning cache the
        float path uses (keyed by graph/compiler/codegen version plus
        an int8 tag), so a repeat session times nothing."""
        if backend == "xla":
            self._backend = QuantizedXLABackend(self.qgraph)
            return
        if backend != "c":
            raise ValueError(
                f"precision='int8' supports backends 'c' and 'xla', "
                f"not {backend!r}")
        if autotune:
            cands = candidates
            if not cands:
                cands = ["generic"]
                if runtime.host_supports_ssse3():
                    cands.insert(0, "sse")
                if runtime.host_supports_avx2():
                    cands.insert(0, "avx")
            cache = (tune_cache if isinstance(tune_cache, TuningCache)
                     else TuningCache(tune_cache))
            # the generated int8 C embeds the calibration-derived
            # qparams, so the cache key must carry them: a different
            # calibration set/method is a different program
            qdigest = quantize_mod.qparams_digest(self.qgraph)
            key = cache.key(self.graph, "+".join(cands),
                            extra=f"int8:{qdigest}:i{tune_iters}")
            rec = cache.get(key)
            if rec is not None and rec.get("simd") in cands:
                self.simd = rec["simd"]
                self._backend = CBackend(
                    self.graph, simd=self.simd, func_name=func_name,
                    threads=threads, qgraph=self.qgraph)
                self.tuned = TuneResult(levels={}, us_per_call=float(
                    rec.get("us_per_call", 0.0)), from_cache=True)
                return
            x = np.random.default_rng(0).normal(
                size=self.graph.input_shape).astype(np.float32)
            best = None
            for simd in cands:
                b = CBackend(self.graph, simd=simd, func_name=func_name,
                             threads=threads, qgraph=self.qgraph)
                t = b.time_per_call_us(x, iters=tune_iters,
                                       warmup=max(10, tune_iters // 10))
                if best is None or t < best[0]:
                    best = (t, simd, b)
            _, self.simd, self._backend = best
            cache.put(key, {"simd": self.simd,
                            "us_per_call": round(best[0], 3)})
            self.tuned = TuneResult(levels={}, us_per_call=best[0],
                                    from_cache=False)
        else:
            self._backend = CBackend(self.graph, simd=self.simd,
                                     func_name=func_name, threads=threads,
                                     qgraph=self.qgraph)

    # -- shapes --------------------------------------------------------------

    @property
    def input_shape(self):
        return self.graph.input_shape

    @property
    def output_shape(self):
        return self.graph.output_shape

    # -- execution -----------------------------------------------------------

    def predict(self, x: np.ndarray) -> np.ndarray:
        """Single image ``(*in_shape)`` -> ``(*out_shape)``, or batch
        ``(N, *in_shape)`` -> ``(N, *out_shape)``."""
        x = np.asarray(x, dtype=np.float32)
        in_shape = tuple(self.input_shape)
        if x.shape == in_shape:
            return self._backend.predict_batch(x[None])[0]
        if x.shape[1:] == in_shape:
            return self._backend.predict_batch(x)
        raise ValueError(
            f"predict: expected {in_shape} or (N,)+{in_shape}, "
            f"got {x.shape}")

    def benchmark(self, x: Optional[np.ndarray] = None, *,
                  iters: int = 500, warmup: int = 20) -> float:
        """Single-image latency of this session's backend in µs/call.

        Accepts one image or a batch — a batch is sliced to its first
        image here, consistently for every backend (the C backend's
        ctypes timing loop reads exactly one image's worth of memory
        and would otherwise trip its single-image assert)."""
        if x is None:
            x = np.random.default_rng(0).normal(
                size=self.input_shape).astype(np.float32)
        x = np.asarray(x, np.float32)
        in_shape = tuple(self.input_shape)
        if x.shape != in_shape:
            if x.ndim == len(in_shape) + 1 and x.shape[1:] == in_shape:
                x = x[0]  # batch -> its first image, for all backends
            else:
                raise ValueError(
                    f"benchmark times one image of {in_shape}, "
                    f"got {x.shape}")
        return self._backend.time_per_call_us(x, iters=iters, warmup=warmup)

    # -- introspection -------------------------------------------------------

    @property
    def info(self) -> dict:
        d = {"backend": self.backend_name, "simd": self.simd,
             "precision": self.precision,
             "input_shape": tuple(self.input_shape),
             "output_shape": tuple(self.output_shape)}
        if self.qgraph is not None:
            d["quantized_layers"] = sorted(self.qgraph.weights)
            d["input_qparams"] = (self.qgraph.input_qp.scale,
                                  self.qgraph.input_qp.zero_point)
            d["calibration_method"] = self.qgraph.method
            if self.qgraph.method == "percentile":
                d["calibration_percentile"] = self.qgraph.percentile
        if self.tuned is not None:
            d.update(levels=self.tuned.levels,
                     tuned_us_per_call=self.tuned.us_per_call,
                     tuned_from_cache=self.tuned.from_cache)
        if isinstance(self._backend, CBackend):
            net = self._backend.net
            d["c_source_bytes"] = net.c_source_bytes
            d["so_path"] = net.so_path
            # liveness-planned memory: the one workspace all
            # intermediates share, vs. the per-layer-static scheme it
            # replaced, plus how many bytes are live at each layer step
            d["arena_bytes"] = net.arena_bytes
            d["arena_buffer_sum_bytes"] = net.arena_buffer_sum_bytes
            d["per_layer_live_bytes"] = dict(net.per_layer_live_bytes or {})
            d["peak_live_bytes"] = max(
                (net.per_layer_live_bytes or {}).values(), default=0)
            d["threads"] = self._backend.threads
        return d
