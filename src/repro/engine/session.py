"""The unified inference entry point: one session, three substrates.

    cfg = SessionConfig(backend="c", autotune=True)
    sess = InferenceSession(graph, config=cfg)
    probs = sess.predict(batch)          # (N, *out_shape)

Post-training int8 quantization is one more config field:

    sess = InferenceSession(graph, config=SessionConfig(
        precision="int8",
        calibration=CalibrationConfig(data=sample_batch)))

The session owns the whole deployment pipeline the repo previously
scattered across benchmarks/examples: the NNCG optimization passes,
ISA selection, per-layer variant autotuning (with the on-disk tuning
cache), calibration + quantization, codegen + compile, and batched
execution.

The historical kwarg-per-knob constructor
(``InferenceSession(graph, backend="c", autotune=True, ...)``) still
works: the kwargs are folded into a :class:`SessionConfig` by a shim
that emits a single :class:`DeprecationWarning` per process.
"""
from __future__ import annotations

import warnings
from typing import Optional

import numpy as np

from repro.core import cgen, passes, quantize as quantize_mod, runtime
from repro.core.graph import CNNGraph
from repro.core.schedule import Schedule, make_schedule

from .autotune import (Autotuner, TuneResult, TuningCache,
                       fusion_schedule_candidates,
                       int8_variant_candidates, tune_best_simd,
                       tune_pipeline_stages)
from .backends import (Backend, CBackend, QuantizedXLABackend, get_backend)
from .config import CalibrationConfig, SessionConfig

_UNSET = object()

# the legacy kwargs, in the order the old signature declared them
_LEGACY_KWARGS = ("autotune", "simd", "simd_search", "unroll", "optimize",
                  "threads", "tune_cache", "tune_iters", "func_name",
                  "precision", "calibration", "calib_samples",
                  "calibration_method", "calibration_percentile")

_legacy_warned = False


def _warn_legacy_once() -> None:
    global _legacy_warned
    if _legacy_warned:
        return
    _legacy_warned = True
    warnings.warn(
        "InferenceSession(graph, backend=..., <kwargs>) is deprecated; "
        "pass InferenceSession(graph, config=SessionConfig(...)) instead "
        "(calibration knobs go in SessionConfig.calibration="
        "CalibrationConfig(...)).",
        DeprecationWarning, stacklevel=4)


def _config_from_legacy(backend, kw: dict) -> SessionConfig:
    """Fold the historical kwargs into a SessionConfig."""
    calib = CalibrationConfig(
        data=kw.get("calibration"),
        samples=kw.get("calib_samples", 32),
        method=kw.get("calibration_method"),
        percentile=kw.get("calibration_percentile", 99.99))
    fields = {k: kw[k] for k in ("autotune", "simd", "simd_search", "unroll",
                                 "optimize", "threads", "tune_cache",
                                 "tune_iters", "func_name", "precision")
              if k in kw}
    return SessionConfig(backend=backend, calibration=calib, **fields)


class SessionInfo(dict):
    """The session's introspection dict.  Also callable —
    ``sess.info()`` and ``sess.info[...]`` both work, so callers
    written against either spelling of the API keep running."""

    def __call__(self) -> "SessionInfo":
        return self


class InferenceSession:
    """Build once, predict many — over any registered backend.

    Parameters
    ----------
    graph:    trained :class:`CNNGraph` (raw; passes run here unless
              ``config.optimize=False``).
    config:   a :class:`SessionConfig` (or a dict accepted by
              ``SessionConfig(**d)``).  Field reference:

              * ``backend`` — ``"c"`` | ``"xla"`` | ``"pallas"`` (see
                :func:`repro.engine.backends.available_backends`).
              * ``autotune`` — C backend only: benchmark every per-layer
                codegen variant and keep the fastest, consulting the
                on-disk tuning cache.
              * ``simd`` — C codegen mode
                (``'generic'|'structured'|'sse'|'avx'``); defaults to
                the widest ISA the host supports.
              * ``simd_search`` — with ``autotune``, simd modes to tune
                under; the engine keeps the fastest (mode, levels) pair.
              * ``unroll`` — C backend without autotune: ``"auto"``
                (static heuristic), a single level, or a per-layer dict.
              * ``threads`` — C backend: drive batches thread-parallel
                through the reentrant ``<func>_ws`` entry point.
              * ``tune_cache`` — directory (or :class:`TuningCache`) for
                persisted tuning results; ``None`` = default cache dir.
              * ``tune_iters`` — timing iterations per tuning candidate.
              * ``precision`` — ``"fp32"`` (default) or ``"int8"``
                post-training quantization.
              * ``calibration`` — a :class:`CalibrationConfig`:
                ``data`` (representative inputs ``(N, *in_shape)``;
                ``None`` synthesizes ``samples`` camera-like frames —
                bounded, spatially smooth, the domain the paper's nets
                see; unbounded noise was a diagnosed accuracy
                regression), ``method``
                (``"minmax"|"percentile"|"mse"``, or ``None`` = auto:
                minmax on caller data, percentile on synthesized
                frames), ``percentile``.

    Legacy: every config field is also accepted as a keyword argument
    (``calibration`` knobs under their old names ``calibration=``,
    ``calib_samples=``, ``calibration_method=``,
    ``calibration_percentile=``); that path emits one
    ``DeprecationWarning`` per process and cannot be mixed with
    ``config=``.
    """

    def __init__(self, graph: CNNGraph, backend=_UNSET, *,
                 config: Optional[SessionConfig] = None,
                 **legacy):
        unknown = set(legacy) - set(_LEGACY_KWARGS)
        if unknown:
            raise TypeError(
                f"InferenceSession: unexpected keyword arguments "
                f"{sorted(unknown)}")
        if config is not None:
            if backend is not _UNSET or legacy:
                raise TypeError(
                    "InferenceSession: pass either config= or the legacy "
                    "kwargs, not both")
            if isinstance(config, dict):
                config = SessionConfig(**config)
        else:
            if backend is not _UNSET or legacy:
                _warn_legacy_once()
            config = _config_from_legacy(
                "c" if backend is _UNSET else backend, legacy)
        if config.lm is not None:
            raise TypeError(
                "SessionConfig.lm is an LM workload: construct "
                "repro.engine.LMSession(config=cfg) instead of "
                "InferenceSession (which serves CNN graphs)")
        self.config = config

        self.backend_name = config.backend
        self.precision = config.precision
        self.simd = config.simd or runtime.best_isa()
        candidates = (list(config.simd_search)
                      if (config.simd_search and config.autotune
                          and config.backend == "c") else None)
        widths = [cgen.ISAS[s].width if s in cgen.ISAS else 4
                  for s in (candidates or [self.simd])]
        # int8 kernels vectorize over window taps, not output channels —
        # SIMD channel alignment would only add dead compute
        multiple = 1 if config.precision == "int8" else max(widths)
        self.graph = (passes.optimize(graph, simd_multiple=multiple)
                      if config.optimize else graph)
        self.tuned: Optional[TuneResult] = None
        self.qgraph = None
        self.schedule: Optional[Schedule] = None

        if config.precision == "int8":
            if config.calibration.qparams is not None:
                # externally-determined (e.g. QAT-exported) scales and
                # zero-points: no calibration pass at all
                self.qgraph = quantize_mod.quantize_from_qparams(
                    self.graph, config.calibration.qparams)
            else:
                calibration = config.calibration.data
                method = config.calibration.resolved_method(
                    data_provided=calibration is not None)
                if calibration is None:
                    calibration = self._default_calibration()
                self.qgraph = quantize_mod.quantize(
                    self.graph, calibration, method=method,
                    percentile=config.calibration.percentile,
                    per_channel=config.calibration.per_channel)
            self._init_int8(candidates)
            return

        if config.backend == "c":
            self.schedule = self._resolve_schedule()
            if config.autotune:
                cache = self._tuning_cache()
                if candidates:
                    self.simd, self.tuned = tune_best_simd(
                        self.graph, candidates, cache=cache,
                        iters=config.tune_iters, schedule=self.schedule)
                else:
                    tuner = Autotuner(self.simd, iters=config.tune_iters,
                                      cache=cache, schedule=self.schedule)
                    self.tuned = tuner.tune(self.graph)
                unroll_cfg = self.tuned.levels
            elif config.unroll == "auto":
                unroll_cfg = cgen.choose_levels(self.graph, 20_000)
            else:
                unroll_cfg = config.unroll
            # tuned levels were measured at the tuner's emission budget;
            # the deployed build must emit the same code
            term_budget = (self.tuned.term_cap if self.tuned is not None
                           else None)
            self._backend: Backend = CBackend(
                self.graph, simd=self.simd, unroll=unroll_cfg,
                func_name=config.func_name, term_budget=term_budget,
                threads=config.threads, schedule=self.schedule)
        else:
            self._backend = get_backend(config.backend)(self.graph)

    # -- construction helpers ------------------------------------------------

    def _tuning_cache(self) -> TuningCache:
        tc = self.config.tune_cache
        return tc if isinstance(tc, TuningCache) else TuningCache(tc)

    def _resolve_schedule(self, qgraph=None) -> Schedule:
        """The graph-level schedule this session deploys: epilogue
        fusion per ``config.fusion`` (auto = on — output is bitwise
        identical and the arena never grows; int8 autotune additionally
        times the unfused build and may deploy it, see
        :meth:`_init_int8`) and the pipeline stage count per
        ``config.pipeline_stages`` (0 = auto: the autotuner times the
        host's viable stage counts on a frame stream and the winner
        persists in the tuning cache)."""
        cfg = self.config
        fusion = True if cfg.fusion is None else cfg.fusion
        s = cfg.pipeline_stages
        if s == 0:
            s = tune_pipeline_stages(
                self.graph, simd=self.simd, qgraph=qgraph,
                cache=self._tuning_cache(), fusion=fusion,
                iters=max(8, cfg.tune_iters // 8),
                func_name=cfg.func_name)
        return make_schedule(self.graph, nstages=s, fusion=fusion)

    def _default_calibration(self) -> np.ndarray:
        """Representative frames for int8 calibration when the caller
        supplies none.  The paper's nets consume camera images: ranges
        calibrated on unbounded standard-normal noise (the old default)
        are unrepresentative of deployment and measurably cost accuracy
        — the exact failure mode diagnosed on the robot net (top-1
        agreement 0.94 on noise vs 0.99+ on camera-like frames)."""
        from repro.data.pipeline import camera_frame_batch
        in_shape = tuple(self.graph.input_shape)
        n = self.config.calibration.samples
        if len(in_shape) == 3:
            return camera_frame_batch(n, in_shape, seed=0)
        # non-image input: bounded uniform noise still beats unbounded
        # normal for range calibration
        return np.random.default_rng(0).uniform(
            -1.0, 1.0, size=(n,) + in_shape).astype(np.float32)

    def _init_int8(self, candidates) -> None:
        """Build the int8 serving backend.

        The quantized kernels' variant space is the SIMD mode (the int8
        emitters are rolled — unroll levels don't apply): with
        ``autotune`` the session times each candidate build and keeps
        the fastest; integer accumulation is order-independent, so all
        candidates are bit-identical and the choice is purely speed.
        The winning mode persists in the same on-disk tuning cache the
        float path uses (keyed by graph/compiler/codegen version plus
        an int8 tag), so a repeat session times nothing."""
        cfg = self.config
        if cfg.backend == "xla":
            self._backend = QuantizedXLABackend(self.qgraph)
            return
        if cfg.backend != "c":
            raise ValueError(
                f"precision='int8' supports backends 'c' and 'xla', "
                f"not {cfg.backend!r}")
        sched = self.schedule = self._resolve_schedule(self.qgraph)
        if cfg.autotune:
            cands = candidates
            if not cands:
                cands = int8_variant_candidates(self.qgraph)
            else:
                # explicit simd_search lists still go through the
                # runtime CPU-feature guard (no SIGILL, no duplicate
                # builds after fallback collapses variants)
                cands = list(dict.fromkeys(
                    runtime.resolve_int8_simd(s) for s in cands))
            # fusion kinds are a variant axis too when the config
            # leaves fusion to auto: fused output is bit-identical,
            # but on layers with channel-group tails a fused requant
            # epilogue can lose more than the skipped memory
            # round-trip buys, so each distinct kind subset (all,
            # Adds-only, none) is timed like any other code version
            scheds = [sched]
            if cfg.fusion is None:
                scheds = fusion_schedule_candidates(
                    self.graph, nstages=len(sched.stages))
            cache = self._tuning_cache()
            # the generated int8 C embeds the calibration-derived
            # qparams, so the cache key must carry them: a different
            # calibration set/method is a different program — and so
            # is a different schedule (fusion + stage partition)
            qdigest = quantize_mod.qparams_digest(self.qgraph)
            key = cache.key(self.graph, "+".join(cands),
                            extra=f"int8:{qdigest}:i{cfg.tune_iters}:sched:"
                                  + "+".join(s.digest() for s in scheds))
            rec = cache.get(key)
            if rec is not None and rec.get("simd") in cands:
                self.schedule = next(
                    (s for s in scheds if s.digest() == rec.get("sched")),
                    sched)
                self._backend = CBackend(
                    self.graph, simd=rec["simd"], func_name=cfg.func_name,
                    threads=cfg.threads, qgraph=self.qgraph,
                    schedule=self.schedule)
                self.simd = self._backend.opts.simd
                self.tuned = TuneResult(levels={}, us_per_call=float(
                    rec.get("us_per_call", 0.0)), from_cache=True)
                return
            x = np.random.default_rng(0).normal(
                size=self.graph.input_shape).astype(np.float32)
            best = None
            for simd in cands:
                for sc in scheds:
                    b = CBackend(self.graph, simd=simd,
                                 func_name=cfg.func_name,
                                 threads=cfg.threads, qgraph=self.qgraph,
                                 schedule=sc)
                    # min over repeats: scheduler noise must not persist
                    # a wrong variant/schedule into the tuning cache
                    t = min(b.time_per_call_us(
                        x, iters=cfg.tune_iters,
                        warmup=max(10, cfg.tune_iters // 10))
                        for _ in range(3))
                    if best is None or t < best[0]:
                        best = (t, simd, sc, b)
            _, _, self.schedule, self._backend = best
            self.simd = self._backend.opts.simd
            cache.put(key, {"simd": self.simd,
                            "sched": self.schedule.digest(),
                            "us_per_call": round(best[0], 3)})
            self.tuned = TuneResult(levels={}, us_per_call=best[0],
                                    from_cache=False)
        else:
            # no autotune: honor an explicit simd= (post guard) or take
            # the host's best int8 variant outright
            simd = cfg.simd or runtime.supported_int8_simds()[0]
            self._backend = CBackend(self.graph, simd=simd,
                                     func_name=cfg.func_name,
                                     threads=cfg.threads,
                                     qgraph=self.qgraph,
                                     schedule=sched)
            self.simd = self._backend.opts.simd

    # -- shapes --------------------------------------------------------------

    @property
    def input_shape(self):
        return self.graph.input_shape

    @property
    def output_shape(self):
        return self.graph.output_shape

    @property
    def backend(self) -> Backend:
        """The live :class:`Backend` this session serves through."""
        return self._backend

    def close(self) -> None:
        self._backend.close()

    # -- execution -----------------------------------------------------------

    def predict(self, x: np.ndarray) -> np.ndarray:
        """Single image ``(*in_shape)`` -> ``(*out_shape)``, or batch
        ``(N, *in_shape)`` -> ``(N, *out_shape)``."""
        x = np.asarray(x, dtype=np.float32)
        in_shape = tuple(self.input_shape)
        if x.shape == in_shape:
            return self._backend.predict_batch(x[None])[0]
        if x.shape[1:] == in_shape:
            return self._backend.predict_batch(x)
        raise ValueError(
            f"predict: expected {in_shape} or (N,)+{in_shape}, "
            f"got {x.shape}")

    def benchmark(self, x: Optional[np.ndarray] = None, *,
                  iters: int = 500, warmup: int = 20) -> float:
        """Single-image latency of this session's backend in µs/call.

        Accepts one image or a batch — a batch is sliced to its first
        image here, consistently for every backend (the C backend's
        ctypes timing loop reads exactly one image's worth of memory
        and would otherwise trip its single-image assert)."""
        if x is None:
            x = np.random.default_rng(0).normal(
                size=self.input_shape).astype(np.float32)
        x = np.asarray(x, np.float32)
        in_shape = tuple(self.input_shape)
        if x.shape != in_shape:
            if x.ndim == len(in_shape) + 1 and x.shape[1:] == in_shape:
                x = x[0]  # batch -> its first image, for all backends
            else:
                raise ValueError(
                    f"benchmark times one image of {in_shape}, "
                    f"got {x.shape}")
        return self._backend.time_per_call_us(x, iters=iters, warmup=warmup)

    # -- introspection -------------------------------------------------------

    @property
    def info(self) -> SessionInfo:
        d = SessionInfo(
            backend=self.backend_name, simd=self.simd,
            precision=self.precision,
            input_shape=tuple(self.input_shape),
            output_shape=tuple(self.output_shape),
            # the stable, reconstructible config section:
            # SessionConfig(**info["config"]) == config.portable()
            config=self.config.to_dict())
        if self.qgraph is not None:
            d["quantized_layers"] = sorted(self.qgraph.weights)
            d["input_qparams"] = (self.qgraph.input_qp.scale,
                                  self.qgraph.input_qp.zero_point)
            d["calibration_method"] = self.qgraph.method
            if self.qgraph.method == "percentile":
                d["calibration_percentile"] = self.qgraph.percentile
        if self.schedule is not None:
            # fusion decisions + stage partition of the deployed build
            d["schedule"] = self.schedule.describe()
        if self.tuned is not None:
            d.update(levels=self.tuned.levels,
                     tuned_us_per_call=self.tuned.us_per_call,
                     tuned_from_cache=self.tuned.from_cache)
        desc = self._backend.describe()
        if "arena_bytes" in desc:
            # liveness-planned memory: the one workspace all
            # intermediates share, vs. the per-layer-static scheme it
            # replaced, plus how many bytes are live at each layer step
            d["c_source_bytes"] = desc["c_source_bytes"]
            d["so_path"] = desc["so_path"]
            d["arena_bytes"] = desc["arena_bytes"]
            d["arena_buffer_sum_bytes"] = desc["arena_buffer_sum_bytes"]
            d["per_layer_live_bytes"] = desc["per_layer_live_bytes"]
            d["peak_live_bytes"] = max(
                desc["per_layer_live_bytes"].values(), default=0)
            d["threads"] = desc["threads"]
        return d
