"""The unified inference entry point: one session, three substrates.

    sess = InferenceSession(graph, backend="c", autotune=True)
    probs = sess.predict(batch)          # (N, *out_shape)

The session owns the whole deployment pipeline the repo previously
scattered across benchmarks/examples: the NNCG optimization passes,
ISA selection, per-layer variant autotuning (with the on-disk tuning
cache), codegen + compile, and batched execution.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Union

import numpy as np

from repro.core import cgen, passes, runtime
from repro.core.graph import CNNGraph

from .autotune import Autotuner, TuneResult, TuningCache, tune_best_simd
from .backends import Backend, CBackend, get_backend


class InferenceSession:
    """Build once, predict many — over any registered backend.

    Parameters
    ----------
    graph:    trained :class:`CNNGraph` (raw; passes run here unless
              ``optimize=False``).
    backend:  ``"c"`` | ``"xla"`` | ``"pallas"`` (see
              :func:`repro.engine.backends.available_backends`).
    autotune: C backend only — benchmark every per-layer codegen variant
              and keep the fastest, consulting the on-disk tuning cache.
    simd:     C codegen mode (``'generic'|'structured'|'sse'|'avx'``);
              defaults to the widest ISA the host supports.
    simd_search: with ``autotune``, a list of simd modes to tune under —
              the engine keeps the fastest (mode, per-layer levels) pair.
    unroll:   C backend without autotune — ``"auto"`` (static heuristic),
              a single level, or a per-layer dict.
    threads:  C backend — drive batches thread-parallel through the
              reentrant ``<func>_ws`` entry point (one liveness-planned
              workspace per thread); ``None``/1 keeps the sequential
              generated batch loop.
    tune_cache: directory (or :class:`TuningCache`) for persisted tuning
              results; ``None`` uses the default cache dir.
    tune_iters: timing iterations per candidate during autotuning.
    """

    def __init__(self, graph: CNNGraph, backend: str = "c", *,
                 autotune: bool = False,
                 simd: Optional[str] = None,
                 simd_search: Optional[Sequence[str]] = None,
                 unroll: Union[str, int, None, Dict] = "auto",
                 optimize: bool = True,
                 threads: Optional[int] = None,
                 tune_cache: Union[None, str, TuningCache] = None,
                 tune_iters: int = 300,
                 func_name: str = "nncg_net"):
        self.backend_name = backend
        self.simd = simd or runtime.best_isa()
        candidates = list(simd_search) if (simd_search and autotune
                                           and backend == "c") else None
        widths = [cgen.ISAS[s].width if s in cgen.ISAS else 4
                  for s in (candidates or [self.simd])]
        self.graph = (passes.optimize(graph, simd_multiple=max(widths))
                      if optimize else graph)
        self.tuned: Optional[TuneResult] = None

        if backend == "c":
            if autotune:
                cache = (tune_cache if isinstance(tune_cache, TuningCache)
                         else TuningCache(tune_cache))
                if candidates:
                    self.simd, self.tuned = tune_best_simd(
                        self.graph, candidates, cache=cache,
                        iters=tune_iters)
                else:
                    tuner = Autotuner(self.simd, iters=tune_iters,
                                      cache=cache)
                    self.tuned = tuner.tune(self.graph)
                unroll_cfg = self.tuned.levels
            elif unroll == "auto":
                unroll_cfg = cgen.choose_levels(self.graph, 20_000)
            else:
                unroll_cfg = unroll
            # tuned levels were measured at the tuner's emission budget;
            # the deployed build must emit the same code
            term_budget = (self.tuned.term_cap if self.tuned is not None
                           else None)
            self._backend: Backend = CBackend(
                self.graph, simd=self.simd, unroll=unroll_cfg,
                func_name=func_name, term_budget=term_budget,
                threads=threads)
        else:
            self._backend = get_backend(backend)(self.graph)

    # -- shapes --------------------------------------------------------------

    @property
    def input_shape(self):
        return self.graph.input_shape

    @property
    def output_shape(self):
        return self.graph.output_shape

    # -- execution -----------------------------------------------------------

    def predict(self, x: np.ndarray) -> np.ndarray:
        """Single image ``(*in_shape)`` -> ``(*out_shape)``, or batch
        ``(N, *in_shape)`` -> ``(N, *out_shape)``."""
        x = np.asarray(x, dtype=np.float32)
        in_shape = tuple(self.input_shape)
        if x.shape == in_shape:
            return self._backend.predict_batch(x[None])[0]
        if x.shape[1:] == in_shape:
            return self._backend.predict_batch(x)
        raise ValueError(
            f"predict: expected {in_shape} or (N,)+{in_shape}, "
            f"got {x.shape}")

    def benchmark(self, x: Optional[np.ndarray] = None, *,
                  iters: int = 500, warmup: int = 20) -> float:
        """Single-image latency of this session's backend in µs/call."""
        if x is None:
            x = np.random.default_rng(0).normal(
                size=self.input_shape).astype(np.float32)
        x = np.asarray(x, np.float32)
        if x.shape != tuple(self.input_shape):
            raise ValueError(
                f"benchmark times one image of {tuple(self.input_shape)}, "
                f"got {x.shape} — pass batch[i], not the batch")
        return self._backend.time_per_call_us(x, iters=iters, warmup=warmup)

    # -- introspection -------------------------------------------------------

    @property
    def info(self) -> dict:
        d = {"backend": self.backend_name, "simd": self.simd,
             "input_shape": tuple(self.input_shape),
             "output_shape": tuple(self.output_shape)}
        if self.tuned is not None:
            d.update(levels=self.tuned.levels,
                     tuned_us_per_call=self.tuned.us_per_call,
                     tuned_from_cache=self.tuned.from_cache)
        if isinstance(self._backend, CBackend):
            net = self._backend.net
            d["c_source_bytes"] = net.c_source_bytes
            d["so_path"] = net.so_path
            # liveness-planned memory: the one workspace all
            # intermediates share, vs. the per-layer-static scheme it
            # replaced, plus how many bytes are live at each layer step
            d["arena_bytes"] = net.arena_bytes
            d["arena_buffer_sum_bytes"] = net.arena_buffer_sum_bytes
            d["per_layer_live_bytes"] = dict(net.per_layer_live_bytes or {})
            d["peak_live_bytes"] = max(
                (net.per_layer_live_bytes or {}).values(), default=0)
            d["threads"] = self._backend.threads
        return d
