"""Typed, frozen session configuration.

:class:`InferenceSession` grew one keyword argument per PR until
constructing it programmatically (the serving layer, benchmark sweeps,
config files) meant threading seventeen loosely-validated kwargs.
:class:`SessionConfig` is the consolidation: one frozen dataclass, one
nested :class:`CalibrationConfig` for the int8 calibration knobs,
validation at construction time, and a stable JSON-safe ``to_dict()``
that round-trips::

    cfg = SessionConfig(backend="c", autotune=True, precision="int8")
    sess = InferenceSession(graph, config=cfg)
    assert SessionConfig(**sess.info["config"]) == cfg.portable()

The legacy per-kwarg path (``InferenceSession(graph, backend="c",
autotune=True, ...)``) still works through a deprecation shim in
``session.py`` that builds a ``SessionConfig`` internally.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple, Union

from repro.core import quantize as quantize_mod

_PRECISIONS = ("fp32", "int8")


@dataclass(frozen=True)
class CalibrationConfig:
    """The int8 calibration knobs (ignored at ``precision="fp32"``).

    ``data`` is the representative sample batch ``(N, *in_shape)``; when
    ``None`` the session synthesizes ``samples`` camera-like frames via
    :func:`repro.data.pipeline.camera_frame_batch` (bounded, spatially
    smooth — the input domain the paper's nets actually see).  ``data``
    is runtime state, not a knob: it is excluded from ``to_dict()``.

    ``method=None`` means *auto*: ``"minmax"`` when the caller provided
    ``data`` (the historical, bit-stable behavior), ``"percentile"``
    when the session synthesizes its default frames (outlier-tail clip
    is what keeps the robot net's top-1 agreement >= 0.99 there).
    """

    data: Optional[Any] = None          # np.ndarray; not serialized
    samples: int = 32
    method: Optional[str] = None        # None = auto (see above)
    percentile: float = 99.99

    def __post_init__(self):
        if (self.method is not None
                and self.method not in quantize_mod.CALIBRATION_METHODS):
            raise ValueError(
                f"calibration method {self.method!r}; expected one of "
                f"{quantize_mod.CALIBRATION_METHODS} or None (auto)")
        if not (0.0 < self.percentile <= 100.0):
            raise ValueError(
                f"calibration percentile {self.percentile!r} not in (0, 100]")
        if self.samples < 1:
            raise ValueError(f"calibration samples {self.samples} < 1")

    def resolved_method(self, *, data_provided: bool) -> str:
        """The concrete range-selection method after resolving auto."""
        if self.method is not None:
            return self.method
        return "minmax" if data_provided else "percentile"

    def to_dict(self) -> Dict[str, Any]:
        """JSON-safe knobs (``data`` omitted — arrays don't serialize)."""
        return {"samples": self.samples, "method": self.method,
                "percentile": self.percentile}


def _coerce_calibration(v) -> CalibrationConfig:
    if isinstance(v, CalibrationConfig):
        return v
    if isinstance(v, dict):
        return CalibrationConfig(**v)
    if v is None:
        return CalibrationConfig()
    # legacy spelling: calibration=<sample batch array>
    return CalibrationConfig(data=v)


@dataclass(frozen=True)
class SessionConfig:
    """Everything :class:`InferenceSession` needs beyond the graph.

    Field semantics match the historical kwargs one-for-one (see the
    session docstring); the four calibration knobs live in the nested
    :class:`CalibrationConfig`.  Frozen: a config can key caches and be
    shared across threads/workers without defensive copies.
    """

    backend: str = "c"
    autotune: bool = False
    simd: Optional[str] = None
    simd_search: Optional[Tuple[str, ...]] = None
    unroll: Union[str, int, None, Dict] = "auto"
    optimize: bool = True
    threads: Optional[int] = None
    tune_cache: Optional[Any] = None    # dir path str, or a TuningCache
    tune_iters: int = 300
    func_name: str = "nncg_net"
    precision: str = "fp32"
    calibration: CalibrationConfig = field(default_factory=CalibrationConfig)
    # graph-level schedule (C backend): epilogue fusion on/off
    # (None = auto = on; output is bitwise identical either way) and
    # pipeline stage count (1 = monolithic, k>1 = layer-pipelined
    # build streaming batches across k cores, 0 = auto: the autotuner
    # times the host's viable stage counts and keeps the fastest)
    fusion: Optional[bool] = None
    pipeline_stages: int = 1

    def __post_init__(self):
        if self.precision not in _PRECISIONS:
            raise ValueError(
                f"precision {self.precision!r}; expected one of {_PRECISIONS}")
        if self.tune_iters < 1:
            raise ValueError(f"tune_iters {self.tune_iters} < 1")
        if self.pipeline_stages < 0:
            raise ValueError(
                f"pipeline_stages {self.pipeline_stages} < 0 "
                f"(0 = auto, 1 = single stage, k = k stages)")
        # normalize the container-ish fields so equality and to_dict()
        # are stable regardless of how the caller spelled them
        object.__setattr__(self, "calibration",
                           _coerce_calibration(self.calibration))
        if self.simd_search is not None:
            object.__setattr__(self, "simd_search",
                               tuple(self.simd_search))

    def replace(self, **changes) -> "SessionConfig":
        """A copy with ``changes`` applied (frozen-friendly update)."""
        return dataclasses.replace(self, **changes)

    def portable(self) -> "SessionConfig":
        """The serializable projection of this config: calibration data
        and live :class:`TuningCache` objects dropped (a cache *path*
        string is kept).  ``SessionConfig(**cfg.to_dict())`` equals
        ``cfg.portable()``."""
        changes: Dict[str, Any] = {}
        if self.calibration.data is not None:
            changes["calibration"] = dataclasses.replace(
                self.calibration, data=None)
        if self.tune_cache is not None and not isinstance(
                self.tune_cache, str):
            changes["tune_cache"] = getattr(self.tune_cache, "path", None)
        return self.replace(**changes) if changes else self

    def to_dict(self) -> Dict[str, Any]:
        """Stable JSON-safe dict; ``SessionConfig(**d)`` reconstructs."""
        p = self.portable()
        d = dataclasses.asdict(p)
        d["calibration"] = p.calibration.to_dict()
        if d["simd_search"] is not None:
            d["simd_search"] = list(d["simd_search"])
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SessionConfig":
        return cls(**d)
