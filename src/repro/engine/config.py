"""Typed, frozen session configuration.

:class:`InferenceSession` grew one keyword argument per PR until
constructing it programmatically (the serving layer, benchmark sweeps,
config files) meant threading seventeen loosely-validated kwargs.
:class:`SessionConfig` is the consolidation: one frozen dataclass, one
nested :class:`CalibrationConfig` for the int8 calibration knobs,
validation at construction time, and a stable JSON-safe ``to_dict()``
that round-trips::

    cfg = SessionConfig(backend="c", autotune=True, precision="int8")
    sess = InferenceSession(graph, config=cfg)
    assert SessionConfig(**sess.info["config"]) == cfg.portable()

The legacy per-kwarg path (``InferenceSession(graph, backend="c",
autotune=True, ...)``) still works through a deprecation shim in
``session.py`` that builds a ``SessionConfig`` internally.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple, Union

from repro.core import quantize as quantize_mod

_PRECISIONS = ("fp32", "int8")


@dataclass(frozen=True)
class CalibrationConfig:
    """The int8 calibration knobs (ignored at ``precision="fp32"``).

    ``data`` is the representative sample batch ``(N, *in_shape)``; when
    ``None`` the session synthesizes ``samples`` camera-like frames via
    :func:`repro.data.pipeline.camera_frame_batch` (bounded, spatially
    smooth — the input domain the paper's nets actually see).  ``data``
    is runtime state, not a knob: it is excluded from ``to_dict()``.

    ``method=None`` means *auto*: ``"minmax"`` when the caller provided
    ``data`` (the historical, bit-stable behavior), ``"percentile"``
    when the session synthesizes its default frames (outlier-tail clip
    is what keeps the robot net's top-1 agreement >= 0.99 there).

    ``qparams`` accepts externally-determined quantization parameters —
    e.g. exported from a QAT run — as a mapping of layer name to
    :class:`repro.core.quantize.QParams` (or a ``(scale, zero_point)``
    pair).  When set, the session skips calibration entirely and feeds
    the provided scales/zero-points straight into the
    :class:`QuantizedGraph`; like ``data`` it is runtime state, not a
    serializable knob.

    ``per_channel=True`` gives eligible layers per-output-channel
    activation qparams (scales folded into the consumers' weight
    quantization; see :func:`repro.core.quantize.per_channel_eligible`)
    — finer steps for narrow channels at zero inner-loop cost.
    Ignored when ``qparams`` is provided (the import format is
    per-tensor).
    """

    data: Optional[Any] = None          # np.ndarray; not serialized
    samples: int = 32
    method: Optional[str] = None        # None = auto (see above)
    percentile: float = 99.99
    qparams: Optional[Dict[str, Any]] = None  # QAT import; not serialized
    per_channel: bool = False

    def __post_init__(self):
        if (self.method is not None
                and self.method not in quantize_mod.CALIBRATION_METHODS):
            raise ValueError(
                f"calibration method {self.method!r}; expected one of "
                f"{quantize_mod.CALIBRATION_METHODS} or None (auto)")
        if not (0.0 < self.percentile <= 100.0):
            raise ValueError(
                f"calibration percentile {self.percentile!r} not in (0, 100]")
        if self.samples < 1:
            raise ValueError(f"calibration samples {self.samples} < 1")

    def resolved_method(self, *, data_provided: bool) -> str:
        """The concrete range-selection method after resolving auto."""
        if self.method is not None:
            return self.method
        return "minmax" if data_provided else "percentile"

    def to_dict(self) -> Dict[str, Any]:
        """JSON-safe knobs (``data`` omitted — arrays don't serialize)."""
        return {"samples": self.samples, "method": self.method,
                "percentile": self.percentile,
                "per_channel": self.per_channel}


@dataclass(frozen=True)
class LMConfig:
    """The LM workload sub-config carried by ``SessionConfig.lm``.

    Setting it routes the session through :class:`repro.engine.lm.LMSession`
    and the ``"pallas-lm"`` backend instead of a compiled CNN graph.

    ``arch`` names an entry of :data:`repro.configs.lm_archs.ARCHS`;
    ``smoke=True`` shrinks it via ``ModelConfig.smoke()`` (the CI/CPU
    shape).  ``attn_variant``/``scan_variant``/``block_q``/``block_k``
    pin :class:`repro.models.kernel_policy.KernelPolicy` axes; axes left
    ``None`` are chosen by the autotuner when ``autotune=True`` (winner
    persisted in the tuning cache) and fall back to the defaults
    otherwise.  ``mesh_shape`` requests a device mesh for data-parallel
    prefill via :mod:`repro.launch.mesh`; when the host has fewer
    devices the session falls back to single-device cleanly.
    """

    arch: str = "gemma3-4b"
    smoke: bool = True
    max_context: int = 128
    decode_batch: int = 1
    attn_variant: Optional[str] = None
    scan_variant: Optional[str] = None
    block_q: Optional[int] = None
    block_k: Optional[int] = None
    mesh_shape: Optional[Tuple[int, ...]] = None
    seed: int = 0

    def __post_init__(self):
        # deferred imports: repro.configs/repro.models pull in jax, which
        # the pure-C config path must not require at import time
        from repro.configs.lm_archs import ARCHS
        from repro.models.kernel_policy import (ATTENTION_VARIANTS,
                                                SCAN_VARIANTS)
        if self.arch not in ARCHS:
            raise ValueError(
                f"lm arch {self.arch!r}; expected one of "
                f"{tuple(sorted(ARCHS))}")
        if self.max_context < 1:
            raise ValueError(f"max_context {self.max_context} < 1")
        if self.decode_batch < 1:
            raise ValueError(f"decode_batch {self.decode_batch} < 1")
        if (self.attn_variant is not None
                and self.attn_variant not in ATTENTION_VARIANTS):
            raise ValueError(
                f"attn_variant {self.attn_variant!r}; expected one of "
                f"{ATTENTION_VARIANTS} or None (autotuned)")
        if (self.scan_variant is not None
                and self.scan_variant not in SCAN_VARIANTS):
            raise ValueError(
                f"scan_variant {self.scan_variant!r}; expected one of "
                f"{SCAN_VARIANTS} or None (autotuned)")
        for name in ("block_q", "block_k"):
            v = getattr(self, name)
            if v is not None and v < 1:
                raise ValueError(f"{name} {v} < 1")
        if self.mesh_shape is not None:
            object.__setattr__(self, "mesh_shape",
                               tuple(int(d) for d in self.mesh_shape))
            if any(d < 1 for d in self.mesh_shape):
                raise ValueError(f"mesh_shape {self.mesh_shape}")

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        if d["mesh_shape"] is not None:
            d["mesh_shape"] = list(d["mesh_shape"])
        return d


def _coerce_lm(v) -> Optional[LMConfig]:
    if v is None or isinstance(v, LMConfig):
        return v
    if isinstance(v, dict):
        return LMConfig(**v)
    if isinstance(v, str):  # shorthand: lm="gemma3-4b"
        return LMConfig(arch=v)
    raise TypeError(f"lm must be an LMConfig, dict, arch name or None; "
                    f"got {type(v).__name__}")


def _coerce_calibration(v) -> CalibrationConfig:
    if isinstance(v, CalibrationConfig):
        return v
    if isinstance(v, dict):
        return CalibrationConfig(**v)
    if v is None:
        return CalibrationConfig()
    # legacy spelling: calibration=<sample batch array>
    return CalibrationConfig(data=v)


@dataclass(frozen=True)
class SessionConfig:
    """Everything :class:`InferenceSession` needs beyond the graph.

    Field semantics match the historical kwargs one-for-one (see the
    session docstring); the four calibration knobs live in the nested
    :class:`CalibrationConfig`.  Frozen: a config can key caches and be
    shared across threads/workers without defensive copies.
    """

    backend: str = "c"
    autotune: bool = False
    simd: Optional[str] = None
    simd_search: Optional[Tuple[str, ...]] = None
    unroll: Union[str, int, None, Dict] = "auto"
    optimize: bool = True
    threads: Optional[int] = None
    tune_cache: Optional[Any] = None    # dir path str, or a TuningCache
    tune_iters: int = 300
    func_name: str = "nncg_net"
    precision: str = "fp32"
    calibration: CalibrationConfig = field(default_factory=CalibrationConfig)
    # graph-level schedule (C backend): epilogue fusion on/off for
    # every consumer kind — residual Adds, MaxPool/AvgPool, Concat
    # edges (None = auto = on, and int8 autotune additionally times
    # kind subsets as code variants; output is bitwise identical
    # either way) and pipeline stage count (1 = monolithic, k>1 =
    # layer-pipelined build streaming batches across k cores, 0 =
    # auto: the autotuner times the host's viable stage counts and
    # keeps the fastest)
    fusion: Optional[bool] = None
    pipeline_stages: int = 1
    # LM workload sub-config; None = classic CNN-graph session.  Accepts
    # an LMConfig, a dict (from to_dict round-trips), or an arch name.
    lm: Optional[LMConfig] = None

    def __post_init__(self):
        if self.precision not in _PRECISIONS:
            raise ValueError(
                f"precision {self.precision!r}; expected one of {_PRECISIONS}")
        if self.tune_iters < 1:
            raise ValueError(f"tune_iters {self.tune_iters} < 1")
        if self.pipeline_stages < 0:
            raise ValueError(
                f"pipeline_stages {self.pipeline_stages} < 0 "
                f"(0 = auto, 1 = single stage, k = k stages)")
        # normalize the container-ish fields so equality and to_dict()
        # are stable regardless of how the caller spelled them
        object.__setattr__(self, "calibration",
                           _coerce_calibration(self.calibration))
        object.__setattr__(self, "lm", _coerce_lm(self.lm))
        if self.simd_search is not None:
            object.__setattr__(self, "simd_search",
                               tuple(self.simd_search))

    def replace(self, **changes) -> "SessionConfig":
        """A copy with ``changes`` applied (frozen-friendly update)."""
        return dataclasses.replace(self, **changes)

    def portable(self) -> "SessionConfig":
        """The serializable projection of this config: calibration data
        and live :class:`TuningCache` objects dropped (a cache *path*
        string is kept).  ``SessionConfig(**cfg.to_dict())`` equals
        ``cfg.portable()``."""
        changes: Dict[str, Any] = {}
        if (self.calibration.data is not None
                or self.calibration.qparams is not None):
            changes["calibration"] = dataclasses.replace(
                self.calibration, data=None, qparams=None)
        if self.tune_cache is not None and not isinstance(
                self.tune_cache, str):
            changes["tune_cache"] = getattr(self.tune_cache, "path", None)
        return self.replace(**changes) if changes else self

    def to_dict(self) -> Dict[str, Any]:
        """Stable JSON-safe dict; ``SessionConfig(**d)`` reconstructs."""
        p = self.portable()
        d = dataclasses.asdict(p)
        d["calibration"] = p.calibration.to_dict()
        d["lm"] = p.lm.to_dict() if p.lm is not None else None
        if d["simd_search"] is not None:
            d["simd_search"] = list(d["simd_search"])
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SessionConfig":
        return cls(**d)
