"""Unified multi-backend inference engine (paper §III deployment +
Table VII per-layer variant selection, as a library)."""
from .autotune import (Autotuner, TuneResult, TuningCache, cc_fingerprint,
                       graph_fingerprint, tune_best_simd)
from .backends import (Backend, available_backends, get_backend,
                       register_backend)
from .config import CalibrationConfig, SessionConfig
from .session import InferenceSession

__all__ = [
    "Autotuner",
    "Backend",
    "CalibrationConfig",
    "InferenceSession",
    "SessionConfig",
    "TuneResult",
    "TuningCache",
    "available_backends",
    "cc_fingerprint",
    "get_backend",
    "graph_fingerprint",
    "register_backend",
    "tune_best_simd",
]
