"""Unified multi-backend inference engine (paper §III deployment +
Table VII per-layer variant selection, as a library)."""
from .autotune import (Autotuner, LMTuneResult, TuneResult, TuningCache,
                       cc_fingerprint, device_digest, graph_fingerprint,
                       lm_fingerprint, tune_best_simd, tune_lm_variants)
from .backends import (Backend, KVCacheHandle, LMBackend, PallasLMBackend,
                       available_backends, get_backend, register_backend)
from .config import CalibrationConfig, LMConfig, SessionConfig
from .lm import LMSession
from .session import InferenceSession

__all__ = [
    "Autotuner",
    "Backend",
    "CalibrationConfig",
    "InferenceSession",
    "KVCacheHandle",
    "LMBackend",
    "LMConfig",
    "LMSession",
    "LMTuneResult",
    "PallasLMBackend",
    "SessionConfig",
    "TuneResult",
    "TuningCache",
    "available_backends",
    "cc_fingerprint",
    "device_digest",
    "get_backend",
    "graph_fingerprint",
    "lm_fingerprint",
    "register_backend",
    "tune_best_simd",
    "tune_lm_variants",
]
