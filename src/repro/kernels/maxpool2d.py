"""Pallas TPU kernel: 2-D max pooling (paper §II-B.2).

Channels ride the lane dimension (P4); the window tap loop is static and
unrolled at trace time (P1); the max is a VPU ``jnp.maximum`` — the
vector analogue of the paper's ``_mm_max_ps`` / ternary emission (P2).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pool_kernel(x_ref, o_ref, *, kh, kw, sh, sw, oh, ow):
    x = x_ref[0]  # (H, W, TC)
    tc = x.shape[-1]
    out = None
    for n in range(kh):
        for m in range(kw):
            xs = jax.lax.slice(
                x, (n, m, 0),
                (n + (oh - 1) * sh + 1, m + (ow - 1) * sw + 1, tc),
                (sh, sw, 1))
            out = xs if out is None else jnp.maximum(out, xs)
    o_ref[0] = out


def maxpool2d_pallas(x: jax.Array, *, size: Tuple[int, int] = (2, 2),
                     strides: Optional[Tuple[int, int]] = None,
                     block_c: Optional[int] = None,
                     interpret: bool = True) -> jax.Array:
    n, h, w, c = x.shape
    kh, kw = size
    sh, sw = strides or size
    oh = (h - kh) // sh + 1
    ow = (w - kw) // sw + 1
    tc = block_c or min(c, 128)
    if c % tc:
        tc = c
    kern = functools.partial(_pool_kernel, kh=kh, kw=kw, sh=sh, sw=sw,
                             oh=oh, ow=ow)
    return pl.pallas_call(
        kern,
        grid=(n, c // tc),
        in_specs=[pl.BlockSpec((1, h, w, tc), lambda i, j: (i, 0, 0, j))],
        out_specs=pl.BlockSpec((1, oh, ow, tc), lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, c), x.dtype),
        interpret=interpret,
    )(x)
