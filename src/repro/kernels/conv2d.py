"""Pallas TPU kernel: fused Conv2D + bias + (leaky-)ReLU.

This is the paper's compute hot spot (§II-B.1) rebuilt TPU-native instead
of ported: the CPU version vectorizes over output channels with SSE
(groups of 4); here ``c_out`` lives on the 128-wide lane dimension and
each kernel invocation computes the convolution as an **implicit GEMM** —
one MXU ``dot`` per filter tap over the ``c_in`` contraction — which is
how a systolic array wants to see a convolution (no im2col
materialization in HBM).

NNCG principle mapping:
  * P1 (unroll/caching): the tap loop is a *static* Python loop — fully
    unrolled at trace time; the whole padded image tile stays resident in
    VMEM across taps (the cache-residency side of the trade-off).
  * P2 (cond-move):     activation is a ``jnp.where`` → VPU select.
  * P3 (constants):     shapes/taps/strides are compile-time constants;
    BN is folded into weights/bias *before* the call (passes.py).
  * P4 (SIMD layout):   NHWC with ``c_out`` blocked on lanes,
    ``block_cout`` a multiple of 128 where the layer allows.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, kh: int, kw: int,
                 sh: int, sw: int, oh: int, ow: int,
                 act: Optional[str], alpha: float):
    ci = x_ref.shape[-1]
    tc = o_ref.shape[-1]
    x = x_ref[0]  # (HP, WP, CI) — whole padded tile, VMEM-resident
    acc = jnp.zeros((oh * ow, tc), jnp.float32)
    for n in range(kh):          # P1: static tap loop, unrolled at trace
        for m in range(kw):
            xs = jax.lax.slice(
                x, (n, m, 0),
                (n + (oh - 1) * sh + 1, m + (ow - 1) * sw + 1, ci),
                (sh, sw, 1))  # (OH, OW, CI)
            acc += jnp.dot(xs.reshape(oh * ow, ci),
                           w_ref[n, m].astype(x.dtype),
                           preferred_element_type=jnp.float32)
    acc = acc + b_ref[0][None, :].astype(jnp.float32)
    if act == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif act == "leaky_relu":
        acc = jnp.where(acc > 0, acc, alpha * acc)  # P2: select, no branch
    o_ref[0] = acc.reshape(oh, ow, tc).astype(o_ref.dtype)


def conv2d_pallas(x: jax.Array, w: jax.Array, b: jax.Array, *,
                  strides: Tuple[int, int] = (1, 1),
                  padding: str = "valid",
                  act: Optional[str] = None, alpha: float = 0.1,
                  block_cout: Optional[int] = None,
                  interpret: bool = True) -> jax.Array:
    """x: (N,H,W,CI) NHWC; w: (KH,KW,CI,CO) HWIO; b: (CO,)."""
    n, h, wd, ci = x.shape
    kh, kw, wci, co = w.shape
    assert wci == ci
    sh, sw = strides
    if padding == "same":
        out_h, out_w = -(-h // sh), -(-wd // sw)
        ph = max((out_h - 1) * sh + kh - h, 0)
        pw = max((out_w - 1) * sw + kw - wd, 0)
        x = jnp.pad(x, ((0, 0), (ph // 2, ph - ph // 2),
                        (pw // 2, pw - pw // 2), (0, 0)))
        h, wd = h + ph, wd + pw
    oh = (h - kh) // sh + 1
    ow = (wd - kw) // sw + 1
    tc = block_cout or min(co, 128)
    if co % tc:
        tc = co
    b2 = b.reshape(1, co)
    kern = functools.partial(_conv_kernel, kh=kh, kw=kw, sh=sh, sw=sw,
                             oh=oh, ow=ow, act=act, alpha=alpha)
    return pl.pallas_call(
        kern,
        grid=(n, co // tc),
        in_specs=[
            pl.BlockSpec((1, h, wd, ci), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((kh, kw, ci, tc), lambda i, j: (0, 0, 0, j)),
            pl.BlockSpec((1, tc), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow, tc), lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, co), x.dtype),
        interpret=interpret,
    )(x, w, b2)
