"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels target TPU and are validated in interpret mode per the repo
policy). On a real TPU backend the same calls compile to Mosaic.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax

from .conv2d import conv2d_pallas
from .flash_attention import flash_attention_pallas
from .linear_scan import linear_scan_pallas
from .maxpool2d import maxpool2d_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "strides", "padding", "act", "alpha", "block_cout"))
def conv2d(x, w, b, *, strides: Tuple[int, int] = (1, 1),
           padding: str = "valid", act: Optional[str] = None,
           alpha: float = 0.1, block_cout: Optional[int] = None):
    return conv2d_pallas(x, w, b, strides=strides, padding=padding, act=act,
                         alpha=alpha, block_cout=block_cout,
                         interpret=_default_interpret())


@functools.partial(jax.jit, static_argnames=("size", "strides", "block_c"))
def maxpool2d(x, *, size: Tuple[int, int] = (2, 2),
              strides: Optional[Tuple[int, int]] = None,
              block_c: Optional[int] = None):
    return maxpool2d_pallas(x, size=size, strides=strides, block_c=block_c,
                            interpret=_default_interpret())


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "scale", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None,
                    scale: Optional[float] = None,
                    block_q: int = 128, block_k: int = 128):
    return flash_attention_pallas(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=block_q, block_k=block_k, interpret=_default_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def linear_scan(decay, k, v, r, s0, *, chunk: int = 128):
    return linear_scan_pallas(decay, k, v, r, s0, chunk=chunk,
                              interpret=_default_interpret())
