"""Pallas TPU kernel: flash attention (prefill) with causal + sliding
window masks and GQA.

The LM-side hot spot. Online-softmax tiling: the KV sequence is the
innermost (sequential) grid axis; running (m, l, acc) live in VMEM
scratch across KV steps, so the O(S^2) score matrix never exists in HBM.

NNCG principle mapping: masks are built from iota arithmetic and applied
with ``jnp.where`` — branch-free (P2); the (causal, window, GQA group)
structure is compile-time constant (P3); block shapes put the MXU dims on
(128, 128) tiles (P4).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  bq: int, bk: int, n_kv_blocks: int):
    sb = pl.program_id(3)
    tb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]  # (BQ, D)
    k = k_ref[0, 0]  # (BK, D)
    v = v_ref[0, 0]  # (BK, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qi = tb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kj = sb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= (qi - kj) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)  # kill fully-masked rows exactly
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = alpha * l_scr[...] + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = alpha * acc_scr[...] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(sb == n_kv_blocks - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked query rows -> 0
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True,
                           window: Optional[int] = None,
                           scale: Optional[float] = None,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q: (B, Hq, T, D); k, v: (B, Hkv, S, D); Hq % Hkv == 0."""
    b, hq, t, d = q.shape
    _, hkv, s, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    bq = min(block_q, t)
    bk = min(block_k, s)
    assert t % bq == 0 and s % bk == 0, "pad sequence to block multiples"
    scale = scale if scale is not None else d ** -0.5
    n_kv_blocks = s // bk
    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq, bk=bk, n_kv_blocks=n_kv_blocks)
    return pl.pallas_call(
        kern,
        grid=(b, hq, t // bq, n_kv_blocks),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bi, hi, tb, sb: (bi, hi, tb, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, tb, sb: (bi, hi // group, sb, 0)),
            pl.BlockSpec((1, 1, bk, d),
                         lambda bi, hi, tb, sb: (bi, hi // group, sb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d),
                               lambda bi, hi, tb, sb: (bi, hi, tb, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
