"""Pallas TPU kernel: chunked diagonal-decay linear-attention scan.

One kernel serves both SSM families in the pool:

  * **Mamba2**: per-head scalar decay ``a_t`` (broadcast over N),
    B_t -> ``k``, C_t -> ``r``, x_t -> ``v``.
  * **RWKV6**:  data-dependent per-channel decay ``w_t`` -> ``decay``,
    key/value/receptance map directly.

Recurrence (per head, state S in R^{N x M}):

    S_t = diag(decay_t) @ S_{t-1} + k_t^T v_t
    y_t = r_t @ S_t

The sequence is chunked on the innermost grid axis; the state is VMEM
scratch carried across sequential grid steps — the TPU version of the
paper's "keep the working set cache-resident across the unrolled loop"
(P1/P3: chunk size, head count and state width are compile-time
constants; no branches anywhere, P2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(decay_ref, k_ref, v_ref, r_ref, s0_ref, y_ref, sT_ref,
                 state_scr, *, chunk: int, n_chunks: int):
    cb = pl.program_id(1)

    @pl.when(cb == 0)
    def _init():
        state_scr[...] = s0_ref[0].astype(jnp.float32)

    def step(t, state):
        d = decay_ref[0, t].astype(jnp.float32)   # (H, N)
        k = k_ref[0, t].astype(jnp.float32)       # (H, N)
        v = v_ref[0, t].astype(jnp.float32)       # (H, M)
        r = r_ref[0, t].astype(jnp.float32)       # (H, N)
        state = d[:, :, None] * state + k[:, :, None] * v[:, None, :]
        y = (r[:, :, None] * state).sum(axis=1)   # (H, M)
        y_ref[0, t] = y.astype(y_ref.dtype)
        return state

    state = jax.lax.fori_loop(0, chunk, step, state_scr[...])
    state_scr[...] = state

    @pl.when(cb == n_chunks - 1)
    def _emit_state():
        sT_ref[0] = state.astype(sT_ref.dtype)


def linear_scan_pallas(decay: jax.Array, k: jax.Array, v: jax.Array,
                       r: jax.Array, s0: jax.Array, *,
                       chunk: int = 128, interpret: bool = True):
    """decay/k/r: (B, T, H, N); v: (B, T, H, M); s0: (B, H, N, M).

    Returns (y: (B, T, H, M), final_state: (B, H, N, M)).
    """
    b, t, h, n = k.shape
    m = v.shape[-1]
    chunk = min(chunk, t)
    assert t % chunk == 0, "pad T to a chunk multiple"
    n_chunks = t // chunk
    kern = functools.partial(_scan_kernel, chunk=chunk, n_chunks=n_chunks)
    grid = (b, n_chunks)
    seq_spec = lambda shape_last2: pl.BlockSpec(
        (1, chunk) + shape_last2, lambda bi, ci: (bi, ci, 0, 0))
    state_spec = pl.BlockSpec((1, h, n, m), lambda bi, ci: (bi, 0, 0, 0))
    y, s_final = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[seq_spec((h, n)), seq_spec((h, n)), seq_spec((h, m)),
                  seq_spec((h, n)), state_spec],
        out_specs=[seq_spec((h, m)), state_spec],
        out_shape=[jax.ShapeDtypeStruct((b, t, h, m), v.dtype),
                   jax.ShapeDtypeStruct((b, h, n, m), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((h, n, m), jnp.float32)],
        interpret=interpret,
    )(decay, k, v, r, s0)
    return y, s_final
