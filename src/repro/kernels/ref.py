"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` layer)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def conv2d_ref(x, w, b, *, strides=(1, 1), padding="valid",
               act: Optional[str] = None, alpha: float = 0.1):
    pad = padding.upper()
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=strides, padding=pad,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    y = y + b
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act == "leaky_relu":
        y = jnp.where(y > 0, y, alpha * y)
    return y


def maxpool2d_ref(x, *, size=(2, 2), strides=None):
    kh, kw = size
    sh, sw = strides or size
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, kh, kw, 1), (1, sh, sw, 1), "VALID")


def attention_ref(q, k, v, *, causal=True, window: Optional[int] = None,
                  scale: Optional[float] = None):
    """Dense masked softmax attention; q (B,Hq,T,D), k/v (B,Hkv,S,D)."""
    b, hq, t, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum("bhtd,bhsd->bhts", q, k).astype(jnp.float32) * scale
    qi = jnp.arange(t)[:, None]
    kj = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= (qi - kj) < window
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(mask, p, 0.0)
    return jnp.einsum("bhts,bhsd->bhtd", p, v.astype(p.dtype)).astype(q.dtype)


def linear_scan_ref(decay, k, v, r, s0):
    """Oracle for the chunked scan: lax.scan over time.

    decay/k/r: (B,T,H,N); v: (B,T,H,M); s0: (B,H,N,M).
    """
    def step(state, inp):
        d, kk, vv, rr = inp
        state = d[..., None] * state + kk[..., None] * vv[..., None, :]
        y = (rr[..., None] * state).sum(axis=-2)
        return state, y

    def one_batch(s0_b, d_b, k_b, v_b, r_b):
        sT, y = jax.lax.scan(step, s0_b.astype(jnp.float32),
                             (d_b.astype(jnp.float32), k_b.astype(jnp.float32),
                              v_b.astype(jnp.float32), r_b.astype(jnp.float32)))
        return y.astype(v.dtype), sT

    y, sT = jax.vmap(one_batch)(s0, decay, k, v, r)
    return y, sT
