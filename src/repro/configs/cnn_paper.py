"""The paper's three evaluation CNNs (Tables I, II, III), built verbatim.

Weights are He-initialized from a fixed seed (the paper's latency results
do not depend on weight values, only structure); the ball classifier can
additionally be *trained* on the synthetic ball dataset via
:func:`trained_ball_classifier` (used by the quantization tests and
``examples/quickstart.py``).
"""
from __future__ import annotations

import numpy as np

from repro.core.graph import (
    Add,
    AvgPool,
    BatchNorm,
    CNNGraph,
    Concat,
    Conv2D,
    DepthwiseConv2D,
    Dropout,
    GlobalAvgPool,
    Input,
    LeakyReLU,
    MaxPool,
    ReLU,
    Softmax,
)


def _conv(rng, kh, kw, ci, co, **kw_args) -> Conv2D:
    fan_in = kh * kw * ci
    w = rng.normal(0.0, np.sqrt(2.0 / fan_in), size=(kh, kw, ci, co))
    b = rng.normal(0.0, 0.01, size=(co,))
    return Conv2D(weights=w.astype(np.float32), bias=b.astype(np.float32),
                  **kw_args)


def _bn(rng, c) -> BatchNorm:
    return BatchNorm(
        mean=rng.normal(0, 0.5, c), var=rng.uniform(0.5, 1.5, c),
        gamma=rng.uniform(0.8, 1.2, c), beta=rng.normal(0, 0.1, c))


def ball_classifier(seed: int = 0) -> CNNGraph:
    """Paper Table I — 16x16x1 ball/no-ball classifier."""
    r = np.random.default_rng(seed)
    return CNNGraph([
        Input(shape=(16, 16, 1)),
        _conv(r, 5, 5, 1, 8, strides=(2, 2), padding="same"),
        ReLU(),
        MaxPool(size=(2, 2), strides=(2, 2)),
        _conv(r, 3, 3, 8, 12, padding="valid"),
        ReLU(),
        _conv(r, 2, 2, 12, 2, padding="valid"),
        Softmax(),
    ])


def pedestrian_classifier(seed: int = 0) -> CNNGraph:
    """Paper Table II — 36x18 (Daimler) pedestrian classifier."""
    r = np.random.default_rng(seed)
    return CNNGraph([
        Input(shape=(36, 18, 1)),
        _conv(r, 3, 3, 1, 12, padding="same"),
        ReLU(),
        MaxPool(size=(2, 2)),
        _conv(r, 3, 3, 12, 32, padding="same"),
        LeakyReLU(alpha=0.1),
        MaxPool(size=(2, 2)),
        _conv(r, 3, 3, 32, 64, padding="same"),
        LeakyReLU(alpha=0.1),
        MaxPool(size=(2, 2)),
        Dropout(rate=0.3),
        _conv(r, 4, 2, 64, 2, padding="valid"),
        Softmax(),
    ])


def robot_detector(seed: int = 0) -> CNNGraph:
    """Paper Table III — 60x80x3 YOLO-style robot detector backbone."""
    r = np.random.default_rng(seed)
    layers = [Input(shape=(60, 80, 3))]

    def block(ci, co, pool):
        layers.append(_conv(r, 3, 3, ci, co, padding="same"))
        layers.append(_bn(r, co))
        layers.append(LeakyReLU(alpha=0.1))
        if pool:
            layers.append(MaxPool(size=(2, 2)))

    block(3, 8, pool=True)
    block(8, 12, pool=False)
    block(12, 8, pool=True)
    block(8, 16, pool=False)
    block(16, 20, pool=False)
    return CNNGraph(layers)


def _dwconv(rng, kh, kw, c, mult, **kw_args) -> DepthwiseConv2D:
    fan_in = kh * kw
    w = rng.normal(0.0, np.sqrt(2.0 / fan_in), size=(kh, kw, c, mult))
    b = rng.normal(0.0, 0.01, size=(c * mult,))
    return DepthwiseConv2D(weights=w.astype(np.float32),
                           bias=b.astype(np.float32), **kw_args)


def residual_cnn(seed: int = 0) -> CNNGraph:
    """A small ResNet/MobileNet-style DAG (not from the paper): a
    depthwise-separable block with a residual Add, a two-branch Concat,
    and a global-average-pool head.  Exercises every non-sequential
    construct the DAG IR supports, end-to-end through codegen."""
    r = np.random.default_rng(seed)
    return CNNGraph([
        Input(shape=(16, 16, 3), name="in"),
        _conv(r, 3, 3, 3, 8, padding="same", name="stem"),
        ReLU(name="stem_relu"),
        # depthwise-separable residual block on the stem features
        _dwconv(r, 3, 3, 8, 1, padding="same", name="dw",
                inputs=["stem_relu"]),
        ReLU(name="dw_relu"),
        _conv(r, 1, 1, 8, 8, padding="valid", name="pw", inputs=["dw_relu"]),
        Add(name="res_add", inputs=["pw", "stem_relu"]),
        ReLU(name="res_relu"),
        # two-branch feature mix, channel-concatenated
        _conv(r, 1, 1, 8, 4, padding="valid", name="branch_1x1",
              inputs=["res_relu"]),
        _conv(r, 3, 3, 8, 4, padding="same", name="branch_3x3",
              inputs=["res_relu"]),
        Concat(name="mix", inputs=["branch_1x1", "branch_3x3"]),
        AvgPool(size=(2, 2), name="pool"),
        GlobalAvgPool(name="gap"),
        _conv(r, 1, 1, 8, 4, padding="valid", name="head"),
        Softmax(name="probs"),
    ])


def trained_ball_classifier(steps: int = 150, *, seed: int = 0,
                            learning_rate: float = 3e-3, batch: int = 64,
                            eval_n: int = 2000, log=None):
    """The Table-I ball net *trained* on the synthetic ball dataset.

    The calibration-quality work (percentile/MSE range selection) is
    gated on this trained net, not on random weights — random-weight
    activations are unstructured and hide calibration differences.
    Deterministic in ``(steps, seed)``.  Returns ``(graph, accuracy)``
    with the trained weights inserted and the held-out accuracy on a
    fresh synthetic split."""
    import jax
    import jax.numpy as jnp

    from repro.core import jax_exec
    from repro.data.pipeline import ball_image_batch
    from repro.optim import AdamW

    graph = ball_classifier(seed=seed)
    params = jax_exec.extract_params(graph)
    opt = AdamW(learning_rate=learning_rate, weight_decay=0.0)
    opt_state = opt.init(params)

    def loss_fn(p, x, y):
        logits = jax_exec.forward_with_params(graph, p, x)[:, 0, 0, :]
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, y[:, None], axis=1).mean()

    @jax.jit
    def step(p, s, x, y):
        loss, g = jax.value_and_grad(loss_fn)(p, x, y)
        up, s = opt.update(g, s, p)
        p = jax.tree.map(lambda a, u: a + u, p, up)
        return p, s, loss

    for i in range(steps):
        xs, ys = ball_image_batch(batch, seed=0, step=i)
        params, opt_state, loss = step(params, opt_state,
                                       jnp.asarray(xs), jnp.asarray(ys))
        if log is not None and (i + 1) % 50 == 0:
            log(f"  step {i + 1}: loss {float(loss):.4f}")

    xs, ys = ball_image_batch(eval_n, seed=99, step=0)
    pred = jnp.argmax(jax_exec.forward_with_params(
        graph, params, jnp.asarray(xs))[:, 0, 0, :], -1)
    acc = float((pred == jnp.asarray(ys)).mean())
    return jax_exec.insert_params(graph, params), acc


PAPER_CNNS = {
    "ball": ball_classifier,
    "pedestrian": pedestrian_classifier,
    "robot": robot_detector,
}

# non-paper workloads the engine also serves; kept out of PAPER_CNNS so
# paper-table parametrizations stay exactly the paper's three nets
EXTRA_CNNS = {
    "residual": residual_cnn,
}
