"""The 10 assigned LM-family architectures (exact published configs).

Pattern legend: A=global attention, L=sliding-window, M=Mamba2, R=RWKV6,
S=shared-weight attention block (Zamba2). See DESIGN.md §6 for
applicability and shape-skip notes.
"""
from __future__ import annotations

from typing import Dict

from repro.models.config import ModelConfig

def _pat(s: str) -> str:
    """gemma3 patterns are written with G for readability; G == A."""
    return s.replace("G", "A")


ARCHS: Dict[str, ModelConfig] = {}


def _register(cfg: ModelConfig) -> ModelConfig:
    ARCHS[cfg.name] = cfg
    return cfg


zamba2_2p7b = _register(ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    grad_accum=4,
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab_size=32000, pattern="MMMMMS", ssm_state=64, ssm_head_dim=64,
    act="gelu",
))  # Mamba2 backbone + one shared attention block applied every 6 layers

hubert_xlarge = _register(ModelConfig(
    name="hubert-xlarge", family="audio",
    grad_accum=2,
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_ff=5120,
    vocab_size=504, pattern="A", causal=False, embed_inputs=False,
    act="gelu", mlp_gated=False,
))  # encoder-only; frame frontend is a stub (precomputed embeddings)

gemma3_4b = _register(ModelConfig(
    name="gemma3-4b", family="dense",
    grad_accum=4,
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, d_ff=10240,
    vocab_size=262144, head_dim=256, pattern=_pat("LLLLLG"),
    prologue="LLLL", window=1024, act="gelu", tie_embeddings=True,
    rope_theta=1e6,
))  # 4L prologue + 5x(5L+1G) = 34L, 29:5 local:global (published 5:1)

h2o_danube3_4b = _register(ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    grad_accum=4,
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, d_ff=10240,
    vocab_size=32000, head_dim=120, pattern="L", window=4096,
))  # llama+mistral mix with sliding-window attention

gemma3_27b = _register(ModelConfig(
    name="gemma3-27b", family="dense",
    grad_accum=8,
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, d_ff=21504,
    vocab_size=262144, head_dim=128, pattern=_pat("LLLLLG"),
    prologue="LL", window=1024, act="gelu", tie_embeddings=True,
    rope_theta=1e6,
))  # 2L prologue + 10x(5L+1G) = 62L, 52:10 local:global (published 5:1)

qwen15_110b = _register(ModelConfig(
    name="qwen1.5-110b", family="dense",
    grad_accum=8,
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=49152,
    vocab_size=152064, head_dim=128, pattern="A", qkv_bias=True,
))

deepseek_moe_16b = _register(ModelConfig(
    name="deepseek-moe-16b", family="moe",
    grad_accum=4,
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=102400, head_dim=128, pattern="A",
    n_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408,
))  # fine-grained: 64 routed (top-6) + 2 shared experts of 1408

grok1_314b = _register(ModelConfig(
    name="grok-1-314b", family="moe",
    grad_accum=8,
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=32768,
    vocab_size=131072, head_dim=128, pattern="A",
    n_experts=8, n_shared_experts=0, top_k=2, moe_d_ff=32768,
))

rwkv6_7b = _register(ModelConfig(
    name="rwkv6-7b", family="ssm",
    grad_accum=4,
    n_layers=32, d_model=4096, n_heads=0, n_kv_heads=0, d_ff=14336,
    vocab_size=65536, pattern="R", ssm_head_dim=64,
))  # Finch: attention-free, data-dependent decay

qwen2_vl_72b = _register(ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    grad_accum=8,
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab_size=152064, head_dim=128, pattern="A", qkv_bias=True,
    mrope_sections=(16, 24, 24),
))  # M-RoPE backbone; vision frontend is a stub (precomputed patch embeds)


# ------------------------------------------------------------- shapes -------

SHAPES = {
    "train_4k":    dict(kind="train",   seq_len=4_096,   global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32_768,  global_batch=32),
    "decode_32k":  dict(kind="decode",  seq_len=32_768,  global_batch=128),
    "long_500k":   dict(kind="decode",  seq_len=524_288, global_batch=1),
}

# long_500k needs sub-quadratic attention over the context; pure
# full-attention archs skip it (DESIGN.md §6). Encoder-only archs have no
# autoregressive step at all.
_LONG_OK = {"zamba2-2.7b", "rwkv6-7b", "h2o-danube-3-4b",
            "gemma3-4b", "gemma3-27b"}


def cell_supported(arch: str, shape: str) -> bool:
    cfg = ARCHS[arch]
    kind = SHAPES[shape]["kind"]
    if cfg.is_encoder and kind == "decode":
        return False
    if shape == "long_500k" and arch not in _LONG_OK:
        return False
    return True


def all_cells():
    return [(a, s) for a in ARCHS for s in SHAPES if cell_supported(a, s)]
