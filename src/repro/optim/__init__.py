from .adamw import AdamW, AdamWState, global_norm, warmup_cosine
