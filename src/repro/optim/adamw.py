"""AdamW with global-norm clipping and warmup+cosine schedule.

Self-contained (no optax). Moments are f32 regardless of param dtype —
the standard mixed-precision recipe; with FSDP-sharded params the
moments inherit the same sharding (they are elementwise pytrees).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1) -> Callable:
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        prog = jnp.clip((step - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup_steps, warm, cos)
    return schedule


class AdamWState(NamedTuple):
    step: jax.Array
    mu: any
    nu: any


@dataclass(frozen=True)
class AdamW:
    learning_rate: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.clip_norm is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gn + 1e-9))
            grads = jax.tree.map(
                lambda g: (g.astype(jnp.float32) * scale), grads)
        else:
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: self.b2 * v + (1 - self.b2) * jnp.square(g),
            state.nu, grads)
        t = step.astype(jnp.float32)
        mu_hat_c = 1.0 / (1 - self.b1 ** t)
        nu_hat_c = 1.0 / (1 - self.b2 ** t)
        lr = (self.learning_rate(step)
              if callable(self.learning_rate) else self.learning_rate)
        updates = jax.tree.map(
            lambda m, v, p: -lr * (m * mu_hat_c
                                   / (jnp.sqrt(v * nu_hat_c) + self.eps)
                                   + self.weight_decay
                                   * p.astype(jnp.float32)),
            mu, nu, params)
        return updates, AdamWState(step=step, mu=mu, nu=nu)
