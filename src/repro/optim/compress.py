"""Gradient compression for the inter-pod all-reduce (DESIGN.md §9).

At 2+ pods the 'pod' axis rides the slower inter-pod links; the
gradient all-reduce over it is pure DP traffic. ``int8_allreduce``
quantizes each leaf to int8 with a per-leaf f32 scale (max-abs),
all-reduces the int8 payload, and dequantizes — 4x fewer wire bytes
than f32 — with **error feedback** (the quantization residual is carried
and added to the next step's gradient) so the compression bias does not
accumulate.

Usage (inside a shard_map over the 'pod' axis, or standalone on any
pytree for the unit tests):

    g_hat, new_residual = compress_allreduce(grads, residual,
                                             axis_name="pod")
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization; returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_allreduce(grads, residual=None, *,
                       axis_name: Optional[str] = None):
    """int8 all-reduce with error feedback over ``axis_name``.

    grads/residual: congruent pytrees. Returns (mean_grads, residual').
    With axis_name=None this is a pure quantize/dequantize round-trip
    (used by the unit tests and single-pod runs).
    """
    if residual is None:
        residual = jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, r):
        v = g.astype(jnp.float32) + r
        q, s = quantize_int8(v)
        new_r = v - dequantize_int8(q, s)          # error feedback
        if axis_name is not None:
            # int8 payloads sum without overflow in i32; scales average
            qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
            ssum = jax.lax.psum(s, axis_name)
            n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
            # each pod contributed q_i * s_i ~= q_i * s_mean (scales are
            # near-identical across pods for IID gradient shards)
            out = qsum.astype(jnp.float32) * (ssum / n) / n
        else:
            out = dequantize_int8(q, s)
        return out.astype(g.dtype), new_r

    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    g_out = jax.tree_util.tree_unflatten(tree, [o[0] for o in outs])
    r_out = jax.tree_util.tree_unflatten(tree, [o[1] for o in outs])
    return g_out, r_out


def wire_bytes_saved(grads) -> int:
    """f32 -> int8: the inter-pod all-reduce payload shrinks 4x."""
    total = sum(l.size for l in jax.tree.leaves(grads))
    return total * 4 - total
