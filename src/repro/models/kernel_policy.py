"""The autotunable Pallas/jax kernel-variant axes of the LM stack.

The C backend's variant space is per-layer unroll levels and int8 ISA
tiles; the LM stack's variant space is which attention/scan kernel the
prefill path runs and at what block sizes.  :class:`KernelPolicy` is
that selection as a value: the model code reads it off the ``Par``
context (``par.kernels``), the autotuner times candidate policies like
it times C code versions, and the winner is serialized into the same
on-disk tuning cache (``KernelPolicy(**record)`` round-trips).

Variant axes:

* ``attention`` — the prefill/train attention kernel:
  ``"flash_jax"`` (pure-jnp online-softmax flash with a custom VJP —
  the historical default), ``"flash_pallas"`` (the Pallas TPU flash
  kernel from :mod:`repro.kernels.flash_attention`; interpret mode on
  CPU), or ``"reference"`` (dense masked softmax,
  :func:`repro.kernels.ref.attention_ref`).
* ``scan`` — the RWKV6 diagonal-decay recurrence: ``"chunked"``
  (lax.scan of rematerialized chunks) or ``"linear_scan"`` (the Pallas
  kernel from :mod:`repro.kernels.linear_scan`).
* ``block_q`` / ``block_k`` — flash tile sizes; clipped per call site
  to the largest divisor of the actual sequence length
  (:func:`fit_block`), so one policy serves every prompt shape.

Decode (T == 1) always runs the gather-based
:func:`repro.models.layers.decode_attention_jax` path — a one-row
flash tile has nothing to tile.
"""
from __future__ import annotations

from typing import NamedTuple

ATTENTION_VARIANTS = ("flash_jax", "flash_pallas", "reference")
SCAN_VARIANTS = ("chunked", "linear_scan")


class KernelPolicy(NamedTuple):
    attention: str = "flash_jax"
    scan: str = "chunked"
    block_q: int = 512
    block_k: int = 512

    def validate(self) -> "KernelPolicy":
        if self.attention not in ATTENTION_VARIANTS:
            raise ValueError(
                f"attention variant {self.attention!r}; expected one of "
                f"{ATTENTION_VARIANTS}")
        if self.scan not in SCAN_VARIANTS:
            raise ValueError(
                f"scan variant {self.scan!r}; expected one of "
                f"{SCAN_VARIANTS}")
        if self.block_q < 1 or self.block_k < 1:
            raise ValueError(
                f"flash blocks ({self.block_q}, {self.block_k}) must be >= 1")
        return self


DEFAULT_KERNELS = KernelPolicy()


def fit_block(n: int, block: int) -> int:
    """Largest divisor of ``n`` that is <= ``block`` (>= 1).

    The Pallas kernels assert the sequence length divides the tile; a
    policy tuned at one shape must still run at every other, so block
    sizes are a *ceiling*, fitted per call site."""
    b = max(1, min(int(block), int(n)))
    while n % b:
        b -= 1
    return b
