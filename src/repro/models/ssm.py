"""SSM blocks: Mamba2 (SSD chunked) and RWKV6 (Finch) time/channel mix.

Both are built on the same diagonal-decay recurrence the Pallas
``linear_scan`` kernel implements:

    S_t = diag(decay_t) S_{t-1} + k_t^T v_t ;  y_t = r_t S_t

Mamba2 trains with the **chunked SSD algorithm** (quadratic within a
chunk via MXU matmuls, sequential only across chunks) — the TPU-native
reading of the paper's P1 trade-off: the chunk is the unroll unit that
keeps the working set in VMEM/registers while bounding code (HLO) size.
RWKV6's per-channel data-dependent decay uses a lax.scan on the XLA path
(kernels/linear_scan.py is the TPU hot-path equivalent).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .kernel_policy import fit_block
from .layers import act_fn, group_norm_heads, linear, rms_norm


# ============================================================== Mamba2 ======

def ssd_chunked(a: jax.Array, u: jax.Array, Bm: jax.Array, Cm: jax.Array,
                s0: Optional[jax.Array] = None, chunk: int = 128):
    """Chunked scan for S_t = a_t S_{t-1} + B_t u_t ; y_t = C_t S_t.

    a (B,T,H) in (0,1];  u (B,T,H,P);  Bm/Cm (B,T,N) (shared over heads).
    Returns y (B,T,H,P), S_final (B,H,N,P).
    """
    B_, T, H = a.shape
    P, N = u.shape[-1], Bm.shape[-1]
    c = min(chunk, T)
    assert T % c == 0
    nc = T // c
    a_ = a.reshape(B_, nc, c, H)
    u_ = u.reshape(B_, nc, c, H, P)
    Bc = Bm.reshape(B_, nc, c, N)
    Cc = Cm.reshape(B_, nc, c, N)

    la = jnp.log(jnp.clip(a_.astype(jnp.float32), 1e-20))
    cum = jnp.cumsum(la, axis=2)                       # (B,nc,c,H) inclusive

    # intra-chunk: y_t += sum_{j<=t} (C_t.B_j) exp(cum_t - cum_j) u_j
    scores = jnp.einsum("bgin,bgjn->bgij", Cc, Bc,
                        preferred_element_type=jnp.float32)
    Lm = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,nc,i,j,H)
    tri = jnp.tril(jnp.ones((c, c), bool))
    Lm = jnp.where(tri[None, None, :, :, None], Lm, 0.0)
    y_intra = jnp.einsum("bgij,bgijh,bgjhp->bgihp",
                         scores, Lm, u_.astype(jnp.float32))

    # inter-chunk: chunk summary states, then a short scan across chunks
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)    # (B,nc,c,H)
    cstate = jnp.einsum("bgjn,bgjh,bgjhp->bghnp", Bc.astype(jnp.float32),
                        decay_to_end, u_.astype(jnp.float32))
    cdecay = jnp.exp(cum[:, :, -1, :])                 # (B,nc,H)

    s_init = (jnp.zeros((B_, H, N, P), jnp.float32) if s0 is None
              else s0.astype(jnp.float32))

    def step(S, inp):
        cd, cs = inp                                   # (B,H), (B,H,N,P)
        S_new = cd[:, :, None, None] * S + cs
        return S_new, S                                # emit state *before*

    (S_final, S_prevs) = jax.lax.scan(
        step, s_init, (jnp.moveaxis(cdecay, 1, 0), jnp.moveaxis(cstate, 1, 0)))
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)              # (B,nc,H,N,P)

    y_inter = jnp.einsum("bgin,bgih,bghnp->bgihp",
                         Cc.astype(jnp.float32), jnp.exp(cum), S_prevs)
    y = (y_intra + y_inter).reshape(B_, T, H, P)
    return y.astype(u.dtype), S_final


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                  state: Optional[jax.Array] = None):
    """Depthwise causal conv; x (B,T,C), w (K,C). Returns (y, new_state)
    where state caches the last K-1 inputs for decode."""
    K = w.shape[0]
    if state is None:
        hist = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        hist = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(hist[:, i:i + x.shape[1]] * w[i][None, None] for i in range(K))
    new_state = hist[:, hist.shape[1] - (K - 1):]
    return y + b[None, None], new_state


class MambaState(NamedTuple):
    ssm: jax.Array    # (B, H, N, P) f32
    conv: jax.Array   # (B, K-1, d_inner)


def mamba2_mix(x: jax.Array, p: dict, *, ssm_state: int, head_dim: int,
               chunk: int = 128, state: Optional[MambaState] = None,
               ) -> Tuple[jax.Array, MambaState]:
    """Mamba2 mixer. x (B,T,D). Single-step decode when T == 1 and state
    is given (pure recurrence, no chunking)."""
    B_, T, D = x.shape
    d_inner = p["w_in"].shape[1] // 2
    H = d_inner // head_dim
    N = ssm_state

    xz = linear(x, p["w_in"])
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_state = None if state is None else state.conv
    xi, new_conv = causal_conv1d(xi, p["conv_w"], p["conv_b"], conv_state)
    xi = jax.nn.silu(xi)

    Bm = linear(xi, p["w_B"])                      # (B,T,N)
    Cm = linear(xi, p["w_C"])                      # (B,T,N)
    dt = jax.nn.softplus(linear(xi, p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"])           # (B,T,H)
    a = jnp.exp(-dt * jnp.exp(p["A_log"]))         # (B,T,H) in (0,1)
    xh = xi.reshape(B_, T, H, head_dim)
    u = xh.astype(jnp.float32) * dt[..., None]     # discretized input

    if T == 1 and state is not None:
        S = state.ssm
        S = a[:, 0, :, None, None] * S + jnp.einsum(
            "bn,bhp->bhnp", Bm[:, 0].astype(jnp.float32), u[:, 0])
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), S)
        y = y[:, None]
        S_final = S
    else:
        s0 = None if state is None else state.ssm
        y, S_final = ssd_chunked(a, u, Bm, Cm, s0=s0, chunk=chunk)

    y = y + xh.astype(jnp.float32) * p["D_skip"][None, None, :, None]
    y = y.reshape(B_, T, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = linear(y, p["w_out"])
    return out, MambaState(ssm=S_final, conv=new_conv)


def init_mamba2(key, D: int, *, ssm_state: int, head_dim: int,
                conv_kernel: int = 4, dtype=jnp.bfloat16) -> dict:
    d_inner = 2 * D
    H = d_inner // head_dim
    ks = jax.random.split(key, 6)
    sc = lambda k, sh, fan: (jax.random.normal(k, sh, jnp.float32)
                             * fan ** -0.5).astype(dtype)
    return {
        "w_in": sc(ks[0], (D, 2 * d_inner), D),
        "conv_w": sc(ks[1], (conv_kernel, d_inner), conv_kernel).astype(jnp.float32),
        "conv_b": jnp.zeros((d_inner,), jnp.float32),
        "w_B": sc(ks[2], (d_inner, ssm_state), d_inner),
        "w_C": sc(ks[3], (d_inner, ssm_state), d_inner),
        "w_dt": sc(ks[4], (d_inner, H), d_inner),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[5], (H,), jnp.float32,
                                       jnp.log(1e-3), jnp.log(1e-1))))),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
        "w_out": sc(ks[0], (d_inner, D), d_inner),
    }


# ============================================================== RWKV6 =======

class RWKVState(NamedTuple):
    wkv: jax.Array      # (B, H, N, N) f32
    prev_tm: jax.Array  # (B, D) last token seen by time-mix
    prev_cm: jax.Array  # (B, D) last token seen by channel-mix


def _token_shift(x, prev):
    """Shift by one token; ``prev`` is the last token of the previous
    segment (zeros at sequence start)."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def rwkv6_time_mix(x, p, *, head_dim: int,
                   state: Optional[RWKVState] = None,
                   constraint=None, chunk: int = 64,
                   scan: str = "chunked"):
    """RWKV6 'Finch' time mix with data-dependent per-channel decay.

    The recurrence runs as a scan-of-chunks with the chunk body
    rematerialized (jax.checkpoint): the differentiated outer scan stores
    one (B,H,N,N) state per *chunk* instead of per step — O(T/chunk)
    instead of O(T) residuals. ``constraint`` shards the head dim.

    ``scan="linear_scan"`` routes the recurrence through the Pallas
    kernel instead (prefill/train only; single-step decode keeps the
    trivial scan). The kernel reads the state *post*-update (y_t = r·S_t)
    while RWKV reads it pre-update plus the u-bonus, so the kernel gets
    inputs shifted by one step — its state after step t is then S_{t-1} —
    and the separable bonus r·(u ⊙ k_t v_tᵀ) = (Σ_n r u k)·v_t plus the
    true final state are one elementwise step each outside the kernel."""
    B_, T, D = x.shape
    N = head_dim
    H = D // N
    prev = (jnp.zeros((B_, D), x.dtype) if state is None
            else state.prev_tm.astype(x.dtype))
    xx = _token_shift(x, prev)

    def lerp(mu):
        return x + (xx - x) * mu.astype(x.dtype)

    xr, xk, xv, xw, xg = (lerp(p[f"mu_{c}"]) for c in "rkvwg")
    r = linear(xr, p["w_r"]).reshape(B_, T, H, N)
    k = linear(xk, p["w_k"]).reshape(B_, T, H, N)
    v = linear(xv, p["w_v"]).reshape(B_, T, H, N)
    g = jax.nn.silu(linear(xg, p["w_g"]))
    # data-dependent decay (low-rank): w = exp(-exp(w0 + tanh(xw A) B))
    dd = jnp.einsum("btr,rd->btd", jnp.tanh(linear(xw, p["w_dec_A"])),
                    p["w_dec_B"].astype(x.dtype))
    logw = p["w_dec0"].astype(jnp.float32) + dd.astype(jnp.float32)
    decay = jnp.exp(-jnp.exp(logw)).reshape(B_, T, H, N)   # (0,1)
    u = p["u_bonus"].reshape(H, N).astype(jnp.float32)

    if constraint is not None:  # shard heads over 'model'
        r, k, v = constraint(r), constraint(k), constraint(v)
        decay = constraint(decay)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    rf = r.astype(jnp.float32)
    s0 = (jnp.zeros((B_, H, N, N), jnp.float32) if state is None
          else state.wkv)

    if scan == "linear_scan" and T > 1:
        from ..kernels.ops import linear_scan
        one = jnp.ones((B_, 1, H, N), jnp.float32)
        d_sh = jnp.concatenate([one, decay[:, :-1]], axis=1)
        k_sh = jnp.concatenate([0.0 * one, kf[:, :-1]], axis=1)
        v_sh = jnp.concatenate([0.0 * one, vf[:, :-1]], axis=1)
        y, S_prev = linear_scan(d_sh, k_sh, v_sh, rf, s0,
                                chunk=fit_block(T, chunk))
        y = y + jnp.einsum("bthn,hn,bthn->bth", rf, u, kf)[..., None] * vf
        S_final = (decay[:, -1][..., None] * S_prev
                   + kf[:, -1][..., None] * vf[:, -1][..., None, :])
        y = group_norm_heads(y, p["ln_x"].reshape(H, N)[None, None])
        y = (y.reshape(B_, T, D).astype(x.dtype)) * g
        out = linear(y, p["w_o"])
        return out, S_final, x[:, -1]

    def step(S, inp):
        rt, kt, vt, dt = inp  # (B,H,N) x3, (B,H,N)
        kv = kt[..., None] * vt[..., None, :]              # (B,H,N,N)
        y = jnp.einsum("bhn,bhnm->bhm", rt, S + u[None, :, :, None] * kv)
        S = dt[..., None] * S + kv
        return S, y

    c = chunk
    while T % c:
        c //= 2
    nc = T // c

    def chunk_body(S, inp):
        return jax.lax.scan(step, S, inp)

    if nc > 1:
        chunk_body = jax.checkpoint(chunk_body)

    def chunked(arr):  # (B,T,H,N) -> (nc, c, B, H, N)
        return jnp.moveaxis(arr, 1, 0).reshape(nc, c, B_, H, N)

    S_final, y = jax.lax.scan(
        chunk_body, s0, (chunked(rf), chunked(kf), chunked(vf),
                         chunked(decay)))
    y = jnp.moveaxis(y.reshape(T, B_, H, N), 0, 1)         # (B,T,H,N)
    y = group_norm_heads(y, p["ln_x"].reshape(H, N)[None, None])
    y = (y.reshape(B_, T, D).astype(x.dtype)) * g
    out = linear(y, p["w_o"])
    new_prev = x[:, -1]
    return out, S_final, new_prev


def rwkv6_channel_mix(x, p, state_prev=None):
    B_, T, D = x.shape
    prev = (jnp.zeros((B_, D), x.dtype) if state_prev is None
            else state_prev.astype(x.dtype))
    xx = _token_shift(x, prev)
    xk = x + (xx - x) * p["mu_ck"].astype(x.dtype)
    xr = x + (xx - x) * p["mu_cr"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(linear(xk, p["w_ck"])))
    kv = linear(k, p["w_cv"])
    out = jax.nn.sigmoid(linear(xr, p["w_cr"])) * kv
    return out, x[:, -1]


def init_rwkv6(key, D: int, d_ff: int, *, head_dim: int, dec_rank: int = 64,
               dtype=jnp.bfloat16) -> dict:
    N = head_dim
    H = D // N
    ks = jax.random.split(key, 12)
    sc = lambda k, sh, fan: (jax.random.normal(k, sh, jnp.float32)
                             * fan ** -0.5).astype(dtype)
    p = {f"mu_{c}": jnp.full((D,), 0.5, jnp.float32) for c in "rkvwg"}
    p.update({
        "w_r": sc(ks[0], (D, D), D), "w_k": sc(ks[1], (D, D), D),
        "w_v": sc(ks[2], (D, D), D), "w_g": sc(ks[3], (D, D), D),
        "w_o": sc(ks[4], (D, D), D),
        "w_dec_A": sc(ks[5], (D, dec_rank), D),
        "w_dec_B": sc(ks[6], (dec_rank, D), dec_rank),
        "w_dec0": jnp.full((D,), -1.0, jnp.float32),
        "u_bonus": jnp.zeros((D,), jnp.float32),
        "ln_x": jnp.ones((D,), jnp.float32),
        "mu_ck": jnp.full((D,), 0.5, jnp.float32),
        "mu_cr": jnp.full((D,), 0.5, jnp.float32),
        "w_ck": sc(ks[7], (D, d_ff), D),
        "w_cv": sc(ks[8], (d_ff, D), d_ff),
        "w_cr": sc(ks[9], (D, D), D),
    })
    return p
