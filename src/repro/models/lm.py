"""LM-level API: forward, loss, train_step / prefill / decode factories.

All step functions are **branch-free** (paper P2) and close over the
config (P3: every structural decision is a trace-time constant), so a
``.lower().compile()`` of any step is a fully specialized program — the
TPU analogue of NNCG's single self-contained C function.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .stack import DEFAULT_PAR, Par, apply_stack, init_cache, init_params
from .layers import rms_norm


def embed_tokens(params, cfg: ModelConfig, tokens_or_embeds, par: Par):
    if jnp.issubdtype(tokens_or_embeds.dtype, jnp.integer):
        emb = params["embed"]
        x = jnp.take(emb, tokens_or_embeds, axis=0)
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)  # gemma-style scale
    else:  # frontend stub (audio frames / vision patches) or VLM embeds
        x = tokens_or_embeds.astype(jnp.dtype(cfg.dtype))
    return par.constraint(x, "activations")


def unembed(params, cfg: ModelConfig, x, par: Par):
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("btd,dv->btv", x, head.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return par.constraint(logits, "logits")


def forward(params, cfg: ModelConfig, batch: Dict[str, Any],
            par: Par = DEFAULT_PAR, caches=None, pos=None):
    """batch: {'tokens' (B,T) int | 'embeds' (B,T,D), optional 'positions'
    (B,T), optional 'positions3' (3,B,T)}."""
    inp = batch["embeds"] if "embeds" in batch else batch["tokens"]
    x = embed_tokens(params, cfg, inp, par)
    B, T = x.shape[:2]
    positions = batch.get("positions")
    if positions is None:
        base = jnp.arange(T, dtype=jnp.int32)[None]
        positions = base + (0 if pos is None else pos)
        positions = jnp.broadcast_to(positions, (B, T))
    pos3 = batch.get("positions3")
    x, new_caches = apply_stack(x, params, cfg, par, positions=positions,
                                caches=caches, pos=pos, pos3=pos3)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, cfg, x, par)
    return logits, new_caches


def loss_fn(params, cfg: ModelConfig, batch, par: Par = DEFAULT_PAR,
            z_loss: float = 1e-4):
    logits, _ = forward(params, cfg, batch, par)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = batch.get("mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    denom = jnp.maximum(mask.sum(), 1.0)
    xent = (nll * mask).sum() / denom
    zl = z_loss * ((lse ** 2) * mask).sum() / denom
    return xent + zl, {"xent": xent, "z_loss": zl}


def make_train_step(cfg: ModelConfig, optimizer, par: Par = DEFAULT_PAR):
    """Returns train_step(state, batch) -> (state, metrics); state is
    (params, opt_state, step)."""

    from repro.optim.adamw import global_norm

    grad_fn = jax.value_and_grad(
        lambda p, b: loss_fn(p, cfg, b, par), has_aux=True)

    def train_step(state, batch):
        params, opt_state, step = state
        K = cfg.grad_accum
        if K > 1:
            # microbatching: K sequential grad microsteps, one optimizer
            # update — activation memory scales 1/K (grads are one f32
            # tree). The batch dim splits evenly across microbatches so
            # per-device sharding is unchanged.
            def to_micro(key, a):
                if key == "positions3":  # (3, B, T): batch is dim 1
                    return jnp.moveaxis(
                        a.reshape(a.shape[0], K, a.shape[1] // K,
                                  *a.shape[2:]), 1, 0)
                return a.reshape((K, a.shape[0] // K) + a.shape[1:])

            micro = {k: to_micro(k, v) for k, v in batch.items()}

            def acc(carry, b):
                gsum, lsum = carry
                (loss, aux), g = grad_fn(params, b)
                gsum = jax.tree.map(
                    lambda s, x: s + x.astype(jnp.float32), gsum, g)
                return (gsum, lsum + loss), aux

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), auxs = jax.lax.scan(acc, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / K, gsum)
            loss = lsum / K
            aux = jax.tree.map(lambda a: a.mean(), auxs)
        else:
            (loss, aux), grads = grad_fn(params, batch)
        gnorm = global_norm(grads)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: (p + u).astype(p.dtype),
                              params, updates)
        metrics = {"loss": loss, **aux, "grad_norm": gnorm}
        return (params, opt_state, step + 1), metrics

    return train_step


def make_eval_step(cfg: ModelConfig, par: Par = DEFAULT_PAR):
    def eval_step(params, batch):
        loss, aux = loss_fn(params, cfg, batch, par)
        return {"loss": loss, **aux}
    return eval_step


def make_prefill_step(cfg: ModelConfig, max_len: int,
                      par: Par = DEFAULT_PAR):
    """prefill(params, batch) -> (last_logits (B,V), caches, next_pos)."""

    def prefill(params, batch):
        inp = batch["embeds"] if "embeds" in batch else batch["tokens"]
        B, T = inp.shape[:2]
        caches = init_cache(cfg, B, max_len)
        logits, caches = forward(params, cfg, batch, par, caches=caches,
                                 pos=jnp.int32(0))
        return logits[:, -1], caches, jnp.int32(T)

    return prefill


def make_decode_step(cfg: ModelConfig, par: Par = DEFAULT_PAR):
    """decode(params, caches, tokens (B,1) | embeds, pos) ->
    (logits (B,V), caches, pos+1). One new token against the caches."""
    assert not cfg.is_encoder, f"{cfg.name} is encoder-only: no decode step"

    def decode(params, caches, tokens, pos):
        batch = ({"tokens": tokens} if cfg.embed_inputs
                 else {"embeds": tokens})
        B = tokens.shape[0]
        batch["positions"] = jnp.broadcast_to(
            pos[None, None].astype(jnp.int32), (B, 1))
        if cfg.mrope_sections is not None:
            batch["positions3"] = jnp.broadcast_to(
                pos[None, None, None].astype(jnp.int32), (3, B, 1))
        logits, caches = forward(params, cfg, batch, par, caches=caches,
                                 pos=pos)
        return logits[:, -1], caches, pos + 1

    return decode


def param_count(cfg: ModelConfig) -> int:
    """Exact parameter count via eval_shape (no allocation)."""
    import math
    shapes = jax.eval_shape(lambda: init_params(cfg))
    return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """Params touched per token (MoE: shared + top-k routed only)."""
    n = param_count(cfg)
    if not cfg.n_experts:
        return n
    Fe = cfg.moe_d_ff or cfg.d_ff
    D = cfg.d_model
    per_expert = 3 * D * Fe
    inactive = (cfg.n_experts - cfg.top_k) * per_expert * cfg.n_layers
    return n - inactive
